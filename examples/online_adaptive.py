"""Online adaptive control on a nonstationary Azure-like trace.

The controller estimates class arrival rates from a rolling window
(Eq. 50), re-solves the planning LP every 10 s, and retargets the
mixed/solo split (Eq. 51).  Compared against the same gate-and-route
policy with a *static* (initially mis-planned) split.

Run:  PYTHONPATH=src python examples/online_adaptive.py
"""

import numpy as np

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import TraceConfig, synth_azure_trace, trace_class_means
from repro.serving.engine_sim import ClusterEngine, EngineConfig

N = 10
prim = ServicePrimitives()
pricing = Pricing()

trace = synth_azure_trace(TraceConfig(horizon=600.0, compression=0.1, seed=7))
means = trace_class_means(trace, 2)  # [(P_mean, D_mean, rate), ...]
classes = [
    WorkloadClass(f"class{i}", prompt_len=means[i][0], decode_len=means[i][1],
                  arrival_rate=means[i][2] / N, patience=3e-4)
    for i in range(2)
]

# deliberately mis-planned static baseline (cold-start rates guess)
cold = [c.__class__(c.name, c.prompt_len, c.decode_len, 1e-3, c.patience)
        for c in classes]
static_plan = solve_bundled_lp(cold, prim, pricing)

for name, controller in (
    ("static (mis-planned)", None),
    ("online adaptive", OnlineController(
        classes, prim, pricing, n=N,
        config=OnlineControllerConfig(window=30.0, replan_every=10.0,
                                   safety=3.0))),
):
    policy = gate_and_route(static_plan)
    eng = ClusterEngine(classes, policy, EngineConfig(prim, pricing, N),
                        controller=controller)
    m = eng.run(trace, horizon=600.0)
    s = m.summary()
    print(f"{name:22s} revenue/s={s['revenue_rate']:8.2f} "
          f"completion={s['completion_rate']:.3f} "
          f"ttft_mean={s['ttft_mean']:.2f}s")
