"""Online adaptive control on a nonstationary workload scenario.

The controller estimates class arrival rates from a rolling window
(Eq. 50), re-solves the planning LP every 10 s, and retargets the
mixed/solo split (Eq. 51).  This demo uses the registry's `rate_shift`
scenario (arrival rate steps 2.5x at t = 120 s and the class mix flips)
and shows two things:

1. the rolling-window estimator *tracking* the shift: estimated
   vs true per-class rates, window by window
   (``trace_class_means_windowed`` is the ground truth);
2. the closed loop beating the same policy frozen on the hindsight
   static plan and on the cold-start plan
   (``repro.workloads.closed_loop``).

Run:  PYTHONPATH=src python examples/online_adaptive.py
"""

import numpy as np

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import trace_class_means, trace_class_means_windowed
from repro.workloads import ClosedLoopConfig, compare_policies, get_scenario

N = 8
WINDOW = 30.0
prim = ServicePrimitives()
pricing = Pricing()

scn = get_scenario("rate_shift")
trace = scn.generate(seed=0)

# -- 1) estimated vs true rates over time -----------------------------------
means = trace_class_means(trace, scn.n_classes)
classes = [
    WorkloadClass(scn.class_names[i], prompt_len=means[i][0],
                  decode_len=means[i][1], arrival_rate=means[i][2] / N,
                  patience=3e-4)
    for i in range(scn.n_classes)
]
ctrl = OnlineController(
    classes, prim, pricing, n=N,
    config=OnlineControllerConfig(window=WINDOW, replan_every=10.0,
                                  safety=1.0))

truth = trace_class_means_windowed(trace, scn.n_classes, WINDOW)
it = iter(trace)
r = next(it, None)
print(f"rolling-window estimator vs truth ({scn.name}, window={WINDOW:.0f}s,"
      f" shift at t=120s)")
print(f"{'window':>12s} | {'true rate/s':>18s} | {'estimated rate/s':>18s}")
for t0, t1, w_means in truth:
    while r is not None and r.t_arrival < t1:
        ctrl.observe_arrival(r.t_arrival, r.cls)
        r = next(it, None)
    # estimate_rates returns per-server rates inflated by the safety
    # factor; undo both to compare with the cluster-level truth
    lam_hat = ctrl.estimate_rates(t1) * N / ctrl.cfg.safety
    true_rates = [w_means[i][2] for i in range(scn.n_classes)]
    print(f"[{t0:4.0f},{t1:4.0f}) | "
          + np.array2string(np.array(true_rates), precision=2).rjust(18)
          + " | "
          + np.array2string(lam_hat, precision=2).rjust(18))

# -- 2) closed loop vs frozen plans -----------------------------------------
res = compare_policies(scn, ClosedLoopConfig(n_servers=N, seed=0),
                       variants=("adaptive", "static", "static_cold"))
print(f"\nclosed loop on {scn.name} (n={N}, {res['n_requests']} requests):")
for name, m in res["variants"].items():
    print(f"{name:12s} revenue/s={m['revenue_rate']:8.2f} "
          f"completion={m['completion_rate']:.3f} "
          f"ttft_p95={m['ttft_p95']:6.2f}s replans={int(m['replans'])}")
print(f"adaptive vs hindsight-static: {res['adaptive_lead_pct']:+.1f}% "
      f"revenue rate")
