"""Quickstart: plan with the fluid LP, control with gate-and-route.

Reproduces the paper's core loop in ~40 lines:
  1. define heterogeneous workload classes (P_i, D_i, lambda_i, theta_i),
  2. solve the steady-state planning LP (Eq. 40) for occupancy targets,
  3. run the stochastic cluster under the gate-and-route policy,
  4. check per-GPU revenue against the fluid optimum R*.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.planning import solve_bundled_lp
from repro.core.policies import baseline_sarathi, gate_and_route
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

# 1. Workload: a decode-heavy class (creative writing) and a prefill-heavy
#    class (summarization), with the paper's A100/Qwen3-8B calibration.
classes = [
    WorkloadClass("decode-heavy", prompt_len=300, decode_len=1000,
                  arrival_rate=0.5, patience=0.1),
    WorkloadClass("prefill-heavy", prompt_len=3000, decode_len=400,
                  arrival_rate=0.5, patience=0.1),
]
prim = ServicePrimitives()  # alpha=0.0174, beta=6.2e-5, B=16, C=256
pricing = Pricing(c_p=0.1, c_d=0.2)

# 2. Fluid planning LP
plan = solve_bundled_lp(classes, prim, pricing)
print(f"fluid-optimal per-GPU revenue R* = {plan.revenue_rate:.3f}/s")
print(f"prefill occupancy targets x*     = {plan.x.round(4)}")
print(f"mixed GPUs out of 200            = {plan.mixed_servers(200)}")

# 3. Stochastic system under gate-and-route vs a Sarathi-style heuristic
for policy in (gate_and_route(plan), baseline_sarathi(plan)):
    sim = CTMCSimulator(classes, prim, pricing, policy, n=200, seed=0)
    res = sim.run(horizon=400.0, warmup=100.0)
    gap = 100 * (1 - res.revenue_rate_per_server / plan.revenue_rate)
    print(f"{policy.name:18s} revenue/GPU/s = "
          f"{res.revenue_rate_per_server:.3f}  (gap to fluid: {gap:+.1f}%)")
