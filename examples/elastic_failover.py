"""Fault tolerance: server failures, recovery, and straggler mitigation.

At t=150s three servers fail (their in-flight prefills/decodes are
re-queued and re-prefilled); at t=300s they recover; one surviving server
runs 3x slow from t=150s.  The online controller observes capacity changes
and replans the LP each time, so the mixed/solo split tracks the shrunken
and restored cluster.

Run:  PYTHONPATH=src python examples/elastic_failover.py
"""

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import TraceConfig, synth_azure_trace, trace_class_means
from repro.serving.engine_sim import ClusterEngine, EngineConfig

N = 12
prim = ServicePrimitives()
pricing = Pricing()
trace = synth_azure_trace(TraceConfig(horizon=600.0, compression=0.06, seed=3))
means = trace_class_means(trace, 2)  # [(P_mean, D_mean, rate), ...]
classes = [
    WorkloadClass(f"class{i}", prompt_len=means[i][0], decode_len=means[i][1],
                  arrival_rate=means[i][2] / N, patience=3e-4)
    for i in range(2)
]
plan = solve_bundled_lp(classes, prim, pricing)

events = [
    (150.0, "fail", 0), (150.0, "fail", 1), (150.0, "fail", 2),
    (150.0, "straggle", 3, 3.0),          # server 3 runs 3x slow
    (300.0, "recover", 0), (300.0, "recover", 1), (300.0, "recover", 2),
    (300.0, "straggle", 3, 1.0),
]

for name, evs in (("healthy cluster", []), ("failures+straggler", events)):
    controller = OnlineController(
        classes, prim, pricing, n=N,
        config=OnlineControllerConfig(window=30.0, replan_every=10.0))
    eng = ClusterEngine(classes, gate_and_route(plan),
                        EngineConfig(prim, pricing, N),
                        controller=controller)
    m = eng.run(trace, horizon=600.0, failure_events=evs)
    s = m.summary()
    print(f"{name:20s} revenue/s={s['revenue_rate']:8.2f} "
          f"completions={s['completions']:4d} "
          f"ttft_p99={s['ttft_p99']:.2f}s mean={s['ttft_mean']:.2f}s")
