"""Serve a small model with batched requests on a real-compute cluster.

End-to-end data-plane demo: the LP plans the mixed/solo split, the
occupancy gate admits prefills, chunked prefill runs fused with decodes
(the paper's mixed iteration) as actual jitted compute, and completed
prefills migrate their KV to solo servers.

Run:  PYTHONPATH=src python examples/serve_cluster.py [--servers 4]
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:])
