"""Batched trace replay with the JAX iteration-level engine.

The paper's Table 2 / Fig. 4 policy comparison replays a calibrated
trace through the per-server scheduling simulator.  This example runs an
Azure-like synthetic trace under three policy families in
`repro.serving.engine_jax.ClusterEngineJAX` -- each policy an 8-
replication `jax.vmap` batch over PRNG keys -- and cross-checks
gate-and-route against the exact Python event loop
(`repro.serving.engine_sim.ClusterEngine`, the semantics oracle; the two
engines are held to statistical equivalence in
`tests/test_engine_jax.py`).

Run:  PYTHONPATH=src python examples/engine_jax_demo.py
"""

import numpy as np

from repro.core.planning import solve_bundled_lp
from repro.core.policies import baseline_sarathi, baseline_vllm, gate_and_route
from repro.core.types import Pricing, ServicePrimitives
from repro.data.traces import TraceConfig, synth_azure_trace
from repro.serving.engine_jax import ClusterEngineJAX
from repro.serving.engine_sim import ClusterEngine, EngineConfig
from repro.sweep.evaluators import planner_classes_from_trace

n, reps = 10, 8
PRIM, PRICE = ServicePrimitives(), Pricing(c_p=0.1, c_d=0.2)
tcfg = TraceConfig(horizon=30.0, base_rate=2.0, compression=0.06, seed=42)
trace = synth_azure_trace(tcfg)
classes = planner_classes_from_trace(trace, n)
plan = solve_bundled_lp(classes, PRIM, PRICE)
print(f"{len(trace)} requests over {tcfg.horizon}s, n={n} servers")

policies = [
    ("gate_and_route", gate_and_route(plan), {}),
    ("vllm", baseline_vllm(plan), {}),
    ("sarathi", baseline_sarathi(plan), dict(sarathi_budget=True)),
]
for name, pol, kw in policies:
    cfg = EngineConfig(PRIM, PRICE, n_servers=n, **kw)
    eng = ClusterEngineJAX(classes, pol, cfg, trace, horizon=tcfg.horizon)
    out = eng.run_batch(range(reps))
    rev = [m["revenue_rate"] for m in out]
    print(f"{name:15s} revenue/s = {np.mean(rev):8.2f}  "
          f"ttft_p95 = {out[0]['ttft_p95']:.3f}s  "
          f"completions = {out[0]['completions']:.0f}  "
          f"({reps} reps, step budget {eng.n_steps}, "
          f"budget_exhausted={out[0]['budget_exhausted']:.0f})")

# same trajectory law as the exact Python event loop (the oracle)
cfg = EngineConfig(PRIM, PRICE, n_servers=n)
m = ClusterEngine(classes, gate_and_route(plan), cfg).run(
    trace, horizon=tcfg.horizon).summary()
print(f"python oracle    revenue/s = {m['revenue_rate']:8.2f}  "
      f"ttft_p95 = {m['ttft_p95']:.3f}s  "
      f"completions = {m['completions']:.0f}")
