"""Batched CTMC replications with the uniformized JAX engine.

The EC.8.5 convergence question -- how fast does per-GPU revenue close
the gap to the fluid optimum R* as the cluster grows? -- needs many
independent replications per cluster size.  This example runs a
64-replication batch per n as ONE `jax.vmap`'d scan each
(`repro.core.ctmc_jax.UniformizedCTMC`), cross-checks the smallest size
against the exact Python event loop (same law, tested in
`tests/test_ctmc_jax.py`), and prints the shrinking gap.

Run:  PYTHONPATH=src python examples/ctmc_jax_demo.py
"""

import numpy as np

from repro.core.ctmc_jax import UniformizedCTMC
from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

classes = [
    WorkloadClass("decode-heavy", prompt_len=300, decode_len=1000,
                  arrival_rate=0.5, patience=0.1),
    WorkloadClass("prefill-heavy", prompt_len=3000, decode_len=400,
                  arrival_rate=0.5, patience=0.1),
]
prim, pricing = ServicePrimitives(), Pricing(c_p=0.1, c_d=0.2)
plan = solve_bundled_lp(classes, prim, pricing)
policy = gate_and_route(plan)
print(f"fluid-optimal per-GPU revenue R* = {plan.revenue_rate:.3f}/s")

horizon, warmup, reps = 60.0, 15.0, 64
for n in (20, 50, 200):
    sim = UniformizedCTMC(classes, prim, pricing, policy, n=n,
                          horizon=horizon, warmup=warmup)
    rates = [r.revenue_rate_per_server for r in sim.run_batch(range(reps))]
    gap = 100 * (1 - np.mean(rates) / plan.revenue_rate)
    hw = 1.96 * np.std(rates, ddof=1) / np.sqrt(reps)
    print(f"n={n:4d}: revenue/GPU/s = {np.mean(rates):7.3f} ± {hw:.3f} "
          f"({reps} reps, gap to R*: {gap:+.1f}%, "
          f"{sim.n_steps} scan steps)")

# same law as the exact Python event loop (here: 8 replications at n=20)
py = CTMCSimulator(classes, prim, pricing, policy, n=20)
py_rates = [r.revenue_rate_per_server
            for r in py.run_batch(horizon, warmup=warmup,
                                  rngs=np.random.SeedSequence(0).spawn(8))]
print(f"python oracle at n=20: revenue/GPU/s = {np.mean(py_rates):7.3f} "
      f"(8 reps)")
