"""Train a ~100M-parameter dense LM for a few hundred steps on CPU, with
checkpoint/restart (kill it mid-run and re-run: it resumes from the last
checkpoint, including the data cursor).

Run:  PYTHONPATH=src python examples/train_small.py
"""

from repro.launch.train import preset_100m, run_training

if __name__ == "__main__":
    out = run_training(
        preset_100m(),
        steps=300,
        batch=8,
        seq_len=256,
        microbatches=2,
        ckpt_dir="artifacts/ckpt_100m",
        ckpt_every=50,
    )
    first, last = out["losses"][0], out["final_loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {len(out['losses'])} steps")
