"""Pluggable iteration-time models consumed by every engine.

An :class:`IterationTimeModel` answers the two questions the serving
engines ask each iteration:

* ``tau_mix(C)`` -- seconds for a mixed iteration carrying a prefill
  chunk of ``C`` tokens;
* ``tau_solo(K)`` -- seconds for a decode-only iteration with ``K``
  aggregate resident KV tokens;

plus ``primitives()``, the projection onto the queueing-model constants
(:class:`ServicePrimitives`) that the planning LP / CTMC / fluid layers
consume -- so one calibration run reparameterizes the whole stack.

Registry (``MODELS``, names cross-checked against the docs by
``tools/check_docs.py``):

* ``affine`` -- the seed constants.  The default engine path; built so
  its arithmetic is *bitwise identical* to the engines' historical
  inline expressions (same op order: ``alpha + beta * c``).
* ``fitted`` -- an :class:`AffineModel` carrying the surfaces fitted
  from a :class:`CalibrationArtifact`.
* ``table`` -- piecewise-linear interpolation over the artifact's raw
  per-cell medians (constant extrapolation beyond the knots), for when
  the measured surface visibly bends away from affine.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Protocol, Tuple, runtime_checkable

from repro.core.types import DEFAULT_PRIMITIVES, ServicePrimitives

from .fit import fit_affine

__all__ = [
    "DEFAULT_SOLO_KV_SLOPE",
    "MODELS",
    "AffineModel",
    "IterationTimeModel",
    "TableModel",
    "engine_config_for_model",
    "list_models",
    "model_from_artifact",
]

# The engines' historical decode KV slope (paper Sec. 6.2: b_s for the
# A100 calibration); mirrors EngineConfig.solo_kv_slope's default.
DEFAULT_SOLO_KV_SLOPE = 1.08e-7


@runtime_checkable
class IterationTimeModel(Protocol):
    """What the engines require of an iteration-time model."""

    name: str
    kind: str  # "affine" | "table" -- engine_jax's static dispatch key

    def tau_mix(self, chunk: float) -> float: ...

    def tau_solo(self, kv_tokens: float) -> float: ...

    def primitives(self) -> ServicePrimitives: ...


@dataclass(frozen=True)
class AffineModel:
    """The paper's affine surfaces; default parameters = seed constants."""

    alpha: float = DEFAULT_PRIMITIVES.alpha
    beta: float = DEFAULT_PRIMITIVES.beta
    a_s: float = DEFAULT_PRIMITIVES.tau_solo
    b_s: float = DEFAULT_SOLO_KV_SLOPE
    batch_cap: int = DEFAULT_PRIMITIVES.batch_cap
    chunk: int = DEFAULT_PRIMITIVES.chunk
    name: str = "affine"
    kind: str = field(default="affine", init=False)

    def tau_mix(self, chunk: float) -> float:
        # op order matches the engines' historical inline expression
        # (alpha + beta * C) so the default model is bitwise identical
        return self.alpha + self.beta * chunk

    def tau_solo(self, kv_tokens: float) -> float:
        return self.a_s + self.b_s * kv_tokens

    def primitives(self) -> ServicePrimitives:
        return ServicePrimitives(alpha=self.alpha, beta=self.beta,
                                 gamma=1.0 / self.a_s,
                                 batch_cap=self.batch_cap, chunk=self.chunk)

    def jax_params(self) -> Dict[str, float]:
        return {"alpha": self.alpha, "beta": self.beta,
                "tau_solo": self.a_s, "b_s": self.b_s}

    @classmethod
    def from_primitives(cls, prim: ServicePrimitives,
                        solo_kv_slope: float = DEFAULT_SOLO_KV_SLOPE,
                        name: str = "affine") -> "AffineModel":
        return cls(alpha=prim.alpha, beta=prim.beta, a_s=prim.tau_solo,
                   b_s=solo_kv_slope, batch_cap=prim.batch_cap,
                   chunk=prim.chunk, name=name)

    @classmethod
    def from_artifact(cls, art, *, batch_cap: int = 16,
                      chunk: int = 256) -> "AffineModel":
        return cls(alpha=art.alpha, beta=art.beta, a_s=art.a_s,
                   b_s=art.b_s, batch_cap=batch_cap, chunk=chunk,
                   name="fitted")


def _interp(x: float, xs: Tuple[float, ...], ys: Tuple[float, ...]) -> float:
    """Piecewise-linear with constant extrapolation (jnp.interp semantics,
    so engine_sim and engine_jax agree on the table model exactly)."""
    if x <= xs[0]:
        return ys[0]
    if x >= xs[-1]:
        return ys[-1]
    for i in range(1, len(xs)):
        if x <= xs[i]:
            t = (x - xs[i - 1]) / (xs[i] - xs[i - 1])
            return ys[i - 1] + t * (ys[i] - ys[i - 1])
    return ys[-1]  # unreachable


@dataclass(frozen=True)
class TableModel:
    """Interpolated iteration-time surfaces over measured knots."""

    mix_x: Tuple[float, ...]  # chunk knots C
    mix_y: Tuple[float, ...]  # tau_mix at each knot
    solo_x: Tuple[float, ...]  # aggregate-KV knots K
    solo_y: Tuple[float, ...]  # tau_solo at each knot
    batch_cap: int = 16
    chunk: int = 256
    name: str = "table"
    kind: str = field(default="table", init=False)

    def __post_init__(self) -> None:
        for xs, ys, lbl in ((self.mix_x, self.mix_y, "mix"),
                            (self.solo_x, self.solo_y, "solo")):
            if len(xs) != len(ys) or len(xs) < 2:
                raise ValueError(f"table {lbl}: need >= 2 paired knots")
            if list(xs) != sorted(xs):
                raise ValueError(f"table {lbl}: knots must be increasing")

    def tau_mix(self, chunk: float) -> float:
        return _interp(float(chunk), self.mix_x, self.mix_y)

    def tau_solo(self, kv_tokens: float) -> float:
        return _interp(float(kv_tokens), self.solo_x, self.solo_y)

    def primitives(self) -> ServicePrimitives:
        """Affine projection of the knots (the LP/CTMC layers need the
        scalar (alpha, beta, gamma) abstraction regardless)."""
        mix = fit_affine(self.mix_x, self.mix_y)
        solo = fit_affine(self.solo_x, self.solo_y)
        return ServicePrimitives(alpha=mix.intercept, beta=mix.slope,
                                 gamma=1.0 / solo.intercept,
                                 batch_cap=self.batch_cap, chunk=self.chunk)

    def knots(self) -> Dict[str, Tuple[float, ...]]:
        """Knot arrays for engine_jax's jnp.interp step-kernel path."""
        return {"mix_x": self.mix_x, "mix_y": self.mix_y,
                "solo_x": self.solo_x, "solo_y": self.solo_y}

    @classmethod
    def from_artifact(cls, art, *, batch_cap: int = 16,
                      chunk: int = 256) -> "TableModel":
        # knots come from the reference (largest) batch, matching the
        # conditioning convention of fit.fit_surfaces
        ref_b = max(s.batch for s in art.samples)

        def knots(samples, key):
            by_x: Dict[float, list] = {}
            for s in samples:
                by_x.setdefault(float(getattr(s, key)), []).append(s.tau)
            xs = sorted(by_x)
            ys = []
            for x in xs:
                vals = sorted(by_x[x])
                n = len(vals)
                ys.append(vals[n // 2] if n % 2
                          else 0.5 * (vals[n // 2 - 1] + vals[n // 2]))
            return tuple(xs), tuple(ys)

        mx, my = knots([s for s in art.samples
                        if s.mode == "mixed" and s.batch == ref_b], "chunk")
        sx, sy = knots([s for s in art.samples
                        if s.mode == "solo" and s.batch == ref_b], "kv")
        return cls(mix_x=mx, mix_y=my, solo_x=sx, solo_y=sy,
                   batch_cap=batch_cap, chunk=chunk)


def model_from_artifact(art, kind: str = "fitted", **kw) -> IterationTimeModel:
    """Build a model of the given registry ``kind`` from an artifact."""
    if kind not in MODELS:
        raise KeyError(f"unknown model kind {kind!r}; have {list_models()}")
    return MODELS[kind](art, **kw)


# name -> factory(artifact | None, **kw).  "affine" ignores the artifact
# (it IS the seed constants); the artifact-backed kinds require one.
def _make_affine(art=None, **kw) -> AffineModel:
    return AffineModel(**kw)


def _make_fitted(art=None, **kw) -> AffineModel:
    if art is None:
        raise ValueError("model kind 'fitted' needs a CalibrationArtifact")
    return AffineModel.from_artifact(art, **kw)


def _make_table(art=None, **kw) -> TableModel:
    if art is None:
        raise ValueError("model kind 'table' needs a CalibrationArtifact")
    return TableModel.from_artifact(art, **kw)


MODELS: Dict[str, Callable[..., IterationTimeModel]] = {
    "affine": _make_affine,
    "fitted": _make_fitted,
    "table": _make_table,
}


def list_models() -> Tuple[str, ...]:
    return tuple(sorted(MODELS))


def engine_config_for_model(model: IterationTimeModel, *,
                            pricing=None, **engine_kw):
    """An ``EngineConfig`` wired to ``model`` (primitives + iter_model).

    Lazy import keeps :mod:`repro.serving.engine_sim` free of any
    calibration dependency -- the engines only know the protocol.
    """
    from repro.core.types import Pricing
    from repro.serving.engine_sim import EngineConfig

    return EngineConfig(
        prim=model.primitives(),
        pricing=pricing if pricing is not None else Pricing(),
        iter_model=model,
        **engine_kw,
    )
