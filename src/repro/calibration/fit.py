"""Robust affine fitting for the paper's iteration-time surfaces.

``fit_affine`` fits ``y = intercept + slope * x`` by ordinary least
squares followed by a few IRLS rounds with Huber weights, so a stray
timing outlier (a GC pause, a recompile) cannot tilt the surface.  The
degenerate constant-input case -- all ``x`` equal, or all ``y`` equal --
is reported explicitly instead of being papered over by the old
``ss_tot or 1.0`` trick in ``bench_calibration``.

No numpy dependency: the grids are tiny (tens of points) and pure-float
arithmetic keeps the fit bit-reproducible across platforms.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

__all__ = ["AffineFit", "FitDegenerateError", "fit_affine", "fit_surfaces"]

_HUBER_K = 1.345  # 95% Gaussian efficiency, the standard Huber constant
_IRLS_ROUNDS = 3


class FitDegenerateError(ValueError):
    """The inputs cannot identify an affine model (constant x)."""


@dataclass(frozen=True)
class AffineFit:
    """``y ~= intercept + slope * x`` with residual diagnostics."""

    intercept: float
    slope: float
    r2: float
    rmse: float
    max_abs_residual: float
    n: int
    constant_y: bool = False  # all y equal: slope is exactly 0 by fiat
    clamped: bool = False  # negative slope clamped to 0 (monotonicity)

    def __call__(self, x: float) -> float:
        return self.intercept + self.slope * x

    def to_dict(self) -> dict:
        return {
            "intercept": self.intercept,
            "slope": self.slope,
            "r2": self.r2,
            "rmse": self.rmse,
            "max_abs_residual": self.max_abs_residual,
            "n": self.n,
            "constant_y": self.constant_y,
            "clamped": self.clamped,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "AffineFit":
        return cls(intercept=float(d["intercept"]), slope=float(d["slope"]),
                   r2=float(d["r2"]), rmse=float(d["rmse"]),
                   max_abs_residual=float(d["max_abs_residual"]),
                   n=int(d["n"]), constant_y=bool(d["constant_y"]),
                   clamped=bool(d["clamped"]))


def _wls(xs: Sequence[float], ys: Sequence[float],
         ws: Sequence[float]) -> Tuple[float, float]:
    sw = sum(ws)
    mx = sum(w * x for w, x in zip(ws, xs)) / sw
    my = sum(w * y for w, y in zip(ws, ys)) / sw
    sxx = sum(w * (x - mx) ** 2 for w, x in zip(ws, xs))
    sxy = sum(w * (x - mx) * (y - my) for w, x, y in zip(ws, xs, ys))
    slope = sxy / sxx
    return my - slope * mx, slope


def fit_affine(xs: Sequence[float], ys: Sequence[float], *,
               clamp_nonnegative_slope: bool = True) -> AffineFit:
    """Huber-robust affine fit with explicit degenerate diagnostics.

    Raises :class:`FitDegenerateError` when ``x`` carries no spread (the
    slope is unidentifiable).  A constant-``y`` input is *not* an error
    -- the surface is flat -- but the fit is flagged ``constant_y`` so
    callers can surface it instead of trusting a fabricated R^2.
    """
    xs = [float(x) for x in xs]
    ys = [float(y) for y in ys]
    if len(xs) != len(ys) or len(xs) < 2:
        raise ValueError(f"need >= 2 paired points, got {len(xs)}/{len(ys)}")
    n = len(xs)
    if max(xs) - min(xs) <= 0.0:
        raise FitDegenerateError(
            f"all {n} x-values equal ({xs[0]!r}): affine slope is "
            f"unidentifiable; widen the calibration grid axis")

    if max(ys) - min(ys) <= 0.0:
        # Perfectly flat surface: intercept = the constant, slope = 0.
        return AffineFit(intercept=ys[0], slope=0.0, r2=1.0, rmse=0.0,
                         max_abs_residual=0.0, n=n, constant_y=True)

    ws = [1.0] * n
    intercept, slope = _wls(xs, ys, ws)
    for _ in range(_IRLS_ROUNDS):
        resid = [y - (intercept + slope * x) for x, y in zip(xs, ys)]
        # scale via MAD (fall back to rmse when MAD underflows)
        srt = sorted(abs(r) for r in resid)
        mad = srt[n // 2] if n % 2 else 0.5 * (srt[n // 2 - 1] + srt[n // 2])
        scale = 1.4826 * mad or math.sqrt(sum(r * r for r in resid) / n)
        if scale <= 0.0:
            break  # exact fit already
        ws = [1.0 if abs(r) <= _HUBER_K * scale
              else _HUBER_K * scale / abs(r) for r in resid]
        intercept, slope = _wls(xs, ys, ws)

    clamped = False
    if clamp_nonnegative_slope and slope < 0.0:
        # tau surfaces are physically non-decreasing in C and K; a
        # negative fitted slope is timing noise -- clamp and refit the
        # intercept as the (robust-weighted) mean.
        slope = 0.0
        intercept = sum(w * y for w, y in zip(ws, ys)) / sum(ws)
        clamped = True

    resid = [y - (intercept + slope * x) for x, y in zip(xs, ys)]
    ss_res = sum(r * r for r in resid)
    my = sum(ys) / n
    ss_tot = sum((y - my) ** 2 for y in ys)
    return AffineFit(
        intercept=intercept,
        slope=slope,
        r2=1.0 - ss_res / ss_tot,
        rmse=math.sqrt(ss_res / n),
        max_abs_residual=max(abs(r) for r in resid),
        n=n,
        clamped=clamped,
    )


def fit_surfaces(samples: Sequence, *,
                 batch: int = None) -> Dict[str, AffineFit]:
    """Fit both paper surfaces from a flat list of :class:`Sample` s.

    Mixed cells (``mode == "mixed"``) identify ``tau_mix(C)``; solo
    cells identify ``tau_solo(K)``.  Returns ``{"mix": fit, "solo":
    fit}`` where ``mix.intercept = alpha``, ``mix.slope = beta``,
    ``solo.intercept = a_s``, ``solo.slope = b_s``.

    The paper's surfaces are conditioned on a *full* decode batch, so
    the fit uses the reference batch -- ``batch`` if given, else the
    largest batch present.  Cells at smaller batches stay in the sample
    set as batch-sensitivity diagnostics but do not enter the
    regression (iteration time also moves with B, which would otherwise
    contaminate the C/K slopes).
    """
    samples = list(samples)
    if batch is None:
        if not samples:
            raise ValueError("no samples")
        batch = max(s.batch for s in samples)
    mix = [(s.chunk, s.tau) for s in samples
           if s.mode == "mixed" and s.batch == batch]
    solo = [(s.kv, s.tau) for s in samples
            if s.mode == "solo" and s.batch == batch]
    if not mix or not solo:
        raise ValueError(
            f"need both mixed and solo samples (got {len(mix)} mixed, "
            f"{len(solo)} solo)")
    return {
        "mix": fit_affine([x for x, _ in mix], [y for _, y in mix]),
        "solo": fit_affine([x for x, _ in solo], [y for _, y in solo]),
    }
