"""Kernel-calibrated iteration-time models (the paper's Section 6.2 loop).

The engines consume the state-dependent service-rate surface

    tau_mix(C) = alpha + beta * C     (mixed iteration, prefill chunk C)
    tau_solo(K) = a_s + b_s * K       (decode-only iteration, resident KV K)

This package closes the silicon -> queueing-model -> policy loop: it
*measures* those surfaces from the repo's own compute substrate -- the
Pallas kernels under :mod:`repro.kernels` on an accelerator, or the
deterministic analytic roofline (:mod:`repro.launch.roofline` physics +
``repro.launch.mesh.v5e_constants``) when none is attached -- robust-fits
the affine models with residual/R^2 diagnostics, and emits a versioned
JSON :class:`CalibrationArtifact`.  The result plugs back into every
engine through the :class:`IterationTimeModel` protocol
(``MODELS`` registry: ``affine`` | ``fitted`` | ``table``).

See ``docs/CALIBRATION.md`` for the grid design, fit method, model plug
points, artifact schema and fallback semantics.
"""

from .artifact import SCHEMA_VERSION, CalibrationArtifact
from .fit import AffineFit, FitDegenerateError, fit_affine, fit_surfaces
from .grid import CalibrationGrid
from .measure import (Sample, collect_samples, iteration_costs, roofline_tau,
                      timeit_median)
from .models import (DEFAULT_SOLO_KV_SLOPE, MODELS, AffineModel,
                     IterationTimeModel, TableModel, engine_config_for_model,
                     list_models, model_from_artifact)
from .run import calibrate

__all__ = [
    "SCHEMA_VERSION",
    "CalibrationArtifact",
    "AffineFit",
    "FitDegenerateError",
    "fit_affine",
    "fit_surfaces",
    "CalibrationGrid",
    "Sample",
    "collect_samples",
    "iteration_costs",
    "roofline_tau",
    "timeit_median",
    "DEFAULT_SOLO_KV_SLOPE",
    "MODELS",
    "AffineModel",
    "IterationTimeModel",
    "TableModel",
    "engine_config_for_model",
    "list_models",
    "model_from_artifact",
    "calibrate",
]
