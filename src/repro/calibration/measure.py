"""Timing backends for the calibration grid.

Two backends produce ``tau`` (seconds per engine iteration) for a grid
cell:

* ``"kernels"`` -- times the repo's own Pallas kernels through their
  public :mod:`repro.kernels` ``ops`` wrappers on the attached
  accelerator (warmup + median-of-k ``time.perf_counter``), and adds the
  analytic weight-streaming and launch-overhead terms the attention
  kernels alone cannot see.  Only meaningful on a real accelerator;
  interpret-mode timings measure the Python emulator, not silicon.
* ``"roofline"`` -- fully deterministic closed-form fallback: per-
  iteration FLOPs and HBM bytes from the :class:`ModelConfig` shape math
  (the same physics as ``launch/roofline.py``) against
  ``mesh.v5e_constants``.  The *additive* roofline sum (compute + memory
  + overhead, not the max) keeps the surface exactly affine in ``C`` and
  ``K``, so the fitter's R^2 diagnostic is meaningful and the
  no-accelerator path is reproducible bit-for-bit.

``backend="auto"`` picks ``"kernels"`` on TPU and ``"roofline"``
elsewhere.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.launch.mesh import v5e_constants
from repro.telemetry.timing import timeit_median

from .grid import CalibrationGrid, GridCell

__all__ = [
    "DEFAULT_OVERHEAD_S",
    "Sample",
    "collect_samples",
    "iteration_costs",
    "roofline_tau",
    "timeit_median",
]

# Fixed per-iteration launch/dispatch overhead for the analytic backend.
# Chosen at the scale of the paper's measured A100 intercepts (Sec. 6.2:
# alpha = 17.4 ms includes scheduler + launch cost the roofline terms
# cannot see); the exact value only shifts the fitted intercepts, never
# the slopes or the fit quality.
DEFAULT_OVERHEAD_S = 2e-3


@dataclass(frozen=True)
class Sample:
    """One timed grid cell."""

    mode: str  # "mixed" | "solo"
    batch: int
    chunk: int  # prefill chunk C (0 for solo)
    kv: int  # aggregate resident KV tokens K
    tau: float  # seconds per iteration
    backend: str  # "kernels" | "roofline"

    def to_dict(self) -> dict:
        return {"mode": self.mode, "batch": self.batch, "chunk": self.chunk,
                "kv": self.kv, "tau": self.tau, "backend": self.backend}

    @classmethod
    def from_dict(cls, d: dict) -> "Sample":
        return cls(mode=str(d["mode"]), batch=int(d["batch"]),
                   chunk=int(d["chunk"]), kv=int(d["kv"]),
                   tau=float(d["tau"]), backend=str(d["backend"]))


# timeit_median moved to repro.telemetry.timing (one canonical timing
# helper for calibration + every benchmark); re-exported here unchanged.


# --------------------------------------------------------------- analytic
def _dtype_bytes(cfg) -> int:
    return 2 if cfg.param_dtype == "bfloat16" else 4


def _attn_layer_stats(cfg) -> Dict[str, float]:
    """Per-attention-layer width and KV-cache bytes per resident token."""
    n_attn = d_attn = kv_bytes = 0
    specs = cfg.block_specs()
    el = 1 if cfg.kv_quant else _dtype_bytes(cfg)
    for s in specs:
        if s.mixer in ("attn", "attn_local"):
            n_attn += 1
            d_attn = cfg.attn.n_heads * cfg.attn.head_dim
            kv_bytes = 2 * cfg.attn.n_kv_heads * cfg.attn.head_dim * el
            if cfg.kv_quant:  # per-(token, kv-head) fp32 scales, K and V
                kv_bytes += 2 * cfg.attn.n_kv_heads * 4
        elif s.mixer == "mla":
            n_attn += 1
            d_attn = cfg.mla.n_heads * (cfg.mla.qk_nope_dim
                                        + cfg.mla.qk_rope_dim)
            kv_bytes = (cfg.mla.kv_lora_rank
                        + cfg.mla.qk_rope_dim) * _dtype_bytes(cfg)
        # ssm / rec layers carry O(1) state: no per-token KV growth
    return {"n_attn": n_attn, "d_attn": d_attn, "kv_bytes": kv_bytes}


def iteration_costs(cfg, *, tokens: int, kv_tokens: int) -> Dict[str, float]:
    """Closed-form FLOPs and HBM bytes for one engine iteration.

    ``tokens`` = tokens computed this iteration (prefill chunk + one per
    decode stream); ``kv_tokens`` = aggregate resident KV tokens across
    the batch.  Both terms are *linear* in their argument by
    construction, which is exactly the paper's affine-surface claim.
    """
    from repro.models.model import active_param_count

    n_active = active_param_count(cfg)
    st = _attn_layer_stats(cfg)
    flops = 2.0 * n_active * tokens + 4.0 * st["d_attn"] * st["n_attn"] * kv_tokens
    bytes_ = (float(n_active) * _dtype_bytes(cfg)
              + float(st["kv_bytes"]) * kv_tokens)
    return {"flops": flops, "bytes": bytes_}


def roofline_tau(cfg, *, tokens: int, kv_tokens: int,
                 hw: Optional[dict] = None,
                 overhead_s: float = DEFAULT_OVERHEAD_S) -> float:
    """Deterministic analytic iteration time (additive roofline sum)."""
    hw = hw or v5e_constants()
    c = iteration_costs(cfg, tokens=tokens, kv_tokens=kv_tokens)
    return (overhead_s + c["flops"] / hw["peak_flops_bf16"]
            + c["bytes"] / hw["hbm_bw"])


def _cell_tokens(cell: GridCell) -> int:
    # mixed iteration computes the prefill chunk plus one token per
    # decode stream; a solo iteration computes one token per stream.
    return cell.chunk + cell.batch if cell.mode == "mixed" else cell.batch


# ---------------------------------------------------------------- kernels
def _time_kernels_cell(cfg, cell: GridCell, *, reps: int) -> float:
    """Accelerator path: Pallas attention kernels + analytic rest.

    The attention ops see the cell's exact (C, K) shapes; the dense
    weight-stream and launch-overhead terms (shape-independent of C and
    K at fixed batch) come from the same closed form as the roofline
    backend, so both backends fit commensurable surfaces.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.prefill_attention.ops import prefill_attention

    if cfg.attn is None:
        raise ValueError(
            f"kernel backend needs an attention config (model "
            f"{cfg.name!r} has none); use backend='roofline'")
    H, KV, D = cfg.attn.n_heads, cfg.attn.n_kv_heads, cfg.attn.head_dim
    B = cell.batch
    key = jax.random.PRNGKey(0)
    dt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32

    # per-stream cache length covering the aggregate K
    S = max(1, math.ceil(cell.kv / B))
    qd = jax.random.normal(key, (B, 1, H, D), dt)
    kc = jax.random.normal(key, (B, S, KV, D), dt)
    vc = jax.random.normal(key, (B, S, KV, D), dt)
    kv_len = jnp.full((B,), S, jnp.int32)

    def run_decode():
        decode_attention(qd, kc, vc, kv_len).block_until_ready()

    tau = timeit_median(run_decode, reps=reps)

    if cell.mode == "mixed" and cell.chunk > 0:
        qp = jax.random.normal(key, (1, cell.chunk, H, D), dt)
        kp = jax.random.normal(key, (1, cell.chunk, KV, D), dt)

        def run_prefill():
            prefill_attention(qp, kp, kp).block_until_ready()

        tau += timeit_median(run_prefill, reps=reps)

    # analytic weight-stream + launch terms (attention already measured)
    hw = v5e_constants()
    from repro.models.model import active_param_count
    n_active = active_param_count(cfg)
    tau += (DEFAULT_OVERHEAD_S
            + 2.0 * n_active * _cell_tokens(cell) / hw["peak_flops_bf16"]
            + float(n_active) * _dtype_bytes(cfg) / hw["hbm_bw"])
    return tau


def _resolve_backend(backend: str) -> str:
    if backend != "auto":
        return backend
    try:
        import jax
        return "kernels" if jax.default_backend() == "tpu" else "roofline"
    except Exception:
        return "roofline"


def collect_samples(grid: CalibrationGrid, cfg, *, backend: str = "auto",
                    reps: int = 5) -> List[Sample]:
    """Time every grid cell; returns one :class:`Sample` per cell."""
    backend = _resolve_backend(backend)
    if backend not in ("kernels", "roofline"):
        raise ValueError(f"unknown backend {backend!r}")
    out: List[Sample] = []
    for cell in grid.cells():
        if backend == "kernels":
            tau = _time_kernels_cell(cfg, cell, reps=reps)
        else:
            tau = roofline_tau(cfg, tokens=_cell_tokens(cell),
                               kv_tokens=cell.kv)
        out.append(Sample(mode=cell.mode, batch=cell.batch, chunk=cell.chunk,
                          kv=cell.kv, tau=tau, backend=backend))
    return out
