"""Calibration grid: the (batch B x prefill-chunk C x KV-tokens K) cells.

Two cell families, mirroring the paper's Fig. 3 measurement design:

* **mixed** cells ``(B, C, K0)`` vary the prefill chunk size ``C`` at a
  fixed baseline resident KV load ``K0`` -- these identify
  ``tau_mix(C) = alpha + beta * C``;
* **solo** cells ``(B, K)`` vary the aggregate resident KV tokens ``K``
  (spread over the ``B`` decode streams) -- these identify
  ``tau_solo(K) = a_s + b_s * K``.

``K`` is the *server-aggregate* KV residency (the quantity
``_Server.kv_tokens()`` tracks in the engine), not per-stream length.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

__all__ = ["CalibrationGrid", "GridCell"]


@dataclass(frozen=True)
class GridCell:
    """One timing cell; ``chunk == 0`` marks a decode-only (solo) cell."""

    mode: str  # "mixed" | "solo"
    batch: int
    chunk: int  # prefill chunk C (0 for solo cells)
    kv: int  # aggregate resident KV tokens K


@dataclass(frozen=True)
class CalibrationGrid:
    batch: Tuple[int, ...] = (8, 16)
    chunk: Tuple[int, ...] = (32, 64, 128, 256, 512)
    kv: Tuple[int, ...] = (256, 1024, 4096, 8192)
    kv_mixed: int = 1024  # baseline K during the mixed-cell chunk sweep

    def __post_init__(self) -> None:
        for name in ("batch", "chunk", "kv"):
            vals = getattr(self, name)
            if not vals or any(int(v) <= 0 for v in vals):
                raise ValueError(f"grid axis {name!r} needs positive entries")
            if list(vals) != sorted(set(int(v) for v in vals)):
                raise ValueError(
                    f"grid axis {name!r} must be strictly increasing")
        if len(self.chunk) < 2 or len(self.kv) < 2:
            raise ValueError("need >= 2 chunk and >= 2 kv points to "
                             "identify the affine slopes")
        if self.kv_mixed <= 0:
            raise ValueError("kv_mixed must be positive")

    @classmethod
    def default(cls) -> "CalibrationGrid":
        return cls()

    @classmethod
    def tiny(cls) -> "CalibrationGrid":
        """CPU-smoke grid (CI's ``calibration-smoke`` step)."""
        return cls(batch=(8,), chunk=(32, 64, 128), kv=(256, 1024, 2048),
                   kv_mixed=512)

    def mixed_cells(self) -> Iterator[GridCell]:
        for b in self.batch:
            for c in self.chunk:
                yield GridCell("mixed", int(b), int(c), int(self.kv_mixed))

    def solo_cells(self) -> Iterator[GridCell]:
        for b in self.batch:
            for k in self.kv:
                yield GridCell("solo", int(b), 0, int(k))

    def cells(self) -> Iterator[GridCell]:
        yield from self.mixed_cells()
        yield from self.solo_cells()

    @property
    def n_cells(self) -> int:
        return len(self.batch) * (len(self.chunk) + len(self.kv))

    # ------------------------------------------------------------- schema
    def to_dict(self) -> dict:
        return {"batch": list(self.batch), "chunk": list(self.chunk),
                "kv": list(self.kv), "kv_mixed": int(self.kv_mixed)}

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationGrid":
        return cls(batch=tuple(int(v) for v in d["batch"]),
                   chunk=tuple(int(v) for v in d["chunk"]),
                   kv=tuple(int(v) for v in d["kv"]),
                   kv_mixed=int(d["kv_mixed"]))
