"""Versioned JSON calibration artifact.

A :class:`CalibrationArtifact` is the full provenance of one calibration
run: the grid, every raw timing sample, both fitted surfaces with their
diagnostics, and the hardware constants in force.  JSON serialisation is
*lossless* -- floats go through Python's shortest-round-trip repr, so
``from_json(to_json(a)) == a`` exactly (a property test pins this).

Schema versioning: ``schema_version`` is written into every artifact and
checked on load; bump :data:`SCHEMA_VERSION` on any breaking layout
change.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Tuple

from repro.core.types import ServicePrimitives

from .fit import AffineFit
from .grid import CalibrationGrid
from .measure import Sample

__all__ = ["SCHEMA_VERSION", "CalibrationArtifact"]

SCHEMA_VERSION = 1


@dataclass(frozen=True)
class CalibrationArtifact:
    """One calibration run: grid + raw samples + fitted surfaces."""

    arch: str
    backend: str  # "kernels" | "roofline"
    grid: CalibrationGrid
    samples: Tuple[Sample, ...]
    mix: AffineFit  # tau_mix(C):  alpha = intercept, beta = slope
    solo: AffineFit  # tau_solo(K): a_s = intercept,  b_s = slope
    hw: Dict[str, float]
    created: str = ""  # ISO timestamp, caller-supplied (may be empty)
    schema_version: int = SCHEMA_VERSION

    # ------------------------------------------------------ paper names
    @property
    def alpha(self) -> float:
        return self.mix.intercept

    @property
    def beta(self) -> float:
        return self.mix.slope

    @property
    def a_s(self) -> float:
        return self.solo.intercept

    @property
    def b_s(self) -> float:
        return self.solo.slope

    @property
    def min_r2(self) -> float:
        return min(self.mix.r2, self.solo.r2)

    def primitives(self, *, batch_cap: int = 16,
                   chunk: int = 256) -> ServicePrimitives:
        """Project the fitted surfaces onto the queueing-model constants.

        ``gamma = 1 / a_s`` evaluates tau_solo at ``K = 0``; the KV slope
        ``b_s`` lives outside :class:`ServicePrimitives` (the engines
        carry it separately) and is exposed via :attr:`b_s`.
        """
        return ServicePrimitives(alpha=self.alpha, beta=self.beta,
                                 gamma=1.0 / self.a_s,
                                 batch_cap=batch_cap, chunk=chunk)

    # ----------------------------------------------------------- (de)ser
    def to_dict(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "arch": self.arch,
            "backend": self.backend,
            "created": self.created,
            "grid": self.grid.to_dict(),
            "samples": [s.to_dict() for s in self.samples],
            "fits": {"mix": self.mix.to_dict(), "solo": self.solo.to_dict()},
            "hw": dict(self.hw),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationArtifact":
        ver = int(d.get("schema_version", -1))
        if ver != SCHEMA_VERSION:
            raise ValueError(
                f"calibration artifact schema_version {ver} != supported "
                f"{SCHEMA_VERSION}; re-run the calibration")
        return cls(
            arch=str(d["arch"]),
            backend=str(d["backend"]),
            grid=CalibrationGrid.from_dict(d["grid"]),
            samples=tuple(Sample.from_dict(s) for s in d["samples"]),
            mix=AffineFit.from_dict(d["fits"]["mix"]),
            solo=AffineFit.from_dict(d["fits"]["solo"]),
            hw={k: float(v) for k, v in d["hw"].items()},
            created=str(d.get("created", "")),
            schema_version=ver,
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationArtifact":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path) -> "CalibrationArtifact":
        return cls.from_json(Path(path).read_text())
