"""End-to-end calibration driver + CLI.

``calibrate()`` runs the full pipeline -- grid -> timing backend -> robust
fit -> artifact -- for one architecture from the :mod:`repro.configs`
registry.  The CLI writes the artifact JSON and prints a one-line summary
with the fitted paper constants and fit diagnostics:

    python -m repro.calibration --arch qwen2-0.5b --backend roofline \
        --tiny --out artifacts/calibration/qwen2-0.5b.json
"""

from __future__ import annotations

import argparse
from typing import Optional

from repro.configs import ARCHS, get_config
from repro.launch.mesh import v5e_constants

from .artifact import CalibrationArtifact
from .fit import fit_surfaces
from .grid import CalibrationGrid
from .measure import collect_samples

__all__ = ["calibrate"]


def calibrate(arch: str = "qwen2-0.5b", *,
              grid: Optional[CalibrationGrid] = None,
              backend: str = "auto", reps: int = 5, reduced: bool = False,
              created: str = "") -> CalibrationArtifact:
    """Time + fit one architecture; returns the artifact (not saved)."""
    grid = grid or CalibrationGrid.default()
    cfg = get_config(arch, reduced=reduced)
    samples = collect_samples(grid, cfg, backend=backend, reps=reps)
    fits = fit_surfaces(samples)
    return CalibrationArtifact(
        arch=arch,
        backend=samples[0].backend,
        grid=grid,
        samples=tuple(samples),
        mix=fits["mix"],
        solo=fits["solo"],
        hw={k: float(v) for k, v in v5e_constants().items()},
        created=created,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "kernels", "roofline"])
    ap.add_argument("--tiny", action="store_true",
                    help="CI-smoke grid instead of the default grid")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-smoke) model config")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=None, help="artifact JSON path")
    args = ap.parse_args(argv)

    grid = CalibrationGrid.tiny() if args.tiny else CalibrationGrid.default()
    art = calibrate(args.arch, grid=grid, backend=args.backend,
                    reps=args.reps, reduced=args.reduced)
    print(f"[calibrate] {art.arch} backend={art.backend} "
          f"alpha={art.alpha:.6g} beta={art.beta:.6g} "
          f"a_s={art.a_s:.6g} b_s={art.b_s:.6g} "
          f"r2(mix)={art.mix.r2:.4f} r2(solo)={art.solo.r2:.4f}")
    if args.out:
        path = art.save(args.out)
        print(f"[calibrate] wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
