"""``python -m repro.calibration`` — the calibration CLI.

A separate ``__main__`` (rather than ``python -m repro.calibration.run``)
so runpy does not re-execute a module the package ``__init__`` already
imported.
"""

import sys

from .run import main

if __name__ == "__main__":
    sys.exit(main())
