"""Chrome-trace / Perfetto ``trace_event`` JSON export.

Renders request lifecycles and closed-loop replan epochs as a browsable
timeline: load the emitted file in ``chrome://tracing`` or
https://ui.perfetto.dev.  The format is the Trace Event Format's JSON
object form -- ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with
``"X"`` (complete) events carrying ``ts``/``dur`` in *microseconds* and
``"i"`` (instant) events marking replans.

Inputs are plain *lifecycle records*: one dict per request with
``rid``/``cls`` plus the timestamps the engines track anyway --
``t_arr`` (arrival), optional ``t_admit`` (prefill start) and
``t_prefill_done`` (the Python engine knows these), ``t_first`` (first
decode emission) and ``t_last`` (last emission).  The JAX engines only
carry arrival/first/last, so their queue-wait and prefill spans merge
into one ``wait+prefill`` span; the Python engine renders all three
phases.  :func:`validate_trace` is the schema gate CI's
``telemetry-smoke`` runs on every emitted file.
"""

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Iterable, Optional

__all__ = [
    "TRACE_SCHEMA_VERSION",
    "lifecycle_events",
    "replan_events",
    "trace_payload",
    "validate_trace",
    "write_trace",
]

TRACE_SCHEMA_VERSION = 1

_PID_REQUESTS = 1
_PID_CONTROL = 2
_PHASES = ("queue", "prefill", "wait+prefill", "decode")


def _us(t: float) -> float:
    return float(t) * 1e6


def _finite(v) -> bool:
    return v is not None and math.isfinite(float(v))


def _span(name: str, cat: str, tid: int, t0: float, t1: float,
          args: Optional[dict] = None) -> dict:
    ev = {"name": name, "cat": cat, "ph": "X", "ts": _us(t0),
          "dur": max(_us(t1) - _us(t0), 0.0), "pid": _PID_REQUESTS,
          "tid": int(tid)}
    if args:
        ev["args"] = args
    return ev


def lifecycle_events(records: Iterable[dict]) -> list:
    """Trace events for request lifecycles.

    Each record renders up to three spans on its own track (``tid`` =
    request id): the queue wait (arrival -> prefill admit), the prefill
    span (admit -> prefill done) and the decode span (first -> last
    emission).  Records without admit/prefill-done timestamps (the JAX
    engines) merge the first two into one ``wait+prefill`` span ending
    at the first emission.
    """
    events = []
    for r in records:
        rid = int(r["rid"])
        cat = str(r.get("cls", "request"))
        t_arr = r.get("t_arr")
        t_admit = r.get("t_admit")
        t_pfd = r.get("t_prefill_done")
        t_first = r.get("t_first")
        t_last = r.get("t_last")
        args = {"state": r.get("state", "")} if r.get("state") else None
        if _finite(t_arr) and _finite(t_admit):
            events.append(_span("queue", cat, rid, t_arr, t_admit, args))
            if _finite(t_pfd):
                events.append(_span("prefill", cat, rid, t_admit, t_pfd))
        elif _finite(t_arr) and _finite(t_first):
            events.append(
                _span("wait+prefill", cat, rid, t_arr, t_first, args))
        if _finite(t_first) and _finite(t_last):
            events.append(_span("decode", cat, rid, t_first, t_last))
    return events


def replan_events(replans: Iterable) -> list:
    """Instant events for closed-loop replan epochs.  Each entry is a
    time (seconds) or a ``(time, args-dict)`` pair."""
    events = []
    for rp in replans:
        if isinstance(rp, (tuple, list)):
            t, args = rp[0], dict(rp[1])
        else:
            t, args = rp, None
        ev = {"name": "replan", "cat": "control", "ph": "i",
              "ts": _us(t), "pid": _PID_CONTROL, "tid": 0, "s": "g"}
        if args:
            ev["args"] = args
        events.append(ev)
    return events


def trace_payload(events: list, *, source: str = "repro") -> dict:
    return {
        "traceEvents": list(events),
        "displayTimeUnit": "ms",
        "otherData": {"schema_version": TRACE_SCHEMA_VERSION,
                      "source": source},
    }


def write_trace(path, events: list, *, source: str = "repro") -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(trace_payload(events, source=source)))
    return p


def validate_trace(obj) -> list:
    """Schema check for an emitted trace (parsed JSON or a path);
    returns error strings (empty = valid Trace Event Format)."""
    if isinstance(obj, (str, Path)):
        try:
            obj = json.loads(Path(obj).read_text())
        except (OSError, json.JSONDecodeError) as exc:
            return [f"unreadable trace file: {exc}"]
    errors = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    sv = (obj.get("otherData") or {}).get("schema_version")
    if sv is not None and (not isinstance(sv, int)
                           or sv > TRACE_SCHEMA_VERSION or sv < 1):
        errors.append(f"otherData.schema_version {sv!r} outside "
                      f"[1, {TRACE_SCHEMA_VERSION}]")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: event must be an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            errors.append(f"{where}: ph {ph!r} not one of X/i/M")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        if ph in ("X", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or not math.isfinite(ts):
                errors.append(f"{where}: ts must be a finite number")
        if ph == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float))
                    or not math.isfinite(dur) or dur < 0):
                errors.append(f"{where}: dur must be a finite "
                              f"non-negative number")
            if ev.get("name") in _PHASES and ev.get("pid") != _PID_REQUESTS:
                errors.append(f"{where}: lifecycle span on pid "
                              f"{ev.get('pid')!r} (expected "
                              f"{_PID_REQUESTS})")
        if len(errors) > 50:
            errors.append("... (further errors suppressed)")
            break
    return errors
