"""On-device observability for the engine stack.

Three layers (see ``docs/OBSERVABILITY.md``):

* :mod:`repro.telemetry.probes` -- jit-compatible fixed-shape state
  probes threaded through the engine scan carries (time-binned
  trajectories, counters, on-device latency histograms), plus the
  pure-Python :class:`PyProbes` twin and the host-side
  :func:`extract_probes` report.
* :mod:`repro.telemetry.trace` -- Chrome-trace/Perfetto ``trace_event``
  JSON export of request lifecycles and replan epochs.
* :mod:`repro.telemetry.manifest` -- schema-versioned ``RunRecord``
  JSONL provenance for every artifact-producing entry point.

``python -m repro.telemetry`` renders trajectory/SLI reports and
validates emitted trace/manifest files.
"""

from .manifest import (MANIFEST_SCHEMA_VERSION, append_record,
                       default_manifest_path, payload_digest, read_records,
                       run_record, validate_record)
from .probes import (PROBES, ProbeSpec, PyProbes, extract_probes,
                     hist_attainment, hist_edges, hist_percentile,
                     resolve_probe_spec)
from .timing import timeit_median
from .trace import (TRACE_SCHEMA_VERSION, lifecycle_events, replan_events,
                    trace_payload, validate_trace, write_trace)

__all__ = [
    "MANIFEST_SCHEMA_VERSION",
    "PROBES",
    "ProbeSpec",
    "PyProbes",
    "TRACE_SCHEMA_VERSION",
    "append_record",
    "default_manifest_path",
    "extract_probes",
    "hist_attainment",
    "hist_edges",
    "hist_percentile",
    "lifecycle_events",
    "payload_digest",
    "read_records",
    "replan_events",
    "resolve_probe_spec",
    "run_record",
    "timeit_median",
    "trace_payload",
    "validate_record",
    "validate_trace",
    "write_trace",
]
