"""Schema-versioned run manifests (``RunRecord`` JSONL).

Every artifact-producing entry point -- the sweep runner,
``run_closed_loop`` and each ``benchmarks/run.py`` benchmark -- emits
one :func:`run_record` describing *how* its outputs were produced: git
SHA, JAX/numpy versions, placement/evaluator, spec hash, wall-clock and
sha256 digests of the artifacts written.  Records append to
``artifacts/manifests/runs.jsonl`` (one JSON object per line) and are
also embedded under the ``"manifest"`` key of ``artifacts/bench/*.json``
payloads, which ``tools/check_bench.py`` gates: a committed benchmark
artifact without a valid manifest fails CI.

The schema is hand-validated (:func:`validate_record`) -- no jsonschema
dependency -- and versioned by ``MANIFEST_SCHEMA_VERSION`` so later PRs
can evolve it without breaking old readers.  ``payload_digest`` hashes
the *canonical* JSON form of a payload with its ``"manifest"`` key
removed, so the embedded record never hashes itself.
"""

from __future__ import annotations

import hashlib
import json
import platform
import subprocess
import sys
import time
from pathlib import Path
from typing import Iterable, Optional

__all__ = [
    "MANIFEST_SCHEMA_VERSION",
    "append_record",
    "default_manifest_path",
    "file_digest",
    "git_sha",
    "payload_digest",
    "read_records",
    "run_record",
    "validate_record",
]

MANIFEST_SCHEMA_VERSION = 1

# required key -> allowed types (None allowed where recorded as nullable)
_SCHEMA = {
    "schema_version": (int,),
    "kind": (str,),
    "name": (str,),
    "created_unix": (int, float),
    "git_sha": (str, type(None)),
    "jax_version": (str, type(None)),
    "numpy_version": (str, type(None)),
    "python": (str,),
    "platform": (str,),
    "wall_s": (int, float, type(None)),
    "extra": (dict,),
    "artifacts": (dict,),
}
_KINDS = ("bench", "sweep", "closed_loop", "telemetry")


def git_sha(root: Optional[Path] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, check=True, timeout=10)
        sha = out.stdout.strip()
        return sha or None
    except Exception:
        return None


def payload_digest(payload: dict) -> str:
    """sha256 of the canonical JSON form, ``"manifest"`` key excluded
    (so a digest embedded next to the record stays self-consistent)."""
    body = {k: v for k, v in payload.items() if k != "manifest"}
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"),
                      default=float)
    return hashlib.sha256(blob.encode()).hexdigest()


def file_digest(path) -> str:
    return hashlib.sha256(Path(path).read_bytes()).hexdigest()


def _jax_version() -> Optional[str]:
    try:
        import jax
        return str(jax.__version__)
    except Exception:
        return None


def _numpy_version() -> Optional[str]:
    try:
        import numpy
        return str(numpy.__version__)
    except Exception:
        return None


def run_record(*, kind: str, name: str, wall_s: Optional[float] = None,
               extra: Optional[dict] = None,
               artifacts: Optional[dict] = None,
               root: Optional[Path] = None) -> dict:
    """One schema-versioned RunRecord.

    ``kind`` is the producing subsystem (one of ``bench``, ``sweep``,
    ``closed_loop``, ``telemetry``); ``extra`` carries free-form
    provenance (placement, evaluator, spec hash, payload digest...);
    ``artifacts`` maps artifact paths to their sha256 digests.
    """
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
    return {
        "schema_version": MANIFEST_SCHEMA_VERSION,
        "kind": kind,
        "name": str(name),
        "created_unix": time.time(),
        "git_sha": git_sha(root),
        "jax_version": _jax_version(),
        "numpy_version": _numpy_version(),
        "python": platform.python_version(),
        "platform": f"{sys.platform}-{platform.machine()}",
        "wall_s": None if wall_s is None else float(wall_s),
        "extra": dict(extra or {}),
        "artifacts": {str(k): str(v)
                      for k, v in (artifacts or {}).items()},
    }


def validate_record(record) -> list:
    """Schema check; returns a list of error strings (empty = valid)."""
    errors = []
    if not isinstance(record, dict):
        return [f"record must be a JSON object, got "
                f"{type(record).__name__}"]
    for key, types in _SCHEMA.items():
        if key not in record:
            errors.append(f"missing required key {key!r}")
        elif not isinstance(record[key], types):
            errors.append(
                f"key {key!r}: expected "
                f"{'/'.join(t.__name__ for t in types)}, got "
                f"{type(record[key]).__name__}")
    if isinstance(record.get("schema_version"), int) and (
            record["schema_version"] > MANIFEST_SCHEMA_VERSION
            or record["schema_version"] < 1):
        errors.append(
            f"schema_version {record['schema_version']} outside the "
            f"supported range [1, {MANIFEST_SCHEMA_VERSION}]")
    if "kind" in record and record.get("kind") not in _KINDS:
        errors.append(f"kind {record.get('kind')!r} not one of {_KINDS}")
    for k, v in (record.get("artifacts") or {}).items():
        if not isinstance(v, str):
            errors.append(f"artifacts[{k!r}]: digest must be a string")
    return errors


def default_manifest_path(root: Optional[Path] = None) -> Path:
    base = Path(root) if root is not None else Path.cwd()
    return base / "artifacts" / "manifests" / "runs.jsonl"


def append_record(record: dict, path=None) -> Path:
    """Append one record to the JSONL manifest (creating it); returns
    the path written.  Raises on an invalid record -- provenance files
    must never accumulate garbage."""
    errs = validate_record(record)
    if errs:
        raise ValueError(f"invalid RunRecord: {'; '.join(errs)}")
    p = Path(path) if path is not None else default_manifest_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    with p.open("a") as fh:
        fh.write(json.dumps(record, sort_keys=True, default=float) + "\n")
    return p


def read_records(path) -> Iterable[dict]:
    for i, line in enumerate(Path(path).read_text().splitlines()):
        line = line.strip()
        if not line:
            continue
        try:
            yield json.loads(line)
        except json.JSONDecodeError as exc:
            raise ValueError(f"{path}:{i + 1}: invalid JSONL ({exc})")
