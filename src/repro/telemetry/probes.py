"""Fixed-shape, jit-compatible state probes for every engine.

The probe layer answers the question the end-of-run summaries cannot:
*what did the state trajectory look like?*  Per-class queue depth,
decode occupancy, prefill chunks in flight, gate admit/drop counters and
per-server busy time are captured as **time-binned fixed-shape arrays
carried through the scan** -- no host round-trips, no data-dependent
shapes, so the probed step jits, vmaps and shard_maps exactly like the
bare one.  On-device latency histograms (TTFT and E2E analogues from the
request admit -> first-iteration -> done timestamps the engines already
track) yield SLI-attainment percentiles straight from the carry.

Design contract (enforced by ``tests/test_telemetry.py`` differential
tests, ``docs/OBSERVABILITY.md`` carries the derivations):

* ``telemetry=None`` (the default) adds **zero** carry keys and leaves
  the step function byte-identical -- the bitwise-no-change guarantee is
  structural, not numerical;
* with a :class:`ProbeSpec`, every probe lives under a ``tlm_``-prefixed
  carry key that the engines' summary paths never read, so the
  non-telemetry outputs stay bitwise identical even with probes ON;
* trajectory probes are *last-value-per-bin* scatters (the value at the
  end of each time bin; empty bins forward-fill host-side), counters are
  per-bin adds, busy time is an indicator integral attributed to the bin
  the interval starts in, and probe writes happen once per loop step
  (= once per ``k_events`` block in the multi-event hot path);
* latency histograms use log-spaced bucket edges
  (:func:`hist_edges`); percentiles interpolate within the matched
  bucket, so they are resolution-limited estimates, not exact order
  statistics.

:class:`ProbeSpec` is a frozen (hashable) dataclass precisely so it can
ride through ``jax.jit(..., static_argnames=...)`` as a compile-time
static: probes-off and probes-on are different compiled kernels, never a
runtime branch.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

__all__ = [
    "PROBES",
    "ProbeDef",
    "ProbeSpec",
    "PyProbes",
    "extract_probes",
    "hist_attainment",
    "hist_edges",
    "hist_percentile",
    "resolve_probe_spec",
]


@dataclass(frozen=True)
class ProbeSpec:
    """Compile-time probe configuration (hashable -> usable as a jit
    static).  ``n_bins`` time bins partition ``[0, horizon]``;
    ``n_hist`` log-spaced latency buckets span
    ``[hist_min, hist_max]`` seconds (under/overflow land in the edge
    buckets)."""

    n_bins: int = 64
    n_hist: int = 32
    hist_min: float = 1e-3
    hist_max: float = 1e3

    def __post_init__(self):
        if self.n_bins < 1 or self.n_hist < 2:
            raise ValueError(
                f"need n_bins >= 1 and n_hist >= 2, got "
                f"{self.n_bins}/{self.n_hist}")
        if not 0 < self.hist_min < self.hist_max:
            raise ValueError(
                f"need 0 < hist_min < hist_max, got "
                f"{self.hist_min}/{self.hist_max}")


def resolve_probe_spec(telemetry) -> Optional[ProbeSpec]:
    """Coerce the ``telemetry`` kwarg every entry point accepts:
    ``None``/``False`` -> off, ``True`` -> default spec, a dict (e.g.
    from ``spec.extra`` JSON) -> ``ProbeSpec(**dict)``, a spec ->
    itself."""
    if telemetry is None or telemetry is False:
        return None
    if telemetry is True:
        return ProbeSpec()
    if isinstance(telemetry, dict):
        return ProbeSpec(**telemetry)
    if isinstance(telemetry, ProbeSpec):
        return telemetry
    raise TypeError(f"telemetry must be None/bool/dict/ProbeSpec, "
                    f"got {type(telemetry).__name__}")


@dataclass(frozen=True)
class ProbeDef:
    """One registered probe: its carry key, shape axes and fill rule."""

    key: str  # carry key ("tlm_" prefix keeps it out of summary paths)
    axes: str  # human-readable shape, e.g. "(n_bins, I)"
    fill: str  # "last" | "sum" | "integral" | "hist"
    description: str


# The probe registry: the single source of truth check_docs.py holds
# docs/OBSERVABILITY.md against (both directions).  Keys are the public
# probe names; ``key`` is the scan-carry array each engine threads.
PROBES: Dict[str, ProbeDef] = {
    "queue_depth": ProbeDef(
        "tlm_q", "(n_bins, I)", "last",
        "per-class prefill-queue depth at the end of each time bin"),
    "decode_occupancy": ProbeDef(
        "tlm_occ", "(n_bins,)", "last",
        "total occupied decode slots at the end of each time bin"),
    "prefill_in_flight": ProbeDef(
        "tlm_pf", "(n_bins,)", "last",
        "servers with an active prefill chunk at the end of each bin"),
    "admits": ProbeDef(
        "tlm_adm", "(n_bins, I)", "sum",
        "per-class gate admissions per bin (queue-head advances; "
        "includes lazily-expired heads when deadline expiry is on)"),
    "drops": ProbeDef(
        "tlm_drop", "(n_bins,)", "sum",
        "abandonments/drops per bin"),
    "events": ProbeDef(
        "tlm_ev", "(n_bins,)", "sum",
        "engine events per bin (arrivals + iteration boundaries)"),
    "busy_seconds": ProbeDef(
        "tlm_busy_bin", "(n_bins,)", "integral",
        "aggregate server-busy seconds per bin (indicator integral, "
        "attributed to the bin each inter-event interval starts in)"),
    "busy_per_server": ProbeDef(
        "tlm_busy_srv", "(n,)", "integral",
        "per-server busy seconds over the whole run "
        "(busy fraction = value / horizon)"),
    "ttft_hist": ProbeDef(
        "tlm_ttft", "(n_hist,)", "hist",
        "time-to-first-token histogram (first decode emission minus "
        "arrival), log-spaced buckets"),
    "e2e_hist": ProbeDef(
        "tlm_e2e", "(n_hist,)", "hist",
        "end-to-end latency histogram (request done minus arrival), "
        "log-spaced buckets"),
}

# trajectory probes the aggregate CTMC engines can also fill (they have
# no per-request identity, so the hist/admit probes stay zero there)
CTMC_PROBE_KEYS = ("tlm_q", "tlm_occ", "tlm_pf", "tlm_drop", "tlm_ev")

# derived scalar metrics the sweep evaluators / closed loop add to cell
# results when telemetry is on (tools/check_docs.py accepts these next
# to the carry keys when cross-checking docs against this module)
DERIVED_METRICS = ("tlm_events", "tlm_drops", "tlm_ttft_p95")


def hist_edges(spec: ProbeSpec) -> np.ndarray:
    """The ``n_hist - 1`` log-spaced interior bucket edges (seconds)."""
    return np.geomspace(spec.hist_min, spec.hist_max, spec.n_hist - 1)


def probe_carry(spec: ProbeSpec, *, n: int, I: int, dtype) -> dict:
    """Fresh zeroed probe arrays to merge into an engine's scan carry."""
    import jax.numpy as jnp

    nb, nh = spec.n_bins, spec.n_hist
    return {
        "tlm_q": jnp.zeros((nb, I), dtype),
        "tlm_occ": jnp.zeros(nb, dtype),
        "tlm_pf": jnp.zeros(nb, dtype),
        "tlm_adm": jnp.zeros((nb, I), dtype),
        "tlm_drop": jnp.zeros(nb, dtype),
        "tlm_ev": jnp.zeros(nb, dtype),
        "tlm_busy_bin": jnp.zeros(nb, dtype),
        "tlm_busy_srv": jnp.zeros(n, dtype),
        "tlm_ttft": jnp.zeros(nh, dtype),
        "tlm_e2e": jnp.zeros(nh, dtype),
    }


def ctmc_probe_carry(spec: ProbeSpec, *, I: int, dtype) -> dict:
    """The trajectory subset for the aggregate CTMC engine (per-request
    histograms do not exist at the class-aggregate level)."""
    import jax.numpy as jnp

    nb = spec.n_bins
    return {
        "tlm_q": jnp.zeros((nb, I), dtype),
        "tlm_occ": jnp.zeros(nb, dtype),
        "tlm_pf": jnp.zeros(nb, dtype),
        "tlm_drop": jnp.zeros(nb, dtype),
        "tlm_ev": jnp.zeros(nb, dtype),
    }


def time_bin(t, horizon, n_bins, mask):
    """Bin index of time ``t`` in ``[0, horizon]``; masked-off lanes map
    to ``n_bins`` so ``mode="drop"`` scatters discard them."""
    import jax.numpy as jnp

    width = horizon / n_bins
    b = jnp.clip(jnp.floor(t / width), 0, n_bins - 1).astype(jnp.int32)
    return jnp.where(mask, b, n_bins)


def wrap_engine_step_probes(step, spec: ProbeSpec, params: dict):
    """Post-step probe pass for the engine_jax scan body.

    Wraps the (possibly k-event / fast-forward) step: after each loop
    step, last-value trajectories are scattered into the bin of the new
    clock, counter deltas are added there, and the server-busy indicator
    is integrated over the step's time advance.  Latency histograms need
    no step instrumentation at all: the engine's own ``t_first``/
    ``t_last`` per-request marks are bucketed once after the loop
    (:func:`repro.serving.engine_jax._fill_latency_hists`; the streaming
    engine folds retired rows at each splice instead).
    """
    import jax.numpy as jnp

    nb = spec.n_bins

    def wrapped(carry, idx):
        t0 = carry["t"]
        busy0 = carry["busy"]
        qhead0 = carry["qhead"]
        ab0 = carry["abandons"]
        ev0 = carry["n_events"]
        c = step(carry, idx)
        dt = c["t"].dtype
        moved = c["n_events"] > ev0
        b = time_bin(c["t"], params["h_eff"], nb, moved)
        qlen = (c["qarr"] - c["qhead"]).astype(dt)
        c["tlm_q"] = c["tlm_q"].at[b].set(qlen, mode="drop")
        occ = jnp.sum((c["slot_rid"] >= 0).astype(dt))
        c["tlm_occ"] = c["tlm_occ"].at[b].set(occ, mode="drop")
        pf = jnp.sum((c["pf_rid"] >= 0).astype(dt))
        c["tlm_pf"] = c["tlm_pf"].at[b].set(pf, mode="drop")
        c["tlm_adm"] = c["tlm_adm"].at[b].add(
            (c["qhead"] - qhead0).astype(dt), mode="drop")
        c["tlm_drop"] = c["tlm_drop"].at[b].add(c["abandons"] - ab0,
                                                mode="drop")
        c["tlm_ev"] = c["tlm_ev"].at[b].add(c["n_events"] - ev0,
                                            mode="drop")
        span = jnp.maximum(c["t"] - t0, 0.0)
        bs = busy0.astype(dt) * span
        c["tlm_busy_srv"] = c["tlm_busy_srv"] + bs
        b0 = time_bin(t0, params["h_eff"], nb, moved)
        c["tlm_busy_bin"] = c["tlm_busy_bin"].at[b0].add(jnp.sum(bs),
                                                        mode="drop")
        return c

    return wrapped


def wrap_ctmc_step_probes(step, spec: ProbeSpec, horizon: float):
    """Post-step probe pass for the uniformized-CTMC scan body
    (class-aggregate state: queue = Q_p, occupancy = Y_m + Y_s,
    prefills in flight = X)."""
    import jax.numpy as jnp

    nb = spec.n_bins

    def wrapped(carry, idx):
        ev0 = carry["n_events"]
        ab0 = carry["ab_p"] + carry["ab_d"]
        out, aux = step(carry, idx)
        # the CTMC step rebuilds its carry dict from scratch; re-attach
        # the probe arrays before scattering into them
        out = dict(out)
        for k in CTMC_PROBE_KEYS:
            out[k] = carry[k]
        dt = out["t"].dtype
        moved = out["n_events"] > ev0
        b = time_bin(out["t"], horizon, nb, moved)
        out["tlm_q"] = out["tlm_q"].at[b].set(out["qp"].astype(dt),
                                              mode="drop")
        out["tlm_occ"] = out["tlm_occ"].at[b].set(
            jnp.sum(out["ym"] + out["ys"]), mode="drop")
        out["tlm_pf"] = out["tlm_pf"].at[b].set(jnp.sum(out["x"]),
                                                mode="drop")
        out["tlm_drop"] = out["tlm_drop"].at[b].add(
            jnp.sum(out["ab_p"] + out["ab_d"] - ab0), mode="drop")
        out["tlm_ev"] = out["tlm_ev"].at[b].add(out["n_events"] - ev0,
                                                mode="drop")
        return out, aux

    return wrapped


# ------------------------------------------------------------ host side
def _reduce(arr: np.ndarray, tail_ndim: int, how: str) -> np.ndarray:
    """Collapse any leading replication/instance axes: counters and
    histograms sum, last-value/integral trajectories average."""
    arr = np.asarray(arr, dtype=np.float64)
    extra = arr.ndim - tail_ndim
    if extra <= 0:
        return arr
    flat = arr.reshape((-1,) + arr.shape[extra:])
    return flat.sum(axis=0) if how == "sum" else flat.mean(axis=0)


def _ffill(vals: np.ndarray, seen: np.ndarray) -> np.ndarray:
    """Forward-fill empty bins (no event landed there) with the last
    observed value; leading empty bins keep the initial (zero) state."""
    out = np.array(vals, dtype=np.float64)
    last = np.zeros(out.shape[1:] if out.ndim > 1 else ())
    for i in range(out.shape[0]):
        if seen[i]:
            last = out[i]
        else:
            out[i] = last
    return out


def extract_probes(raw: dict, spec: ProbeSpec, *, horizon: float,
                   n_servers: int) -> dict:
    """Host-side probe report from a raw carry (device or numpy).

    Accepts a single-replication carry or a batched one (leading axes
    are reduced: counters/histograms sum, trajectories average over the
    per-replication forward-filled values).  Returns plain numpy arrays
    plus derived SLI percentiles -- everything JSON-serializable via
    ``tolist()``.
    """
    nb = spec.n_bins
    width = horizon / nb

    def tail(key):
        return 2 if key == "tlm_q" or key == "tlm_adm" else 1

    have = {k: np.asarray(raw[k]) for k in
            (d.key for d in PROBES.values()) if k in raw}
    if not have:
        raise KeyError("raw carry holds no tlm_* probe arrays -- was the "
                       "run made with telemetry enabled?")

    # per-replication forward-fill BEFORE averaging the last-value
    # trajectories (an empty bin means "state unchanged", not zero)
    ev_full = np.asarray(have["tlm_ev"], dtype=np.float64)
    flat_ev = ev_full.reshape((-1, nb))

    def ffilled(key):
        arr = np.asarray(have[key], dtype=np.float64)
        flat = arr.reshape((flat_ev.shape[0],) + arr.shape[-(tail(key)):])
        return np.stack([
            _ffill(flat[r], flat_ev[r] > 0)
            for r in range(flat.shape[0])]).mean(axis=0)

    out = {
        "spec": {"n_bins": nb, "n_hist": spec.n_hist,
                 "hist_min": spec.hist_min, "hist_max": spec.hist_max},
        "horizon": float(horizon),
        "bin_width": float(width),
        "t_bins": (np.arange(nb) + 0.5) * width,
        "queue_depth": ffilled("tlm_q"),
        "decode_occupancy": ffilled("tlm_occ"),
        "prefill_in_flight": ffilled("tlm_pf"),
        "events": _reduce(have["tlm_ev"], 1, "sum"),
        "drops": _reduce(have["tlm_drop"], 1, "sum"),
    }
    if "tlm_adm" in have:
        out["admits"] = _reduce(have["tlm_adm"], 2, "sum")
    if "tlm_busy_srv" in have:
        busy = _reduce(have["tlm_busy_srv"], 1, "mean")
        out["busy_per_server"] = busy / max(horizon, 1e-12)
        out["busy_seconds"] = _reduce(have["tlm_busy_bin"], 1, "mean")
        out["busy_fraction"] = (out["busy_seconds"]
                                / (width * max(n_servers, 1)))
    edges = hist_edges(spec)
    out["hist_edges"] = edges
    for name, key in (("ttft", "tlm_ttft"), ("e2e", "tlm_e2e")):
        if key not in have:
            continue
        h = _reduce(have[key], 1, "sum")
        out[f"{name}_hist"] = h
        for q in (50, 95, 99):
            out[f"{name}_p{q}"] = hist_percentile(h, edges, q)
    return out


def hist_percentile(hist: np.ndarray, edges: np.ndarray,
                    q: float) -> float:
    """Percentile estimate from a bucketed histogram: find the bucket
    holding the q-th observation and interpolate linearly inside it
    (edge buckets clamp to their finite edge).  NaN on an empty
    histogram."""
    hist = np.asarray(hist, dtype=np.float64)
    total = hist.sum()
    if total <= 0:
        return float("nan")
    cum = np.cumsum(hist)
    target = q / 100.0 * total
    k = int(np.searchsorted(cum, target, side="left"))
    k = min(k, hist.size - 1)
    lo = edges[k - 1] if k >= 1 else edges[0]
    hi = edges[k] if k < edges.size else edges[-1]
    prev = cum[k - 1] if k >= 1 else 0.0
    frac = 0.0 if hist[k] <= 0 else (target - prev) / hist[k]
    return float(lo + (hi - lo) * np.clip(frac, 0.0, 1.0))


def hist_attainment(hist: np.ndarray, edges: np.ndarray,
                    target_s: float) -> float:
    """Fraction of observations at or below ``target_s`` (conservative:
    a bucket counts only if its upper edge is within the target)."""
    hist = np.asarray(hist, dtype=np.float64)
    total = hist.sum()
    if total <= 0:
        return float("nan")
    upper = np.append(edges, np.inf)
    return float(hist[upper <= target_s].sum() / total)


class PyProbes:
    """The pure-Python twin of the device probes, for
    :class:`repro.serving.engine_sim.ClusterEngine` and
    :class:`repro.core.simulator.CTMCSimulator`.

    Produces the same ``tlm_*`` arrays (numpy) under the same bin/fill
    semantics, so :func:`extract_probes` renders both identically.
    """

    def __init__(self, spec: ProbeSpec, *, horizon: float, n_servers: int,
                 n_classes: int):
        self.spec = spec
        self.horizon = max(float(horizon), 1e-12)
        self.width = self.horizon / spec.n_bins
        nb, nh = spec.n_bins, spec.n_hist
        self.arr = {
            "tlm_q": np.zeros((nb, n_classes)),
            "tlm_occ": np.zeros(nb),
            "tlm_pf": np.zeros(nb),
            "tlm_adm": np.zeros((nb, n_classes)),
            "tlm_drop": np.zeros(nb),
            "tlm_ev": np.zeros(nb),
            "tlm_busy_bin": np.zeros(nb),
            "tlm_busy_srv": np.zeros(n_servers),
            "tlm_ttft": np.zeros(nh),
            "tlm_e2e": np.zeros(nh),
        }
        self.edges = hist_edges(spec)
        self._t_prev = 0.0
        self._busy_prev = np.zeros(n_servers, dtype=bool)

    def _bin(self, t: float) -> int:
        return int(np.clip(t // self.width, 0, self.spec.n_bins - 1))

    def sample(self, t: float, *, queue_depth, decode_occupancy: float,
               prefill_in_flight: float, busy=None) -> None:
        """Record the post-event state at time ``t`` (last value in the
        bin wins) and integrate the busy indicator since the previous
        sample."""
        b = self._bin(t)
        self.arr["tlm_q"][b] = np.asarray(queue_depth, dtype=float)
        self.arr["tlm_occ"][b] = float(decode_occupancy)
        self.arr["tlm_pf"][b] = float(prefill_in_flight)
        self.arr["tlm_ev"][b] += 1.0
        if busy is not None:
            span = max(t - self._t_prev, 0.0)
            bs = self._busy_prev.astype(float) * span
            self.arr["tlm_busy_srv"] += bs
            self.arr["tlm_busy_bin"][self._bin(self._t_prev)] += bs.sum()
            self._busy_prev = np.asarray(busy, dtype=bool).copy()
        self._t_prev = t

    def count(self, t: float, *, admit_class: Optional[int] = None,
              drops: float = 0.0) -> None:
        b = self._bin(t)
        if admit_class is not None:
            self.arr["tlm_adm"][b, admit_class] += 1.0
        if drops:
            self.arr["tlm_drop"][b] += drops

    def observe_ttft(self, v: float) -> None:
        self.arr["tlm_ttft"][int(np.searchsorted(self.edges, v))] += 1.0

    def observe_e2e(self, v: float) -> None:
        self.arr["tlm_e2e"][int(np.searchsorted(self.edges, v))] += 1.0

    def raw(self) -> dict:
        """The ``tlm_*`` arrays, shaped exactly like the device carry."""
        return dict(self.arr)

    def extract(self) -> dict:
        return extract_probes(self.raw(), self.spec, horizon=self.horizon,
                              n_servers=self.arr["tlm_busy_srv"].size)
