"""CLI for the telemetry subsystem.

    PYTHONPATH=src python -m repro.telemetry report --scenario rate_shift
    PYTHONPATH=src python -m repro.telemetry validate trace.json
    PYTHONPATH=src python -m repro.telemetry validate-manifest runs.jsonl

``report`` replays one registered workload scenario through the chosen
engine with probes ON and renders the time-binned trajectories plus the
on-device SLI percentiles as terminal tables; ``--out`` additionally
writes the Chrome-trace JSON (open in chrome://tracing or Perfetto) and
``--manifest`` appends a ``telemetry`` RunRecord.  ``validate`` /
``validate-manifest`` are the schema gates CI's telemetry-smoke step
runs on every emitted artifact.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from .manifest import (append_record, file_digest, read_records, run_record,
                       validate_record)
from .probes import hist_attainment, resolve_probe_spec
from .trace import validate_trace

__all__ = ["main"]


def _sparkline(vals, width: int = 48) -> str:
    """Down-sampled unicode sparkline of one trajectory."""
    blocks = " .:-=+*#%@"
    v = np.asarray(vals, dtype=np.float64)
    if v.size > width:
        edge = np.linspace(0, v.size, width + 1).astype(int)
        v = np.array([v[a:b].mean() if b > a else 0.0
                      for a, b in zip(edge[:-1], edge[1:])])
    hi = float(v.max())
    if hi <= 0:
        return blocks[0] * v.size
    idx = np.clip((v / hi * (len(blocks) - 1)).round().astype(int),
                  0, len(blocks) - 1)
    return "".join(blocks[i] for i in idx)


def _report(args) -> int:
    from repro.workloads.closed_loop import ClosedLoopConfig, run_closed_loop
    from repro.workloads.scenarios import get_scenario

    scn = get_scenario(args.scenario)
    horizon = float(args.horizon if args.horizon is not None
                    else min(scn.horizon, 60.0))
    spec = resolve_probe_spec(True)
    cfg = ClosedLoopConfig(n_servers=args.n, horizon=horizon,
                           seed=args.seed)
    t0 = time.time()

    # run through the Python engine (full lifecycle timestamps) and
    # keep the metrics object for its telemetry report
    from repro.core.types import Pricing, ServicePrimitives
    from repro.serving.engine_sim import ClusterEngine, EngineConfig
    from repro.workloads.closed_loop import _plans

    prim, pricing = ServicePrimitives(), Pricing()
    trace = scn.generate(seed=cfg.seed, horizon=horizon)
    _cold_cls, _cold, full_cls, full_plan = _plans(
        scn, trace, cfg, prim, pricing)
    from repro.core.policies import gate_and_route

    eng = ClusterEngine(
        full_cls, gate_and_route(full_plan),
        EngineConfig(prim, pricing, args.n, seed=cfg.seed, telemetry=spec))
    metrics = eng.run(trace, horizon=horizon)
    tl = metrics.telemetry
    wall = time.time() - t0

    print(f"[telemetry] scenario={scn.name} n={args.n} "
          f"horizon={horizon:g}s seed={cfg.seed} "
          f"({len(trace)} requests, {wall:.2f}s wall)")
    print(f"  bins: {tl['spec']['n_bins']} x {tl['bin_width']:.3g}s, "
          f"hist: {tl['spec']['n_hist']} buckets "
          f"[{tl['spec']['hist_min']:g}, {tl['spec']['hist_max']:g}]s")
    print("\n  trajectory (per bin)        min     mean      max  shape")
    rows = [("queue_depth", tl["queue_depth"].sum(axis=-1)),
            ("decode_occupancy", tl["decode_occupancy"]),
            ("prefill_in_flight", tl["prefill_in_flight"])]
    if "busy_fraction" in tl:
        rows.append(("busy_fraction", tl["busy_fraction"]))
    for name, v in rows:
        print(f"  {name:<22} {v.min():>8.2f} {v.mean():>8.2f} "
              f"{v.max():>8.2f}  {_sparkline(v)}")
    print(f"\n  counters: events={tl['events'].sum():.0f} "
          f"drops={tl['drops'].sum():.0f} "
          + (f"admits={tl['admits'].sum():.0f}" if "admits" in tl else ""))
    print("\n  SLI (from on-device histograms)   p50      p95      p99"
          "    <=1s")
    for sli in ("ttft", "e2e"):
        if f"{sli}_p50" not in tl:
            continue
        att = hist_attainment(tl[f"{sli}_hist"], tl["hist_edges"], 1.0)
        print(f"  {sli:<30} {tl[f'{sli}_p50']:>8.3f} "
              f"{tl[f'{sli}_p95']:>8.3f} {tl[f'{sli}_p99']:>8.3f} "
              f"{100 * att:>6.1f}%")

    artifacts = {}
    if args.out:
        from .trace import lifecycle_events, write_trace

        p = write_trace(args.out, lifecycle_events(eng.lifecycle_records()),
                        source=f"telemetry-report/{scn.name}")
        errs = validate_trace(p)
        if errs:
            print(f"[telemetry] ERROR: emitted trace invalid: {errs[:3]}",
                  file=sys.stderr)
            return 1
        artifacts[str(p)] = file_digest(p)
        print(f"\n  wrote trace {p} (load in chrome://tracing)")
    if args.manifest:
        rec = run_record(kind="telemetry", name=f"report/{scn.name}",
                         wall_s=wall,
                         extra={"n": args.n, "horizon": horizon,
                                "seed": cfg.seed,
                                "events": float(tl["events"].sum())},
                         artifacts=artifacts)
        mp = append_record(rec, args.manifest)
        print(f"  appended RunRecord to {mp}")
    return 0


def _validate(args) -> int:
    errs = validate_trace(args.path)
    if errs:
        print(f"[telemetry] {args.path}: INVALID ({len(errs)} errors)")
        for e in errs[:20]:
            print(f"  - {e}")
        return 1
    import json
    from pathlib import Path

    n = len(json.loads(Path(args.path).read_text())["traceEvents"])
    print(f"[telemetry] {args.path}: valid trace ({n} events)")
    return 0


def _validate_manifest(args) -> int:
    bad = 0
    total = 0
    try:
        for i, rec in enumerate(read_records(args.path)):
            total += 1
            errs = validate_record(rec)
            if errs:
                bad += 1
                print(f"[telemetry] {args.path}:{i + 1}: "
                      f"{'; '.join(errs[:5])}")
    except (OSError, ValueError) as exc:
        print(f"[telemetry] {args.path}: unreadable ({exc})")
        return 1
    if bad:
        print(f"[telemetry] {args.path}: {bad}/{total} records INVALID")
        return 1
    print(f"[telemetry] {args.path}: {total} valid records")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Render telemetry reports; validate trace-event and "
                    "manifest artifacts.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report",
                        help="replay a scenario with probes on and print "
                             "trajectory + SLI tables")
    rp.add_argument("--scenario", default="rate_shift",
                    help="registered workload scenario name")
    rp.add_argument("--n", type=int, default=8, help="cluster size")
    rp.add_argument("--horizon", type=float, default=None,
                    help="replay horizon (default: min(scenario, 60s))")
    rp.add_argument("--seed", type=int, default=0)
    rp.add_argument("--out", default=None,
                    help="write the Chrome-trace JSON here")
    rp.add_argument("--manifest", default=None,
                    help="append a RunRecord to this JSONL manifest")
    rp.set_defaults(fn=_report)

    vp = sub.add_parser("validate",
                        help="schema-check a trace-event JSON file")
    vp.add_argument("path")
    vp.set_defaults(fn=_validate)

    mp = sub.add_parser("validate-manifest",
                        help="schema-check a RunRecord JSONL manifest")
    mp.add_argument("path")
    mp.set_defaults(fn=_validate_manifest)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
