"""Shared wall-clock timing helper (warmup + median-of-reps).

The single canonical implementation of ``timeit_median`` -- previously
grown inside :mod:`repro.calibration.measure` and re-imported ad hoc by
the benchmark suite.  It now lives in the telemetry layer (it *is* a
measurement primitive) and is re-exported by
:mod:`repro.calibration.measure` and :mod:`benchmarks.common` so every
historical import path keeps working.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable

__all__ = ["timeit_median"]


def timeit_median(fn: Callable[[], object], *, warmup: int = 2,
                  reps: int = 5) -> float:
    """Median-of-``reps`` wall time of ``fn()`` after ``warmup`` calls.

    Replaces the old ``bench_calibration`` bare ``time.time`` reps=3
    loop: ``perf_counter`` is monotonic and the median discards the
    recompile/GC outliers that made the benchmark flaky.  The warmup
    calls also discard jit compilation for JAX legs.
    """
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return statistics.median(times)
