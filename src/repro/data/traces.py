"""Workload traces: Azure-like synthesis and CSV replay.

The Azure LLM inference traces released with Splitwise (2023) and DynamoLLM
(2024) are not redistributable in this offline container, so we provide a
generator that matches their published *marginal* statistics: two native
classes (``code``, ``conversation``) with lognormal prompt/output lengths and
bursty arrivals from a two-state Markov-modulated Poisson process (MMPP).
``load_trace_csv`` replays a real trace file (columns: t, class, P, D) when one
is available, so all benchmarks accept either source.

Interarrival-time compression (the paper's load-scaling device, Section 6.2)
is a parameter of both paths.
"""

from __future__ import annotations

import csv
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

__all__ = ["Request", "ClassProfile", "TraceConfig", "TraceValidationError",
           "TraceTensors", "synth_azure_trace", "load_trace_csv",
           "validate_requests", "tensorize_trace", "untensorize_trace",
           "chunk_trace", "concat_chunks",
           "dolly_classes", "DOLLY_STATS", "trace_class_means",
           "trace_class_means_windowed"]


class TraceValidationError(ValueError):
    """A request trace violates the invariants every engine assumes."""


@dataclass
class Request:
    rid: int
    t_arrival: float
    cls: int
    prompt_len: int
    decode_len: int
    patience: float = float("inf")  # absolute deadline length (seconds)


@dataclass(frozen=True)
class ClassProfile:
    """Marginal statistics of one synthetic request class.

    ``patience`` is the absolute per-request deadline in seconds
    (:attr:`Request.patience`; ``inf`` = never expires), mirroring the
    ``patience`` argument of :func:`dolly_classes` so synthetic traces
    can exercise the SLI/expiry paths too.
    """

    name: str
    mean_prompt: float
    mean_decode: float
    cv_prompt: float = 1.0  # lognormal coefficient of variation
    cv_decode: float = 1.0
    share: float = 0.5  # fraction of traffic
    patience: float = float("inf")  # per-request deadline (seconds)


#: Published task-category means from the Dolly-15k table (paper Table EC.4).
DOLLY_STATS = {
    "brainstorming": (61, 331),
    "classification": (123, 142),
    "closed_qa": (992, 182),
    "creative_writing": (89, 915),
    "general_qa": (69, 572),
    "information_extraction": (1139, 273),
    "open_qa": (45, 293),
    "summarization": (1177, 436),
}


@dataclass(frozen=True)
class TraceConfig:
    """Azure-like two-class trace (code + conversation)."""

    horizon: float = 600.0  # seconds of (compressed) trace
    base_rate: float = 2.0  # total requests/second before compression
    compression: float = 1.0  # divide interarrival times by 1/compression<1
    profiles: tuple = (
        ClassProfile("code", mean_prompt=2048, mean_decode=36,
                     cv_prompt=1.2, cv_decode=1.5, share=0.45),
        ClassProfile("conversation", mean_prompt=1020, mean_decode=211,
                     cv_prompt=1.4, cv_decode=1.1, share=0.55),
    )
    # MMPP burstiness: rate multipliers and switching rates between regimes.
    mmpp_levels: tuple = (0.55, 1.9)
    mmpp_switch: tuple = (1 / 45.0, 1 / 25.0)
    seed: int = 42


def _lognormal(rng, mean, cv, size=None):
    sigma2 = np.log(1 + cv * cv)
    mu = np.log(mean) - sigma2 / 2
    return rng.lognormal(mu, np.sqrt(sigma2), size=size)


def sample_lengths(rng, profile: ClassProfile) -> tuple:
    """Draw one (P, D) pair from a profile's lognormal marginals.

    Shared by :func:`synth_azure_trace` and the scenario generators in
    :mod:`repro.workloads`; the floors (8 prompt / 2 decode tokens) keep
    degenerate draws out of the engines.
    """
    P = max(8, int(_lognormal(rng, profile.mean_prompt, profile.cv_prompt)))
    D = max(2, int(_lognormal(rng, profile.mean_decode, profile.cv_decode)))
    return P, D


def validate_requests(reqs: Sequence[Request],
                      source: str = "trace") -> Sequence[Request]:
    """Shared validation behind every trace source (and tensorization).

    Both engines assume arrival times are finite, nonnegative and
    nondecreasing, and token lengths strictly positive; a violation used
    to surface only as downstream NaNs (empty metrics, silent zero
    revenue).  Raises :class:`TraceValidationError` naming the offending
    request instead.  Returns ``reqs`` unchanged so call sites can chain.
    """
    t_prev = 0.0
    for k, r in enumerate(reqs):
        if not np.isfinite(r.t_arrival) or r.t_arrival < 0:
            raise TraceValidationError(
                f"{source}: request {r.rid} (index {k}) has non-finite or "
                f"negative arrival time {r.t_arrival!r}")
        if r.t_arrival < t_prev:
            raise TraceValidationError(
                f"{source}: arrival times must be nondecreasing, but "
                f"request {r.rid} (index {k}) arrives at {r.t_arrival} "
                f"after one at {t_prev}")
        t_prev = r.t_arrival
        if not r.prompt_len >= 1 or not r.decode_len >= 1:
            raise TraceValidationError(
                f"{source}: request {r.rid} (index {k}) has non-positive "
                f"token lengths P={r.prompt_len}, D={r.decode_len}")
        if not r.patience > 0:  # NaN fails this too
            raise TraceValidationError(
                f"{source}: request {r.rid} (index {k}) has non-positive "
                f"patience {r.patience!r} (use inf for no deadline)")
        if r.cls < 0:
            raise TraceValidationError(
                f"{source}: request {r.rid} (index {k}) has negative "
                f"class {r.cls}")
    return reqs


def synth_azure_trace(cfg: TraceConfig = TraceConfig()) -> list[Request]:
    """Generate a bursty multiclass trace; timestamps already compressed."""
    rng = np.random.default_rng(cfg.seed)
    shares = np.array([p.share for p in cfg.profiles], dtype=float)
    shares /= shares.sum()
    reqs: list[Request] = []
    t = 0.0
    regime = 0
    # Draw next MMPP switch time.
    t_switch = rng.exponential(1.0 / cfg.mmpp_switch[regime])
    rid = 0
    horizon_raw = cfg.horizon / cfg.compression
    while t < horizon_raw:
        rate = cfg.base_rate * cfg.mmpp_levels[regime]
        dt = rng.exponential(1.0 / rate)
        if t + dt > t_switch:
            t = t_switch
            regime = 1 - regime
            t_switch = t + rng.exponential(1.0 / cfg.mmpp_switch[regime])
            continue
        t += dt
        i = int(rng.choice(len(cfg.profiles), p=shares))
        p = cfg.profiles[i]
        P, D = sample_lengths(rng, p)
        reqs.append(Request(rid, t * cfg.compression, i, P, D,
                            patience=p.patience))
        rid += 1
    validate_requests(reqs, source="synth_azure_trace")
    return reqs


def load_trace_csv(path: str, compression: float = 1.0,
                   class_names: Optional[Sequence[str]] = None) -> list[Request]:
    """Replay a real trace CSV with columns (t, class, P, D) and an
    optional ``patience`` column (deadline seconds; absent/empty =
    ``inf``, matching the ``repro.workloads`` CSV export)."""
    out: list[Request] = []
    name_to_idx: dict[str, int] = (
        {n: k for k, n in enumerate(class_names)} if class_names else {}
    )
    with open(path) as f:
        for rid, row in enumerate(csv.DictReader(f)):
            cname = row.get("class", "0")
            if cname not in name_to_idx and not cname.isdigit():
                name_to_idx.setdefault(cname, len(name_to_idx))
            cls = int(cname) if cname.isdigit() else name_to_idx[cname]
            out.append(
                Request(
                    rid,
                    float(row["t"]) * compression,
                    cls,
                    int(float(row["P"])),
                    int(float(row["D"])),
                    patience=float(row.get("patience") or "inf"),
                )
            )
    out.sort(key=lambda r: r.t_arrival)
    validate_requests(out, source=f"load_trace_csv({path})")
    return out


# ---------------------------------------------------------------------------
# Tensorization (the JAX trace-replay engine's input format)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceTensors:
    """A trace as padded, fixed-shape arrays (``engine_jax`` input).

    All arrays share the padded length ``R``; rows with ``valid == False``
    are padding (``t`` is ``+inf`` there so masked time-minima ignore
    them).  ``rid`` is always ``arange(R)`` for the first ``n_real`` rows:
    tensorization re-ids requests in arrival order, which is what makes
    per-class FCFS a masked ``argmin`` over ``rid``.  ``n_dropped`` counts
    requests cut by the ``max_requests`` cap (never silent: the engine
    surfaces it as a diagnostic).
    """

    rid: np.ndarray        # (R,) int32, arange
    t: np.ndarray          # (R,) float64 arrival times, +inf on padding
    cls: np.ndarray        # (R,) int32
    P: np.ndarray          # (R,) int32 prompt tokens (1 on padding)
    D: np.ndarray          # (R,) int32 decode tokens (1 on padding)
    patience: np.ndarray   # (R,) float64 deadlines (inf = none)
    valid: np.ndarray      # (R,) bool
    n_real: int
    n_dropped: int = 0

    @property
    def R(self) -> int:
        return int(self.rid.shape[0])

    @property
    def n_classes(self) -> int:
        m = self.cls[self.valid]
        return int(m.max()) + 1 if m.size else 1


def tensorize_trace(reqs: Sequence[Request],
                    max_requests: Optional[int] = None,
                    pad_to: Optional[int] = None) -> TraceTensors:
    """Pack a request list into padded arrays for the JAX engine.

    ``max_requests`` caps the number of (earliest-arriving) requests kept;
    the overflow count is recorded in ``n_dropped`` rather than silently
    shifting load.  ``pad_to`` pads the arrays up to a fixed length so
    traces of different sizes share one compiled scan (must be >= the
    kept length).  Requests are validated (:func:`validate_requests`) and
    re-numbered ``0..len-1`` in arrival order.
    """
    reqs = list(validate_requests(reqs, source="tensorize_trace"))
    n_dropped = 0
    if max_requests is not None and len(reqs) > max_requests:
        n_dropped = len(reqs) - int(max_requests)
        reqs = reqs[: int(max_requests)]
    n_real = len(reqs)
    R = max(n_real, 1) if pad_to is None else int(pad_to)
    if R < n_real:
        raise TraceValidationError(
            f"pad_to={R} is smaller than the kept trace length {n_real}")
    t = np.full(R, np.inf, dtype=np.float64)
    cls = np.zeros(R, dtype=np.int32)
    P = np.ones(R, dtype=np.int32)
    D = np.ones(R, dtype=np.int32)
    pat = np.full(R, np.inf, dtype=np.float64)
    valid = np.zeros(R, dtype=bool)
    for k, r in enumerate(reqs):
        t[k] = r.t_arrival
        cls[k] = r.cls
        P[k] = int(r.prompt_len)
        D[k] = int(r.decode_len)
        pat[k] = r.patience
        valid[k] = True
    return TraceTensors(rid=np.arange(R, dtype=np.int32), t=t, cls=cls,
                        P=P, D=D, patience=pat, valid=valid,
                        n_real=n_real, n_dropped=n_dropped)


def untensorize_trace(tt: TraceTensors) -> list[Request]:
    """Inverse of :func:`tensorize_trace` (padding rows dropped).

    Round-trips everything except the original ``rid`` labels, which
    tensorization canonicalises to arrival order (the property tests pin
    this contract down).
    """
    return [
        Request(int(tt.rid[k]), float(tt.t[k]), int(tt.cls[k]),
                int(tt.P[k]), int(tt.D[k]), float(tt.patience[k]))
        for k in range(tt.R) if tt.valid[k]
    ]


def chunk_trace(reqs: Sequence[Request],
                chunk_size: int) -> list[TraceTensors]:
    """Split a trace into fixed-shape chunks for streamed replay.

    Every chunk is padded to exactly ``chunk_size`` rows so they all
    share one compiled step function (the streaming engine splices them
    into its working set one at a time instead of materialising a
    single ``(R,)`` table for the whole trace).  Chunks keep arrival
    order; ``concat_chunks`` is the inverse.  An empty trace yields one
    all-padding chunk so callers never special-case zero requests.
    """
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    reqs = list(validate_requests(reqs, source="chunk_trace"))
    if not reqs:
        return [tensorize_trace([], pad_to=chunk_size)]
    return [tensorize_trace(reqs[k:k + chunk_size], pad_to=chunk_size)
            for k in range(0, len(reqs), chunk_size)]


def concat_chunks(chunks: Sequence[TraceTensors]) -> TraceTensors:
    """Reassemble ``chunk_trace`` output into one padded trace.

    Validates the chunk *seams*: the first real arrival of each chunk
    must not precede the last real arrival of the one before it (the
    streamed replay consumes arrivals in order, so a non-monotone seam
    means the chunks were shuffled or came from different traces) and
    raises :class:`TraceValidationError` otherwise.  Padding rows are
    dropped; requests are re-numbered globally in arrival order.
    """
    if not chunks:
        raise TraceValidationError("concat_chunks: no chunks given")
    t_prev = -np.inf
    reqs: list[Request] = []
    for k, ch in enumerate(chunks):
        if ch.n_real == 0:
            continue
        t_real = ch.t[ch.valid]
        if t_real[0] < t_prev:
            raise TraceValidationError(
                f"concat_chunks: chunk {k} starts at t={t_real[0]} before "
                f"the previous chunk's last arrival t={t_prev} -- chunks "
                f"are out of order or from different traces")
        t_prev = float(t_real[-1])
        reqs.extend(untensorize_trace(ch))
    out = tensorize_trace(reqs)
    n_dropped = sum(ch.n_dropped for ch in chunks)
    if n_dropped:
        out = TraceTensors(rid=out.rid, t=out.t, cls=out.cls, P=out.P,
                           D=out.D, patience=out.patience, valid=out.valid,
                           n_real=out.n_real, n_dropped=n_dropped)
    return out


def dolly_classes(names: Sequence[str], total_rate: float, patience: float = 0.0):
    """WorkloadClass list from the published Dolly category means (EC Table 4)."""
    from repro.core.types import WorkloadClass

    share = total_rate / len(names)
    return [
        WorkloadClass(n, DOLLY_STATS[n][0], DOLLY_STATS[n][1], share, patience)
        for n in names
    ]


def trace_class_means(reqs: Sequence[Request], n_classes: int):
    """Empirical per-class (mean P, mean D, rate/sec) -- planner inputs."""
    horizon = max((r.t_arrival for r in reqs), default=0.0) or 1.0
    out = []
    for i in range(n_classes):
        sub = [r for r in reqs if r.cls == i]
        if not sub:
            out.append((1.0, 1.0, 0.0))
            continue
        out.append(
            (
                float(np.mean([r.prompt_len for r in sub])),
                float(np.mean([r.decode_len for r in sub])),
                len(sub) / horizon,
            )
        )
    return out


def trace_class_means_windowed(reqs: Sequence[Request], n_classes: int,
                               window: float):
    """Per-window empirical class statistics of a (nonstationary) trace.

    Splits ``[0, max arrival]`` into consecutive windows of ``window``
    seconds and returns ``[(t0, t1, means), ...]`` where ``means`` has
    the :func:`trace_class_means` layout ``[(mean P, mean D, rate/sec)]``
    computed from the arrivals inside ``[t0, t1)``.  The final window's
    rate is normalized by its *covered* duration (up to the last
    arrival), not the nominal window length, so a trace whose horizon
    is not a multiple of ``window`` does not show a spurious rate drop
    in the last row.  This is the ground-truth counterpart of the
    online controller's rolling-window estimator (Eq. 50): plotting the
    two against each other shows how fast the controller tracks a rate
    shift (see ``examples/online_adaptive.py``).
    """
    if window <= 0:
        raise ValueError("window must be positive")
    horizon = max((r.t_arrival for r in reqs), default=0.0)
    n_win = max(1, int(np.ceil(horizon / window))) if horizon > 0 else 1
    out = []
    for w in range(n_win):
        t0, t1 = w * window, (w + 1) * window
        covered = max(min(t1, horizon) - t0, 1e-9)
        sub = [r for r in reqs if t0 <= r.t_arrival < t1]
        means = []
        for i in range(n_classes):
            cls_sub = [r for r in sub if r.cls == i]
            if not cls_sub:
                means.append((1.0, 1.0, 0.0))
                continue
            means.append(
                (
                    float(np.mean([r.prompt_len for r in cls_sub])),
                    float(np.mean([r.decode_len for r in cls_sub])),
                    len(cls_sub) / covered,
                )
            )
        out.append((t0, t1, means))
    return out
