from .traces import Request, TraceConfig, load_trace_csv, synth_azure_trace  # noqa: F401
