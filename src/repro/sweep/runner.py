"""Grid executor: run a :class:`SweepSpec`, get a :class:`SweepResult`.

Replaces the ad-hoc serial loops the benchmark scripts used to carry:
one call evaluates the full (mix x policy x n x seed) cross product with
per-cell :class:`numpy.random.SeedSequence` streams (bitwise reproducible,
iteration-order independent) and, for the deterministic fluid evaluator,
a single ``jax.vmap``-batched integration over the whole grid.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

from .evaluators import (MixContext, evaluate_ctmc_cells,
                         evaluate_ctmc_jax_cells, evaluate_engine_cell,
                         evaluate_engine_jax_cells, evaluate_lp_cell,
                         evaluate_lp_jax_grid, prewarm_plans)
from .spec import CellResult, SweepResult, SweepSpec, cell_seed_sequence

__all__ = ["run_sweep"]


def run_sweep(spec: SweepSpec,
              progress: Optional[Callable[[str], None]] = None) -> SweepResult:
    """Evaluate every cell of ``spec``'s grid and collect the results."""
    t0 = time.time()
    say = progress or (lambda _msg: None)
    contexts = [MixContext(mix, spec) for mix in spec.mixes]
    cells: list = []

    if spec.evaluator in ("fluid", "lp_jax"):
        # grid-batched deterministic evaluators: one vmapped solve for the
        # whole (mix x policy) plane, replicated over the (n, seed) axes
        if spec.evaluator == "fluid":
            from .fluid_batch import evaluate_fluid_grid

            dt = float(spec.extra.get("dt", 2e-3))
            say(f"[{spec.name}] fluid: vmap-integrating "
                f"{len(contexts) * len(spec.policies)} instances")
            grid = evaluate_fluid_grid(contexts, spec.policies,
                                       spec.horizon, dt)
        else:
            say(f"[{spec.name}] lp_jax: batch-solving "
                f"{len(contexts) * len(spec.policies)} planning LPs")
            grid = evaluate_lp_jax_grid(contexts, spec.policies, spec.extra)
        for mi, ctx in enumerate(contexts):
            for pi, token in enumerate(spec.policies):
                metrics = grid[(mi, pi)]
                for n in spec.n_servers:
                    for si in range(spec.n_seeds):
                        cells.append(CellResult(ctx.mix.name, token, n, si,
                                                dict(metrics)))
    else:
        if spec.extra.get("batch_plans"):
            # one vmapped interior-point run replaces the per-mix serial
            # simplex solves the cell evaluators would otherwise trigger
            solved = prewarm_plans(contexts, spec.policies)
            say(f"[{spec.name}] prewarmed {solved} planning LPs "
                f"(batch_plans)")
        # extra["crn_policies"]: common random numbers across the policy
        # axis -- every policy sees the same per-(mix, n, seed) streams,
        # turning policy comparisons into paired comparisons (the EC.8.6
        # ablation protocol; variance reduction for rankings).
        crn = bool(spec.extra.get("crn_policies", False))
        for mi, ctx in enumerate(contexts):
            for pi, token in enumerate(spec.policies):
                for ni, n in enumerate(spec.n_servers):
                    streams = [cell_seed_sequence(spec, mi,
                                                  0 if crn else pi, ni, si)
                               for si in range(spec.n_seeds)]
                    say(f"[{spec.name}] {ctx.mix.name} / {token} / n={n} "
                        f"({spec.n_seeds} seeds)")
                    if spec.evaluator == "ctmc":
                        metrics_list = evaluate_ctmc_cells(
                            ctx, token, n, streams)
                    elif spec.evaluator == "ctmc_jax":
                        metrics_list = evaluate_ctmc_jax_cells(
                            ctx, token, n, streams)
                    elif spec.evaluator == "engine":
                        metrics_list = [
                            evaluate_engine_cell(ctx, token, n, ss)
                            for ss in streams]
                    elif spec.evaluator == "engine_jax":
                        metrics_list = evaluate_engine_jax_cells(
                            ctx, token, n, streams)
                    elif spec.evaluator == "lp":
                        # deterministic: one solve, replicated over seeds
                        m = evaluate_lp_cell(ctx, token)
                        metrics_list = [dict(m) for _ in streams]
                    else:  # pragma: no cover - SweepSpec already validates
                        raise ValueError(spec.evaluator)
                    for si, m in enumerate(metrics_list):
                        cells.append(CellResult(ctx.mix.name, token, n, si, m))

    meta = {
        "evaluator": spec.evaluator,
        "n_cells": len(cells),
        "wall_seconds": round(time.time() - t0, 3),
    }
    return SweepResult(spec=spec, cells=cells, meta=meta)
