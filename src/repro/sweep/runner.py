"""Grid executor: run a :class:`SweepSpec`, get a :class:`SweepResult`.

Replaces the ad-hoc serial loops the benchmark scripts used to carry:
one call evaluates the full (mix x policy x n x seed) cross product with
per-cell :class:`numpy.random.SeedSequence` streams (bitwise reproducible,
iteration-order independent).  Dispatch is uniform: every evaluator sits
behind the :class:`~repro.sweep.spec.Evaluator` protocol
(``get_evaluator(spec.evaluator)``), deterministic ones replicate a
single solve over the degenerate seed axis, and grid-batched ones
(fluid ODE, batched planning LP) run their whole (mix x policy) plane in
ONE vmapped solve via their ``prepare`` hook before the cell loop.

``spec.extra["placement"]`` selects the batch execution strategy for the
JAX engines (one of :data:`repro.sweep.sharded.PLACEMENTS`); with
``"shard_map"`` the seed axis is SPMD-partitioned over the device mesh
and the result meta records the detected device count.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

from .evaluators import MixContext, prewarm_plans
from .spec import SweepResult, SweepSpec, cell_seed_sequence, get_evaluator

__all__ = ["run_sweep"]


def run_sweep(spec: SweepSpec,
              progress: Optional[Callable[[str], None]] = None) -> SweepResult:
    """Evaluate every cell of ``spec``'s grid and collect the results."""
    t0 = time.time()
    say = progress or (lambda _msg: None)
    placement = spec.extra.get("placement")
    if placement is not None:
        from .sharded import PLACEMENTS

        if placement not in PLACEMENTS:
            raise ValueError(
                f"extra['placement'] must be one of {PLACEMENTS}, "
                f"got {placement!r}")
    contexts = [MixContext(mix, spec) for mix in spec.mixes]
    ev = get_evaluator(spec.evaluator)
    cells: list = []

    if ev.prepare is not None:
        # grid-batched evaluators: one vmapped solve for the whole
        # (mix x policy) plane, parked on the contexts' caches
        say(f"[{spec.name}] {ev.name}: batch-preparing "
            f"{len(contexts) * len(spec.policies)} instances")
        ev.prepare(contexts, spec.policies, spec.extra)
    elif spec.extra.get("batch_plans"):
        # one vmapped interior-point run replaces the per-mix serial
        # simplex solves the cell evaluators would otherwise trigger
        solved = prewarm_plans(contexts, spec.policies)
        say(f"[{spec.name}] prewarmed {solved} planning LPs (batch_plans)")

    # extra["crn_policies"]: common random numbers across the policy
    # axis -- every policy sees the same per-(mix, n, seed) streams,
    # turning policy comparisons into paired comparisons (the EC.8.6
    # ablation protocol; variance reduction for rankings).
    crn = bool(spec.extra.get("crn_policies", False))
    for mi, ctx in enumerate(contexts):
        for pi, token in enumerate(spec.policies):
            for ni, n in enumerate(spec.n_servers):
                streams = [cell_seed_sequence(spec, mi, 0 if crn else pi,
                                              ni, si)
                           for si in range(spec.n_seeds)]
                say(f"[{spec.name}] {ctx.mix.name} / {token} / n={n} "
                    f"({spec.n_seeds} seeds)")
                cells.extend(ev(ctx, token, n, seeds=streams))

    meta = {
        "evaluator": spec.evaluator,
        "n_cells": len(cells),
        "wall_seconds": round(time.time() - t0, 3),
    }
    if placement is not None:
        from .sharded import detected_devices

        meta["placement"] = placement
        if placement == "shard_map":
            meta["shard_devices"] = detected_devices()
    # schema-versioned provenance record (RunRecord); riders like the
    # sweep CLI append it to artifacts/manifests/runs.jsonl
    import hashlib
    import json

    from repro.telemetry.manifest import run_record

    spec_hash = hashlib.sha256(
        json.dumps(spec.to_dict(), sort_keys=True,
                   default=float).encode()).hexdigest()
    meta["manifest"] = run_record(
        kind="sweep", name=spec.name,
        wall_s=meta["wall_seconds"],
        extra={"evaluator": spec.evaluator, "n_cells": len(cells),
               "placement": placement, "spec_sha256": spec_hash})
    return SweepResult(spec=spec, cells=cells, meta=meta)
