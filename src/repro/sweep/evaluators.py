"""Cell evaluators + the policy-token registry for the sweep subsystem.

A *policy token* is a string naming a policy constructor, optionally with
``:key=value`` arguments, e.g.::

    "gate_and_route"              Section 4 occupancy gate + solo-first router
    "sli_aware"                   Section 5.2 randomized router (SLI plan)
    "GG-SP" ... "FG-SP"           EC.8.6 component ablations
    "vllm", "sarathi"             system baselines
    "distserve_mix_solo:k=4"      DistServe fixed split, absolute k
    "distserve_mix_solo:frac=0.2" fixed split, k = max(1, int(frac * n))

Tokens are resolved against a per-mix :class:`MixContext`, which caches the
planning-LP solves and (for the trace engine) the synthesized trace per
cluster size, so the embarrassingly-parallel seed axis never repeats
deterministic work.

Every evaluator here registers against the unified
:class:`~repro.sweep.spec.Evaluator` protocol (one call signature,
``(ctx, token, n, *, seeds, **extra) -> metric dicts``) under its
:data:`~repro.sweep.spec.EVALUATORS` name -- ``get_evaluator(name)`` is
the one dispatch path the runner and the sharded placements use.  The
historical ``evaluate_*`` entry points remain as thin deprecation shims.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence

import numpy as np

from repro.core.planning import SLISpec, solve_bundled_lp, solve_separate_lp
from repro.core.policies import (PolicySpec, ablation_policy,
                                 baseline_distserve, baseline_sarathi,
                                 baseline_vllm, gate_and_route,
                                 prioritize_and_route, sli_aware_policy)
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

from .spec import MixSpec, SweepSpec, cell_int_seed, register_evaluator

__all__ = [
    "ABLATION_TOKENS",
    "MixContext",
    "parse_policy_token",
    "resolve_policy",
    "evaluate_ctmc_cells",
    "evaluate_ctmc_jax_cells",
    "evaluate_lp_cell",
    "evaluate_lp_jax_grid",
    "evaluate_trace_policy",
    "evaluate_engine_cell",
    "evaluate_engine_jax_cells",
    "prewarm_plans",
]


def _warn_deprecated(old: str, name: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use "
        f"repro.sweep.spec.get_evaluator({name!r}) -- the unified "
        f"Evaluator protocol (ctx, token, n, *, seeds, **extra)",
        DeprecationWarning, stacklevel=3)

# lp-family policy token -> MixContext.plan kind (shared by the serial
# "lp" evaluator and the batched "lp_jax" one)
LP_TOKEN_KINDS = {"lp": "base", "lp_bundled": "base",
                  "lp_separate": "separate", "lp_sli": "sli"}

# plan kind -> (objective, SLISpec) for the batched planner
PLAN_KINDS = {
    "base": ("bundled", None),
    "sli": ("bundled", SLISpec(pin_zero_decode_queue=True)),
    "separate": ("separate", None),
}

ABLATION_TOKENS = ("GG-SP", "FI-WSP", "GI-WSP", "GF-WSP", "FG-SP")


def parse_policy_token(token: str) -> tuple:
    """Split ``"name:k=v,k=v"`` into ``(name, {k: number})``."""
    name, _, argstr = token.partition(":")
    args = {}
    if argstr:
        for part in argstr.split(","):
            k, _, v = part.partition("=")
            if not v:
                raise ValueError(f"malformed policy token {token!r}")
            args[k.strip()] = float(v)
    return name.strip(), args


class MixContext:
    """Per-mix caches shared across the policy/n/seed axes of one sweep."""

    def __init__(self, mix: MixSpec, spec: SweepSpec):
        self.mix = mix
        self.spec = spec
        self.classes = mix.workload_classes()
        self.prim = mix.primitives()
        self.pricing = mix.price()
        self._plans: dict = {}
        self._traces: dict = {}
        self._trace_classes: dict = {}
        # whole-grid Evaluator.prepare hooks park per-token metrics here
        # (keys like ("fluid", token) / ("lp_jax", token))
        self.cache: dict = {}

    # -- planning --------------------------------------------------------------
    def plan(self, kind: str = "base"):
        """LP solutions, cached: "base" (bundled), "sli" (pinned q_d = 0,
        the Section 5.2 router's standing assumption), "separate"."""
        if kind not in self._plans:
            if kind == "base":
                p = solve_bundled_lp(self.classes, self.prim, self.pricing)
            elif kind == "sli":
                p = solve_bundled_lp(
                    self.classes, self.prim, self.pricing,
                    sli=SLISpec(pin_zero_decode_queue=True))
            elif kind == "separate":
                p = solve_separate_lp(self.classes, self.prim, self.pricing)
            else:
                raise ValueError(kind)
            self._plans[kind] = p
        return self._plans[kind]

    # -- trace engine ----------------------------------------------------------
    def trace(self, n: int):
        """Synthesized trace for cluster size n (cached across policies/seeds).

        ``compression_per_server`` in the mix's trace overrides resolves to
        ``compression = value / n`` so per-server offered load stays fixed
        while the cluster grows (the EC.8.3 protocol).  A mix with a
        ``scenario`` name generates from the workload-scenario registry
        (:func:`repro.workloads.get_scenario`) instead of the raw
        ``TraceConfig``; the same overrides apply (narrowed to
        :meth:`Scenario.generate`'s knobs)."""
        if n not in self._traces:
            kw = dict(self.mix.trace)
            cps = kw.pop("compression_per_server", None)
            if cps is not None:
                kw["compression"] = float(cps) / n
            if self.mix.scenario:
                from repro.workloads import get_scenario

                allowed = {"seed", "horizon", "compression", "rate_scale"}
                bad = set(kw) - allowed
                if bad:
                    raise ValueError(
                        f"mix {self.mix.name!r}: trace overrides {sorted(bad)} "
                        f"not supported with scenario={self.mix.scenario!r} "
                        f"(allowed: {sorted(allowed)})")
                self._traces[n] = get_scenario(self.mix.scenario).generate(**kw)
            else:
                from repro.data.traces import TraceConfig, synth_azure_trace

                self._traces[n] = synth_azure_trace(TraceConfig(**kw))
        return self._traces[n]

    def trace_classes(self, n: int):
        if n not in self._trace_classes:
            self._trace_classes[n] = planner_classes_from_trace(
                self.trace(n), n,
                theta=float(self.spec.extra.get("planner_theta", 3e-4)))
        return self._trace_classes[n]

    def trace_plan(self, n: int):
        """Planning LP over the trace-derived classes, cached per n so the
        policy and seed axes never repeat the (deterministic) solve."""
        key = ("trace_plan", n)
        if key not in self._plans:
            self._plans[key] = solve_bundled_lp(
                self.trace_classes(n), self.prim, self.pricing)
        return self._plans[key]


def planner_classes_from_trace(trace, n: int, n_classes: Optional[int] = None,
                               theta: float = 3e-4):
    """Planner inputs from a trace's empirical per-class means."""
    from repro.data.traces import trace_class_means

    if n_classes is None:
        n_classes = max(r.cls for r in trace) + 1
    means = trace_class_means(trace, n_classes)
    return [
        WorkloadClass(f"class{i}", prompt_len=means[i][0],
                      decode_len=means[i][1],
                      arrival_rate=max(means[i][2] / n, 1e-6),
                      patience=theta)
        for i in range(n_classes)
    ]


def resolve_policy(token: str, ctx: MixContext, n: int) -> PolicySpec:
    """Instantiate a policy token for cluster size ``n``."""
    name, args = parse_policy_token(token)
    if name == "gate_and_route":
        return gate_and_route(ctx.plan("base"))
    if name == "gate_and_route_separate":
        # the same plan-tracking occupancy gate, instantiated from the
        # Eq. (42) separate-charging plan and charged separately -- the
        # Theorem 2/3 policy family under the other pricing scheme
        # (bench_optimality_gap's separate-scheme policy)
        return gate_and_route(
            ctx.plan("separate"),
            name="gate_and_route_separate").replace(charging="separate")
    if name == "prioritize_and_route":
        return prioritize_and_route(ctx.plan("separate"))
    if name == "sli_aware":
        return sli_aware_policy(ctx.plan("sli"))
    if name == "sli_aware_general":
        return sli_aware_policy(ctx.plan("sli"), general=True)
    if name in ABLATION_TOKENS:
        return ablation_policy(ctx.plan("base"), name)
    if name == "vllm":
        return baseline_vllm(ctx.plan("base"))
    if name == "sarathi":
        return baseline_sarathi(ctx.plan("base"))
    if name in ("distserve_mix_solo", "distserve_prefill_solo"):
        variant = name[len("distserve_"):]
        k = _distserve_k(args, n)
        return baseline_distserve(ctx.plan("base"), k, variant=variant)
    raise ValueError(f"unknown policy token {token!r}")


def _distserve_k(args: dict, n: int) -> int:
    if "k" in args:
        return int(args["k"])
    if "frac" in args:
        return max(1, int(args["frac"] * n))
    raise ValueError("distserve token needs k= or frac=")


# ---------------------------------------------------------------------------
# CTMC evaluator (aggregate exact simulation; Section 2.3 / EC.8.5)
# ---------------------------------------------------------------------------


def _ctmc_metrics(res, plan) -> dict:
    m = {
        "revenue_rate": float(res.revenue_rate_per_server),
        "R_star": float(plan.revenue_rate),
        "completions": float(res.completions.sum()),
        "arrivals": float(res.arrivals.sum()),
        "abandons_p": float(res.abandons_p.sum()),
        "abandons_d": float(res.abandons_d.sum()),
    }
    if plan.revenue_rate > 0:
        m["gap_pct"] = 100.0 * (1.0 - m["revenue_rate"] / m["R_star"])
    avg_y = res.avg_ym + res.avg_ys
    y_star = plan.ym + plan.ys
    for i in range(len(plan.x)):
        m[f"avg_x/{i}"] = float(res.avg_x[i])
        m[f"avg_y/{i}"] = float(avg_y[i])
        m[f"avg_qp/{i}"] = float(res.avg_qp[i])
        m[f"avg_qd/{i}"] = float(res.avg_qd[i])
        m[f"x_star/{i}"] = float(plan.x[i])
        m[f"y_star/{i}"] = float(y_star[i])
    m["x_err_l1"] = float(np.abs(res.avg_x - plan.x).sum())
    m["y_err_l1"] = float(np.abs(avg_y - y_star).sum())
    return m


@register_evaluator("ctmc")
def _eval_ctmc(ctx: MixContext, token: str, n: int, *,
               seeds: Sequence[np.random.SeedSequence]) -> list:
    """All seed replications of one (mix, policy, n) cell.

    One simulator instance serves the whole replication batch
    (:meth:`CTMCSimulator.run_batch`); each replication gets its own
    spawned stream, so any single cell is exactly reproducible by a direct
    ``CTMCSimulator(..., seed=cell_seed_sequence(...)).run(...)`` call.
    """
    policy = resolve_policy(token, ctx, n)
    spec = ctx.spec
    sim = CTMCSimulator(ctx.classes, ctx.prim, ctx.pricing, policy, n=n,
                        seed=seeds[0], record_every=spec.record_every,
                        telemetry=spec.extra.get("telemetry"))
    results = sim.run_batch(spec.horizon, warmup=spec.warmup, rngs=seeds)
    # judge each policy against its own planning targets (the SLI-aware
    # router plans with q_d pinned to zero, so its x*/y*/R* differ)
    plan = policy.plan if policy.plan is not None else ctx.plan("base")
    out = []
    for r in results:
        m = _ctmc_metrics(r, plan)
        if r.telemetry is not None:
            m["tlm_events"] = float(r.telemetry["events"].sum())
            m["tlm_drops"] = float(r.telemetry["drops"].sum())
        out.append(m)
    return out


def evaluate_ctmc_cells(ctx: MixContext, token: str, n: int,
                        streams: Sequence[np.random.SeedSequence]) -> list:
    """Deprecated: use ``get_evaluator("ctmc")``."""
    _warn_deprecated("evaluate_ctmc_cells", "ctmc")
    return _eval_ctmc(ctx, token, n, seeds=streams)


# ---------------------------------------------------------------------------
# Uniformized JAX CTMC evaluator (same law, vmapped over the seed axis)
# ---------------------------------------------------------------------------


@register_evaluator("ctmc_jax")
def _eval_ctmc_jax(ctx: MixContext, token: str, n: int, *,
                   seeds: Sequence[np.random.SeedSequence],
                   placement: Optional[str] = None,
                   shard: Optional[dict] = None) -> list:
    """All seed replications of one (mix, policy, n) cell, as ONE
    batched run of the uniformized CTMC engine
    (:class:`repro.core.ctmc_jax.UniformizedCTMC`).

    Emits the same metric keys as the Python ``ctmc`` evaluator plus
    three engine diagnostics: ``t_end`` (must equal the horizon --
    smaller means the fixed step budget ran out), ``clip_steps``
    (ticks-mode abandonment-cap clip count; 0 in the default events
    mode) and ``n_events`` (real transitions simulated).  ``stepping``,
    ``n_steps`` and ``x64`` can be overridden via
    ``spec.extra["ctmc_jax"]``.

    ``x64=True`` runs the whole cell in double precision
    (:func:`repro.compat.enable_x64` scoped around construction and the
    scan).  Required at production cluster sizes: once the mean
    inter-event time ``1/(3 n lam)`` drops below the ULP of the float32
    clock (``eps(t) ~ t * 2**-23``), the clock stalls mid-horizon while
    events and revenue keep accruing -- ``t_end < horizon`` and the
    float32 event counter saturating at ``2**24`` are the symptoms.

    ``placement`` picks the batch execution strategy (one of
    :data:`repro.sweep.sharded.PLACEMENTS`; default
    ``spec.extra["placement"]`` or ``"vmap"``) and ``shard`` passes
    tiling overrides to :func:`repro.sweep.sharded.run_sharded`; metric
    values are bitwise identical across placements.
    """
    import contextlib

    from repro.compat import enable_x64
    from repro.core.ctmc_jax import UniformizedCTMC

    spec = ctx.spec
    if placement is None:
        placement = spec.extra.get("placement", "vmap")
    if shard is None:
        shard = spec.extra.get("shard")
    if spec.record_every > 0:
        raise ValueError("the ctmc_jax evaluator does not record "
                         "trajectories; use evaluator='ctmc'")
    kw = dict(spec.extra.get("ctmc_jax", {}))
    x64 = bool(kw.pop("x64", False))
    kw.setdefault("telemetry", spec.extra.get("telemetry"))
    policy = resolve_policy(token, ctx, n)
    with enable_x64() if x64 else contextlib.nullcontext():
        sim = UniformizedCTMC(ctx.classes, ctx.prim, ctx.pricing, policy,
                              n=n, horizon=spec.horizon, warmup=spec.warmup,
                              **kw)
        raw = sim.run_batch_raw([cell_int_seed(ss) for ss in seeds],
                                placement=placement, shard=shard)
        results = sim.results_from_raw(raw)
    clip = np.asarray(raw["clip_steps"])
    plan = policy.plan if policy.plan is not None else ctx.plan("base")
    out = []
    for r, res in enumerate(results):
        m = _ctmc_metrics(res, plan)
        m["t_end"] = float(res.t_end)
        m["clip_steps"] = float(clip[r])
        m["n_events"] = float(res.n_events)
        if sim.telemetry is not None:
            m["tlm_events"] = float(np.asarray(raw["tlm_ev"])[r].sum())
            m["tlm_drops"] = float(np.asarray(raw["tlm_drop"])[r].sum())
        out.append(m)
    return out


def evaluate_ctmc_jax_cells(ctx: MixContext, token: str, n: int,
                            streams: Sequence[np.random.SeedSequence]) -> list:
    """Deprecated: use ``get_evaluator("ctmc_jax")``."""
    _warn_deprecated("evaluate_ctmc_jax_cells", "ctmc_jax")
    return _eval_ctmc_jax(ctx, token, n, seeds=streams)


# ---------------------------------------------------------------------------
# Planning-LP evaluator (deterministic; Figs. 7-8 style sweeps)
# ---------------------------------------------------------------------------


@register_evaluator("lp", deterministic=True)
def _eval_lp(ctx: MixContext, token: str, n: int, *, seeds=()) -> dict:
    """Optimal-plan metrics for one mix (policy axis picks the objective).

    Deterministic: returns ONE metrics dict; the :class:`Evaluator`
    protocol replicates it over the degenerate seed axis.
    """
    name, _ = parse_policy_token(token)
    kind = LP_TOKEN_KINDS.get(name)
    if kind is None:
        raise ValueError(f"lp evaluator got non-lp policy token {token!r}")
    return _lp_metrics(ctx.plan(kind))


def evaluate_lp_cell(ctx: MixContext, token: str) -> dict:
    """Deprecated: use ``get_evaluator("lp")``."""
    _warn_deprecated("evaluate_lp_cell", "lp")
    return _eval_lp(ctx, token, 0)


def _lp_metrics(plan) -> dict:
    from repro.core.planning import tpot_of_plan

    m = {
        "revenue": float(plan.revenue_rate),
        "tpot": float(tpot_of_plan(plan)),
        "x_total": float(plan.x_total),
    }
    for i in range(len(plan.x)):
        m[f"x_star/{i}"] = float(plan.x[i])
        m[f"y_star/{i}"] = float(plan.ym[i] + plan.ys[i])
        m[f"qp_star/{i}"] = float(plan.qp[i])
    return m


# ---------------------------------------------------------------------------
# Batched planning-LP evaluator (vmapped interior point; same grid
# semantics as "lp", whole (mix x policy) plane solved per plan kind)
# ---------------------------------------------------------------------------


def _lp_jax_grid(contexts: Sequence[MixContext],
                 policies: Sequence[str],
                 extra: Optional[dict] = None) -> dict:
    """Metrics for every (mix, lp-policy) pair via
    :func:`repro.core.planning_batch.solve_plan_batch` -- one vmapped
    interior-point run per plan kind instead of a Python loop of simplex
    solves.

    Returns ``{(mix_index, policy_index): metrics}``; the runner
    replicates cells over the degenerate (n, seed) axes exactly as for
    the ``lp`` and ``fluid`` evaluators.  Cells carry the ``lp``
    evaluator's keys plus solver diagnostics: ``lp_primal_res`` /
    ``lp_dual_res`` / ``lp_gap`` (final relative residuals),
    ``lp_converged`` (1.0 iff all three beat the tolerance) and
    ``lp_iters`` (Newton steps taken).  ``extra["lp_jax"]`` may override
    ``{"iters": ..., "tol": ...}``.
    """
    from repro.core.planning_batch import solve_plan_batch

    kw = dict((extra or {}).get("lp_jax", {}))
    jobs: dict = {}  # plan kind -> list of (mi, pi)
    for pi, token in enumerate(policies):
        name, _ = parse_policy_token(token)
        kind = LP_TOKEN_KINDS.get(name)
        if kind is None:
            raise ValueError(
                f"lp_jax evaluator got non-lp policy token {token!r}")
        for mi in range(len(contexts)):
            jobs.setdefault(kind, []).append((mi, pi))

    out: dict = {}
    for kind, cells in jobs.items():
        objective, sli = PLAN_KINDS[kind]
        pb = solve_plan_batch(
            [contexts[mi].classes for mi, _ in cells],
            prims=[contexts[mi].prim for mi, _ in cells],
            pricings=[contexts[mi].pricing for mi, _ in cells],
            objective=objective, sli=sli, **kw)
        for b, (mi, pi) in enumerate(cells):
            m = _lp_metrics(pb.solution(b))
            m["lp_primal_res"] = float(pb.primal_res[b])
            m["lp_dual_res"] = float(pb.dual_res[b])
            m["lp_gap"] = float(pb.gap[b])
            m["lp_converged"] = float(bool(pb.converged[b]))
            m["lp_iters"] = float(pb.n_iter[b])
            out[(mi, pi)] = m
    return out


def _lp_jax_prepare(contexts: Sequence[MixContext],
                    policies: Sequence[str],
                    extra: Optional[dict] = None) -> None:
    """Whole-grid hook: one batched interior-point run per plan kind,
    metrics parked in each ``ctx.cache[("lp_jax", token)]``."""
    grid = _lp_jax_grid(contexts, policies, extra)
    for (mi, pi), m in grid.items():
        contexts[mi].cache[("lp_jax", policies[pi])] = m


@register_evaluator("lp_jax", deterministic=True, prepare=_lp_jax_prepare)
def _eval_lp_jax(ctx: MixContext, token: str, n: int, *, seeds=()) -> dict:
    """Batched-planner metrics for one cell, served from the
    ``prepare`` cache (the runner batch-solves the whole (mix x policy)
    plane up front); a cache miss falls back to a solo batch of one."""
    key = ("lp_jax", token)
    if key not in ctx.cache:
        _lp_jax_prepare([ctx], [token], ctx.spec.extra)
    return ctx.cache[key]


def evaluate_lp_jax_grid(contexts: Sequence[MixContext],
                         policies: Sequence[str],
                         extra: Optional[dict] = None) -> dict:
    """Deprecated: use ``get_evaluator("lp_jax")`` (grid shape via its
    ``prepare`` hook)."""
    _warn_deprecated("evaluate_lp_jax_grid", "lp_jax")
    return _lp_jax_grid(contexts, policies, extra)


# ---------------------------------------------------------------------------
# Fluid-limit evaluator (deterministic; one vmapped integration per grid)
# ---------------------------------------------------------------------------


def _fluid_prepare(contexts: Sequence[MixContext],
                   policies: Sequence[str],
                   extra: Optional[dict] = None) -> None:
    """Whole-grid hook: integrate the full (mix x policy) plane as ONE
    vmapped scan per router family
    (:func:`repro.sweep.fluid_batch.evaluate_fluid_grid`), metrics parked
    in each ``ctx.cache[("fluid", token)]``."""
    from .fluid_batch import evaluate_fluid_grid

    dt = float((extra or {}).get("dt", 2e-3))
    grid = evaluate_fluid_grid(contexts, policies,
                               contexts[0].spec.horizon, dt)
    for (mi, pi), m in grid.items():
        contexts[mi].cache[("fluid", policies[pi])] = m


@register_evaluator("fluid", deterministic=True, prepare=_fluid_prepare)
def _eval_fluid(ctx: MixContext, token: str, n: int, *, seeds=()) -> dict:
    """Fluid-limit metrics for one cell, served from the ``prepare``
    cache; a cache miss falls back to a solo integration.  The fluid
    limit has no cluster-size or seed dependence, so one dict covers the
    degenerate (n, seed) axes."""
    key = ("fluid", token)
    if key not in ctx.cache:
        _fluid_prepare([ctx], [token], ctx.spec.extra)
    return ctx.cache[key]


def prewarm_plans(contexts: Sequence[MixContext],
                  tokens: Sequence[str]) -> int:
    """Batch-solve the class-derived planning LPs the given policy tokens
    will need and stuff every :class:`MixContext` plan cache, so the
    per-cell ``ctx.plan(...)`` lookups never fall back to the serial
    simplex (``spec.extra["batch_plans"]`` turns this on in the runner).

    Returns the number of (mix, kind) plans solved.  Trace-derived plans
    (``MixContext.trace_plan``) are per-``n`` and stay on the oracle
    path.
    """
    from repro.core.planning_batch import solve_plan_batch

    kinds = set()
    for token in tokens:
        name, _ = parse_policy_token(token)
        if name in LP_TOKEN_KINDS:
            kinds.add(LP_TOKEN_KINDS[name])
        elif name in ("sli_aware", "sli_aware_general"):
            kinds.add("sli")
        elif name in ("prioritize_and_route", "gate_and_route_separate"):
            kinds.add("separate")
        else:  # gate_and_route / ablations / system baselines
            kinds.add("base")
    todo = [(ctx, kind) for kind in sorted(kinds) for ctx in contexts
            if ctx.mix.classes and kind not in ctx._plans]
    for kind in sorted({k for _, k in todo}):
        group = [ctx for ctx, k in todo if k == kind]
        objective, sli = PLAN_KINDS[kind]
        pb = solve_plan_batch(
            [ctx.classes for ctx in group],
            prims=[ctx.prim for ctx in group],
            pricings=[ctx.pricing for ctx in group],
            objective=objective,
            sli=sli).require_converged(f"prewarm_plans[{kind}]")
        for b, ctx in enumerate(group):
            ctx._plans[kind] = pb.solution(b)
    return len(todo)


# ---------------------------------------------------------------------------
# Per-server trace engine evaluators (Section 6.2 calibrated simulator)
# ---------------------------------------------------------------------------


def engine_policy_and_cfg(token: str, plan, prim: ServicePrimitives,
                          pricing: Pricing, n: int, seed: int = 0):
    """Resolve a trace-engine policy token to ``(PolicySpec, EngineConfig)``.

    Shared by the Python ``engine`` evaluator and the vmapped
    ``engine_jax`` one, so both understand exactly the same token set:
    ``gate_and_route``, ``sarathi`` (decode-first chunk budget), ``vllm``
    (prefill-first; chunking stays a system property C, exactly as in the
    paper's Section 2 model) and the two DistServe fixed splits.
    """
    from repro.serving.engine_sim import EngineConfig

    name, args = parse_policy_token(token)
    cfg = EngineConfig(prim, pricing, n, seed=seed)
    if name == "gate_and_route":
        policy = gate_and_route(plan)
    elif name == "sarathi":
        policy = baseline_sarathi(plan)
        cfg = EngineConfig(prim, pricing, n, seed=seed, sarathi_budget=True)
    elif name == "vllm":
        policy = baseline_vllm(plan)
    elif name in ("distserve_mix_solo", "distserve_prefill_solo"):
        policy = baseline_distserve(plan, _distserve_k(args, n),
                                    variant=name[len("distserve_"):])
    else:
        raise ValueError(f"engine evaluator got unknown policy {token!r}")
    return policy, cfg


def evaluate_trace_policy(token: str, trace, n: int, *,
                          prim: Optional[ServicePrimitives] = None,
                          pricing: Optional[Pricing] = None,
                          horizon: float = 600.0, online: bool = True,
                          seed: int = 42, sli: Optional[SLISpec] = None,
                          safety: float = 3.0,
                          classes=None, plan=None, telemetry=None) -> dict:
    """One (policy, trace) evaluation in the calibrated per-server engine.

    This is the single implementation behind both the sweep's "engine"
    evaluator and :func:`benchmarks.common.run_trace_policy`.  Pass a
    pre-solved ``plan`` (with matching ``classes``) to skip the LP solve;
    the sweep runner does this via :meth:`MixContext.trace_plan`.
    """
    from repro.core.online import OnlineController, OnlineControllerConfig
    from repro.serving.engine_sim import ClusterEngine, EngineConfig

    prim = prim or ServicePrimitives()
    pricing = pricing or Pricing()
    if classes is None:
        classes = planner_classes_from_trace(trace, n)
    if plan is None:
        plan = solve_bundled_lp(classes, prim, pricing, sli=sli)
    name, args = parse_policy_token(token)
    policy, cfg = engine_policy_and_cfg(token, plan, prim, pricing, n,
                                        seed=seed)
    if telemetry is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, telemetry=telemetry)
    controller = None
    if name == "gate_and_route" and online:
        controller = OnlineController(
            classes, prim, pricing, n=n,
            config=OnlineControllerConfig(sli=sli, safety=safety))
    eng = ClusterEngine(classes, policy, cfg, controller=controller)
    m = eng.run(trace, horizon=horizon)
    out = m.summary()
    if name.startswith("distserve_"):
        out["distserve_k"] = _distserve_k(args, n)
    if m.telemetry is not None:
        out["tlm_events"] = float(m.telemetry["events"].sum())
        out["tlm_drops"] = float(m.telemetry["drops"].sum())
        out["tlm_ttft_p95"] = float(m.telemetry["ttft_p95"])
    return {k: float(v) for k, v in out.items()}


def _engine_cell(ctx: MixContext, token: str, n: int,
                 ss: np.random.SeedSequence) -> dict:
    spec = ctx.spec
    return evaluate_trace_policy(
        token, ctx.trace(n), n,
        prim=ctx.prim, pricing=ctx.pricing,
        horizon=spec.horizon,
        online=bool(spec.extra.get("online", True)),
        seed=cell_int_seed(ss),
        safety=float(spec.extra.get("safety", 3.0)),
        classes=ctx.trace_classes(n),
        plan=ctx.trace_plan(n),
        telemetry=spec.extra.get("telemetry"),
    )


@register_evaluator("engine")
def _eval_engine(ctx: MixContext, token: str, n: int, *,
                 seeds: Sequence[np.random.SeedSequence]) -> list:
    """Per-seed replications of the Python trace engine (serial loop of
    :func:`evaluate_trace_policy`; trace / planner-classes / plan cached
    per n on the context)."""
    return [_engine_cell(ctx, token, n, ss) for ss in seeds]


def evaluate_engine_cell(ctx: MixContext, token: str, n: int,
                         ss: np.random.SeedSequence) -> dict:
    """Deprecated: use ``get_evaluator("engine")``."""
    _warn_deprecated("evaluate_engine_cell", "engine")
    return _engine_cell(ctx, token, n, ss)


@register_evaluator("engine_jax")
def _eval_engine_jax(ctx: MixContext, token: str, n: int, *,
                     seeds: Sequence[np.random.SeedSequence],
                     placement: Optional[str] = None,
                     shard: Optional[dict] = None) -> list:
    """All seed replications of one (mix, policy, n) cell, as ONE
    batched run of the iteration-level trace-replay engine
    (:class:`repro.serving.engine_jax.ClusterEngineJAX`).

    Same policy tokens and summary-metric keys as the Python ``engine``
    evaluator, plus four engine diagnostics: ``t_end`` (last processed
    event time), ``budget_exhausted`` (1.0 iff the fixed scan budget cut
    the replay short -- asserted 0 by the CI smoke), ``n_iters`` /
    ``n_events`` (iterations / events simulated) and ``n_dropped``
    (requests cut by a ``max_requests`` cap).  Differences from the
    Python evaluator: the online controller is not supported, so
    ``gate_and_route`` runs open-loop on the static plan, and engine
    kwargs (``max_steps``, ``max_requests``, ``drain``, plus the hot-path
    switches ``fastforward`` and ``k_events`` -- see the engine module
    docstring for when each applies) come from
    ``spec.extra["engine_jax"]``.

    ``placement`` / ``shard`` select the batch execution strategy
    exactly as for the ``ctmc_jax`` evaluator (defaults from
    ``spec.extra``); metric values are bitwise identical across
    placements.
    """
    from repro.serving.engine_jax import ClusterEngineJAX

    spec = ctx.spec
    if placement is None:
        placement = spec.extra.get("placement", "vmap")
    if shard is None:
        shard = spec.extra.get("shard")
    if spec.record_every > 0:
        raise ValueError("the engine_jax evaluator does not record "
                         "queue traces; use evaluator='engine'")
    kw = dict(spec.extra.get("engine_jax", {}))
    if spec.extra.get("telemetry") is not None:
        kw.setdefault("telemetry", spec.extra["telemetry"])
    policy, cfg = engine_policy_and_cfg(token, ctx.trace_plan(n), ctx.prim,
                                        ctx.pricing, n)
    eng = ClusterEngineJAX(ctx.trace_classes(n), policy, cfg, ctx.trace(n),
                           horizon=spec.horizon, **kw)
    raw = eng.run_batch_raw([cell_int_seed(ss) for ss in seeds],
                            placement=placement, shard=shard)
    out = eng.summaries_from_raw(raw)
    name, args = parse_policy_token(token)
    if name.startswith("distserve_"):
        for m in out:
            m["distserve_k"] = _distserve_k(args, n)
    if eng.telemetry is not None:
        from repro.telemetry.probes import hist_edges, hist_percentile

        edges = hist_edges(eng.telemetry)
        ev = np.asarray(raw["tlm_ev"])
        dr = np.asarray(raw["tlm_drop"])
        tt = np.asarray(raw["tlm_ttft"])
        for r, m in enumerate(out):
            m["tlm_events"] = float(ev[r].sum())
            m["tlm_drops"] = float(dr[r].sum())
            m["tlm_ttft_p95"] = float(hist_percentile(tt[r], edges, 95))
    return [{k: float(v) for k, v in m.items()} for m in out]


def evaluate_engine_jax_cells(ctx: MixContext, token: str, n: int,
                              streams: Sequence[np.random.SeedSequence]
                              ) -> list:
    """Deprecated: use ``get_evaluator("engine_jax")``."""
    _warn_deprecated("evaluate_engine_jax_cells", "engine_jax")
    return _eval_engine_jax(ctx, token, n, seeds=streams)
