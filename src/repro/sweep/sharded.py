"""SPMD sharding layer for the batched sweep engines.

Every JAX evaluator in this repo reduces a grid of independent cells --
(mix, policy, n, seed) replications for the simulators, stacked LP
instances for the planner -- to "one kernel, many leading-axis items".
This module partitions that leading axis over the 1-D ``"cells"`` mesh
(:func:`repro.launch.mesh.cells_mesh`) behind one dispatch path:

* ``placement="single"``    one jitted kernel call per cell (debug /
  memory floor);
* ``placement="vmap"``      the classic single-device batch -- the
  **bitwise oracle** every other placement must reproduce exactly;
* ``placement="shard_map"`` the batch partitioned across devices via
  ``shard_map``; per-cell independence (no collectives inside the
  kernel) keeps it bitwise identical to the vmap oracle at any device
  count.

Three properties make the layer safe on arbitrary grids:

* **Host-count-agnostic PRNG** -- every cell's key derives from its
  *grid coordinates* (``cell_seed_sequence`` -> ``cell_int_seed`` ->
  ``prng_key``), never from its device placement, so 1 device and N
  devices draw identical randomness.
* **Padded-cell masking** -- a ragged batch (``n_cells`` not a multiple
  of the mesh) is padded by repeating cell 0; the padded lanes compute
  real (discarded) work and the host slice ``[:n_cells]`` masks them
  out before anyone reads the results.
* **Device-memory-aware tiling** -- :func:`plan_shards` caps the cells
  resident per device (explicitly or from a ``bytes_per_cell`` /
  ``memory_budget`` estimate) and the runner loops the batch through
  ``n_tiles`` equal-shape passes, so grids larger than device memory
  shard in chunks under ONE compiled executable.

See ``docs/SHARDING.md`` for the mesh layout and the tiling math.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = [
    "PLACEMENTS",
    "ShardPlan",
    "plan_shards",
    "pad_batch",
    "run_sharded",
    "detected_devices",
]

# every way a batch engine can execute its cell batch; "vmap" is the
# single-device oracle, "shard_map" must match it bitwise
PLACEMENTS = ("single", "vmap", "shard_map")

def detected_devices() -> int:
    import jax

    return jax.device_count()


def _warn_serialized(n_devices: int) -> None:
    """Once per process: a shard_map placement that landed on one device
    is a correct but serial run.  Shares the ``"shard-serial"`` guard
    with the compat shard_map shim, so the condition warns exactly once
    no matter which layer detects it first."""
    from repro.compat import warn_once

    warn_once(
        "shard-serial",
        f"placement='shard_map' is running on a 1-device mesh "
        f"({n_devices} device detected): results are exact but the "
        f"batch is not partitioned -- force more host devices with "
        f"XLA_FLAGS=--xla_force_host_platform_device_count=N",
        stacklevel=4)


@dataclass(frozen=True)
class ShardPlan:
    """How one cell batch lays out over the mesh.

    ``per_device`` cells sit on each of ``n_devices`` devices per pass,
    so one pass covers ``tile = n_devices * per_device`` cells and the
    batch takes ``n_tiles`` equal-shape passes (one compile); the final
    ``padded - n_cells`` lanes are padding, masked off on the host.
    """

    n_cells: int
    n_devices: int
    per_device: int

    def __post_init__(self) -> None:
        if self.n_cells < 1 or self.n_devices < 1 or self.per_device < 1:
            raise ValueError(f"degenerate shard plan: {self}")

    @property
    def tile(self) -> int:
        return self.n_devices * self.per_device

    @property
    def n_tiles(self) -> int:
        return -(-self.n_cells // self.tile)

    @property
    def padded(self) -> int:
        return self.n_tiles * self.tile

    @property
    def n_padding(self) -> int:
        return self.padded - self.n_cells

    def report(self) -> dict:
        return {
            "n_cells": self.n_cells, "n_devices": self.n_devices,
            "per_device": self.per_device, "tile": self.tile,
            "n_tiles": self.n_tiles, "n_padding": self.n_padding,
        }


def plan_shards(n_cells: int, *, n_devices: Optional[int] = None,
                max_cells_per_device: Optional[int] = None,
                bytes_per_cell: Optional[float] = None,
                memory_budget: Optional[float] = None) -> ShardPlan:
    """Tile a batch of ``n_cells`` over the devices.

    Default: one pass, ``per_device = ceil(n_cells / n_devices)``.  A
    cap -- ``max_cells_per_device`` directly, or derived as
    ``floor(memory_budget / bytes_per_cell)`` from a per-cell footprint
    estimate -- splits the batch into multiple equal-shape tiles so the
    per-device working set never exceeds the cap.
    """
    if n_cells < 1:
        raise ValueError(f"n_cells must be >= 1, got {n_cells}")
    d = int(n_devices) if n_devices is not None else detected_devices()
    cap = max_cells_per_device
    if bytes_per_cell is not None and memory_budget is not None:
        if bytes_per_cell <= 0:
            raise ValueError("bytes_per_cell must be positive")
        by_mem = max(1, int(memory_budget // bytes_per_cell))
        cap = by_mem if cap is None else min(int(cap), by_mem)
    per = -(-n_cells // d)
    if cap is not None:
        if cap < 1:
            raise ValueError(f"cell cap must be >= 1, got {cap}")
        per = min(per, int(cap))
    return ShardPlan(n_cells=int(n_cells), n_devices=d, per_device=per)


def pad_batch(batched, padded: int):
    """Pad every leaf of ``batched`` along axis 0 to length ``padded`` by
    repeating item 0 (a real cell: its padding lanes compute valid,
    discarded work, so no kernel ever sees out-of-distribution zeros)."""
    import jax
    import jax.numpy as jnp

    def pad(leaf):
        n = leaf.shape[0]
        if n == padded:
            return leaf
        reps = jnp.broadcast_to(leaf[:1],
                                (padded - n,) + tuple(leaf.shape[1:]))
        return jnp.concatenate([leaf, reps], axis=0)

    return jax.tree_util.tree_map(pad, batched)


def run_sharded(kernel, replicated, batched, *,
                plan: Optional[ShardPlan] = None,
                mesh=None,
                n_devices: Optional[int] = None,
                max_cells_per_device: Optional[int] = None,
                bytes_per_cell: Optional[float] = None,
                memory_budget: Optional[float] = None):
    """Evaluate ``kernel(replicated, item)`` for every leading-axis item
    of the ``batched`` pytree, partitioned over the cells mesh.

    Returns ``(raw, report)``: ``raw`` mirrors the kernel's output
    pytree with a leading axis of exactly ``n_cells`` (padding masked
    off, tiles re-concatenated on the host as numpy arrays), ``report``
    is the :meth:`ShardPlan.report` dict plus the serialized flag.
    """
    import jax

    from repro.launch.mesh import cells_mesh, shard_cells_fn

    leaves = jax.tree_util.tree_leaves(batched)
    if not leaves:
        raise ValueError("run_sharded got an empty batched pytree")
    n_cells = int(leaves[0].shape[0])
    if plan is None:
        plan = plan_shards(n_cells, n_devices=n_devices,
                           max_cells_per_device=max_cells_per_device,
                           bytes_per_cell=bytes_per_cell,
                           memory_budget=memory_budget)
    elif plan.n_cells != n_cells:
        raise ValueError(f"plan is for {plan.n_cells} cells, batch has "
                         f"{n_cells}")
    if mesh is None:
        mesh = cells_mesh(plan.n_devices)
    if plan.n_devices == 1:
        _warn_serialized(plan.n_devices)

    fn = shard_cells_fn(kernel, mesh=mesh)  # ONE compile for all tiles
    full = pad_batch(batched, plan.padded)
    tiles = []
    for t in range(plan.n_tiles):
        sl = slice(t * plan.tile, (t + 1) * plan.tile)
        part = jax.tree_util.tree_map(lambda leaf: leaf[sl], full)
        out = fn(replicated, part)
        tiles.append(jax.tree_util.tree_map(np.asarray, out))
    raw = (tiles[0] if plan.n_tiles == 1 else jax.tree_util.tree_map(
        lambda *xs: np.concatenate(xs, axis=0), *tiles))
    raw = jax.tree_util.tree_map(lambda leaf: leaf[:n_cells], raw)
    report = dict(plan.report(), serialized=bool(plan.n_devices == 1))
    return raw, report
