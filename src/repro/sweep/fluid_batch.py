"""jax.vmap-batched fluid-trajectory evaluation for sweep grids.

The fluid ODE (Section 3) is deterministic and per-server scale, so a
sweep's whole (mix x policy) plane can be integrated as ONE vmapped
``lax.scan`` instead of a Python loop of integrations: every instance's
parameter pytree (:func:`repro.core.fluid.fluid_params`) is stacked along
a leading batch axis and :func:`repro.core.fluid.fluid_final_state`
runs once per router family (the solo-first / randomized branch is a
static compile-time flag).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fluid import fluid_final_state, fluid_params

from .evaluators import MixContext, parse_policy_token

__all__ = ["fluid_policy_plan", "integrate_fluid_batch",
           "evaluate_fluid_grid"]

# policy token -> (plan kind, randomized-router flag)
_FLUID_POLICIES = {
    "gate_and_route": ("base", False),
    "sli_aware": ("sli", True),
}


def fluid_policy_plan(token: str):
    name, _ = parse_policy_token(token)
    if name not in _FLUID_POLICIES:
        raise ValueError(
            f"fluid evaluator supports {sorted(_FLUID_POLICIES)}, "
            f"got {token!r}")
    return _FLUID_POLICIES[name]


def integrate_fluid_batch(params_list: Sequence[dict], dt: float,
                          n_steps: int, randomized: bool) -> tuple:
    """Integrate a batch of fluid instances to steady state in one
    vmapped scan.

    All instances must share the class count I (leaves stack to (S, I)).
    Returns ``(final_state, revenue_rate)`` with a leading batch axis:
    ``final_state`` is the ``(qp, x, qdm, qds, ym, ys)`` tuple of (S, I)
    arrays, ``revenue_rate`` is (S,).  Only the final step is kept, so
    memory stays O(S * I) regardless of n_steps.
    """
    batched = jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves), *params_list)
    I = batched["lam"].shape[-1]
    z = jnp.zeros((len(params_list), I))
    state0 = (z, z, z, z, z, z)
    return jax.vmap(
        lambda p, s: fluid_final_state(p, s, dt, n_steps=n_steps,
                                       randomized=randomized)
    )(batched, state0)


def evaluate_fluid_grid(contexts: Sequence[MixContext],
                        policies: Sequence[str], horizon: float,
                        dt: float) -> dict:
    """Metrics for every (mix, policy) pair, batched per router family.

    Returns ``{(mix_index, policy_index): metrics dict}``.  The fluid
    limit has no cluster-size or seed dependence; the sweep runner
    replicates these metrics across the degenerate (n, seed) axes.
    """
    n_steps = max(1, int(horizon / dt))
    jobs: dict = {}  # randomized flag -> list of (key, params, plan)
    for mi, ctx in enumerate(contexts):
        for pi, token in enumerate(policies):
            kind, randomized = fluid_policy_plan(token)
            plan = ctx.plan(kind)
            params = fluid_params(ctx.classes, ctx.prim, ctx.pricing, plan,
                                  randomized_router=randomized)
            jobs.setdefault(randomized, []).append(((mi, pi), params, plan))

    out: dict = {}
    for randomized, group in jobs.items():
        keys = [g[0] for g in group]
        params_list = [g[1] for g in group]
        plans = [g[2] for g in group]
        (qp, x, qdm, qds, ym, ys), rev = integrate_fluid_batch(
            params_list, dt, n_steps, randomized)
        qd = np.asarray(qdm + qds)
        for b, key in enumerate(keys):
            plan = plans[b]
            m = {
                "revenue_rate": float(rev[b]),
                "R_star": float(plan.revenue_rate),
            }
            if plan.revenue_rate > 0:
                m["gap_pct"] = 100.0 * (1.0 - m["revenue_rate"]
                                        / m["R_star"])
            fx = np.asarray(x[b])
            fy = np.asarray(ym[b] + ys[b])
            y_star = plan.ym + plan.ys
            for i in range(fx.shape[0]):
                m[f"avg_x/{i}"] = float(fx[i])
                m[f"avg_y/{i}"] = float(fy[i])
                m[f"avg_qp/{i}"] = float(qp[b, i])
                m[f"avg_qd/{i}"] = float(qd[b, i])
                m[f"x_star/{i}"] = float(plan.x[i])
                m[f"y_star/{i}"] = float(y_star[i])
            m["x_err_l1"] = float(np.abs(fx - plan.x).sum())
            m["y_err_l1"] = float(np.abs(fy - y_star).sum())
            out[key] = m
    return out
