"""CLI entry for batched policy sweeps.

    PYTHONPATH=src python -m repro.sweep.run \
        --policies gate_and_route,sli_aware,FG-SP \
        --ns 20,50,100 --n-seeds 8 --out artifacts/sweep/default.json

Runs the (policy x cluster-size x seed x mix) grid through the chosen
evaluator and writes one schema-validated JSON artifact (see
:mod:`repro.sweep.spec`).  ``--spec FILE`` replays a previously saved
spec verbatim; ``benchmarks/run.py`` delegates its "sweep" suite entry
here.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

from .runner import run_sweep
from .sharded import PLACEMENTS
from .spec import EVALUATORS, MixSpec, SweepResult, SweepSpec

__all__ = ["main", "default_mix", "fmt_table"]

# The EC.8.5 two-class synthetic instance (decode-heavy vs prefill-heavy);
# the same instance anchors bench_sli_pareto / bench_convergence.
TWO_CLASS = MixSpec(
    name="two_class",
    classes=(
        dict(name="decode-heavy", prompt_len=300, decode_len=1000,
             arrival_rate=0.5, patience=0.1),
        dict(name="prefill-heavy", prompt_len=3000, decode_len=400,
             arrival_rate=0.5, patience=0.1),
    ),
)

MIX_PRESETS = {"two_class": TWO_CLASS}


def default_mix(name: str = "two_class") -> MixSpec:
    return MIX_PRESETS[name]


def _csv(s: str) -> tuple:
    return tuple(p for p in s.split(",") if p)


def fmt_table(rows, cols, title):
    w = {c: max(len(c), *(len(f"{r.get(c, '')}") for r in rows)) for c in cols}
    out = [title, " | ".join(c.ljust(w[c]) for c in cols)]
    out.append("-|-".join("-" * w[c] for c in cols))
    for r in rows:
        out.append(" | ".join(f"{r.get(c, '')}".ljust(w[c]) for c in cols))
    return "\n".join(out)


def build_spec(args) -> SweepSpec:
    if args.spec:
        d = json.loads(Path(args.spec).read_text())
        if "spec" in d and "schema_version" in d:
            d = d["spec"]  # a saved SweepResult artifact: replay its grid
        return SweepSpec.from_dict(d)
    if args.smoke:
        return SweepSpec(
            name=args.name or "smoke", evaluator="ctmc",
            policies=("gate_and_route",), n_servers=(20,), n_seeds=1,
            seed=args.seed, mixes=(default_mix(args.mix or "two_class"),),
            horizon=5.0, warmup=1.0)
    policies = _csv(args.policies)
    ns = tuple(int(n) for n in _csv(args.ns))
    n_seeds = args.n_seeds
    horizon, warmup = args.horizon, args.warmup
    if args.quick:
        ns = ns[:2]
        n_seeds = min(n_seeds, 2)
        horizon, warmup = min(horizon, 40.0), min(warmup, 10.0)
    mixes = (default_mix(args.mix or "two_class"),)
    if args.scenarios:
        # scenario axis: one mix per registered workload scenario; only
        # the trace-driven evaluators generate from scenarios
        if args.mix is not None:
            raise SystemExit("--scenarios and --mix are mutually exclusive "
                             "(each scenario becomes its own mix)")
        if args.evaluator not in ("engine", "engine_jax"):
            raise SystemExit(
                "--scenarios needs a trace-driven evaluator "
                "(--evaluator engine or engine_jax)")
        from repro.workloads import get_scenario

        names = _csv(args.scenarios)
        overrides = {}
        if args.rate_scale != 1.0:
            overrides["rate_scale"] = args.rate_scale
        mixes = tuple(
            MixSpec(
                name=name, scenario=name,
                # only spec.horizon is replayed: don't generate (and, for
                # engine_jax, tensorize) arrivals past it
                trace=dict(
                    overrides,
                    horizon=min(horizon, get_scenario(name).horizon)))
            for name in names)
    extra = {}
    if args.placement:
        extra["placement"] = args.placement
    return SweepSpec(
        name=args.name or "sweep", evaluator=args.evaluator,
        policies=policies, n_servers=ns, n_seeds=n_seeds, seed=args.seed,
        mixes=mixes, horizon=horizon, warmup=warmup, extra=extra)


def summarize(result: SweepResult) -> str:
    spec = result.spec
    rows = []
    key = ("revenue" if spec.evaluator in ("lp", "lp_jax")
           else "revenue_rate")
    for mix in spec.mixes:
        for token in spec.policies:
            for n in spec.n_servers:
                sel = result.select(mix=mix.name, policy=token, n=n)
                if not sel:
                    continue
                vals = np.array([c.metrics[key] for c in sel])
                row = {"mix": mix.name, "policy": token, "n": n,
                       key: round(float(vals.mean()), 2),
                       "std": round(float(vals.std()), 2),
                       "seeds": len(sel)}
                gaps = [c.metrics["gap_pct"] for c in sel
                        if "gap_pct" in c.metrics]
                if gaps:
                    row["gap_pct"] = round(float(np.mean(gaps)), 2)
                rows.append(row)
    cols = ["mix", "policy", "n", key, "std", "seeds"]
    if any("gap_pct" in r for r in rows):
        cols.append("gap_pct")
    return fmt_table(rows, cols,
                      f"\n[sweep:{spec.name}] {spec.evaluator} grid, "
                      f"{result.meta.get('n_cells', len(result.cells))} cells "
                      f"in {result.meta.get('wall_seconds', '?')}s")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep.run",
        description="Run a batched (policy x n x seed x mix) sweep and "
                    "write one schema-validated JSON artifact.")
    ap.add_argument("--policies", default="gate_and_route,sli_aware,FG-SP",
                    help="comma-separated policy tokens")
    ap.add_argument("--ns", default="20,50,100",
                    help="comma-separated cluster sizes")
    ap.add_argument("--n-seeds", type=int, default=8,
                    help="seed replications per cell")
    ap.add_argument("--seed", type=int, default=0,
                    help="master entropy for the per-cell streams")
    ap.add_argument("--evaluator", default="ctmc", choices=EVALUATORS)
    ap.add_argument("--placement", default=None, choices=PLACEMENTS,
                    help="batch execution strategy for the JAX evaluators "
                         "(shard_map partitions the seed axis over the "
                         "device mesh; default vmap)")
    ap.add_argument("--mix", default=None, choices=sorted(MIX_PRESETS),
                    help="workload-mix preset (default two_class; "
                         "mutually exclusive with --scenarios)")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated workload-scenario names (the "
                         "scenario axis: one mix per name; engine/"
                         "engine_jax evaluators only; see python -m "
                         "repro.workloads.run --list)")
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="scenario arrival-intensity multiplier "
                         "(with --scenarios)")
    ap.add_argument("--horizon", type=float, default=90.0)
    ap.add_argument("--warmup", type=float, default=30.0)
    ap.add_argument("--name", default=None, help="sweep/artifact name")
    ap.add_argument("--spec", default=None,
                    help="JSON file with a full SweepSpec (overrides flags)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default artifacts/sweep/<name>.json)")
    ap.add_argument("--quick", action="store_true",
                    help="trim the grid for a fast sanity run")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal 1x1x1 grid (CI smoke test)")
    args = ap.parse_args(argv)

    spec = build_spec(args)
    print(f"[sweep:{spec.name}] {spec.evaluator}: "
          f"{len(spec.policies)} policies x {len(spec.n_servers)} sizes x "
          f"{spec.n_seeds} seeds x {len(spec.mixes)} mixes "
          f"= {spec.n_cells} cells", flush=True)
    result = run_sweep(spec, progress=lambda m: print(m, flush=True))
    print(summarize(result))
    out = Path(args.out) if args.out else (
        Path("artifacts") / "sweep" / f"{spec.name}.json")
    result.save(out)
    print(f"[sweep:{spec.name}] wrote {out}")
    record = result.meta.get("manifest")
    if record is not None:
        from repro.telemetry.manifest import (append_record, file_digest)

        record = dict(record, artifacts={str(out): file_digest(out)})
        # the repo-central log is for artifacts that live in the repo's
        # artifacts/ tree; a sweep written elsewhere (smoke runs, /tmp)
        # carries its manifest next to the artifact instead
        central = Path("artifacts").resolve()
        in_repo = out.resolve().is_relative_to(central)
        mpath = append_record(record) if in_repo else append_record(
            record, out.with_name(out.stem + ".runs.jsonl"))
        print(f"[sweep:{spec.name}] manifest -> {mpath}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
