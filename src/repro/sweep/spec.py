"""Schema for batched policy sweeps: ``SweepSpec`` in, ``SweepResult`` out.

A sweep evaluates the cross product

    workload mix  x  policy  x  cluster size n  x  seed replication

under one of seven evaluators (aggregate CTMC, its vmapped uniformized
JAX twin, vmapped fluid ODE, planning LP, the planning LP's vmapped
interior-point twin, per-server trace engine, and the trace engine's
vmapped JAX twin) and emits a single JSON artifact that
every benchmark shares.  Randomness is fully determined by ``SweepSpec.seed``:
each grid cell derives its own :class:`numpy.random.SeedSequence` from the
cell's *coordinates*, so results are independent of iteration order and
bitwise reproducible (see :func:`cell_seed_sequence`).
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

__all__ = [
    "SCHEMA_VERSION",
    "EVALUATORS",
    "Evaluator",
    "register_evaluator",
    "get_evaluator",
    "MixSpec",
    "SweepSpec",
    "CellResult",
    "SweepResult",
    "SweepSchemaError",
    "cell_seed_sequence",
    "validate_payload",
]

SCHEMA_VERSION = 1
EVALUATORS = ("ctmc", "ctmc_jax", "fluid", "lp", "lp_jax", "engine",
              "engine_jax")


class SweepSchemaError(ValueError):
    """A sweep payload does not conform to the published schema."""


@dataclass(frozen=True)
class MixSpec:
    """One workload mix: request classes plus instance overrides.

    ``classes`` holds :class:`WorkloadClass` kwargs dicts (empty for the
    "engine" evaluator, which derives planner classes from the trace).
    ``prim`` / ``pricing`` override :class:`ServicePrimitives` /
    :class:`Pricing` fields; ``trace`` overrides
    :class:`repro.data.traces.TraceConfig` fields and additionally accepts
    ``compression_per_server`` (compression is then ``value / n``, keeping
    per-server offered load constant across cluster sizes).

    ``scenario`` names a registered workload scenario
    (:func:`repro.workloads.get_scenario`); when set, the trace-driven
    evaluators (``engine`` / ``engine_jax``) generate the trace from the
    scenario instead of the raw ``TraceConfig``, and ``trace`` overrides
    narrow to the :meth:`Scenario.generate` knobs (``seed``,
    ``horizon``, ``compression`` / ``compression_per_server``,
    ``rate_scale``).  This is the sweep's *scenario axis*: one mix per
    scenario name (``python -m repro.sweep.run --scenarios ...``).
    """

    name: str = "default"
    classes: tuple = ()
    prim: dict = field(default_factory=dict)
    pricing: dict = field(default_factory=dict)
    trace: dict = field(default_factory=dict)
    scenario: str = ""

    def workload_classes(self) -> tuple:
        return tuple(WorkloadClass(**dict(c)) for c in self.classes)

    def primitives(self) -> ServicePrimitives:
        return ServicePrimitives(**self.prim)

    def price(self) -> Pricing:
        return Pricing(**self.pricing)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "classes": [dict(c) for c in self.classes],
            "prim": dict(self.prim),
            "pricing": dict(self.pricing),
            "trace": dict(self.trace),
            "scenario": self.scenario,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MixSpec":
        return cls(
            name=d.get("name", "default"),
            classes=tuple(dict(c) for c in d.get("classes", ())),
            prim=dict(d.get("prim", {})),
            pricing=dict(d.get("pricing", {})),
            trace=dict(d.get("trace", {})),
            scenario=d.get("scenario", ""),
        )


@dataclass(frozen=True)
class SweepSpec:
    """Full description of a policy-sweep grid (JSON round-trippable)."""

    name: str = "sweep"
    evaluator: str = "ctmc"  # one of EVALUATORS
    policies: tuple = ("gate_and_route",)
    n_servers: tuple = (50,)
    n_seeds: int = 1
    seed: int = 0  # master entropy; cells derive their own streams
    mixes: tuple = (MixSpec(),)
    horizon: float = 200.0
    warmup: float = 50.0
    record_every: float = 0.0
    extra: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.evaluator not in EVALUATORS:
            raise SweepSchemaError(
                f"evaluator {self.evaluator!r} not in {EVALUATORS}")
        if not self.policies or not self.n_servers or not self.mixes:
            raise SweepSchemaError("policies/n_servers/mixes must be nonempty")
        if self.n_seeds < 1:
            raise SweepSchemaError("n_seeds must be >= 1")

    @property
    def n_cells(self) -> int:
        return (len(self.mixes) * len(self.policies) * len(self.n_servers)
                * self.n_seeds)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["policies"] = list(self.policies)
        d["n_servers"] = [int(n) for n in self.n_servers]
        d["mixes"] = [m.to_dict() for m in self.mixes]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SweepSpec":
        return cls(
            name=d.get("name", "sweep"),
            evaluator=d.get("evaluator", "ctmc"),
            policies=tuple(d.get("policies", ("gate_and_route",))),
            n_servers=tuple(int(n) for n in d.get("n_servers", (50,))),
            n_seeds=int(d.get("n_seeds", 1)),
            seed=int(d.get("seed", 0)),
            mixes=tuple(MixSpec.from_dict(m)
                        for m in d.get("mixes", ({},))),
            horizon=float(d.get("horizon", 200.0)),
            warmup=float(d.get("warmup", 50.0)),
            record_every=float(d.get("record_every", 0.0)),
            extra=dict(d.get("extra", {})),
        )


def cell_seed_sequence(spec: SweepSpec, mix_i: int, policy_i: int,
                       n_i: int, seed_i: int) -> np.random.SeedSequence:
    """Independent, coordinate-keyed RNG stream for one grid cell.

    The entropy is ``(spec.seed, mix, policy, n, seed)`` *indices*, so the
    same spec always yields the same stream per cell no matter how the grid
    is iterated or parallelised, and adding values to one axis never
    perturbs the streams of existing cells on the other axes.
    """
    return np.random.SeedSequence(
        entropy=(int(spec.seed), mix_i, policy_i, n_i, seed_i))


def cell_int_seed(ss: np.random.SeedSequence) -> int:
    """Collapse a cell stream to an int for engines that take int seeds."""
    return int(ss.generate_state(1, np.uint32)[0])


# ---------------------------------------------------------------------------
# Evaluator protocol: one call signature for every engine
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Evaluator:
    """One registered sweep evaluator behind the uniform protocol.

    Calling it evaluates one (mix, policy, n) cell group::

        evaluator(ctx, token, n, seeds=streams, **extra)
            -> list[CellResult]   # one per seed replication

    ``ctx`` is the :class:`~repro.sweep.evaluators.MixContext`, ``token``
    a policy token, ``n`` the cluster size, ``seeds`` the cell's
    :class:`numpy.random.SeedSequence` streams (one per replication) and
    ``extra`` evaluator-specific overrides (e.g. ``placement=`` for the
    JAX engines) that default from ``ctx.spec.extra``.

    ``fn`` implements the cell group and returns metric dicts -- a list
    (one per seed), or for ``deterministic`` evaluators a single dict
    that is replicated over the seed axis.  ``prepare(contexts,
    policies, extra)`` is an optional whole-grid hook the runner calls
    once up front; the grid-batched evaluators (fluid ODE, batched
    planning LP) use it to solve the full (mix x policy) plane in ONE
    vmapped run and cache per-cell metrics on the contexts.
    """

    name: str
    fn: Callable
    deterministic: bool = False
    prepare: Optional[Callable] = None

    def __call__(self, ctx, token: str, n: int, *, seeds, **extra) -> list:
        out = self.fn(ctx, token, n, seeds=seeds, **extra)
        if self.deterministic:
            metrics = [dict(out) for _ in seeds]
        else:
            metrics = [dict(m) for m in out]
            if len(metrics) != len(seeds):
                raise SweepSchemaError(
                    f"evaluator {self.name!r} returned {len(metrics)} "
                    f"metric dicts for {len(seeds)} seeds")
        return [CellResult(ctx.mix.name, token, int(n), si, m)
                for si, m in enumerate(metrics)]


EVALUATOR_REGISTRY: Dict[str, Evaluator] = {}


def register_evaluator(name: str, *, deterministic: bool = False,
                       prepare: Optional[Callable] = None) -> Callable:
    """Decorator: register ``fn`` as the evaluator behind ``name``.

    The canonical names live in :data:`EVALUATORS`; the built-in
    implementations register themselves on first import of
    :mod:`repro.sweep.evaluators`.
    """

    def deco(fn: Callable) -> Callable:
        EVALUATOR_REGISTRY[name] = Evaluator(
            name=name, fn=fn, deterministic=deterministic, prepare=prepare)
        return fn

    return deco


def get_evaluator(name: str) -> Evaluator:
    """The :class:`Evaluator` registered under ``name``."""
    if name not in EVALUATOR_REGISTRY:
        import repro.sweep.evaluators  # noqa: F401 - registers built-ins
    try:
        return EVALUATOR_REGISTRY[name]
    except KeyError:
        raise SweepSchemaError(
            f"no evaluator registered under {name!r} "
            f"(known: {sorted(EVALUATOR_REGISTRY)})") from None


@dataclass
class CellResult:
    """Scalar metrics of one grid cell (per-class metrics are flattened
    as ``"<metric>/<class index>"`` keys).

    Non-finite metrics (e.g. ``ttft_mean`` when nothing completed within
    the horizon) serialise as JSON ``null`` -- never the bare ``NaN``
    token, which strict JSON parsers reject -- and load back as NaN.
    """

    mix: str
    policy: str
    n: int
    seed: int  # seed *index* on the replication axis
    metrics: dict

    def to_dict(self) -> dict:
        def enc(v):
            v = float(v)
            return v if math.isfinite(v) else None

        return {"mix": self.mix, "policy": self.policy, "n": int(self.n),
                "seed": int(self.seed),
                "metrics": {k: enc(v) for k, v in self.metrics.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "CellResult":
        return cls(mix=d["mix"], policy=d["policy"], n=int(d["n"]),
                   seed=int(d["seed"]),
                   metrics={k: (float("nan") if v is None else float(v))
                            for k, v in d["metrics"].items()})


@dataclass
class SweepResult:
    """All cells of one sweep + the spec that produced them."""

    spec: SweepSpec
    cells: list
    meta: dict = field(default_factory=dict)

    # -- queries ---------------------------------------------------------------
    def select(self, *, mix: Optional[str] = None,
               policy: Optional[str] = None,
               n: Optional[int] = None,
               seed: Optional[int] = None) -> list:
        out = []
        for c in self.cells:
            if mix is not None and c.mix != mix:
                continue
            if policy is not None and c.policy != policy:
                continue
            if n is not None and c.n != n:
                continue
            if seed is not None and c.seed != seed:
                continue
            out.append(c)
        return out

    def metric(self, name: str, **filters) -> np.ndarray:
        """Metric values over matching cells (grid order)."""
        return np.array([c.metrics[name] for c in self.select(**filters)])

    def mean_over_seeds(self, name: str, **filters) -> float:
        vals = self.metric(name, **filters)
        return float(np.mean(vals)) if vals.size else float("nan")

    # -- serialisation ---------------------------------------------------------
    def to_payload(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "spec": self.spec.to_dict(),
            "cells": [c.to_dict() for c in self.cells],
            "meta": dict(self.meta),
        }

    @classmethod
    def from_payload(cls, payload: dict) -> "SweepResult":
        validate_payload(payload)
        return cls(
            spec=SweepSpec.from_dict(payload["spec"]),
            cells=[CellResult.from_dict(c) for c in payload["cells"]],
            meta=dict(payload.get("meta", {})),
        )

    def fingerprint(self) -> str:
        """Deterministic digest of spec + cells (meta excluded: it carries
        wall-clock runtime, which legitimately varies between runs)."""
        import hashlib

        p = self.to_payload()
        blob = json.dumps({"spec": p["spec"], "cells": p["cells"]},
                          sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()

    def save(self, path) -> Path:
        path = Path(path)
        payload = self.to_payload()
        validate_payload(payload)  # never write a non-conforming artifact
        path.parent.mkdir(parents=True, exist_ok=True)
        # allow_nan=False backstops the null encoding of non-finite metrics
        path.write_text(json.dumps(payload, indent=1, allow_nan=False))
        return path

    @classmethod
    def load(cls, path) -> "SweepResult":
        return cls.from_payload(json.loads(Path(path).read_text()))


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SweepSchemaError(msg)


def validate_payload(payload: dict) -> None:
    """Structural validation of a sweep artifact; raises SweepSchemaError."""
    _require(isinstance(payload, dict), "payload must be an object")
    for key in ("schema_version", "spec", "cells"):
        _require(key in payload, f"missing top-level key {key!r}")
    _require(payload["schema_version"] == SCHEMA_VERSION,
             f"schema_version must be {SCHEMA_VERSION}")
    spec = payload["spec"]
    _require(isinstance(spec, dict), "spec must be an object")
    for key in ("name", "evaluator", "policies", "n_servers", "n_seeds",
                "seed", "mixes", "horizon", "warmup"):
        _require(key in spec, f"spec missing key {key!r}")
    _require(spec["evaluator"] in EVALUATORS,
             f"unknown evaluator {spec['evaluator']!r}")
    _require(isinstance(spec["policies"], list) and spec["policies"],
             "spec.policies must be a nonempty list")
    _require(isinstance(spec["n_servers"], list) and spec["n_servers"],
             "spec.n_servers must be a nonempty list")
    _require(isinstance(spec["mixes"], list) and spec["mixes"],
             "spec.mixes must be a nonempty list")
    for m in spec["mixes"]:
        _require(isinstance(m, dict) and "name" in m,
                 "each mix must be an object with a name")
    cells = payload["cells"]
    _require(isinstance(cells, list), "cells must be a list")
    mix_names = {m["name"] for m in spec["mixes"]}
    policies = set(spec["policies"])
    for c in cells:
        _require(isinstance(c, dict), "each cell must be an object")
        for key in ("mix", "policy", "n", "seed", "metrics"):
            _require(key in c, f"cell missing key {key!r}")
        _require(c["mix"] in mix_names, f"cell mix {c['mix']!r} not in spec")
        _require(c["policy"] in policies,
                 f"cell policy {c['policy']!r} not in spec")
        _require(isinstance(c["metrics"], dict) and c["metrics"],
                 "cell metrics must be a nonempty object")
        for k, v in c["metrics"].items():
            _require(isinstance(k, str), "metric keys must be strings")
            _require(v is None or (isinstance(v, (int, float))
                                   and not isinstance(v, bool)),
                     f"metric {k!r} must be a number or null (non-finite)")
