"""Batched policy-sweep subsystem.

Evaluates a (workload mix x policy x cluster size x seed) grid in one
call -- the execution backbone of every benchmark in ``benchmarks/`` and
the paper's convergence (EC.8.5) and scaling (EC.8.3) experiments.

* :mod:`repro.sweep.spec` -- ``SweepSpec`` / ``SweepResult`` JSON schema,
  per-cell ``SeedSequence`` streams, the :class:`Evaluator` protocol +
  registry (``get_evaluator`` / ``register_evaluator``).
* :mod:`repro.sweep.evaluators` -- policy-token registry + the registered
  ctmc / ctmc_jax / fluid / lp / lp_jax / engine / engine_jax evaluators.
* :mod:`repro.sweep.fluid_batch` -- ``jax.vmap``-batched fluid-ODE grid.
* :mod:`repro.sweep.sharded` -- SPMD (``shard_map``) grid partitioning
  over the device mesh; :data:`PLACEMENTS` catalog.
* :mod:`repro.sweep.runner` -- :func:`run_sweep` grid executor.
* :mod:`repro.sweep.run` -- ``python -m repro.sweep.run`` CLI.
"""

from .spec import (CellResult, Evaluator, MixSpec, SweepResult,
                   SweepSchemaError, SweepSpec, cell_seed_sequence,
                   get_evaluator, register_evaluator, validate_payload)
from .runner import run_sweep
from .sharded import PLACEMENTS

__all__ = [
    "CellResult",
    "Evaluator",
    "MixSpec",
    "PLACEMENTS",
    "SweepResult",
    "SweepSchemaError",
    "SweepSpec",
    "cell_seed_sequence",
    "get_evaluator",
    "register_evaluator",
    "validate_payload",
    "run_sweep",
]
