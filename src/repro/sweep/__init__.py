"""Batched policy-sweep subsystem.

Evaluates a (workload mix x policy x cluster size x seed) grid in one
call -- the execution backbone of every benchmark in ``benchmarks/`` and
the paper's convergence (EC.8.5) and scaling (EC.8.3) experiments.

* :mod:`repro.sweep.spec` -- ``SweepSpec`` / ``SweepResult`` JSON schema,
  per-cell ``SeedSequence`` streams.
* :mod:`repro.sweep.evaluators` -- policy-token registry + the ctmc /
  ctmc_jax / lp / engine cell evaluators.
* :mod:`repro.sweep.fluid_batch` -- ``jax.vmap``-batched fluid-ODE grid.
* :mod:`repro.sweep.runner` -- :func:`run_sweep` grid executor.
* :mod:`repro.sweep.run` -- ``python -m repro.sweep.run`` CLI.
"""

from .spec import (CellResult, MixSpec, SweepResult, SweepSchemaError,
                   SweepSpec, cell_seed_sequence, validate_payload)
from .runner import run_sweep

__all__ = [
    "CellResult",
    "MixSpec",
    "SweepResult",
    "SweepSchemaError",
    "SweepSpec",
    "cell_seed_sequence",
    "validate_payload",
    "run_sweep",
]
