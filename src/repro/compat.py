"""Version-guarded shims over moving JAX APIs.

The repo targets the modern surface (``jax.shard_map``,
``jax.sharding.AxisType``) but must keep working on older CPU-only
installs such as the test container's jax.  Import these names from here
instead of from jax directly; each shim degrades to the closest older
equivalent.
"""

from __future__ import annotations

import inspect
import warnings

import jax

__all__ = ["AxisType", "shard_map", "make_mesh", "pcast", "prng_key",
           "enable_x64", "SHARD_MAP_IMPL"]

try:  # scoped double precision (the lp_jax solver runs inside this)
    from jax.experimental import enable_x64
except ImportError:  # very old jax: emulate with a global-flag swap
    from contextlib import contextmanager

    @contextmanager
    def enable_x64(new_val: bool = True):
        old = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", new_val)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", old)

try:  # jax >= 0.5-ish: explicit axis types on mesh axes
    from jax.sharding import AxisType
except ImportError:  # older jax: every mesh axis is implicitly "auto"
    AxisType = None

try:  # jax >= 0.8 public API
    from jax import shard_map as _shard_map_impl
    SHARD_MAP_IMPL = "jax.shard_map"
except ImportError:
    try:  # older jax: same callable under experimental
        from jax.experimental.shard_map import shard_map as _shard_map_impl
        SHARD_MAP_IMPL = "jax.experimental.shard_map"
    except ImportError:  # ancient jax: single-device emulation only
        _shard_map_impl = None
        SHARD_MAP_IMPL = "fallback"

# the replication-check kwarg was renamed check_rep -> check_vma; probe
# once so callers can pass a version-neutral ``check=``
_SHARD_CHECK_KW = None
if _shard_map_impl is not None:
    _params = inspect.signature(_shard_map_impl).parameters
    _SHARD_CHECK_KW = ("check_vma" if "check_vma" in _params
                       else "check_rep" if "check_rep" in _params else None)

# Process-wide once-per-kind warning guard.  The shard_map shim here and
# ``run_sharded`` both detect the same condition (a "sharded" run that is
# actually serial on a 1-device mesh) from different layers, so without a
# shared guard a single sweep warns once per layer per process.  Each
# distinct ``kind`` fires at most once; tests reset via ``reset_warn_once``.
_warned_once: set = set()


def warn_once(kind: str, message: str, *, stacklevel: int = 3) -> bool:
    """Emit ``message`` as a RuntimeWarning the first time ``kind`` is seen.

    Returns True if the warning fired, False if ``kind`` already warned
    in this process.
    """
    if kind in _warned_once:
        return False
    _warned_once.add(kind)
    warnings.warn(message, RuntimeWarning, stacklevel=stacklevel + 1)
    return True


def reset_warn_once(kind: str | None = None) -> None:
    """Re-arm the once-per-kind guard (all kinds when ``kind`` is None)."""
    if kind is None:
        _warned_once.clear()
    else:
        _warned_once.discard(kind)


def _warn_shard_fallback() -> None:
    """One-time, loud: a "sharded" run on this jax is actually serial."""
    warn_once(
        "shard-serial",
        f"this jax has no shard_map; emulating on a 1-device mesh "
        f"({jax.device_count()} device(s) detected) -- the run computes "
        f"the same values but is NOT partitioned across devices",
        stacklevel=4)


def shard_map(f, *, mesh, in_specs, out_specs, check=None):
    """``shard_map`` with a *visible* single-device fallback.

    On any jax that ships shard_map (public or experimental) this is a
    pass-through (``check`` maps onto ``check_vma``/``check_rep``,
    whichever this jax spells).  Without it, the only mesh we can honor
    is a 1-device mesh -- there each "cells"-axis block IS the full
    array, so calling ``f`` directly is exact -- and a one-time
    ``RuntimeWarning`` with the detected device count makes the
    serialization visible instead of silent; a multi-device mesh raises,
    because silently computing wrong shapes is worse than failing.
    """
    if _shard_map_impl is not None:
        kw = {}
        if check is not None and _SHARD_CHECK_KW is not None:
            kw[_SHARD_CHECK_KW] = check
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kw)
    if mesh.devices.size != 1:
        raise RuntimeError(
            f"this jax has no shard_map and the fallback only emulates a "
            f"1-device mesh, got {mesh.devices.size} devices")
    _warn_shard_fallback()
    return f


if hasattr(jax.lax, "pcast"):
    pcast = jax.lax.pcast
else:
    def pcast(x, axes, *, to):  # noqa: ARG001 - mirror the jax signature
        # Older jax has no varying-manual-axes type system; replicated and
        # varying values are indistinguishable, so the cast is a no-op.
        return x


def prng_key(seed: int):
    """Raw uint32 PRNG key where available, new-style typed key otherwise.

    The uniformized CTMC engine stacks per-replication keys for
    ``jax.vmap``; raw ``PRNGKey`` arrays stack on every jax this repo
    supports, while ``jax.random.key`` typed arrays are the only option
    once ``PRNGKey`` is removed.
    """
    if hasattr(jax.random, "PRNGKey"):
        return jax.random.PRNGKey(int(seed))
    return jax.random.key(int(seed))


def make_mesh(shape, axis_names, *, axis_types=None):
    """``jax.make_mesh`` that tolerates installs without ``AxisType``.

    ``axis_types=None`` means "Auto on every axis" where the concept
    exists, and is simply dropped where it does not.
    """
    if AxisType is None:
        return jax.make_mesh(shape, axis_names)
    if axis_types is None:
        axis_types = (AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(shape, axis_names, axis_types=axis_types)
