"""Version-guarded shims over moving JAX APIs.

The repo targets the modern surface (``jax.shard_map``,
``jax.sharding.AxisType``) but must keep working on older CPU-only
installs such as the test container's jax.  Import these names from here
instead of from jax directly; each shim degrades to the closest older
equivalent.
"""

from __future__ import annotations

import jax

__all__ = ["AxisType", "shard_map", "make_mesh", "pcast", "prng_key",
           "enable_x64"]

try:  # scoped double precision (the lp_jax solver runs inside this)
    from jax.experimental import enable_x64
except ImportError:  # very old jax: emulate with a global-flag swap
    from contextlib import contextmanager

    @contextmanager
    def enable_x64(new_val: bool = True):
        old = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", new_val)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", old)

try:  # jax >= 0.5-ish: explicit axis types on mesh axes
    from jax.sharding import AxisType
except ImportError:  # older jax: every mesh axis is implicitly "auto"
    AxisType = None

try:  # jax >= 0.8 public API
    from jax import shard_map
except ImportError:  # older jax: same callable under experimental
    from jax.experimental.shard_map import shard_map


if hasattr(jax.lax, "pcast"):
    pcast = jax.lax.pcast
else:
    def pcast(x, axes, *, to):  # noqa: ARG001 - mirror the jax signature
        # Older jax has no varying-manual-axes type system; replicated and
        # varying values are indistinguishable, so the cast is a no-op.
        return x


def prng_key(seed: int):
    """Raw uint32 PRNG key where available, new-style typed key otherwise.

    The uniformized CTMC engine stacks per-replication keys for
    ``jax.vmap``; raw ``PRNGKey`` arrays stack on every jax this repo
    supports, while ``jax.random.key`` typed arrays are the only option
    once ``PRNGKey`` is removed.
    """
    if hasattr(jax.random, "PRNGKey"):
        return jax.random.PRNGKey(int(seed))
    return jax.random.key(int(seed))


def make_mesh(shape, axis_names, *, axis_types=None):
    """``jax.make_mesh`` that tolerates installs without ``AxisType``.

    ``axis_types=None`` means "Auto on every axis" where the concept
    exists, and is simply dropped where it does not.
    """
    if AxisType is None:
        return jax.make_mesh(shape, axis_names)
    if axis_types is None:
        axis_types = (AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(shape, axis_names, axis_types=axis_types)
