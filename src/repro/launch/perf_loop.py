import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Perf hillclimb driver: run strategies on a cell, diff roofline terms.

    python -m repro.launch.perf_loop --arch deepseek-v3-671b \
        --shape decode_32k --strategies baseline,kv_int8,kv_heads

Each strategy compiles the cell, the roofline terms are tabulated against
the baseline, and the deltas on the dominant term are printed -- the
measurement half of the hypothesis -> change -> measure -> validate loop
(EXPERIMENTS.md section Perf is the log).
"""

import argparse
from pathlib import Path

from repro.launch.dryrun import ARTIFACTS, STRATEGIES, run_cell
from repro.launch.roofline import roofline_terms


def fmt(x):
    if x >= 1:
        return f"{x:8.3f}s "
    if x >= 1e-3:
        return f"{x*1e3:8.3f}ms"
    return f"{x*1e6:8.1f}us"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--strategies", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)
    base_terms = None
    print(f"perf loop: {args.arch} x {args.shape}")
    hdr = (f"{'strategy':14s} {'compute':10s} {'memory':10s} {'collect':10s}"
           f" {'dominant':10s} {'roofline%':9s} {'d(dom)%':8s}")
    print(hdr)
    for s in args.strategies.split(","):
        assert s in STRATEGIES, (s, sorted(STRATEGIES))
        rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       strategy_name=s)
        if not rec.get("ok"):
            print(f"{s:14s} FAIL {rec.get('error', rec.get('skipped'))}")
            continue
        t = roofline_terms(rec)
        if base_terms is None:
            base_terms = t
            delta = ""
        else:
            dom = base_terms["dominant"] + "_s"
            delta = f"{100 * (t[dom] / base_terms[dom] - 1):+7.1f}%"
        print(f"{s:14s} {fmt(t['compute_s'])} {fmt(t['memory_s'])} "
              f"{fmt(t['collective_s'])} {t['dominant']:10s} "
              f"{100 * t['roofline_fraction']:8.2f}% {delta}")


if __name__ == "__main__":
    main()
