"""Production mesh factories + the sweep's SPMD "cells" mesh.

A *logical server* in the paper's queueing model is one TP group = one
"model"-axis slice of the mesh; the "data" axis enumerates logical servers
for serving and is the FSDP/DP axis for training; the "pod" axis extends
either scheme across pods.  For *simulation* the unit of parallelism is a
grid cell (one (mix, policy, n, seed) replication), so the batch engines
shard over a 1-D mesh whose single axis is named ``"cells"``
(:func:`cells_mesh`); :func:`shard_cells` is the raw shard_map primitive
over that axis (strict -- the grid-level padding/tiling lives in
:mod:`repro.sweep.sharded`).  Defined as functions (never module-level
constants) so importing this module never touches jax device state.
"""

from __future__ import annotations

from typing import Optional

from repro.compat import make_mesh, shard_map

__all__ = ["make_production_mesh", "v5e_constants", "cells_mesh",
           "shard_cells", "shard_cells_fn"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def cells_mesh(n_devices: Optional[int] = None):
    """1-D mesh over the host's devices; its only axis is ``"cells"``.

    Every sharded sweep partitions its flattened grid-cell batch over
    this axis.  ``n_devices`` defaults to ``jax.device_count()`` (all
    visible devices); pass a smaller count to leave devices free.
    """
    import jax

    d = int(n_devices) if n_devices is not None else jax.device_count()
    if d < 1:
        raise ValueError(f"cells_mesh needs >= 1 device, got {d}")
    return make_mesh((d,), ("cells",))


def shard_cells_fn(kernel, *, mesh):
    """Build the jitted "cells"-sharded batch executable for ``kernel``.

    The returned callable ``fn(replicated, batched)`` vmaps
    ``kernel(replicated, item)`` over the leading axis of every leaf of
    the ``batched`` pytree, partitioned over ``mesh``'s ``"cells"``
    axis; ``replicated`` is broadcast to every device.  Build it ONCE
    and call it per equal-shape tile -- jit caches on the callable, so
    a multi-tile batch compiles a single executable.  Strict by design:
    the leading axis must divide evenly by the mesh size (ragged grids
    are padded/tiled one layer up, in :mod:`repro.sweep.sharded`).
    Per-cell independence (no collectives in ``kernel``) is what makes
    the result bitwise identical to a plain single-device ``jax.vmap``
    -- the property the device-count-invariance tests pin down.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    d = mesh.devices.size

    def vmapped(rep, bat):
        return jax.vmap(lambda b: kernel(rep, b))(bat)

    sharded = shard_map(vmapped, mesh=mesh, in_specs=(P(), P("cells")),
                        out_specs=P("cells"), check=False)
    jitted = jax.jit(sharded)

    def fn(replicated, batched):
        leaves = jax.tree_util.tree_leaves(batched)
        if not leaves:
            raise ValueError("shard_cells got an empty batched pytree")
        n = leaves[0].shape[0]
        if n % d != 0:
            raise ValueError(
                f"shard_cells is strict: {n} cells do not divide over "
                f"{d} devices (pad via repro.sweep.sharded)")
        return jitted(replicated, batched)

    return fn


def shard_cells(kernel, replicated, batched, *, mesh):
    """One-shot convenience wrapper over :func:`shard_cells_fn`."""
    return shard_cells_fn(kernel, mesh=mesh)(replicated, batched)


def v5e_constants() -> dict:
    """TPU v5e per-chip hardware constants for the roofline terms."""
    return {
        "peak_flops_bf16": 197e12,  # FLOP/s
        "hbm_bw": 819e9,            # B/s
        "ici_link_bw": 50e9,        # B/s per link (~45-50 GB/s each way)
        "hbm_bytes": 16 * 1024**3,  # 16 GiB
        "ici_links": 4,             # 2D torus: 4 links per chip
    }
