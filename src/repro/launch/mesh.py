"""Production mesh factories.

A *logical server* in the paper's queueing model is one TP group = one
"model"-axis slice of the mesh; the "data" axis enumerates logical servers
for serving and is the FSDP/DP axis for training; the "pod" axis extends
either scheme across pods.  Defined as functions (never module-level
constants) so importing this module never touches jax device state.
"""

from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "v5e_constants"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def v5e_constants() -> dict:
    """TPU v5e per-chip hardware constants for the roofline terms."""
    return {
        "peak_flops_bf16": 197e12,  # FLOP/s
        "hbm_bw": 819e9,            # B/s
        "ici_link_bw": 50e9,        # B/s per link (~45-50 GB/s each way)
        "hbm_bytes": 16 * 1024**3,  # 16 GiB
        "ici_links": 4,             # 2D torus: 4 links per chip
    }
