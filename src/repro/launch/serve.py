"""End-to-end serving driver: gate-and-route over real-compute engines.

Plans with the paper's LP, partitions servers mixed/solo, replays a
synthesized two-class trace through :class:`repro.serving.cluster.RealCluster`
(actual jitted prefill/decode compute + real KV migration), and prints the
revenue/latency summary.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --servers 4 --requests 24
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.planning import solve_bundled_lp
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.models import model as M
from repro.serving.cluster import RealCluster


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch-cap", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="total arrivals/s across classes")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    prim = ServicePrimitives(batch_cap=args.batch_cap, chunk=args.chunk)
    pricing = Pricing()
    classes = [
        WorkloadClass("code", prompt_len=48, decode_len=12,
                      arrival_rate=args.rate / 2 / args.servers, patience=0.1),
        WorkloadClass("conversation", prompt_len=12, decode_len=32,
                      arrival_rate=args.rate / 2 / args.servers, patience=0.1),
    ]
    plan = solve_bundled_lp(classes, prim, pricing)
    print(f"LP plan: x*={np.round(plan.x, 4)} "
          f"mixed={plan.mixed_servers(args.servers)}/{args.servers} "
          f"R*={plan.revenue_rate:.3f}/server/s")

    params = M.init_model(cfg, jax.random.PRNGKey(args.seed))
    cluster = RealCluster(cfg, params, classes, plan, prim, pricing,
                          n_servers=args.servers, max_len=256,
                          seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs, t = [], 0.0
    for _ in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        c = int(rng.integers(len(classes)))
        P = classes[c].prompt_len
        toks = rng.integers(2, cfg.vocab_size, size=P).astype(np.int32)
        reqs.append((t, c, toks, classes[c].decode_len))
    metrics = cluster.run(reqs, horizon=t + 1000.0)
    for k, v in metrics.summary().items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
