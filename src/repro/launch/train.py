"""End-to-end training driver with checkpoint/restart.

Runs real compute on the available devices (reduced configs / the ~100M
preset on CPU; the full configs are exercised via the dry-run).  Supports
resume-from-checkpoint (step, optimizer, data cursor), gradient
accumulation, and optional mesh sharding when multiple devices exist.

Usage:
    PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config
from repro.models.config import AttentionConfig, ModelConfig
from repro.training import (DataConfig, OptConfig, SyntheticLM,
                            init_train_state, make_train_step)

__all__ = ["preset_100m", "run_training"]


def preset_100m() -> ModelConfig:
    """~100M-param dense LM for the end-to-end example."""
    return ModelConfig(
        name="repro-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        d_ff=2048,
        vocab_size=8192,
        attn=AttentionConfig(n_heads=12, n_kv_heads=4, head_dim=64),
        pattern=("attn",),
        max_seq_len=1024,
    )


def run_training(cfg: ModelConfig, *, steps: int, batch: int, seq_len: int,
                 ckpt_dir: str | None, ckpt_every: int = 50,
                 microbatches: int = 1, log_every: int = 10,
                 seed: int = 0, opt: OptConfig | None = None) -> dict:
    opt = opt or OptConfig(lr=3e-4, warmup_steps=20, total_steps=steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch=batch,
                      seq_len=seq_len, seed=seed)
    ds = SyntheticLM(dcfg)
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=microbatches,
                                      remat=True))
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    cursor = 0
    if mgr is not None and mgr.latest_step() is not None:
        state, meta = mgr.restore()
        cursor = meta.get("cursor", 0)
        state = jax.tree.map(jnp.asarray, state)
        print(f"resumed from step {mgr.latest_step()} (cursor={cursor})")
    else:
        state = init_train_state(cfg, jax.random.PRNGKey(seed), opt)

    losses = []
    t0 = time.time()
    start = int(state["opt"]["step"])
    for it in range(start, steps):
        batch_np = ds.batch_at(cursor)
        cursor += 1
        state, metrics = step_fn(
            state, {k: jnp.asarray(v) for k, v in batch_np.items()})
        losses.append(float(metrics["loss"]))
        if it % log_every == 0 or it == steps - 1:
            tok_s = (batch * seq_len * (it - start + 1)) / max(
                time.time() - t0, 1e-9)
            print(f"step {it:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}",
                  flush=True)
        if mgr is not None and (it + 1) % ckpt_every == 0:
            mgr.save(it + 1, state, metadata={"cursor": cursor})
    if mgr is not None:
        mgr.save(steps, state, metadata={"cursor": cursor})
    return {"losses": losses, "final_loss": losses[-1] if losses else None}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--preset", default=None, choices=["100m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    if args.preset == "100m":
        cfg = preset_100m()
    elif args.arch:
        cfg = get_config(args.arch, reduced=args.reduced)
    else:
        raise SystemExit("need --arch or --preset")
    out = run_training(cfg, steps=args.steps, batch=args.batch,
                       seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                       microbatches=args.microbatches)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
