import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces the compiled artifact's

* ``memory_analysis()``  -- per-device bytes (proves what fits),
* ``cost_analysis()``    -- per-device FLOPs / bytes accessed,
* collective traffic parsed from the post-SPMD HLO text,

and corrects lax.scan once-counting with **per-segment extrapolation**:
XLA counts a scanned layer body once, so we additionally compile small
*unrolled* variants (all segments at repeat=1; each segment at repeat=2)
and linearly extrapolate  true = c1 + sum_s (rep_s - 1) * (c_s - c1),
which is exact because every program here is layer-linear.  Memory numbers
come from the full scanned compile (the shipped program).

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
    python -m repro.launch.dryrun ... --strategy <name>   # perf hillclimb
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, input_specs, skip_reason, SHAPES
from repro.launch.hlo_analysis import collective_traffic
from repro.launch.mesh import make_production_mesh, v5e_constants
from repro.models import model as M
from repro.models.config import ModelConfig, segment_layers
from repro.models.params import abstract_params, partition_specs
from repro.serving.steps import make_decode_step, make_prefill_step
from repro.training import OptConfig, make_train_step
from repro.training.optimizer import opt_init
from repro.training.sharding import auto_demote, batch_spec, make_rules

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# --------------------------------------------------------------- strategies
#
# A strategy is a named set of sharding / step-construction choices; the
# perf loop (EXPERIMENTS.md section Perf) iterates over these.

STRATEGIES: dict[str, dict] = {
    # paper-faithful baseline: TP over "model", FSDP over "data" for train,
    # serving caches sharded (batch -> data, seq -> model).
    "baseline": {},
    # decode: shard the KV cache over kv_heads instead of seq
    "kv_heads": {"cache_seq_axis": None, "cache_heads_axis": "model"},
    # decode: int8 KV cache with per-(token, head) scales (~2x less HBM)
    "kv_int8": {"kv_quant": True},
    # train: no remat (more memory, fewer FLOPs) -- ablation point
    "no_remat": {"remat": False},
    # train: 2D sharded batch (batch over data+model) for giant-batch cells
    "batch_2d": {"batch_over_model": True},
    # moe: expert parallelism over the whole pod (1 expert-shard per chip)
    "expert_ep": {"moe_expert_axis": ("data", "model")},
    # moe: align the dispatch buffer's capacity dim with the token axis so
    # the scatter stays local (kills GSPMD's buffer-sized all-reduces)
    "moe_dispatch": {"moe_dispatch_hint": ("model", "data")},
    # combined serving fix for giant MoEs: pod-wide EP + local dispatch
    "ep_dispatch": {"moe_expert_axis": ("data", "model"),
                    "moe_dispatch_hint": (("data", "model"), None)},
    # combined: pod-wide EP + local dispatch + int8 latent/KV cache
    "ep_dispatch_int8": {"moe_expert_axis": ("data", "model"),
                         "moe_dispatch_hint": (("data", "model"), None),
                         "kv_quant": True},
    # small-model training: pure 256-way data parallelism (params
    # replicated).  Constraining only the *inputs* to a 2D batch is not
    # enough -- GSPMD re-shards activations to match FSDP/TP weight
    # layouts -- so this also replicates every weight rule.
    "dp_all": {"replicate_params": True, "batch_over_model": True},
    # moe: tighter capacity factor (1.05): ~16% less dispatch-buffer
    # traffic at the cost of a little token dropping under skew
    "moe_cap105": {"moe_dispatch_hint": ("model", "data"),
                   "moe_capacity": 1.05},
    # few-expert MoE serving fit: shard the expert FF dim over the whole
    # pod (grok-1: 8 experts can't split 256 ways, but d_ff=32768 can)
    "ff_pod": {"moe_expert_ff_axis": ("data", "model")},
}


# ------------------------------------------------------------- shardings


def _dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def build_rules(cfg: ModelConfig, mesh, kind: str, strategy: dict) -> dict:
    if strategy.get("replicate_params"):
        from repro.models.params import DEFAULT_RULES
        return {k: None for k in DEFAULT_RULES}
    fsdp = kind == "train"
    fsdp_axis = ("pod", "data") if ("pod" in mesh.axis_names and fsdp) else "data"
    overrides = {}
    if strategy.get("moe_expert_axis"):
        overrides["expert"] = strategy["moe_expert_axis"]
    if strategy.get("moe_expert_ff_axis"):
        overrides["expert"] = None
        overrides["expert_ff"] = strategy["moe_expert_ff_axis"]
    rules = make_rules(mesh, fsdp=fsdp, fsdp_axis=fsdp_axis,
                       overrides=overrides)
    defs = M.model_defs(cfg)
    rules = auto_demote(defs, rules, mesh)
    if (cfg.moe is not None and rules.get("expert") is None
            and rules.get("expert_ff") is None):
        # few-expert MoE (e.g. grok's 8 experts < 16-way axis): fall back to
        # tensor-parallel experts -- shard the expert FF dim instead of the
        # expert dim, so expert weights never replicate across the pod.
        trial = dict(rules)
        trial["expert_ff"] = "model"
        trial2 = auto_demote(defs, trial, mesh)
        if trial2.get("expert_ff") == "model":
            rules = trial2
    return rules


def cache_pspecs(cfg: ModelConfig, caches_abs, mesh, strategy: dict,
                 batch_axis="data"):
    """PartitionSpecs for the (segment-stacked) cache tree.

    Leaves are (layer_rep, B, ...): batch -> "data"; the sequence dim of
    attention/MLA caches -> "model" (baseline) so long KV shards; SSM/LRU
    states replicate over "model" unless head-divisible.
    """
    seq_ax = strategy.get("cache_seq_axis", "model")
    heads_ax = strategy.get("cache_heads_axis", None)
    msize = mesh.shape["model"]
    bsize = (int(np.prod([mesh.shape[a] for a in batch_axis]))
             if isinstance(batch_axis, tuple)
             else (mesh.shape[batch_axis] if batch_axis else 1))

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        ax = [None] * nd
        ax[1] = batch_axis if (batch_axis and
                               leaf.shape[1] % bsize == 0) else None
        if name in ("k", "v", "xk", "xv"):
            # (rep, B, S, KV, D)
            if heads_ax and leaf.shape[3] % msize == 0:
                ax[3] = heads_ax
            elif seq_ax and leaf.shape[2] % msize == 0:
                ax[2] = seq_ax
        elif name in ("c_kv", "k_rope", "k_s", "v_s"):
            if seq_ax and leaf.shape[2] % msize == 0:
                ax[2] = seq_ax
        elif name in ("pos", "c_s", "r_s"):
            if seq_ax and leaf.shape[2] % msize == 0:
                ax[2] = seq_ax
        elif name == "ssm":  # (rep, B, H, P, N)
            if leaf.shape[2] % msize == 0:
                ax[2] = "model"
        elif name == "h":  # (rep, B, W)
            if leaf.shape[2] % msize == 0:
                ax[2] = "model"
        # conv caches replicate over model
        return P(*ax)

    return jax.tree_util.tree_map_with_path(spec_for, caches_abs)


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------- cell builds


def _abstract_opt(params_abs, ocfg):
    return jax.eval_shape(lambda p: opt_init(p, ocfg), params_abs)


def _abstract_cache(cfg, batch, max_len, dtype):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, batch, max_len, dtype))


def lower_cell(cfg: ModelConfig, shape_name: str, mesh, *, unroll: bool,
               strategy: dict, donate: bool = True):
    """Lower one (cfg, shape) on mesh; returns jax ``Lowered``."""
    kind = SHAPES[shape_name].kind
    sspec = SHAPES[shape_name]
    if strategy.get("kv_quant"):
        cfg = cfg.replace(kv_quant=True)
    if strategy.get("moe_dispatch_hint") and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, dispatch_hint=strategy["moe_dispatch_hint"]))
    if strategy.get("moe_capacity") and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=strategy["moe_capacity"]))
    dtype = _dtype_of(cfg)
    defs = M.model_defs(cfg)
    rules = build_rules(cfg, mesh, kind, strategy)
    pspecs = partition_specs(defs, rules)
    params_abs = abstract_params(defs, dtype)
    params_sh = _ns(mesh, pspecs)
    bspec = batch_spec(mesh) if not strategy.get("batch_over_model") else P(
        tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names))
    gb = SHAPES[shape_name].global_batch
    baxes = bspec[0] if bspec else ()
    bsize = int(np.prod([mesh.shape[a] for a in (
        baxes if isinstance(baxes, tuple) else (baxes,))])) if baxes else 1
    if gb % bsize != 0:
        bspec = P()  # e.g. long_500k's global_batch=1: replicate the batch
    data_sh = NamedSharding(mesh, bspec)
    repl = NamedSharding(mesh, P())

    specs = input_specs(cfg, shape_name)

    if kind == "train":
        big = M.param_count(cfg) > 100e9
        ocfg = OptConfig(state_dtype="bfloat16" if big else "float32")
        opt_abs = _abstract_opt(params_abs, ocfg)
        opt_sh = {"m": params_sh, "v": params_sh, "step": repl}
        state_abs = {"params": params_abs, "opt": opt_abs}
        state_sh = {"params": params_sh, "opt": opt_sh}
        step = make_train_step(cfg, ocfg, microbatches=1,
                               remat=strategy.get("remat", True),
                               unroll=unroll)
        batch_abs = dict(specs)
        batch_sh = {k: data_sh for k in batch_abs}
        fn = jax.jit(step,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, repl),
                     donate_argnums=(0,) if donate else ())
        with jax.set_mesh(mesh):
            return fn.lower(state_abs, batch_abs)

    batch_axis = bspec[0] if len(bspec) else None

    if kind == "prefill":
        pstep = make_prefill_step(cfg, unroll=unroll)
        gb, S = specs["tokens"].shape
        extra = cfg.vision.n_patches if cfg.vision is not None else 0
        caches_abs = _abstract_cache(cfg, gb, S + extra, dtype)
        cache_sh = _ns(mesh, cache_pspecs(cfg, caches_abs, mesh, strategy,
                                          batch_axis))
        stub_keys = [k for k in specs if k in ("enc_frames", "prefix_embeds")]

        def fn(params, caches, tokens, positions, *stubs):
            kw = dict(zip(stub_keys, stubs))
            return pstep(params, caches, tokens, positions, **kw)

        jfn = jax.jit(
            fn,
            in_shardings=(params_sh, cache_sh, data_sh, data_sh,
                          *([data_sh] * len(stub_keys))),
            out_shardings=(cache_sh, data_sh),
            donate_argnums=(1,) if donate else ())
        with jax.set_mesh(mesh):
            return jfn.lower(params_abs, caches_abs, specs["tokens"],
                             specs["positions"],
                             *[specs[k] for k in stub_keys])

    # decode
    dstep = make_decode_step(cfg, unroll=unroll, masked=False)
    gb = specs["tokens"].shape[0]
    S = sspec.seq_len
    caches_abs = _abstract_cache(cfg, gb, S, dtype)
    cache_sh = _ns(mesh, cache_pspecs(cfg, caches_abs, mesh, strategy,
                                      batch_axis))
    vec_sh = data_sh
    state_abs = {
        "caches": caches_abs,
        "length": jax.ShapeDtypeStruct((gb,), jnp.int32),
        "last_token": jax.ShapeDtypeStruct((gb,), jnp.int32),
        "active": jax.ShapeDtypeStruct((gb,), jnp.bool_),
    }
    state_sh = {"caches": cache_sh, "length": vec_sh, "last_token": vec_sh,
                "active": vec_sh}
    jfn = jax.jit(
        dstep,
        in_shardings=(params_sh, state_sh),
        out_shardings=(state_sh, vec_sh),
        donate_argnums=(1,) if donate else ())
    with jax.set_mesh(mesh):
        return jfn.lower(params_abs, state_abs)


# ----------------------------------------------------------- extrapolation


def make_variant(cfg: ModelConfig, seg_reps, enc_layers=None) -> ModelConfig:
    segs = segment_layers(cfg.block_specs())
    blocks: list = []
    for (block, _rep), r in zip(segs, seg_reps):
        blocks += list(block) * r
    out = cfg.replace(blocks_override=tuple(blocks), n_layers=len(blocks))
    if cfg.encoder is not None and enc_layers is not None:
        out = out.replace(
            encoder=dataclasses.replace(cfg.encoder, n_layers=enc_layers))
    return out


def _analyze(lowered) -> dict:
    comp = lowered.compile()
    ca = comp.cost_analysis() or {}
    coll = collective_traffic(comp.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
        "_compiled": comp,
    }


def analyze_cell(cfg: ModelConfig, shape_name: str, mesh, *,
                 strategy: dict, variants: bool = True) -> dict:
    """Full analysis: scanned compile (memory) + extrapolated costs."""
    t0 = time.time()
    full_low = lower_cell(cfg, shape_name, mesh, unroll=False,
                          strategy=strategy)
    full = _analyze(full_low)
    mem = full["_compiled"].memory_analysis()
    out = {
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
        "scanned": {k: full[k] for k in ("flops", "bytes")},
        "scanned_coll": full["coll"],
    }

    segs = segment_layers(cfg.block_specs())
    reps = [r for _, r in segs]
    enc_L = cfg.encoder.n_layers if cfg.encoder is not None else None
    if not variants or (all(r == 1 for r in reps) and (enc_L or 1) == 1):
        out["extrapolated"] = {
            "flops": full["flops"], "bytes": full["bytes"],
            "coll_total": full["coll"]["total"],
            "coll": {k: v for k, v in full["coll"].items()
                     if k != "counts"},
        }
        out["compile_seconds"] = time.time() - t0
        return out

    def cost_of(seg_reps, enc):
        v = make_variant(cfg, seg_reps, enc)
        low = lower_cell(v, shape_name, mesh, unroll=True, strategy=strategy,
                         donate=False)
        return _analyze(low)

    ones = [1] * len(segs)
    c1 = cost_of(ones, 1 if enc_L else None)
    terms = []  # (multiplier, cost_dict)
    for si in range(len(segs)):
        if reps[si] == 1:
            continue
        r2 = list(ones)
        r2[si] = 2
        c2 = cost_of(r2, 1 if enc_L else None)
        terms.append((reps[si] - 1, c1, c2))
    if enc_L and enc_L > 1:
        c2 = cost_of(ones, 2)
        terms.append((enc_L - 1, c1, c2))

    def extra(key, sub=None):
        base = (c1[key][sub] if sub else c1[key])
        tot = base
        for mult, a, b in terms:
            av = (a[key][sub] if sub else a[key])
            bv = (b[key][sub] if sub else b[key])
            tot += mult * (bv - av)
        return tot

    out["extrapolated"] = {
        "flops": extra("flops"),
        "bytes": extra("bytes"),
        "coll_total": extra("coll", "total"),
        "coll": {k: extra("coll", k) for k in
                 ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")},
    }
    out["compile_seconds"] = time.time() - t0
    return out


def model_flops_reference(cfg: ModelConfig, shape_name: str) -> dict:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N = active params."""
    s = SHAPES[shape_name]
    n_active = M.active_param_count(cfg)
    n_total = M.param_count(cfg)
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        mf = 6.0 * n_active * tokens
    elif s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        mf = 2.0 * n_active * tokens
    else:
        tokens = s.global_batch  # one token per request
        mf = 2.0 * n_active * tokens
    return {"model_flops": mf, "active_params": n_active,
            "total_params": n_total, "tokens": tokens}


# ------------------------------------------------------------------ driver


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             strategy_name: str = "baseline", variants: bool = True,
             out_dir: Path = ARTIFACTS) -> dict:
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_name)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "strategy": strategy_name, "n_devices": 512 if multi_pod else 256,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}__{mesh_tag}__{strategy_name}.json"
    if reason is not None:
        rec["skipped"] = reason
        path.write_text(json.dumps(rec, indent=1))
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = STRATEGIES[strategy_name]
    try:
        res = analyze_cell(cfg, shape_name, mesh, strategy=strategy,
                           variants=variants)
        rec.update(res)
        rec.update(model_flops_reference(cfg, shape_name))
        rec["ok"] = True
    except Exception as e:  # a failure here is a bug in the system
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="baseline",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--no-variants", action="store_true",
                    help="skip the unrolled extrapolation compiles")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args(argv)
    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    for a in archs:
        for s in shapes:
            t0 = time.time()
            rec = run_cell(a, s, multi_pod=args.multi_pod,
                           strategy_name=args.strategy,
                           variants=not args.no_variants,
                           out_dir=Path(args.out))
            status = ("SKIP " + rec.get("skipped", "")) if "skipped" in rec \
                else ("OK" if rec.get("ok") else "FAIL " + rec.get("error", ""))
            print(f"[{time.time()-t0:7.1f}s] {a} x {s} x "
                  f"{'multi' if args.multi_pod else 'single'}: {status}",
                  flush=True)


if __name__ == "__main__":
    main()
