"""Parse collective traffic out of post-SPMD HLO text.

``cost_analysis()`` has no collective figures, so we regex the compiled
module for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, take each op's largest shape token as the payload,
and convert to per-device interconnect traffic with the standard ring-
algorithm factors:

    all-reduce       2 (k-1)/k * N
    all-gather       (k-1)/k * N      (N = gathered result)
    reduce-scatter   (k-1)/k * N      (N = scattered operand)
    all-to-all       (k-1)/k * N
    collective-permute           N

Shapes inside ``while`` (lax.scan) bodies appear once in the text; the
dry-run's per-segment extrapolation corrects for trip counts the same way
it corrects FLOPs.
"""

from __future__ import annotations

import re

__all__ = ["collective_traffic", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "u64": 8, "s64": 8, "c64": 8,
    "f32": 4, "u32": 4, "s32": 4,
    "bf16": 2, "f16": 2, "u16": 2, "s16": 2,
    "u8": 1, "s8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "u4": 0.5, "s4": 0.5,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
# matches only a *flat* (possibly empty) pair list -- "{}" or "{0,1}";
# nested "{{0,1},...}" deliberately fails to match (real traffic).
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{([^{}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_traffic(hlo_text: str) -> dict:
    """Per-device collective traffic (bytes) by op kind + total + op count."""
    out = {k: 0.0 for k in _OPS}
    counts = {k: 0 for k in _OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for op in _OPS:
            # match the op as instruction (e.g. " = bf16[...] all-gather(")
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                kind = op
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(stripped.split("metadata=")[0])
        if not shapes:
            continue
        payload = max(_shape_bytes(dt, dims) for dt, dims in shapes)
        k = _group_size(stripped)
        if kind == "all-reduce":
            traffic = 2.0 * (k - 1) / k * payload if k > 1 else 0.0
        elif kind == "collective-permute":
            pairs = _PERMUTE_PAIRS_RE.search(stripped)
            empty = pairs is not None and not pairs.group(1).strip()
            traffic = 0.0 if empty else payload
        else:
            traffic = (k - 1) / k * payload if k > 1 else 0.0
        out[kind] += traffic
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _OPS)
    out["counts"] = counts
    return out
