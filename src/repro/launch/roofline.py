"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh x strategy) cell, from the compiled artifact:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / link_bw       [s]

(cost_analysis on the SPMD-partitioned module reports *per-device* figures,
verified by probe; collective bytes come from the HLO parse with ring
factors.)  The dominant term is the bottleneck; the reported

    roofline_fraction = T_ideal / T_bound,
    T_ideal = max(MODEL_FLOPS/chips/peak, argument_bytes/HBM_bw)
    T_bound = max(compute, memory, collective terms)

is the score the perf loop climbs: T_ideal is the physics floor (useful
FLOPs at peak, or the resident state streamed exactly once -- whichever
binds), T_bound is what the compiled program would take at roofline speeds.

Usage: python -m repro.launch.roofline [--dir artifacts/dryrun] [--md out.md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.mesh import v5e_constants

__all__ = ["roofline_terms", "load_records", "render_table"]


def roofline_terms(rec: dict, hw: dict | None = None) -> dict:
    hw = hw or v5e_constants()
    ex = rec["extrapolated"]
    chips = rec["n_devices"]
    t_c = ex["flops"] / hw["peak_flops_bf16"]
    t_m = ex["bytes"] / hw["hbm_bw"]
    t_x = ex["coll_total"] / hw["ici_link_bw"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    t_ideal_c = rec["model_flops"] / chips / hw["peak_flops_bf16"]
    t_ideal_m = rec["memory"]["argument_bytes"] / hw["hbm_bw"]
    t_ideal = max(t_ideal_c, t_ideal_m)
    t_bound = max(t_c, t_m, t_x)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops": rec["model_flops"],
        "hlo_flops_per_dev": ex["flops"],
        "useful_flop_ratio": (rec["model_flops"] / chips / ex["flops"]
                              if ex["flops"] else float("nan")),
        "t_ideal_s": t_ideal,
        "t_bound_s": t_bound,
        "roofline_fraction": t_ideal / t_bound if t_bound else float("nan"),
        "arg_gib_per_dev": rec["memory"]["argument_bytes"] / 2**30,
    }


def load_records(d: Path, mesh: str = "pod16x16",
                 strategy: str | None = None) -> list[dict]:
    out = []
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") != mesh:
            continue
        if strategy is not None and rec.get("strategy") != strategy:
            continue
        out.append(rec)
    return out


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def render_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | strat | compute | memory | collective | "
           "dominant | useful-FLOP | arg GiB/dev | roofline frac | note |")
    sep = "|" + "---|" * 11
    rows = [hdr, sep]
    for r in recs:
        if "skipped" in r:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['strategy']} | -- | -- |"
                f" -- | -- | -- | -- | -- | SKIP: {r['skipped']} |")
            continue
        if not r.get("ok"):
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['strategy']} | -- | -- |"
                f" -- | -- | -- | -- | -- | FAIL |")
            continue
        t = roofline_terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} |"
            f" {_fmt_s(t['compute_s'])} | {_fmt_s(t['memory_s'])} |"
            f" {_fmt_s(t['collective_s'])} | {t['dominant']} |"
            f" {t['useful_flop_ratio']:.3f} | {t['arg_gib_per_dev']:.2f} |"
            f" {t['roofline_fraction']:.3f} | |")
    return "\n".join(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(
        Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"))
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args(argv)
    recs = load_records(Path(args.dir), args.mesh, args.strategy)
    table = render_table(recs)
    print(table)
    if args.md:
        Path(args.md).write_text(table + "\n")


if __name__ == "__main__":
    main()
