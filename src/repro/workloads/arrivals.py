"""Arrival processes for the workload-scenario subsystem.

Every process produces a sorted array of arrival times on ``[0, horizon)``
given a :class:`numpy.random.Generator`.  Four families cover the paper's
nonstationary regimes (Section 6.2) plus the classic teletraffic shapes:

* :class:`PoissonArrivals` -- homogeneous rate ``lam``.
* :class:`MMPPArrivals` -- a k-regime Markov-modulated Poisson process
  generalizing the two-state burst model of
  :class:`repro.data.traces.TraceConfig`: regimes cycle ``0 -> 1 -> ...
  -> k-1 -> 0`` with exponential holding times ``1/switch[j]`` and rate
  ``base_rate * levels[j]`` inside regime j (for k = 2 this is exactly
  the existing toggle).
* :class:`PiecewiseConstantArrivals` -- deterministic rate schedule
  ``rates[j]`` on ``[times[j], times[j+1})``; the building block for
  rate-shift steps (:func:`rate_shift`), flash-crowd spikes
  (:func:`flash_crowd`) and binned diurnal curves (:func:`diurnal`).

Sampling is exact (no thinning): homogeneous segments exploit the
memoryless property at every breakpoint, and the MMPP simulates its
regime path explicitly.

**Compression semantics.**  ``scaled(f)`` multiplies the arrival
intensity by ``f``.  For the MMPP it also multiplies the regime-switch
rates, which reproduces the trace generator's interarrival-compression
device exactly (compressing the time axis by ``c`` is the same law as
multiplying *all* process rates by ``1/c``).  Piecewise-constant
schedules keep their authored breakpoints -- a rate shift scripted at
``t = 150 s`` stays at 150 s no matter how hard the load is scaled.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

__all__ = [
    "ArrivalProcess",
    "PoissonArrivals",
    "MMPPArrivals",
    "PiecewiseConstantArrivals",
    "rate_shift",
    "flash_crowd",
    "diurnal",
]


class ArrivalProcess:
    """Protocol-ish base: sample, instantaneous/mean intensity, scaling."""

    def sample(self, rng: np.random.Generator, horizon: float) -> np.ndarray:
        raise NotImplementedError

    def rate_at(self, t: float) -> float:
        """Deterministic intensity at ``t`` (MMPP: its stationary mean)."""
        raise NotImplementedError

    def mean_rate(self, horizon: float) -> float:
        """Time-averaged intensity over ``[0, horizon)``."""
        raise NotImplementedError

    def rate_bound(self) -> float:
        """A finite upper bound on the instantaneous intensity."""
        raise NotImplementedError

    def scaled(self, factor: float) -> "ArrivalProcess":
        """Process with intensity multiplied by ``factor`` (see module doc)."""
        raise NotImplementedError


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    rate: float  # requests/second (cluster level)

    def __post_init__(self) -> None:
        if not self.rate > 0:
            raise ValueError("PoissonArrivals needs rate > 0")

    def sample(self, rng, horizon):
        # draw in chunks: E[count] + slack, extend on the rare shortfall
        out = []
        t = 0.0
        chunk = max(16, int(self.rate * horizon * 1.2) + 16)
        while t < horizon:
            gaps = rng.exponential(1.0 / self.rate, size=chunk)
            ts = t + np.cumsum(gaps)
            out.append(ts[ts < horizon])
            t = float(ts[-1])
        return np.concatenate(out) if out else np.empty(0)

    def rate_at(self, t):
        return self.rate

    def mean_rate(self, horizon):
        return self.rate

    def rate_bound(self):
        return self.rate

    def scaled(self, factor):
        return PoissonArrivals(self.rate * factor)


@dataclass(frozen=True)
class MMPPArrivals(ArrivalProcess):
    """k-regime cyclic MMPP (see module docstring)."""

    base_rate: float
    levels: tuple = (0.55, 1.9)  # per-regime rate multipliers
    switch: tuple = (1 / 45.0, 1 / 25.0)  # rate of *leaving* each regime

    def __post_init__(self) -> None:
        if not self.base_rate > 0:
            raise ValueError("MMPPArrivals needs base_rate > 0")
        if len(self.levels) != len(self.switch) or len(self.levels) < 2:
            raise ValueError("levels/switch must align, with >= 2 regimes")
        if any(not lv >= 0 for lv in self.levels):
            raise ValueError("regime levels must be nonnegative")
        if any(not sw > 0 for sw in self.switch):
            raise ValueError("switch rates must be positive")

    @property
    def n_regimes(self) -> int:
        return len(self.levels)

    def sample(self, rng, horizon):
        out = []
        t, j = 0.0, 0
        t_switch = rng.exponential(1.0 / self.switch[j])
        while t < horizon:
            rate = self.base_rate * self.levels[j]
            if rate <= 0:  # silent regime: jump straight to the switch
                t = t_switch
                j = (j + 1) % self.n_regimes
                t_switch = t + rng.exponential(1.0 / self.switch[j])
                continue
            dt = rng.exponential(1.0 / rate)
            if t + dt > t_switch:
                t = t_switch
                j = (j + 1) % self.n_regimes
                t_switch = t + rng.exponential(1.0 / self.switch[j])
                continue
            t += dt
            if t < horizon:
                out.append(t)
        return np.asarray(out)

    def _stationary(self) -> np.ndarray:
        # cycle chain: time share of regime j is proportional to its
        # mean holding time 1/switch[j]
        hold = 1.0 / np.asarray(self.switch, dtype=float)
        return hold / hold.sum()

    def rate_at(self, t):
        return self.mean_rate(0.0)

    def mean_rate(self, horizon):
        pi = self._stationary()
        return float(self.base_rate * (pi * np.asarray(self.levels)).sum())

    def rate_bound(self):
        return float(self.base_rate * max(self.levels))

    def scaled(self, factor):
        # scale switching too: identical in law to compressing the time
        # axis, which is how TraceConfig.compression behaves
        return dataclasses.replace(
            self, base_rate=self.base_rate * factor,
            switch=tuple(s * factor for s in self.switch))


@dataclass(frozen=True)
class PiecewiseConstantArrivals(ArrivalProcess):
    """Rate ``rates[j]`` on ``[times[j], times[j+1])``; ``times[0] == 0``
    and the last rate extends to the sampling horizon."""

    times: tuple
    rates: tuple

    def __post_init__(self) -> None:
        if len(self.times) != len(self.rates) or not self.times:
            raise ValueError("times/rates must be nonempty and align")
        if self.times[0] != 0.0:
            raise ValueError("times must start at 0.0")
        if any(b <= a for a, b in zip(self.times, self.times[1:])):
            raise ValueError("times must be strictly increasing")
        if any(not r >= 0 for r in self.rates):
            raise ValueError("rates must be nonnegative")
        if not any(r > 0 for r in self.rates):
            raise ValueError("at least one segment must have positive rate")

    def sample(self, rng, horizon):
        out = []
        t, j = 0.0, 0
        n_seg = len(self.times)
        while t < horizon:
            t_next = self.times[j + 1] if j + 1 < n_seg else horizon
            t_next = min(t_next, horizon)
            r = self.rates[j]
            if r <= 0:
                t = t_next
                j = min(j + 1, n_seg - 1)
                if t >= horizon:
                    break
                continue
            dt = rng.exponential(1.0 / r)
            if t + dt >= t_next:
                # memoryless: restart at the boundary under the new rate
                t = t_next
                if j + 1 < n_seg:
                    j += 1
                    continue
                break
            t += dt
            out.append(t)
        return np.asarray(out)

    def _segment(self, t: float) -> int:
        return int(np.searchsorted(np.asarray(self.times), t, side="right")
                   - 1)

    def rate_at(self, t):
        return float(self.rates[self._segment(max(t, 0.0))])

    def mean_rate(self, horizon):
        if horizon <= 0:
            return float(self.rates[0])
        edges = [min(t, horizon) for t in self.times] + [horizon]
        total = 0.0
        for j, r in enumerate(self.rates):
            total += r * max(0.0, edges[j + 1] - edges[j])
        return total / horizon

    def rate_bound(self):
        return float(max(self.rates))

    def scaled(self, factor):
        return dataclasses.replace(
            self, rates=tuple(r * factor for r in self.rates))


def rate_shift(rate0: float, rate1: float,
               t_shift: float) -> PiecewiseConstantArrivals:
    """Single step change ``rate0 -> rate1`` at ``t_shift``."""
    return PiecewiseConstantArrivals(times=(0.0, float(t_shift)),
                                     rates=(float(rate0), float(rate1)))


def flash_crowd(base_rate: float, spike_mult: float, t_on: float,
                t_off: float) -> PiecewiseConstantArrivals:
    """Flash crowd: ``base_rate`` except ``base_rate * spike_mult`` on
    ``[t_on, t_off)``."""
    if not 0.0 < t_on < t_off:
        raise ValueError("need 0 < t_on < t_off")
    return PiecewiseConstantArrivals(
        times=(0.0, float(t_on), float(t_off)),
        rates=(float(base_rate), float(base_rate * spike_mult),
               float(base_rate)))


def diurnal(base_rate: float, amplitude: float, period: float,
            horizon: float, n_bins: int = 24) -> PiecewiseConstantArrivals:
    """Piecewise-constant diurnal curve: a sinusoid
    ``base_rate * (1 + amplitude * sin(2 pi t / period))`` binned into
    ``n_bins`` steps per period across ``[0, horizon)``."""
    if not 0 <= amplitude < 1:
        raise ValueError("amplitude must be in [0, 1)")
    if period <= 0 or horizon <= 0 or n_bins < 2:
        raise ValueError("need period > 0, horizon > 0, n_bins >= 2")
    dt = period / n_bins
    n_total = int(np.ceil(horizon / dt))
    times = tuple(k * dt for k in range(n_total))
    mids = np.asarray(times) + dt / 2
    rates = tuple(float(base_rate * (1 + amplitude *
                                     np.sin(2 * np.pi * m / period)))
                  for m in mids)
    return PiecewiseConstantArrivals(times=times, rates=rates)
