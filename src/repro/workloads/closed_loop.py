"""Closed-loop adaptive control on workload scenarios.

This is the harness the ISSUE's control story was missing: it wires
:class:`repro.core.online.OnlineController` into the per-server
:class:`repro.serving.engine_sim.ClusterEngine` replay of any registered
scenario -- the engine feeds every arrival to the controller, the
controller re-estimates class rates on a rolling window (Eq. 50),
re-solves the planning LP at control epochs, and publishes the new
occupancy/queue targets and mixed-server count M* (Eq. 51) back into the
running gate-and-route policy; scenario capacity events additionally
drive ``OnlineController.set_capacity`` replans through the engine's
failure hooks.

Variants (same trace, same engine seed -- paired comparisons):

* ``adaptive``    -- gate-and-route, cold-start plan, online replanning.
* ``static``      -- gate-and-route on the *hindsight* static plan
                     (full-trace empirical means; the strongest static
                     baseline).
* ``static_cold`` -- gate-and-route frozen on the cold-start plan (what
                     a no-controller deployment actually runs after a
                     regime shift).
* ``vllm`` / ``sarathi`` -- the class-agnostic system heuristics.

The cold-start plan is solved from the first ``cold_window`` seconds of
the trace, i.e. exactly the information a deployment has at launch; on
nonstationary scenarios (``rate_shift``, ``flash_crowd``, ``diurnal``)
the adaptive variant's win over the frozen plans is the paper's
Section 6.2 message.  ``benchmarks/bench_scenarios.py`` tables these
comparisons over the whole registry.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.planning import solve_bundled_lp
from repro.core.policies import (baseline_sarathi, baseline_vllm,
                                 gate_and_route)
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import trace_class_means, trace_class_means_windowed
from repro.serving.engine_sim import ClusterEngine, EngineConfig

from .scenarios import Scenario, get_scenario

__all__ = ["ClosedLoopConfig", "VARIANTS", "run_closed_loop",
           "compare_policies", "plans_for_scenarios"]

VARIANTS = ("adaptive", "static", "static_cold", "vllm", "sarathi")


@dataclass(frozen=True)
class ClosedLoopConfig:
    """Knobs of one closed-loop scenario replay."""

    n_servers: int = 8
    horizon: Optional[float] = None  # None = the scenario's own horizon
    compression: float = 1.0
    rate_scale: float = 1.0
    seed: int = 0
    # controller (Section 6.2)
    replan_every: float = 10.0
    window: float = 30.0
    safety: float = 1.5
    planner_theta: float = 3e-4
    # planning inputs
    cold_window: float = 30.0  # launch-time knowledge for cold-start plans
    drain: bool = False

    def controller_config(self) -> OnlineControllerConfig:
        return OnlineControllerConfig(
            window=self.window, safety=self.safety,
            replan_every=self.replan_every,
            planning_theta=self.planner_theta)


def _classes_from_means(means, n: int, theta: float,
                        names: Sequence[str]) -> list:
    return [
        WorkloadClass(names[i] if i < len(names) else f"class{i}",
                      prompt_len=max(means[i][0], 1.0),
                      decode_len=max(means[i][1], 1.0),
                      arrival_rate=max(means[i][2] / n, 1e-6),
                      patience=theta)
        for i in range(len(means))
    ]


def _plan_classes(scn: Scenario, trace, cfg: ClosedLoopConfig):
    """(cold-start classes, hindsight classes) for one scenario replay."""
    I, names = scn.n_classes, scn.class_names
    n = cfg.n_servers
    windows = trace_class_means_windowed(trace, I, cfg.cold_window)
    cold_cls = _classes_from_means(windows[0][2], n, cfg.planner_theta, names)
    full_cls = _classes_from_means(trace_class_means(trace, I), n,
                                   cfg.planner_theta, names)
    return cold_cls, full_cls


def _plans(scn: Scenario, trace, cfg: ClosedLoopConfig, prim, pricing):
    """(cold classes, cold plan, hindsight classes, hindsight plan)."""
    cold_cls, full_cls = _plan_classes(scn, trace, cfg)
    return (cold_cls, solve_bundled_lp(cold_cls, prim, pricing),
            full_cls, solve_bundled_lp(full_cls, prim, pricing))


def plans_for_scenarios(scenarios: Sequence, traces: Sequence,
                        cfgs: Sequence[ClosedLoopConfig],
                        prim: Optional[ServicePrimitives] = None,
                        pricing: Optional[Pricing] = None) -> list:
    """Cold-start + hindsight plans for MANY scenario replays in ONE
    batched interior-point solve (:func:`repro.core.planning_batch.
    solve_plan_batch`; class counts may differ across scenarios -- the
    batch pads internally).

    Returns one :func:`_plans`-shaped tuple per scenario, ready to pass
    to :func:`run_closed_loop` / :func:`compare_policies` via ``plans=``.
    ``bench_scenarios`` uses this to stop the registry-wide closed-loop
    table from serialising 2 x n_scenarios simplex solves.
    """
    prim = prim or ServicePrimitives()
    pricing = pricing or Pricing()
    scenarios = [get_scenario(s) if isinstance(s, str) else s
                 for s in scenarios]
    if not (len(scenarios) == len(traces) == len(cfgs)):
        raise ValueError("scenarios/traces/cfgs must align")
    pairs = [_plan_classes(scn, trace, cfg)
             for scn, trace, cfg in zip(scenarios, traces, cfgs)]
    from repro.core.planning_batch import solve_plan_batch

    pb = solve_plan_batch(
        [cls for pair in pairs for cls in pair], prim,
        pricing).require_converged("plans_for_scenarios")
    return [
        (cold, pb.solution(2 * k), full, pb.solution(2 * k + 1))
        for k, (cold, full) in enumerate(pairs)
    ]


def run_closed_loop(scenario, variant: str = "adaptive",
                    cfg: ClosedLoopConfig = ClosedLoopConfig(),
                    prim: Optional[ServicePrimitives] = None,
                    pricing: Optional[Pricing] = None,
                    trace=None, plans=None, telemetry=None,
                    trace_path=None, manifest_path=None) -> dict:
    """Replay one scenario under one variant; returns a flat metric dict.

    ``scenario`` is a :class:`Scenario` or a registered name.  Pass a
    pre-generated ``trace`` to share it across variants (what
    :func:`compare_policies` does -- common random numbers); ``plans``
    (a :func:`_plans` tuple for that trace) additionally skips the
    per-variant LP re-solves, which depend only on trace + cfg.

    Observability riders (all default off; the metric dict is identical
    when they stay off):

    * ``telemetry`` -- a :class:`repro.telemetry.ProbeSpec` / ``True`` /
      dict of overrides: threads time-binned probes through the engine
      and adds ``tlm_events`` / ``tlm_drops`` / ``tlm_ttft_p95`` to the
      returned metrics.
    * ``trace_path`` -- write a Chrome-trace JSON of request lifecycles
      plus replan/capacity instant events there (implies ``telemetry``).
    * ``manifest_path`` -- append one ``closed_loop`` RunRecord to this
      JSONL manifest (digesting the trace file when also written).
    """
    t_wall = time.time()
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; one of {VARIANTS}")
    if trace_path is not None and telemetry is None:
        telemetry = True  # lifecycle records need probes on
    prim = prim or ServicePrimitives()
    pricing = pricing or Pricing()
    n = cfg.n_servers
    if trace is None:
        trace = scenario.generate(seed=cfg.seed, horizon=cfg.horizon,
                                  compression=cfg.compression,
                                  rate_scale=cfg.rate_scale)
    horizon = float(cfg.horizon if cfg.horizon is not None
                    else scenario.horizon)
    cold_cls, cold_plan, full_cls, full_plan = (
        plans if plans is not None
        else _plans(scenario, trace, cfg, prim, pricing))

    controller = None
    if variant == "adaptive":
        classes, policy = cold_cls, gate_and_route(cold_plan)
        controller = OnlineController(cold_cls, prim, pricing, n=n,
                                      config=cfg.controller_config())
    elif variant == "static":
        classes, policy = full_cls, gate_and_route(full_plan)
    elif variant == "static_cold":
        classes, policy = cold_cls, gate_and_route(cold_plan)
    elif variant == "vllm":
        classes, policy = full_cls, baseline_vllm(full_plan)
    else:  # sarathi
        classes, policy = full_cls, baseline_sarathi(full_plan)

    replan_log: list = []
    if controller is not None and (trace_path is not None
                                   or manifest_path is not None):
        # the controller records a count but not epochs; intercept
        # replan(t) to keep the timeline for the trace export
        inner_replan = controller.replan

        def _logged_replan(t: float):
            plan = inner_replan(t)
            replan_log.append((float(t), {
                "epoch": len(replan_log) + 1, "n": controller.n,
                "mixed_target": int(plan.mixed_servers(controller.n))}))
            return plan

        controller.replan = _logged_replan

    ecfg = EngineConfig(prim, pricing, n, seed=cfg.seed,
                        sarathi_budget=(variant == "sarathi"),
                        telemetry=telemetry)
    eng = ClusterEngine(classes, policy, ecfg, controller=controller)
    m = eng.run(trace, horizon=horizon,
                failure_events=scenario.failure_events(n),
                drain=cfg.drain)
    out = m.summary()
    out["drops"] = float(m.abandons)  # expired/abandoned requests
    out["drop_rate"] = (m.abandons / m.arrivals) if m.arrivals else 0.0
    out["replans"] = float(controller.replan_count) if controller else 0.0
    out["mixed_target_final"] = float(
        controller.mixed_target() if controller
        else policy.mixed_target(n))
    if m.telemetry is not None:
        tl = m.telemetry
        out["tlm_events"] = float(tl["events"].sum())
        out["tlm_drops"] = float(tl["drops"].sum())
        out["tlm_ttft_p95"] = float(tl["ttft_p95"])
    artifacts = {}
    if trace_path is not None:
        from repro.telemetry.trace import (lifecycle_events, replan_events,
                                           write_trace)

        events = lifecycle_events(eng.lifecycle_records())
        events += replan_events(replan_log)
        p = write_trace(trace_path, events,
                        source=f"closed_loop/{scenario.name}/{variant}")
        artifacts[str(p)] = None
    if manifest_path is not None:
        from repro.telemetry.manifest import (append_record, file_digest,
                                              run_record)

        record = run_record(
            kind="closed_loop", name=f"{scenario.name}/{variant}",
            wall_s=time.time() - t_wall,
            extra={"n": n, "horizon": horizon, "seed": cfg.seed,
                   "n_requests": len(trace),
                   "replans": float(out["replans"]),
                   "telemetry": telemetry is not None},
            artifacts={p: file_digest(p) for p in artifacts})
        append_record(record, manifest_path)
    return {k: float(v) for k, v in out.items()}


def compare_policies(scenario, cfg: ClosedLoopConfig = ClosedLoopConfig(),
                     variants: Sequence[str] = ("adaptive", "static",
                                                "static_cold", "vllm"),
                     prim: Optional[ServicePrimitives] = None,
                     pricing: Optional[Pricing] = None,
                     trace=None, plans=None) -> dict:
    """All variants on ONE generated trace (paired by construction).

    Returns ``{"scenario", "n", "horizon", "n_requests", "variants":
    {name: metrics}, "adaptive_lead_pct": ...}`` where the lead is the
    adaptive variant's revenue-rate advantage over the hindsight static
    plan (positive = closed loop wins).  Pass ``trace`` / ``plans``
    (from :func:`plans_for_scenarios`) when comparing many scenarios:
    the plan solves then run as one batch instead of per call.
    """
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    prim = prim or ServicePrimitives()
    pricing = pricing or Pricing()
    if trace is None:
        trace = scenario.generate(seed=cfg.seed, horizon=cfg.horizon,
                                  compression=cfg.compression,
                                  rate_scale=cfg.rate_scale)
    if plans is None:
        plans = _plans(scenario, trace, cfg, prim, pricing)
    res = {
        v: run_closed_loop(scenario, v, cfg, prim=prim, pricing=pricing,
                           trace=trace, plans=plans)
        for v in variants
    }
    out = {
        "scenario": scenario.name,
        "n": cfg.n_servers,
        "horizon": float(cfg.horizon if cfg.horizon is not None
                         else scenario.horizon),
        "n_requests": len(trace),
        "variants": res,
    }
    if "adaptive" in res and "static" in res:
        base = res["static"]["revenue_rate"]
        out["adaptive_lead_pct"] = (
            100.0 * (res["adaptive"]["revenue_rate"] - base)
            / max(base, 1e-12))
    return out
