"""Declarative workload scenarios + the scenario registry.

A :class:`Scenario` names a *class mix* (tuple of
:class:`repro.data.traces.ClassProfile`, with per-class patience), an
:class:`repro.workloads.arrivals.ArrivalProcess`, an optional
*mix schedule* (time-varying class shares -- the device behind the
``rate_shift`` scenario's composition shift), and an optional
*capacity-event script* (server failures/joins/stragglers that feed
``ClusterEngine.run(failure_events=...)`` and, through it,
``OnlineController.set_capacity``).  ``generate()`` emits a validated
``list[Request]`` and ``tensorize()`` packs it straight into
:class:`repro.data.traces.TraceTensors` for the JAX engines.

The registry (:func:`register_scenario` / :func:`get_scenario` /
:func:`list_scenarios`) ships a catalog spanning stationary to
adversarial: the Azure-like slices the benchmarks replayed with
hand-rolled ``TraceConfig`` blocks, Dolly/agentic/RAG/reasoning mixes,
and the nonstationary shapes (diurnal, flash crowd, rate shift,
capacity churn) the online controller exists for.  The catalog table in
``docs/WORKLOADS.md`` is cross-checked against this registry by
``tools/check_docs.py``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.data.traces import (ClassProfile, Request, TraceTensors,
                               sample_lengths, tensorize_trace,
                               validate_requests)

from .arrivals import (ArrivalProcess, MMPPArrivals, PoissonArrivals, diurnal,
                       flash_crowd, rate_shift)

__all__ = [
    "CapacityEvent",
    "EVENT_KINDS",
    "Scenario",
    "ScenarioError",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
]


class ScenarioError(KeyError):
    """Unknown scenario name or invalid scenario definition."""


#: Capacity-event verbs the engine understands; docs/HETEROGENEITY.md
#: must document every kind and ``tools/check_docs.py`` enforces both
#: directions.
EVENT_KINDS = ("fail", "recover", "straggle", "degrade")


@dataclass(frozen=True)
class CapacityEvent:
    """One scripted elasticity event.

    ``kind`` is one of the engine's event verbs: ``"fail"`` /
    ``"recover"`` (elastic capacity, replanned via
    ``OnlineController.set_capacity``), ``"straggle"`` (iteration-time
    multiplier ``speed``) or ``"degrade"`` (KV handoff link bandwidth
    fraction ``speed``; 1.0 restores -- replans WITHOUT a capacity
    change).  ``sid`` is the target server id; scripts are authored
    against the scenario's recommended cluster size and the harness
    clamps ids to the actual ``n``.
    """

    t: float
    kind: str  # one of EVENT_KINDS
    sid: int
    speed: float = 1.0

    def __post_init__(self) -> None:
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown capacity event kind {self.kind!r}")
        if self.t < 0 or self.sid < 0 or self.speed <= 0:
            raise ValueError("capacity events need t, sid >= 0 and speed > 0")

    def as_tuple(self, n: Optional[int] = None) -> tuple:
        """Engine-format event; clamps ``sid`` into ``[0, n)`` if given."""
        sid = self.sid if n is None else min(self.sid, n - 1)
        if self.kind in ("straggle", "degrade"):
            return (self.t, self.kind, sid, self.speed)
        return (self.t, self.kind, sid)


@dataclass(frozen=True)
class Scenario:
    """A named, fully declarative workload scenario (see module doc)."""

    name: str
    description: str
    profiles: tuple  # ClassProfile per class
    arrivals: ArrivalProcess
    horizon: float = 300.0
    # optional nonstationary class mix: ((t, shares), ...); shares at time
    # t' are those of the last entry with t <= t', else the profile shares
    mix_schedule: tuple = ()
    capacity_events: tuple = ()  # CapacityEvent script
    seed: int = 0
    tags: tuple = ()  # free-form labels ("stationary", "bursty", ...)

    def __post_init__(self) -> None:
        if not self.profiles:
            raise ValueError(f"scenario {self.name!r} has no profiles")
        I = len(self.profiles)
        for t, shares in self.mix_schedule:
            if len(shares) != I:
                raise ValueError(
                    f"scenario {self.name!r}: mix_schedule entry at t={t} "
                    f"has {len(shares)} shares for {I} classes")
            if t < 0 or not all(s >= 0 for s in shares) or sum(shares) <= 0:
                raise ValueError(
                    f"scenario {self.name!r}: invalid mix_schedule entry "
                    f"at t={t}")
        sched_ts = [t for t, _ in self.mix_schedule]
        if sched_ts != sorted(sched_ts) or len(set(sched_ts)) != len(sched_ts):
            raise ValueError(
                f"scenario {self.name!r}: mix_schedule times must be "
                f"strictly increasing, got {sched_ts}")
        if self.horizon <= 0:
            raise ValueError(f"scenario {self.name!r}: horizon must be > 0")

    # ------------------------------------------------------------- introspect
    @property
    def n_classes(self) -> int:
        return len(self.profiles)

    @property
    def class_names(self) -> tuple:
        return tuple(p.name for p in self.profiles)

    def shares_at(self, t: float) -> np.ndarray:
        """Normalized class shares in effect at time ``t`` (the latest
        schedule entry with ``t_k <= t``; profile shares before any)."""
        shares = np.array([p.share for p in self.profiles], dtype=float)
        best = -np.inf
        for t_k, s_k in self.mix_schedule:
            if best < t_k <= t:
                best = t_k
                shares = np.array(s_k, dtype=float)
        return shares / shares.sum()

    def failure_events(self, n: Optional[int] = None) -> list:
        """Capacity script in ``ClusterEngine.run(failure_events=...)``
        format, server ids clamped to cluster size ``n``."""
        return [ev.as_tuple(n) for ev in self.capacity_events]

    def expected_rates(self, horizon: Optional[float] = None) -> np.ndarray:
        """Per-class time-averaged arrival rates (planner cold-start
        inputs; cluster level, requests/second)."""
        h = self.horizon if horizon is None else horizon
        # average the (deterministic) share path on a coarse grid
        ts = np.linspace(0.0, h, 65)[:-1]
        shares = np.stack([self.shares_at(float(t)) for t in ts]).mean(0)
        return self.arrivals.mean_rate(h) * shares

    # --------------------------------------------------------------- generate
    def generate(self, seed: Optional[int] = None,
                 horizon: Optional[float] = None,
                 compression: float = 1.0,
                 rate_scale: float = 1.0) -> list:
        """Sample one validated request trace.

        ``compression`` follows :class:`repro.data.traces.TraceConfig`
        (divide interarrival times by ``1/compression``, i.e. scale the
        offered load by ``1/compression``); ``rate_scale`` multiplies the
        intensity directly.  Both leave authored schedule landmarks
        (rate-shift times, capacity events) on the output time axis --
        see the :mod:`repro.workloads.arrivals` module doc.
        """
        if compression <= 0 or rate_scale <= 0:
            raise ValueError("compression and rate_scale must be positive")
        h = self.horizon if horizon is None else float(horizon)
        factor = rate_scale / compression
        proc = self.arrivals if factor == 1.0 else self.arrivals.scaled(factor)
        rng = np.random.default_rng(self.seed if seed is None else seed)
        times = proc.sample(rng, h)
        reqs = []
        for rid, t in enumerate(times):
            i = int(rng.choice(self.n_classes, p=self.shares_at(float(t))))
            p = self.profiles[i]
            P, D = sample_lengths(rng, p)
            reqs.append(Request(rid, float(t), i, P, D, patience=p.patience))
        validate_requests(reqs, source=f"scenario:{self.name}")
        return reqs

    def tensorize(self, seed: Optional[int] = None,
                  horizon: Optional[float] = None,
                  compression: float = 1.0, rate_scale: float = 1.0,
                  max_requests: Optional[int] = None,
                  pad_to: Optional[int] = None) -> TraceTensors:
        """``tensorize_trace(generate(...))`` -- JAX-engine input."""
        return tensorize_trace(
            self.generate(seed=seed, horizon=horizon,
                          compression=compression, rate_scale=rate_scale),
            max_requests=max_requests, pad_to=pad_to)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_scenario(s: Scenario, overwrite: bool = False) -> Scenario:
    """Add a scenario to the registry (``overwrite=False`` protects the
    built-ins from accidental shadowing).  Returns ``s`` for chaining."""
    if s.name in _REGISTRY and not overwrite:
        raise ScenarioError(f"scenario {s.name!r} already registered")
    _REGISTRY[s.name] = s
    return s


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ScenarioError(
            f"unknown scenario {name!r}; available: {list_scenarios()}"
        ) from None


def list_scenarios() -> list:
    """Sorted names of every registered scenario."""
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-in catalog (documented in docs/WORKLOADS.md; tools/check_docs.py
# cross-checks the doc table against this registry)
# ---------------------------------------------------------------------------

# The Azure-like two-class marginals the repo has always synthesized
# (TraceConfig defaults); kept verbatim so the benchmarks that replayed
# hand-rolled TraceConfig blocks can point here instead.
_AZURE_2023_PROFILES = (
    ClassProfile("code", mean_prompt=2048, mean_decode=36,
                 cv_prompt=1.2, cv_decode=1.5, share=0.45),
    ClassProfile("conversation", mean_prompt=1020, mean_decode=211,
                 cv_prompt=1.4, cv_decode=1.1, share=0.55),
)
_AZURE_2024_PROFILES = (
    ClassProfile("code", mean_prompt=3200, mean_decode=25,
                 cv_prompt=1.1, cv_decode=1.3, share=0.35),
    ClassProfile("conversation", mean_prompt=810, mean_decode=320,
                 cv_prompt=1.5, cv_decode=1.2, share=0.65),
)
_AZURE_MMPP = MMPPArrivals(base_rate=2.0, levels=(0.55, 1.9),
                           switch=(1 / 45.0, 1 / 25.0))


def _dolly_profiles():
    from repro.data.traces import DOLLY_STATS

    picks = (("brainstorming", 0.25), ("closed_qa", 0.3),
             ("summarization", 0.2), ("general_qa", 0.25))
    return tuple(
        ClassProfile(n, mean_prompt=DOLLY_STATS[n][0],
                     mean_decode=DOLLY_STATS[n][1], cv_prompt=0.8,
                     cv_decode=0.9, share=s, patience=90.0)
        for n, s in picks)


_BUILTINS = (
    Scenario(
        name="azure_2023",
        description="Azure-like 2023 slice: code + conversation marginals, "
                    "two-state MMPP bursts (the repo's classic TraceConfig).",
        profiles=_AZURE_2023_PROFILES,
        arrivals=_AZURE_MMPP,
        horizon=300.0,
        seed=42,
        tags=("stationary", "bursty", "azure"),
    ),
    Scenario(
        name="azure_2024",
        description="Azure-like 2024 slice: heavier conversation share, "
                    "longer outputs.",
        profiles=_AZURE_2024_PROFILES,
        arrivals=_AZURE_MMPP,
        horizon=300.0,
        seed=24,
        tags=("stationary", "bursty", "azure"),
    ),
    Scenario(
        name="conv_latent",
        description="EC.8.4 latent-mixture instance: 'conversation' is "
                    "secretly chat + analysis with opposite P/D profiles "
                    "(the workload-classification benchmark's generator).",
        profiles=(
            ClassProfile("code", mean_prompt=2048, mean_decode=36,
                         cv_prompt=1.2, cv_decode=1.5, share=0.385),
            ClassProfile("conv-chat", mean_prompt=200, mean_decode=900,
                         cv_prompt=0.6, cv_decode=0.8, share=0.462),
            ClassProfile("conv-analysis", mean_prompt=2600, mean_decode=30,
                         cv_prompt=0.6, cv_decode=0.8, share=0.153),
        ),
        arrivals=_AZURE_MMPP,
        horizon=300.0,
        seed=42,
        tags=("stationary", "bursty", "latent-classes"),
    ),
    Scenario(
        name="dolly_mix",
        description="Four Dolly-15k task categories (EC Table 4 means) "
                    "under homogeneous Poisson arrivals with finite "
                    "patience, so expiry/abandonment paths fire.",
        profiles=_dolly_profiles(),
        arrivals=PoissonArrivals(rate=20.0),
        horizon=240.0,
        seed=1,
        tags=("stationary", "deadline"),
    ),
    Scenario(
        name="agentic_loops",
        description="Agentic tool-use traffic: many short-prompt/short-"
                    "decode tool steps punctuated by long planning turns, "
                    "with a 3-regime MMPP (idle / steady / tool-storm).",
        profiles=(
            ClassProfile("tool_step", mean_prompt=600, mean_decode=90,
                         cv_prompt=0.9, cv_decode=1.1, share=0.7,
                         patience=45.0),
            ClassProfile("plan_turn", mean_prompt=1600, mean_decode=650,
                         cv_prompt=1.0, cv_decode=1.0, share=0.3,
                         patience=120.0),
        ),
        arrivals=MMPPArrivals(base_rate=16.0, levels=(0.3, 1.0, 2.6),
                              switch=(1 / 30.0, 1 / 40.0, 1 / 15.0)),
        horizon=240.0,
        seed=5,
        tags=("bursty", "agentic", "deadline"),
    ),
    Scenario(
        name="rag_heavy",
        description="Retrieval-augmented mix: huge stuffed-context "
                    "prompts with short answers next to ordinary chat "
                    "(prefill-dominated contention).",
        profiles=(
            ClassProfile("rag_query", mean_prompt=6000, mean_decode=120,
                         cv_prompt=0.7, cv_decode=0.9, share=0.4),
            ClassProfile("chat", mean_prompt=500, mean_decode=260,
                         cv_prompt=1.2, cv_decode=1.0, share=0.6),
        ),
        arrivals=PoissonArrivals(rate=14.0),
        horizon=240.0,
        seed=11,
        tags=("stationary", "prefill-heavy", "rag"),
    ),
    Scenario(
        name="reasoning_long",
        description="Reasoning-model traffic: short prompts, very long "
                    "chains of thought (decode-dominated contention).",
        profiles=(
            ClassProfile("reasoning", mean_prompt=350, mean_decode=2400,
                         cv_prompt=0.8, cv_decode=0.6, share=0.35),
            ClassProfile("chat", mean_prompt=700, mean_decode=220,
                         cv_prompt=1.2, cv_decode=1.0, share=0.65),
        ),
        arrivals=PoissonArrivals(rate=10.0),
        horizon=240.0,
        seed=13,
        tags=("stationary", "decode-heavy", "reasoning"),
    ),
    Scenario(
        name="diurnal",
        description="Piecewise-constant diurnal curve (one simulated "
                    "'day' of 240 s, amplitude 0.6) over the Azure 2023 "
                    "marginals.",
        profiles=_AZURE_2023_PROFILES,
        arrivals=diurnal(base_rate=18.0, amplitude=0.6, period=240.0,
                         horizon=480.0, n_bins=16),
        horizon=480.0,
        seed=3,
        tags=("nonstationary", "diurnal"),
    ),
    Scenario(
        name="flash_crowd",
        description="Flash crowd: 5x arrival spike on [100, 140) s over "
                    "otherwise steady Azure 2023 traffic.",
        profiles=_AZURE_2023_PROFILES,
        arrivals=flash_crowd(base_rate=14.0, spike_mult=5.0,
                             t_on=100.0, t_off=140.0),
        horizon=300.0,
        seed=7,
        tags=("nonstationary", "adversarial", "spike"),
    ),
    Scenario(
        name="rate_shift",
        description="Regime change at t = 120 s: arrival rate steps "
                    "2.5x and the mix flips from code-heavy to "
                    "conversation-heavy -- the online controller's "
                    "showcase (Section 6.2).",
        profiles=(
            ClassProfile("code", mean_prompt=2048, mean_decode=36,
                         cv_prompt=1.2, cv_decode=1.5, share=0.8),
            ClassProfile("conversation", mean_prompt=1020, mean_decode=211,
                         cv_prompt=1.4, cv_decode=1.1, share=0.2),
        ),
        arrivals=rate_shift(rate0=12.0, rate1=30.0, t_shift=120.0),
        mix_schedule=((120.0, (0.25, 0.75)),),
        horizon=300.0,
        seed=9,
        tags=("nonstationary", "adversarial", "rate-shift"),
    ),
    Scenario(
        name="capacity_churn",
        description="Server churn under steady load: two failures at "
                    "t = 60 s, staggered recovery, one straggler -- "
                    "drives OnlineController.set_capacity replans.",
        profiles=_AZURE_2023_PROFILES,
        arrivals=PoissonArrivals(rate=16.0),
        horizon=300.0,
        capacity_events=(
            CapacityEvent(60.0, "fail", 0),
            CapacityEvent(60.0, "fail", 1),
            CapacityEvent(150.0, "recover", 0),
            CapacityEvent(210.0, "recover", 1),
            CapacityEvent(90.0, "straggle", 2, speed=3.0),
            CapacityEvent(180.0, "straggle", 2, speed=1.0),
        ),
        seed=17,
        tags=("nonstationary", "elastic", "failures"),
    ),
    Scenario(
        name="link_degrade",
        description="Interconnect brownout under steady load: three "
                    "servers lose 3/4 of their KV handoff bandwidth on "
                    "[60, 180) s (`degrade` events), then recover -- "
                    "slows prefill->decode transfers without changing "
                    "the server count, so replans are rate-driven.",
        profiles=_AZURE_2023_PROFILES,
        arrivals=PoissonArrivals(rate=16.0),
        horizon=300.0,
        capacity_events=(
            CapacityEvent(60.0, "degrade", 0, speed=0.25),
            CapacityEvent(60.0, "degrade", 1, speed=0.25),
            CapacityEvent(60.0, "degrade", 2, speed=0.25),
            CapacityEvent(180.0, "degrade", 0, speed=1.0),
            CapacityEvent(180.0, "degrade", 1, speed=1.0),
            CapacityEvent(180.0, "degrade", 2, speed=1.0),
        ),
        seed=19,
        tags=("nonstationary", "elastic", "links", "heterogeneity"),
    ),
)

for _s in _BUILTINS:
    register_scenario(_s)
del _s
