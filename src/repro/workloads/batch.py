"""Vmapped batched scenario generation (seeds x scenarios) in JAX.

The Python :meth:`Scenario.generate` path is exact but serial; sweeps
want *hundreds* of (scenario, seed) traces.  This module compiles one
fixed-shape sampling kernel and evaluates the whole batch as

    jax.vmap over scenarios ( jax.vmap over seeds ( kernel ) )

Representation: every scenario is lowered to a *binned intensity* on a
``T``-point grid over ``[0, H)`` plus per-class length/patience
parameters (padded to the batch's max class count).  Sampling is
Lewis-Shedler thinning against the scenario's rate bound -- ``R``
candidate arrivals at rate ``rate_bound``, each kept with probability
``rate(t)/rate_bound`` -- which is exact for Poisson and
piecewise-constant intensities whose breakpoints lie on the grid, and a
binned approximation otherwise.  MMPP scenarios sample their regime
path *inside* the kernel (one ``lax.scan`` over grid bins, at most one
regime switch per bin -- accurate once ``dt << min holding time``), so
burstiness is preserved per replication rather than averaged away.

Outputs are padded, :class:`repro.data.traces.TraceTensors`-shaped
arrays ``(S, K, R)``; :func:`batch_cell_tensors` /
:func:`batch_cell_requests` extract one cell for the engines.  The
kernel never truncates silently: ``truncated[s, k] = 1`` iff the
candidate budget ``R`` ran out before the horizon (the default budget
makes this a ~4-sigma event).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.data.traces import Request, TraceTensors, validate_requests

from .arrivals import MMPPArrivals
from .scenarios import Scenario

__all__ = [
    "scenario_grid_params",
    "generate_batch",
    "batch_cell_tensors",
    "batch_cell_requests",
    "ScenarioStream",
]

_LEN_FLOOR_P, _LEN_FLOOR_D = 8, 2  # same floors as Scenario.generate


def scenario_grid_params(scn: Scenario, horizon_max: float, T: int,
                         I_max: int, K_max: int, compression: float = 1.0,
                         rate_scale: float = 1.0) -> dict:
    """Lower one scenario to the kernel's padded parameter arrays."""
    factor = rate_scale / compression
    proc = scn.arrivals if factor == 1.0 else scn.arrivals.scaled(factor)
    dt = horizon_max / T
    mids = (np.arange(T) + 0.5) * dt
    is_mmpp = isinstance(proc, MMPPArrivals)
    if is_mmpp:
        k = proc.n_regimes
        levels = np.zeros(K_max)
        switch = np.ones(K_max)
        levels[:k] = np.asarray(proc.levels, dtype=float)
        switch[:k] = np.asarray(proc.switch, dtype=float)
        rate_grid = np.full(T, proc.mean_rate(horizon_max))  # unused branch
        base = proc.base_rate
    else:
        k = 1
        levels, switch, base = np.zeros(K_max), np.ones(K_max), 0.0
        rate_grid = np.array([proc.rate_at(float(t)) for t in mids])
    shares = np.zeros((T, I_max))
    for b, t in enumerate(mids):
        shares[b, : scn.n_classes] = scn.shares_at(float(t))
    mean_p = np.ones(I_max)
    mean_d = np.ones(I_max)
    cv_p = np.ones(I_max)
    cv_d = np.ones(I_max)
    patience = np.full(I_max, np.inf)
    for i, p in enumerate(scn.profiles):
        mean_p[i], mean_d[i] = p.mean_prompt, p.mean_decode
        cv_p[i], cv_d[i] = p.cv_prompt, p.cv_decode
        patience[i] = p.patience
    return {
        "rate_grid": rate_grid.astype(np.float32),
        "share_log": np.log(np.maximum(shares, 1e-30)).astype(np.float32),
        "is_mmpp": np.float32(1.0 if is_mmpp else 0.0),
        "mmpp_base": np.float32(base),
        "mmpp_levels": levels.astype(np.float32),
        "mmpp_switch": switch.astype(np.float32),
        "mmpp_k": np.int32(k),
        "rate_bound": np.float32(proc.rate_bound()),
        "horizon": np.float32(min(scn.horizon, horizon_max)),
        "mean_p": mean_p.astype(np.float32),
        "mean_d": mean_d.astype(np.float32),
        "cv_p": cv_p.astype(np.float32),
        "cv_d": cv_d.astype(np.float32),
        "patience": patience.astype(np.float32),
    }


def _make_kernel(R: int, T: int, dt: float):
    import jax
    import jax.numpy as jnp

    def kernel(par, key):
        k_reg, k_gap, k_acc, k_cls, k_p, k_d = jax.random.split(key, 6)

        # -- effective intensity grid (MMPP: sample the regime path) ----
        def step(j, u):
            p_switch = 1.0 - jnp.exp(-par["mmpp_switch"][j] * dt)
            j_next = jnp.where(u < p_switch,
                               jnp.where(j + 1 >= par["mmpp_k"], 0, j + 1), j)
            return j_next, par["mmpp_base"] * par["mmpp_levels"][j]

        _, mmpp_grid = jax.lax.scan(
            step, jnp.int32(0), jax.random.uniform(k_reg, (T,)))
        rate_grid = jnp.where(par["is_mmpp"] > 0, mmpp_grid, par["rate_grid"])

        # -- candidate arrivals at the bound, thinned to rate(t) --------
        bound = jnp.maximum(par["rate_bound"], 1e-9)
        gaps = jax.random.exponential(k_gap, (R,)) / bound
        times = jnp.cumsum(gaps)
        bins = jnp.clip((times / dt).astype(jnp.int32), 0, T - 1)
        lam_t = rate_grid[bins]
        u = jax.random.uniform(k_acc, (R,))
        accept = (times < par["horizon"]) & (u * bound < lam_t)
        truncated = times[R - 1] < par["horizon"]

        # -- class labels + lognormal lengths + patience ----------------
        cls = jax.random.categorical(k_cls, par["share_log"][bins], axis=-1)

        def lengths(kk, mean, cv, floor):
            sigma2 = jnp.log1p(cv[cls] * cv[cls])
            mu = jnp.log(mean[cls]) - sigma2 / 2
            z = jax.random.normal(kk, (R,))
            val = jnp.exp(mu + jnp.sqrt(sigma2) * z)
            return jnp.maximum(floor, val.astype(jnp.int32))

        P = lengths(k_p, par["mean_p"], par["cv_p"], _LEN_FLOOR_P)
        D = lengths(k_d, par["mean_d"], par["cv_d"], _LEN_FLOOR_D)
        pat = par["patience"][cls]

        # -- compact accepted rows to the front (stable by time) --------
        t_keyed = jnp.where(accept, times, jnp.inf)
        order = jnp.argsort(t_keyed)  # accepted stay in arrival order
        t_s = t_keyed[order]
        valid = jnp.isfinite(t_s)
        return {
            "t": t_s,
            "cls": jnp.where(valid, cls[order], 0).astype(jnp.int32),
            "P": jnp.where(valid, P[order], 1).astype(jnp.int32),
            "D": jnp.where(valid, D[order], 1).astype(jnp.int32),
            "patience": jnp.where(valid, pat[order], jnp.inf),
            "valid": valid,
            "n_real": valid.sum().astype(jnp.int32),
            "truncated": truncated.astype(jnp.int32),
        }

    return kernel


def generate_batch(scenarios: Sequence[Scenario], seeds: Sequence[int],
                   horizon: Optional[float] = None, T: int = 512,
                   R: Optional[int] = None, compression: float = 1.0,
                   rate_scale: float = 1.0) -> dict:
    """Generate ``len(scenarios) x len(seeds)`` traces as ONE vmapped batch.

    Returns host ``numpy`` arrays shaped ``(S, K, R)`` (``t``/``cls``/
    ``P``/``D``/``patience``/``valid``) plus ``(S, K)`` ``n_real`` and
    ``truncated`` counters and the shared ``R``/``horizon`` under
    ``"meta"``.  All scenarios share one compiled kernel: shorter
    scenarios simply stop accepting at their own horizon.
    """
    import jax
    import jax.numpy as jnp

    from repro.compat import prng_key

    if not scenarios or not len(seeds):
        raise ValueError("need at least one scenario and one seed")
    H = float(horizon if horizon is not None
              else max(s.horizon for s in scenarios))
    I_max = max(s.n_classes for s in scenarios)
    K_max = max((s.arrivals.n_regimes
                 for s in scenarios if isinstance(s.arrivals, MMPPArrivals)),
                default=1)
    params = [scenario_grid_params(s, H, T, I_max, K_max,
                                   compression=compression,
                                   rate_scale=rate_scale)
              for s in scenarios]
    if R is None:
        # candidate budget: bound * horizon + 4 sigma + slack
        need = max(float(p["rate_bound"]) * H for p in params)
        R = int(need + 4.0 * np.sqrt(max(need, 1.0)) + 64)
    stacked = {k: jnp.stack([jnp.asarray(p[k]) for p in params])
               for k in params[0]}
    keys = jnp.stack([prng_key(int(s)) for s in seeds])
    kernel = _make_kernel(int(R), int(T), H / T)
    fn = jax.jit(jax.vmap(jax.vmap(kernel, in_axes=(None, 0)),
                          in_axes=(0, None)))
    out = {k: np.asarray(v) for k, v in fn(stacked, keys).items()}
    out["meta"] = {
        "R": int(R), "T": int(T), "horizon": H,
        "scenarios": [s.name for s in scenarios],
        "seeds": [int(s) for s in seeds],
    }
    return out


class ScenarioStream:
    """Stream one scenario's trace as fixed-shape on-device chunks.

    :func:`generate_batch` materialises a whole ``(S, K, R)`` candidate
    table up front, so its memory ceiling is the trace length.  This
    stream draws the *same law* -- Lewis-Shedler thinning against the
    scenario's rate bound, per-class lognormal lengths, an MMPP regime
    path on the ``T``-point grid -- but hands out padded
    :class:`TraceTensors` chunks of ``chunk_size`` *candidates* at a
    time, so a streamed replay can consume millions of requests while
    holding one chunk.

    Chunk-size invariance (a metamorphic property the differential
    tests pin down): every candidate draws its randomness from
    ``fold_in(key, candidate_index)`` and the arrival clock accumulates
    strictly left-to-right in float64 on the host, so the concatenation
    of the emitted chunks is bitwise independent of ``chunk_size``.

    ``next_chunk()`` returns ``None`` once the candidate clock passes
    the horizon; the final real chunk may be partially filled (its
    ``valid`` mask says how far).
    """

    def __init__(self, scenario: Scenario, seed: int,
                 chunk_size: int = 4096, horizon: Optional[float] = None,
                 T: int = 512, compression: float = 1.0,
                 rate_scale: float = 1.0):
        import jax
        import jax.numpy as jnp

        from repro.compat import prng_key

        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self.scenario = scenario
        self.chunk_size = int(chunk_size)
        self.horizon = float(horizon if horizon is not None
                             else scenario.horizon)
        I = scenario.n_classes
        K = (scenario.arrivals.n_regimes
             if isinstance(scenario.arrivals, MMPPArrivals) else 1)
        par = scenario_grid_params(scenario, self.horizon, T, I, K,
                                   compression=compression,
                                   rate_scale=rate_scale)
        self._dt = self.horizon / T
        self._T = int(T)
        self._bound = max(float(par["rate_bound"]), 1e-9)
        # one regime path per stream (the kernel's scan, emitted rate
        # first, then the switch draw), sampled once so every chunk
        # thins against the same intensity grid
        if float(par["is_mmpp"]) > 0:
            rng = np.random.default_rng(seed)
            grid = np.empty(T)
            j = 0
            for b in range(T):
                grid[b] = float(par["mmpp_base"] * par["mmpp_levels"][j])
                p_switch = 1.0 - np.exp(-float(par["mmpp_switch"][j])
                                        * self._dt)
                if rng.uniform() < p_switch:
                    j = 0 if j + 1 >= int(par["mmpp_k"]) else j + 1
            self._rate_grid = grid
        else:
            self._rate_grid = par["rate_grid"].astype(np.float64)
        shares = np.exp(par["share_log"].astype(np.float64))  # (T, I)
        shares /= np.maximum(shares.sum(axis=1, keepdims=True), 1e-30)
        self._cdf = np.cumsum(shares, axis=1)
        self._mean_p = par["mean_p"].astype(np.float64)
        self._mean_d = par["mean_d"].astype(np.float64)
        self._cv_p = par["cv_p"].astype(np.float64)
        self._cv_d = par["cv_d"].astype(np.float64)
        self._patience = par["patience"].astype(np.float64)
        self._key = prng_key(int(seed))
        self._i0 = 0
        self._t = 0.0
        self._done = False
        self.n_emitted = 0

        C = self.chunk_size

        def draws(key, i0):
            def one(i):
                k = jax.random.fold_in(key, i)
                kg, ka, kc, kp, kd = jax.random.split(k, 5)
                return (jax.random.exponential(kg),
                        jax.random.uniform(ka),
                        jax.random.uniform(kc),
                        jax.random.normal(kp),
                        jax.random.normal(kd))

            return jax.vmap(one)(jnp.arange(C, dtype=jnp.uint32) + i0)

        self._draw = jax.jit(draws)

    @property
    def exhausted(self) -> bool:
        return self._done

    def next_chunk(self) -> Optional[TraceTensors]:
        if self._done:
            return None
        import jax.numpy as jnp

        C = self.chunk_size
        g, ua, uc, zp, zd = (np.asarray(x, dtype=np.float64)
                             for x in self._draw(self._key,
                                                 jnp.uint32(self._i0)))
        self._i0 += C
        # strict left-to-right accumulation including the carried clock:
        # the association (and hence every bit) matches any chunking
        times = np.add.accumulate(np.concatenate(([self._t], g / self._bound)))[1:]
        self._t = float(times[-1])
        bins = np.clip((times / self._dt).astype(np.int64), 0, self._T - 1)
        accept = ((times < self.horizon)
                  & (ua * self._bound < self._rate_grid[bins]))
        cls = np.minimum((uc[:, None] >= self._cdf[bins]).sum(axis=1),
                         self._cdf.shape[1] - 1)

        def lengths(z, mean, cv, floor):
            sigma2 = np.log1p(cv[cls] * cv[cls])
            mu = np.log(mean[cls]) - sigma2 / 2
            val = np.exp(mu + np.sqrt(sigma2) * z)
            return np.maximum(floor, val.astype(np.int64)).astype(np.int32)

        P = lengths(zp, self._mean_p, self._cv_p, _LEN_FLOOR_P)
        D = lengths(zd, self._mean_d, self._cv_d, _LEN_FLOOR_D)
        n = int(accept.sum())
        t = np.full(C, np.inf)
        cl = np.zeros(C, np.int32)
        Pp = np.ones(C, np.int32)
        Dd = np.ones(C, np.int32)
        pat = np.full(C, np.inf)
        valid = np.zeros(C, bool)
        t[:n] = times[accept]
        cl[:n] = cls[accept]
        Pp[:n] = P[accept]
        Dd[:n] = D[accept]
        pat[:n] = self._patience[cls[accept]]
        valid[:n] = True
        self.n_emitted += n
        if self._t >= self.horizon:
            self._done = True
        return TraceTensors(rid=np.arange(C, dtype=np.int32), t=t,
                            cls=cl, P=Pp, D=Dd, patience=pat,
                            valid=valid, n_real=n)


def batch_cell_tensors(batch: dict, s: int, k: int) -> TraceTensors:
    """One (scenario, seed) cell as engine-ready :class:`TraceTensors`."""
    valid = batch["valid"][s, k]
    R = valid.shape[0]
    t = batch["t"][s, k].astype(np.float64)
    t[~valid] = np.inf
    return TraceTensors(
        rid=np.arange(R, dtype=np.int32),
        t=t,
        cls=batch["cls"][s, k].astype(np.int32),
        P=batch["P"][s, k].astype(np.int32),
        D=batch["D"][s, k].astype(np.int32),
        patience=batch["patience"][s, k].astype(np.float64),
        valid=valid.astype(bool),
        n_real=int(batch["n_real"][s, k]),
        n_dropped=0,
    )


def batch_cell_requests(batch: dict, s: int, k: int) -> list:
    """One (scenario, seed) cell as a validated ``list[Request]``."""
    tt = batch_cell_tensors(batch, s, k)
    reqs = [Request(int(tt.rid[i]), float(tt.t[i]), int(tt.cls[i]),
                    int(tt.P[i]), int(tt.D[i]), float(tt.patience[i]))
            for i in range(tt.R) if tt.valid[i]]
    name = batch["meta"]["scenarios"][s]
    return list(validate_requests(reqs, source=f"generate_batch:{name}"))
