"""Vmapped batched scenario generation (seeds x scenarios) in JAX.

The Python :meth:`Scenario.generate` path is exact but serial; sweeps
want *hundreds* of (scenario, seed) traces.  This module compiles one
fixed-shape sampling kernel and evaluates the whole batch as

    jax.vmap over scenarios ( jax.vmap over seeds ( kernel ) )

Representation: every scenario is lowered to a *binned intensity* on a
``T``-point grid over ``[0, H)`` plus per-class length/patience
parameters (padded to the batch's max class count).  Sampling is
Lewis-Shedler thinning against the scenario's rate bound -- ``R``
candidate arrivals at rate ``rate_bound``, each kept with probability
``rate(t)/rate_bound`` -- which is exact for Poisson and
piecewise-constant intensities whose breakpoints lie on the grid, and a
binned approximation otherwise.  MMPP scenarios sample their regime
path *inside* the kernel (one ``lax.scan`` over grid bins, at most one
regime switch per bin -- accurate once ``dt << min holding time``), so
burstiness is preserved per replication rather than averaged away.

Outputs are padded, :class:`repro.data.traces.TraceTensors`-shaped
arrays ``(S, K, R)``; :func:`batch_cell_tensors` /
:func:`batch_cell_requests` extract one cell for the engines.  The
kernel never truncates silently: ``truncated[s, k] = 1`` iff the
candidate budget ``R`` ran out before the horizon (the default budget
makes this a ~4-sigma event).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.data.traces import Request, TraceTensors, validate_requests

from .arrivals import MMPPArrivals
from .scenarios import Scenario

__all__ = [
    "scenario_grid_params",
    "generate_batch",
    "batch_cell_tensors",
    "batch_cell_requests",
]

_LEN_FLOOR_P, _LEN_FLOOR_D = 8, 2  # same floors as Scenario.generate


def scenario_grid_params(scn: Scenario, horizon_max: float, T: int,
                         I_max: int, K_max: int, compression: float = 1.0,
                         rate_scale: float = 1.0) -> dict:
    """Lower one scenario to the kernel's padded parameter arrays."""
    factor = rate_scale / compression
    proc = scn.arrivals if factor == 1.0 else scn.arrivals.scaled(factor)
    dt = horizon_max / T
    mids = (np.arange(T) + 0.5) * dt
    is_mmpp = isinstance(proc, MMPPArrivals)
    if is_mmpp:
        k = proc.n_regimes
        levels = np.zeros(K_max)
        switch = np.ones(K_max)
        levels[:k] = np.asarray(proc.levels, dtype=float)
        switch[:k] = np.asarray(proc.switch, dtype=float)
        rate_grid = np.full(T, proc.mean_rate(horizon_max))  # unused branch
        base = proc.base_rate
    else:
        k = 1
        levels, switch, base = np.zeros(K_max), np.ones(K_max), 0.0
        rate_grid = np.array([proc.rate_at(float(t)) for t in mids])
    shares = np.zeros((T, I_max))
    for b, t in enumerate(mids):
        shares[b, : scn.n_classes] = scn.shares_at(float(t))
    mean_p = np.ones(I_max)
    mean_d = np.ones(I_max)
    cv_p = np.ones(I_max)
    cv_d = np.ones(I_max)
    patience = np.full(I_max, np.inf)
    for i, p in enumerate(scn.profiles):
        mean_p[i], mean_d[i] = p.mean_prompt, p.mean_decode
        cv_p[i], cv_d[i] = p.cv_prompt, p.cv_decode
        patience[i] = p.patience
    return {
        "rate_grid": rate_grid.astype(np.float32),
        "share_log": np.log(np.maximum(shares, 1e-30)).astype(np.float32),
        "is_mmpp": np.float32(1.0 if is_mmpp else 0.0),
        "mmpp_base": np.float32(base),
        "mmpp_levels": levels.astype(np.float32),
        "mmpp_switch": switch.astype(np.float32),
        "mmpp_k": np.int32(k),
        "rate_bound": np.float32(proc.rate_bound()),
        "horizon": np.float32(min(scn.horizon, horizon_max)),
        "mean_p": mean_p.astype(np.float32),
        "mean_d": mean_d.astype(np.float32),
        "cv_p": cv_p.astype(np.float32),
        "cv_d": cv_d.astype(np.float32),
        "patience": patience.astype(np.float32),
    }


def _make_kernel(R: int, T: int, dt: float):
    import jax
    import jax.numpy as jnp

    def kernel(par, key):
        k_reg, k_gap, k_acc, k_cls, k_p, k_d = jax.random.split(key, 6)

        # -- effective intensity grid (MMPP: sample the regime path) ----
        def step(j, u):
            p_switch = 1.0 - jnp.exp(-par["mmpp_switch"][j] * dt)
            j_next = jnp.where(u < p_switch,
                               jnp.where(j + 1 >= par["mmpp_k"], 0, j + 1), j)
            return j_next, par["mmpp_base"] * par["mmpp_levels"][j]

        _, mmpp_grid = jax.lax.scan(
            step, jnp.int32(0), jax.random.uniform(k_reg, (T,)))
        rate_grid = jnp.where(par["is_mmpp"] > 0, mmpp_grid, par["rate_grid"])

        # -- candidate arrivals at the bound, thinned to rate(t) --------
        bound = jnp.maximum(par["rate_bound"], 1e-9)
        gaps = jax.random.exponential(k_gap, (R,)) / bound
        times = jnp.cumsum(gaps)
        bins = jnp.clip((times / dt).astype(jnp.int32), 0, T - 1)
        lam_t = rate_grid[bins]
        u = jax.random.uniform(k_acc, (R,))
        accept = (times < par["horizon"]) & (u * bound < lam_t)
        truncated = times[R - 1] < par["horizon"]

        # -- class labels + lognormal lengths + patience ----------------
        cls = jax.random.categorical(k_cls, par["share_log"][bins], axis=-1)

        def lengths(kk, mean, cv, floor):
            sigma2 = jnp.log1p(cv[cls] * cv[cls])
            mu = jnp.log(mean[cls]) - sigma2 / 2
            z = jax.random.normal(kk, (R,))
            val = jnp.exp(mu + jnp.sqrt(sigma2) * z)
            return jnp.maximum(floor, val.astype(jnp.int32))

        P = lengths(k_p, par["mean_p"], par["cv_p"], _LEN_FLOOR_P)
        D = lengths(k_d, par["mean_d"], par["cv_d"], _LEN_FLOOR_D)
        pat = par["patience"][cls]

        # -- compact accepted rows to the front (stable by time) --------
        t_keyed = jnp.where(accept, times, jnp.inf)
        order = jnp.argsort(t_keyed)  # accepted stay in arrival order
        t_s = t_keyed[order]
        valid = jnp.isfinite(t_s)
        return {
            "t": t_s,
            "cls": jnp.where(valid, cls[order], 0).astype(jnp.int32),
            "P": jnp.where(valid, P[order], 1).astype(jnp.int32),
            "D": jnp.where(valid, D[order], 1).astype(jnp.int32),
            "patience": jnp.where(valid, pat[order], jnp.inf),
            "valid": valid,
            "n_real": valid.sum().astype(jnp.int32),
            "truncated": truncated.astype(jnp.int32),
        }

    return kernel


def generate_batch(scenarios: Sequence[Scenario], seeds: Sequence[int],
                   horizon: Optional[float] = None, T: int = 512,
                   R: Optional[int] = None, compression: float = 1.0,
                   rate_scale: float = 1.0) -> dict:
    """Generate ``len(scenarios) x len(seeds)`` traces as ONE vmapped batch.

    Returns host ``numpy`` arrays shaped ``(S, K, R)`` (``t``/``cls``/
    ``P``/``D``/``patience``/``valid``) plus ``(S, K)`` ``n_real`` and
    ``truncated`` counters and the shared ``R``/``horizon`` under
    ``"meta"``.  All scenarios share one compiled kernel: shorter
    scenarios simply stop accepting at their own horizon.
    """
    import jax
    import jax.numpy as jnp

    from repro.compat import prng_key

    if not scenarios or not len(seeds):
        raise ValueError("need at least one scenario and one seed")
    H = float(horizon if horizon is not None
              else max(s.horizon for s in scenarios))
    I_max = max(s.n_classes for s in scenarios)
    K_max = max((s.arrivals.n_regimes
                 for s in scenarios if isinstance(s.arrivals, MMPPArrivals)),
                default=1)
    params = [scenario_grid_params(s, H, T, I_max, K_max,
                                   compression=compression,
                                   rate_scale=rate_scale)
              for s in scenarios]
    if R is None:
        # candidate budget: bound * horizon + 4 sigma + slack
        need = max(float(p["rate_bound"]) * H for p in params)
        R = int(need + 4.0 * np.sqrt(max(need, 1.0)) + 64)
    stacked = {k: jnp.stack([jnp.asarray(p[k]) for p in params])
               for k in params[0]}
    keys = jnp.stack([prng_key(int(s)) for s in seeds])
    kernel = _make_kernel(int(R), int(T), H / T)
    fn = jax.jit(jax.vmap(jax.vmap(kernel, in_axes=(None, 0)),
                          in_axes=(0, None)))
    out = {k: np.asarray(v) for k, v in fn(stacked, keys).items()}
    out["meta"] = {
        "R": int(R), "T": int(T), "horizon": H,
        "scenarios": [s.name for s in scenarios],
        "seeds": [int(s) for s in seeds],
    }
    return out


def batch_cell_tensors(batch: dict, s: int, k: int) -> TraceTensors:
    """One (scenario, seed) cell as engine-ready :class:`TraceTensors`."""
    valid = batch["valid"][s, k]
    R = valid.shape[0]
    t = batch["t"][s, k].astype(np.float64)
    t[~valid] = np.inf
    return TraceTensors(
        rid=np.arange(R, dtype=np.int32),
        t=t,
        cls=batch["cls"][s, k].astype(np.int32),
        P=batch["P"][s, k].astype(np.int32),
        D=batch["D"][s, k].astype(np.int32),
        patience=batch["patience"][s, k].astype(np.float64),
        valid=valid.astype(bool),
        n_real=int(batch["n_real"][s, k]),
        n_dropped=0,
    )


def batch_cell_requests(batch: dict, s: int, k: int) -> list:
    """One (scenario, seed) cell as a validated ``list[Request]``."""
    tt = batch_cell_tensors(batch, s, k)
    reqs = [Request(int(tt.rid[i]), float(tt.t[i]), int(tt.cls[i]),
                    int(tt.P[i]), int(tt.D[i]), float(tt.patience[i]))
            for i in range(tt.R) if tt.valid[i]]
    name = batch["meta"]["scenarios"][s]
    return list(validate_requests(reqs, source=f"generate_batch:{name}"))
