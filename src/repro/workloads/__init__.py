"""Nonstationary workload scenarios + the closed-loop control harness.

Layers:

* :mod:`repro.workloads.arrivals` -- arrival processes (Poisson,
  k-regime MMPP, piecewise-constant rate-shift / flash-crowd / diurnal).
* :mod:`repro.workloads.scenarios` -- the declarative :class:`Scenario`
  spec, capacity-event scripts, and the registry of built-ins
  (:func:`get_scenario` / :func:`list_scenarios`).
* :mod:`repro.workloads.batch` -- vmapped (seeds x scenarios) JAX trace
  generation for sweep-scale runs (imported lazily: needs jax).
* :mod:`repro.workloads.closed_loop` -- OnlineController wired into the
  engine replay, compared against static/heuristic baselines.

CLI: ``python -m repro.workloads.run`` (catalog listing, generation
stats, closed-loop comparisons).  See ``docs/WORKLOADS.md``.
"""

from .arrivals import (ArrivalProcess, MMPPArrivals,
                       PiecewiseConstantArrivals, PoissonArrivals, diurnal,
                       flash_crowd, rate_shift)
from .closed_loop import (VARIANTS, ClosedLoopConfig, compare_policies,
                          plans_for_scenarios, run_closed_loop)
from .scenarios import (CapacityEvent, EVENT_KINDS, Scenario, ScenarioError,
                        get_scenario, list_scenarios, register_scenario)

__all__ = [
    "ArrivalProcess",
    "PoissonArrivals",
    "MMPPArrivals",
    "PiecewiseConstantArrivals",
    "rate_shift",
    "flash_crowd",
    "diurnal",
    "CapacityEvent",
    "EVENT_KINDS",
    "Scenario",
    "ScenarioError",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "ClosedLoopConfig",
    "VARIANTS",
    "run_closed_loop",
    "compare_policies",
    "plans_for_scenarios",
]
