"""CLI for the workload-scenario subsystem.

    # catalog
    PYTHONPATH=src python -m repro.workloads.run --list

    # generate one scenario, print trace stats (optionally save CSV)
    PYTHONPATH=src python -m repro.workloads.run --scenario rate_shift \
        --stats --seed 3 --out /tmp/rate_shift.csv

    # closed-loop comparison (adaptive vs static vs heuristics)
    PYTHONPATH=src python -m repro.workloads.run --scenario rate_shift \
        --closed-loop --n 8 --quick

``--closed-loop`` prints one row per variant and, with ``--out``,
writes the full comparison payload as JSON.
``benchmarks/bench_scenarios.py`` runs the same comparison over the
whole registry.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

from repro.sweep.run import fmt_table

from .closed_loop import ClosedLoopConfig, compare_policies
from .scenarios import get_scenario, list_scenarios

__all__ = ["main"]


def _catalog_rows():
    rows = []
    for name in list_scenarios():
        s = get_scenario(name)
        rows.append({
            "scenario": name,
            "classes": len(s.profiles),
            "arrivals": type(s.arrivals).__name__,
            "mean_rate": round(s.arrivals.mean_rate(s.horizon), 2),
            "horizon": s.horizon,
            "events": len(s.capacity_events),
            "tags": ",".join(s.tags),
        })
    return rows


def _trace_stats(scn, trace, horizon: float) -> dict:
    per_cls = np.bincount([r.cls for r in trace], minlength=scn.n_classes)
    return {
        "scenario": scn.name,
        "n_requests": len(trace),
        "mean_rate": round(len(trace) / max(horizon, 1e-9), 2),
        "per_class": {scn.class_names[i]: int(per_cls[i])
                      for i in range(scn.n_classes)},
        "mean_P": round(float(np.mean([r.prompt_len for r in trace])), 1)
        if trace else 0.0,
        "mean_D": round(float(np.mean([r.decode_len for r in trace])), 1)
        if trace else 0.0,
        "finite_patience": int(sum(np.isfinite(r.patience) for r in trace)),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.workloads.run",
        description="Workload scenarios: catalog, generation, closed loop.")
    ap.add_argument("--list", action="store_true",
                    help="print the scenario catalog and exit")
    ap.add_argument("--scenario", default=None,
                    help="scenario name (see --list)")
    ap.add_argument("--stats", action="store_true",
                    help="generate the scenario and print trace statistics")
    ap.add_argument("--closed-loop", action="store_true",
                    help="run the adaptive-vs-static comparison")
    ap.add_argument("--variants", default="adaptive,static,static_cold,vllm",
                    help="comma-separated closed-loop variants")
    ap.add_argument("--n", type=int, default=8, help="cluster size")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--horizon", type=float, default=None,
                    help="override the scenario horizon (seconds)")
    ap.add_argument("--compression", type=float, default=1.0,
                    help="interarrival compression (TraceConfig semantics)")
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="multiply arrival intensity directly")
    ap.add_argument("--quick", action="store_true",
                    help="60 s horizon, light load (CI smoke sizing)")
    ap.add_argument("--out", default=None,
                    help="write trace CSV (--stats) or JSON (--closed-loop)")
    args = ap.parse_args(argv)

    if args.list:
        print(fmt_table(_catalog_rows(),
                        ["scenario", "classes", "arrivals", "mean_rate",
                         "horizon", "events", "tags"],
                        f"\n[workloads] {len(list_scenarios())} registered "
                        f"scenarios"))
        return 0

    if not args.scenario:
        ap.error("--scenario is required unless --list is given")
    scn = get_scenario(args.scenario)
    horizon = args.horizon
    rate_scale = args.rate_scale
    if args.quick:
        horizon = min(60.0, horizon or scn.horizon)
        rate_scale = rate_scale * 0.5

    if args.closed_loop:
        cfg = ClosedLoopConfig(n_servers=args.n, horizon=horizon,
                               compression=args.compression,
                               rate_scale=rate_scale, seed=args.seed)
        res = compare_policies(scn, cfg,
                               variants=tuple(
                                   v for v in args.variants.split(",") if v))
        rows = [
            dict(variant=v,
                 revenue_rate=round(m["revenue_rate"], 2),
                 completion=round(m["completion_rate"], 3),
                 drops=int(m["drops"]),
                 ttft_p95=round(m["ttft_p95"], 2),
                 replans=int(m["replans"]))
            for v, m in res["variants"].items()
        ]
        print(fmt_table(rows, ["variant", "revenue_rate", "completion",
                               "drops", "ttft_p95", "replans"],
                        f"\n[workloads:{scn.name}] closed loop, "
                        f"n={res['n']}, {res['n_requests']} requests"))
        if "adaptive_lead_pct" in res:
            print(f"[workloads:{scn.name}] adaptive vs hindsight-static: "
                  f"{res['adaptive_lead_pct']:+.1f}% revenue rate")
        if args.out:
            Path(args.out).parent.mkdir(parents=True, exist_ok=True)
            Path(args.out).write_text(json.dumps(res, indent=1))
            print(f"[workloads:{scn.name}] wrote {args.out}")
        return 0

    # default / --stats: generate and describe
    trace = scn.generate(seed=args.seed, horizon=horizon,
                         compression=args.compression, rate_scale=rate_scale)
    stats = _trace_stats(scn, trace, horizon or scn.horizon)
    print(json.dumps(stats, indent=1))
    if args.out:
        path = Path(args.out)
        path.parent.mkdir(parents=True, exist_ok=True)
        # numeric class ids: load_trace_csv renumbers unknown *names* by
        # first appearance, so names would not round-trip the indices
        with path.open("w") as f:
            f.write("t,class,P,D,patience\n")
            for r in trace:
                f.write(f"{r.t_arrival},{r.cls},"
                        f"{r.prompt_len},{r.decode_len},{r.patience}\n")
        print(f"[workloads:{scn.name}] wrote {len(trace)} requests to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
