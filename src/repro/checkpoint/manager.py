"""Sharded, atomic, keep-last-k checkpointing with restore-time resharding.

Layout (one directory per step):

    <root>/step_000420/
        manifest.json      # tree structure, shapes/dtypes, content hashes,
                           # host shard table, user metadata (data cursor...)
        host00.npz         # this host's param/optimizer shards
        ...
    <root>/step_000420.tmp_*   (staging; atomic rename on commit)

Design points for 1000+ node deployments, scaled down honestly to this
container:

* **Atomicity** -- writes land in a ``.tmp`` staging dir; ``manifest.json``
  is written last and the directory is atomically renamed.  A crash never
  leaves a readable-but-corrupt checkpoint.
* **Per-host sharding** -- every host saves only the shards it owns
  (``addressable_shards``); restore reassembles and *re-shards to the
  current mesh*, so restarting with a different topology (elastic resize,
  failed pod) works.
* **Integrity** -- every leaf records a SHA256; restore verifies before
  device_put.
* **keep-last-k** -- bounded disk usage with ``gc()``.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (f"[{i}]",))
    else:
        yield path, tree


def _unflatten(items: dict):
    root: dict = {}
    for key, val in items.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def listify(node):
        if isinstance(node, dict):
            if node and all(k.startswith("[") for k in node):
                return [listify(node[f"[{i}]"]) for i in range(len(node))]
            return {k: listify(v) for k, v in node.items()}
        return node

    return listify(root)


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3,
                 host_id: Optional[int] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host = host_id if host_id is not None else jax.process_index()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             shardings: Any = None) -> Path:
        """Save a pytree (params / full train state) atomically."""
        final = self.root / f"step_{step:08d}"
        tmp = Path(tempfile.mkdtemp(prefix=final.name + ".tmp_",
                                    dir=self.root))
        arrays, manifest_leaves = {}, {}
        for path, leaf in _flatten(tree):
            key = "/".join(path)
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            manifest_leaves[key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
                "host": self.host,
            }
        np.savez(tmp / f"host{self.host:02d}.npz", **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": manifest_leaves,
            "metadata": metadata or {},
            "n_hosts": jax.process_count(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self.gc()
        return final

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.is_dir() and (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None, shardings: Any = None,
                verify: bool = True) -> tuple[Any, dict]:
        """Load a checkpoint; optionally device_put to (new) shardings.

        Returns (tree, metadata).  ``shardings``: matching pytree of
        NamedSharding (or None for host arrays) -- restoring onto a
        different mesh than the one that saved is supported (reshard on
        load).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays: dict[str, np.ndarray] = {}
        for npz in sorted(d.glob("host*.npz")):
            with np.load(npz) as z:
                for k in z.files:
                    arrays[k] = z[k]
        if verify:
            for k, meta in manifest["leaves"].items():
                h = hashlib.sha256(arrays[k].tobytes()).hexdigest()
                if h != meta["sha256"]:
                    raise IOError(f"checkpoint corruption in leaf {k}")
        tree = _unflatten(arrays)
        if shardings is not None:
            flat_sh = {"/".join(p): s for p, s in _flatten(shardings)}
            tree = _unflatten({
                k: (jax.device_put(v, flat_sh[k]) if flat_sh.get(k) is not None
                    else v)
                for k, v in arrays.items()
            })
        return tree, manifest["metadata"]

    # -------------------------------------------------------------------- gc
    def gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)
        # clean stale staging dirs
        for p in self.root.glob("step_*.tmp_*"):
            shutil.rmtree(p, ignore_errors=True)
