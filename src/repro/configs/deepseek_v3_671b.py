"""deepseek-v3-671b [moe]: 61L, d_model=7168, 128H MLA, expert d_ff=2048,
vocab=129280; MoE 256 routed top-8 + 1 shared; first 3 layers dense
(d_ff=18432); MTP head.  [arXiv:2412.19437; hf]
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    d_ff=18432,  # dense layers (first moe_start_layer layers)
    vocab_size=129280,
    mla=MLAConfig(n_heads=128, q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1),
    pattern=("mla",),
    moe_start_layer=3,
    mtp=True,
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=4,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    mla=MLAConfig(n_heads=4, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1),
    moe_start_layer=1,
    max_seq_len=128,
    param_dtype="float32",
)
