"""mamba2-130m [ssm]: 24L, d_model=768, attention-free SSD blocks,
vocab=50280, ssm_state=128.  [arXiv:2405.21060; unverified]
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_width=4, chunk=256),
    pattern=("ssm",),
    tie_embeddings=True,
    subquadratic=True,
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=3,
    d_model=64,
    vocab_size=512,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4, chunk=32),
    max_seq_len=128,
    param_dtype="float32",
)
