"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H, d_ff=2048,
vocab=51865.  Encoder-decoder; conv audio frontend is a stub (input_specs
provide precomputed frame embeddings).  [arXiv:2212.04356; unverified]
"""

from repro.models.config import (AttentionConfig, AudioFrontendStub,
                                 EncoderConfig, ModelConfig)

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    d_ff=2048,
    vocab_size=51865,
    attn=AttentionConfig(n_heads=8, n_kv_heads=8, head_dim=64, rope=False),
    encoder=EncoderConfig(n_layers=6, n_frames=1500, d_model=512, n_heads=8,
                          d_ff=2048),
    audio=AudioFrontendStub(n_frames=1500),
    pattern=("attn",),
    mlp_act="gelu",
    norm="layernorm",
    max_seq_len=32768 + 8,
)

REDUCED = CONFIG.replace(
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=256,
    attn=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16, rope=False),
    encoder=EncoderConfig(n_layers=2, n_frames=16, d_model=64, n_heads=4,
                          d_ff=128),
    audio=AudioFrontendStub(n_frames=16),
    max_seq_len=128,
)
