"""Assigned input shapes and their ShapeDtypeStruct stand-ins.

Four shapes per LM architecture (40 cells across the 10 archs):

* ``train_4k``     seq 4,096  x global_batch 256   -> lowers train_step
* ``prefill_32k``  seq 32,768 x global_batch 32    -> lowers prefill_step
* ``decode_32k``   seq 32,768 x global_batch 128   -> lowers serve_step
                   (one new token, KV cache of seq_len)
* ``long_500k``    seq 524,288 x global_batch 1    -> serve_step; only for
                   sub-quadratic archs (ssm / hybrid / local-attn hybrids)

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs
(no device allocation) for every model input of the step being lowered,
including stub modality frontends (audio frames / vision patches).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "supported_shapes", "input_specs",
           "skip_reason"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    """None if the (arch, shape) cell runs; else a one-line skip reason."""
    s = SHAPES[shape]
    if s.name == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return None


def supported_shapes(cfg: ModelConfig) -> list[str]:
    return [k for k in SHAPES if skip_reason(cfg, k) is None]


def _stub_specs(cfg: ModelConfig, batch: int) -> dict:
    out = {}
    if cfg.encoder is not None:
        out["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.n_frames, cfg.encoder.d_model), jnp.bfloat16)
    if cfg.vision is not None:
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision.n_patches, cfg.d_model), jnp.bfloat16)
    return out


def input_specs(cfg: ModelConfig, shape: str, *,
                global_batch: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the data inputs of the lowered step."""
    s = SHAPES[shape]
    gb = global_batch if global_batch is not None else s.global_batch
    if s.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((gb, s.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((gb, s.seq_len), jnp.int32),
        }
        specs.update(_stub_specs(cfg, gb))
        return specs
    if s.kind == "prefill":
        specs = {
            "tokens": jax.ShapeDtypeStruct((gb, s.seq_len), jnp.int32),
            "positions": jax.ShapeDtypeStruct((gb, s.seq_len), jnp.int32),
        }
        specs.update(_stub_specs(cfg, gb))
        return specs
    # decode: one new token against a seq_len KV cache
    return {
        "tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32),
        "positions": jax.ShapeDtypeStruct((gb,), jnp.int32),
    }
