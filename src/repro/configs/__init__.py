"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config(arch_id)`` returns the full published config;
``get_config(arch_id, reduced=True)`` the CPU-smoke-testable variant of the
same family.  Shapes live in :mod:`repro.configs.shapes`.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

from .shapes import SHAPES, ShapeSpec, input_specs, skip_reason, supported_shapes

__all__ = ["ARCHS", "get_config", "SHAPES", "ShapeSpec", "input_specs",
           "skip_reason", "supported_shapes", "all_cells"]

#: arch id -> module name
ARCHS: dict[str, str] = {
    "whisper-base": "whisper_base",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "grok-1-314b": "grok_1_314b",
    "deepseek-67b": "deepseek_67b",
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma2-2b": "gemma2_2b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-130m": "mamba2_130m",
    "paligemma-3b": "paligemma_3b",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, including skipped ones."""
    return [(a, s) for a in ARCHS for s in SHAPES]
