"""qwen2-0.5b [dense]: 24L, d_model=896, 14H (GQA kv=2), d_ff=4864,
vocab=151936; GQA with QKV bias; tied embeddings.  [arXiv:2407.10671; hf]
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151936,
    attn=AttentionConfig(n_heads=14, n_kv_heads=2, head_dim=64,
                         rope_theta=1e6, qkv_bias=True),
    pattern=("attn",),
    tie_embeddings=True,
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=3,
    d_model=64,
    d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True),
    max_seq_len=128,
    param_dtype="float32",
)
