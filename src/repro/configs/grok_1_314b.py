"""grok-1-314b [moe]: 64L, d_model=6144, 48H (GQA kv=8), expert d_ff=32768,
vocab=131072; MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]
"""

from repro.models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab_size=131072,
    attn=AttentionConfig(n_heads=48, n_kv_heads=8, head_dim=128),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
    pattern=("attn",),
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=3,
    d_model=64,
    d_ff=256,
    vocab_size=512,
    attn=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
    max_seq_len=128,
    param_dtype="float32",
)
