"""recurrentgemma-2b [hybrid]: 26L, d_model=2560, 10H (GQA kv=1), d_ff=7680,
vocab=256000; RG-LRU + local attention in a (rec, rec, attn_local) 1:2
pattern, window 2048.  [arXiv:2402.19427; hf]
"""

from repro.models.config import AttentionConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    d_ff=7680,
    vocab_size=256000,
    attn=AttentionConfig(n_heads=10, n_kv_heads=1, head_dim=256, window=2048),
    rglru=RGLRUConfig(width=2560, conv_width=4),
    pattern=("rec", "rec", "attn_local"),
    mlp_act="geglu",
    tie_embeddings=True,
    scale_embed=True,
    subquadratic=True,
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=3,
    d_model=64,
    d_ff=192,
    vocab_size=512,
    attn=AttentionConfig(n_heads=4, n_kv_heads=1, head_dim=16, window=32),
    rglru=RGLRUConfig(width=64, conv_width=4),
    max_seq_len=128,
    param_dtype="float32",
)
