"""gemma2-2b [dense]: 26L, d_model=2304, 8H (GQA kv=4), d_ff=9216,
vocab=256000; local(4096)+global alternating, attn softcap 50, final logit
softcap 30, geglu, tied + scaled embeddings.  [arXiv:2408.00118; hf]

long_500k note: the local layers hold a 4096-token ring cache; the alternate
global layers hold the full 500k KV -- linear per decode step, so the arch is
treated as sub-quadratic-capable and the global-KV memory term is called out
in the roofline table.
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    d_ff=9216,
    vocab_size=256000,
    attn=AttentionConfig(n_heads=8, n_kv_heads=4, head_dim=256, window=4096,
                         attn_softcap=50.0),
    pattern=("attn_local", "attn"),
    mlp_act="geglu",
    logit_softcap=30.0,
    tie_embeddings=True,
    scale_embed=True,
    subquadratic=True,  # local/global hybrid; see module docstring
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=4,
    d_model=64,
    d_ff=256,
    vocab_size=512,
    attn=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=32,
                         attn_softcap=50.0),
    max_seq_len=128,
    param_dtype="float32",
)
