"""deepseek-67b [dense]: 95L, d_model=8192, 64H (GQA kv=8), d_ff=22016,
vocab=102400; llama-arch.  [arXiv:2401.02954; hf]
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    d_ff=22016,
    vocab_size=102400,
    attn=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128),
    pattern=("attn",),
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=3,
    d_model=96,
    d_ff=256,
    vocab_size=512,
    attn=AttentionConfig(n_heads=6, n_kv_heads=2, head_dim=16),
    max_seq_len=128,
    param_dtype="float32",
)
