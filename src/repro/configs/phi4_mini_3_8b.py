"""phi4-mini-3.8b [dense]: 32L, d_model=3072, 24H (GQA kv=8), d_ff=8192,
vocab=200064; RoPE + SwiGLU + GQA.  [arXiv:2412.08905; hf]
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=200064,
    attn=AttentionConfig(n_heads=24, n_kv_heads=8, head_dim=128),
    pattern=("attn",),
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=3,
    d_model=96,
    d_ff=256,
    vocab_size=512,
    attn=AttentionConfig(n_heads=6, n_kv_heads=2, head_dim=16),
    max_seq_len=128,
    param_dtype="float32",
)
