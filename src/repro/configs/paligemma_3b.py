"""paligemma-3b [vlm]: 18L gemma backbone, d_model=2048, 8H (GQA kv=1),
d_ff=16384, vocab=257216; SigLIP vision tower is a stub (input_specs provide
precomputed patch embeddings, prefix-LM attention over the prefix).
[arXiv:2407.07726; hf]
"""

from repro.models.config import (AttentionConfig, ModelConfig,
                                 PrefixVisionStub)

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257216,
    attn=AttentionConfig(n_heads=8, n_kv_heads=1, head_dim=256),
    vision=PrefixVisionStub(n_patches=256),
    pattern=("attn",),
    mlp_act="geglu",
    tie_embeddings=True,
    scale_embed=True,
    param_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=3,
    d_model=64,
    d_ff=192,
    vocab_size=512,
    attn=AttentionConfig(n_heads=4, n_kv_heads=1, head_dim=16),
    vision=PrefixVisionStub(n_patches=4),
    max_seq_len=128,
    param_dtype="float32",
)
