"""Fixed-iteration batched LP solver (primal-dual interior point, jit/vmap).

The planning layer (Eqs. 40/42 + SLI rows) needs thousands of small dense
LP solves per sweep/replan epoch; the hand-rolled tableau simplex in
:mod:`repro.core.lp` is exact but serial Python.  This module solves the
same problem form

    maximize    c' x
    subject to  A_ub x <= b_ub
                A_eq x == b_eq
                x >= 0

with a **Mehrotra predictor-corrector interior-point method** whose every
step is a fixed-shape dense linear solve, so one instance jits and a
whole batch of instances runs as a single ``jax.vmap`` over the leading
axis -- the exact same porting pattern as ``ctmc_jax``/``engine_jax``,
with :func:`repro.core.lp.linprog_max` kept as the semantics oracle.

Why interior point (and not a ported simplex): the simplex's pivot
sequence is data-dependent control flow (ragged across a batch), while
the IPM is a *fixed iteration count* of identical Newton steps on the
standard-form KKT system -- ``jax.lax.fori_loop`` of Cholesky solves --
which is the structure ``jit``/``vmap`` want.  Convergence is
superlinear near the central path; on the planning corpus the solver
reaches ~1e-10 relative residuals in < 30 iterations, so the default
budget of ``DEFAULT_ITERS = 60`` has a 2x margin.  Iterates freeze once
converged (steps are masked), so extra budget costs FLOPs, not accuracy.

Numerics: the KKT solves need double precision (normal equations square
the condition number), so the entry points run inside the
``repro.compat.enable_x64`` scope -- double precision is *local* to the
solver and the process-global default dtype is untouched.  The
standard-form data is Ruiz-equilibrated before iterating, which is what
keeps the badly scaled planning rows (``theta ~ 3e-4`` next to
``mu_p ~ 1e2``) well conditioned.

Infeasible/unbounded instances cannot raise from inside ``jit``; they
surface as ``converged == False`` with large final residuals in the
:class:`LPBatchResult` diagnostics.  Callers that need hard errors (the
planner) validate inputs first and/or check ``converged``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compat import enable_x64

__all__ = ["LPBatchResult", "solve_lp_batch", "linprog_max_jax",
           "DEFAULT_ITERS", "DEFAULT_TOL"]

DEFAULT_ITERS = 60  # fixed Newton-step budget (see module docstring)
DEFAULT_TOL = 1e-9  # relative primal/dual/complementarity target
_ETA = 0.99  # fraction-to-boundary step damping
_FLOOR = 1e-300  # positivity floor for (z, s) after a step
_RUIZ_ITERS = 6


@dataclass
class LPBatchResult:
    """Batched solver output; every leaf has leading batch axis S.

    ``primal_res`` / ``dual_res`` / ``gap`` are the final *relative*
    residuals (infinity norms over ``1 + |data|``; ``gap`` is the mean
    complementarity over ``1 + |objective|``); ``converged`` is their
    joint ``< tol`` test and ``n_iter`` counts Newton steps actually
    taken before the iterate froze.
    """

    x: np.ndarray  # (S, n) primal solution (original variables)
    fun: np.ndarray  # (S,) objective value c'x of the maximisation
    slack: np.ndarray  # (S, m_ub) slacks of the <= rows
    dual_ub: np.ndarray  # (S, m_ub) duals of <= rows (>= 0)
    dual_eq: np.ndarray  # (S, m_eq) duals of == rows (free sign)
    primal_res: np.ndarray  # (S,)
    dual_res: np.ndarray  # (S,)
    gap: np.ndarray  # (S,)
    converged: np.ndarray  # (S,) bool
    n_iter: np.ndarray  # (S,) int


def _max_step(v, dv):
    """Largest alpha in [0, 1] keeping v + alpha * dv >= 0."""
    ratios = jnp.where(dv < 0, -v / jnp.where(dv < 0, dv, -1.0), jnp.inf)
    return jnp.minimum(1.0, jnp.min(ratios))


def _ruiz(Ah, bh, ch):
    """Ruiz equilibration of the standard-form data + scalar b/c scaling."""
    m, nh = Ah.shape
    Dr = jnp.ones(m, Ah.dtype)
    Dc = jnp.ones(nh, Ah.dtype)

    def body(_, val):
        Ah, Dr, Dc = val
        rn = jnp.max(jnp.abs(Ah), axis=1)
        rs = jnp.where(rn > 0, 1.0 / jnp.sqrt(rn), 1.0)
        Ah = Ah * rs[:, None]
        cn = jnp.max(jnp.abs(Ah), axis=0)
        cs = jnp.where(cn > 0, 1.0 / jnp.sqrt(cn), 1.0)
        Ah = Ah * cs[None, :]
        return Ah, Dr * rs, Dc * cs

    Ah, Dr, Dc = lax.fori_loop(0, _RUIZ_ITERS, body, (Ah, Dr, Dc))
    bs = bh * Dr
    cs = ch * Dc
    beta = jnp.maximum(1.0, jnp.max(jnp.abs(bs)))
    gamma = jnp.maximum(1.0, jnp.max(jnp.abs(cs)))
    return Ah, bs / beta, cs / gamma, Dr, Dc, beta, gamma


def _ipm_core(c, A_ub, b_ub, A_eq, b_eq, tol, iters):
    """One LP instance: max c'x, A_ub x <= b_ub, A_eq x == b_eq, x >= 0.

    Returns a dict of device arrays (see :class:`LPBatchResult`).
    """
    f64 = jnp.float64
    c = c.astype(f64)
    n = c.shape[0]
    m_ub = A_ub.shape[0]
    m_eq = A_eq.shape[0]
    m = m_ub + m_eq
    nh = n + m_ub

    # Standard equality form over z = [x; w]:  Ah z = bh, z >= 0, and the
    # *minimisation* objective ch = -[c; 0] (duals are negated back below).
    Ah = jnp.zeros((m, nh), f64)
    Ah = Ah.at[:m_ub, :n].set(A_ub.astype(f64))
    Ah = Ah.at[:m_ub, n:].set(jnp.eye(m_ub, dtype=f64))
    Ah = Ah.at[m_ub:, :n].set(A_eq.astype(f64))
    bh = jnp.concatenate([b_ub.astype(f64), b_eq.astype(f64)])
    ch = jnp.concatenate([-c, jnp.zeros(m_ub, f64)])

    As, bs, cs, Dr, Dc, beta, gamma = _ruiz(Ah, bh, ch)
    delta = 1e-12  # static primal-dual regularisation of the normal matrix

    # Mehrotra starting point: least-squares (z, y, s) shifted positive.
    # The naive all-ones start stalls on instances whose optimum sits far
    # from the unit box (e.g. very tight / very loose SLI cap rows).
    AAt = As @ As.T
    AAt = AAt + (delta * (1.0 + jnp.trace(AAt) / m)) * jnp.eye(m, dtype=f64)
    L0 = jax.scipy.linalg.cho_factor(AAt, lower=True)
    z_ls = As.T @ jax.scipy.linalg.cho_solve(L0, bs)
    y0 = jax.scipy.linalg.cho_solve(L0, As @ cs)
    s_ls = cs - As.T @ y0
    z_sh = z_ls + jnp.maximum(-1.5 * jnp.min(z_ls), 0.0) + 1e-2
    s_sh = s_ls + jnp.maximum(-1.5 * jnp.min(s_ls), 0.0) + 1e-2
    dot = jnp.dot(z_sh, s_sh)
    z0 = z_sh + 0.5 * dot / jnp.sum(s_sh)
    s0 = s_sh + 0.5 * dot / jnp.sum(z_sh)

    def residuals(z, y, s):
        """Relative residuals on the ORIGINAL (unscaled, max-form) data."""
        z_f = Dc * beta * z
        s_f = (gamma / Dc) * s
        y_f = Dr * gamma * y
        pr = (jnp.max(jnp.abs(bh - Ah @ z_f))
              / (1.0 + jnp.max(jnp.abs(bh))))
        dr = (jnp.max(jnp.abs(ch - Ah.T @ y_f - s_f))
              / (1.0 + jnp.max(jnp.abs(ch))))
        gp = (jnp.dot(z_f, s_f) / nh) / (1.0 + jnp.abs(jnp.dot(ch, z_f)))
        return pr, dr, gp

    reg = 1e-10  # primal-dual regularisation of the augmented system

    def body(_, state):
        z, y, s, done, it = state
        r_p = bs - As @ z
        r_d = cs - As.T @ y - s
        mu = jnp.dot(z, s) / nh
        pr, dr, gp = residuals(z, y, s)
        done = done | ((pr < tol) & (dr < tol) & (gp < tol))

        # Regularised augmented KKT system (quasi-definite; LU-solved).
        # Normal equations A D A' square the conditioning and break down
        # on degenerate optimal faces (d = z/s spans ~1e16 there); the
        # augmented form stays solvable to float64 accuracy.
        K = jnp.zeros((nh + m, nh + m), f64)
        K = K.at[:nh, :nh].set(jnp.diag(-s / z - reg))
        K = K.at[:nh, nh:].set(As.T)
        K = K.at[nh:, :nh].set(As)
        K = K.at[nh:, nh:].set(reg * jnp.eye(m, dtype=f64))
        LU = jax.scipy.linalg.lu_factor(K)

        def direction(tau):
            rhs = jnp.concatenate([r_d - (tau - z * s) / z, r_p])
            sol = jax.scipy.linalg.lu_solve(LU, rhs)
            dz = sol[:nh]
            dy = sol[nh:]
            ds = (tau - z * s - s * dz) / z
            return dz, dy, ds

        # Mehrotra: affine predictor -> centring parameter -> corrector.
        dz_a, dy_a, ds_a = direction(jnp.zeros_like(z))
        a_p = _max_step(z, dz_a)
        a_d = _max_step(s, ds_a)
        mu_aff = jnp.dot(z + a_p * dz_a, s + a_d * ds_a) / nh
        sigma = jnp.clip((mu_aff / jnp.maximum(mu, _FLOOR)) ** 3, 0.0, 1.0)
        dz, dy, ds = direction(sigma * mu - dz_a * ds_a)
        a_p = jnp.minimum(1.0, _ETA * _max_step(z, dz))
        a_d = jnp.minimum(1.0, _ETA * _max_step(s, ds))

        # Frozen-once-converged: jnp.where (not arithmetic masking) so a
        # post-convergence NaN direction can never leak into the iterate.
        z = jnp.where(done, z, jnp.maximum(z + a_p * dz, _FLOOR))
        s = jnp.where(done, s, jnp.maximum(s + a_d * ds, _FLOOR))
        y = jnp.where(done, y, y + a_d * dy)
        it = it + jnp.where(done, 0, 1)
        return z, y, s, done, it

    state0 = (z0, y0, s0, jnp.bool_(False), jnp.int32(0))
    z, y, s, _, it = lax.fori_loop(0, iters, body, state0)

    # Undo the scaling; final diagnostics on the ORIGINAL (max-form) data.
    z_full = Dc * beta * z
    y_min = Dr * gamma * y
    x = z_full[:n]
    slack = z_full[n:]
    y_max = -y_min
    fun = jnp.dot(c, x)
    pr, dr, gp = residuals(z, y, s)
    return {
        "x": x,
        "fun": fun,
        "slack": slack,
        "dual_ub": jnp.maximum(y_max[:m_ub], 0.0),
        "dual_eq": y_max[m_ub:],
        "primal_res": pr,
        "dual_res": dr,
        "gap": gp,
        "converged": (pr < tol) & (dr < tol) & (gp < tol),
        "n_iter": it,
    }


@partial(jax.jit, static_argnames=("iters",))
def _ipm_batch(c, A_ub, b_ub, A_eq, b_eq, tol, iters):
    return jax.vmap(
        lambda cc, G, h, A, b: _ipm_core(cc, G, h, A, b, tol, iters)
    )(c, A_ub, b_ub, A_eq, b_eq)


def _as_batch(a, shape, name):
    out = np.asarray(a, dtype=np.float64)
    if out.shape != shape:
        raise ValueError(f"{name}: expected shape {shape}, got {out.shape}")
    return out


def solve_lp_batch(
    c: np.ndarray,
    A_ub: np.ndarray = None,
    b_ub: np.ndarray = None,
    A_eq: np.ndarray = None,
    b_eq: np.ndarray = None,
    *,
    iters: int = DEFAULT_ITERS,
    tol: float = DEFAULT_TOL,
) -> LPBatchResult:
    """Solve a batch of ``max c'x s.t. A_ub x <= b_ub, A_eq x == b_eq,
    x >= 0`` instances in one jitted, vmapped interior-point run.

    ``c`` is (S, n); constraint blocks are (S, m, n) / (S, m) with the
    same (m, n) across the batch (pad degenerate instances; values may
    vary freely).  ``None`` blocks mean zero rows.  Returns a
    :class:`LPBatchResult` of host numpy arrays.
    """
    c = np.atleast_2d(np.asarray(c, dtype=np.float64))
    S, n = c.shape
    if A_ub is None:
        A_ub = np.zeros((S, 0, n))
        b_ub = np.zeros((S, 0))
    if A_eq is None:
        A_eq = np.zeros((S, 0, n))
        b_eq = np.zeros((S, 0))
    A_ub = np.asarray(A_ub, dtype=np.float64)
    m_ub = A_ub.shape[1]
    m_eq = np.asarray(A_eq).shape[1]
    A_ub = _as_batch(A_ub, (S, m_ub, n), "A_ub")
    b_ub = _as_batch(b_ub, (S, m_ub), "b_ub")
    A_eq = _as_batch(A_eq, (S, m_eq, n), "A_eq")
    b_eq = _as_batch(b_eq, (S, m_eq), "b_eq")
    with enable_x64():
        out = _ipm_batch(jnp.asarray(c), jnp.asarray(A_ub),
                         jnp.asarray(b_ub), jnp.asarray(A_eq),
                         jnp.asarray(b_eq), float(tol), int(iters))
        out = {k: np.asarray(v) for k, v in out.items()}
    return LPBatchResult(**out)


def linprog_max_jax(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None, *,
                    iters: int = DEFAULT_ITERS,
                    tol: float = DEFAULT_TOL) -> LPBatchResult:
    """Single-instance convenience wrapper (batch axis of 1, squeezed).

    Same problem form and result fields as
    :func:`repro.core.lp.linprog_max`; use the oracle when you need
    exact vertex solutions or a basis, use this when you need the jitted
    fixed-iteration path (see ``docs/PLANNING.md`` for the decision
    table).
    """
    c = np.asarray(c, dtype=np.float64)

    def up(a, rows=False):
        if a is None:
            return None
        a = np.asarray(a, dtype=np.float64)
        return a[None] if rows else np.atleast_2d(a)[None]

    res = solve_lp_batch(c[None], up(A_ub), up(b_ub, rows=True),
                         up(A_eq), up(b_eq, rows=True),
                         iters=iters, tol=tol)
    return LPBatchResult(**{k: v[0] for k, v in res.__dict__.items()})
