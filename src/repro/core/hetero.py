"""Server heterogeneity: GPU classes, fleets, and KV-transfer costs.

The paper's cluster is homogeneous -- n identical servers sharing one
:class:`~repro.core.types.ServicePrimitives`.  Production fleets mix GPU
generations and pay a real KV-cache handoff cost when a prefill finishes
on one server and its decode continues elsewhere (the DistServe-style
disaggregated pattern).  This module adds the declarative layer:

* :class:`ServerClass` -- one GPU class: an architecture from the
  :mod:`repro.configs` registry whose :class:`ServicePrimitives` are
  resolved through the calibration pipeline (roofline backend, tiny
  grid), a time-scale factor, and a link model (``link_gbps`` +
  ``kv_bytes_per_token``) that prices the KV handoff in seconds per
  prompt token.
* ``SERVER_CLASSES`` -- the named registry (``register_server_class`` /
  ``get_server_class`` / ``list_server_classes``), cross-checked against
  docs/HETEROGENEITY.md by ``tools/check_docs.py``.
* :class:`FleetSpec` -- a concrete fleet: (class, count) pairs plus a
  global ``xfer_scale`` knob; produces the per-server parameter arrays
  the engines consume and the ``(weight, prim, kv_xfer)`` triples the
  heterogeneous planning LP consumes
  (:func:`repro.core.planning_batch.solve_hetero_batch`).
* Class-aware routing: :func:`class_aware_policies` projects a
  :class:`~repro.core.planning_batch.HeteroPlanSolution` onto per-class
  server pools, each running the paper's homogeneous gate-and-route;
  :func:`blind_primitives` builds the fleet-average primitives a
  class-blind operator would plan with.

See docs/HETEROGENEITY.md for the model and the transfer-cost math.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .planning_batch import HeteroPlanSolution, solve_hetero_plan
from .types import Pricing, ServicePrimitives

__all__ = [
    "ServerClass",
    "SERVER_CLASSES",
    "register_server_class",
    "get_server_class",
    "list_server_classes",
    "resolve_class_primitives",
    "FleetSpec",
    "blind_primitives",
    "class_aware_policies",
    "plan_fleet",
]


@dataclass(frozen=True)
class ServerClass:
    """One GPU class in a heterogeneous fleet.

    ``speed`` is a TIME multiplier (engine_sim straggler convention:
    1.0 nominal, > 1 slower) applied to the resolved iteration-time
    surfaces.  ``link_gbps`` and ``kv_bytes_per_token`` price the
    prefill->decode KV handoff: a finishing prefill of P prompt tokens
    additionally occupies its server for ``kv_sec_per_token * P``
    seconds while the cache ships over the link.  Either set ``arch``
    (primitives resolved via the calibration pipeline) or pass explicit
    ``prim`` / ``b_s`` overrides (the ``paper-a100`` class does this so
    a one-class fleet degenerates bitwise to the homogeneous defaults).
    """

    name: str
    arch: Optional[str] = None  # repro.configs registry key
    speed: float = 1.0  # iteration-time multiplier (>1 = slower GPU)
    link_gbps: float = 200.0  # KV handoff link bandwidth (Gbit/s)
    kv_bytes_per_token: float = 131072.0  # KV-cache bytes per prompt token
    prim: Optional[ServicePrimitives] = None  # explicit override
    b_s: Optional[float] = None  # explicit solo KV slope override (s/token)

    def __post_init__(self) -> None:
        if (self.arch is None) == (self.prim is None):
            raise ValueError(
                f"server class {self.name!r}: set exactly one of arch= "
                f"(calibration-resolved) or prim= (explicit)")
        if self.speed <= 0 or self.link_gbps <= 0:
            raise ValueError(
                f"server class {self.name!r}: speed and link_gbps must be "
                f"positive")
        if self.kv_bytes_per_token < 0:
            raise ValueError(
                f"server class {self.name!r}: kv_bytes_per_token must be "
                f"nonnegative")

    @property
    def kv_sec_per_token(self) -> float:
        """KV handoff seconds per prompt token = bytes/token over link B/W."""
        return self.kv_bytes_per_token / (self.link_gbps * 1e9 / 8.0)


#: Named registry -- docs/HETEROGENEITY.md must mention every entry and
#: ``tools/check_docs.py`` enforces both directions.
SERVER_CLASSES: dict = {}


def register_server_class(sc: ServerClass) -> ServerClass:
    if sc.name in SERVER_CLASSES:
        raise ValueError(f"server class {sc.name!r} already registered")
    SERVER_CLASSES[sc.name] = sc
    return sc


def get_server_class(name: str) -> ServerClass:
    try:
        return SERVER_CLASSES[name]
    except KeyError:
        raise KeyError(
            f"unknown server class {name!r}; registered: "
            f"{sorted(SERVER_CLASSES)}") from None


def list_server_classes() -> list:
    return sorted(SERVER_CLASSES)


# The paper's homogeneous calibration as a degenerate class: explicit
# default primitives (no calibration round-trip), nominal speed, and the
# engine_sim default solo KV slope -- a one-class paper-a100 fleet with
# xfer_scale=0 reproduces the homogeneous engines bitwise.
register_server_class(ServerClass(
    name="paper-a100", prim=ServicePrimitives(), b_s=1.08e-7,
    link_gbps=200.0, kv_bytes_per_token=131072.0))
# Calibration-resolved generations: the A-class is the nominal datum,
# the H-class trades ~2x faster iterations for the same link, and the
# L-class is an older, slower part behind a thinner link (where KV
# handoff hurts most).
register_server_class(ServerClass(
    name="a100-cal", arch="gemma2-2b", speed=1.0,
    link_gbps=200.0, kv_bytes_per_token=131072.0))
register_server_class(ServerClass(
    name="h100-cal", arch="gemma2-2b", speed=0.5,
    link_gbps=400.0, kv_bytes_per_token=131072.0))
register_server_class(ServerClass(
    name="l4-cal", arch="qwen2-0.5b", speed=2.5,
    link_gbps=50.0, kv_bytes_per_token=65536.0))


_CALIB_CACHE: dict = {}


def _calibrated(arch: str):
    """Calibration artifact for ``arch`` (roofline backend, tiny grid,
    reduced config -- the deterministic analytic surface), cached."""
    if arch not in _CALIB_CACHE:
        from repro.calibration.grid import CalibrationGrid
        from repro.calibration.run import calibrate

        _CALIB_CACHE[arch] = calibrate(
            arch, grid=CalibrationGrid.tiny(), backend="roofline",
            reduced=True)
    return _CALIB_CACHE[arch]


def resolve_class_primitives(sc: ServerClass, *, batch_cap: int = 16,
                             chunk: int = 256) -> tuple:
    """``(ServicePrimitives, b_s)`` for one class, speed-scaled.

    ``batch_cap`` / ``chunk`` are fleet-uniform (the engines' pointer
    tables and ring sizes assume one B and one chunk); classes differ in
    their time surfaces only.  ``speed`` multiplies every time constant:
    ``alpha * s``, ``beta * s``, ``tau_solo * s`` (i.e. ``gamma / s``),
    ``b_s * s``.
    """
    if sc.prim is not None:
        base, b_s = sc.prim, (1.08e-7 if sc.b_s is None else sc.b_s)
        alpha, beta, gamma = base.alpha, base.beta, base.gamma
    else:
        art = _calibrated(sc.arch)
        alpha, beta, gamma = art.alpha, art.beta, 1.0 / art.a_s
        b_s = art.b_s
    s = float(sc.speed)
    prim = ServicePrimitives(alpha=alpha * s, beta=beta * s,
                             gamma=gamma / s, batch_cap=batch_cap,
                             chunk=chunk)
    return prim, float(b_s) * s


@dataclass(frozen=True)
class FleetSpec:
    """A concrete heterogeneous fleet: (class, count) pairs.

    ``xfer_scale`` multiplies every class's ``kv_sec_per_token`` --
    0 turns the KV handoff charge off entirely (the engines' hot paths
    then stay bitwise identical to the homogeneous build), 1 is the
    physical link model, > 1 sweeps degraded interconnects.  Servers are
    assigned to classes in contiguous blocks (class 0 owns servers
    ``0..counts[0]-1``, etc.).
    """

    classes: tuple  # tuple[ServerClass, ...]
    counts: tuple  # tuple[int, ...]
    xfer_scale: float = 1.0

    def __post_init__(self) -> None:
        if len(self.classes) == 0 or len(self.classes) != len(self.counts):
            raise ValueError("FleetSpec needs matching non-empty "
                             "classes/counts")
        if any(int(c) <= 0 for c in self.counts):
            raise ValueError("FleetSpec counts must be positive")
        if self.xfer_scale < 0:
            raise ValueError("xfer_scale must be nonnegative")
        object.__setattr__(self, "classes", tuple(self.classes))
        object.__setattr__(self, "counts",
                           tuple(int(c) for c in self.counts))

    @classmethod
    def of(cls, spec: Sequence[tuple], xfer_scale: float = 1.0
           ) -> "FleetSpec":
        """From ``[(class_name_or_ServerClass, count), ...]``."""
        classes = tuple(get_server_class(s) if isinstance(s, str) else s
                        for s, _ in spec)
        return cls(classes, tuple(int(k) for _, k in spec),
                   xfer_scale=xfer_scale)

    @property
    def n(self) -> int:
        return sum(self.counts)

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    @property
    def weights(self) -> np.ndarray:
        return np.asarray(self.counts, dtype=np.float64) / self.n

    def assignment(self) -> np.ndarray:
        """(n,) int32 class index per server (contiguous blocks)."""
        return np.repeat(np.arange(len(self.classes), dtype=np.int32),
                         self.counts)

    def resolved(self, base: Optional[ServicePrimitives] = None) -> list:
        """Per-class ``(prim, b_s, kv_xfer)`` triples (fleet-uniform
        B/chunk from ``base``)."""
        base = base or ServicePrimitives()
        out = []
        for sc in self.classes:
            prim, b_s = resolve_class_primitives(
                sc, batch_cap=base.batch_cap, chunk=base.chunk)
            out.append((prim, b_s,
                        float(self.xfer_scale) * sc.kv_sec_per_token))
        return out

    def planner_fleet(self, base: Optional[ServicePrimitives] = None
                      ) -> list:
        """``(weight, prim, kv_xfer)`` triples for
        :func:`repro.core.planning_batch.solve_hetero_batch`."""
        w = self.weights
        return [(float(w[c]), prim, kv)
                for c, (prim, _, kv) in enumerate(self.resolved(base))]

    def server_params(self, base: Optional[ServicePrimitives] = None
                      ) -> dict:
        """Per-server (n,) float64 parameter arrays for the engines:
        ``alpha``, ``beta``, ``tau_solo``, ``b_s``, ``kv_xfer``, plus
        the (n,) int32 ``cls`` assignment."""
        res = self.resolved(base)
        idx = self.assignment()
        pick = lambda vals: np.asarray(vals, dtype=np.float64)[idx]  # noqa: E731
        return {
            "cls": idx,
            "alpha": pick([p.alpha for p, _, _ in res]),
            "beta": pick([p.beta for p, _, _ in res]),
            "tau_solo": pick([p.tau_solo for p, _, _ in res]),
            "b_s": pick([b for _, b, _ in res]),
            "kv_xfer": pick([k for _, _, k in res]),
        }


def blind_primitives(fleet: FleetSpec,
                     base: Optional[ServicePrimitives] = None) -> tuple:
    """``(ServicePrimitives, b_s, kv_xfer)`` a class-blind operator sees.

    Fleet-share-weighted averages of the TIME surfaces (``alpha``,
    ``beta``, ``tau_solo``, ``b_s``, ``kv_xfer``) -- what a single
    calibration run against a mixed fleet would fit.  The blind baseline
    plans the homogeneous Eq. 40 LP with these and runs ONE
    gate-and-route over the whole mixed fleet.
    """
    base = base or ServicePrimitives()
    res = fleet.resolved(base)
    w = fleet.weights
    avg = lambda vals: float(np.dot(w, np.asarray(vals)))  # noqa: E731
    prim = ServicePrimitives(
        alpha=avg([p.alpha for p, _, _ in res]),
        beta=avg([p.beta for p, _, _ in res]),
        gamma=1.0 / avg([p.tau_solo for p, _, _ in res]),
        batch_cap=base.batch_cap, chunk=base.chunk)
    return prim, avg([b for _, b, _ in res]), avg([k for _, _, k in res])


def plan_fleet(classes, fleet: FleetSpec,
               pricing: Optional[Pricing] = None, *,
               base: Optional[ServicePrimitives] = None,
               objective: str = "bundled") -> HeteroPlanSolution:
    """Heterogeneous fluid plan for ``fleet`` (single LP solve)."""
    return solve_hetero_plan(classes, fleet.planner_fleet(base), pricing,
                             objective=objective)


def class_aware_policies(hplan: HeteroPlanSolution) -> list:
    """Per-pool gate-and-route policies from a heterogeneous plan.

    Pool ``c`` (the fleet's class-c servers) runs the paper's
    homogeneous gate-and-route instantiated from the plan's class-c
    projection (:meth:`HeteroPlanSolution.pool_plan`); arrivals are
    split across pools with :meth:`HeteroPlanSolution.split_probs`.
    """
    from .policies import gate_and_route

    return [gate_and_route(hplan.pool_plan(c),
                           name=f"gate_and_route_pool{c}")
            for c in range(hplan.n_server_classes)]
