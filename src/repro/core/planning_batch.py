"""Batched assembly + solve of the steady-state planning LPs (Eqs. 40/42).

Stacks the planning constraint blocks of MANY instances -- workload-class
configurations, pricing points, patience (theta) values, capacity scales,
SLI caps -- into (S, m, n) tensors and solves them in ONE jitted/vmapped
interior-point run (:func:`repro.core.lp_jax.solve_lp_batch`).  This is
the planner's analogue of ``ctmc_jax``/``engine_jax``: the serial
:func:`repro.core.planning.solve_plan` simplex stays the semantics
oracle, and every layer that used to loop Python LP solves (sweep grids,
closed-loop hindsight plans, SLI cap sweeps, controller replans) batches
through here instead.

Block layout per instance (identical to :mod:`repro.core.planning`):

    columns  [x(I) | ym(I) | ys(I) | qp(I) | qd(I) | aux(penalty)]
    ub rows  [3 capacity | fairness caps | penalty pairs | TPOT]
    eq rows  [I prefill flow balance | I decode flow balance | I q_d pin]

Instances with fewer classes than the batch maximum are padded with a
negligible filler class (``lam = PAD_LAM``, ``theta = 1``) whose
occupancy/revenue contribution is below the solver tolerance; results
are sliced back to each instance's true class count, and pairwise SLI
rows that would reference a filler class are neutralised per instance
(the filler must never act as an absolute fairness anchor).

SLI support matches :class:`repro.core.planning.SLISpec`, with the cap
fields (``prefill_fairness_cap`` / ``decode_fairness_cap`` /
``tpot_cap``) additionally accepting length-S arrays -- what
``bench_sli_pareto`` uses to solve a whole Pareto frontier in one call.
Penalty weights and ``pin_zero_decode_queue`` are static per batch
(they change the block *structure*, not just values).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .lp_jax import DEFAULT_ITERS, DEFAULT_TOL, solve_lp_batch
from .planning import PlanSolution, SLISpec, validate_planning_instance
from .types import Pricing, ServicePrimitives, WorkloadClass, rate_arrays

__all__ = ["PlanBatch", "solve_plan_batch", "solve_plan_jax", "PAD_LAM"]

PAD_LAM = 1e-9  # filler-class arrival rate (keeps padded rows nonsingular)


def _cap_array(v, S: int, name: str) -> Optional[np.ndarray]:
    if v is None:
        return None
    out = np.broadcast_to(np.asarray(v, dtype=np.float64), (S,)).copy()
    if not np.all(np.isfinite(out)):
        raise ValueError(f"{name}: caps must be finite, got {out}")
    return out


@dataclass
class PlanBatch:
    """Stacked plan solutions + solver diagnostics for S instances."""

    objective: str
    instances: tuple  # per-instance class tuples (unpadded)
    prims: tuple
    pricings: tuple
    x: np.ndarray  # (S, I_max)
    ym: np.ndarray
    ys: np.ndarray
    qp: np.ndarray
    qd: np.ndarray
    revenue_rate: np.ndarray  # (S,) revenue part (penalty added back)
    sli_value: np.ndarray  # (S,) penalty part (0 without penalties)
    dual_capacity: np.ndarray  # (S, 3) duals of the capacity rows
    primal_res: np.ndarray  # (S,) solver diagnostics (relative)
    dual_res: np.ndarray
    gap: np.ndarray
    converged: np.ndarray  # (S,) bool
    n_iter: np.ndarray  # (S,)
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.instances)

    def solution(self, k: int) -> PlanSolution:
        """Instance ``k`` as a :class:`PlanSolution` (padding sliced off);
        drop-in for the policy constructors, ``lp`` left ``None``."""
        I = len(self.instances[k])
        return PlanSolution(
            classes=self.instances[k],
            prim=self.prims[k],
            pricing=self.pricings[k],
            objective=self.objective,
            x=self.x[k, :I].copy(),
            ym=self.ym[k, :I].copy(),
            ys=self.ys[k, :I].copy(),
            qp=self.qp[k, :I].copy(),
            qd=self.qd[k, :I].copy(),
            revenue_rate=float(self.revenue_rate[k]),
            sli_value=float(self.sli_value[k]),
            lp=None,
            dual_capacity=self.dual_capacity[k].copy(),
        )

    def solutions(self) -> list:
        return [self.solution(k) for k in range(len(self))]

    def require_converged(self, label: str = "planning batch") -> "PlanBatch":
        """Raise a diagnostic LPInfeasible unless every instance converged.

        The IPM cannot raise from inside ``jit``, so infeasible/unbounded
        instances surface as ``converged == False``; every entry point
        that hands plans to a *policy* (``solve_plan_jax``, the
        controller replan paths, scenario plan batching, cache prewarm)
        must funnel through this so a garbage plan is never published --
        matching the simplex oracle's eager LPInfeasible.
        """
        from .lp import LPInfeasible

        if bool(np.all(self.converged)):
            return self
        bad = np.nonzero(~np.asarray(self.converged, dtype=bool))[0]
        detail = ", ".join(
            f"[{k}] primal={self.primal_res[k]:.2e} "
            f"dual={self.dual_res[k]:.2e} gap={self.gap[k]:.2e}"
            for k in bad[:4])
        raise LPInfeasible(
            f"{label} ({self.objective}): {bad.size}/{len(self)} instances "
            f"did not converge within the fixed iteration budget "
            f"({detail}{', ...' if bad.size > 4 else ''}); the instance is "
            f"likely infeasible or unbounded -- the serial solve_plan "
            f"oracle raises eagerly on the same input")


def _pad_instances(instances) -> tuple:
    """Equalise class counts with a negligible filler class."""
    I_max = max(len(cl) for cl in instances)
    filler = WorkloadClass("__pad__", prompt_len=1.0, decode_len=1.0,
                           arrival_rate=PAD_LAM, patience=1.0)
    return tuple(tuple(cl) + (filler,) * (I_max - len(cl))
                 for cl in instances), I_max


def _stack_arrays(padded, prims, capacity) -> dict:
    """(S, I) parameter tensors from the padded instances."""
    arrs = [rate_arrays(cl, prim) for cl, prim in zip(padded, prims)]
    out = {k: np.stack([a[k] for a in arrs]) for k in arrs[0]}
    if capacity is not None:
        for k in ("mu_p", "mu_m", "mu_s"):
            out[k] = out[k] * capacity[:, None]
    return out


def _assemble(arr, prim_B, prim_tau, prim_gamma, prim_chunk, cp, cd,
              objective: str, sli: Optional[SLISpec], I_per):
    """Stacked (c, A_ub, b_ub, A_eq, b_eq) planning tensors.

    ``arr`` holds (S, I) arrays; the prim/pricing arguments are (S,)
    arrays and ``I_per`` the per-instance TRUE class counts.  Row/column
    order mirrors :mod:`repro.core.planning` exactly (capacity rows
    first, so ``dual_ub[:, :3]`` are the capacity shadow prices there
    too).  Pairwise SLI rows touching a padded class are neutralised
    per instance (zero row, slack rhs) -- a filler class's x ~ 0 would
    otherwise turn ``x_i - x_pad <= cap`` into an absolute cap that the
    unpadded LP does not have.
    """
    S, I = arr["lam"].shape
    I_per = np.asarray(I_per, dtype=int)
    ix, iym, iys, iqp, iqd = (np.arange(I), I + np.arange(I),
                              2 * I + np.arange(I), 3 * I + np.arange(I),
                              4 * I + np.arange(I))
    n_base = 5 * I

    pen_p = sli is not None and np.any(sli.prefill_fairness_penalty > 0)
    pen_d = sli is not None and np.any(sli.decode_fairness_penalty > 0)
    col_tp = n_base if pen_p else None
    col_td = n_base + int(pen_p) if pen_d else None
    n_cols = n_base + int(pen_p) + int(pen_d)

    pairs = [(i, j) for i in range(I) for j in range(I) if i != j]

    A_ub, b_ub = [], []

    def ub_row(cols, vals, rhs, real=None):
        """One <= row pattern; ``vals`` entries broadcast to (S,).

        ``real`` masks the row OFF (zero coefficients, rhs 1) for
        instances where it references a padded class.
        """
        row = np.zeros((S, n_cols))
        rhs = np.broadcast_to(np.asarray(rhs, dtype=np.float64), (S,)).copy()
        for c, v in zip(cols, vals):
            row[:, c] = np.broadcast_to(v, (S,))
        if real is not None:
            row[~real, :] = 0.0
            rhs[~real] = 1.0  # 0 <= 1: trivially slack
        A_ub.append(row)
        b_ub.append(rhs)

    B = prim_B
    ub_row(ix, [1.0] * I, 1.0)  # prefill capacity
    row = np.zeros((S, n_cols))
    row[:, iym] = 1.0
    row[:, ix] = -(B - 1.0)[:, None]
    A_ub.append(row)
    b_ub.append(np.zeros(S))  # mixed decode capacity
    row = np.zeros((S, n_cols))
    row[:, iys] = 1.0
    row[:, ix] = B[:, None]
    A_ub.append(row)
    b_ub.append(B.copy())  # solo decode capacity

    cap_p = _cap_array(sli.prefill_fairness_cap, S,
                       "prefill_fairness_cap") if sli else None
    cap_d = _cap_array(sli.decode_fairness_cap, S,
                       "decode_fairness_cap") if sli else None
    cap_t = _cap_array(sli.tpot_cap, S, "tpot_cap") if sli else None
    pair_real = {(i, j): (I_per > max(i, j)) for i, j in pairs}
    if cap_p is not None:
        for i, j in pairs:
            ub_row([ix[i], ix[j]], [1.0, -1.0], cap_p, real=pair_real[i, j])
    if cap_d is not None:
        for i, j in pairs:
            ub_row([iys[i], iys[j]], [1.0, -1.0], cap_d,
                   real=pair_real[i, j])
    for col, block, on in ((col_tp, ix, pen_p), (col_td, iys, pen_d)):
        if not on:
            continue
        for i, j in pairs:
            ub_row([block[i], block[j], col], [1.0, -1.0, -1.0], 0.0,
                   real=pair_real[i, j])
    if cap_t is not None:
        # TPOT cap (47), cross-multiplied; coefficient on every x column.
        coef = ((prim_tau * (B - 1.0) - B / prim_gamma)
                - cap_t * ((B - 1.0) - B))
        row = np.zeros((S, n_cols))
        row[:, ix] = coef[:, None]
        A_ub.append(row)
        b_ub.append(cap_t * B - B / prim_gamma)

    eq_rows, b_eq = [], []
    for i in range(I):
        row = np.zeros((S, n_cols))
        row[:, ix[i]] = arr["mu_p"][:, i]
        row[:, iqp[i]] = arr["theta"][:, i]
        eq_rows.append(row)
        b_eq.append(arr["lam"][:, i])  # prefill flow balance
    for i in range(I):
        row = np.zeros((S, n_cols))
        row[:, ix[i]] = arr["mu_p"][:, i]
        row[:, iqd[i]] = -arr["theta"][:, i]
        row[:, iym[i]] = -arr["mu_m"][:, i]
        row[:, iys[i]] = -arr["mu_s"][:, i]
        eq_rows.append(row)
        b_eq.append(np.zeros(S))  # decode flow balance
    if sli is not None and sli.pin_zero_decode_queue:
        for i in range(I):
            row = np.zeros((S, n_cols))
            row[:, iqd[i]] = 1.0
            eq_rows.append(row)
            b_eq.append(np.zeros(S))

    c = np.zeros((S, n_cols))
    if objective == "bundled":
        w = cp[:, None] * arr["P"] + cd[:, None] * arr["D"]  # Eq. (21)
        c[:, iym] = w * arr["mu_m"]
        c[:, iys] = w * arr["mu_s"]
    elif objective == "separate":
        c[:, ix] = (cp * prim_chunk / prim_tau)[:, None]
        c[:, iym] = (cd / prim_tau)[:, None]
        c[:, iys] = (cd * prim_gamma)[:, None]
    else:
        raise ValueError(objective)
    pen = np.zeros((S, n_cols))
    if pen_p:
        pen[:, col_tp] = np.broadcast_to(sli.prefill_fairness_penalty, (S,))
    if pen_d:
        pen[:, col_td] = np.broadcast_to(sli.decode_fairness_penalty, (S,))
    c = c - pen

    return (c, np.stack(A_ub, axis=1), np.stack(b_ub, axis=1),
            np.stack(eq_rows, axis=1), np.stack(b_eq, axis=1), pen)


def solve_plan_batch(
    instances: Sequence[Sequence[WorkloadClass]],
    prim: Optional[ServicePrimitives] = None,
    pricing: Optional[Pricing] = None,
    *,
    objective: str = "bundled",
    sli: Optional[SLISpec] = None,
    prims: Optional[Sequence[ServicePrimitives]] = None,
    pricings: Optional[Sequence[Pricing]] = None,
    capacity=None,
    iters: int = DEFAULT_ITERS,
    tol: float = DEFAULT_TOL,
) -> PlanBatch:
    """Solve the planning LP for every instance in ONE vmapped IPM run.

    ``instances`` is a sequence of workload-class sequences (class counts
    may differ; padding is internal).  ``prims`` / ``pricings`` override
    the shared ``prim`` / ``pricing`` per instance; ``capacity`` is an
    optional length-S uniform service-rate scale.  Degenerate instances
    (empty, zero traffic, nonpositive capacity) raise the same
    diagnostic :class:`repro.core.lp.LPInfeasible` as the serial oracle.
    """
    instances = [tuple(cl) for cl in instances]
    S = len(instances)
    if S == 0:
        raise ValueError("solve_plan_batch needs at least one instance")
    prims = tuple(prims) if prims is not None else (
        (prim or ServicePrimitives(),) * S)
    pricings = tuple(pricings) if pricings is not None else (
        (pricing or Pricing(),) * S)
    if len(prims) != S or len(pricings) != S:
        raise ValueError("prims/pricings must match the instance count")
    capacity = (np.broadcast_to(np.asarray(capacity, dtype=np.float64),
                                (S,)).copy()
                if capacity is not None else None)
    for k, cl in enumerate(instances):
        validate_planning_instance(
            cl, 1.0 if capacity is None else float(capacity[k]),
            label=f"planning LP batch[{k}] ({objective})")

    padded, I_max = _pad_instances(instances)
    arr = _stack_arrays(padded, prims, capacity)
    to_f = lambda vals: np.array(vals, dtype=np.float64)  # noqa: E731
    c, A_ub, b_ub, A_eq, b_eq, pen = _assemble(
        arr,
        to_f([p.batch_cap for p in prims]),
        to_f([p.tau_mix for p in prims]),
        to_f([p.gamma for p in prims]),
        to_f([p.chunk for p in prims]),
        to_f([p.c_p for p in pricings]),
        to_f([p.c_d for p in pricings]),
        objective, sli, [len(cl) for cl in instances])

    res = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, iters=iters, tol=tol)
    sol_pen = np.einsum("sj,sj->s", pen, res.x)
    blk = lambda j: res.x[:, j * I_max:(j + 1) * I_max]  # noqa: E731
    return PlanBatch(
        objective=objective,
        instances=tuple(instances),
        prims=prims,
        pricings=pricings,
        x=blk(0), ym=blk(1), ys=blk(2), qp=blk(3), qd=blk(4),
        revenue_rate=res.fun + sol_pen,
        sli_value=sol_pen,
        dual_capacity=res.dual_ub[:, :3],
        primal_res=res.primal_res,
        dual_res=res.dual_res,
        gap=res.gap,
        converged=res.converged,
        n_iter=res.n_iter,
        meta={"iters": int(iters), "tol": float(tol), "I_max": int(I_max),
              "n_ub": int(A_ub.shape[1]), "n_eq": int(A_eq.shape[1])},
    )


def solve_plan_jax(classes, prim=None, pricing=None, objective="bundled",
                   sli: Optional[SLISpec] = None, capacity: float = 1.0,
                   iters: int = DEFAULT_ITERS,
                   tol: float = DEFAULT_TOL) -> PlanSolution:
    """Single-instance planning solve on the jitted fixed-iteration path.

    Call-compatible with :func:`repro.core.planning.solve_plan`,
    including raising :class:`repro.core.lp.LPInfeasible` when the
    instance does not admit a converged plan; repeated same-shape solves
    (controller replan epochs) reuse one compiled kernel instead of
    re-running the Python simplex.
    """
    pb = solve_plan_batch(
        [tuple(classes)], prim, pricing, objective=objective, sli=sli,
        capacity=None if capacity == 1.0 else [capacity],
        iters=iters, tol=tol)
    return pb.require_converged("solve_plan_jax").solution(0)
