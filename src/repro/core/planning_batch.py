"""Batched assembly + solve of the steady-state planning LPs (Eqs. 40/42).

Stacks the planning constraint blocks of MANY instances -- workload-class
configurations, pricing points, patience (theta) values, capacity scales,
SLI caps -- into (S, m, n) tensors and solves them in ONE jitted/vmapped
interior-point run (:func:`repro.core.lp_jax.solve_lp_batch`).  This is
the planner's analogue of ``ctmc_jax``/``engine_jax``: the serial
:func:`repro.core.planning.solve_plan` simplex stays the semantics
oracle, and every layer that used to loop Python LP solves (sweep grids,
closed-loop hindsight plans, SLI cap sweeps, controller replans) batches
through here instead.

Block layout per instance (identical to :mod:`repro.core.planning`):

    columns  [x(I) | ym(I) | ys(I) | qp(I) | qd(I) | aux(penalty)]
    ub rows  [3 capacity | fairness caps | penalty pairs | TPOT]
    eq rows  [I prefill flow balance | I decode flow balance | I q_d pin]

Instances with fewer classes than the batch maximum are padded with a
negligible filler class (``lam = PAD_LAM``, ``theta = 1``) whose
occupancy/revenue contribution is below the solver tolerance; results
are sliced back to each instance's true class count, and pairwise SLI
rows that would reference a filler class are neutralised per instance
(the filler must never act as an absolute fairness anchor).

SLI support matches :class:`repro.core.planning.SLISpec`, with the cap
fields (``prefill_fairness_cap`` / ``decode_fairness_cap`` /
``tpot_cap``) additionally accepting length-S arrays -- what
``bench_sli_pareto`` uses to solve a whole Pareto frontier in one call.
Penalty weights and ``pin_zero_decode_queue`` are static per batch
(they change the block *structure*, not just values).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .lp_jax import DEFAULT_ITERS, DEFAULT_TOL, solve_lp_batch
from .planning import PlanSolution, SLISpec, validate_planning_instance
from .types import Pricing, ServicePrimitives, WorkloadClass, rate_arrays

__all__ = ["PlanBatch", "solve_plan_batch", "solve_plan_jax", "PAD_LAM",
           "HeteroPlanBatch", "HeteroPlanSolution", "solve_hetero_batch",
           "solve_hetero_plan"]

PAD_LAM = 1e-9  # filler-class arrival rate (keeps padded rows nonsingular)


def _cap_array(v, S: int, name: str) -> Optional[np.ndarray]:
    if v is None:
        return None
    out = np.broadcast_to(np.asarray(v, dtype=np.float64), (S,)).copy()
    if not np.all(np.isfinite(out)):
        raise ValueError(f"{name}: caps must be finite, got {out}")
    return out


@dataclass
class PlanBatch:
    """Stacked plan solutions + solver diagnostics for S instances."""

    objective: str
    instances: tuple  # per-instance class tuples (unpadded)
    prims: tuple
    pricings: tuple
    x: np.ndarray  # (S, I_max)
    ym: np.ndarray
    ys: np.ndarray
    qp: np.ndarray
    qd: np.ndarray
    revenue_rate: np.ndarray  # (S,) revenue part (penalty added back)
    sli_value: np.ndarray  # (S,) penalty part (0 without penalties)
    dual_capacity: np.ndarray  # (S, 3) duals of the capacity rows
    primal_res: np.ndarray  # (S,) solver diagnostics (relative)
    dual_res: np.ndarray
    gap: np.ndarray
    converged: np.ndarray  # (S,) bool
    n_iter: np.ndarray  # (S,)
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.instances)

    def solution(self, k: int) -> PlanSolution:
        """Instance ``k`` as a :class:`PlanSolution` (padding sliced off);
        drop-in for the policy constructors, ``lp`` left ``None``."""
        I = len(self.instances[k])
        return PlanSolution(
            classes=self.instances[k],
            prim=self.prims[k],
            pricing=self.pricings[k],
            objective=self.objective,
            x=self.x[k, :I].copy(),
            ym=self.ym[k, :I].copy(),
            ys=self.ys[k, :I].copy(),
            qp=self.qp[k, :I].copy(),
            qd=self.qd[k, :I].copy(),
            revenue_rate=float(self.revenue_rate[k]),
            sli_value=float(self.sli_value[k]),
            lp=None,
            dual_capacity=self.dual_capacity[k].copy(),
        )

    def solutions(self) -> list:
        return [self.solution(k) for k in range(len(self))]

    def require_converged(self, label: str = "planning batch") -> "PlanBatch":
        """Raise a diagnostic LPInfeasible unless every instance converged.

        The IPM cannot raise from inside ``jit``, so infeasible/unbounded
        instances surface as ``converged == False``; every entry point
        that hands plans to a *policy* (``solve_plan_jax``, the
        controller replan paths, scenario plan batching, cache prewarm)
        must funnel through this so a garbage plan is never published --
        matching the simplex oracle's eager LPInfeasible.
        """
        from .lp import LPInfeasible

        if bool(np.all(self.converged)):
            return self
        bad = np.nonzero(~np.asarray(self.converged, dtype=bool))[0]
        detail = ", ".join(
            f"[{k}] primal={self.primal_res[k]:.2e} "
            f"dual={self.dual_res[k]:.2e} gap={self.gap[k]:.2e}"
            for k in bad[:4])
        raise LPInfeasible(
            f"{label} ({self.objective}): {bad.size}/{len(self)} instances "
            f"did not converge within the fixed iteration budget "
            f"({detail}{', ...' if bad.size > 4 else ''}); the instance is "
            f"likely infeasible or unbounded -- the serial solve_plan "
            f"oracle raises eagerly on the same input")


def _pad_instances(instances) -> tuple:
    """Equalise class counts with a negligible filler class."""
    I_max = max(len(cl) for cl in instances)
    filler = WorkloadClass("__pad__", prompt_len=1.0, decode_len=1.0,
                           arrival_rate=PAD_LAM, patience=1.0)
    return tuple(tuple(cl) + (filler,) * (I_max - len(cl))
                 for cl in instances), I_max


def _stack_arrays(padded, prims, capacity) -> dict:
    """(S, I) parameter tensors from the padded instances."""
    arrs = [rate_arrays(cl, prim) for cl, prim in zip(padded, prims)]
    out = {k: np.stack([a[k] for a in arrs]) for k in arrs[0]}
    if capacity is not None:
        for k in ("mu_p", "mu_m", "mu_s"):
            out[k] = out[k] * capacity[:, None]
    return out


def _assemble(arr, prim_B, prim_tau, prim_gamma, prim_chunk, cp, cd,
              objective: str, sli: Optional[SLISpec], I_per):
    """Stacked (c, A_ub, b_ub, A_eq, b_eq) planning tensors.

    ``arr`` holds (S, I) arrays; the prim/pricing arguments are (S,)
    arrays and ``I_per`` the per-instance TRUE class counts.  Row/column
    order mirrors :mod:`repro.core.planning` exactly (capacity rows
    first, so ``dual_ub[:, :3]`` are the capacity shadow prices there
    too).  Pairwise SLI rows touching a padded class are neutralised
    per instance (zero row, slack rhs) -- a filler class's x ~ 0 would
    otherwise turn ``x_i - x_pad <= cap`` into an absolute cap that the
    unpadded LP does not have.
    """
    S, I = arr["lam"].shape
    I_per = np.asarray(I_per, dtype=int)
    ix, iym, iys, iqp, iqd = (np.arange(I), I + np.arange(I),
                              2 * I + np.arange(I), 3 * I + np.arange(I),
                              4 * I + np.arange(I))
    n_base = 5 * I

    pen_p = sli is not None and np.any(sli.prefill_fairness_penalty > 0)
    pen_d = sli is not None and np.any(sli.decode_fairness_penalty > 0)
    col_tp = n_base if pen_p else None
    col_td = n_base + int(pen_p) if pen_d else None
    n_cols = n_base + int(pen_p) + int(pen_d)

    pairs = [(i, j) for i in range(I) for j in range(I) if i != j]

    A_ub, b_ub = [], []

    def ub_row(cols, vals, rhs, real=None):
        """One <= row pattern; ``vals`` entries broadcast to (S,).

        ``real`` masks the row OFF (zero coefficients, rhs 1) for
        instances where it references a padded class.
        """
        row = np.zeros((S, n_cols))
        rhs = np.broadcast_to(np.asarray(rhs, dtype=np.float64), (S,)).copy()
        for c, v in zip(cols, vals):
            row[:, c] = np.broadcast_to(v, (S,))
        if real is not None:
            row[~real, :] = 0.0
            rhs[~real] = 1.0  # 0 <= 1: trivially slack
        A_ub.append(row)
        b_ub.append(rhs)

    B = prim_B
    ub_row(ix, [1.0] * I, 1.0)  # prefill capacity
    row = np.zeros((S, n_cols))
    row[:, iym] = 1.0
    row[:, ix] = -(B - 1.0)[:, None]
    A_ub.append(row)
    b_ub.append(np.zeros(S))  # mixed decode capacity
    row = np.zeros((S, n_cols))
    row[:, iys] = 1.0
    row[:, ix] = B[:, None]
    A_ub.append(row)
    b_ub.append(B.copy())  # solo decode capacity

    cap_p = _cap_array(sli.prefill_fairness_cap, S,
                       "prefill_fairness_cap") if sli else None
    cap_d = _cap_array(sli.decode_fairness_cap, S,
                       "decode_fairness_cap") if sli else None
    cap_t = _cap_array(sli.tpot_cap, S, "tpot_cap") if sli else None
    pair_real = {(i, j): (I_per > max(i, j)) for i, j in pairs}
    if cap_p is not None:
        for i, j in pairs:
            ub_row([ix[i], ix[j]], [1.0, -1.0], cap_p, real=pair_real[i, j])
    if cap_d is not None:
        for i, j in pairs:
            ub_row([iys[i], iys[j]], [1.0, -1.0], cap_d,
                   real=pair_real[i, j])
    for col, block, on in ((col_tp, ix, pen_p), (col_td, iys, pen_d)):
        if not on:
            continue
        for i, j in pairs:
            ub_row([block[i], block[j], col], [1.0, -1.0, -1.0], 0.0,
                   real=pair_real[i, j])
    if cap_t is not None:
        # TPOT cap (47), cross-multiplied; coefficient on every x column.
        coef = ((prim_tau * (B - 1.0) - B / prim_gamma)
                - cap_t * ((B - 1.0) - B))
        row = np.zeros((S, n_cols))
        row[:, ix] = coef[:, None]
        A_ub.append(row)
        b_ub.append(cap_t * B - B / prim_gamma)

    eq_rows, b_eq = [], []
    for i in range(I):
        row = np.zeros((S, n_cols))
        row[:, ix[i]] = arr["mu_p"][:, i]
        row[:, iqp[i]] = arr["theta"][:, i]
        eq_rows.append(row)
        b_eq.append(arr["lam"][:, i])  # prefill flow balance
    for i in range(I):
        row = np.zeros((S, n_cols))
        row[:, ix[i]] = arr["mu_p"][:, i]
        row[:, iqd[i]] = -arr["theta"][:, i]
        row[:, iym[i]] = -arr["mu_m"][:, i]
        row[:, iys[i]] = -arr["mu_s"][:, i]
        eq_rows.append(row)
        b_eq.append(np.zeros(S))  # decode flow balance
    if sli is not None and sli.pin_zero_decode_queue:
        for i in range(I):
            row = np.zeros((S, n_cols))
            row[:, iqd[i]] = 1.0
            eq_rows.append(row)
            b_eq.append(np.zeros(S))

    c = np.zeros((S, n_cols))
    if objective == "bundled":
        w = cp[:, None] * arr["P"] + cd[:, None] * arr["D"]  # Eq. (21)
        c[:, iym] = w * arr["mu_m"]
        c[:, iys] = w * arr["mu_s"]
    elif objective == "separate":
        c[:, ix] = (cp * prim_chunk / prim_tau)[:, None]
        c[:, iym] = (cd / prim_tau)[:, None]
        c[:, iys] = (cd * prim_gamma)[:, None]
    else:
        raise ValueError(objective)
    pen = np.zeros((S, n_cols))
    if pen_p:
        pen[:, col_tp] = np.broadcast_to(sli.prefill_fairness_penalty, (S,))
    if pen_d:
        pen[:, col_td] = np.broadcast_to(sli.decode_fairness_penalty, (S,))
    c = c - pen

    return (c, np.stack(A_ub, axis=1), np.stack(b_ub, axis=1),
            np.stack(eq_rows, axis=1), np.stack(b_eq, axis=1), pen)


def solve_plan_batch(
    instances: Sequence[Sequence[WorkloadClass]],
    prim: Optional[ServicePrimitives] = None,
    pricing: Optional[Pricing] = None,
    *,
    objective: str = "bundled",
    sli: Optional[SLISpec] = None,
    prims: Optional[Sequence[ServicePrimitives]] = None,
    pricings: Optional[Sequence[Pricing]] = None,
    capacity=None,
    iters: int = DEFAULT_ITERS,
    tol: float = DEFAULT_TOL,
) -> PlanBatch:
    """Solve the planning LP for every instance in ONE vmapped IPM run.

    ``instances`` is a sequence of workload-class sequences (class counts
    may differ; padding is internal).  ``prims`` / ``pricings`` override
    the shared ``prim`` / ``pricing`` per instance; ``capacity`` is an
    optional length-S uniform service-rate scale.  Degenerate instances
    (empty, zero traffic, nonpositive capacity) raise the same
    diagnostic :class:`repro.core.lp.LPInfeasible` as the serial oracle.
    """
    instances = [tuple(cl) for cl in instances]
    S = len(instances)
    if S == 0:
        raise ValueError("solve_plan_batch needs at least one instance")
    prims = tuple(prims) if prims is not None else (
        (prim or ServicePrimitives(),) * S)
    pricings = tuple(pricings) if pricings is not None else (
        (pricing or Pricing(),) * S)
    if len(prims) != S or len(pricings) != S:
        raise ValueError("prims/pricings must match the instance count")
    capacity = (np.broadcast_to(np.asarray(capacity, dtype=np.float64),
                                (S,)).copy()
                if capacity is not None else None)
    for k, cl in enumerate(instances):
        validate_planning_instance(
            cl, 1.0 if capacity is None else float(capacity[k]),
            label=f"planning LP batch[{k}] ({objective})")

    padded, I_max = _pad_instances(instances)
    arr = _stack_arrays(padded, prims, capacity)
    to_f = lambda vals: np.array(vals, dtype=np.float64)  # noqa: E731
    c, A_ub, b_ub, A_eq, b_eq, pen = _assemble(
        arr,
        to_f([p.batch_cap for p in prims]),
        to_f([p.tau_mix for p in prims]),
        to_f([p.gamma for p in prims]),
        to_f([p.chunk for p in prims]),
        to_f([p.c_p for p in pricings]),
        to_f([p.c_d for p in pricings]),
        objective, sli, [len(cl) for cl in instances])

    res = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, iters=iters, tol=tol)
    sol_pen = np.einsum("sj,sj->s", pen, res.x)
    blk = lambda j: res.x[:, j * I_max:(j + 1) * I_max]  # noqa: E731
    return PlanBatch(
        objective=objective,
        instances=tuple(instances),
        prims=prims,
        pricings=pricings,
        x=blk(0), ym=blk(1), ys=blk(2), qp=blk(3), qd=blk(4),
        revenue_rate=res.fun + sol_pen,
        sli_value=sol_pen,
        dual_capacity=res.dual_ub[:, :3],
        primal_res=res.primal_res,
        dual_res=res.dual_res,
        gap=res.gap,
        converged=res.converged,
        n_iter=res.n_iter,
        meta={"iters": int(iters), "tol": float(tol), "I_max": int(I_max),
              "n_ub": int(A_ub.shape[1]), "n_eq": int(A_eq.shape[1])},
    )


def solve_plan_jax(classes, prim=None, pricing=None, objective="bundled",
                   sli: Optional[SLISpec] = None, capacity: float = 1.0,
                   iters: int = DEFAULT_ITERS,
                   tol: float = DEFAULT_TOL) -> PlanSolution:
    """Single-instance planning solve on the jitted fixed-iteration path.

    Call-compatible with :func:`repro.core.planning.solve_plan`,
    including raising :class:`repro.core.lp.LPInfeasible` when the
    instance does not admit a converged plan; repeated same-shape solves
    (controller replan epochs) reuse one compiled kernel instead of
    re-running the Python simplex.
    """
    pb = solve_plan_batch(
        [tuple(classes)], prim, pricing, objective=objective, sli=sli,
        capacity=None if capacity == 1.0 else [capacity],
        iters=iters, tol=tol)
    return pb.require_converged("solve_plan_jax").solution(0)


# ---------------------------------------------------------------------------
# Heterogeneous fleets: class-indexed capacity row groups
# ---------------------------------------------------------------------------
#
# Per-instance column layout (C server classes, I workload classes):
#
#     [x(C*I) | ym(C*I) | ys(C*I) | qp(I) | qd(I)]      x[c,i] at c*I + i
#
#     ub rows  [class-0 capacity triple | class-1 triple | ... ]  (3C rows)
#     eq rows  [I prefill flow balance | I decode flow balance]
#
# Each class keeps its OWN capacity triple (sum_i x[c,i] <= 1 etc.) --
# servers of different GPU classes cannot trade occupancy -- while the
# flow-balance rows couple the classes through fleet shares w_c = n_c/n:
# a class-c server contributes w_c of the fleet-average per-server rate.
# With C = 1 (w = 1) the tensors reduce bitwise to the homogeneous
# Eq. 40/42 assembly above.  See docs/HETEROGENEITY.md.


@dataclass
class HeteroPlanSolution:
    """Heterogeneous fluid plan: per-(class c, workload i) occupancies."""

    classes: tuple
    prims: tuple  # per-server-class ServicePrimitives
    weights: np.ndarray  # (C,) fleet shares n_c / n
    kv_xfers: np.ndarray  # (C,) KV transfer seconds per prompt token
    pricing: Pricing
    objective: str  # "bundled" | "separate"
    x: np.ndarray  # (C, I)
    ym: np.ndarray  # (C, I)
    ys: np.ndarray  # (C, I)
    qp: np.ndarray  # (I,) shared fluid queues (per fleet-average server)
    qd: np.ndarray
    revenue_rate: float  # fleet-average per-server R*
    dual_capacity: np.ndarray = None  # (C, 3) capacity shadow prices

    @property
    def n_server_classes(self) -> int:
        return len(self.prims)

    def rate_tensors(self) -> dict:
        """(C, I) mu tensors (transfer-adjusted mu_p), plus lam/theta/P/D."""
        arrs = [rate_arrays(self.classes, p, kv_xfer=float(k))
                for p, k in zip(self.prims, self.kv_xfers)]
        out = {k: np.stack([a[k] for a in arrs]) for k in
               ("mu_p", "mu_m", "mu_s")}
        out.update({k: arrs[0][k] for k in ("lam", "theta", "P", "D")})
        return out

    def split_probs(self) -> np.ndarray:
        """(C, I) routing split: class-i arrivals go to pool c w.p. p_ci.

        Proportional to each pool's planned prefill throughput
        ``w_c mu_p[c,i] x[c,i]`` -- the fluid-optimal split, since any
        other split starves one pool's planned occupancy.  Workload
        classes the plan rejects entirely (zero column) fall back to a
        fleet-share split so the gate still sees them arrive.
        """
        arr = self.rate_tensors()
        num = self.weights[:, None] * arr["mu_p"] * self.x
        den = num.sum(axis=0, keepdims=True)
        fallback = np.broadcast_to(self.weights[:, None], num.shape)
        return np.where(den > 0, num / np.maximum(den, 1e-300), fallback)

    def pool_plan(self, c: int) -> PlanSolution:
        """Class-c pool projected to a homogeneous :class:`PlanSolution`.

        Per-pool-server occupancy targets are ``x[c]`` directly; the
        shared fluid queues are split by the pool's routing share and
        rescaled from per-fleet-server to per-pool-server units
        (``p_ci / w_c``).  Feed this to ``gate_and_route`` to get the
        class-aware policy for the pool's ``n_c`` servers.
        """
        w_c = float(self.weights[c])
        p_c = self.split_probs()[c]
        arr = self.rate_tensors()
        wi = (self.pricing.c_p * arr["P"] + self.pricing.c_d * arr["D"])
        if self.objective == "bundled":
            rev = float(np.sum(wi * (arr["mu_m"][c] * self.ym[c]
                                     + arr["mu_s"][c] * self.ys[c])))
        else:
            rev = float(np.sum(
                self.pricing.c_p * arr["P"] * arr["mu_p"][c] * self.x[c]
                + self.pricing.c_d * arr["D"] * (arr["mu_m"][c] * self.ym[c]
                                                 + arr["mu_s"][c]
                                                 * self.ys[c])))
        return PlanSolution(
            classes=self.classes,
            prim=self.prims[c],
            pricing=self.pricing,
            objective=self.objective,
            x=self.x[c].copy(),
            ym=self.ym[c].copy(),
            ys=self.ys[c].copy(),
            qp=self.qp * p_c / w_c,
            qd=self.qd * p_c / w_c,
            revenue_rate=rev,
            sli_value=0.0,
            lp=None,
            dual_capacity=(None if self.dual_capacity is None
                           else self.dual_capacity[c].copy()),
        )


@dataclass
class HeteroPlanBatch:
    """Stacked heterogeneous plans + solver diagnostics for S instances."""

    objective: str
    instances: tuple  # per-instance class tuples (unpadded)
    fleets: tuple  # per-instance tuples of (weight, prim, kv_xfer)
    pricings: tuple
    x: np.ndarray  # (S, C, I_max)
    ym: np.ndarray
    ys: np.ndarray
    qp: np.ndarray  # (S, I_max)
    qd: np.ndarray
    revenue_rate: np.ndarray  # (S,)
    dual_capacity: np.ndarray  # (S, C, 3)
    primal_res: np.ndarray
    dual_res: np.ndarray
    gap: np.ndarray
    converged: np.ndarray
    n_iter: np.ndarray
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.instances)

    def solution(self, k: int) -> HeteroPlanSolution:
        I = len(self.instances[k])
        fl = self.fleets[k]
        return HeteroPlanSolution(
            classes=self.instances[k],
            prims=tuple(p for _, p, _ in fl),
            weights=np.array([w for w, _, _ in fl], dtype=np.float64),
            kv_xfers=np.array([x for _, _, x in fl], dtype=np.float64),
            pricing=self.pricings[k],
            objective=self.objective,
            x=self.x[k, :, :I].copy(),
            ym=self.ym[k, :, :I].copy(),
            ys=self.ys[k, :, :I].copy(),
            qp=self.qp[k, :I].copy(),
            qd=self.qd[k, :I].copy(),
            revenue_rate=float(self.revenue_rate[k]),
            dual_capacity=self.dual_capacity[k].copy(),
        )

    def solutions(self) -> list:
        return [self.solution(k) for k in range(len(self))]

    def require_converged(self,
                          label: str = "hetero planning batch"
                          ) -> "HeteroPlanBatch":
        from .lp import LPInfeasible

        if bool(np.all(self.converged)):
            return self
        bad = np.nonzero(~np.asarray(self.converged, dtype=bool))[0]
        detail = ", ".join(
            f"[{k}] primal={self.primal_res[k]:.2e} "
            f"dual={self.dual_res[k]:.2e} gap={self.gap[k]:.2e}"
            for k in bad[:4])
        raise LPInfeasible(
            f"{label} ({self.objective}): {bad.size}/{len(self)} instances "
            f"did not converge within the fixed iteration budget ({detail}"
            f"{', ...' if bad.size > 4 else ''})")


def _normalize_fleet(fleet) -> tuple:
    """Validate one instance's ((weight, prim, kv_xfer), ...) triples."""
    fl = tuple((float(w), p, float(x)) for w, p, x in fleet)
    if not fl:
        raise ValueError("hetero fleet needs at least one server class")
    tot = sum(w for w, _, _ in fl)
    if not np.isfinite(tot) or tot <= 0:
        raise ValueError(f"fleet weights must sum positive, got {tot}")
    if any(w < 0 for w, _, _ in fl):
        raise ValueError("fleet weights must be nonnegative")
    if any(x < 0 or not np.isfinite(x) for _, _, x in fl):
        raise ValueError("kv_xfer must be finite and nonnegative")
    return tuple((w / tot, p, x) for w, p, x in fl)


def _assemble_hetero(arr, weights, B_c, cp, cd, objective: str):
    """Stacked hetero (c, A_ub, b_ub, A_eq, b_eq) tensors.

    ``arr["mu_*"]`` are (S, C, I); ``weights`` / ``B_c`` are (S, C);
    ``cp`` / ``cd`` are (S,).  Capacity rows come first, group-major per
    server class, so ``dual_ub[:, :3C].reshape(S, C, 3)`` are the
    per-class capacity shadow prices.
    """
    S, C, I = arr["mu_p"].shape
    CI = C * I
    x_at = lambda c: c * I + np.arange(I)  # noqa: E731
    ym_at = lambda c: CI + c * I + np.arange(I)  # noqa: E731
    ys_at = lambda c: 2 * CI + c * I + np.arange(I)  # noqa: E731
    iqp = 3 * CI + np.arange(I)
    iqd = 3 * CI + I + np.arange(I)
    n_cols = 3 * CI + 2 * I

    A_ub, b_ub = [], []
    for c in range(C):
        B = B_c[:, c]
        row = np.zeros((S, n_cols))
        row[:, x_at(c)] = 1.0
        A_ub.append(row)
        b_ub.append(np.ones(S))  # prefill capacity, class c
        row = np.zeros((S, n_cols))
        row[:, ym_at(c)] = 1.0
        row[:, x_at(c)] = -(B - 1.0)[:, None]
        A_ub.append(row)
        b_ub.append(np.zeros(S))  # mixed decode capacity, class c
        row = np.zeros((S, n_cols))
        row[:, ys_at(c)] = 1.0
        row[:, x_at(c)] = B[:, None]
        A_ub.append(row)
        b_ub.append(B.copy())  # solo decode capacity, class c

    eq_rows, b_eq = [], []
    for i in range(I):
        row = np.zeros((S, n_cols))
        for c in range(C):
            row[:, x_at(c)[i]] = weights[:, c] * arr["mu_p"][:, c, i]
        row[:, iqp[i]] = arr["theta"][:, i]
        eq_rows.append(row)
        b_eq.append(arr["lam"][:, i])  # prefill flow balance
    for i in range(I):
        row = np.zeros((S, n_cols))
        for c in range(C):
            w = weights[:, c]
            row[:, x_at(c)[i]] = w * arr["mu_p"][:, c, i]
            row[:, ym_at(c)[i]] = -w * arr["mu_m"][:, c, i]
            row[:, ys_at(c)[i]] = -w * arr["mu_s"][:, c, i]
        row[:, iqd[i]] = -arr["theta"][:, i]
        eq_rows.append(row)
        b_eq.append(np.zeros(S))  # decode flow balance

    c_obj = np.zeros((S, n_cols))
    if objective == "bundled":
        wi = cp[:, None] * arr["P"] + cd[:, None] * arr["D"]  # (S, I)
        for c in range(C):
            w = weights[:, c][:, None]
            c_obj[:, ym_at(c)] = wi * w * arr["mu_m"][:, c]
            c_obj[:, ys_at(c)] = wi * w * arr["mu_s"][:, c]
    elif objective == "separate":
        for c in range(C):
            w = weights[:, c][:, None]
            c_obj[:, x_at(c)] = (cp[:, None] * arr["P"] * w
                                 * arr["mu_p"][:, c])
            c_obj[:, ym_at(c)] = (cd[:, None] * arr["D"] * w
                                  * arr["mu_m"][:, c])
            c_obj[:, ys_at(c)] = (cd[:, None] * arr["D"] * w
                                  * arr["mu_s"][:, c])
    else:
        raise ValueError(objective)

    return (c_obj, np.stack(A_ub, axis=1), np.stack(b_ub, axis=1),
            np.stack(eq_rows, axis=1), np.stack(b_eq, axis=1))


def solve_hetero_batch(
    instances: Sequence[Sequence[WorkloadClass]],
    fleets: Sequence[Sequence[tuple]],
    pricing: Optional[Pricing] = None,
    *,
    objective: str = "bundled",
    pricings: Optional[Sequence[Pricing]] = None,
    iters: int = DEFAULT_ITERS,
    tol: float = DEFAULT_TOL,
) -> HeteroPlanBatch:
    """Batched heterogeneous planning solve (class-indexed capacity rows).

    ``fleets[s]`` is a sequence of ``(weight, prim, kv_xfer)`` triples --
    one per server class -- with weights the fleet shares ``n_c / n``
    (normalised here) and ``kv_xfer`` the KV handoff seconds per prompt
    token for that class.  All instances in one batch must share the
    same class count C.  :class:`repro.core.hetero.FleetSpec.planner_fleet`
    produces the triples from a declarative fleet spec.
    """
    instances = [tuple(cl) for cl in instances]
    S = len(instances)
    if S == 0:
        raise ValueError("solve_hetero_batch needs at least one instance")
    fleets = tuple(_normalize_fleet(fl) for fl in fleets)
    if len(fleets) != S:
        raise ValueError("fleets must match the instance count")
    C = len(fleets[0])
    if any(len(fl) != C for fl in fleets):
        raise ValueError("all instances in a hetero batch must share the "
                         "same server-class count")
    pricings = tuple(pricings) if pricings is not None else (
        (pricing or Pricing(),) * S)
    if len(pricings) != S:
        raise ValueError("pricings must match the instance count")
    for k, cl in enumerate(instances):
        validate_planning_instance(
            cl, 1.0, label=f"hetero planning batch[{k}] ({objective})")

    padded, I_max = _pad_instances(instances)
    # (S, C, I) mu tensors: one rate_arrays call per (instance, class).
    per_sc = [[rate_arrays(cl, p, kv_xfer=x) for _, p, x in fl]
              for cl, fl in zip(padded, fleets)]
    arr = {k: np.stack([np.stack([a[k] for a in row]) for row in per_sc])
           for k in ("mu_p", "mu_m", "mu_s")}
    for k in ("lam", "theta", "P", "D"):
        arr[k] = np.stack([row[0][k] for row in per_sc])
    to_f = lambda vals: np.array(vals, dtype=np.float64)  # noqa: E731
    c, A_ub, b_ub, A_eq, b_eq = _assemble_hetero(
        arr,
        to_f([[w for w, _, _ in fl] for fl in fleets]),
        to_f([[p.batch_cap for _, p, _ in fl] for fl in fleets]),
        to_f([p.c_p for p in pricings]),
        to_f([p.c_d for p in pricings]),
        objective)

    res = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, iters=iters, tol=tol)
    CI = C * I_max
    xcol = res.x[:, :CI].reshape(S, C, I_max)
    ymcol = res.x[:, CI:2 * CI].reshape(S, C, I_max)
    yscol = res.x[:, 2 * CI:3 * CI].reshape(S, C, I_max)
    return HeteroPlanBatch(
        objective=objective,
        instances=tuple(instances),
        fleets=fleets,
        pricings=pricings,
        x=xcol, ym=ymcol, ys=yscol,
        qp=res.x[:, 3 * CI:3 * CI + I_max],
        qd=res.x[:, 3 * CI + I_max:3 * CI + 2 * I_max],
        revenue_rate=res.fun,
        dual_capacity=res.dual_ub[:, :3 * C].reshape(S, C, 3),
        primal_res=res.primal_res,
        dual_res=res.dual_res,
        gap=res.gap,
        converged=res.converged,
        n_iter=res.n_iter,
        meta={"iters": int(iters), "tol": float(tol), "I_max": int(I_max),
              "C": int(C), "n_ub": int(A_ub.shape[1]),
              "n_eq": int(A_eq.shape[1])},
    )


def solve_hetero_plan(classes, fleet, pricing=None, *,
                      objective: str = "bundled",
                      iters: int = DEFAULT_ITERS,
                      tol: float = DEFAULT_TOL) -> HeteroPlanSolution:
    """Single-instance heterogeneous planning solve (raises on
    non-convergence, like :func:`solve_plan_jax`)."""
    hb = solve_hetero_batch([tuple(classes)], [tuple(fleet)], pricing,
                            objective=objective, iters=iters, tol=tol)
    return hb.require_converged("solve_hetero_plan").solution(0)
