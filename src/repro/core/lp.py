"""Self-contained dense linear-programming solver (two-phase primal simplex).

The container ships without scipy, and the paper's planning problems (Eqs. 40,
42, 49) are small (a handful of classes -> tens of variables/constraints), so a
carefully written dense tableau simplex with Bland anti-cycling is exact enough
and fully controllable.  We also return dual variables so the SLI benchmarks can
report *shadow prices* (Section 6.3) directly from the solver.

Problem form::

    maximize    c' x
    subject to  A_ub x <= b_ub
                A_eq x == b_eq
                x >= 0
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

__all__ = ["LPResult", "linprog_max", "LPInfeasible", "LPUnbounded"]


class LPInfeasible(RuntimeError):
    pass


class LPUnbounded(RuntimeError):
    pass


@dataclass
class LPResult:
    x: np.ndarray  # primal solution (original variables)
    fun: float  # optimal objective value (of the maximisation)
    slack: np.ndarray  # slacks of the <= rows
    dual_ub: np.ndarray  # duals of <= rows (>= 0)
    dual_eq: np.ndarray  # duals of == rows (free sign)
    n_iter: int = 0
    status: str = "optimal"
    basis: list = field(default_factory=list)


def _pivot(T: np.ndarray, basis: np.ndarray, row: int, col: int) -> None:
    T[row] /= T[row, col]
    piv = T[:, col].copy()
    piv[row] = 0.0
    T -= np.outer(piv, T[row])
    basis[row] = col


def _simplex(T: np.ndarray, basis: np.ndarray, n_total: int, tol: float,
             max_iter: int, bland_after: Optional[int] = None) -> int:
    """Run primal simplex on tableau T (last row = -reduced costs for max).

    Uses Dantzig rule with a Bland fallback to guarantee termination: the
    fallback engages after a run of degenerate (zero-progress) pivots,
    and unconditionally once the *total* pivot count passes
    ``bland_after`` (default ``10 * (m + n_total)``) -- Dantzig can cycle
    through degenerate bases without ever stalling on one of them, so a
    stall counter alone is not a termination proof; Bland's rule is.
    Returns the iteration count.
    """
    m = T.shape[0] - 1
    if bland_after is None:
        bland_after = 10 * (m + n_total)
    it = 0
    stall = 0
    while it < max_iter:
        it += 1
        red = T[-1, :n_total]
        use_bland = stall > 2 * (m + n_total) or it > bland_after
        if use_bland:
            cand = np.nonzero(red < -tol)[0]
            if cand.size == 0:
                return it
            col = int(cand[0])
        else:
            col = int(np.argmin(red))
            if red[col] >= -tol:
                return it
        ratios = np.full(m, np.inf)
        pos = T[:m, col] > tol
        ratios[pos] = T[:m, -1][pos] / T[:m, col][pos]
        row = int(np.argmin(ratios))
        if not np.isfinite(ratios[row]):
            raise LPUnbounded("LP is unbounded")
        if use_bland:
            best = ratios[row]
            tie = np.nonzero(np.abs(ratios - best) <= tol * (1 + abs(best)))[0]
            row = int(tie[np.argmin(basis[tie])])
        if ratios[row] <= tol:
            stall += 1
        else:
            stall = 0
        _pivot(T, basis, row, col)
    raise RuntimeError("simplex iteration limit exceeded")


def linprog_max(
    c: np.ndarray,
    A_ub: Optional[np.ndarray] = None,
    b_ub: Optional[np.ndarray] = None,
    A_eq: Optional[np.ndarray] = None,
    b_eq: Optional[np.ndarray] = None,
    tol: float = 1e-9,
    max_iter: int = 20000,
    bland_after: Optional[int] = None,
) -> LPResult:
    """Solve ``max c'x s.t. A_ub x <= b_ub, A_eq x == b_eq, x >= 0``.

    ``bland_after`` caps the number of Dantzig pivots before each phase
    permanently switches to Bland's rule (anti-cycling safety valve);
    ``None`` picks ``10 * (rows + columns)``.
    """
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    if A_ub is None:
        A_ub = np.zeros((0, n))
        b_ub = np.zeros(0)
    if A_eq is None:
        A_eq = np.zeros((0, n))
        b_eq = np.zeros(0)
    A_ub = np.atleast_2d(np.asarray(A_ub, dtype=np.float64))
    A_eq = np.atleast_2d(np.asarray(A_eq, dtype=np.float64))
    b_ub = np.asarray(b_ub, dtype=np.float64).ravel()
    b_eq = np.asarray(b_eq, dtype=np.float64).ravel()
    m_ub, m_eq = A_ub.shape[0], A_eq.shape[0]
    m = m_ub + m_eq

    # Standard form: [A_ub | I_slack ; A_eq | 0] x_aug = b, x_aug >= 0.
    A = np.zeros((m, n + m_ub))
    A[:m_ub, :n] = A_ub
    A[:m_ub, n:] = np.eye(m_ub)
    A[m_ub:, :n] = A_eq
    b = np.concatenate([b_ub, b_eq])
    # Make b >= 0 (flip rows).
    neg = b < 0
    A[neg] *= -1.0
    b[neg] *= -1.0

    n_sn = n + m_ub  # structural + slack count

    # ---- Phase 1: artificial variables on every row -----------------------
    T = np.zeros((m + 1, n_sn + m + 1))
    T[:m, :n_sn] = A
    T[:m, n_sn : n_sn + m] = np.eye(m)
    T[:m, -1] = b
    # Phase-1 objective: minimise sum of artificials == maximise -sum(a).
    T[-1, n_sn : n_sn + m] = 1.0
    # Price out the artificial basis.
    T[-1, :] -= T[:m, :].sum(axis=0)
    basis = np.arange(n_sn, n_sn + m)
    it1 = _simplex(T, basis, n_sn + m, tol, max_iter, bland_after)
    phase1 = -T[-1, -1]
    if phase1 > 1e-7 * max(1.0, np.abs(b).max()):
        raise LPInfeasible(f"phase-1 infeasibility residual {phase1:.3e}")

    # Drive any artificial still in the basis out (degenerate rows).
    for r in range(m):
        if basis[r] >= n_sn:
            cols = np.nonzero(np.abs(T[r, :n_sn]) > tol)[0]
            if cols.size:
                _pivot(T, basis, r, int(cols[0]))
            # else: redundant row, leave the zero artificial basic.

    # ---- Phase 2 -----------------------------------------------------------
    T2 = np.zeros((m + 1, n_sn + 1))
    T2[:m, :n_sn] = T[:m, :n_sn]
    T2[:m, -1] = T[:m, -1]
    c_aug = np.zeros(n_sn)
    c_aug[:n] = c
    T2[-1, :n_sn] = -c_aug
    # Price out the current basis.
    for r in range(m):
        if basis[r] < n_sn and abs(T2[-1, basis[r]]) > 0:
            T2[-1, :] -= T2[-1, basis[r]] * T2[r, :]
    # Forbid re-entry of artificials by construction (they're not in T2).
    basis2 = basis.copy()
    it2 = _simplex(T2, basis2, n_sn, tol, max_iter, bland_after)

    x_aug = np.zeros(n_sn)
    for r in range(m):
        if basis2[r] < n_sn:
            x_aug[basis2[r]] = T2[r, -1]
    x = x_aug[:n]
    fun = float(c @ x)

    # Duals: solve y' B = c_B' from the final basis (artificial leftovers from
    # redundant rows contribute unit columns e_r with zero cost).
    B_cols = [int(j) for j in basis2]
    Bmat = np.zeros((m, m))
    cB = np.zeros(m)
    for k, j in enumerate(B_cols):
        if j < n_sn:
            Bmat[:, k] = A[:, j]
            cB[k] = c_aug[j]
        else:
            Bmat[j - n_sn, k] = 1.0  # artificial column e_{j-n_sn}
    try:
        y = np.linalg.solve(Bmat.T, cB)
    except np.linalg.LinAlgError:
        y, *_ = np.linalg.lstsq(Bmat.T, cB, rcond=None)
    # Undo the row sign flips applied to make b >= 0.
    y = np.where(neg, -y, y)
    dual_eq = y[m_ub:].copy()
    dual_ub = np.maximum(y[:m_ub], 0.0)

    slack = b_ub - A_ub @ x if m_ub else np.zeros(0)
    return LPResult(
        x=x,
        fun=fun,
        slack=slack,
        dual_ub=dual_ub,
        dual_eq=dual_eq,
        n_iter=it1 + it2,
        basis=B_cols,
    )
