"""Fluid model of Section 3, integrated with jax.lax.scan.

Integrates the fluid dynamics (24)-(32) under the gate-and-route policy
family (instantaneous occupancy-tracking prefill gate + work-conserving
solo-first or randomized decode router), and exposes the steady state for
validation against the planning LP (Theorem 2 / Theorem 4 property tests)
and against the CTMC simulator (Theorem 1).

The integrator is split into :func:`fluid_params` (per-instance parameter
pytree) and :func:`integrate_fluid_core` (a pure jittable scan over that
pytree) so batch drivers can ``jax.vmap`` one compiled trajectory evaluator
across a whole sweep grid (see :mod:`repro.sweep.fluid_batch`).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .planning import PlanSolution
from .types import (Pricing, ServicePrimitives, WorkloadClass, rate_arrays,
                    resolve_primitives)

__all__ = [
    "FluidTrajectory",
    "fluid_params",
    "integrate_fluid_core",
    "fluid_final_state",
    "integrate_fluid",
    "fluid_steady_state",
]


@dataclass
class FluidTrajectory:
    t: np.ndarray
    qp: np.ndarray  # (T, I)
    x: np.ndarray
    qd: np.ndarray
    ym: np.ndarray
    ys: np.ndarray
    revenue_rate: np.ndarray  # (T,) instantaneous bundled reward rate

    def final(self) -> dict:
        return {
            "qp": self.qp[-1],
            "x": self.x[-1],
            "qd": self.qd[-1],
            "ym": self.ym[-1],
            "ys": self.ys[-1],
        }


def fluid_params(
    classes: Sequence[WorkloadClass],
    prim: ServicePrimitives,
    pricing: Pricing,
    plan: PlanSolution,
    randomized_router: bool = False,
) -> dict:
    """Parameter pytree of the fluid ODE for one problem instance.

    Every leaf is a jnp array, so instances with the same class count stack
    along a leading axis for :func:`jax.vmap` (``p_s`` is all-ones when the
    solo-first router is in force; the branch itself is selected by the
    static ``randomized`` flag of :func:`integrate_fluid_core`).
    """
    prim = resolve_primitives(prim)
    arr = rate_arrays(classes, prim)
    B = float(prim.batch_cap)
    x_star = jnp.asarray(plan.x)
    X_star = jnp.sum(x_star)  # static partition: fraction of mixed servers
    p_s = (
        jnp.asarray(plan.solo_probs())
        if randomized_router
        else jnp.ones_like(x_star)
    )
    return {
        "lam": jnp.asarray(arr["lam"]),
        "theta": jnp.asarray(arr["theta"]),
        "mu_p": jnp.asarray(arr["mu_p"]),
        "mu_m": jnp.asarray(arr["mu_m"]),
        "mu_s": jnp.asarray(arr["mu_s"]),
        "w": jnp.asarray([pricing.bundled_reward(c) for c in classes]),
        "x_star": x_star,
        "cap_m": (B - 1.0) * X_star,
        "cap_s": B * (1.0 - X_star),
        "p_s": p_s,
    }


def _proportional_fill(q, free):
    """Move up to `free` total mass out of q, proportionally (FCFS-equiv)."""
    tot = jnp.sum(q)
    take = jnp.minimum(tot, free)
    frac = jnp.where(tot > 0, take / jnp.maximum(tot, 1e-30), 0.0)
    return q * frac


def _fluid_step(params: dict, state: tuple, dt, randomized: bool) -> tuple:
    """One Euler step of the policy fluid; returns the next state tuple."""
    lam, theta = params["lam"], params["theta"]
    mu_p, mu_m, mu_s = params["mu_p"], params["mu_m"], params["mu_s"]
    x_star = params["x_star"]
    cap_m, cap_s, p_s = params["cap_m"], params["cap_s"], params["p_s"]

    qp, x, qdm, qds, ym, ys = state
    # -- primitive flows over dt ------------------------------------------
    a = lam * dt
    bp = theta * qp * dt
    sp = mu_p * x * dt
    sdm = mu_m * ym * dt
    sds = mu_s * ys * dt
    bdm = theta * qdm * dt
    bds = theta * qds * dt

    qp = qp + a - bp
    x = x - sp
    ym = ym - sdm
    ys = ys - sds
    qdm = qdm - bdm
    qds = qds - bds

    # -- prefill gate: instantaneous pull-up to targets --------------------
    admit = jnp.minimum(qp, jnp.maximum(x_star - x, 0.0))
    x = x + admit
    qp = qp - admit

    # -- decode router ------------------------------------------------------
    if not randomized:
        # solo-first, single logical buffer (kept in the solo half)
        inflow = sp
        free_s = jnp.maximum(cap_s - jnp.sum(ys), 0.0)
        to_s = _proportional_fill(inflow, free_s)
        inflow = inflow - to_s
        ys = ys + to_s
        free_m = jnp.maximum(cap_m - jnp.sum(ym), 0.0)
        to_m = _proportional_fill(inflow, free_m)
        inflow = inflow - to_m
        ym = ym + to_m
        qds = qds + inflow
        # work-conserving buffer drain (solo first)
        free_s = jnp.maximum(cap_s - jnp.sum(ys), 0.0)
        pull = _proportional_fill(qds + qdm, free_s)
        frac = pull / jnp.maximum(qds + qdm, 1e-30)
        ys = ys + pull
        qds = qds - frac * qds
        qdm = qdm - frac * qdm
        free_m = jnp.maximum(cap_m - jnp.sum(ym), 0.0)
        pull = _proportional_fill(qds + qdm, free_m)
        frac = pull / jnp.maximum(qds + qdm, 1e-30)
        ym = ym + pull
        qds = qds - frac * qds
        qdm = qdm - frac * qdm
    else:
        # randomized router with per-pool buffers (Section 5.2 / EC.7)
        qds = qds + sp * p_s
        qdm = qdm + sp * (1.0 - p_s)
        free_s = jnp.maximum(cap_s - jnp.sum(ys), 0.0)
        to_s = _proportional_fill(qds, free_s)
        ys = ys + to_s
        qds = qds - to_s
        free_m = jnp.maximum(cap_m - jnp.sum(ym), 0.0)
        to_m = _proportional_fill(qdm, free_m)
        ym = ym + to_m
        qdm = qdm - to_m

    qp = jnp.maximum(qp, 0.0)
    qdm = jnp.maximum(qdm, 0.0)
    qds = jnp.maximum(qds, 0.0)
    return (qp, x, qdm, qds, ym, ys)


def _revenue_rate(params: dict, state: tuple):
    """Instantaneous bundled reward rate of a fluid state (Eq. 21 flow)."""
    _, _, _, _, ym, ys = state
    return jnp.sum(params["w"] * (params["mu_m"] * ym
                                  + params["mu_s"] * ys))


@partial(jax.jit, static_argnames=("n_steps", "randomized"))
def integrate_fluid_core(params: dict, state0: tuple, dt, *,
                         n_steps: int, randomized: bool):
    """Pure Euler scan of the policy fluid; vmappable over ``params``/``state0``.

    ``state0`` is the tuple ``(qp, x, qdm, qds, ym, ys)`` of per-class
    arrays; returns per-step stacked ``(qp, x, qd, ym, ys, revenue_rate)``.
    For steady-state-only callers prefer :func:`fluid_final_state`, which
    does not materialise the O(n_steps) trajectory.
    """

    def step(state, _):
        new = _fluid_step(params, state, dt, randomized)
        qp, x, qdm, qds, ym, ys = new
        return new, (qp, x, qdm + qds, ym, ys,
                     _revenue_rate(params, new))

    _, out = jax.lax.scan(step, state0, None, length=n_steps)
    return out


@partial(jax.jit, static_argnames=("n_steps", "randomized"))
def fluid_final_state(params: dict, state0: tuple, dt, *,
                      n_steps: int, randomized: bool):
    """Final fluid state + revenue rate only, O(1) memory in n_steps.

    Same dynamics as :func:`integrate_fluid_core` but the scan carries no
    per-step outputs -- the batched sweep evaluator vmaps this over whole
    grids without holding (batch, n_steps, I) trajectories live.
    """

    def step(state, _):
        return _fluid_step(params, state, dt, randomized), ()

    final, _ = jax.lax.scan(step, state0, None, length=n_steps)
    return final, _revenue_rate(params, final)


def _initial_state(I: int, x0: Optional[dict]) -> tuple:
    z = jnp.zeros(I)
    if x0 is None:
        return (z, z, z, z, z, z)
    return tuple(
        jnp.asarray(x0.get(k, np.zeros(I)), dtype=jnp.result_type(float))
        for k in ("qp", "x", "qdm", "qds", "ym", "ys")
    )


def integrate_fluid(
    classes: Sequence[WorkloadClass],
    prim: ServicePrimitives,
    pricing: Pricing,
    plan: PlanSolution,
    horizon: float,
    dt: float = 1e-3,
    randomized_router: bool = False,
    x0: Optional[dict] = None,
    record_stride: int = 100,
) -> FluidTrajectory:
    """Euler-integrate the policy fluid; returns recorded trajectory."""
    params = fluid_params(classes, prim, pricing, plan, randomized_router)
    state0 = _initial_state(len(classes), x0)
    n_steps = int(horizon / dt)
    out = integrate_fluid_core(params, state0, dt, n_steps=n_steps,
                               randomized=randomized_router)
    qp, x, qd, ym, ys, rev = (np.asarray(o) for o in out)
    idx = np.arange(0, n_steps, record_stride)
    return FluidTrajectory(
        t=(idx + 1) * dt,
        qp=qp[idx],
        x=x[idx],
        qd=qd[idx],
        ym=ym[idx],
        ys=ys[idx],
        revenue_rate=rev[idx],
    )


def fluid_steady_state(
    classes, prim, pricing, plan, horizon=400.0, dt=2e-3,
    randomized_router=False
) -> dict:
    traj = integrate_fluid(
        classes, prim, pricing, plan, horizon, dt,
        randomized_router=randomized_router, record_stride=max(1, int(horizon / dt) // 50),
    )
    return {
        "qp": traj.qp[-1],
        "x": traj.x[-1],
        "qd": traj.qd[-1],
        "ym": traj.ym[-1],
        "ys": traj.ys[-1],
        "revenue_rate": float(traj.revenue_rate[-1]),
    }
