"""Online adaptive controller (Section 6.2, Eqs. 50-51).

Estimates class-level arrival rates from a rolling window, periodically
re-solves the planning LP with a small regularising impatience parameter, and
publishes new targets (x*, q_p*, M*) to the running policy.  The controller is
engine-agnostic: the simulator/engine calls :meth:`observe_arrival` on every
arrival and :meth:`maybe_replan` at control epochs; elasticity (server
failures/joins) is handled by replanning with the current capacity ``n``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from .planning import PlanSolution, SLISpec, solve_plan
from .types import Pricing, ServicePrimitives, WorkloadClass

__all__ = ["OnlineControllerConfig", "OnlineController"]


@dataclass(frozen=True)
class OnlineControllerConfig:
    window: float = 30.0  # W (seconds)
    safety: float = 3.0  # rho >= 1
    lam_min: float = 1e-6
    eps: float = 1e-9
    replan_every: float = 10.0
    planning_theta: float = 3e-4  # regularisation theta in the planning LP
    objective: str = "bundled"
    sli: Optional[SLISpec] = None


class OnlineController:
    def __init__(
        self,
        classes: Sequence[WorkloadClass],
        prim: ServicePrimitives,
        pricing: Pricing,
        n: int,
        config: OnlineControllerConfig = OnlineControllerConfig(),
        on_replan: Optional[Callable[[PlanSolution, int], None]] = None,
    ):
        self.classes = tuple(classes)
        self.prim = prim
        self.pricing = pricing
        self.n = n
        self.cfg = config
        self.on_replan = on_replan
        self.I = len(self.classes)
        self._arrivals: list[list[float]] = [[] for _ in range(self.I)]
        self._next_replan = 0.0
        self.plan: Optional[PlanSolution] = None
        self.lam_hat = np.full(self.I, config.lam_min)
        self.replan_count = 0

    # -- observation hooks ---------------------------------------------------
    def observe_arrival(self, t: float, cls: int) -> None:
        self._arrivals[cls].append(t)

    def set_capacity(self, n: int, t: float) -> None:
        """Elastic capacity change (failure / join): replan immediately."""
        if n != self.n:
            self.n = n
            self.replan(t)

    # -- planning --------------------------------------------------------------
    def estimate_rates(self, t: float) -> np.ndarray:
        """Conservative rolling-window estimate, Eq. (50)."""
        cfg = self.cfg
        w_eff = min(cfg.window, max(t, cfg.eps))
        lo = t - cfg.window
        lam = np.empty(self.I)
        # a fully-failed cluster (n == 0, e.g. a capacity script killing
        # every server) still replans: normalize per surviving server,
        # or per single server while none survive
        denom = max(self.n, 1) * w_eff
        for i in range(self.I):
            ts = self._arrivals[i]
            # drop old events (amortised)
            k = 0
            while k < len(ts) and ts[k] < lo:
                k += 1
            if k:
                del ts[:k]
            lam[i] = max(cfg.safety * len(ts) / denom, cfg.lam_min)
        return lam

    def replan(self, t: float) -> PlanSolution:
        self.lam_hat = self.estimate_rates(t)
        classes = tuple(
            dataclasses.replace(
                c, arrival_rate=float(self.lam_hat[i]),
                patience=self.cfg.planning_theta,
            )
            for i, c in enumerate(self.classes)
        )
        self.plan = solve_plan(
            classes, self.prim, self.pricing,
            objective=self.cfg.objective, sli=self.cfg.sli,
        )
        self.replan_count += 1
        if self.on_replan is not None:
            self.on_replan(self.plan, self.plan.mixed_servers(self.n))
        return self.plan

    def maybe_replan(self, t: float) -> Optional[PlanSolution]:
        if t >= self._next_replan:
            self._next_replan = t + self.cfg.replan_every
            return self.replan(t)
        return None

    def mixed_target(self) -> int:
        """Desired number of mixed servers M*(t_k), Eq. (51)."""
        if self.plan is None:
            return self.n
        return self.plan.mixed_servers(self.n)
