"""Online adaptive controller (Section 6.2, Eqs. 50-51).

Estimates class-level arrival rates from a rolling window, periodically
re-solves the planning LP with a small regularising impatience parameter, and
publishes new targets (x*, q_p*, M*) to the running policy.  The controller is
engine-agnostic: the simulator/engine calls :meth:`observe_arrival` on every
arrival and :meth:`maybe_replan` at control epochs; elasticity (server
failures/joins) is handled by replanning with the current capacity ``n``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from .planning import PlanSolution, SLISpec, solve_plan
from .types import Pricing, ServicePrimitives, WorkloadClass

__all__ = ["OnlineControllerConfig", "OnlineController",
           "replan_controllers_batch"]

SOLVERS = ("simplex", "lp_jax")


@dataclass(frozen=True)
class OnlineControllerConfig:
    window: float = 30.0  # W (seconds)
    safety: float = 3.0  # rho >= 1
    lam_min: float = 1e-6
    eps: float = 1e-9
    replan_every: float = 10.0
    planning_theta: float = 3e-4  # regularisation theta in the planning LP
    objective: str = "bundled"
    sli: Optional[SLISpec] = None
    # "simplex" = the exact serial oracle (repro.core.lp); "lp_jax" =
    # the jitted fixed-iteration interior point (repro.core.lp_jax):
    # every same-shape replan epoch reuses one compiled kernel, which is
    # what keeps adaptive closed-loop sweeps off the Python simplex.
    solver: str = "simplex"

    def __post_init__(self) -> None:
        if self.solver not in SOLVERS:
            raise ValueError(
                f"solver {self.solver!r} not in {SOLVERS}")


class OnlineController:
    def __init__(
        self,
        classes: Sequence[WorkloadClass],
        prim: ServicePrimitives,
        pricing: Pricing,
        n: int,
        config: OnlineControllerConfig = OnlineControllerConfig(),
        on_replan: Optional[Callable[[PlanSolution, int], None]] = None,
    ):
        self.classes = tuple(classes)
        self.prim = prim
        self.pricing = pricing
        self.n = n
        self.cfg = config
        self.on_replan = on_replan
        self.I = len(self.classes)
        self._arrivals: list[list[float]] = [[] for _ in range(self.I)]
        self._next_replan = 0.0
        self.plan: Optional[PlanSolution] = None
        self.lam_hat = np.full(self.I, config.lam_min)
        self.replan_count = 0

    # -- observation hooks ---------------------------------------------------
    def observe_arrival(self, t: float, cls: int) -> None:
        self._arrivals[cls].append(t)

    def set_capacity(self, n: int, t: float) -> None:
        """Elastic capacity change (failure / join): replan immediately."""
        if n != self.n:
            self.n = n
            self.replan(t)

    # -- planning --------------------------------------------------------------
    def estimate_rates(self, t: float) -> np.ndarray:
        """Conservative rolling-window estimate, Eq. (50)."""
        cfg = self.cfg
        w_eff = min(cfg.window, max(t, cfg.eps))
        lo = t - cfg.window
        lam = np.empty(self.I)
        # a fully-failed cluster (n == 0, e.g. a capacity script killing
        # every server) still replans: normalize per surviving server,
        # or per single server while none survive
        denom = max(self.n, 1) * w_eff
        for i in range(self.I):
            ts = self._arrivals[i]
            # drop old events (amortised)
            k = 0
            while k < len(ts) and ts[k] < lo:
                k += 1
            if k:
                del ts[:k]
            lam[i] = max(cfg.safety * len(ts) / denom, cfg.lam_min)
        return lam

    def _planner_classes(self, t: float) -> tuple:
        self.lam_hat = self.estimate_rates(t)
        return tuple(
            dataclasses.replace(
                c, arrival_rate=float(self.lam_hat[i]),
                patience=self.cfg.planning_theta,
            )
            for i, c in enumerate(self.classes)
        )

    def _publish(self, plan: PlanSolution) -> PlanSolution:
        self.plan = plan
        self.replan_count += 1
        if self.on_replan is not None:
            self.on_replan(plan, plan.mixed_servers(self.n))
        return plan

    def replan(self, t: float) -> PlanSolution:
        classes = self._planner_classes(t)
        if self.cfg.solver == "lp_jax":
            from .planning_batch import solve_plan_jax

            plan = solve_plan_jax(classes, self.prim, self.pricing,
                                  objective=self.cfg.objective,
                                  sli=self.cfg.sli)
        else:
            plan = solve_plan(classes, self.prim, self.pricing,
                              objective=self.cfg.objective,
                              sli=self.cfg.sli)
        return self._publish(plan)

    def maybe_replan(self, t: float) -> Optional[PlanSolution]:
        if t >= self._next_replan:
            self._next_replan = t + self.cfg.replan_every
            return self.replan(t)
        return None

    def mixed_target(self) -> int:
        """Desired number of mixed servers M*(t_k), Eq. (51)."""
        if self.plan is None:
            return self.n
        return self.plan.mixed_servers(self.n)


def replan_controllers_batch(controllers: Sequence[OnlineController],
                             t: float) -> list:
    """Replan MANY controllers at one control epoch in a single vmapped
    interior-point solve (paired closed-loop sweeps: every scenario cell
    carries its own controller, and their epochs align by construction).

    All controllers must share objective/SLI config (one LP structure);
    each contributes its own estimated rates, primitives, pricing and
    capacity.  Publishes each plan through the normal ``on_replan`` hook
    and returns the :class:`PlanSolution` list.
    """
    from .planning_batch import solve_plan_batch

    if not controllers:
        return []
    cfg0 = controllers[0].cfg
    for c in controllers:
        if (c.cfg.objective, c.cfg.sli) != (cfg0.objective, cfg0.sli):
            raise ValueError(
                "replan_controllers_batch needs a homogeneous "
                "objective/sli across controllers (got "
                f"{(c.cfg.objective, c.cfg.sli)} vs "
                f"{(cfg0.objective, cfg0.sli)})")
    instances = [c._planner_classes(t) for c in controllers]
    pb = solve_plan_batch(
        instances,
        prims=[c.prim for c in controllers],
        pricings=[c.pricing for c in controllers],
        objective=cfg0.objective,
        sli=cfg0.sli).require_converged("replan_controllers_batch")
    plans = []
    for k, c in enumerate(controllers):
        c._next_replan = max(c._next_replan, t + c.cfg.replan_every)
        plans.append(c._publish(pb.solution(k)))
    return plans
