"""Scheduling policies: the paper's gate-and-route family and baselines.

A policy is a combination of

* a **prefill gate** -- which class to admit when a prefill slot idles
  (Section 4.1 occupancy rule; Section 5.1 priority rule; FCFS baseline),
* a **decode router** -- where completed prefills decode (Section 4.1
  solo-first; Section 5.2 randomized p_{s,i}; immediate/local baselines),
* **static planning** -- the mixed/solo partition M = ceil(n sum x_i*).

The same policy objects drive the aggregate CTMC simulator
(:mod:`repro.core.simulator`) and the per-server iteration-level engine
(:mod:`repro.serving.engine_sim`), so policy logic is written against the
minimal :class:`GateView` protocol below.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Protocol, Sequence

import numpy as np

from .planning import PlanSolution
from .types import WorkloadClass

__all__ = [
    "GateView",
    "PrefillGate",
    "OccupancyGate",
    "PriorityRatioGate",
    "FCFSGate",
    "DecodeRouterKind",
    "PolicySpec",
    "gate_and_route",
    "prioritize_and_route",
    "sli_aware_policy",
    "ablation_policy",
    "baseline_vllm",
    "baseline_sarathi",
    "baseline_distserve",
]


class GateView(Protocol):
    """What a prefill gate may observe (class-level state)."""

    def prefill_queue_len(self, i: int) -> int: ...
    def prefill_in_service(self, i: int) -> float: ...  # X_i
    def n_servers(self) -> int: ...
    def head_of_line_class(self) -> Optional[int]: ...  # oldest waiting job


class PrefillGate:
    def select(self, view: GateView, waiting: Sequence[int]) -> Optional[int]:
        raise NotImplementedError


class OccupancyGate(PrefillGate):
    """Paper Section 4.1: admit argmin_i xi_i = (X_i - n x_i*)/x_i*.

    Finite-n refinement: we evaluate the *post-admission* deviation
    xi_i = (X_i + 1 - n x_i*)/x_i* ("where would admitting one more leave
    this class?").  For classes with n*x_i* >= 1 this differs from the
    paper's rule by an O(1/x_i*) shift that vanishes relative to the
    O(sqrt(n))/x_i* fluctuations, so Theorem 2's asymptotics are untouched;
    for classes with tiny targets (n*x_i* < 1, short prefills) it prevents
    an integer-oscillation pathology where the class wins admission at
    every X_i = 0 epoch and gets over-admitted by ~P_code/P_i.

    Classes with x_i* == 0 are never admitted (their deviation is +inf);
    ties broken by largest queue deviation delta_i = Q_{p,i} - n q_{p,i}*.
    """

    def __init__(self, x_star: np.ndarray, qp_star: np.ndarray):
        self.x_star = np.asarray(x_star, dtype=float)
        self.qp_star = np.asarray(qp_star, dtype=float)

    def update_targets(self, x_star, qp_star) -> None:
        self.x_star = np.asarray(x_star, dtype=float)
        self.qp_star = np.asarray(qp_star, dtype=float)

    def select(self, view: GateView, waiting: Sequence[int]) -> Optional[int]:
        n = view.n_servers()
        best, best_key = None, None
        for i in waiting:
            if self.x_star[i] <= 1e-12:
                continue
            xi = (view.prefill_in_service(i) + 1.0
                  - n * self.x_star[i]) / self.x_star[i]
            delta = view.prefill_queue_len(i) - n * self.qp_star[i]
            key = (xi, -delta)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best


class PriorityRatioGate(PrefillGate):
    """Section 5.1: admit the waiting class with the largest D_i / P_i."""

    def __init__(self, classes: Sequence[WorkloadClass]):
        self.ratio = np.array([c.decode_len / c.prompt_len for c in classes])

    def select(self, view: GateView, waiting: Sequence[int]) -> Optional[int]:
        if not waiting:
            return None
        return max(waiting, key=lambda i: self.ratio[i])


class FCFSGate(PrefillGate):
    """Class-agnostic: admit the head-of-line job across all classes."""

    def select(self, view: GateView, waiting: Sequence[int]) -> Optional[int]:
        if not waiting:
            return None
        hol = view.head_of_line_class()
        return hol if hol is not None and hol in waiting else waiting[0]


DecodeRouterKind = str  # "solo_first" | "randomized" | "immediate" | "local_fcfs"


@dataclass
class PolicySpec:
    """Fully specifies a scheduling policy for either simulator.

    ``partition``: "static" (LP M), "none" (every server may prefill) or
    "fixed:<k>" (DistServe-style fixed split, k mixed/prefill servers).
    """

    name: str
    gate: PrefillGate
    router: DecodeRouterKind = "solo_first"
    partition: str = "static"
    plan: Optional[PlanSolution] = None
    # Randomized router targets (SLI-aware; Section 5.2 / EC.7):
    solo_prob: Optional[np.ndarray] = None  # p_{s,i}
    pool_weights_mixed: Optional[np.ndarray] = None  # varpi_{m,i}
    pool_weights_solo: Optional[np.ndarray] = None  # varpi_{s,i}
    # DistServe prefill/solo variant: prefill-only servers hand off all decodes.
    prefill_only_mixed: bool = False
    # Charging scheme used for revenue accounting ("bundled" | "separate").
    charging: str = "bundled"

    def mixed_target(self, n: int) -> int:
        if self.partition == "none":
            return n
        if self.partition.startswith("fixed:"):
            return min(n, int(self.partition.split(":")[1]))
        assert self.plan is not None, "static partition requires a plan"
        return self.plan.mixed_servers(n)

    def replace(self, **kw) -> "PolicySpec":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------


def gate_and_route(plan: PlanSolution, name: str = "gate_and_route") -> PolicySpec:
    """Occupancy-based Gate-and-Route with static planning (GG-SP, Section 4)."""
    return PolicySpec(
        name=name,
        gate=OccupancyGate(plan.x, plan.qp),
        router="solo_first",
        partition="static",
        plan=plan,
        charging="bundled",
    )


def prioritize_and_route(plan: PlanSolution,
                         name: str = "prioritize_and_route") -> PolicySpec:
    """Separate-charging Prioritize-and-Route (Section 5.1)."""
    return PolicySpec(
        name=name,
        gate=PriorityRatioGate(plan.classes),
        router="solo_first",
        partition="static",
        plan=plan,
        charging="separate",
    )


def sli_aware_policy(plan: PlanSolution, name: str = "sli_aware",
                     general: bool = False) -> PolicySpec:
    """SLI-aware Gate-and-Route (Section 5.2), randomized decode router.

    With ``general=True`` uses the EC.7 within-pool class-selection weights
    (supports plans with q_d* > 0).
    """
    arrm = plan.ym * np.array(
        [1.0 / (c.decode_len * plan.prim.tau_mix) for c in plan.classes]
    )
    arrs = plan.ys * np.array(
        [plan.prim.gamma / c.decode_len for c in plan.classes]
    )
    wm = arrm / arrm.sum() if arrm.sum() > 0 else np.ones_like(arrm) / len(arrm)
    ws = arrs / arrs.sum() if arrs.sum() > 0 else np.ones_like(arrs) / len(arrs)
    return PolicySpec(
        name=name,
        gate=OccupancyGate(plan.x, plan.qp),
        router="randomized",
        partition="static",
        plan=plan,
        solo_prob=plan.solo_probs(),
        pool_weights_mixed=wm if general else None,
        pool_weights_solo=ws if general else None,
        charging="bundled",
    )


def ablation_policy(plan: PlanSolution, which: str) -> PolicySpec:
    """EC.8.6 component ablations.

    GG-SP : full policy.           FI-WSP: FCFS gate, immediate decode, no SP.
    GI-WSP: gate, immediate, noSP. GF-WSP: gate, local FCFS router, no SP.
    FG-SP : FCFS gate, solo-first router, static planning.
    """
    table = {
        "GG-SP": dict(gate=OccupancyGate(plan.x, plan.qp), router="solo_first",
                      partition="static"),
        "FI-WSP": dict(gate=FCFSGate(), router="immediate", partition="none"),
        "GI-WSP": dict(gate=OccupancyGate(plan.x, plan.qp), router="immediate",
                       partition="none"),
        "GF-WSP": dict(gate=OccupancyGate(plan.x, plan.qp), router="local_fcfs",
                       partition="none"),
        "FG-SP": dict(gate=FCFSGate(), router="solo_first", partition="static"),
    }
    cfg = table[which]
    return PolicySpec(name=which, plan=plan, charging="bundled", **cfg)


def baseline_vllm(plan: PlanSolution) -> PolicySpec:
    """vLLM-style: prefill-first continuous batching, no split, class-agnostic."""
    return PolicySpec(
        name="vllm_style",
        gate=FCFSGate(),
        router="local_fcfs",
        partition="none",
        plan=plan,
        charging="bundled",
    )


def baseline_sarathi(plan: PlanSolution) -> PolicySpec:
    """Sarathi-style: admit when slots available, decode-first local execution."""
    return PolicySpec(
        name="sarathi_style",
        gate=FCFSGate(),
        router="immediate",
        partition="none",
        plan=plan,
        charging="bundled",
    )


def baseline_distserve(plan: PlanSolution, k: int,
                       variant: str = "mix_solo") -> PolicySpec:
    """DistServe-style best fixed split. ``variant``:

    * "mix_solo": k mixed servers (prefill+decode) vs n-k solo.
    * "prefill_solo": k prefill-only servers; all decodes go to solo group.
    """
    return PolicySpec(
        name=f"distserve_{variant}_k{k}",
        gate=FCFSGate(),
        router="solo_first",
        partition=f"fixed:{k}",
        plan=plan,
        prefill_only_mixed=(variant == "prefill_solo"),
        charging="bundled",
    )
