"""Aggregate CTMC simulator of the multiclass many-server network (Section 2.3).

This simulates the paper's stochastic model *exactly* (exponential primitives,
Poisson arrivals, Eqs. (7)-(9)) at the class-aggregate level: with a static
mixed/solo partition, per-server identities are exchangeable, so the Markov
state is (Q_p, X, Q_d(m/s), Y_m, Y_s) per class.  This is the engine behind
the large-n convergence experiments (EC.8.5) and the fluid-limit property
tests; the per-server iteration-level engine lives in
:mod:`repro.serving.engine_sim`.

Semantics notes (documented deviations = none for the policy family covered):

* Gate-and-route family only (static partition; occupancy/priority/FCFS gate;
  solo-first or randomized router).  Per-server-local baselines need the
  per-server engine.
* FCFS-across-classes buffer pulls are realised as proportional-to-queue-length
  sampling (exchangeable-order equivalence; exact in the fluid limit).
* Decodes on the mixed group run at mu_m (Lemma EC.4's convention -- in the
  targeted regime mixed servers essentially always host an active prefill).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.telemetry.probes import PyProbes, resolve_probe_spec

from .policies import PolicySpec
from .types import (Pricing, ServicePrimitives, WorkloadClass, rate_arrays,
                    resolve_primitives)

__all__ = ["CTMCResult", "CTMCSimulator"]


@dataclass
class CTMCResult:
    t_end: float
    revenue: float
    revenue_rate_per_server: float
    completions: np.ndarray
    arrivals: np.ndarray
    abandons_p: np.ndarray
    abandons_d: np.ndarray
    # time-averaged occupancies (per server, fluid scale)
    avg_x: np.ndarray
    avg_ym: np.ndarray
    avg_ys: np.ndarray
    avg_qp: np.ndarray
    avg_qd: np.ndarray
    n_events: int = 0  # transitions actually applied (excl. the final break)
    trajectory: Optional[dict] = field(default=None, repr=False)
    # extract_probes() report for telemetry-enabled runs; summary fields
    # above never depend on it (telemetry-invariance contract)
    telemetry: Optional[dict] = field(default=None, repr=False)


class _View:
    """GateView implementation over the aggregate state."""

    def __init__(self, sim: "CTMCSimulator"):
        self.sim = sim

    def prefill_queue_len(self, i: int) -> int:
        return int(self.sim.Qp[i])

    def prefill_in_service(self, i: int) -> float:
        return float(self.sim.X[i])

    def n_servers(self) -> int:
        return self.sim.n

    def head_of_line_class(self) -> Optional[int]:
        # exchangeable approximation: class proportional to queue length
        tot = self.sim.Qp.sum()
        if tot <= 0:
            return None
        p = self.sim.Qp / tot
        return int(self.sim.rng.choice(self.sim.I, p=p))


class CTMCSimulator:
    """Event-driven exact simulation of the aggregate CTMC.

    ``seed`` accepts an int, a :class:`numpy.random.SeedSequence`, or a
    :class:`numpy.random.Generator`; sweep drivers pass spawned child
    sequences so every grid cell gets a reproducible independent stream.
    One simulator can serve many replications via :meth:`reset` /
    :meth:`run_batch` without rebuilding the policy or rate arrays.
    """

    def __init__(
        self,
        classes: Sequence[WorkloadClass],
        prim: ServicePrimitives,
        pricing: Pricing,
        policy: PolicySpec,
        n: int,
        seed: int = 0,
        record_every: float = 0.0,
        telemetry=None,
    ):
        self.classes = tuple(classes)
        self.prim = prim = resolve_primitives(prim)
        self.pricing = pricing
        self.policy = policy
        self.n = int(n)
        self.arr = rate_arrays(self.classes, prim)
        self.I = len(self.classes)
        self.B = prim.batch_cap
        self.M = policy.mixed_target(self.n)
        self.record_every = record_every
        self.telemetry = resolve_probe_spec(telemetry)

        I = self.I
        self.Qp = np.zeros(I)
        self.X = np.zeros(I)
        self.Qdm = np.zeros(I)  # decode buffer routed to the mixed pool
        self.Qds = np.zeros(I)  # decode buffer routed to the solo pool
        self.Ym = np.zeros(I)
        self.Ys = np.zeros(I)

        self.w = np.array([pricing.bundled_reward(c) for c in self.classes])
        self.w_pre = np.array([pricing.prefill_reward(c) for c in self.classes])
        self.w_dec = np.array([pricing.decode_reward(c) for c in self.classes])

        self.view = _View(self)
        self.rng = np.random.default_rng(seed)
        self.reset()

    # -- replication management ------------------------------------------------
    def snapshot(self) -> dict:
        """Copy of the current Markov state (for warm-starting replications)."""
        return {
            "qp": self.Qp.copy(), "x": self.X.copy(),
            "qdm": self.Qdm.copy(), "qds": self.Qds.copy(),
            "ym": self.Ym.copy(), "ys": self.Ys.copy(),
        }

    def reset(self, rng: Optional[object] = None,
              state: Optional[dict] = None) -> "CTMCSimulator":
        """Re-zero (or warm-start) the state in place for a fresh replication.

        ``rng`` accepts an int seed, a spawned
        :class:`~numpy.random.SeedSequence`, or a ready-made
        :class:`~numpy.random.Generator` stream -- so batch drivers can
        hand each replication its own independent stream; ``None`` keeps
        the current stream. ``state`` is a :meth:`snapshot` dict; omitting
        it restarts empty. All per-class arrays are reused, not
        reallocated.
        """
        if rng is not None:
            self.rng = np.random.default_rng(rng)
        for name, key in (("Qp", "qp"), ("X", "x"), ("Qdm", "qdm"),
                          ("Qds", "qds"), ("Ym", "ym"), ("Ys", "ys")):
            arr = getattr(self, name)
            if state is not None:
                arr[:] = state[key]
            else:
                arr[:] = 0.0
        return self

    def run_batch(self, horizon: float, warmup: float = 0.0, *,
                  rngs: Sequence[object],
                  warm_start: Optional[dict] = None) -> list[CTMCResult]:
        """Run independent replications, one per RNG stream in ``rngs``.

        The simulator object (policy, rate arrays, reward vectors) is reused
        across replications; each entry of ``rngs`` seeds one replication via
        :meth:`reset`.  With ``warm_start`` (a :meth:`snapshot`, e.g. the end
        state of a pilot run) every replication starts from that state, which
        lets callers amortise one warmup across the whole batch.
        """
        out = []
        for r in rngs:
            self.reset(rng=r, state=warm_start)
            out.append(self.run(horizon, warmup=warmup))
        return out

    # -- capacity ------------------------------------------------------------
    @property
    def free_prefill_slots(self) -> int:
        return int(self.M - self.X.sum())

    @property
    def free_mixed_slots(self) -> int:
        cap = 0 if self.policy.prefill_only_mixed else (self.B - 1) * self.M
        return int(cap - self.Ym.sum())

    @property
    def free_solo_slots(self) -> int:
        return int(self.B * (self.n - self.M) - self.Ys.sum())

    # -- control hooks ---------------------------------------------------------
    def _admit_prefills(self) -> None:
        gate = self.policy.gate
        while self.free_prefill_slots > 0:
            waiting = [i for i in range(self.I) if self.Qp[i] >= 1]
            if not waiting:
                return
            i = gate.select(self.view, waiting)
            if i is None:
                return
            self.Qp[i] -= 1
            self.X[i] += 1

    def _route_decode(self, i: int) -> None:
        """A class-i job finished prefill and needs a decode slot."""
        if self.policy.router == "randomized":
            p = float(self.policy.solo_prob[i])
            if self.rng.random() <= p:
                self._enter_pool(i, solo=True)
            else:
                self._enter_pool(i, solo=False)
        else:  # solo_first (default for the aggregate engine)
            if self.free_solo_slots > 0:
                self.Ys[i] += 1
            elif self.free_mixed_slots > 0:
                self.Ym[i] += 1
            else:
                self.Qds[i] += 1  # single logical buffer kept in the solo half

    def _enter_pool(self, i: int, solo: bool) -> None:
        if solo:
            if self.free_solo_slots > 0:
                self.Ys[i] += 1
            else:
                self.Qds[i] += 1
        else:
            if self.free_mixed_slots > 0:
                self.Ym[i] += 1
            else:
                self.Qdm[i] += 1

    def _pull_buffer(self, solo: bool) -> None:
        """A decode slot freed; pull per policy from the matching buffer."""
        if self.policy.router == "randomized":
            q = self.Qds if solo else self.Qdm
            w = (
                self.policy.pool_weights_solo
                if solo
                else self.policy.pool_weights_mixed
            )
            nz = np.nonzero(q >= 1)[0]
            if nz.size == 0:
                return
            if w is None:  # plain randomized router: FCFS-equivalent pull
                p = q[nz] / q[nz].sum()
            else:  # EC.7 general policy: weights restricted to nonempty buffers
                ww = w[nz]
                if ww.sum() <= 0:
                    p = q[nz] / q[nz].sum()
                else:
                    p = ww / ww.sum()
            i = int(self.rng.choice(nz, p=p))
            q[i] -= 1
            (self.Ys if solo else self.Ym)[i] += 1
        else:
            # single logical FCFS buffer (both halves), exchangeable pull
            q = self.Qds + self.Qdm
            tot = q.sum()
            if tot <= 0:
                return
            i = int(self.rng.choice(self.I, p=q / tot))
            if self.Qds[i] >= 1:
                self.Qds[i] -= 1
            else:
                self.Qdm[i] -= 1
            (self.Ys if solo else self.Ym)[i] += 1

    def _record(self, traj: dict, t: float) -> None:
        traj["t"].append(t)
        for key, v in (("x", self.X), ("ym", self.Ym), ("ys", self.Ys),
                       ("qp", self.Qp), ("qd", self.Qdm + self.Qds)):
            traj[key].append(v.copy())

    # -- main loop -------------------------------------------------------------
    def run(self, horizon: float, warmup: float = 0.0) -> CTMCResult:
        arr = self.arr
        I = self.I
        lam_total = self.n * arr["lam"]
        revenue = 0.0
        completions = np.zeros(I)
        arrivals = np.zeros(I)
        ab_p = np.zeros(I)
        ab_d = np.zeros(I)
        # time-averaged state accumulators (measured after warmup)
        acc = {k: np.zeros(I) for k in ("x", "ym", "ys", "qp", "qd")}
        acc_t = 0.0
        traj = (
            {"t": [], "x": [], "ym": [], "ys": [], "qp": [], "qd": []}
            if self.record_every > 0
            else None
        )
        next_rec = 0.0
        n_events = 0
        probes = (PyProbes(self.telemetry,
                           horizon=horizon if horizon > 0 else 1.0,
                           n_servers=self.n, n_classes=I)
                  if self.telemetry is not None else None)

        t = 0.0
        rng = self.rng
        self._admit_prefills()
        while t < horizon:
            rates = np.concatenate(
                [
                    lam_total,  # arrivals
                    arr["mu_p"] * self.X,  # prefill completions
                    arr["mu_m"] * self.Ym,  # mixed decode completions
                    arr["mu_s"] * self.Ys,  # solo decode completions
                    arr["theta"] * self.Qp,  # prefill abandonment
                    arr["theta"] * (self.Qdm + self.Qds),  # decode abandonment
                ]
            )
            total = rates.sum()
            if total <= 0:
                break
            dt = rng.exponential(1.0 / total)
            t_new = min(t + dt, horizon)
            span = t_new - t
            if t_new > warmup:
                eff = t_new - max(t, warmup)
                acc["x"] += eff * self.X
                acc["ym"] += eff * self.Ym
                acc["ys"] += eff * self.Ys
                acc["qp"] += eff * self.Qp
                acc["qd"] += eff * (self.Qdm + self.Qds)
                acc_t += eff
            if traj is not None and t_new >= next_rec:
                # clamp the sample time to the horizon and advance next_rec
                # on the absolute record grid -- anchoring it at
                # t_new + record_every would drift the sampling comb by one
                # inter-event gap per sample (and let the final sample land
                # at an off-grid time when record_every doesn't divide the
                # horizon)
                self._record(traj, min(t_new, horizon))
                next_rec = (
                    np.floor(t_new / self.record_every) + 1.0
                ) * self.record_every
            t = t_new
            if t >= horizon:
                break

            k = int(rng.choice(rates.size, p=rates / total))
            n_events += 1
            cat, i = divmod(k, I)
            if cat == 0:  # arrival
                arrivals[i] += 1
                self.Qp[i] += 1
                self._admit_prefills()
            elif cat == 1:  # prefill completion
                self.X[i] -= 1
                if self.policy.charging == "separate" and t > warmup:
                    revenue += self.w_pre[i]
                self._route_decode(i)
                self._admit_prefills()
            elif cat == 2:  # mixed decode completion
                self.Ym[i] -= 1
                completions[i] += 1
                if t > warmup:
                    revenue += (
                        self.w_dec[i]
                        if self.policy.charging == "separate"
                        else self.w[i]
                    )
                self._pull_buffer(solo=False)
            elif cat == 3:  # solo decode completion
                self.Ys[i] -= 1
                completions[i] += 1
                if t > warmup:
                    revenue += (
                        self.w_dec[i]
                        if self.policy.charging == "separate"
                        else self.w[i]
                    )
                self._pull_buffer(solo=True)
            elif cat == 4:  # prefill abandonment
                self.Qp[i] -= 1
                ab_p[i] += 1
            else:  # decode abandonment
                if self.Qds[i] >= 1 and (
                    self.Qdm[i] < 1 or rng.random() < self.Qds[i] / (self.Qds[i] + self.Qdm[i])
                ):
                    self.Qds[i] -= 1
                else:
                    self.Qdm[i] -= 1
                ab_d[i] += 1
            if probes is not None:
                # post-event state, matching wrap_ctmc_step_probes: queue
                # = Q_p, occupancy = Y_m + Y_s, prefills in flight = X
                if cat >= 4:
                    probes.count(t, drops=1.0)
                probes.sample(
                    t, queue_depth=self.Qp,
                    decode_occupancy=float((self.Ym + self.Ys).sum()),
                    prefill_in_flight=float(self.X.sum()))

        if traj is not None and (not traj["t"] or traj["t"][-1] < t):
            # final sample at the (clamped) end time, so the trajectory
            # always closes at min(t_end, horizon)
            self._record(traj, t)
        meas = max(acc_t, 1e-12)
        return CTMCResult(
            t_end=t,
            revenue=revenue,
            revenue_rate_per_server=revenue / (self.n * meas),
            completions=completions,
            arrivals=arrivals,
            abandons_p=ab_p,
            abandons_d=ab_d,
            avg_x=acc["x"] / meas / self.n,
            avg_ym=acc["ym"] / meas / self.n,
            avg_ys=acc["ys"] / meas / self.n,
            avg_qp=acc["qp"] / meas / self.n,
            avg_qd=acc["qd"] / meas / self.n,
            n_events=n_events,
            trajectory=(
                {k: np.array(v) for k, v in traj.items()} if traj else None
            ),
            telemetry=probes.extract() if probes is not None else None,
        )
