"""Primitive types for the prefill-decode contention control framework.

These mirror the paper's Section 2 notation:

* :class:`WorkloadClass` -- a request class i with (P_i, D_i, lambda_i, theta_i).
* :class:`ServicePrimitives` -- iteration-time abstraction (alpha, beta, gamma, B, C)
  and the induced service rates mu_{p,i}, mu_{m,i}, mu_{s,i} of Eq. (4).
* :class:`Pricing` -- token prices (c_p, c_d) and the bundled reward w_i (Eq. 21).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = [
    "WorkloadClass",
    "ServicePrimitives",
    "Pricing",
    "ClassRates",
    "rates_for",
    "resolve_primitives",
    "DEFAULT_PRIMITIVES",
]


@dataclass(frozen=True)
class WorkloadClass:
    """A request class: representative prompt/decode lengths and traffic."""

    name: str
    prompt_len: float  # P_i (tokens)
    decode_len: float  # D_i (tokens)
    arrival_rate: float  # lambda_i, per *logical server* per second
    patience: float = 0.0  # theta_i >= 0 (exponential abandonment rate)

    def __post_init__(self) -> None:
        if self.prompt_len <= 0 or self.decode_len <= 0:
            raise ValueError(f"class {self.name}: token lengths must be positive")
        if self.arrival_rate < 0 or self.patience < 0:
            raise ValueError(f"class {self.name}: rates must be nonnegative")


@dataclass(frozen=True)
class ServicePrimitives:
    """Iteration-time abstraction (Section 2.2).

    tau_mix(C) = alpha + beta * C   (mixed iteration: one prefill chunk present)
    tau_solo   = 1 / gamma          (decode-only iteration)

    B is the per-server decode-stream cap; C the prefill chunk size (tokens).
    Defaults are the paper's A100 / Qwen3-8B calibration (Section 6.1).
    """

    alpha: float = 0.0174
    beta: float = 6.2e-5
    gamma: float = 1.0 / 0.0089  # 1 / tau_solo
    batch_cap: int = 16  # B
    chunk: int = 256  # C

    def __post_init__(self) -> None:
        if self.alpha <= 0 or self.beta < 0 or self.gamma <= 0:
            raise ValueError("invalid iteration-time primitives")
        if self.batch_cap < 2 or self.chunk < 1:
            raise ValueError("need B >= 2 and C >= 1")

    @property
    def tau_mix(self) -> float:
        """Mixed iteration time tau = alpha + beta * C (Eq. 3)."""
        return self.alpha + self.beta * self.chunk

    @property
    def tau_solo(self) -> float:
        return 1.0 / self.gamma

    @property
    def solo_efficiency_ok(self) -> bool:
        """Proposition 1's calibrated-regime condition gamma*tau >= (B-1)/B."""
        return self.gamma * self.tau_mix >= (self.batch_cap - 1) / self.batch_cap

    @property
    def kappa(self) -> float:
        """Mode speed ratio kappa = mu_s / mu_m = gamma * tau (class independent)."""
        return self.gamma * self.tau_mix

    def with_(self, **kw) -> "ServicePrimitives":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ClassRates:
    """Service rates of Eq. (4) for one class."""

    mu_p: float  # prefill completion rate C / (P_i * tau)
    mu_m: float  # mixed-mode decode completion rate 1 / (D_i * tau)
    mu_s: float  # solo-mode decode completion rate gamma / D_i


def resolve_primitives(prim) -> ServicePrimitives:
    """Accept a :class:`ServicePrimitives` or anything exposing the
    calibration ``IterationTimeModel`` protocol (a ``primitives()``
    method) -- so planning/CTMC/fluid entry points can consume a fitted
    iteration-time model directly."""
    if isinstance(prim, ServicePrimitives):
        return prim
    getter = getattr(prim, "primitives", None)
    if callable(getter):
        out = getter()
        if isinstance(out, ServicePrimitives):
            return out
    raise TypeError(
        f"expected ServicePrimitives or an IterationTimeModel with a "
        f".primitives() method, got {type(prim).__name__}")


def rates_for(cls: WorkloadClass, prim: ServicePrimitives,
              kv_xfer: float = 0.0) -> ClassRates:
    """Per-class service rates (Eq. 4), optionally transfer-adjusted.

    ``kv_xfer`` is the KV handoff charge in seconds per prompt token
    (KV bytes/token over link bandwidth; see docs/HETEROGENEITY.md): a
    finishing prefill additionally occupies its server for
    ``kv_xfer * P_i`` seconds while the cache ships to the decode pool,
    so the effective prefill service time is ``P_i tau / chunk +
    kv_xfer * P_i``.  The ``kv_xfer == 0`` branch is taken in Python so
    the legacy expression (and its bitwise value) is untouched for
    every existing homogeneous caller.
    """
    prim = resolve_primitives(prim)
    tau = prim.tau_mix
    if kv_xfer == 0.0:
        mu_p = prim.chunk / (cls.prompt_len * tau)
    else:
        mu_p = 1.0 / (cls.prompt_len * tau / prim.chunk
                      + kv_xfer * cls.prompt_len)
    return ClassRates(
        mu_p=mu_p,
        mu_m=1.0 / (cls.decode_len * tau),
        mu_s=prim.gamma / cls.decode_len,
    )


@dataclass(frozen=True)
class Pricing:
    """Per-token prices; bundled reward w_i = c_p P_i + c_d D_i (Eq. 21)."""

    c_p: float = 0.1
    c_d: float = 0.2

    def bundled_reward(self, cls: WorkloadClass) -> float:
        return self.c_p * cls.prompt_len + self.c_d * cls.decode_len

    def prefill_reward(self, cls: WorkloadClass) -> float:
        return self.c_p * cls.prompt_len

    def decode_reward(self, cls: WorkloadClass) -> float:
        return self.c_d * cls.decode_len


DEFAULT_PRIMITIVES = ServicePrimitives()


def rate_arrays(
    classes: Sequence[WorkloadClass], prim: ServicePrimitives,
    kv_xfer: float = 0.0,
) -> dict[str, np.ndarray]:
    """Vectorised per-class parameter arrays used by the LP/fluid/simulator."""
    prim = resolve_primitives(prim)
    rr = [rates_for(c, prim, kv_xfer) for c in classes]
    return {
        "lam": np.array([c.arrival_rate for c in classes], dtype=np.float64),
        "theta": np.array([c.patience for c in classes], dtype=np.float64),
        "P": np.array([c.prompt_len for c in classes], dtype=np.float64),
        "D": np.array([c.decode_len for c in classes], dtype=np.float64),
        "mu_p": np.array([r.mu_p for r in rr], dtype=np.float64),
        "mu_m": np.array([r.mu_m for r in rr], dtype=np.float64),
        "mu_s": np.array([r.mu_s for r in rr], dtype=np.float64),
    }
