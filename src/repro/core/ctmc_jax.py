"""Uniformized JAX simulation of the aggregate CTMC (jit + vmap batched).

Same stochastic law as :class:`repro.core.simulator.CTMCSimulator` -- the
paper's aggregate many-server CTMC (Section 2.3) under the gate-and-route
policy family -- re-expressed so the event loop becomes a fixed-length
``jax.lax.scan``.  That makes one replication jittable and a whole
replication batch a single ``jax.vmap`` over PRNG keys, which is what lets
the convergence experiments (EC.8.5) scale to thousands of replications at
n up to 10^3.

**Uniformization.**  The exact CTMC jumps at state-dependent total rate
``R(s)``.  Uniformization picks a constant ``Lambda >= sup_s R(s)``, runs a
Poisson(``Lambda``) clock, and at each tick executes a real transition with
probability ``R(s)/Lambda`` (otherwise a self-loop).  The embedded process
has exactly the original law, but every step is structurally identical --
a categorical draw over a fixed-length rate vector -- so it scans.  The
bound used here (see ``docs/SIMULATORS.md`` for the derivation):

    Lambda =   n * sum_i lambda_i              (arrivals)
             + M * max_i mu_p,i                (prefills; X_+ <= M)
             + cap_m * max_i mu_m,i            (mixed decodes; Y_m+ <= cap_m)
             + cap_s * max_i mu_s,i            (solo decodes;  Y_s+ <= cap_s)
             + sum_i theta_i * (Qp_cap_i + Qd_cap_i)   (abandonment caps)

where ``cap_m = (B-1) * M`` (0 for prefill-only mixed servers) and
``cap_s = B * (n - M)`` are the static decode-slot capacities.  The first
four terms are hard pathwise bounds.  Abandonment rates are proportional
to *unbounded* queue lengths, so they are clipped at generous per-class
caps ``Q*_cap_i`` (default ``cap_margin * n lambda_i / theta_i`` plus
fluctuation slack -- several times the no-service-at-all equilibrium, far
outside the stable regime the policies operate in).  Steps on which a
queue actually exceeds its cap under-sample abandonment; they are counted
in ``clip_steps`` so callers can assert the clip never engaged (the
equivalence tests do).

**Self-loop skipping (default stepping mode).**  On the ``Lambda`` clock a
run of self-loops out of state ``s`` is Geometric(``R(s)/Lambda``), and a
geometric number of Exp(``Lambda``) ticks is exactly one Exp(``R(s)``)
holding time -- so the self-loop runs can be collapsed and every scan step
made a *real* transition (the embedded-jump / SSA form of the same chain).
The scan length then comes from a pathwise conservation law instead of the
``Lambda * T`` tick budget: every prefill completion or prefill abandon
consumes one arrival, every decode completion or decode abandon consumes
one prefill completion, so with ``A`` arrivals there are at most ``3 A``
events, and ``A`` itself is Poisson(``n sum_i lambda_i * T``).  The
default ``stepping="events"`` uses this budget (~``3 n lambda T`` steps,
unclipped exact rates, no self-loops); ``stepping="ticks"`` runs the
strict ``Lambda``-clock form (~``Lambda * T`` steps) for when a
fixed-rate clock is wanted, e.g. to couple replications tick-by-tick.
Both modes stop accounting at the horizon; if the step budget is ever
exhausted early (Poisson tail), ``t_end < horizon`` reports it.

**Semantics parity** with the Python engine (same documented deviations):
FCFS buffer pulls are proportional-to-queue-length draws, mixed decodes
always run at ``mu_m``, and at most one prefill admission per event (an
invariant of the gate family when starting from an empty state, which is
why the Python engine's ``while`` admission loop collapses to one
branchless update here).

Supported policy surface (mirrors :class:`CTMCSimulator` exactly):

* gates: :class:`OccupancyGate`, :class:`PriorityRatioGate`,
  :class:`FCFSGate`;
* routers: ``solo_first`` (also used for ``immediate`` / ``local_fcfs``,
  exactly as the aggregate Python engine does) and ``randomized``
  (incl. the EC.7 pool weights);
* charging: ``bundled`` | ``separate``.

Not supported: event-resolution trajectory recording (``record_every``)
and warm starts -- use the Python engine for those.  Time-*binned*
trajectories are available on-device via ``telemetry=`` (a
:class:`repro.telemetry.probes.ProbeSpec`), which threads fixed-shape
``tlm_*`` probe arrays through the scan carry; ``telemetry=None`` (the
default) compiles the byte-identical bare kernel.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import prng_key
from repro.telemetry.probes import (ctmc_probe_carry, extract_probes,
                                    resolve_probe_spec,
                                    wrap_ctmc_step_probes)

from .policies import FCFSGate, OccupancyGate, PolicySpec, PriorityRatioGate
from .simulator import CTMCResult
from .types import (Pricing, ServicePrimitives, WorkloadClass, rate_arrays,
                    resolve_primitives)

__all__ = [
    "UniformizedCTMC",
    "uniformization_bound",
    "run_uniformized",
    "run_uniformized_batch",
]

_EPS_TARGET = 1e-12  # OccupancyGate's "class is never admitted" threshold


def _gate_kind(policy: PolicySpec) -> str:
    gate = policy.gate
    if isinstance(gate, OccupancyGate):
        return "occupancy"
    if isinstance(gate, PriorityRatioGate):
        return "priority"
    if isinstance(gate, FCFSGate):
        return "fcfs"
    raise ValueError(
        f"ctmc_jax does not support gate {type(gate).__name__}; "
        "use the Python CTMCSimulator")


def _categorical(u, weights):
    """Index ~ weights/sum(weights) from one uniform draw.

    ``side='right'`` on the cumsum means zero-weight entries are never
    selected; an all-zero vector returns the last index (callers mask
    that case with their own validity flag).
    """
    c = jnp.cumsum(weights)
    return jnp.minimum(jnp.searchsorted(c, u * c[-1], side="right"),
                       weights.shape[0] - 1)


def uniformization_bound(classes: Sequence[WorkloadClass],
                         prim: ServicePrimitives, policy: PolicySpec,
                         n: int, cap_margin: float = 6.0,
                         kv_xfer: float = 0.0) -> dict:
    """Static rate bound + abandonment caps for one instance.

    Returns ``{"Lambda", "M", "cap_m", "cap_s", "qp_cap", "qd_cap"}`` as
    plain numpy values (``qp_cap``/``qd_cap`` are per-class arrays, inf
    where ``theta_i == 0`` -- a zero rate needs no cap).
    """
    prim = resolve_primitives(prim)
    arr = rate_arrays(classes, prim, kv_xfer)
    lam_tot = n * arr["lam"]
    theta = arr["theta"]
    M = policy.mixed_target(n)
    B = prim.batch_cap
    cap_m = 0.0 if policy.prefill_only_mixed else float((B - 1) * M)
    cap_s = float(B * (n - M))
    with np.errstate(divide="ignore", invalid="ignore"):
        base = np.where(theta > 0, lam_tot / np.maximum(theta, 1e-300), 0.0)
    cap = np.ceil(cap_margin * base + 20.0 * np.sqrt(base + 1.0) + 100.0)
    qp_cap = np.where(theta > 0, cap, np.inf)
    qd_cap = np.where(theta > 0, cap, np.inf)
    ab = float(np.sum(np.where(theta > 0, theta * cap, 0.0)))
    lam = (float(lam_tot.sum())
           + float(M * arr["mu_p"].max())
           + cap_m * float(arr["mu_m"].max())
           + cap_s * float(arr["mu_s"].max())
           + 2.0 * ab)
    return {"Lambda": lam, "M": float(M), "cap_m": cap_m, "cap_s": cap_s,
            "qp_cap": qp_cap, "qd_cap": qd_cap}


def _build_step(params: dict, key, gate_kind: str, router_kind: str,
                charging: str, has_pw: bool, stepping: str):
    """Step closure for the scan: one Lambda-clock tick (``"ticks"``) or
    one real transition with self-loops skipped (``"events"``)."""
    I = params["lam_tot"].shape[0]
    lam = params["Lambda"]
    dtype = params["lam_tot"].dtype

    def step(carry, idx):
        u = jax.random.uniform(jax.random.fold_in(key, idx), (4,),
                               dtype=dtype)
        qp, x = carry["qp"], carry["x"]
        qdm, qds = carry["qdm"], carry["qds"]
        ym, ys = carry["ym"], carry["ys"]
        t = carry["t"]
        horizon, warmup = params["horizon"], params["warmup"]
        qd = qdm + qds

        active = t < horizon

        # -- holding time + which event fires ------------------------------
        if stepping == "ticks":
            # Lambda-clock: abandonment rates clipped at the caps so the
            # static bound Lambda >= R(s) holds; excess mass self-loops
            rates = jnp.concatenate([
                params["lam_tot"],
                params["mu_p"] * x,
                params["mu_m"] * ym,
                params["mu_s"] * ys,
                params["theta"] * jnp.minimum(qp, params["qp_cap"]),
                params["theta"] * jnp.minimum(qd, params["qd_cap"]),
            ])
            c = jnp.cumsum(rates)
            dt = -jnp.log1p(-u[0]) / lam
            t_new = jnp.minimum(t + dt, horizon)
            idx_ev = jnp.searchsorted(c, u[1] * lam, side="right")
            live = idx_ev < 6 * I  # ticks past R(s) are self-loops
        else:
            # embedded jumps: exact (unclipped) rates, Exp(R(s)) holding
            rates = jnp.concatenate([
                params["lam_tot"],
                params["mu_p"] * x,
                params["mu_m"] * ym,
                params["mu_s"] * ys,
                params["theta"] * qp,
                params["theta"] * qd,
            ])
            c = jnp.cumsum(rates)
            total = c[-1]
            dt = jnp.where(total > 0, -jnp.log1p(-u[0])
                           / jnp.maximum(total, 1e-30), horizon)
            t_new = jnp.minimum(t + dt, horizon)
            idx_ev = jnp.searchsorted(c, u[1] * total, side="right")
            live = total > 0
        # time-average accumulation over [t, t_new) with the PRE-event
        # state (the event, if any, happens at t_new); events at exactly
        # the horizon are never applied (matching the Python loop's break)
        eff = jnp.clip(t_new - jnp.maximum(t, warmup), 0.0) * active
        ev = active & (t_new < horizon) & live
        idx_c = jnp.minimum(idx_ev, 6 * I - 1)
        cat = idx_c // I
        i = idx_c % I
        one = jax.nn.one_hot(i, I, dtype=dtype)

        is_arr = ev & (cat == 0)
        is_pc = ev & (cat == 1)
        is_md = ev & (cat == 2)
        is_sd = ev & (cat == 3)
        is_ap = ev & (cat == 4)
        is_ad = ev & (cat == 5)

        def f(b):
            return b.astype(dtype)

        rev_on = f(t_new > warmup)
        free_s = params["cap_s"] - ys.sum()
        free_m = params["cap_m"] - ym.sum()

        # -- route the decode of a completed class-i prefill ---------------
        if router_kind == "randomized":
            go_solo = u[2] <= params["p_s"][i]
            route_ys = f(is_pc & go_solo & (free_s >= 1))
            route_qds = f(is_pc & go_solo & (free_s < 1))
            route_ym = f(is_pc & ~go_solo & (free_m >= 1))
            route_qdm = f(is_pc & ~go_solo & (free_m < 1))
        else:  # solo_first (single logical buffer kept in the solo half)
            route_ys = f(is_pc & (free_s >= 1))
            route_ym = f(is_pc & (free_s < 1) & (free_m >= 1))
            route_qds = f(is_pc & (free_s < 1) & (free_m < 1))
            route_qdm = jnp.zeros((), dtype)

        # -- pull from the buffer into the slot a decode completion freed --
        pull = is_md | is_sd
        if router_kind == "randomized":
            qpool = jnp.where(is_sd, qds, qdm)
            mask = f(qpool >= 1)
            if has_pw:
                wsel = jnp.where(is_sd, params["pw_s"], params["pw_m"])
                wsel = wsel * mask
                probs = jnp.where(wsel.sum() > 0, wsel, qpool * mask)
            else:
                probs = qpool * mask
            j = _categorical(u[2], probs)
            pull_ok = pull & (mask.sum() >= 1)
            pull_from_ds = f(pull_ok & is_sd)
            pull_from_dm = f(pull_ok & is_md)
        else:
            qtot = qds + qdm
            j = _categorical(u[2], qtot)
            pull_ok = pull & (qtot.sum() >= 1)
            take_ds = qds[j] >= 1
            pull_from_ds = f(pull_ok & take_ds)
            pull_from_dm = f(pull_ok & ~take_ds)
        onej = jax.nn.one_hot(j, I, dtype=dtype)
        pull_to_ys = f(pull_ok & is_sd)
        pull_to_ym = f(pull_ok & is_md)

        # -- decode abandonment: which buffer half loses the job -----------
        denom = jnp.maximum(qds[i] + qdm[i], 1.0)
        ab_take_s = (qds[i] >= 1) & ((qdm[i] < 1) | (u[2] < qds[i] / denom))
        ab_ds = f(is_ad & ab_take_s)
        ab_dm = f(is_ad & ~ab_take_s)

        # -- stage 1: apply the event --------------------------------------
        qp1 = qp + one * (f(is_arr) - f(is_ap))
        x1 = x - one * f(is_pc)
        ym1 = ym + one * (route_ym - f(is_md)) + onej * pull_to_ym
        ys1 = ys + one * (route_ys - f(is_sd)) + onej * pull_to_ys
        qdm1 = qdm + one * (route_qdm - ab_dm) - onej * pull_from_dm
        qds1 = qds + one * (route_qds - ab_ds) - onej * pull_from_ds

        # -- stage 2: prefill admission (at most one needed per event) -----
        adm_ev = is_arr | is_pc
        free_p = params["M"] - x1.sum()
        if gate_kind == "occupancy":
            mask = (qp1 >= 1) & (params["x_star"] > _EPS_TARGET)
            xi = ((x1 + 1.0 - params["n"] * params["x_star"])
                  / jnp.maximum(params["x_star"], 1e-30))
            keyv = jnp.where(mask, xi, jnp.inf)
            tie = mask & (keyv == keyv.min())
            delta = qp1 - params["n"] * params["qp_star"]
            cand = jnp.argmax(jnp.where(tie, delta, -jnp.inf))
            can_admit = mask.any()
        elif gate_kind == "priority":
            mask = qp1 >= 1
            cand = jnp.argmax(jnp.where(mask, params["ratio"], -jnp.inf))
            can_admit = mask.any()
        else:  # fcfs: head-of-line class ~ queue lengths (exchangeable)
            cand = _categorical(u[3], qp1)
            can_admit = qp1.sum() >= 1
        admit = f(adm_ev & can_admit & (free_p >= 1))
        onec = jax.nn.one_hot(cand, I, dtype=dtype)
        qp2 = qp1 - onec * admit
        x2 = x1 + onec * admit

        # -- revenue -------------------------------------------------------
        if charging == "separate":
            rev_inc = (params["w_pre"][i] * f(is_pc)
                       + params["w_dec"][i] * (f(is_md) + f(is_sd)))
        else:
            rev_inc = params["w"][i] * (f(is_md) + f(is_sd))
        rev_inc = rev_inc * rev_on

        if stepping == "ticks":
            clipped = active & (
                jnp.any((params["theta"] > 0) & (qp > params["qp_cap"]))
                | jnp.any((params["theta"] > 0) & (qd > params["qd_cap"])))
        else:  # exact rates; nothing to clip
            clipped = jnp.zeros((), bool)

        new = {
            "qp": qp2, "x": x2, "qdm": qdm1, "qds": qds1,
            "ym": ym1, "ys": ys1,
            "t": jnp.where(active, t_new, t),
            "rev": carry["rev"] + rev_inc,
            "acc_x": carry["acc_x"] + eff * x,
            "acc_ym": carry["acc_ym"] + eff * ym,
            "acc_ys": carry["acc_ys"] + eff * ys,
            "acc_qp": carry["acc_qp"] + eff * qp,
            "acc_qd": carry["acc_qd"] + eff * qd,
            "acc_t": carry["acc_t"] + eff,
            "completions": carry["completions"]
            + one * (f(is_md) + f(is_sd)),
            "arrivals": carry["arrivals"] + one * f(is_arr),
            "ab_p": carry["ab_p"] + one * f(is_ap),
            "ab_d": carry["ab_d"] + one * f(is_ad),
            "clip_steps": carry["clip_steps"] + f(clipped),
            "n_events": carry["n_events"] + f(ev),
        }
        return new, None

    return step


def _init_carry(I: int, dtype, telemetry=None) -> dict:
    z = jnp.zeros(I, dtype)
    s = jnp.zeros((), dtype)
    c = {
        "qp": z, "x": z, "qdm": z, "qds": z, "ym": z, "ys": z,
        "t": s, "rev": s,
        "acc_x": z, "acc_ym": z, "acc_ys": z, "acc_qp": z, "acc_qd": z,
        "acc_t": s,
        "completions": z, "arrivals": z, "ab_p": z, "ab_d": z,
        "clip_steps": s, "n_events": s,
    }
    if telemetry is not None:
        c.update(ctmc_probe_carry(telemetry, I=I, dtype=dtype))
    return c


_STATICS = ("n_steps", "gate_kind", "router_kind", "charging", "has_pw",
            "stepping", "telemetry")


def _run_core(params, key, *, n_steps, gate_kind, router_kind, charging,
              has_pw, stepping, telemetry=None):
    I = params["lam_tot"].shape[0]
    step = _build_step(params, key, gate_kind, router_kind, charging,
                       has_pw, stepping)
    if telemetry is not None:
        step = wrap_ctmc_step_probes(step, telemetry, params["horizon"])
    carry, _ = jax.lax.scan(
        step, _init_carry(I, params["lam_tot"].dtype, telemetry),
        jnp.arange(n_steps, dtype=jnp.uint32))
    return carry


run_uniformized = jax.jit(_run_core, static_argnames=_STATICS)


@partial(jax.jit, static_argnames=_STATICS)
def run_uniformized_batch(params, keys, *, n_steps, gate_kind, router_kind,
                          charging, has_pw, stepping, telemetry=None):
    """vmap of :func:`run_uniformized` over a leading batch of PRNG keys."""
    return jax.vmap(
        lambda k: _run_core(params, k, n_steps=n_steps, gate_kind=gate_kind,
                            router_kind=router_kind, charging=charging,
                            has_pw=has_pw, stepping=stepping,
                            telemetry=telemetry))(keys)


class UniformizedCTMC:
    """Batched uniformized simulator of the aggregate CTMC.

    Drop-in statistical replacement for :class:`CTMCSimulator` on the
    gate-and-route family: same classes/primitives/pricing/policy inputs,
    same :class:`CTMCResult` outputs, but replications run as one
    ``jax.vmap`` batch over PRNG keys.  ``horizon`` and ``warmup`` are
    fixed at construction because the scan length (``n_steps ~
    Lambda * horizon``) is a static compile-time quantity.

    ``stepping`` picks the scan form: ``"events"`` (default) runs one real
    transition per step with the conservation-law event budget
    (~``3 n lambda T`` steps); ``"ticks"`` runs the strict Lambda-clock
    uniformization (~``Lambda * T`` steps, self-loops included).
    ``cap_margin`` scales the abandonment-rate caps of the ticks-mode
    bound (larger = safer bound, more self-loops); ``steps_margin`` adds
    Poisson slack to the step count so the scan covers the horizon with
    overwhelming probability (check ``t_end == horizon`` on the result).
    """

    def __init__(self, classes: Sequence[WorkloadClass],
                 prim: ServicePrimitives, pricing: Pricing,
                 policy: PolicySpec, n: int, horizon: float,
                 warmup: float = 0.0, *, stepping: str = "events",
                 cap_margin: float = 6.0, steps_margin: float = 6.0,
                 n_steps: int | None = None, telemetry=None,
                 kv_xfer: float = 0.0):
        self.classes = tuple(classes)
        self.policy = policy
        self.n = int(n)
        self.I = len(self.classes)
        self.horizon = float(horizon)
        self.warmup = float(warmup)

        if stepping not in ("events", "ticks"):
            raise ValueError(f"stepping must be events|ticks, got {stepping!r}")
        self.stepping = stepping

        # KV-transfer charge (seconds per prompt token): folds into the
        # aggregate prefill service rate mu_p; the 0.0 default takes the
        # legacy expression in rates_for, keeping existing runs bitwise
        arr = rate_arrays(self.classes, prim, kv_xfer)
        bound = uniformization_bound(self.classes, prim, policy, self.n,
                                     cap_margin=cap_margin,
                                     kv_xfer=kv_xfer)
        self.Lambda = bound["Lambda"]
        self.M = int(bound["M"])
        if n_steps is not None:
            self.n_steps = int(n_steps)
        elif stepping == "ticks":
            lt = self.Lambda * self.horizon
            self.n_steps = int(math.ceil(
                lt + steps_margin * math.sqrt(lt) + 64))
        else:
            # pathwise: events <= 3 * arrivals, arrivals ~ Poisson(n lam T)
            at = float(self.n * arr["lam"].sum()) * self.horizon
            self.n_steps = int(math.ceil(
                3.0 * (at + steps_margin * math.sqrt(at)) + 64))

        self.gate_kind = _gate_kind(policy)
        self.router_kind = ("randomized" if policy.router == "randomized"
                            else "solo_first")
        self.charging = policy.charging
        pw_m, pw_s = policy.pool_weights_mixed, policy.pool_weights_solo
        if (pw_m is None) != (pw_s is None):
            raise ValueError("ctmc_jax needs both pool-weight vectors "
                             "or neither")
        self.has_pw = pw_m is not None

        dt = jnp.result_type(float)
        ones = np.ones(self.I)

        def a(v):
            return jnp.asarray(v, dtype=dt)

        gate = policy.gate
        self.params = {
            "lam_tot": a(self.n * arr["lam"]),
            "theta": a(arr["theta"]),
            "mu_p": a(arr["mu_p"]),
            "mu_m": a(arr["mu_m"]),
            "mu_s": a(arr["mu_s"]),
            "w": a([pricing.bundled_reward(c) for c in self.classes]),
            "w_pre": a([pricing.prefill_reward(c) for c in self.classes]),
            "w_dec": a([pricing.decode_reward(c) for c in self.classes]),
            "x_star": a(gate.x_star if isinstance(gate, OccupancyGate)
                        else ones),
            "qp_star": a(gate.qp_star if isinstance(gate, OccupancyGate)
                         else 0 * ones),
            "ratio": a(gate.ratio if isinstance(gate, PriorityRatioGate)
                       else ones),
            "p_s": a(policy.solo_prob if policy.solo_prob is not None
                     else ones),
            "pw_m": a(pw_m if pw_m is not None else ones),
            "pw_s": a(pw_s if pw_s is not None else ones),
            "n": a(self.n),
            "M": a(self.M),
            "cap_m": a(bound["cap_m"]),
            "cap_s": a(bound["cap_s"]),
            "qp_cap": a(bound["qp_cap"]),
            "qd_cap": a(bound["qd_cap"]),
            "Lambda": a(self.Lambda),
            "horizon": a(self.horizon),
            "warmup": a(self.warmup),
        }
        self._static = dict(n_steps=self.n_steps, gate_kind=self.gate_kind,
                            router_kind=self.router_kind,
                            charging=self.charging, has_pw=self.has_pw,
                            stepping=self.stepping,
                            telemetry=resolve_probe_spec(telemetry))
        self.telemetry = self._static["telemetry"]

    # -- raw (device array) interface -------------------------------------
    def _key(self, seed):
        if isinstance(seed, (int, np.integer)):
            return prng_key(int(seed))
        return seed

    def run_raw(self, seed) -> dict:
        """One replication; returns the raw scan carry (device arrays)."""
        return run_uniformized(self.params, self._key(seed), **self._static)

    def run_batch_raw(self, seeds: Sequence, *, placement: str = "vmap",
                      shard: Optional[dict] = None) -> dict:
        """All replications in one batch; leaves gain a leading
        replication axis.

        ``placement`` picks the execution layout (see
        :mod:`repro.sweep.sharded`): ``"vmap"`` (default) is the
        single-device oracle, ``"shard_map"`` partitions the key batch
        over the devices' 1-D cells mesh (bitwise identical results),
        ``"single"`` falls back to one jitted run per seed.  ``shard``
        forwards tiling kwargs (``n_devices``,
        ``max_cells_per_device``, ``bytes_per_cell``,
        ``memory_budget``) to :func:`repro.sweep.sharded.run_sharded`.
        """
        if placement == "single":
            outs = [self.run_raw(s) for s in seeds]
            return {k: jnp.stack([o[k] for o in outs]) for k in outs[0]}
        keys = jnp.stack([self._key(s) for s in seeds])
        if placement == "vmap":
            return run_uniformized_batch(self.params, keys, **self._static)
        if placement == "shard_map":
            from repro.sweep.sharded import run_sharded

            static = dict(self._static)
            raw, self.shard_report = run_sharded(
                lambda p, k: _run_core(p, k, **static),
                self.params, keys, **(shard or {}))
            return raw
        raise ValueError(f"unknown placement {placement!r} (expected "
                         f"single|vmap|shard_map)")

    def telemetry_from_raw(self, raw: dict) -> dict:
        """Host-side probe report (:func:`extract_probes`) from a raw
        carry of a telemetry-enabled run.  The aggregate chain fills the
        trajectory probes only -- per-request latency histograms do not
        exist at the class-aggregate level."""
        if self.telemetry is None:
            raise ValueError("this UniformizedCTMC was built without "
                             "telemetry=; pass a ProbeSpec/True at init")
        return extract_probes(raw, self.telemetry, horizon=self.horizon,
                              n_servers=self.n)

    # -- CTMCResult interface ----------------------------------------------
    def _to_result(self, o: dict) -> CTMCResult:
        meas = max(float(o["acc_t"]), 1e-12)
        n = self.n
        return CTMCResult(
            t_end=float(o["t"]),
            revenue=float(o["rev"]),
            revenue_rate_per_server=float(o["rev"]) / (n * meas),
            completions=np.asarray(o["completions"], dtype=np.float64),
            arrivals=np.asarray(o["arrivals"], dtype=np.float64),
            abandons_p=np.asarray(o["ab_p"], dtype=np.float64),
            abandons_d=np.asarray(o["ab_d"], dtype=np.float64),
            avg_x=np.asarray(o["acc_x"]) / meas / n,
            avg_ym=np.asarray(o["acc_ym"]) / meas / n,
            avg_ys=np.asarray(o["acc_ys"]) / meas / n,
            avg_qp=np.asarray(o["acc_qp"]) / meas / n,
            avg_qd=np.asarray(o["acc_qd"]) / meas / n,
            n_events=int(o["n_events"]),
        )

    def results_from_raw(self, raw: dict) -> list:
        """Split a :meth:`run_batch_raw` carry into per-replication
        :class:`CTMCResult` objects."""
        host = {k: np.asarray(v) for k, v in raw.items()}
        reps = host["t"].shape[0]
        return [self._to_result({k: v[r] for k, v in host.items()})
                for r in range(reps)]

    def run(self, seed) -> CTMCResult:
        return self._to_result({k: np.asarray(v)
                                for k, v in self.run_raw(seed).items()})

    def run_batch(self, seeds: Sequence, *, placement: str = "vmap",
                  shard: Optional[dict] = None) -> list:
        return self.results_from_raw(
            self.run_batch_raw(seeds, placement=placement, shard=shard))
