"""Steady-state fluid planning LPs (paper Eqs. 40, 42, 49).

Variables per class i (block layout, I classes):

    x[i]    prefill occupancy per server            (fraction of a server)
    ym[i]   mixed-mode decode occupancy per server  (slots)
    ys[i]   solo-mode decode occupancy per server   (slots)
    qp[i]   prefill queue mass per server
    qd[i]   decode queue mass per server

Constraints (LP 40):

    sum_i x[i]                 <= 1
    sum_i ym[i] - (B-1) sum x  <= 0
    sum_i ys[i] + B sum x      <= B
    mu_p[i] x[i] + theta[i] qp[i]                        == lam[i]     (prefill FB)
    mu_p[i] x[i] - theta[i] qd[i] - mu_m[i] ym[i] - mu_s[i] ys[i] == 0 (decode FB)

SLI extensions (Section 5): prefill/decode fairness (pairwise linearised max
gap), TPOT cap (47) which is linear after cross-multiplying, optional
``q_d = 0`` pinning, and penalty (soft) forms via auxiliary gap variables.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .lp import LPResult, linprog_max
from .types import (Pricing, ServicePrimitives, WorkloadClass, rate_arrays,
                    resolve_primitives)

__all__ = [
    "PlanSolution",
    "SLISpec",
    "solve_bundled_lp",
    "solve_separate_lp",
    "solve_plan",
    "tpot_of_plan",
    "validate_planning_instance",
]


def validate_planning_instance(classes, capacity: float = 1.0,
                               label: str = "planning LP") -> tuple:
    """Reject degenerate planner inputs with a diagnostic LPInfeasible.

    The simplex/IPM layers assume a nonempty class list with *some*
    offered traffic and positive service capacity; violating that used
    to surface as an IndexError deep in the tableau (empty classes) or a
    silently meaningless all-zero plan (no traffic).  Shared by
    :func:`solve_plan` and :func:`repro.core.planning_batch.solve_plan_batch`.
    """
    from .lp import LPInfeasible

    classes = tuple(classes)
    if not classes:
        raise LPInfeasible(
            f"{label}: empty class list -- the steady-state plan needs at "
            "least one workload class")
    lam = np.array([c.arrival_rate for c in classes], dtype=np.float64)
    if not np.any(lam > 0):
        names = [c.name for c in classes]
        raise LPInfeasible(
            f"{label}: degenerate instance -- all arrival rates are zero "
            f"(classes={names}); the plan is undefined without traffic "
            "(feed estimated rates, e.g. OnlineController.estimate_rates, "
            "which floors at lam_min)")
    if not capacity > 0:
        raise LPInfeasible(
            f"{label}: zero service capacity (capacity={capacity:g}) "
            f"cannot serve offered load lam_total={float(lam.sum()):.4g}")
    return classes


@dataclass(frozen=True)
class SLISpec:
    """Service-level-indicator configuration for the planning LP (Section 5).

    ``None`` disables a term.  Hard caps are constraints (43)/(45)/(47);
    ``*_penalty`` weights add linearised penalty terms (44)/(46) to the
    objective.  ``pin_zero_decode_queue`` adds q_d,i == 0 (the standing
    assumption of Section 5's zero-buffer router).
    """

    prefill_fairness_cap: Optional[float] = None  # eta_1
    decode_fairness_cap: Optional[float] = None  # eta_2
    tpot_cap: Optional[float] = None  # eta_3 (seconds / output token)
    prefill_fairness_penalty: float = 0.0  # eta_1'
    decode_fairness_penalty: float = 0.0  # eta_2'
    pin_zero_decode_queue: bool = False

    @property
    def active(self) -> bool:
        return (
            self.prefill_fairness_cap is not None
            or self.decode_fairness_cap is not None
            or self.tpot_cap is not None
            or self.prefill_fairness_penalty > 0
            or self.decode_fairness_penalty > 0
            or self.pin_zero_decode_queue
        )


@dataclass
class PlanSolution:
    """Optimal fluid plan + planning metadata used by the policies."""

    classes: tuple
    prim: ServicePrimitives
    pricing: Pricing
    objective: str  # "bundled" | "separate"
    x: np.ndarray  # per-class prefill occupancy targets  x_i*
    ym: np.ndarray
    ys: np.ndarray
    qp: np.ndarray
    qd: np.ndarray
    revenue_rate: float  # optimal per-server reward rate R*
    sli_value: float  # penalty part (0 if none)
    lp: LPResult = field(repr=False, default=None)
    dual_capacity: np.ndarray = None  # duals of the 3 capacity rows

    @property
    def x_total(self) -> float:
        return float(self.x.sum())

    def mixed_servers(self, n: int) -> int:
        """Static partition size M = ceil(n * sum_i x_i*) (Section 4.1)."""
        m = int(np.ceil(n * self.x_total - 1e-12))
        return min(max(m, 0), n)

    def solo_probs(self) -> np.ndarray:
        """Randomised-router probabilities p_{s,i} (Section 5.2)."""
        arr = rate_arrays(self.classes, self.prim)
        num = self.ys * arr["mu_s"]
        den = num + self.ym * arr["mu_m"]
        with np.errstate(invalid="ignore", divide="ignore"):
            p = np.where(den > 0, num / np.maximum(den, 1e-300), 1.0)
        return np.clip(p, 0.0, 1.0)


def _layout(I: int):
    """Column index helpers for the block layout [x, ym, ys, qp, qd, (aux)]."""
    return dict(x=0, ym=I, ys=2 * I, qp=3 * I, qd=4 * I, n=5 * I)


def _base_constraints(arr, B: float):
    I = arr["lam"].shape[0]
    L = _layout(I)
    n = L["n"]
    A_ub, b_ub, A_eq, b_eq = [], [], [], []

    row = np.zeros(n)
    row[L["x"] : L["x"] + I] = 1.0
    A_ub.append(row)
    b_ub.append(1.0)  # prefill capacity

    row = np.zeros(n)
    row[L["ym"] : L["ym"] + I] = 1.0
    row[L["x"] : L["x"] + I] = -(B - 1)
    A_ub.append(row)
    b_ub.append(0.0)  # mixed decode capacity

    row = np.zeros(n)
    row[L["ys"] : L["ys"] + I] = 1.0
    row[L["x"] : L["x"] + I] = B
    A_ub.append(row)
    b_ub.append(B)  # solo decode capacity

    for i in range(I):
        row = np.zeros(n)
        row[L["x"] + i] = arr["mu_p"][i]
        row[L["qp"] + i] = arr["theta"][i]
        A_eq.append(row)
        b_eq.append(arr["lam"][i])  # prefill flow balance
    for i in range(I):
        row = np.zeros(n)
        row[L["x"] + i] = arr["mu_p"][i]
        row[L["qd"] + i] = -arr["theta"][i]
        row[L["ym"] + i] = -arr["mu_m"][i]
        row[L["ys"] + i] = -arr["mu_s"][i]
        A_eq.append(row)
        b_eq.append(0.0)  # decode flow balance
    return A_ub, b_ub, A_eq, b_eq, L


def _add_sli(A_ub, b_ub, A_eq, b_eq, L, I, sli: SLISpec, prim: ServicePrimitives,
             n_cols: int):
    """Append SLI rows; returns (possibly widened) matrices + penalty vector."""
    B, tau, gamma = prim.batch_cap, prim.tau_mix, prim.gamma
    extra_cols = 0
    pen_fair_p = sli.prefill_fairness_penalty > 0
    pen_fair_d = sli.decode_fairness_penalty > 0
    col_tp = n_cols if pen_fair_p else None
    if pen_fair_p:
        extra_cols += 1
    col_td = n_cols + extra_cols if pen_fair_d else None
    if pen_fair_d:
        extra_cols += 1
    width = n_cols + extra_cols

    def wrow(r=None):
        out = np.zeros(width)
        if r is not None:
            out[: r.shape[0]] = r
        return out

    A_ub2 = [wrow(r) for r in A_ub]
    A_eq2 = [wrow(r) for r in A_eq]

    # Pairwise fairness caps: x_i - x_j <= eta (43) / ys_i - ys_j <= eta (45).
    if sli.prefill_fairness_cap is not None:
        for i in range(I):
            for j in range(I):
                if i == j:
                    continue
                row = wrow()
                row[L["x"] + i] = 1.0
                row[L["x"] + j] = -1.0
                A_ub2.append(row)
                b_ub.append(sli.prefill_fairness_cap)
    if sli.decode_fairness_cap is not None:
        for i in range(I):
            for j in range(I):
                if i == j:
                    continue
                row = wrow()
                row[L["ys"] + i] = 1.0
                row[L["ys"] + j] = -1.0
                A_ub2.append(row)
                b_ub.append(sli.decode_fairness_cap)

    # Penalty (soft) fairness: t >= x_i - x_j for all pairs; objective -= eta' t.
    for col, key, on in ((col_tp, "x", pen_fair_p), (col_td, "ys", pen_fair_d)):
        if not on:
            continue
        for i in range(I):
            for j in range(I):
                if i == j:
                    continue
                row = wrow()
                row[L[key] + i] = 1.0
                row[L[key] + j] = -1.0
                row[col] = -1.0
                A_ub2.append(row)
                b_ub.append(0.0)

    # TPOT cap (47): cross-multiplied linear constraint in X = sum_i x_i:
    #   tau (B-1) X + (B/gamma)(1-X) <= eta3 [ (B-1) X + B (1-X) ]
    if sli.tpot_cap is not None:
        eta = sli.tpot_cap
        coef_X = (tau * (B - 1) - B / gamma) - eta * ((B - 1) - B)
        const = eta * B - B / gamma
        row = wrow()
        row[L["x"] : L["x"] + I] = coef_X
        A_ub2.append(row)
        b_ub.append(const)

    if sli.pin_zero_decode_queue:
        for i in range(I):
            row = wrow()
            row[L["qd"] + i] = 1.0
            A_eq2.append(row)
            b_eq.append(0.0)

    pen = np.zeros(width)
    if pen_fair_p:
        pen[col_tp] = sli.prefill_fairness_penalty
    if pen_fair_d:
        pen[col_td] = sli.decode_fairness_penalty
    return A_ub2, b_ub, A_eq2, b_eq, width, pen


def _solve(
    classes: Sequence[WorkloadClass],
    prim: ServicePrimitives,
    pricing: Pricing,
    objective: str,
    sli: Optional[SLISpec] = None,
    capacity: float = 1.0,
) -> PlanSolution:
    classes = validate_planning_instance(
        classes, capacity, label=f"planning LP ({objective})")
    prim = resolve_primitives(prim)
    arr = rate_arrays(classes, prim)
    if capacity != 1.0:  # uniform server-speed scale (elasticity studies)
        arr = dict(arr)
        for k in ("mu_p", "mu_m", "mu_s"):
            arr[k] = arr[k] * capacity
    I = len(classes)
    B = float(prim.batch_cap)
    A_ub, b_ub, A_eq, b_eq, L = _base_constraints(arr, B)
    n_cols = L["n"]
    pen = np.zeros(n_cols)
    if sli is not None and sli.active:
        A_ub, b_ub, A_eq, b_eq, n_cols, pen = _add_sli(
            A_ub, b_ub, A_eq, b_eq, L, I, sli, prim, n_cols
        )

    c = np.zeros(n_cols)
    if objective == "bundled":
        w = np.array([pricing.bundled_reward(k) for k in classes])
        c[L["ym"] : L["ym"] + I] = w * arr["mu_m"]
        c[L["ys"] : L["ys"] + I] = w * arr["mu_s"]
    elif objective == "separate":
        # Eq. (42): coefficients are class independent.
        c[L["x"] : L["x"] + I] = pricing.c_p * prim.chunk / prim.tau_mix
        c[L["ym"] : L["ym"] + I] = pricing.c_d / prim.tau_mix
        c[L["ys"] : L["ys"] + I] = pricing.c_d * prim.gamma
    else:
        raise ValueError(objective)
    c -= pen

    from .lp import LPInfeasible

    try:
        res = linprog_max(c, np.array(A_ub), np.array(b_ub), np.array(A_eq),
                          np.array(b_eq))
    except LPInfeasible as exc:
        # Enrich the bare phase-1 residual with the planning instance:
        # with theta_i = 0 the prefill flow balance pins x_i = lam_i/mu_p_i,
        # so overload (sum of pinned x_i > 1) is the canonical cause.
        zero_theta = arr["theta"] <= 0
        pinned = np.where(zero_theta, arr["lam"] / arr["mu_p"], 0.0)
        raise LPInfeasible(
            f"planning LP ({objective}) infeasible for I={I} classes "
            f"(B={B:g}): {exc}; lam={np.round(arr['lam'], 6).tolist()}, "
            f"theta={np.round(arr['theta'], 6).tolist()}; zero-patience "
            f"classes pin x_i = lam_i/mu_p_i with total pinned prefill "
            f"occupancy {float(pinned.sum()):.4g} (must be <= 1)") from exc
    x = res.x
    sol_pen = float(pen @ x)
    plan = PlanSolution(
        classes=classes,
        prim=prim,
        pricing=pricing,
        objective=objective,
        x=x[L["x"] : L["x"] + I].copy(),
        ym=x[L["ym"] : L["ym"] + I].copy(),
        ys=x[L["ys"] : L["ys"] + I].copy(),
        qp=x[L["qp"] : L["qp"] + I].copy(),
        qd=x[L["qd"] : L["qd"] + I].copy(),
        revenue_rate=float(res.fun + sol_pen),  # revenue part (before penalty)
        sli_value=sol_pen,
        lp=res,
        dual_capacity=res.dual_ub[:3].copy(),
    )
    return plan


def solve_bundled_lp(
    classes: Sequence[WorkloadClass],
    prim: ServicePrimitives = None,
    pricing: Pricing = None,
    sli: Optional[SLISpec] = None,
    capacity: float = 1.0,
) -> PlanSolution:
    """Solve the bundled-charging steady-state LP (40) (+ optional SLI rows)."""
    prim = prim or ServicePrimitives()
    pricing = pricing or Pricing()
    return _solve(classes, prim, pricing, "bundled", sli, capacity)


def solve_separate_lp(
    classes: Sequence[WorkloadClass],
    prim: ServicePrimitives = None,
    pricing: Pricing = None,
    sli: Optional[SLISpec] = None,
    capacity: float = 1.0,
) -> PlanSolution:
    """Solve the separate-charging steady-state LP (42) (+ optional SLI rows)."""
    prim = prim or ServicePrimitives()
    pricing = pricing or Pricing()
    return _solve(classes, prim, pricing, "separate", sli, capacity)


def solve_plan(classes, prim=None, pricing=None, objective="bundled",
               sli: Optional[SLISpec] = None,
               capacity: float = 1.0) -> PlanSolution:
    """Front door of the planning layer (``capacity`` uniformly scales the
    service rates; ``capacity <= 0`` raises a diagnostic LPInfeasible)."""
    if objective == "bundled":
        return solve_bundled_lp(classes, prim, pricing, sli, capacity)
    return solve_separate_lp(classes, prim, pricing, sli, capacity)


def tpot_of_plan(plan: PlanSolution) -> float:
    """Average time-per-output-token of a plan, Eq. (47)'s left-hand side."""
    prim = plan.prim
    B, tau, gamma = prim.batch_cap, prim.tau_mix, prim.gamma
    X = plan.x_total
    num = tau * (B - 1) * X + (1.0 / gamma) * B * (1 - X)
    den = (B - 1) * X + B * (1 - X)
    return num / den if den > 0 else float("nan")
