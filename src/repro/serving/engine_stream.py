"""Streamed trace replay over a compacted working-set window.

:class:`~repro.serving.engine_jax.ClusterEngineJAX` replays one
host-padded ``(R,)`` trace: every per-request array -- arrival times,
lifecycle codes, first/last-emission times, the FCFS ring -- is sized by
the *whole* trace, so the padded tables are the memory ceiling and a
million-request replay would allocate dozens of ``(1e6,)`` arrays per
replication.  :class:`StreamingEngineJAX` removes that ceiling: it
drives the *same* compiled step function over a fixed working set of
``window`` rows, consuming the trace as fixed-shape chunks
(:func:`repro.data.traces.chunk_trace` output, or a
:class:`repro.workloads.batch.ScenarioStream` that samples arrivals
on-device as it goes) and retiring finished requests between chunks.

**Segments and the frontier.**  The replay alternates two jitted
kernels.  ``_compact_splice`` retires rows whose future is decided
(``DONE``/``ABANDONED``: their TTFT/TPOT/completion contributions fold
into scalar accumulators), compacts the survivors to the front of the
window with a stable order-preserving permutation (new row ids stay in
arrival order, which is what keeps per-class FCFS an ``argmin`` and the
queue windows valid), remaps every rid-valued structure (decode slots,
active prefills, the FCFS ring) through the permutation, splices the
next chunk's rows after the survivors, and rebuilds the per-class FCFS
tables.  ``_run_segment`` then runs the engine step under a
``while_loop`` whose guard stops *strictly before the frontier* -- the
first arrival of the next, not-yet-spliced chunk -- so no event that
could interact with unseen arrivals is processed early; the
fast-forward window is capped by the same frontier (see
``params["frontier"]`` in the step builder).  With the frontier at
``+inf`` (final segment) the loop simply drains to the horizon.

**What can stream.**  The deterministic global-buffer routers
(``solo_first`` / ``local_fcfs``), any gate family, ``k_events == 1``,
no deadlines (``patience == inf`` -- expiry retires queued rows lazily,
which the compactor does not model).  The working set must hold every
*unfinished* request at any instant: queued + in-prefill + buffered +
decoding rows plus one chunk of future arrivals.  If a splice would
overflow the window the engine raises (never silently drops load);
pick ``window`` above the workload's peak backlog.  Percentile metrics
(`ttft_p95` etc.) are not computable from streamed scalars and are
reported as ``NaN``; means, completion counts and revenue are exact.

Horizon semantics are *drain*: the replay runs to ``horizon``
(generated streams have no meaningful "last arrival" to stop at).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import prng_key
from repro.core.policies import PolicySpec
from repro.core.types import WorkloadClass
from repro.data.traces import (TraceTensors, TraceValidationError,
                               chunk_trace, tensorize_trace)
from repro.telemetry.probes import extract_probes, hist_edges

from .engine_jax import (ClusterEngineJAX, _init_carry,
                         _DECODE, _DONE, _NOT_ARRIVED, _QUEUED,
                         iteration_budget, run as run_engine_facade)
from .engine_sim import EngineConfig

__all__ = ["StreamingEngineJAX", "TraceChunkSource"]


class TraceChunkSource:
    """``next_chunk()`` over pre-chunked :class:`TraceTensors`.

    Accepts either a sequence of equal-shape chunks (``chunk_trace``
    output) or a raw request list plus ``chunk_size`` (chunked here).
    Verifies all chunks share one padded shape -- the streaming engine
    compiles a single splice kernel for that shape.
    """

    def __init__(self, chunks, chunk_size: Optional[int] = None):
        if chunk_size is not None:
            chunks = chunk_trace(chunks, chunk_size)
        self._chunks = list(chunks)
        shapes = {c.R for c in self._chunks}
        if len(shapes) > 1:
            raise TraceValidationError(
                f"chunks must share one padded shape, got {sorted(shapes)}")
        self._it = iter(self._chunks)

    def next_chunk(self) -> Optional[TraceTensors]:
        return next(self._it, None)


@jax.jit
def _compact_splice(carry, tbl, ch, h_eff, tlm_edges=None):
    """Retire finished rows, compact survivors, splice the next chunk.

    Pure function of the carry, the per-request tables and one chunk;
    returns ``(carry', tbl', seg)`` where ``seg`` holds this splice's
    retired-row metric contributions and diagnostics (host-accumulated
    in float64 -- segment-sized partial sums keep float32 exact).  With
    telemetry on, ``tlm_edges`` (the log-spaced histogram edges) folds
    the retired rows' TTFT/E2E latencies into the carry's ``tlm_ttft``/
    ``tlm_e2e`` histograms before their time marks are evicted --
    residual rows are folded host-side at end of stream.
    """
    c = dict(carry)
    tbl = dict(tbl)
    Rw = tbl["t_arr"].shape[0]
    inf = jnp.inf
    iota = jnp.arange(Rw, dtype=jnp.int32)
    f32 = tbl["t_arr"].dtype

    st = c["st"]
    real = tbl["t_arr"] < inf
    keep = (((st >= _QUEUED) & (st <= _DECODE))
            | ((st == _NOT_ARRIVED) & real))
    ret = real & ~keep  # DONE / ABANDONED: metrics are final

    # retired-row metric contributions (computed before any reshuffle)
    t_first, t_last = c["t_first"], c["t_last"]
    D = tbl["D"]
    emitted = ret & jnp.isfinite(t_first)
    done = ret & (st == _DONE)
    tpm = done & (D > 1.0)
    seg = {
        "ret": jnp.sum(ret.astype(f32)),
        "done": jnp.sum(done.astype(f32)),
        "ttft_sum": jnp.sum(jnp.where(emitted, t_first - tbl["t_arr"], 0.0)),
        "ttft_n": jnp.sum(emitted.astype(f32)),
        "tpot_sum": jnp.sum(jnp.where(
            tpm, (t_last - t_first) / jnp.maximum(D - 1.0, 1.0), 0.0)),
        "tpot_n": jnp.sum(tpm.astype(f32)),
    }
    if tlm_edges is not None:
        # retired rows leave the window now: bucket their latencies
        # while the t_first/t_last marks still align with this t_arr
        hb = jnp.searchsorted(tlm_edges, t_first - tbl["t_arr"])
        c["tlm_ttft"] = c["tlm_ttft"].at[hb].add(emitted.astype(f32))
        hb = jnp.searchsorted(tlm_edges, t_last - tbl["t_arr"])
        c["tlm_e2e"] = c["tlm_e2e"].at[hb].add(done.astype(f32))

    # stable keep-first permutation: unique integer keys, so the result
    # is deterministic and order-preserving without relying on sort
    # stability; new rids stay in arrival order
    order = jnp.argsort(jnp.where(keep, iota, Rw + iota)).astype(jnp.int32)
    n_live = jnp.sum(keep.astype(jnp.int32))
    newpos = jnp.zeros(Rw, jnp.int32).at[order].set(iota)
    newpos = jnp.where(keep, newpos, -1)

    def remap(r):
        return jnp.where(r >= 0, newpos[jnp.clip(r, 0, Rw - 1)], -1)

    c["slot_rid"] = remap(c["slot_rid"])
    c["pf_rid"] = remap(c["pf_rid"])
    # FCFS ring: shift the live window to the front, rids remapped
    RL = c["buf"].shape[0]
    rl = jnp.arange(RL, dtype=jnp.int32)
    rwin = c["buf"][jnp.clip(c["buf_hd"] + rl, 0, RL - 1)]
    in_ring = rl < (c["buf_tl"] - c["buf_hd"])
    c["buf"] = jnp.where(in_ring, remap(rwin), -1)
    c["buf_tl"] = c["buf_tl"] - c["buf_hd"]
    c["buf_hd"] = jnp.zeros((), c["buf_hd"].dtype)

    # arrivals cursor: survivors whose arrival was already consumed are
    # exactly the non-NOT_ARRIVED kept rows, and they form a prefix
    c["aptr"] = jnp.sum((keep & (st != _NOT_ARRIVED))
                        .astype(c["aptr"].dtype))

    # splice the chunk's in-horizon rows after the survivors
    C = ch["t"].shape[0]
    chv = ch["valid"] & (ch["t"] <= h_eff)
    n_new = jnp.sum(chv.astype(jnp.int32))
    seg["n_live"] = n_live
    seg["n_new"] = n_new
    seg["overflow"] = (n_live + n_new) > Rw
    pos = jnp.arange(C, dtype=jnp.int32) + n_live

    def splice(old, newv, pad):
        p = jnp.where(iota < n_live, old[order], pad)
        return p.at[pos].set(jnp.where(chv, newv, pad), mode="drop")

    tbl["t_arr"] = splice(tbl["t_arr"], ch["t"], inf)
    tbl["cls"] = splice(tbl["cls"], ch["cls"], 0)
    tbl["P"] = splice(tbl["P"], ch["P"], 1.0)
    tbl["D"] = splice(tbl["D"], ch["D"], 1.0)
    c["st"] = splice(st, jnp.zeros(C, st.dtype), 0)
    c["t_first"] = splice(t_first, jnp.full(C, inf, f32), inf)
    c["t_last"] = splice(t_last, jnp.full(C, -inf, f32), -inf)
    if "tout" in c:  # non-fastforward carry keeps the (R,) token array
        c["tout"] = splice(c["tout"], jnp.zeros(C, f32), 0.0)

    # queue bookkeeping: per-class FCFS tables over queued + future rows
    # (in new-rid order queued rows precede future ones, so the windows
    # [0, #queued_i) are exactly the live queues)
    st2, t2, cls2 = c["st"], tbl["t_arr"], tbl["cls"]
    qf = (st2 == _QUEUED) | ((st2 == _NOT_ARRIVED) & (t2 < inf))
    I = c["qarr"].shape[0]

    def class_row(i):
        m = qf & (cls2 == i)
        r = jnp.argsort(jnp.where(m, iota, Rw + iota)).astype(jnp.int32)
        return jnp.where(iota < jnp.sum(m.astype(jnp.int32)), r, Rw)

    ci = jnp.arange(I, dtype=jnp.int32)
    tbl["class_rids"] = jax.vmap(class_row)(ci)
    c["qhead"] = jnp.zeros(I, c["qhead"].dtype)
    c["qarr"] = jax.vmap(lambda i: jnp.sum(
        ((st2 == _QUEUED) & (cls2 == i)).astype(c["qarr"].dtype)))(ci)

    tbl["A"] = jnp.sum((t2 < inf).astype(f32))
    ta = jnp.where(c["aptr"].astype(f32) < tbl["A"],
                   t2[jnp.clip(c["aptr"], 0, Rw - 1)], inf)
    c["alive"] = jnp.minimum(ta, c["t_next"].min()) <= h_eff
    return c, tbl, seg


def _run_segment(params, key, carry, i0, budget, **statics):
    """Run engine steps until the frontier, the horizon or the budget.

    Thin alias over the :func:`repro.serving.engine_jax.run` facade's
    ``segment=`` mode -- the frontier-capped while loop itself lives
    next to the step kernel in engine_jax.
    """
    return run_engine_facade(params, key, placement="single",
                             segment=(carry, i0, budget), **statics)


class StreamingEngineJAX:
    """Streamed (chunk-fed) twin of :class:`ClusterEngineJAX`.

    Same classes/policy/config inputs; the trace arrives through
    :meth:`run_stream` as a chunk source instead of being fixed at
    construction.  ``window`` is the working-set size (must exceed the
    workload's peak unfinished-request backlog plus one chunk).
    """

    def __init__(self, classes: Sequence[WorkloadClass], policy: PolicySpec,
                 cfg: EngineConfig, horizon: float, *, window: int = 8192,
                 fastforward: bool = True, telemetry=None):
        # an empty window-shaped trace gives us the full policy/params
        # lowering (and its validations) without duplicating it here
        base = ClusterEngineJAX(classes, policy, cfg,
                                tensorize_trace([], pad_to=int(window)),
                                horizon, drain=True,
                                fastforward=fastforward,
                                telemetry=telemetry)
        if base.router_kind not in ("solo_first", "local_fcfs"):
            raise ValueError(
                "StreamingEngineJAX needs a deterministic global-buffer "
                f"router (solo_first/local_fcfs), got {base.router_kind!r}")
        self._base = base
        self.window = int(window)
        self.h_eff = base.h_eff
        self.classes = base.classes
        self.I = base.I
        self.cfg = cfg
        self._statics = {k: v for k, v in base._static.items()
                         if k not in ("n_steps", "loop")}
        self.telemetry = self._statics["telemetry"]
        self._tlm_edges = (
            jnp.asarray(hist_edges(self.telemetry),
                        base.params["t_arr"].dtype)
            if self.telemetry is not None else None)

    def run_stream(self, source, seed=0,
                   max_steps: Optional[int] = None) -> dict:
        """Replay one stream; returns a summary dict (engine keys plus
        ``requests``/``n_segments``/``window_peak`` diagnostics)."""
        src = (source if hasattr(source, "next_chunk")
               else TraceChunkSource(source))
        base = self._base
        Rw = self.window
        dt = base.params["t_arr"].dtype
        st_ = self._statics
        carry = _init_carry(Rw, base.n, int(base.params["B"]), self.I, dt,
                            st_["router_kind"], st_["has_pw"],
                            st_["expiry"], st_["k_events"],
                            st_["fastforward"], st_["telemetry"])
        # the per-segment push count is bounded by the working set, not
        # the whole trace: give the ring two windows of slack
        W = int(base.params["B"]) + 1
        carry["buf"] = jnp.full(2 * Rw + W, -1, jnp.int32)
        tbl = {
            "t_arr": jnp.full(Rw, jnp.inf, dt),
            "cls": jnp.zeros(Rw, jnp.int32),
            "P": jnp.ones(Rw, dt),
            "D": jnp.ones(Rw, dt),
            "class_rids": jnp.full((self.I, Rw), Rw, jnp.int32),
            "A": jnp.zeros((), dt),
        }
        acc = {k: 0.0 for k in ("ret", "done", "ttft_sum", "ttft_n",
                                "tpot_sum", "tpot_n")}
        key = prng_key(int(seed)) if isinstance(seed, (int, np.integer)) \
            else seed
        h_eff = jnp.asarray(self.h_eff, dt)
        i = jnp.zeros((), jnp.int32)
        budget = 0
        clock_budget = None
        requests = 0
        n_segments = 0
        window_peak = 0
        occupancy = []  # kept rows right after each splice: backlog trace
        t_seam = -np.inf
        C0 = None
        pending = src.next_chunk()
        while pending is not None:
            ch = pending
            if C0 is None:
                C0 = ch.R
            elif ch.R != C0:
                raise TraceValidationError(
                    f"chunk shape changed mid-stream: {ch.R} != {C0}")
            if ch.n_real:
                t_real = ch.t[ch.valid]
                if t_real[0] < t_seam:
                    raise TraceValidationError(
                        f"stream chunks out of order: chunk starts at "
                        f"t={t_real[0]} before the previous chunk's last "
                        f"arrival t={t_seam}")
                t_seam = float(t_real[-1])
                if np.isfinite(ch.patience[ch.valid]).any():
                    raise ValueError(
                        "StreamingEngineJAX does not support deadlines "
                        "(finite patience) yet; use ClusterEngineJAX")
                if int(ch.cls[ch.valid].max(initial=0)) >= self.I:
                    raise ValueError("chunk references an unknown class")
                arrs = {
                    "t": jnp.asarray(ch.t, dt),
                    "cls": jnp.asarray(ch.cls, jnp.int32),
                    "P": jnp.asarray(ch.P, dt),
                    "D": jnp.asarray(ch.D, dt),
                    "valid": jnp.asarray(ch.valid),
                }
                b = iteration_budget(ch, self.cfg, self.h_eff)
                if clock_budget is None:
                    # the clock bound is global: never let per-chunk
                    # summation exceed arrivals + one clock bound
                    clock_budget = b
                budget += b
                carry, tbl, seg = _compact_splice(carry, tbl, arrs, h_eff,
                                                  self._tlm_edges)
                if bool(seg["overflow"]):
                    tail = occupancy[-5:]
                    trace = (", ".join(
                        f"seg{n_segments - len(tail) + j}={v}"
                        for j, v in enumerate(tail))
                        if tail else "none (overflow on first splice)")
                    raise RuntimeError(
                        f"working-set overflow at t~{t_seam:.0f} (segment "
                        f"{n_segments}): {int(seg['n_live'])} live rows + "
                        f"{int(seg['n_new'])} new > window={Rw}; raise "
                        "`window` (peak unfinished backlog exceeded); "
                        f"occupancy after recent splices: {trace}")
                occupancy.append(int(seg["n_live"]) + int(seg["n_new"]))
                window_peak = max(window_peak, occupancy[-1])
                requests += int(seg["n_new"])
                for k in acc:
                    acc[k] += float(seg[k])
            nxt = src.next_chunk()
            while nxt is not None and nxt.n_real == 0:
                nxt = src.next_chunk()
            frontier = (np.inf if nxt is None
                        else float(nxt.t[nxt.valid][0]))
            params = dict(base.params)
            params.update(tbl)
            params["frontier"] = jnp.asarray(frontier, dt)
            cap = budget if max_steps is None else min(budget, int(max_steps))
            carry, i = _run_segment(params, key, carry,
                                    i, jnp.asarray(cap, jnp.int32), **st_)
            n_segments += 1
            pending = nxt

        # residual working set + accumulators -> summary
        o = {k: np.asarray(v) for k, v in carry.items()}
        t_arr = np.asarray(tbl["t_arr"], np.float64)
        D = np.asarray(tbl["D"], np.float64)
        st = o["st"]
        t_first = o["t_first"].astype(np.float64)
        t_last = o["t_last"].astype(np.float64)
        arrivals = int(acc["ret"]) + int((st != _NOT_ARRIVED).sum())
        completions = int(acc["done"]) + int((st == _DONE).sum())
        emitted = np.isfinite(t_first)
        ttft_sum = acc["ttft_sum"] + float(
            (t_first[emitted] - t_arr[emitted]).sum())
        ttft_n = acc["ttft_n"] + float(emitted.sum())
        tpm = (st == _DONE) & (D > 1)
        tpot_sum = acc["tpot_sum"] + float(
            ((t_last[tpm] - t_first[tpm])
             / np.maximum(D[tpm] - 1.0, 1.0)).sum())
        tpot_n = acc["tpot_n"] + float(tpm.sum())
        ap = int(o["aptr"])
        A = float(np.asarray(tbl["A"]))
        next_arr = float(t_arr[ap]) if ap < A else np.inf
        next_t = min(next_arr, float(o["t_next"].min(initial=np.inf)))
        horizon = self.h_eff if self.h_eff > 0 else 1.0
        nan = float("nan")
        if self.telemetry is not None:
            # rows still in the window never hit a splice fold: bucket
            # their latencies now (same f32 values the splice fold sees)
            edges = np.asarray(self._tlm_edges)
            t32 = np.asarray(tbl["t_arr"])
            for key_, tmark, m in (
                    ("tlm_ttft", o["t_first"], emitted),
                    ("tlm_e2e", o["t_last"], st == _DONE)):
                h = o[key_].astype(np.float64, copy=True)
                np.add.at(h, np.searchsorted(edges, tmark[m] - t32[m]), 1.0)
                o[key_] = h
        telemetry = (extract_probes(o, self.telemetry, horizon=horizon,
                                    n_servers=self._base.n)
                     if self.telemetry is not None else None)
        return {
            "revenue_rate": float(o["rev"]) / horizon,
            "completion_rate": completions / arrivals if arrivals else 0.0,
            "ttft_mean": ttft_sum / ttft_n if ttft_n else nan,
            "ttft_p95": nan,  # not computable from streamed scalars
            "ttft_p99": nan,
            "tpot_mean": tpot_sum / tpot_n if tpot_n else nan,
            "tpot_p95": nan,
            "tpot_p99": nan,
            "completions": completions,
            "arrivals": arrivals,
            "abandons": int(o["abandons"]),
            "t_end": float(o["t"]),
            "budget_exhausted": float(next_t <= self.h_eff),
            "n_iters": float(o["n_iters"]),
            "n_events": float(o["n_events"]),
            "n_loop": float(o["n_loop"]),
            "n_steps": float(np.asarray(i)),
            "n_dropped": 0.0,
            "requests": requests,
            "n_segments": n_segments,
            "window_peak": window_peak,
            "window_occupancy": occupancy,
            **({"telemetry": telemetry} if telemetry is not None else {}),
        }
