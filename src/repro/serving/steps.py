"""Jit-compilable serving step functions (the data-plane compute).

Three steps, mirroring the paper's iteration taxonomy (Section 2.2):

* ``prefill_step``  -- full-sequence prefill of a request batch (the
  ``prefill_32k`` dry-run cell).
* ``decode_step``   -- one token for every active slot (solo iteration; the
  ``decode_32k`` / ``long_500k`` cells).
* ``mixed_step``    -- one C-token prefill chunk for a designated slot
  *fused with* one decode token for the other slots: the paper's mixed-mode
  GPU iteration as a single compiled program.

All are pure ``(params, state, inputs) -> (state, outputs)`` functions; the
engine (:mod:`repro.serving.engine`) wraps them with slot management, and
launch/dryrun.py lowers them on the production mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

__all__ = ["make_prefill_step", "make_decode_step", "make_mixed_step",
           "init_server_state", "greedy_sample"]


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def init_server_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Slot-structured server state: caches + per-slot bookkeeping."""
    return {
        "caches": M.init_cache(cfg, batch, max_len, dtype),
        "length": jnp.zeros((batch,), jnp.int32),   # tokens in cache
        "last_token": jnp.zeros((batch,), jnp.int32),
        "active": jnp.zeros((batch,), jnp.bool_),   # decoding slots
    }


def make_prefill_step(cfg: ModelConfig, *, kernel_impl: str = "xla",
                      unroll: bool = False, continuation: bool = False):
    """Whole-batch prefill: (params, caches, tokens, positions, stubs).

    ``continuation=True`` gives chunked-prefill semantics (queries attend
    over the cached context) -- the engine's mixed iterations use it.
    """

    def prefill_step(params, caches, tokens, positions, *, enc_frames=None,
                     prefix_embeds=None):
        logits, caches = M.forward_prefill(
            cfg, params, tokens, positions, caches,
            enc_frames=enc_frames, prefix_embeds=prefix_embeds,
            unroll=unroll, kernel_impl=kernel_impl, continuation=continuation)
        return caches, greedy_sample(logits)

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, unroll: bool = False,
                     masked: bool = True):
    """One decode token for every slot (solo iteration).

    With ``masked=True`` (the engine path) inactive slots still *compute*
    (static shapes) but never mutate their caches -- essential when a mixed
    iteration is concurrently prefilling one of the slots.  The dry-run
    lowers ``masked=False`` (all slots active), the pure decode iteration.
    """

    def merge(new, old, act):
        # cache leaves are (layer_rep, B, ...): batch is axis 1
        def one(n, o):
            m = act.reshape((1, -1) + (1,) * (n.ndim - 2))
            return jnp.where(m, n, o)
        return jax.tree.map(one, new, old)

    def decode_step(params, state):
        tokens = state["last_token"][:, None]
        positions = state["length"]
        logits, caches = M.forward_decode(
            cfg, params, tokens, positions, state["caches"], unroll=unroll)
        nxt = greedy_sample(logits)
        act = state["active"]
        if masked:
            caches = merge(caches, state["caches"], act)
        return {
            "caches": caches,
            "length": state["length"] + act.astype(jnp.int32),
            "last_token": jnp.where(act, nxt, state["last_token"]),
            "active": act,
        }, nxt

    return decode_step


def make_mixed_step(cfg: ModelConfig, chunk: int, *, unroll: bool = False):
    """Fused mixed iteration: prefill ``chunk`` tokens into slot ``p_slot``
    while decoding one token on every *other* active slot.

    The chunk runs at batch=1 on a cache slice of the slot-structured state;
    decode masks out the prefilling slot.  Returns (state, decode_tokens,
    chunk_last_logits_token).
    """
    pf = make_prefill_step(cfg, unroll=unroll, continuation=True)
    dec = make_decode_step(cfg, unroll=unroll)

    # cache leaves are (layer_rep, B, ...): the slot/batch dim is axis 1
    def slice_slot(tree, slot):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), tree)

    def write_slot(tree, sub, slot):
        return jax.tree.map(
            lambda a, s: jax.lax.dynamic_update_slice_in_dim(a, s, slot,
                                                             axis=1),
            tree, sub)

    def mixed_step(params, state, p_slot, chunk_tokens, chunk_pos0,
                   *, enc_frames=None, prefix_embeds=None):
        # --- prefill chunk on the designated slot (batch of 1)
        sub_cache = slice_slot(state["caches"], p_slot)
        positions = chunk_pos0 + jnp.arange(chunk)[None, :]
        sub_cache, tok = pf(params, sub_cache, chunk_tokens[None, :],
                            positions, enc_frames=enc_frames,
                            prefix_embeds=prefix_embeds)
        caches = write_slot(state["caches"], sub_cache, p_slot)

        # --- decode everyone else
        mask = jnp.arange(state["active"].shape[0]) != p_slot
        dstate = dict(state, caches=caches,
                      active=state["active"] & mask)
        dstate, dec_tokens = dec(params, dstate)
        # restore the prefilling slot's activity bit
        new_state = dict(
            dstate,
            active=jnp.where(mask, dstate["active"], state["active"]),
        )
        return new_state, dec_tokens, tok[0]

    return mixed_step
