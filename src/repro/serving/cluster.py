"""Real-compute cluster: gate-and-route over N ServerEngines.

The control plane is the paper's: a static mixed/solo partition from the
planning LP, the occupancy-deviation prefill gate, and the solo-first
work-conserving decode router -- but every iteration executes *actual*
jitted model compute, and cross-server decode placement performs *actual*
KV migration (extract/inject).  Virtual time advances per server with the
calibrated iteration times, so revenue/latency metrics are TPU-meaningful
while token streams are bit-exact.

This is deliberately the main policy only; the policy zoo / baselines run
in :mod:`repro.serving.engine_sim` (same scheduler semantics, calibrated
compute), mirroring the paper's own simulator/hardware split.
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.planning import PlanSolution
from repro.core.policies import OccupancyGate
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.models.config import ModelConfig

from .engine import ServerEngine, SlotRequest

__all__ = ["RealCluster", "ClusterMetrics"]


@dataclass
class ClusterMetrics:
    revenue: float = 0.0
    completions: int = 0
    arrivals: int = 0
    migrations: int = 0
    horizon: float = 0.0
    per_class_completions: dict = None

    def summary(self) -> dict:
        return {
            "revenue": self.revenue,
            "revenue_rate": self.revenue / self.horizon if self.horizon else 0,
            "completions": self.completions,
            "arrivals": self.arrivals,
            "kv_migrations": self.migrations,
            "per_class_completions": self.per_class_completions,
        }


class _View:
    def __init__(self, cl):
        self.cl = cl

    def prefill_queue_len(self, i):
        return len(self.cl.prefill_q[i])

    def prefill_in_service(self, i):
        return self.cl.X[i]

    def n_servers(self):
        return len(self.cl.engines)

    def head_of_line_class(self):
        best = None
        best_t = float("inf")
        for i, q in enumerate(self.cl.prefill_q):
            if q and q[0][0] < best_t:
                best_t, best = q[0][0], i
        return best


class RealCluster:
    def __init__(self, cfg: ModelConfig, params, classes: Sequence[WorkloadClass],
                 plan: PlanSolution, prim: ServicePrimitives, pricing: Pricing,
                 n_servers: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.classes = tuple(classes)
        self.I = len(classes)
        self.prim = prim
        self.pricing = pricing
        self.plan = plan
        self.gate = OccupancyGate(plan.x, plan.qp)
        self.view = _View(self)
        M = plan.mixed_servers(n_servers)
        self.groups = ["mixed" if s < M else "solo" for s in range(n_servers)]
        self.engines = [
            ServerEngine(cfg, params, prim=prim, max_len=max_len, seed=seed + s)
            for s in range(n_servers)
        ]
        self.prefill_q: list[deque] = [deque() for _ in range(self.I)]
        self.decode_buf: deque = deque()  # (req, sub_cache, meta)
        self.X = np.zeros(self.I)
        self.rng = np.random.default_rng(seed)
        self.metrics = ClusterMetrics(per_class_completions={})
        self._rid = itertools.count()

    # --------------------------------------------------------------- admit
    def _admit_prefills(self):
        for sid, eng in enumerate(self.engines):
            if self.groups[sid] != "mixed" or eng.has_prefill:
                continue
            if not eng.free_slots():
                continue
            waiting = [i for i in range(self.I) if self.prefill_q[i]]
            if not waiting:
                return
            i = self.gate.select(self.view, waiting)
            if i is None:
                return
            _, req, toks = self.prefill_q[i].popleft()
            eng.start_prefill(req, toks)
            self.X[i] += 1

    def _free_decode_capacity(self, sid: int) -> int:
        cap = (self.prim.batch_cap - 1 if self.groups[sid] == "mixed"
               else self.prim.batch_cap)
        return max(0, cap - self.engines[sid].n_decoding)

    def _dispatch_decodes(self):
        """Solo-first work-conserving placement with real KV injection."""
        while self.decode_buf:
            order = [s for s in range(len(self.engines))
                     if self.groups[s] == "solo"]
            order += [s for s in range(len(self.engines))
                      if self.groups[s] == "mixed"]
            placed = False
            for sid in order:
                eng = self.engines[sid]
                if self._free_decode_capacity(sid) <= 0:
                    continue
                free = eng.free_slots()
                if not free:
                    continue
                req, sub, meta, src = self.decode_buf.popleft()
                eng.inject_slot(free[0], req, sub, meta)
                if src != sid:
                    self.metrics.migrations += 1
                placed = True
                break
            if not placed:
                return

    # ----------------------------------------------------------------- run
    def run(self, requests, horizon: float) -> ClusterMetrics:
        """``requests``: iterable of (t_arrival, cls, prompt_tokens, D)."""
        heap = []
        ctr = itertools.count()
        for (t, cls, toks, D) in requests:
            heapq.heappush(heap, (t, next(ctr), "arrival", (cls, toks, D)))
        for sid in range(len(self.engines)):
            heapq.heappush(heap, (0.0, next(ctr), "iter", sid))
        now = 0.0
        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if t > horizon:
                break
            now = t
            if kind == "arrival":
                cls, toks, D = payload
                req = SlotRequest(rid=next(self._rid), cls=cls,
                                  prompt_len=len(toks), decode_len=D)
                self.prefill_q[cls].append((t, req, np.asarray(toks)))
                self.metrics.arrivals += 1
                self._admit_prefills()
            else:  # server iteration boundary
                sid = payload
                eng = self.engines[sid]
                if not eng.has_prefill and eng.n_decoding == 0:
                    # idle; poll again shortly (cheap virtual-time tick)
                    self._admit_prefills()
                    if eng.has_prefill or eng.n_decoding:
                        heapq.heappush(heap, (now, next(ctr), "iter", sid))
                    else:
                        heapq.heappush(
                            heap, (now + self.prim.tau_solo, next(ctr),
                                   "iter", sid))
                    continue
                res = eng.step()
                for req in res["completed"]:
                    self.metrics.completions += 1
                    self.metrics.per_class_completions[req.cls] = (
                        self.metrics.per_class_completions.get(req.cls, 0) + 1)
                    self.metrics.revenue += self.pricing.bundled_reward(
                        self.classes[req.cls])
                if res["prefill_done"] is not None:
                    req = res["prefill_done"]
                    self.X[req.cls] -= 1
                    # extract the prefilled KV and route via the buffer
                    r2, sub, meta = eng.extract_slot(res["prefill_slot"])
                    assert r2 is req
                    self.decode_buf.append((req, sub, meta, sid))
                    self._dispatch_decodes()
                self._admit_prefills()
                heapq.heappush(
                    heap, (now + max(res["tau"], 1e-9), next(ctr), "iter", sid))
        self.metrics.horizon = min(now, horizon)
        return self.metrics
