"""Per-server iteration-level cluster engine (the paper's "calibrated
scheduling simulator", Section 6.2).

Each logical server advances in *iterations*: a mixed iteration (one prefill
chunk of up to C tokens + up to B-1 decode streams) takes
``tau_mix(chunk) = alpha + beta * chunk`` seconds; a decode-only iteration
takes ``tau_solo(K) = a_s + b_s * K`` seconds (K = resident KV tokens; the
second-order KV slope of Fig. 3).  Requests are replayed from a trace or
sampled; the scheduler hooks implement the full policy zoo:

* gate-and-route / prioritize-and-route / SLI-aware (randomized) routers,
* EC.8.6 ablations (immediate & local-FCFS routers, no static planning),
* vLLM-style (prefill-first, *unchunked* prompt processing, local decode),
* Sarathi-style (decode-first token budget: chunk shrinks with co-resident
  decodes, local decode),
* DistServe best fixed splits (mix/solo and prefill/solo).

The engine also models server failures/recoveries and stragglers, and drives
the online controller (rolling-window replanning, elastic capacity).
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.online import OnlineController
from repro.core.policies import PolicySpec
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import Request
from repro.telemetry.probes import PyProbes, resolve_probe_spec

__all__ = ["EngineConfig", "EngineMetrics", "ClusterEngine"]


@dataclass(frozen=True)
class EngineConfig:
    prim: ServicePrimitives
    pricing: Pricing
    n_servers: int
    solo_kv_slope: float = 1.08e-7  # b_s (s per resident KV token)
    vllm_unchunked: bool = False  # process whole remaining prompt per iter
    sarathi_budget: bool = False  # decode-first chunk budget
    seed: int = 0
    record_queues_every: float = 0.0
    # Optional calibrated IterationTimeModel (repro.calibration.models
    # protocol).  None keeps the historical inline arithmetic untouched;
    # when set, tau_mix/tau_solo come from the model instead of
    # (prim, solo_kv_slope).
    iter_model: Optional[object] = None
    # Optional probe config (None/True/dict/repro.telemetry.ProbeSpec).
    # None keeps the engine untouched; otherwise a PyProbes collector
    # mirrors the device tlm_* arrays and ``metrics.telemetry`` /
    # ``lifecycle_records()`` are populated after ``run``.
    telemetry: Optional[object] = None
    # Optional heterogeneous fleet (repro.core.hetero.FleetSpec).  None
    # keeps every server on (prim, solo_kv_slope) with zero KV-transfer
    # cost; when set, each server gets its class's time surfaces and KV
    # handoff charge (fleet.n must equal n_servers; B/chunk stay
    # fleet-uniform from ``prim``).  Mutually exclusive with iter_model.
    fleet: Optional[object] = None


@dataclass
class _Job:
    req: Request
    prefill_left: int
    tokens_out: int = 0
    server: int = -1
    t_admit: float = float("nan")
    t_prefill_done: float = float("nan")
    t_first_token: float = float("nan")
    t_last_token: float = float("nan")
    pool: str = ""  # randomized router pool assignment ("solo"/"mixed")


@dataclass
class _Server:
    sid: int
    group: str  # "mixed" | "solo"
    target_group: str
    prefill: Optional[_Job] = None
    decodes: list = field(default_factory=list)
    pending_local: deque = field(default_factory=deque)  # immediate-router waits
    speed: float = 1.0
    alive: bool = True
    busy: bool = False  # an iteration is in flight
    iter_decodes: list = field(default_factory=list)  # snapshot at wake
    iter_chunk: int = 0
    # per-server time surfaces (class-resolved under EngineConfig.fleet;
    # copies of the uniform cfg values otherwise)
    alpha: float = 0.0
    beta: float = 0.0
    tau_solo: float = 0.0
    b_s: float = 0.0
    kv_xfer: float = 0.0  # KV handoff seconds per prompt token
    # link bandwidth fraction: 1.0 nominal, < 1 degraded (the "degrade"
    # capacity event); the handoff charge divides by it
    link_scale: float = 1.0

    def kv_tokens(self) -> int:
        k = sum(j.req.prompt_len + j.tokens_out for j in self.decodes)
        if self.prefill is not None:
            k += self.prefill.req.prompt_len - self.prefill.prefill_left
        return k


@dataclass
class EngineMetrics:
    horizon: float = 0.0
    revenue: float = 0.0
    arrivals: int = 0
    completions: int = 0
    abandons: int = 0
    n_iters: int = 0  # completed server iterations (throughput accounting)
    ttft: list = field(default_factory=list)
    tpot: list = field(default_factory=list)
    revenue_t: list = field(default_factory=list)  # (t, cumulative revenue)
    per_class_completions: dict = field(default_factory=dict)
    per_class_arrivals: dict = field(default_factory=dict)
    queue_trace: list = field(default_factory=list)
    # extract_probes() report when the engine ran with telemetry on;
    # never read by summary(), so summaries stay telemetry-invariant
    telemetry: Optional[dict] = None

    def revenue_rate(self) -> float:
        return self.revenue / self.horizon if self.horizon > 0 else 0.0

    def completion_rate(self) -> float:
        return self.completions / self.arrivals if self.arrivals else 0.0

    def summary(self) -> dict:
        def pct(v, q):
            return float(np.percentile(v, q)) if v else float("nan")

        return {
            "revenue_rate": self.revenue_rate(),
            "completion_rate": self.completion_rate(),
            "ttft_mean": float(np.mean(self.ttft)) if self.ttft else float("nan"),
            "ttft_p95": pct(self.ttft, 95),
            "ttft_p99": pct(self.ttft, 99),
            "tpot_mean": float(np.mean(self.tpot)) if self.tpot else float("nan"),
            "tpot_p95": pct(self.tpot, 95),
            "tpot_p99": pct(self.tpot, 99),
            "completions": self.completions,
            "arrivals": self.arrivals,
            "abandons": self.abandons,
        }


class _GateViewEngine:
    def __init__(self, eng: "ClusterEngine"):
        self.eng = eng

    def prefill_queue_len(self, i: int) -> int:
        return len(self.eng.prefill_q[i])

    def prefill_in_service(self, i: int) -> float:
        return self.eng.X[i]

    def n_servers(self) -> int:
        return self.eng.n_alive

    def head_of_line_class(self) -> Optional[int]:
        best_t, best_i = float("inf"), None
        for i, q in enumerate(self.eng.prefill_q):
            if q and q[0].req.t_arrival < best_t:
                best_t, best_i = q[0].req.t_arrival, i
        return best_i


class ClusterEngine:
    """Event-driven per-server engine."""

    def __init__(
        self,
        classes: Sequence[WorkloadClass],
        policy: PolicySpec,
        cfg: EngineConfig,
        controller: Optional[OnlineController] = None,
    ):
        self.classes = tuple(classes)
        self.I = len(self.classes)
        self.policy = policy
        self.cfg = cfg
        self.prim = cfg.prim
        self.pricing = cfg.pricing
        self.rng = np.random.default_rng(cfg.seed)
        self.controller = controller
        self.view = _GateViewEngine(self)

        n = cfg.n_servers
        M = policy.mixed_target(n)
        self.servers = [
            _Server(s, "mixed" if s < M else "solo",
                    "mixed" if s < M else "solo")
            for s in range(n)
        ]
        if cfg.fleet is not None:
            if cfg.iter_model is not None:
                raise ValueError("EngineConfig.fleet and iter_model are "
                                 "mutually exclusive")
            if cfg.fleet.n != n:
                raise ValueError(
                    f"fleet has {cfg.fleet.n} servers but n_servers={n}")
            fp = cfg.fleet.server_params(cfg.prim)
            for s, srv in enumerate(self.servers):
                srv.alpha = float(fp["alpha"][s])
                srv.beta = float(fp["beta"][s])
                srv.tau_solo = float(fp["tau_solo"][s])
                srv.b_s = float(fp["b_s"][s])
                srv.kv_xfer = float(fp["kv_xfer"][s])
        else:
            for srv in self.servers:
                srv.alpha = cfg.prim.alpha
                srv.beta = cfg.prim.beta
                srv.tau_solo = cfg.prim.tau_solo
                srv.b_s = cfg.solo_kv_slope
        self.prefill_q: list[deque] = [deque() for _ in range(self.I)]
        self.decode_buf: deque = deque()  # FCFS (single logical buffer)
        self.decode_buf_solo: deque = deque()  # randomized-router pools
        self.decode_buf_mixed: deque = deque()
        self.X = np.zeros(self.I)  # prefills in service per class
        self.metrics = EngineMetrics()
        self._heap: list = []
        self._counter = itertools.count()
        self._now = 0.0
        self._probes: Optional[PyProbes] = None
        self._jobs: list = []  # lifecycle records (telemetry runs only)

    # ------------------------------------------------------------------ utils
    @property
    def n_alive(self) -> int:
        return sum(1 for s in self.servers if s.alive)

    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._heap, (t, next(self._counter), kind, payload))

    def _decode_cap(self, srv: _Server) -> int:
        B = self.prim.batch_cap
        if self.policy.partition == "none":
            return B - (1 if srv.prefill is not None else 0)
        if srv.group == "mixed":
            if self.policy.prefill_only_mixed:
                return 0
            # paper Section 4.1: "permanently reserves one slot for prefill
            # (or equivalently, prioritize new prefill over decode jobs)" --
            # we implement the work-conserving equivalent: the slot is used
            # by decode while no prefill is active, and prefill admission
            # takes priority as soon as one frees.
            return B - (1 if srv.prefill is not None else 0)
        return B

    def _can_prefill(self, srv: _Server) -> bool:
        if not srv.alive or srv.prefill is not None:
            return False
        if self.policy.partition == "none":
            ok = len(srv.decodes) + len(srv.pending_local) < self.prim.batch_cap
            if self.cfg.sarathi_budget:
                # decode-first: keep headroom so the finished prefill can decode
                ok = ok and len(srv.decodes) < self.prim.batch_cap - 1
            return ok
        if srv.group != "mixed":
            return False
        if self.policy.router == "immediate":
            return len(srv.decodes) + len(srv.pending_local) < self._decode_cap(srv)
        # prefill takes the slot it shares with decode: need one slot free
        # (prefill-priority admission retries at each decode completion)
        return len(srv.decodes) <= self.prim.batch_cap - 1

    # ------------------------------------------------------------ revenue
    def _credit(self, amount: float):
        self.metrics.revenue += amount
        self.metrics.revenue_t.append((self._now, self.metrics.revenue))

    # ------------------------------------------------------------ scheduling
    def _expire_queue(self, q: deque) -> None:
        while q and self._now - q[0].req.t_arrival > q[0].req.patience:
            q.popleft()
            self.metrics.abandons += 1

    def _admit_prefills(self) -> None:
        gate = self.policy.gate
        for srv in self.servers:
            if not self._can_prefill(srv):
                continue
            for q in self.prefill_q:
                self._expire_queue(q)
            waiting = [i for i in range(self.I) if self.prefill_q[i]]
            if not waiting:
                return
            i = gate.select(self.view, waiting)
            if i is None:
                return
            job = self.prefill_q[i].popleft()
            srv.prefill = job
            job.server = srv.sid
            job.t_admit = self._now
            self.X[i] += 1
            if self._probes is not None:
                self._probes.count(self._now, admit_class=i)
            self._wake(srv)

    def _free_slots(self, srv: _Server) -> int:
        return self._decode_cap(srv) - len(srv.decodes)

    def _place_decode(self, job: _Job, srv: _Server) -> None:
        srv.decodes.append(job)
        job.server = srv.sid
        self._wake(srv)

    def _dispatch_decodes(self) -> None:
        """Fill free decode slots from buffers per the router discipline."""
        pol = self.policy
        if pol.router == "randomized":
            for pool, buf, wkey in (
                ("solo", self.decode_buf_solo, "pool_weights_solo"),
                ("mixed", self.decode_buf_mixed, "pool_weights_mixed"),
            ):
                servers = [
                    s for s in self.servers
                    if s.alive and s.group == pool and self._free_slots(s) > 0
                ]
                w = getattr(pol, wkey)
                while servers and buf:
                    job = self._pick_from_buffer(buf, w)
                    if job is None:
                        break
                    srv = servers[int(self.rng.integers(len(servers)))]
                    self._place_decode(job, srv)
                    servers = [s for s in servers if self._free_slots(s) > 0]
            return
        buf = self.decode_buf
        if not buf:
            return
        if pol.router == "solo_first":
            order = [s for s in self.servers if s.alive and s.group == "solo"]
            order += [s for s in self.servers if s.alive and s.group == "mixed"]
        else:  # local_fcfs and the no-partition ablations: any free slot
            order = [s for s in self.servers if s.alive]
        for srv in order:
            while buf and self._free_slots(srv) > 0:
                job = buf.popleft()
                if np.isfinite(job.req.patience) and (
                    self._now - job.t_prefill_done > job.req.patience
                ):
                    self.metrics.abandons += 1
                    continue
                self._place_decode(job, srv)
            if not buf:
                break

    def _pick_from_buffer(self, buf: deque, weights) -> Optional[_Job]:
        while buf:
            if weights is None:
                job = buf.popleft()
            else:
                # EC.7 general policy: class-weighted selection among waiting
                present = {}
                for k, j in enumerate(buf):
                    present.setdefault(j.req.cls, k)
                cls_ids = list(present)
                w = np.array([max(weights[c], 0.0) for c in cls_ids])
                if w.sum() <= 0:
                    job = buf.popleft()
                else:
                    c = cls_ids[int(self.rng.choice(len(cls_ids), p=w / w.sum()))]
                    idx = present[c]
                    buf.rotate(-idx)
                    job = buf.popleft()
                    buf.rotate(idx)
            if np.isfinite(job.req.patience) and (
                self._now - job.t_prefill_done > job.req.patience
            ):
                self.metrics.abandons += 1
                continue
            return job
        return None

    def _route_finished_prefill(self, job: _Job, srv: _Server) -> None:
        pol = self.policy
        if pol.charging == "separate":
            self._credit(self.pricing.c_p * job.req.prompt_len)
        job.t_prefill_done = self._now
        if pol.router == "immediate":
            if self._free_slots(srv) > 0:
                self._place_decode(job, srv)
            else:
                srv.pending_local.append(job)
            return
        if pol.router == "randomized":
            p = float(pol.solo_prob[job.req.cls])
            if self.rng.random() <= p:
                job.pool = "solo"
                self.decode_buf_solo.append(job)
            else:
                job.pool = "mixed"
                self.decode_buf_mixed.append(job)
        else:
            self.decode_buf.append(job)
        self._dispatch_decodes()

    # ------------------------------------------------------------ iterations
    def _iteration_time(self, srv: _Server) -> float:
        m = self.cfg.iter_model
        if m is not None:
            if srv.prefill is not None and srv.iter_chunk > 0:
                return m.tau_mix(srv.iter_chunk) * srv.speed
            return m.tau_solo(srv.kv_tokens()) * srv.speed
        if srv.prefill is not None and srv.iter_chunk > 0:
            t = (srv.alpha + srv.beta * srv.iter_chunk) * srv.speed
            if srv.kv_xfer > 0.0 and srv.iter_chunk >= srv.prefill.prefill_left:
                # finishing chunk: the KV cache ships to the decode pool
                # and occupies the server for bytes-over-bandwidth seconds
                # (link time -- NOT scaled by compute speed, but divided
                # by the link's current bandwidth fraction).
                t += (srv.kv_xfer / srv.link_scale) * srv.prefill.req.prompt_len
            return t
        k = srv.kv_tokens()
        return (srv.tau_solo + srv.b_s * k) * srv.speed

    def _chunk_for(self, srv: _Server) -> int:
        left = srv.prefill.prefill_left
        if self.cfg.vllm_unchunked:
            return left
        if self.cfg.sarathi_budget:
            budget = self.prim.chunk - len(srv.decodes)
            return max(0, min(left, budget))
        return min(left, self.prim.chunk)

    def _wake(self, srv: _Server) -> None:
        if srv.busy or not srv.alive:
            return
        if srv.prefill is None and not srv.decodes:
            return  # idle; woken on assignment
        srv.busy = True
        # Snapshot this iteration's participants: jobs joining mid-iteration
        # wait for the next iteration boundary (continuous batching semantics).
        srv.iter_decodes = list(srv.decodes)
        srv.iter_chunk = self._chunk_for(srv) if srv.prefill is not None else 0
        self._push(self._now + self._iteration_time(srv), "iter", srv.sid)

    def _finish_iteration(self, srv: _Server) -> None:
        srv.busy = False
        if not srv.alive:
            return
        self.metrics.n_iters += 1
        # 1) decode streams emit one token each (snapshot participants only)
        done = []
        for job in srv.iter_decodes:
            job.tokens_out += 1
            if np.isnan(job.t_first_token):
                job.t_first_token = self._now
                self.metrics.ttft.append(self._now - job.req.t_arrival)
                if self._probes is not None:
                    self._probes.observe_ttft(self._now - job.req.t_arrival)
            job.t_last_token = self._now
            if job.tokens_out >= job.req.decode_len:
                done.append(job)
        for job in done:
            srv.decodes.remove(job)
            self.metrics.completions += 1
            if self._probes is not None:
                self._probes.observe_e2e(self._now - job.req.t_arrival)
            self.metrics.per_class_completions[job.req.cls] = (
                self.metrics.per_class_completions.get(job.req.cls, 0) + 1
            )
            if job.req.decode_len > 1:
                self.metrics.tpot.append(
                    (job.t_last_token - job.t_first_token)
                    / (job.req.decode_len - 1)
                )
            if self.policy.charging == "separate":
                self._credit(self.pricing.c_d * job.req.decode_len)
            else:
                self._credit(
                    self.pricing.c_p * job.req.prompt_len
                    + self.pricing.c_d * job.req.decode_len
                )
        # 2) prefill chunk progress
        if srv.prefill is not None:
            job = srv.prefill
            if srv.iter_chunk > 0:
                job.prefill_left -= srv.iter_chunk
            if job.prefill_left <= 0:
                srv.prefill = None
                self.X[job.req.cls] -= 1
                self._route_finished_prefill(job, srv)
        # 3) local pending decode starts (immediate router)
        while srv.pending_local and self._free_slots(srv) > 0:
            self._place_decode(srv.pending_local.popleft(), srv)
        # 4) group flips (non-preemptive replanning)
        if srv.target_group != srv.group and srv.prefill is None:
            if srv.target_group == "solo" or len(srv.decodes) <= (
                self.prim.batch_cap - 1
            ):
                srv.group = srv.target_group
        # 5) refill work
        self._dispatch_decodes()
        self._admit_prefills()
        self._wake(srv)

    # ------------------------------------------------------------ control
    def set_mixed_target(self, m: int) -> None:
        """Retarget the mixed/solo split (online replanning, Eq. 51)."""
        alive = [s for s in self.servers if s.alive]
        mixed = [s for s in alive if s.target_group == "mixed"]
        solo = [s for s in alive if s.target_group == "solo"]
        if len(mixed) > m:
            # prefer flipping servers without an active prefill
            mixed.sort(key=lambda s: (s.prefill is not None, len(s.decodes)))
            for s in mixed[: len(mixed) - m]:
                s.target_group = "solo"
                if s.prefill is None:
                    s.group = "solo"
        elif len(mixed) < m:
            solo.sort(key=lambda s: len(s.decodes))
            for s in solo[: m - len(mixed)]:
                s.target_group = "mixed"
                if len(s.decodes) <= self.prim.batch_cap - 1:
                    s.group = "mixed"
        self._dispatch_decodes()
        self._admit_prefills()

    def fail_server(self, sid: int) -> None:
        srv = self.servers[sid]
        if not srv.alive:
            return
        srv.alive = False
        # active prefill loses progress; decodes lose KV -> re-prefill
        if srv.prefill is not None:
            j = srv.prefill
            j.prefill_left = j.req.prompt_len
            self.X[j.req.cls] -= 1
            self.prefill_q[j.req.cls].appendleft(j)
            srv.prefill = None
        for j in srv.decodes:
            j.prefill_left = j.req.prompt_len
            j.tokens_out = 0
            self.prefill_q[j.req.cls].appendleft(j)
        srv.decodes.clear()
        while srv.pending_local:
            self.decode_buf.append(srv.pending_local.popleft())
        if self.controller is not None:
            self.controller.set_capacity(self.n_alive, self._now)
            self.set_mixed_target(self.controller.mixed_target())

    def recover_server(self, sid: int) -> None:
        srv = self.servers[sid]
        srv.alive = True
        # rejoin in the group the plan targets (a controller may retarget
        # immediately below); never clobber target_group.
        srv.group = srv.target_group
        if self.controller is not None:
            self.controller.set_capacity(self.n_alive, self._now)
            self.set_mixed_target(self.controller.mixed_target())
        self._dispatch_decodes()
        self._admit_prefills()

    def set_straggler(self, sid: int, speed: float) -> None:
        self.servers[sid].speed = speed

    def set_link(self, sid: int, scale: float) -> None:
        """Degrade/restore one server's KV handoff link (capacity
        "degrade" event): ``scale`` is the remaining bandwidth fraction
        (1.0 restores nominal).  Unlike fail/recover the server count is
        unchanged, so the controller replans directly -- transfer-adjusted
        service rates shift even though capacity does not."""
        if scale <= 0 or not np.isfinite(scale):
            raise ValueError(f"link scale must be positive, got {scale}")
        self.servers[sid].link_scale = float(scale)
        if self.controller is not None:
            self._publish_plan(self.controller.replan(self._now))

    def _publish_plan(self, plan) -> None:
        """Push a fresh controller plan into the live policy (shared by
        the periodic control epoch and the degrade hook)."""
        gate = self.policy.gate
        if hasattr(gate, "update_targets"):
            gate.update_targets(plan.x, plan.qp)
        self.policy.plan = plan
        self.set_mixed_target(self.controller.mixed_target())

    # ------------------------------------------------------------ main loop
    def run(self, requests: Sequence[Request], horizon: float,
            failure_events: Sequence[tuple] = (),
            drain: bool = False) -> EngineMetrics:
        """Replay `requests` until `horizon`.

        ``failure_events``: iterable of
        (t, "fail"|"recover"|"straggle"|"degrade", sid[, speed/scale]).
        ``drain=False`` follows the paper's Section 6.2 convention (stop at the
        last prompt arrival); ``drain=True`` runs to `horizon`.
        """
        last_arrival = max(
            (r.t_arrival for r in requests if r.t_arrival <= horizon),
            default=horizon,
        )
        h_eff = horizon if drain else min(horizon, last_arrival)
        for r in requests:
            if r.t_arrival <= h_eff:
                self._push(r.t_arrival, "arrival", r)
        for ev in failure_events:
            self._push(ev[0], ev[1], ev[2:])
        if self.controller is not None:
            self._push(0.0, "control", None)
        next_qrec = 0.0
        tspec = resolve_probe_spec(getattr(self.cfg, "telemetry", None))
        if tspec is not None:
            self._probes = PyProbes(
                tspec, horizon=h_eff if h_eff > 0 else 1.0,
                n_servers=self.cfg.n_servers, n_classes=self.I)
        prev_ab = self.metrics.abandons

        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > h_eff:
                break
            self._now = t
            if kind == "arrival":
                r: Request = payload
                self.metrics.arrivals += 1
                self.metrics.per_class_arrivals[r.cls] = (
                    self.metrics.per_class_arrivals.get(r.cls, 0) + 1
                )
                job = _Job(r, prefill_left=r.prompt_len)
                self.prefill_q[r.cls].append(job)
                if self._probes is not None:
                    self._jobs.append(job)
                if self.controller is not None:
                    self.controller.observe_arrival(t, r.cls)
                self._admit_prefills()
            elif kind == "iter":
                self._finish_iteration(self.servers[payload])
            elif kind == "control":
                plan = self.controller.maybe_replan(t)
                if plan is not None:
                    self._publish_plan(plan)
                self._push(t + self.controller.cfg.replan_every, "control", None)
            elif kind == "fail":
                self.fail_server(payload[0])
            elif kind == "recover":
                self.recover_server(payload[0])
            elif kind == "straggle":
                self.set_straggler(payload[0], payload[1])
            elif kind == "degrade":
                self.set_link(payload[0], payload[1])
            if self._probes is not None:
                if self.metrics.abandons > prev_ab:
                    self._probes.count(
                        t, drops=self.metrics.abandons - prev_ab)
                    prev_ab = self.metrics.abandons
                self._probes.sample(
                    t,
                    queue_depth=[len(q) for q in self.prefill_q],
                    decode_occupancy=sum(
                        len(s.decodes) for s in self.servers),
                    prefill_in_flight=sum(
                        1 for s in self.servers if s.prefill is not None),
                    busy=[s.busy for s in self.servers])
            if (
                self.cfg.record_queues_every > 0
                and self._now >= next_qrec
            ):
                self.metrics.queue_trace.append(
                    (
                        self._now,
                        [len(q) for q in self.prefill_q],
                        len(self.decode_buf)
                        + len(self.decode_buf_solo)
                        + len(self.decode_buf_mixed),
                    )
                )
                next_qrec = self._now + self.cfg.record_queues_every

        self.metrics.horizon = h_eff
        if self._probes is not None:
            self.metrics.telemetry = self._probes.extract()
        return self.metrics

    def lifecycle_records(self, limit: Optional[int] = None) -> list:
        """Request-lifecycle records for the Chrome-trace exporter
        (:func:`repro.telemetry.trace.lifecycle_events`).  This engine
        knows all three phase boundaries (admit, prefill-done, first
        emission), so the trace renders queue/prefill/decode spans."""
        if self._probes is None:
            raise ValueError("lifecycle records need a telemetry-enabled "
                             "run: set EngineConfig.telemetry")
        recs = []
        for job in (self._jobs if limit is None else self._jobs[:limit]):
            done = job.tokens_out >= job.req.decode_len
            recs.append({
                "rid": int(job.req.rid),
                "cls": self.classes[job.req.cls].name,
                "t_arr": float(job.req.t_arrival),
                "t_admit": float(job.t_admit),
                "t_prefill_done": float(job.t_prefill_done),
                "t_first": float(job.t_first_token),
                "t_last": float(job.t_last_token),
                "state": "done" if done else "active",
            })
        return recs
