"""Real-compute logical server: B decode slots over a jitted model replica.

This is the data plane behind ``examples/serve_cluster.py`` and
``launch/serve.py``: actual ``forward_prefill`` / ``forward_decode`` compute
(compiled once per mode), slot-structured KV caches, chunked prefill fused
with decode (the paper's mixed iteration), and **KV extraction/injection**
for cross-server decode routing (the real cost behind the paper's "virtual
decode buffer" abstraction).

Iteration *times* on CPU are not meaningful for TPU planning, so the engine
reports calibrated iteration times from ServicePrimitives alongside the real
token outputs -- exactly the paper's split between GPU physics (calibrated
tau) and scheduling semantics.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ServicePrimitives
from repro.models.config import ModelConfig

from .steps import (init_server_state, make_decode_step, make_mixed_step,
                    make_prefill_step)

__all__ = ["SlotRequest", "ServerEngine"]


@dataclass
class SlotRequest:
    """Host-side view of a request occupying a slot."""

    rid: int
    cls: int
    prompt_len: int
    decode_len: int  # target output tokens (trace-known, as in the paper)
    tokens_out: int = 0
    out_tokens: list = field(default_factory=list)


class ServerEngine:
    def __init__(self, cfg: ModelConfig, params, *, prim: ServicePrimitives,
                 max_len: int, dtype=jnp.float32, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.prim = prim
        self.B = prim.batch_cap
        self.chunk = prim.chunk
        self.max_len = max_len
        self.state = init_server_state(cfg, self.B, max_len, dtype)
        self._decode = jax.jit(make_decode_step(cfg))
        self._mixed = jax.jit(make_mixed_step(cfg, self.chunk))
        self.slots: list[Optional[SlotRequest]] = [None] * self.B
        # host-side prefill progress (one prefill at a time, paper Section 2)
        self.prefill: Optional[tuple[SlotRequest, np.ndarray, int]] = None
        self.prefill_slot: int = -1
        self.rng = np.random.default_rng(seed)

    # ------------------------------------------------------------- capacity
    def free_slots(self) -> list[int]:
        reserved = {self.prefill_slot} if self.prefill else set()
        return [i for i, s in enumerate(self.slots)
                if s is None and i not in reserved]

    @property
    def has_prefill(self) -> bool:
        return self.prefill is not None

    @property
    def n_decoding(self) -> int:
        return sum(
            1 for i, s in enumerate(self.slots)
            if s is not None and i != self.prefill_slot)

    # ------------------------------------------------------------- control
    def start_prefill(self, req: SlotRequest, prompt_tokens: np.ndarray):
        assert self.prefill is None, "one prefill per server"
        free = self.free_slots()
        assert free, "no slot for prefill"
        self.prefill_slot = free[0]
        self.prefill = (req, np.asarray(prompt_tokens, np.int32), 0)
        self.slots[self.prefill_slot] = req

    def extract_slot(self, slot: int):
        """Pull a slot's KV/state out (host trees) for migration."""
        sub = jax.tree.map(lambda a: np.asarray(a[:, slot:slot + 1]),
                           self.state["caches"])
        meta = {
            "length": int(self.state["length"][slot]),
            "last_token": int(self.state["last_token"][slot]),
        }
        req = self.slots[slot]
        # clear the slot
        self.state["length"] = self.state["length"].at[slot].set(0)
        self.state["active"] = self.state["active"].at[slot].set(False)
        self.slots[slot] = None
        return req, sub, meta

    def inject_slot(self, slot: int, req: SlotRequest, sub, meta):
        """Install a migrated (or freshly prefilled) KV into a local slot."""
        assert self.slots[slot] is None

        def put(a, s):
            return jax.lax.dynamic_update_slice_in_dim(
                a, jnp.asarray(s, a.dtype), slot, axis=1)

        self.state["caches"] = jax.tree.map(put, self.state["caches"], sub)
        self.state["length"] = self.state["length"].at[slot].set(
            meta["length"])
        self.state["last_token"] = self.state["last_token"].at[slot].set(
            meta["last_token"])
        self.state["active"] = self.state["active"].at[slot].set(True)
        self.slots[slot] = req

    def activate_slot(self, slot: int):
        """Begin decoding a slot that was prefilled locally."""
        self.state["active"] = self.state["active"].at[slot].set(True)

    # ----------------------------------------------------------- iteration
    def step(self) -> dict:
        """Run one iteration (mixed if a prefill is staged, else solo).

        Returns {"tau": calibrated seconds, "completed": [SlotRequest],
        "prefill_done": SlotRequest | None, "prefill_slot": int}.
        """
        out = {"tau": 0.0, "completed": [], "prefill_done": None,
               "prefill_slot": -1}
        if self.prefill is not None:
            req, toks, done = self.prefill
            n = min(self.chunk, len(toks) - done)
            chunk = np.zeros((self.chunk,), np.int32)
            chunk[:n] = toks[done:done + n]
            self.state, dec_tokens, _ = self._mixed(
                self.params, self.state, self.prefill_slot,
                jnp.asarray(chunk), jnp.full((1, 1), done, jnp.int32))
            # fix the slot's length to true progress (chunk may be padded)
            slot = self.prefill_slot
            self.state["length"] = self.state["length"].at[slot].set(
                done + n)
            self.state["last_token"] = self.state["last_token"].at[slot].set(
                int(toks[done + n - 1]))
            out["tau"] = self.prim.alpha + self.prim.beta * n
            self._account_decode(dec_tokens, skip=slot, out=out)
            if done + n >= len(toks):
                out["prefill_done"] = req
                out["prefill_slot"] = slot
                self.prefill = None
                self.prefill_slot = -1
            else:
                self.prefill = (req, toks, done + n)
        else:
            self.state, dec_tokens = self._decode(self.params, self.state)
            out["tau"] = self.prim.tau_solo
            self._account_decode(dec_tokens, skip=-1, out=out)
        return out

    def _account_decode(self, dec_tokens, *, skip: int, out: dict):
        toks = np.asarray(dec_tokens)
        for i, req in enumerate(self.slots):
            if req is None or i == skip or i == self.prefill_slot:
                continue
            if not bool(self.state["active"][i]):
                continue
            req.tokens_out += 1
            req.out_tokens.append(int(toks[i]))
            if req.tokens_out >= req.decode_len:
                out["completed"].append(req)
                self.state["active"] = self.state["active"].at[i].set(False)
                self.state["length"] = self.state["length"].at[i].set(0)
                self.slots[i] = None
