"""Serving substrate: jitted steps, real-compute engine/cluster, calibrated
iteration-level cluster simulator and its jit/vmap trace-replay twin."""

from .steps import (  # noqa: F401
    init_server_state,
    make_decode_step,
    make_mixed_step,
    make_prefill_step,
)
from .engine_sim import ClusterEngine, EngineConfig, EngineMetrics  # noqa: F401
from .engine_jax import ClusterEngineJAX  # noqa: F401
