"""JAX-batched iteration-level trace-replay engine (jit + vmap).

Same system as :class:`repro.serving.engine_sim.ClusterEngine` -- the
paper's calibrated per-server scheduling simulator (Section 6.2): each
logical server advances in iterations, a mixed iteration (one prefill
chunk of up to C tokens + co-resident decode streams) takes ``tau_mix =
alpha + beta * chunk`` seconds, a decode-only iteration ``tau_solo(K) =
a_s + b_s * K`` (K = resident KV tokens) -- re-expressed so the event
loop becomes a fixed-budget scanned step function and a replication
batch one ``jax.vmap`` over PRNG keys, following the
``repro.core.ctmc_jax`` playbook.  The Python :class:`ClusterEngine`
remains the semantics oracle; ``tests/test_engine_jax.py`` holds the two
engines to statistical equivalence on shared traces.

**Tensorized traces.**  Input is a :class:`repro.data.traces.TraceTensors`
(padded ``(rid, t, class, P, D, patience)`` arrays with a max-requests
cap).  Requests are re-numbered in arrival order, which makes every
queue a *pointer pair over a precomputed table*: arrivals are consumed
by one monotone cursor (arrival times are sorted), and each class's
FCFS prefill queue is a sliding window ``[qhead_i, qarr_i)`` over the
host-precomputed table of that class's rids in arrival order.  The
decode buffer is a ring of rids (pushes are monotone -- each request is
buffered at most once -- so the ring never wraps).  Per-server residency
lives in ``(n, B)`` slot arrays; per-request lifecycle state lives in
``(R,)`` arrays touched only by point gathers and a small, fixed number
of scatters.  One event costs ``O(n*B + B + I)`` *work*, independent of
the trace length ``R``; since a point-scatter costs a full array pass on
CPU XLA, the step is additionally organised to touch each ``(R,)`` array
at most once (all lifecycle transitions flush through ONE combined
scatter-max -- the state codes are ordered along the lifecycle, so max
composes even when a request transitions twice in one event).  This is
what makes the step competitive with (and ~10x faster than, batched)
the Python heap loop.

**One event per step.**  Each step advances to the next event -- the
earliest pending arrival or the earliest iteration boundary (``argmin``
over per-server ``t_next``; ties resolve arrival-first, matching the
Python heap's push order) -- and applies it branchlessly:

1. decode emissions for the finishing server's snapshot participants
   (a per-slot ``live`` flag replicates continuous-batching semantics:
   jobs placed mid-iteration wait for the next boundary),
2. prefill-chunk progress (tracked per *server* -- one active prefill
   each -- so it never touches the request axis); a finished prefill is
   pushed to the decode buffer (or per-server pending state for the
   ``immediate`` router),
3. decode dispatch.  At most ``freed-slots + 1 <= B + 1`` placements
   can happen per event (an invariant of the dispatch discipline), so
   for the deterministic global-buffer routers dispatch is ONE
   closed-form ranked assignment over a ``B+1`` window of the FCFS
   ring: servers contribute free slots in routing order to a cumulative
   array, ring jobs map FCFS rank ``j`` to the server covering slot
   ``j`` -- exactly the Python engine's fill-servers-in-order /
   jobs-in-FCFS-order loop with no sequential sub-steps.  The
   ``immediate`` and ``randomized`` routers keep a bounded placement
   loop (per-placement uniform server draws + EC.7 class weights, like
   the Python engine's rng usage),
4. at most one prefill admission via a branchless gate ``argmax``
   (occupancy deviation with queue-deviation tie-break, decode/prompt
   priority ratio, or the exact head-of-line class for FCFS -- exact,
   not the aggregate CTMC's proportional draw, because the queue heads
   are available here).  One admission per event suffices: the gate
   family maintains the invariant that after every event either no
   prefill slot is free or no admissible class waits, and each event
   frees at most one prefill slot or adds one waiting job,
5. one wake pass (slot snapshot + iteration-time computation) after
   admission -- the Python engine's step-5 order; a server the dispatch
   phase would have woken while idle which then drew the admission
   starts decode-only, its prefill waiting for the next boundary,
   exactly like the oracle.

**Iteration budget and loop form.**  The step budget is the minimum of
two *hard* bounds (no stochastic tail -- everything is deterministic
given the trace): the pathwise bound ``arrivals + sum_r ceil(P_r /
chunk_min) + sum_r D_r`` (every iteration advances a prefill chunk or
emits at least one decode token) and the clock bound ``arrivals + n *
(h_eff / tau_min + 1)`` (every iteration lasts at least ``tau_min =
min(alpha + beta, tau_solo)``).  ``loop="while"`` (default) runs the
step under ``jax.lax.while_loop`` capped at that budget but exiting as
soon as no event is pending before the horizon; ``loop="scan"`` runs
the strict fixed-shape ``jax.lax.scan`` over the full budget (useful
for profiling or step-coupled experiments -- the two forms are
bitwise-identical in their results, the scan just pays for its no-op
tail).  If a caller-supplied ``max_steps`` truncates the budget, the
engine reports ``budget_exhausted`` (next pending event still before
the horizon) -- detected, never silent.  ``docs/SIMULATORS.md`` carries
the derivation.

**Multi-event blocks (``k_events``).**  The scanned body can process
``k_events`` consecutive events per step (default 1 -- the historical
one-event body).  The k-unrolled block is *bitwise identical* to k
single-event steps (``tests/test_engine_diff.py`` pins this) because
every cross-event interaction inside a block goes through carry state
that is updated immediately, while the expensive ``(R,)``-array writes
are *deferred and merged*: lifecycle codes flush through one k-way
combined scatter-max per block (codes are monotone along the
lifecycle, so max composes), first/last-emission times through one
combined scatter-min/-max (write-only in-step), decode-buffer ring
pushes through one k-point scatter (in-block pops overlay the pending
pushes onto the ``B+1`` dispatch window), and the ``(R,)``
resident-token array is replaced by a dense ``(n, B)`` per-slot
counter (each request occupies exactly one decode slot exactly once,
so the counter carries the same information with zero request-axis
traffic).  A block whose horizon/budget exit lands mid-block simply
runs its remaining events as proven no-ops.  This cuts the per-event
``(R,)``-pass count from ~5 to ~4/k -- which is what the per-event
wall time is made of on CPU XLA -- for the deterministic global-buffer
routers; the immediate/randomized routers must keep their per-event
lifecycle reads and gain only the write-only deferrals.

**Documented deviations** from the Python oracle (all measure-zero or
deadline-only; the equivalence tests quantify them):

* deadline expiry is checked at pop time like the Python engine, but an
  expired pop consumes one of the event's bounded placements, and queue
  expiry drops at most one expired head per class per event (the Python
  engine drains all of them) -- identical for the default ``patience =
  inf`` traces (where the whole expiry machinery compiles away), a
  small lag otherwise;
* when several decodes are placed on one idle server in a single event,
  all of them join its first iteration (the Python engine wakes the
  server at the first placement, so later placements wait a boundary);
* the randomized router consumes a different PRNG stream (per-step
  ``fold_in`` draws vs. a shared ``numpy`` generator), so randomized
  policies match statistically, not bitwise;
* exact-tie tie-breaks (simultaneous events, equal-arrival FCFS heads)
  resolve by index rather than heap counter.

Not supported (use the Python engine): server failures/recoveries,
stragglers, the online controller (rolling-window replanning), and
``record_queues_every`` traces.  Replays beyond the host-padded-table
memory ceiling live in :mod:`repro.serving.engine_stream`
(:class:`StreamingEngineJAX`), which drives this same step function
over a compacted working-set window fed by trace chunks.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import prng_key
from repro.core.ctmc_jax import _categorical
from repro.core.policies import (FCFSGate, OccupancyGate, PolicySpec,
                                 PriorityRatioGate)
from repro.core.types import WorkloadClass
from repro.data.traces import TraceTensors, tensorize_trace
from repro.telemetry.probes import (extract_probes, hist_edges,
                                    probe_carry, resolve_probe_spec,
                                    wrap_engine_step_probes)

from .engine_sim import EngineConfig

__all__ = ["ClusterEngineJAX", "iteration_budget", "run",
           "run_engine", "run_engine_batch", "run_engine_multi"]

# request lifecycle (int32 codes carried through the scan)
_NOT_ARRIVED, _QUEUED, _PREFILL, _BUF, _DECODE, _DONE, _ABANDONED = range(7)

_EPS_TARGET = 1e-12  # OccupancyGate's "class is never admitted" threshold


def _gate_kind(policy: PolicySpec) -> str:
    gate = policy.gate
    if isinstance(gate, OccupancyGate):
        return "occupancy"
    if isinstance(gate, PriorityRatioGate):
        return "priority"
    if isinstance(gate, FCFSGate):
        return "fcfs"
    raise ValueError(
        f"engine_jax does not support gate {type(gate).__name__}; "
        "use the Python ClusterEngine")


def iteration_budget(tt: TraceTensors, cfg: EngineConfig, h_eff: float,
                     *, arrived: Optional[np.ndarray] = None) -> int:
    """Hard upper bound on events (arrivals + iteration completions).

    ``min(pathwise, clock)`` -- both bounds are deterministic given the
    trace, so no Poisson slack is needed (see the module docstring and
    ``docs/SIMULATORS.md`` for the derivation).
    """
    prim = cfg.prim
    if arrived is None:
        arrived = tt.valid & (tt.t <= h_eff)
    A = int(arrived.sum())
    P = tt.P[arrived].astype(np.float64)
    D = tt.D[arrived].astype(np.float64)
    if cfg.vllm_unchunked:
        chunks = np.ones_like(P)
    elif cfg.sarathi_budget:
        c_min = max(1, prim.chunk - (prim.batch_cap - 1))
        chunks = np.ceil(P / c_min)
    else:
        chunks = np.ceil(P / prim.chunk)
    pathwise = float(chunks.sum() + D.sum())
    m = cfg.iter_model
    if m is not None:
        # lower-bound the iteration time under the plugged model: affine
        # surfaces are minimal at (C=1, K=0); a table model's true min is
        # over its knot values (constant extrapolation beyond them)
        tau_min = min(m.tau_mix(1.0), m.tau_solo(0.0))
        if hasattr(m, "knots"):
            kn = m.knots()
            tau_min = min(min(kn["mix_y"]), min(kn["solo_y"]))
    elif cfg.fleet is not None:
        # fastest class lower-bounds every server's iteration time (the
        # KV-transfer charge only ever adds time, so it never loosens
        # this bound)
        fp = cfg.fleet.server_params(prim)
        tau_min = float(min((fp["alpha"] + fp["beta"]).min(),
                            fp["tau_solo"].min()))
    else:
        tau_min = min(prim.alpha + prim.beta, prim.tau_solo)
    clock = cfg.n_servers * (h_eff / tau_min + 1.0)
    return A + int(np.ceil(min(pathwise, clock))) + 16


_FFWD_JMAX = 64  # boundaries scanned per fast-forward window (per step)


def _build_step(params: dict, key, *, n: int, B: int, gate_kind: str,
                router_kind: str, charging: str, partition: str,
                sarathi: bool, unchunked: bool, prefill_only: bool,
                has_pw: bool, expiry: bool, model_kind: str = "affine",
                k_events: int = 1, fastforward: bool = False,
                telemetry=None):
    dtype = params["t_arr"].dtype
    # telemetry is a static: probes-off compiles the byte-identical
    # bare kernel.  All in-step probe work lives in the post-step
    # wrapper at the bottom of this builder; the latency histograms
    # need no hooks at all -- the ``t_first``/``t_last`` min/max marks
    # the step already maintains are bucketed once after the loop
    # (:func:`_fill_latency_hists`), keeping the probed step fusable.
    tlm = telemetry
    R = params["t_arr"].shape[0]
    I = params["x_star"].shape[0]
    W = B + 1  # placement bound per event: freed slots + the routed job
    sid = jnp.arange(n, dtype=jnp.int32)
    iota_I = jnp.arange(I, dtype=jnp.int32)
    iota_W = jnp.arange(W, dtype=jnp.int32)
    inf = jnp.asarray(jnp.inf, dtype)
    t_arr, cls = params["t_arr"], params["cls"]
    P, D, patience = params["P"], params["D"], params["patience"]
    # the ranked-assignment routers never read per-request lifecycle
    # state inside the step, so every ``st`` write can be deferred into
    # ONE combined scatter-max per step (a point-scatter costs a full
    # array pass on CPU XLA, so the scatter count on (R,) arrays is what
    # the step's wall time is made of)
    fast_st = router_kind in ("solo_first", "local_fcfs")
    need_tbuf = (expiry or router_kind == "immediate"
                 or (router_kind == "randomized" and has_pw))
    # k-event blocks additionally defer the write-only t_first/t_last
    # scatters and (fast routers) the buf-ring pushes across the whole
    # block, and swap the (R,) resident-token array for a dense (n, B)
    # per-slot counter -- see the module docstring; bitwise-identical
    # to k single-event steps
    multi = k_events > 1
    if fastforward and not (fast_st and model_kind == "affine"):
        raise ValueError("fastforward needs a deterministic global-buffer "
                         "router (solo_first/local_fcfs) and the affine "
                         "iteration-time model")
    dense_tout = fast_st and (multi or fastforward)

    def f(b):
        return b.astype(dtype)

    def rc(idx):
        return jnp.clip(idx, 0, R - 1)

    def used_of(slot_rid):
        return jnp.sum(f(slot_rid >= 0), axis=1)  # (n,)

    def cap_of(pf_rid):
        """Per-server decode-slot capacity given current prefill state."""
        has_pf = f(pf_rid >= 0)
        if partition == "none":
            return params["B"] - has_pf
        mixed = sid < params["Mi"]
        cap_mixed = (jnp.zeros(n, dtype) if prefill_only
                     else params["B"] - has_pf)
        return jnp.where(mixed, cap_mixed, params["B"])

    def place_into(c, srv_i, j, ok):
        """Scatter job ``j`` into the first empty slot of server
        ``srv_i`` (masked by ``ok``) and flip its lifecycle state.
        Used by the sequential (immediate / randomized) dispatchers."""
        row = c["slot_rid"][srv_i]
        slot = jnp.argmax(row < 0)
        c["slot_rid"] = c["slot_rid"].at[srv_i, slot].max(
            jnp.where(ok, j.astype(jnp.int32), -1))
        c["st"] = c["st"].at[rc(j)].max(jnp.where(ok, _DECODE, -1))
        if "srv" in c:
            c["srv"] = c["srv"].at[rc(j)].set(
                jnp.where(ok, srv_i.astype(jnp.int32), c["srv"][rc(j)]))
        return c

    def wake(c, now, active, force_solo):
        """Start an iteration on every non-busy server with work
        (snapshot semantics: resident decodes join, chunk is fixed).

        ``force_solo`` marks servers the Python engine would have woken
        *during* dispatch -- before the admission step could hand them a
        prefill -- so their iteration starts decode-only and the prefill
        waits for the next boundary, exactly like the oracle."""
        used = used_of(c["slot_rid"])
        has_pf = c["pf_rid"] >= 0
        do = active & ~c["busy"] & (has_pf | (used > 0))
        pl = c["pf_left"]  # per-server: one active prefill per server
        if unchunked:
            chn = pl
        elif sarathi:
            chn = jnp.clip(params["C"] - used, 0.0, pl)
        else:
            chn = jnp.minimum(pl, params["C"])
        chn = jnp.where(has_pf & ~force_solo, chn, 0.0)
        occupied = c["slot_rid"] >= 0
        src = rc(c["slot_rid"])
        pfr = rc(c["pf_rid"])
        tout_res = c["slot_tout"] if dense_tout else c["tout"][src]
        kv = (jnp.sum(jnp.where(occupied, P[src] + tout_res, 0.0),
                      axis=1)
              + jnp.where(has_pf, P[pfr] - pl, 0.0))
        if model_kind == "table":
            # piecewise-linear iteration-time surfaces over calibrated
            # knots (jnp.interp clamps beyond the knot range, matching
            # TableModel's constant extrapolation in the Python engine)
            tau = jnp.where(
                has_pf & (chn > 0),
                jnp.interp(chn, params["mix_x"], params["mix_y"]),
                jnp.interp(kv, params["solo_x"], params["solo_y"]))
        else:  # "affine": the historical expression, untouched
            tau = jnp.where(has_pf & (chn > 0),
                            params["alpha"] + params["beta"] * chn,
                            params["tau_solo"] + params["b_s"] * kv)
        # KV-transfer charge: the chunk that FINISHES a prefill ships the
        # whole KV cache to the decode pool and occupies the server for
        # kv_xfer * P extra seconds (DistServe-style handoff).  kv_xfer
        # is 0.0 without a fleet, so this adds an exact + 0.0 and the
        # homogeneous hot path stays bitwise-clean.
        fin = has_pf & (chn > 0.0) & (chn >= pl)
        tau = tau + f(fin) * (params["kv_xfer"] * P[pfr])
        c["chunk"] = jnp.where(do, chn, c["chunk"])
        c["t_next"] = jnp.where(do, now + tau, c["t_next"])
        c["busy"] = c["busy"] | do
        c["slot_live"] = c["slot_live"] | (do[:, None] & occupied)
        return c

    # stacked per-request constants so ffwd pays one gather where it
    # would otherwise pay two (class ids are tiny, exact in float32)
    DP2 = jnp.stack([D, P])
    AC2 = jnp.stack([t_arr, f(cls)])

    def ffwd(c):
        """Retire a batch of non-interacting events in closed form.

        Between two *interaction* events (an arrival, a decode
        completion, a prefill finish -- the only transitions that can
        change queue/dispatch/admission state, by the dispatch-window
        and one-admission-per-event invariants the step maintains),
        every busy server just runs iterations that emit decode tokens
        and advance prefill chunks.  Those boundary events are
        independent across servers and deterministic, so this block
        advances each batchable server over all its boundaries that lie
        strictly before every pending interaction (and before the next
        arrival, and at or before the horizon) in one shot: token
        counters move by ``j`` on the dense per-slot array, first-token
        times scatter once with the exact first-boundary time, and
        ``t_next`` lands on the closed-form partial sum of the
        iteration-time series (constant ``alpha + beta*chunk`` for a
        mid-prefill server; the arithmetic series ``tau_solo + b_s *
        (kv0 + i*L)`` for a decode server whose KV grows by ``L`` per
        iteration).  Results match the one-event path exactly up to
        float summation order (the partial sum replaces ``j`` chained
        additions); the event *sequence* is identical.  A server with a
        freshly-placed, not-yet-woken resident (``slot_live`` false) or
        an in-flight partial chunk boundary is simply not batchable
        this window and is processed by the normal path instead.
        """
        t0 = c["t_next"]  # (n,) first-boundary times (exact)
        occ = c["slot_rid"] >= 0
        L = jnp.sum(f(occ), axis=1)
        rr2 = rc(c["slot_rid"])
        dp = DP2[:, rr2]  # one gather serves both D and P lookups
        # tokens to the earliest resident completion (>= 1 by
        # invariant); a not-yet-woken resident poisons the min to -inf
        # so d > 0 doubles as the all-residents-live check
        d = jnp.min(jnp.where(occ,
                              jnp.where(c["slot_live"],
                                        dp[0] - c["slot_tout"], -inf),
                              inf), axis=1)
        has_pf = c["pf_rid"] >= 0
        pl, chn = c["pf_left"], c["chunk"]
        kv0 = jnp.sum(jnp.where(occ, dp[1] + c["slot_tout"], 0.0),
                      axis=1)
        tau_pf = params["alpha"] + params["beta"] * chn
        a_s, b_s = params["tau_solo"], params["b_s"]

        def T(j):  # time of boundary index j (j = 0 -> t_next)
            dec = j * a_s + b_s * (j * kv0 + L * j * (j - 1.0) / 2.0)
            return t0 + jnp.where(has_pf, j * tau_pf, dec)

        # first interaction boundary per server: earliest completion
        # (j = d-1) or the chunk that finishes the prefill
        jC = d - 1.0
        jF = jnp.ceil(pl / jnp.maximum(chn, 1.0)) - 1.0
        jint = jnp.where(has_pf, jnp.minimum(jC, jF), jC)
        okb = (c["busy"] & (d > 0.0)
               & jnp.where(has_pf, chn > 0, True))
        # step 4 admits at most ONE queued prefill per event, so a
        # waiting head plus an admission-capable server means the very
        # next event -- whatever it is -- performs an admission: every
        # boundary is then an interaction and the window must be empty.
        # (Dispatch needs no such guard: after any event the ring is
        # empty or decode capacity is, and neither changes in-window.)
        qlen0 = f(c["qarr"] - c["qhead"])
        no_pf0 = c["pf_rid"] < 0
        if partition == "none":
            canp0 = no_pf0 & (L < params["B"])
            if sarathi:
                canp0 = canp0 & (L < params["B"] - 1.0)
        else:
            canp0 = ((sid < params["Mi"]) & no_pf0
                     & (L <= params["B"] - 1.0))
        if gate_kind == "occupancy":
            waiting = (qlen0 >= 1) & (params["x_star"] > _EPS_TARGET)
        else:
            waiting = qlen0 >= 1
        blocked = canp0.any() & waiting.any()
        if expiry:  # lazy head-expiry also fires once per event
            blocked = blocked | (qlen0 >= 1).any()
        okb = okb & ~blocked
        jint = jnp.where(okb, jnp.maximum(jint, 0.0), 0.0)
        t_int = jnp.where(okb, T(jint), t0)  # non-batchable: t_next
        t_imin = t_int.min()
        # one lookahead gather serves both the next-arrival bound
        # (its first lane) and the arrival batch below
        a0 = c["aptr"]
        aw = a0 + jnp.arange(_FFWD_JMAX, dtype=a0.dtype)
        acw = AC2[:, rc(aw)]  # one gather serves t_arr and cls lookups
        taw = jnp.where(f(aw) < params["A"], acw[0], inf)
        ta0 = taw[0]
        # with no admission-capable server, an arrival merely joins its
        # class queue -- it cannot admit, dispatch, or wake anything --
        # so arrivals and boundaries commute and neither caps the other
        no_adm = (jnp.zeros((), bool) if expiry
                  else ~canp0.any())
        t_cap = jnp.where(no_adm, t_imin, jnp.minimum(ta0, t_imin))
        if "frontier" in params:
            # streamed replay: never batch past the next chunk's splice
            # point (its arrivals are not loaded yet, so ta0 is blind to
            # them); the segment loop stops there, ffwd must too
            t_cap = jnp.minimum(t_cap, params["frontier"])
        jj = jnp.arange(_FFWD_JMAX, dtype=dtype)[None, :]
        # (n,)-shaped surfaces (heterogeneous fleets) need the explicit
        # column axis; the scalar path emits the identical expressions
        a_sB = a_s[:, None] if jnp.ndim(a_s) else a_s
        b_sB = b_s[:, None] if jnp.ndim(b_s) else b_s
        Tj = (t0[:, None]
              + jnp.where(has_pf[:, None], jj * tau_pf[:, None],
                          jj * a_sB + b_sB * (jj * kv0[:, None]
                                              + L[:, None] * jj
                                              * (jj - 1.0) / 2.0)))
        # batchable boundaries: strictly before every interaction and
        # the next arrival (arrival-first tie-break preserved), at or
        # before the horizon (events at h_eff are processed), strictly
        # before this server's own interaction boundary
        okj = (Tj < t_cap) & (Tj <= params["h_eff"]) & (jj < jint[:, None])
        j_s = jnp.where(okb, jnp.sum(f(okj), axis=1), 0.0)
        adv = j_s > 0
        # post-window state, computed exactly like the per-boundary wake
        pl2 = pl - j_s * chn
        chn2 = pl2 if unchunked else (
            jnp.clip(params["C"] - L, 0.0, pl2) if sarathi
            else jnp.minimum(pl2, params["C"]))
        tau2 = jnp.where(has_pf, params["alpha"] + params["beta"] * chn2,
                         a_s + b_s * (kv0 + j_s * L))
        # finishing-chunk KV-transfer charge, mirroring wake exactly
        # (window boundaries jj < jint <= jF are never finishing chunks,
        # so only the post-window iteration can carry the charge)
        fin2 = has_pf & (chn2 > 0.0) & (chn2 >= pl2)
        tau2 = tau2 + f(fin2) * (params["kv_xfer"] * P[rc(c["pf_rid"])])
        t_last_b = T(j_s - 1.0)  # last batched boundary time
        c["t_next"] = jnp.where(adv, t_last_b + tau2, c["t_next"])
        c["pf_left"] = jnp.where(adv & has_pf, pl2, c["pf_left"])
        c["chunk"] = jnp.where(adv & has_pf, chn2, c["chunk"])
        emit = occ & adv[:, None]
        c["slot_tout"] = c["slot_tout"] + f(emit) * j_s[:, None]
        c["t_first"] = c["t_first"].at[rr2].min(
            jnp.where(emit, t0[:, None], inf))
        nb = jnp.sum(j_s)
        c["n_iters"] = c["n_iters"] + nb
        c["n_events"] = c["n_events"] + nb
        c["t"] = jnp.maximum(c["t"], jnp.where(adv, t_last_b,
                                               -jnp.inf).max())
        if not expiry:
            # queue-only arrival batch: every arrival strictly before
            # the earliest pending interaction (they stay QUEUED -- no
            # admission is possible until a server frees up, which is
            # itself an interaction).  t_arr is sorted, so the mask is
            # a prefix of the lookahead window.
            okm = no_adm & (taw < t_imin)
            m_arr = jnp.sum(jnp.where(okm, 1, 0))
            c["aptr"] = a0 + m_arr.astype(a0.dtype)
            c["st"] = c["st"].at[rc(aw)].max(jnp.where(okm, _QUEUED, -1))
            c["qarr"] = c["qarr"].at[acw[1].astype(jnp.int32)].add(
                jnp.where(okm, 1, 0))
            c["n_events"] = c["n_events"] + f(m_arr)
            c["t"] = jnp.maximum(c["t"],
                                 jnp.where(okm, taw, -jnp.inf).max())
        return c

    def event(c, idx, dfr):
        # ``dfr`` holds the cross-event deferred (R,)-scatter buffers of
        # the enclosing k-block (None in the single-event body)
        u = (jax.random.uniform(jax.random.fold_in(key, idx),
                                (2 * W + 1,), dtype=dtype)
             if router_kind == "randomized" else None)
        st_idx, st_val = [], []  # deferred combined scatter (fast_st)

        def st_max(c, idx_, val_):
            if fast_st:
                tgt_i = dfr["st_i"] if multi else st_idx
                tgt_v = dfr["st_v"] if multi else st_val
                tgt_i.append(jnp.atleast_1d(idx_.astype(jnp.int32)))
                tgt_v.append(jnp.atleast_1d(val_.astype(jnp.int32)))
            else:
                c["st"] = c["st"].at[idx_].max(val_)
            return c

        def mark_first(c, idx_, val_):
            # t_first is write-only in-step: scatter-min defers k-wide
            if multi:
                dfr["tf_i"].append(jnp.atleast_1d(idx_))
                dfr["tf_v"].append(jnp.atleast_1d(val_))
            else:
                c["t_first"] = c["t_first"].at[idx_].min(val_)
            return c

        def mark_last(c, idx_, val_):
            if multi:
                dfr["tl_i"].append(jnp.atleast_1d(idx_))
                dfr["tl_v"].append(jnp.atleast_1d(val_))
            else:
                c["t_last"] = c["t_last"].at[idx_].max(val_)
            return c

        # ---- next event: earliest arrival vs earliest iteration end ----
        ap = c["aptr"]
        ta = jnp.where(f(ap) < params["A"], t_arr[rc(ap)], inf)
        se = jnp.argmin(c["t_next"])
        tsv = c["t_next"][se]
        now = jnp.minimum(ta, tsv)
        active = now <= params["h_eff"]
        if "frontier" in params:
            # streamed replay: an event at/after the next chunk's splice
            # point could interact with arrivals not loaded yet
            active = active & (now < params["frontier"])
        is_arr = active & (ta <= tsv)  # heap pushes arrivals first: ties
        is_iter = active & ~is_arr     # resolve arrival-before-iteration

        # ---- arrival: advance the cursor, push to the class queue ------
        ca = cls[rc(ap)]
        c = st_max(c, rc(ap), jnp.where(is_arr, _QUEUED, -1))
        c["qarr"] = c["qarr"] + jnp.where(is_arr & (iota_I == ca), 1, 0)
        c["aptr"] = ap + jnp.where(is_arr, 1, 0)

        # ---- iteration end on server `se` (small per-server state is
        #      updated elementwise so the whole block fuses) -------------
        at_se = sid == se
        c["busy"] = c["busy"] & ~(at_se & is_iter)
        c["t_next"] = jnp.where(at_se & is_iter, inf, c["t_next"])
        # 1) snapshot decodes emit one token each (B-sized gathers; the
        #    scatters use add/min/max so clip-aliased empty slots -- all
        #    mapped to index 0 -- contribute identities, never clobbers)
        row = c["slot_rid"][se]
        rr = rc(row)
        live = is_iter & (row >= 0) & c["slot_live"][se]
        if dense_tout:  # per-slot counter: zero request-axis traffic
            tout_new = c["slot_tout"][se] + 1.0
            c["slot_tout"] = c["slot_tout"] + f(at_se[:, None]
                                                & live[None, :])
        else:
            tout_new = c["tout"][rr] + 1.0  # live slots hold distinct rids
            c["tout"] = c["tout"].at[rr].add(f(live))
        c = mark_first(c, rr, jnp.where(live, now, inf))
        c = mark_last(c, rr, jnp.where(live, now, -inf))
        done = live & (tout_new >= D[rr])
        if charging == "separate":
            reward = params["c_d"] * D[rr]
        else:
            reward = params["c_p"] * P[rr] + params["c_d"] * D[rr]
        c["rev"] = c["rev"] + jnp.sum(jnp.where(done, reward, 0.0))
        c = st_max(c, rr, jnp.where(done, _DONE, -1))
        if "srv" in c:
            c["srv"] = c["srv"].at[rr].min(
                jnp.where(done, -1, jnp.iinfo(jnp.int32).max))
        done_row = at_se[:, None] & done[None, :]
        c["slot_rid"] = jnp.where(done_row, -1, c["slot_rid"])
        c["slot_live"] = c["slot_live"] & ~done_row
        # 2) prefill-chunk progress + routing of a finished prefill
        #    (prefill-left is per-server: one active prefill per server)
        pf = c["pf_rid"][se]
        has_pf = is_iter & (pf >= 0)
        pfc = rc(pf)
        pln = c["pf_left"][se] - c["chunk"][se]
        c["pf_left"] = c["pf_left"] - jnp.where(at_se & has_pf,
                                                c["chunk"][se], 0.0)
        pf_done = has_pf & (pln <= 0)
        if charging == "separate":
            c["rev"] = c["rev"] + jnp.where(pf_done,
                                            params["c_p"] * P[pfc], 0.0)
        if need_tbuf:
            c["t_buf"] = c["t_buf"].at[pfc].set(
                jnp.where(pf_done, now, c["t_buf"][pfc]))
        c = st_max(c, pfc, jnp.where(pf_done, _BUF, -1))
        c["X"] = c["X"] - jnp.where(pf_done & (iota_I == cls[pfc]),
                                    1.0, 0.0)
        c["pf_rid"] = jnp.where(at_se & pf_done, -1, c["pf_rid"])
        if router_kind == "randomized":
            go_solo = u[0] <= params["p_solo"][cls[pfc]]
            c["pool"] = c["pool"].at[pfc].set(
                jnp.where(pf_done, jnp.where(go_solo, 0, 1),
                          c["pool"][pfc]))
            if not has_pw:  # pool FCFS rings
                for pid, ring in ((0, "buf_s"), (1, "buf_m")):
                    push = pf_done & (c["pool"][pfc] == pid)
                    tl = c[f"{ring}_tl"]
                    c[ring] = c[ring].at[tl].max(jnp.where(push, pf, -1))
                    c[f"{ring}_tl"] = tl + jnp.where(push, 1, 0)
        elif router_kind == "immediate":
            # stays pending on `se`: mark the target in srv
            c["srv"] = c["srv"].at[pfc].set(
                jnp.where(pf_done, se.astype(jnp.int32), c["srv"][pfc]))
        else:  # single global FCFS ring (solo_first / local_fcfs)
            tl = c["buf_tl"]
            if multi:
                # defer the (R+W,) ring write; `buf_tl` (a scalar) still
                # advances immediately, and in-block pops overlay the
                # pending pushes onto the dispatch window below.  A
                # masked push (-1) may share its index with a later real
                # one -- the flush scatter-max composes them.
                dfr["push_i"].append(tl)
                dfr["push_v"].append(jnp.where(pf_done, pf, -1))
            else:
                c["buf"] = c["buf"].at[tl].max(jnp.where(pf_done, pf, -1))
            c["buf_tl"] = tl + jnp.where(pf_done, 1, 0)

        # 3) decode dispatch.  For the deterministic global-buffer routers
        #    this is one closed-form ranked assignment over a W-window of
        #    the FCFS ring (at most freed-slots + 1 <= W placements can
        #    happen per event): servers contribute free slots in routing
        #    order via a cumulative array, ring jobs map rank j to the
        #    server covering slot j -- exactly the Python engine's
        #    fill-servers-in-order / jobs-in-FCFS-order loop.
        busy_pre = c["busy"]  # dispatch-time idleness (se already cleared)
        if router_kind in ("solo_first", "local_fcfs"):
            hd, tl = c["buf_hd"], c["buf_tl"]
            win = jax.lax.dynamic_slice(c["buf"], (hd,), (W,))
            if multi:  # overlay this block's not-yet-flushed ring pushes
                for ti, tv in zip(dfr["push_i"], dfr["push_v"]):
                    win = jnp.where(hd + iota_W == ti,
                                    jnp.maximum(win, tv), win)
            jw = rc(win)
            valid = (hd + iota_W < tl) & is_iter
            if expiry:
                expired = valid & (now - c["t_buf"][jw] > patience[jw])
            else:  # patience == inf everywhere: nothing ever expires
                expired = jnp.zeros(W, bool)
            pe = valid & ~expired  # placeable
            erank = jnp.cumsum(f(pe)) - f(pe)  # exclusive FCFS rank
            free = jnp.maximum(cap_of(c["pf_rid"])
                               - used_of(c["slot_rid"]), 0.0)
            free_sorted = free[params["perm_srv"]]
            cumfree = jnp.cumsum(free_sorted)
            totfree = cumfree[-1]
            consumed = valid & (erank < totfree)  # popped (placed/expired)
            place = pe & (erank < totfree)
            pos = jnp.searchsorted(cumfree, erank, side="right")
            server = params["perm_srv"][jnp.clip(pos, 0, n - 1)]
            within = erank - jnp.where(pos > 0,
                                       cumfree[jnp.maximum(pos - 1, 0)],
                                       0.0)
            # k-th empty physical slot of each server (stable sort puts
            # empty slots first, in index order)
            esort = jnp.argsort(c["slot_rid"] >= 0, axis=1)
            slot = esort[server, jnp.clip(within.astype(jnp.int32),
                                          0, B - 1)]
            c["slot_rid"] = c["slot_rid"].at[server, slot].max(
                jnp.where(place, win, -1))
            if dense_tout:  # fresh occupant: reset the per-slot counter
                c["slot_tout"] = c["slot_tout"].at[server, slot].min(
                    jnp.where(place, 0.0, jnp.inf))
            c = st_max(c, jw,
                       jnp.where(place, _DECODE,
                                 jnp.where(consumed & expired,
                                           _ABANDONED, -1)))
            c["buf_hd"] = hd + jnp.sum(jnp.where(consumed, 1, 0))
            c["abandons"] = c["abandons"] + jnp.sum(f(consumed & expired))
            placed_srv = jnp.zeros(n, bool).at[server].max(place)
        elif router_kind == "immediate":
            # pending jobs live as BUF with srv == se; FCFS by t_buf
            placed_any = jnp.zeros((), bool)
            for k in range(W):
                cap_se = cap_of(c["pf_rid"])[se]
                used_se = used_of(c["slot_rid"])[se]
                elig = (c["st"] == _BUF) & (c["srv"] == se)
                j = jnp.argmin(jnp.where(elig, c["t_buf"], inf))
                do = is_iter & elig.any() & (used_se < cap_se)
                expired = now - c["t_buf"][j] > patience[j]
                c["st"] = c["st"].at[j].max(
                    jnp.where(do & expired, _ABANDONED, -1))
                c["abandons"] = c["abandons"] + f(do & expired)
                c = place_into(c, se, j, do & ~expired)
                placed_any = placed_any | (do & ~expired)
            placed_srv = at_se & placed_any
        else:  # randomized: solo pool drains first; uniform server draw
            solo_srv = sid >= params["Mi"]
            placed_srv = jnp.zeros(n, bool)
            for k in range(W):
                u1, u2 = u[2 * k + 1], u[2 * k + 2]
                cap = cap_of(c["pf_rid"])
                used = used_of(c["slot_rid"])
                free = cap - used > 0
                free_s = solo_srv & free
                free_m = ~solo_srv & free
                if has_pw:  # EC.7 class weights need an in-buffer scan
                    elig_s = (c["st"] == _BUF) & (c["pool"] == 0)
                    elig_m = (c["st"] == _BUF) & (c["pool"] == 1)
                    can_s = free_s.any() & elig_s.any()
                    use_solo = can_s
                    do = is_iter & (can_s | (free_m.any() & elig_m.any()))
                    pool_elig = jnp.where(use_solo, elig_s, elig_m)
                    j_fcfs = jnp.argmin(
                        jnp.where(pool_elig, c["t_buf"], inf))
                    pw = jnp.where(use_solo, params["pw_s"],
                                   params["pw_m"])
                    present = jnp.zeros(I, dtype).at[cls].add(
                        f(pool_elig)) > 0
                    w = jnp.maximum(pw, 0.0) * f(present)
                    ci = _categorical(u1, w)
                    j_w = jnp.argmin(jnp.where(
                        pool_elig & (cls == ci), c["t_buf"], inf))
                    j = jnp.where(w.sum() > 0, j_w, j_fcfs)
                    pop = do & (c["st"][j] == _BUF)  # guard no-op lanes
                    expired = now - c["t_buf"][j] > patience[j]
                    c["st"] = c["st"].at[j].max(
                        jnp.where(pop & expired, _ABANDONED, -1))
                else:  # plain pool FCFS: ring heads
                    can_s = (free_s.any()
                             & (c["buf_s_hd"] < c["buf_s_tl"]))
                    can_m = (free_m.any()
                             & (c["buf_m_hd"] < c["buf_m_tl"]))
                    use_solo = can_s
                    do = is_iter & (can_s | can_m)
                    hd_s, hd_m = c["buf_s_hd"], c["buf_m_hd"]
                    j = jnp.where(use_solo, c["buf_s"][rc(hd_s)],
                                  c["buf_m"][rc(hd_m)])
                    pop = do
                    c["buf_s_hd"] = hd_s + jnp.where(pop & use_solo, 1, 0)
                    c["buf_m_hd"] = hd_m + jnp.where(pop & ~use_solo, 1, 0)
                    if expiry:
                        expired = (now - c["t_buf"][rc(j)]
                                   > patience[rc(j)])
                    else:
                        expired = jnp.zeros((), bool)
                    c["st"] = c["st"].at[rc(j)].max(
                        jnp.where(pop & expired, _ABANDONED, -1))
                pool_free = jnp.where(use_solo, free_s, free_m)
                sv = _categorical(u2, f(pool_free))
                c["abandons"] = c["abandons"] + f(pop & expired)
                c = place_into(c, sv, j, pop & ~expired)
                placed_srv = placed_srv | ((sid == sv) & pop & ~expired)

        # 4) at most one prefill admission (gate family invariant)
        heads = params["class_rids"][iota_I, rc(c["qhead"])]
        qlen = f(c["qarr"] - c["qhead"])
        if expiry:
            # lazy head expiry (at most one head per class per event)
            hexp = (active & (qlen > 0)
                    & (now - t_arr[rc(heads)] > patience[rc(heads)]))
            c = st_max(c, rc(heads), jnp.where(hexp, _ABANDONED, -1))
            c["qhead"] = c["qhead"] + jnp.where(hexp, 1, 0)
            c["abandons"] = c["abandons"] + jnp.sum(f(hexp))
            heads = params["class_rids"][iota_I, rc(c["qhead"])]
            qlen = f(c["qarr"] - c["qhead"])

        used2 = used_of(c["slot_rid"])
        no_pf = c["pf_rid"] < 0
        if partition == "none":
            if router_kind == "immediate":
                pend = _count_pending(c, n, dtype)
                canp = no_pf & (used2 + pend < params["B"])
            else:
                canp = no_pf & (used2 < params["B"])
            if sarathi:
                canp = canp & (used2 < params["B"] - 1)
        else:
            mixed = sid < params["Mi"]
            if router_kind == "immediate":
                pend = _count_pending(c, n, dtype)
                capm = (jnp.zeros(n, dtype) if prefill_only
                        else jnp.full(n, params["B"], dtype))
                canp = mixed & no_pf & (used2 + pend < capm)
            else:
                canp = mixed & no_pf & (used2 <= params["B"] - 1)
        canp = canp & active
        tgt = jnp.argmin(jnp.where(canp, sid, 2 * n))  # first free server
        if gate_kind == "occupancy":
            gmask = (qlen >= 1) & (params["x_star"] > _EPS_TARGET)
            xi = ((c["X"] + 1.0 - params["n_f"] * params["x_star"])
                  / jnp.maximum(params["x_star"], 1e-30))
            keyv = jnp.where(gmask, xi, inf)
            tie = gmask & (keyv == keyv.min())
            delta = qlen - params["n_f"] * params["qp_star"]
            cand = jnp.argmax(jnp.where(tie, delta, -inf))
            can = gmask.any()
        elif gate_kind == "priority":
            gmask = qlen >= 1
            cand = jnp.argmax(jnp.where(gmask, params["ratio"], -inf))
            can = gmask.any()
        else:  # fcfs: exact head-of-line class (oldest waiting request)
            cand = jnp.argmin(jnp.where(qlen >= 1, heads, R))
            can = (qlen >= 1).any()
        admit = canp.any() & can
        jr = heads[cand]
        c = st_max(c, rc(jr), jnp.where(admit, _PREFILL, -1))
        if "srv" in c:
            c["srv"] = c["srv"].at[rc(jr)].set(
                jnp.where(admit, tgt.astype(jnp.int32), c["srv"][rc(jr)]))
        c["qhead"] = c["qhead"] + jnp.where(admit & (iota_I == cand), 1, 0)
        c["X"] = c["X"] + jnp.where(admit & (iota_I == cand), 1.0, 0.0)
        c["pf_rid"] = jnp.where(admit & (sid == tgt),
                                jr.astype(jnp.int32), c["pf_rid"])
        c["pf_left"] = jnp.where(admit & (sid == tgt), P[rc(jr)],
                                 c["pf_left"])

        # flush the deferred lifecycle transitions in ONE scatter-max
        # (codes are ordered along the lifecycle, so max composes even
        # when one request transitions twice in a single event); k-event
        # blocks flush once per block instead
        if fast_st and not multi:
            c["st"] = c["st"].at[jnp.concatenate(st_idx)].max(
                jnp.concatenate(st_val))

        # single wake pass, post-admission (the Python engine's step-5
        # order).  A server the dispatch phase woke while idle -- which
        # then drew the admission -- starts decode-only: its prefill
        # joined after the Python wake and waits for the next boundary.
        force_solo = placed_srv & ~busy_pre & admit & (sid == tgt)
        c = wake(c, now, active, force_solo)

        c["t"] = jnp.where(active, now, c["t"])
        c["n_iters"] = c["n_iters"] + f(is_iter)
        c["n_events"] = c["n_events"] + f(active)
        # early-exit flag: is another event pending before the horizon?
        ta2 = jnp.where(f(c["aptr"]) < params["A"],
                        t_arr[rc(c["aptr"])], inf)
        c["alive"] = jnp.minimum(ta2, c["t_next"].min()) <= params["h_eff"]
        return c

    def finish(step_fn):
        if tlm is None:
            return step_fn
        return wrap_engine_step_probes(step_fn, tlm, params)

    if not multi:
        def step(carry, idx):
            c = dict(carry)
            c["n_loop"] = c["n_loop"] + f(c["alive"])
            if fastforward:
                c = ffwd(c)
            return event(c, idx, None)

        return finish(step)

    def step(carry, idx):
        # idx is the BLOCK index; events keep their global index so the
        # randomized router's fold_in stream is identical at every k
        c = dict(carry)
        c["n_loop"] = c["n_loop"] + f(c["alive"])
        if fastforward:
            c = ffwd(c)
        dfr = {k2: [] for k2 in ("st_i", "st_v", "tf_i", "tf_v",
                                 "tl_i", "tl_v", "push_i", "push_v")}
        base = idx * jnp.uint32(k_events)
        for j in range(k_events):
            c = event(c, base + jnp.uint32(j), dfr)
        # one combined flush per (R,) array for the whole block: max/min
        # compose across events exactly like across transitions
        if fast_st:
            c["st"] = c["st"].at[jnp.concatenate(dfr["st_i"])].max(
                jnp.concatenate(dfr["st_v"]))
            c["buf"] = c["buf"].at[jnp.stack(dfr["push_i"])].max(
                jnp.stack(dfr["push_v"]))
        c["t_first"] = c["t_first"].at[jnp.concatenate(dfr["tf_i"])].min(
            jnp.concatenate(dfr["tf_v"]))
        c["t_last"] = c["t_last"].at[jnp.concatenate(dfr["tl_i"])].max(
            jnp.concatenate(dfr["tl_v"]))
        return c

    return finish(step)


def _count_pending(c, n, dtype):
    """Pending-local counts for the immediate router (O(R) scan; only
    compiled into the immediate/sarathi variant)."""
    return jnp.zeros(n, dtype).at[jnp.clip(c["srv"], 0, n - 1)].add(
        (c["st"] == _BUF).astype(dtype))


def _init_carry(R: int, n: int, B: int, I: int, dtype,
                router_kind: str, has_pw: bool, expiry: bool,
                k_events: int = 1, fastforward: bool = False,
                telemetry=None) -> dict:
    W = B + 1
    c = {
        "st": jnp.zeros(R, jnp.int32),
        "tout": jnp.zeros(R, dtype),
        "t_first": jnp.full(R, jnp.inf, dtype),
        "t_last": jnp.full(R, -jnp.inf, dtype),  # max-scatter identity
        "slot_rid": jnp.full((n, B), -1, jnp.int32),
        "slot_live": jnp.zeros((n, B), bool),
        "pf_rid": jnp.full(n, -1, jnp.int32),
        "pf_left": jnp.zeros(n, dtype),
        "busy": jnp.zeros(n, bool),
        "t_next": jnp.full(n, jnp.inf, dtype),
        "chunk": jnp.zeros(n, dtype),
        "aptr": jnp.zeros((), jnp.int32),
        "qhead": jnp.zeros(I, jnp.int32),
        "qarr": jnp.zeros(I, jnp.int32),
        "X": jnp.zeros(I, dtype),
        "t": jnp.zeros((), dtype),
        "rev": jnp.zeros((), dtype),
        "n_iters": jnp.zeros((), dtype),
        "n_events": jnp.zeros((), dtype),
        "n_loop": jnp.zeros((), dtype),  # loop steps (batching factor)
        "abandons": jnp.zeros((), dtype),
        "alive": jnp.ones((), bool),
    }
    if (expiry or router_kind == "immediate"
            or (router_kind == "randomized" and has_pw)):
        c["t_buf"] = jnp.full(R, jnp.inf, dtype)
    if router_kind in ("solo_first", "local_fcfs"):
        # +W slack so the dispatch window never clamps its start index
        c["buf"] = jnp.full(R + W, -1, jnp.int32)
        c["buf_hd"] = jnp.zeros((), jnp.int32)
        c["buf_tl"] = jnp.zeros((), jnp.int32)
        if k_events > 1 or fastforward:
            # dense per-slot token counter (see _build_step)
            del c["tout"]
            c["slot_tout"] = jnp.zeros((n, B), dtype)
    elif router_kind == "randomized" and not has_pw:
        for ring in ("buf_s", "buf_m"):
            c[ring] = jnp.full(R + W, -1, jnp.int32)
            c[f"{ring}_hd"] = jnp.zeros((), jnp.int32)
            c[f"{ring}_tl"] = jnp.zeros((), jnp.int32)
    if router_kind == "immediate":
        c["srv"] = jnp.full(R, -1, jnp.int32)
    if router_kind == "randomized":
        c["pool"] = jnp.full(R, -1, jnp.int32)
    if telemetry is not None:
        # fixed-shape probe arrays under tlm_ keys: _summary never
        # reads them, so the non-telemetry outputs stay bitwise equal
        c.update(probe_carry(telemetry, n=n, I=I, dtype=dtype))
    return c


def _fill_latency_hists(carry: dict, t_arr, spec) -> dict:
    """Bucket the per-request latency marks into ``tlm_ttft``/``tlm_e2e``.

    The step already maintains ``t_first`` (min-scatter of every
    emission time) and ``t_last`` (max-scatter; equals the completion
    time for ``_DONE`` rows), so TTFT = ``t_first - t_arr`` and E2E =
    ``t_last - t_arr`` are exact per-request latencies and ONE
    searchsorted + scatter after the loop observes each request exactly
    once -- event-for-event what per-step histogram hooks would record,
    at none of their per-step fusion-breaking cost (the < 10% overhead
    contract of docs/OBSERVABILITY.md).  Rows that never emitted
    (``t_first`` infinite; includes padding) and rows not ``_DONE``
    carry zero weight; their NaN/out-of-band differences still land on
    a valid bucket index, so the masked adds are no-ops.
    """
    dt = t_arr.dtype
    edges = jnp.asarray(hist_edges(spec), dt)
    c = dict(carry)
    hb = jnp.searchsorted(edges, c["t_first"] - t_arr)
    c["tlm_ttft"] = c["tlm_ttft"].at[hb].add(
        jnp.isfinite(c["t_first"]).astype(dt))
    hb = jnp.searchsorted(edges, c["t_last"] - t_arr)
    c["tlm_e2e"] = c["tlm_e2e"].at[hb].add(
        (c["st"] == _DONE).astype(dt))
    return c


_STATICS = ("n_steps", "n", "B", "gate_kind", "router_kind", "charging",
            "partition", "sarathi", "unchunked", "prefill_only", "has_pw",
            "expiry", "loop", "model_kind", "k_events", "fastforward",
            "telemetry")


def _run_core(params, key, *, n_steps, n, B, gate_kind, router_kind,
              charging, partition, sarathi, unchunked, prefill_only,
              has_pw, expiry, loop="while", model_kind="affine",
              k_events=1, fastforward=False, telemetry=None):
    step = _build_step(params, key, n=n, B=B, gate_kind=gate_kind,
                       router_kind=router_kind, charging=charging,
                       partition=partition, sarathi=sarathi,
                       unchunked=unchunked, prefill_only=prefill_only,
                       has_pw=has_pw, expiry=expiry, model_kind=model_kind,
                       k_events=k_events, fastforward=fastforward,
                       telemetry=telemetry)
    R = params["t_arr"].shape[0]
    I = params["x_star"].shape[0]
    init = _init_carry(R, n, B, I, params["t_arr"].dtype,
                       router_kind, has_pw, expiry, k_events, fastforward,
                       telemetry)
    # the loop iterates over k-event BLOCKS; a final partial block runs
    # its overhang as proven no-op events (is_arr/is_iter/admit all
    # force False once no event is pending)
    n_blocks = -(-int(n_steps) // int(k_events))
    if loop == "scan":  # strict fixed-shape form (profiling / coupling)
        def body(carry, idx):
            return step(carry, idx), None

        carry, _ = jax.lax.scan(body, init,
                                jnp.arange(n_blocks, dtype=jnp.uint32))
        if telemetry is not None:
            carry = _fill_latency_hists(carry, params["t_arr"], telemetry)
        return carry
    # early-exit form: same step, same budget cap, but the loop stops as
    # soon as no event is pending before the horizon (the scan form pays
    # for its no-op tail; this one does not)
    def cond(state):
        carry, i = state
        return carry["alive"] & (i < n_blocks)

    def body(state):
        carry, i = state
        return step(carry, i.astype(jnp.uint32)), i + 1

    carry, _ = jax.lax.while_loop(
        cond, body, (init, jnp.zeros((), jnp.int32)))
    if telemetry is not None:
        carry = _fill_latency_hists(carry, params["t_arr"], telemetry)
    return carry


run_engine = jax.jit(_run_core, static_argnames=_STATICS)


@partial(jax.jit, static_argnames=_STATICS)
def run_engine_batch(params, keys, **statics):
    """vmap of :func:`run_engine` over a leading batch of PRNG keys."""
    return jax.vmap(lambda k: _run_core(params, k, **statics))(keys)


@partial(jax.jit, static_argnames=_STATICS)
def run_engine_multi(params, keys, **statics):
    """vmap over a leading *instance* axis of params AND keys.

    The instance axis can carry anything that only changes traced
    parameters: a DistServe split scan (instances differ in ``Mi``), a
    set of equal-shape traces replayed in lockstep (pad them to one
    length with ``tensorize_trace(pad_to=...)``), or perturbed
    primitives.  All instances share one compile; statics (shapes,
    router/gate kinds) must match.
    """
    return jax.vmap(lambda p, k: _run_core(p, k, **statics))(params, keys)


# the streamed-replay segment loop has no fixed scan length (it stops at
# the chunk frontier) and always early-exits, so n_steps/loop drop out
_SEG_STATICS = tuple(s for s in _STATICS if s not in ("n_steps", "loop"))


@partial(jax.jit, static_argnames=_SEG_STATICS)
def _run_segment(params, key, carry, i0, budget, **statics):
    """Run engine steps from ``carry`` until the chunk frontier, the
    horizon or the step budget -- the streamed-replay segment loop
    (:class:`repro.serving.engine_stream.StreamingEngineJAX` drives it
    between working-set splices, via :func:`run`'s ``segment=`` mode)."""
    step = _build_step(params, key, **statics)
    Rw = params["t_arr"].shape[0]
    dt = params["t_arr"].dtype
    inf = jnp.inf

    def cond(state):
        c, i = state
        ta = jnp.where(c["aptr"].astype(dt) < params["A"],
                       params["t_arr"][jnp.clip(c["aptr"], 0, Rw - 1)], inf)
        tmin = jnp.minimum(ta, c["t_next"].min())
        return ((tmin <= params["h_eff"]) & (tmin < params["frontier"])
                & (i < budget))

    def body(state):
        c, i = state
        return step(c, i.astype(jnp.uint32)), i + 1

    return jax.lax.while_loop(cond, body, (carry, i0))


def _as_keys(keys):
    """Normalize one-or-many seed specs (ints or PRNG keys) to arrays."""
    if isinstance(keys, (list, tuple)):
        return jnp.stack([prng_key(int(k))
                          if isinstance(k, (int, np.integer)) else k
                          for k in keys])
    if isinstance(keys, (int, np.integer)):
        return prng_key(int(keys))
    return keys


def run(params, keys, *, placement: str = "vmap", multi: bool = False,
        segment=None, shard: Optional[dict] = None, **statics):
    """Unified entry for every way this engine executes.

    One facade over the jitted kernels, so callers (the sweep's
    ``engine_jax`` evaluator, ``bench_engine_speed``, the streaming
    engine) never reach into module internals:

    * ``placement="single"``    one replication (``keys`` is one seed or
      PRNG key);
    * ``placement="vmap"``      a replication batch on one device
      (``keys`` is a sequence/stack; the bitwise oracle);
    * ``placement="shard_map"`` the same batch partitioned over the
      devices' 1-D cells mesh (bitwise identical; ``shard`` forwards
      tiling kwargs to :func:`repro.sweep.sharded.run_sharded`);
    * ``multi=True``            vmap/shard the leading *instance* axis of
      ``params`` together with ``keys`` (the ``run_engine_multi``
      semantics: DistServe split scans, lockstep trace sets);
    * ``segment=(carry, i0, budget)``  streamed-replay segment mode:
      continue ``carry`` under the frontier-capped while loop instead of
      a fresh replay (placement must be ``"single"``; ``statics`` then
      exclude ``n_steps``/``loop``).

    ``statics`` are the usual ``_STATICS`` kwargs
    (:attr:`ClusterEngineJAX.statics`).
    """
    keys = _as_keys(keys)
    if segment is not None:
        if placement != "single" or multi:
            raise ValueError("segment mode is single-placement only")
        carry, i0, budget = segment
        return _run_segment(params, keys, carry, i0, budget, **statics)
    if placement == "single":
        if multi:
            raise ValueError("multi needs a batch placement (vmap|shard_map)")
        return run_engine(params, keys, **statics)
    if placement == "vmap":
        return (run_engine_multi if multi
                else run_engine_batch)(params, keys, **statics)
    if placement == "shard_map":
        from repro.sweep.sharded import run_sharded

        st = dict(statics)
        if multi:
            raw, _ = run_sharded(
                lambda _rep, pk: _run_core(pk[0], pk[1], **st),
                None, (params, keys), **(shard or {}))
        else:
            raw, _ = run_sharded(lambda p, k: _run_core(p, k, **st),
                                 params, keys, **(shard or {}))
        return raw
    raise ValueError(f"unknown placement {placement!r} (expected "
                     f"single|vmap|shard_map)")


class ClusterEngineJAX:
    """Batched trace-replay twin of :class:`ClusterEngine`.

    Same classes/policy/:class:`EngineConfig` inputs and the same
    summary-metric keys, but the trace and horizon are fixed at
    construction (they determine the tensor shapes and the static scan
    budget) and replications run as one ``jax.vmap`` batch over PRNG
    keys.  Gate-and-route family, vLLM-, Sarathi- and DistServe-style
    baselines are supported; failures and the online controller are not
    (see the module docstring).

    ``max_steps`` caps the scan budget below the hard bound; the
    ``budget_exhausted`` diagnostic then reports whether the cap
    truncated the replay.  ``max_requests`` caps the tensorized trace
    (``n_dropped`` reports the overflow).  ``k_events`` unrolls the
    multi-event hot path (k consecutive events per loop step with one
    merged (R,)-scatter flush per block -- bitwise identical results,
    see the module docstring); the default 1 keeps the historical
    one-event body.
    """

    def __init__(self, classes: Sequence[WorkloadClass], policy: PolicySpec,
                 cfg: EngineConfig, trace, horizon: float, *,
                 drain: bool = False, max_steps: Optional[int] = None,
                 max_requests: Optional[int] = None, loop: str = "while",
                 k_events: int = 1, fastforward: bool = False,
                 telemetry=None):
        if loop not in ("while", "scan"):
            raise ValueError(f"loop must be while|scan, got {loop!r}")
        if int(k_events) < 1:
            raise ValueError(f"k_events must be >= 1, got {k_events!r}")
        if cfg.record_queues_every > 0:
            raise ValueError("engine_jax does not record queue traces; "
                             "use the Python ClusterEngine")
        self.classes = tuple(classes)
        self.I = len(self.classes)
        self.policy = policy
        self.cfg = cfg
        self.n = int(cfg.n_servers)
        prim = cfg.prim

        tt = (trace if isinstance(trace, TraceTensors)
              else tensorize_trace(trace, max_requests=max_requests))
        self.trace = tt
        if tt.n_real and int(tt.cls[tt.valid].max()) >= self.I:
            raise ValueError(
                f"trace references class {int(tt.cls[tt.valid].max())} but "
                f"only {self.I} classes were given")

        # horizon semantics of ClusterEngine.run: stop at the last prompt
        # arrival unless draining (paper Section 6.2 convention)
        arr_t = tt.t[tt.valid & (tt.t <= horizon)]
        last_arrival = float(arr_t.max()) if arr_t.size else float(horizon)
        self.h_eff = float(horizon) if drain else min(float(horizon),
                                                      last_arrival)
        arrived = tt.valid & (tt.t <= self.h_eff)

        self.budget = iteration_budget(tt, cfg, self.h_eff, arrived=arrived)
        self.n_steps = (self.budget if max_steps is None
                        else min(self.budget, int(max_steps)))

        self.gate_kind = _gate_kind(policy)
        if policy.router not in ("solo_first", "local_fcfs", "immediate",
                                 "randomized"):
            raise ValueError(f"unknown router {policy.router!r}")
        self.router_kind = policy.router
        # fail at construction, not at first trace: _build_step re-checks
        # but only when the jit cache misses
        if fastforward and policy.router not in ("solo_first",
                                                 "local_fcfs"):
            raise ValueError(
                "fastforward needs a deterministic global-buffer router "
                f"(solo_first/local_fcfs), got {policy.router!r}")
        self.partition = "none" if policy.partition == "none" else "static"
        self.M = int(policy.mixed_target(self.n))
        pw_m, pw_s = policy.pool_weights_mixed, policy.pool_weights_solo
        if (pw_m is None) != (pw_s is None):
            raise ValueError("engine_jax needs both pool-weight vectors "
                             "or neither")
        self.has_pw = pw_m is not None

        # per-class FCFS tables: class i's rids in arrival order (a class
        # queue is then a [qhead, qarr) window over its table row)
        class_rids = np.full((self.I, tt.R), tt.R, dtype=np.int32)
        for i in range(self.I):
            rids = np.nonzero(arrived & (tt.cls == i))[0]
            class_rids[i, : rids.size] = rids

        # static routing order: solo servers first for solo_first
        # (dispatch fills servers along this permutation)
        sids = np.arange(self.n, dtype=np.int32)
        if self.router_kind == "solo_first":
            perm_srv = np.concatenate([sids[self.M:], sids[: self.M]])
        else:
            perm_srv = sids

        dt = jnp.result_type(float)
        ones = np.ones(self.I)

        def a(v):
            return jnp.asarray(v, dtype=dt)

        gate = policy.gate
        self.params = {
            "t_arr": a(np.where(arrived, tt.t, np.inf)),
            "cls": jnp.asarray(tt.cls, jnp.int32),
            "P": a(tt.P),
            "D": a(tt.D),
            "patience": a(tt.patience),
            "class_rids": jnp.asarray(class_rids, jnp.int32),
            "A": a(int(arrived.sum())),
            "x_star": a(gate.x_star if isinstance(gate, OccupancyGate)
                        else ones),
            "qp_star": a(gate.qp_star if isinstance(gate, OccupancyGate)
                         else 0 * ones),
            "ratio": a(gate.ratio if isinstance(gate, PriorityRatioGate)
                       else ones),
            "p_solo": a(policy.solo_prob if policy.solo_prob is not None
                        else ones),
            "pw_m": a(pw_m if pw_m is not None else ones),
            "pw_s": a(pw_s if pw_s is not None else ones),
            "c_p": a(cfg.pricing.c_p),
            "c_d": a(cfg.pricing.c_d),
            "alpha": a(prim.alpha),
            "beta": a(prim.beta),
            "tau_solo": a(prim.tau_solo),
            "b_s": a(cfg.solo_kv_slope),
            "kv_xfer": a(0.0),
            "B": a(prim.batch_cap),
            "C": a(prim.chunk),
            "Mi": jnp.asarray(self.M, jnp.int32),
            "perm_srv": jnp.asarray(perm_srv, jnp.int32),
            "n_f": a(self.n),
            "h_eff": a(self.h_eff),
        }
        # plugged iteration-time model (repro.calibration protocol):
        # affine-kind models override the four surface scalars; table-kind
        # models add knot arrays and flip the static interp dispatch.  No
        # model (the default) leaves params and statics byte-identical.
        self.model_kind = "affine"
        m = cfg.iter_model
        if m is not None:
            self.model_kind = getattr(m, "kind", "affine")
            if self.model_kind == "table":
                for k, v in m.knots().items():
                    self.params[k] = a(np.asarray(v))
            elif hasattr(m, "jax_params"):
                for k, v in m.jax_params().items():
                    self.params[k] = a(v)
            else:  # generic protocol model: sample the affine scalars
                self.params["alpha"] = a(m.tau_mix(0.0))
                self.params["beta"] = a(m.tau_mix(1.0) - m.tau_mix(0.0))
                self.params["tau_solo"] = a(m.tau_solo(0.0))
                self.params["b_s"] = a(m.tau_solo(1.0) - m.tau_solo(0.0))
        if cfg.fleet is not None:
            # heterogeneous fleet: the four time surfaces plus the
            # KV-transfer charge become (n,) per-server arrays (B/chunk
            # stay fleet-uniform -- the pointer tables assume one B).
            # The homogeneous path above keeps scalars, so its compiled
            # HLO is byte-identical to the pre-fleet engine.
            if m is not None:
                raise ValueError("EngineConfig.fleet and iter_model are "
                                 "mutually exclusive")
            if int(cfg.fleet.n) != self.n:
                raise ValueError(
                    f"fleet has {int(cfg.fleet.n)} servers but "
                    f"n_servers={self.n}")
            fp = cfg.fleet.server_params(prim)
            for k_ in ("alpha", "beta", "tau_solo", "b_s", "kv_xfer"):
                self.params[k_] = a(fp[k_])
        self._static = dict(
            n_steps=self.n_steps, n=self.n, B=int(prim.batch_cap),
            gate_kind=self.gate_kind, router_kind=self.router_kind,
            charging=policy.charging, partition=self.partition,
            sarathi=bool(cfg.sarathi_budget),
            unchunked=bool(cfg.vllm_unchunked),
            prefill_only=bool(policy.prefill_only_mixed),
            has_pw=self.has_pw,
            # deadline machinery compiles away on the (default) traces
            # where every request has patience == inf
            expiry=bool(np.isfinite(tt.patience[arrived]).any()),
            loop=loop, model_kind=self.model_kind,
            k_events=int(k_events), fastforward=bool(fastforward),
            # hashable ProbeSpec (or None): rides the jit static path,
            # so probes-off compiles the byte-identical bare kernel
            telemetry=resolve_probe_spec(telemetry))
        self.telemetry = self._static["telemetry"]

    # -- raw (device array) interface -------------------------------------
    def _key(self, seed):
        if isinstance(seed, (int, np.integer)):
            return prng_key(int(seed))
        return seed

    @property
    def statics(self) -> dict:
        """The compile-time kwargs of this instance's kernel -- pass them
        to the module-level :func:`run` facade next to :attr:`params`."""
        return dict(self._static)

    def run_raw(self, seed) -> dict:
        """One replication; returns the raw scan carry (device arrays)."""
        return run(self.params, self._key(seed), placement="single",
                   **self._static)

    def run_batch_raw(self, seeds: Sequence, *, placement: str = "vmap",
                      shard: Optional[dict] = None) -> dict:
        """All replications in one batch; leaves gain a leading
        replication axis.  ``placement``/``shard`` as in :func:`run`."""
        return run(self.params, [self._key(s) for s in seeds],
                   placement=placement, shard=shard, **self._static)

    # -- EngineMetrics.summary() interface ---------------------------------
    def _summary(self, o: dict) -> dict:
        st = np.asarray(o["st"])
        t_first = np.asarray(o["t_first"], dtype=np.float64)
        t_last = np.asarray(o["t_last"], dtype=np.float64)
        t_arr = np.asarray(self.params["t_arr"], dtype=np.float64)
        D = np.asarray(self.params["D"], dtype=np.float64)

        arrivals = int((st != _NOT_ARRIVED).sum())
        completions = int((st == _DONE).sum())
        emitted = np.isfinite(t_first)
        ttft = t_first[emitted] - t_arr[emitted]
        tp_mask = (st == _DONE) & (D > 1)
        tpot = ((t_last[tp_mask] - t_first[tp_mask])
                / np.maximum(D[tp_mask] - 1.0, 1.0))

        def pct(v, q):
            return float(np.percentile(v, q)) if v.size else float("nan")

        # budget diagnostic: an event still pending before the horizon
        # means the step cap cut the replay short
        ap = int(o["aptr"])
        next_arr = (float(t_arr[ap]) if ap < t_arr.shape[0]
                    and st[ap] == _NOT_ARRIVED else np.inf)
        next_t = min(next_arr,
                     float(np.asarray(o["t_next"], dtype=np.float64).min(
                         initial=np.inf)))
        horizon = self.h_eff if self.h_eff > 0 else 1.0
        return {
            "revenue_rate": float(o["rev"]) / horizon,
            "completion_rate": completions / arrivals if arrivals else 0.0,
            "ttft_mean": float(ttft.mean()) if ttft.size else float("nan"),
            "ttft_p95": pct(ttft, 95),
            "ttft_p99": pct(ttft, 99),
            "tpot_mean": float(tpot.mean()) if tpot.size else float("nan"),
            "tpot_p95": pct(tpot, 95),
            "tpot_p99": pct(tpot, 99),
            "completions": completions,
            "arrivals": arrivals,
            "abandons": int(o["abandons"]),
            "t_end": float(o["t"]),
            "budget_exhausted": float(next_t <= self.h_eff),
            "n_iters": float(o["n_iters"]),
            "n_events": float(o["n_events"]),
            "n_steps": float(self.n_steps),
            "n_dropped": float(self.trace.n_dropped),
        }

    def summaries_from_raw(self, raw: dict) -> list:
        """Split a :meth:`run_batch_raw` carry into per-replication
        summary dicts (:meth:`EngineMetrics.summary` keys + engine
        diagnostics)."""
        host = {k: np.asarray(v) for k, v in raw.items()}
        reps = host["t"].shape[0]
        return [self._summary({k: v[r] for k, v in host.items()})
                for r in range(reps)]

    # -- telemetry interface ----------------------------------------------
    def telemetry_from_raw(self, raw: dict) -> dict:
        """Host-side probe report (:func:`repro.telemetry.extract_probes`)
        from a raw carry; batched carries reduce over their leading
        axes.  Requires the engine to have been built with
        ``telemetry=``."""
        if self.telemetry is None:
            raise ValueError("engine was built without telemetry; pass "
                             "telemetry=ProbeSpec(...) (or True)")
        return extract_probes(raw, self.telemetry,
                              horizon=self.h_eff if self.h_eff > 0 else 1.0,
                              n_servers=self.n)

    def lifecycle_records_from_raw(self, raw: dict,
                                   limit: Optional[int] = None) -> list:
        """Per-request lifecycle records for the Chrome-trace exporter
        (:func:`repro.telemetry.lifecycle_events`) from a
        SINGLE-replication raw carry.  The JAX carry tracks
        arrival/first/last only, so queue wait and prefill render as one
        merged span."""
        st = np.asarray(raw["st"])
        if st.ndim != 1:
            raise ValueError("lifecycle records need a single-replication "
                             "carry; index one replication first")
        t_first = np.asarray(raw["t_first"], dtype=np.float64)
        t_last = np.asarray(raw["t_last"], dtype=np.float64)
        t_arr = np.asarray(self.params["t_arr"], dtype=np.float64)
        cls = np.asarray(self.params["cls"])
        names = ("not_arrived", "queued", "prefill", "buffered", "decode",
                 "done", "abandoned")
        records = []
        for rid in np.nonzero(st != _NOT_ARRIVED)[0]:
            records.append({
                "rid": int(rid),
                "cls": self.classes[int(cls[rid])].name,
                "t_arr": float(t_arr[rid]),
                "t_first": float(t_first[rid]),
                "t_last": float(t_last[rid]),
                "state": names[int(st[rid])],
            })
            if limit is not None and len(records) >= limit:
                break
        return records

    def run(self, seed=0) -> dict:
        return self._summary({k: np.asarray(v)
                              for k, v in self.run_raw(seed).items()})

    def run_batch(self, seeds: Sequence, *, placement: str = "vmap",
                  shard: Optional[dict] = None) -> list:
        return self.summaries_from_raw(
            self.run_batch_raw(seeds, placement=placement, shard=shard))
