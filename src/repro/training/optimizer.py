"""AdamW with cosine schedule, global-norm clipping, optional low-precision
moment states (the knob that makes 300B+ optimizer state fit a v5e pod)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "opt_init", "opt_update", "lr_at"]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | const
    state_dtype: str = "float32"  # float32 | bfloat16 (m/v moments)


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "const":
        return cfg.lr * warm
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def opt_init(params, cfg: OptConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def opt_update(params, grads, state, cfg: OptConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
        newp = (p.astype(jnp.float32)
                - lr * (step_dir + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t3: t3[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
