"""Training substrate: optimizer, train step, sharding, data, compression."""

from .optimizer import OptConfig, opt_init, opt_update  # noqa: F401
from .train_step import init_train_state, make_loss, make_train_step  # noqa: F401
from .sharding import auto_demote, batch_spec, make_rules, state_shardings  # noqa: F401
from .data import DataConfig, SyntheticLM, make_batch_iterator  # noqa: F401
