"""Deterministic synthetic LM data pipeline.

Offline container: generates a seeded, Zipf-distributed token stream with
document structure (BOS-delimited docs of lognormal length), packed into
fixed (batch, seq) blocks -- enough structure for a ~100M model to show a
real loss curve.  The iterator is stateless-resumable: ``state`` is a plain
int cursor that checkpoints alongside the train state.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch_iterator"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 512
    bos: int = 1


class SyntheticLM:
    """Markov-flavoured Zipf stream: token t+1 depends on t via a seeded
    permutation mix, so the data has learnable bigram structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)
        # Zipf over an effective vocabulary (clipped to vocab_size)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.p = p / p.sum()

    def batch_at(self, cursor: int) -> dict:
        """Deterministic batch for a given cursor (resume = same stream)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, cursor))
        shape = (cfg.batch, cfg.seq_len + 1)
        base = rng.choice(cfg.vocab_size, size=shape, p=self.p)
        # bigram structure: with prob .5, next token = perm[prev]
        mix = rng.random(shape) < 0.5
        stream = base.copy()
        stream[:, 1:] = np.where(
            mix[:, 1:], self.perm[stream[:, :-1]], base[:, 1:])
        # document boundaries
        doclen = np.maximum(
            8, rng.poisson(cfg.mean_doc_len, size=(cfg.batch, 4)))
        for b in range(cfg.batch):
            pos = np.cumsum(doclen[b])
            pos = pos[pos < cfg.seq_len]
            stream[b, pos] = cfg.bos
        tokens = stream[:, :-1].astype(np.int32)
        labels = stream[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


def make_batch_iterator(cfg: DataConfig, start_cursor: int = 0):
    """Yields (cursor, batch) pairs; checkpoint the cursor to resume."""
    ds = SyntheticLM(cfg)
    cursor = start_cursor
    while True:
        yield cursor, ds.batch_at(cursor)
        cursor += 1
