"""Pipeline-parallel train step (GPipe-style) over a mesh "pipe" axis.

For the largest assigned models an optional third parallelism axis: layers
are split into ``n_stages`` contiguous stages; microbatches stream through
stages with ``jax.lax.ppermute`` boundary transfers inside a ``shard_map``.
The schedule is the standard GPipe fill/drain loop expressed as a
``lax.scan`` over ``n_micro + n_stages - 1`` ticks, with each stage either
idle, forwarding, or (in the backward scan) accumulating grads -- a
deterministic, compiler-visible schedule with bubble fraction
``(S-1)/(M+S-1)`` (reported by :func:`bubble_fraction`).

This module implements forward-only pipelining for inference-style use and
a loss-through-pipeline trick for training: the scanned stage function is
differentiated as a whole (jax.grad through shard_map+ppermute), which is
correct albeit less memory-lean than hand-rolled 1F1B; remat inside each
stage keeps activations bounded.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pcast, shard_map

__all__ = ["bubble_fraction", "make_pipeline_forward"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def make_pipeline_forward(stage_fn, mesh, *, n_micro: int, axis: str = "pipe"):
    """stage_fn(stage_params, x, stage_id) -> y, applied per stage.

    Returns ``f(stacked_stage_params, x_micro)`` where ``x_micro`` has
    leading dim n_micro; output is the final-stage stream, same leading dim.
    Runs as a shard_map over ``axis``; stage s holds stage s's params.
    """
    S = mesh.shape[axis]
    ticks = n_micro + S - 1

    def per_stage(params_local, xs_local):
        # params_local: this stage's params (leading stage dim stripped by
        # shard_map partitioning); xs_local: full microbatch stream
        # (replicated over the pipe axis; only stage 0 consumes it).
        sid = jax.lax.axis_index(axis)
        x0 = xs_local[0]
        buf = jnp.zeros_like(x0)  # inter-stage register
        outs = jnp.zeros((n_micro,) + x0.shape, x0.dtype)
        # carries become device-varying inside the loop; mark them so
        buf = pcast(buf, (axis,), to="varying")
        outs = pcast(outs, (axis,), to="varying")

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if in range); others use buf
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(sid == 0, xs_local[inject], buf)
            y = stage_fn(params_local, x_in, sid)
            # valid iff this stage is processing a real microbatch at tick t
            mb = t - sid
            valid = (mb >= 0) & (mb < n_micro)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage writes outs[mb]; others forward to the next stage
            write = (sid == S - 1) & valid
            mb_idx = jnp.clip(mb, 0, n_micro - 1)
            outs = outs.at[mb_idx].set(
                jnp.where(write, y, outs[mb_idx]))
            buf_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (buf_next, outs), ()

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage's outs are real; zero-fill + psum broadcasts
        # them to every stage (and restores the replicated type for vma)
        outs = jnp.where(sid == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
