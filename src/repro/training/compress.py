"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

TPU adaptation of deep-gradient-compression ideas: each DP worker quantises
its local gradient shard to int8 with a per-tensor scale, all-reduces the
int8 payload (8x fewer collective bytes on the DP axis -- visible in the
dry-run HLO), dequantises, and keeps the quantisation residual locally,
adding it back before the next step (error feedback keeps the scheme
convergent).

Built as a ``shard_map`` over the DP axis so the psum operand is explicit
and auditable in the lowered HLO.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["init_residuals", "make_compressed_psum", "quantize_int8",
           "dequantize_int8"]


def quantize_int8(x):
    """Per-tensor symmetric int8 quantisation; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_psum(mesh, axis: str = "data"):
    """Returns ``f(grads, residuals) -> (mean_grads, new_residuals)``.

    Call *inside* a shard_map over ``axis`` (grads are the local summands).
    The int8 payload is what crosses the interconnect.
    """
    n = mesh.shape[axis]

    def psum_one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g)
        new_r = g - dequantize_int8(q, scale)  # error feedback
        # all-reduce the int8 payload (sum in int32 to avoid overflow),
        # and the tiny scale scalar alongside.
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.psum(scale, axis)
        mean = qsum.astype(jnp.float32) * (ssum / n) / n
        return mean, new_r

    def f(grads, residuals):
        out = jax.tree.map(psum_one, grads, residuals)
        mean = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        return mean, res

    return f
