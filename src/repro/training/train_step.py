"""The jit-compiled training step.

``make_train_step`` builds a pure ``(state, batch) -> (state, metrics)``
function with:

* remat (activation checkpointing) at layer-superblock granularity,
* optional gradient accumulation over microbatches (``lax.scan``),
* AdamW with clipping/schedule (:mod:`repro.training.optimizer`),
* optional int8 error-feedback gradient compression in the data-parallel
  all-reduce (:mod:`repro.training.compress`, shard_map variant).

Sharding is applied by the caller (launch/train.py or launch/dryrun.py) via
``in_shardings``/``out_shardings`` from :mod:`repro.training.sharding`.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

from .optimizer import OptConfig, opt_init, opt_update

__all__ = ["make_loss", "make_train_step", "init_train_state"]


def make_loss(cfg: ModelConfig, *, remat: bool = True,
              unroll: bool = False) -> Callable:
    def loss(params, batch):
        return M.loss_fn(
            cfg, params, batch["tokens"], batch["labels"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_frames=batch.get("enc_frames"),
            remat=remat, unroll=unroll)
    return loss


def init_train_state(cfg: ModelConfig, key, opt: OptConfig,
                     dtype=jnp.float32) -> dict:
    params = M.init_model(cfg, key, dtype)
    return {"params": params, "opt": opt_init(params, opt)}


def make_train_step(cfg: ModelConfig, opt: OptConfig, *,
                    microbatches: int = 1, remat: bool = True,
                    unroll: bool = False,
                    grad_transform: Optional[Callable] = None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``grad_transform`` (e.g. int8 compression psum from compress.py) is
    applied to the raw grads before the optimizer; by default grads flow
    through jit's own sharding-induced reductions.
    """
    loss_f = make_loss(cfg, remat=remat, unroll=unroll)
    grad_f = jax.value_and_grad(loss_f)

    def step(state, batch):
        params = state["params"]
        if microbatches <= 1:
            loss, grads = grad_f(params, batch)
        else:
            def slice_mb(i, x):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def accum(carry, i):
                g_acc, l_acc = carry
                mb_batch = {k: slice_mb(i, v) for k, v in batch.items()}
                l, g = grad_f(params, mb_batch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, om = opt_update(params, grads, state["opt"], opt)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return step
