"""Logical-axis -> mesh-axis rules, with automatic divisibility fallback.

Strategy: tensor-parallel over the mesh "model" axis (heads / ff / vocab /
expert), FSDP over "data" (and optionally "pod") on the "embed" axis, batch
over ("pod","data").  Any logical axis whose dimension is not divisible by
its mesh-axis size *anywhere* in the def tree is demoted to replicated --
this is what lets 14-head / odd-vocab archs share one rule set (the waste is
visible in the roofline's MODEL_FLOPS/HLO ratio, by design).
"""

from __future__ import annotations

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import DEFAULT_RULES, _walk, partition_specs

__all__ = ["make_rules", "batch_spec", "state_shardings", "auto_demote"]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def make_rules(mesh: Mesh, *, fsdp: bool = True,
               fsdp_axis="data", overrides: dict | None = None) -> dict:
    rules = dict(DEFAULT_RULES)
    if fsdp:
        # FSDP shards the "embed" axis; expert_ff stays replicated (expert
        # weights are already 2D-sharded via expert x embed).
        rules["embed"] = fsdp_axis
    if overrides:
        rules.update(overrides)
    return rules


def auto_demote(defs: dict, rules: dict, mesh: Mesh) -> dict:
    """Replicate any logical axis that does not divide everywhere it occurs."""
    bad: set[str] = set()
    for _, d in _walk(defs):
        for dim, ax in zip(d.shape, d.axes):
            if ax is None or rules.get(ax) is None:
                continue
            if dim % _axis_size(mesh, rules[ax]) != 0:
                bad.add(ax)
    out = dict(rules)
    for ax in bad:
        out[ax] = None
    return out


def batch_spec(mesh: Mesh) -> P:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def state_shardings(defs: dict, mesh: Mesh, rules: dict):
    """NamedSharding trees for params and AdamW moments (same layout)."""
    specs = partition_specs(defs, rules)
    import jax

    to_ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = jax.tree.map(to_ns, specs,
                        is_leaf=lambda x: isinstance(x, P))
    return p_sh
