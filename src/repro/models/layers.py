"""Common layers: norms, MLPs, rotary embeddings, embedding/unembedding."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .params import PDef

__all__ = [
    "rmsnorm", "layernorm", "mlp_defs", "apply_mlp", "rope_table",
    "apply_rope", "softcap",
]


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- MLP


def mlp_defs(d_model: int, d_ff: int, act: str) -> dict:
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": PDef((d_model, d_ff), ("embed", "ff")),
            "w_up": PDef((d_model, d_ff), ("embed", "ff")),
            "w_down": PDef((d_ff, d_model), ("ff", "embed")),
        }
    return {  # plain gelu MLP (whisper)
        "w_up": PDef((d_model, d_ff), ("embed", "ff")),
        "b_up": PDef((d_ff,), ("ff",), "zeros"),
        "w_down": PDef((d_ff, d_model), ("ff", "embed")),
        "b_down": PDef((d_model,), ("embed",), "zeros"),
    }


def apply_mlp(p: dict, x, act: str):
    if act in ("swiglu", "geglu"):
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        a = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        return (a * u) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------- RoPE


def rope_table(positions, dim: int, theta: float):
    """positions (...,) -> (sin, cos) of shape (..., dim//2)."""
    freqs = 1.0 / (
        theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x (..., S, H, D); sin/cos (..., S, D/2) broadcast over heads."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    s = sin[..., None, :]
    c = cos[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1
    ).astype(x.dtype)
