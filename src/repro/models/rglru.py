"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block structure (Griffin):  x -> [W_side -> GeLU]  and
[W_main -> causal conv1d(4) -> RG-LRU] -> elementwise product -> W_out.

RG-LRU:  r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
         a_t = exp(c * r_t * log(sigmoid(Lambda)))        (per channel)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Sequence mode uses ``jax.lax.associative_scan`` (log-depth, statically
unrolled in HLO -> honest FLOP counts); decode is the O(1) recurrence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import RGLRUConfig
from .params import PDef

__all__ = ["rglru_defs", "rglru_forward", "rglru_decode", "init_rglru_cache"]


def rglru_defs(cfg: RGLRUConfig, d_model: int) -> dict:
    W = cfg.width or d_model
    return {
        "w_main": PDef((d_model, W), ("embed", "lru")),
        "w_side": PDef((d_model, W), ("embed", "lru")),
        "conv_w": PDef((cfg.conv_width, W), ("conv", "lru"), scale=0.5),
        "conv_b": PDef((W,), ("lru",), "zeros"),
        "w_a": PDef((W, W), ("lru", None), scale=0.02),
        "b_a": PDef((W,), ("lru",), "const:-1.0"),
        "w_i": PDef((W, W), ("lru", None), scale=0.02),
        "b_i": PDef((W,), ("lru",), "zeros"),
        "lam": PDef((W,), ("lru",), "const:2.0"),  # sigmoid(2) ~ .88 decay
        "w_out": PDef((W, d_model), ("lru", "embed")),
    }


def init_rglru_cache(cfg: RGLRUConfig, d_model: int, batch: int, dtype):
    W = cfg.width or d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, W), dtype),
        "h": jnp.zeros((batch, W), jnp.float32),
    }


def _gates(cfg: RGLRUConfig, p, u):
    r = jax.nn.sigmoid(u @ p["w_a"].astype(u.dtype) + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(u @ p["w_i"].astype(u.dtype) + p["b_i"].astype(u.dtype))
    log_sig_lam = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    log_a = cfg.c * r.astype(jnp.float32) * log_sig_lam  # (…, W), negative
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i.astype(jnp.float32) * u.astype(jnp.float32)
    )
    return a, gated


def rglru_forward(cfg: RGLRUConfig, p, x, *, cache=None):
    """x (B,S,d_model) -> (B,S,d_model); writes final state into cache."""
    B, S, _ = x.shape
    side = jax.nn.gelu(x @ p["w_side"].astype(x.dtype))
    u = x @ p["w_main"].astype(x.dtype)
    # causal depthwise conv
    pad = cfg.conv_width - 1
    up = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))
    if cache is not None:
        up = up.at[:, :pad].set(cache["conv"].astype(u.dtype))
    cw = p["conv_w"].astype(x.dtype)
    uc = sum(
        up[:, i : i + S] * cw[i][None, None, :] for i in range(cfg.conv_width)
    ) + p["conv_b"].astype(x.dtype)

    a, gated = _gates(cfg, p, uc)
    h0 = cache["h"] if cache is not None else jnp.zeros_like(gated[:, 0])
    # include initial state by folding it into the first input
    gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (h.astype(x.dtype) * side) @ p["w_out"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {
            "conv": u[:, S - pad :, :].astype(cache["conv"].dtype),
            "h": h[:, -1],
        }
    return y, new_cache


def rglru_decode(cfg: RGLRUConfig, p, x, cache):
    """x (B,1,d_model); O(1) state update."""
    B = x.shape[0]
    side = jax.nn.gelu(x[:, 0] @ p["w_side"].astype(x.dtype))
    u = x[:, 0] @ p["w_main"].astype(x.dtype)  # (B,W)
    hist = cache["conv"].astype(x.dtype)
    full = jnp.concatenate([hist, u[:, None, :]], axis=1)
    cw = p["conv_w"].astype(x.dtype)
    uc = jnp.einsum("bwc,wc->bc", full, cw) + p["conv_b"].astype(x.dtype)
    a, gated = _gates(cfg, p, uc)
    h = a * cache["h"] + gated
    y = (h.astype(x.dtype) * side) @ p["w_out"].astype(x.dtype)
    return y[:, None, :], {"conv": full[:, 1:, :].astype(cache["conv"].dtype),
                           "h": h}
