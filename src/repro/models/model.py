"""Unified model: composes the mixer/channel modules into a full LM.

A model is ``embed -> [segments of layers] -> final_norm -> unembed``.
``segment_layers`` compresses the per-layer BlockSpec list into
``(superblock, repeat)`` segments; each segment's parameters are stacked with
a leading ``repeat`` dim and the forward pass ``lax.scan``s over it (small
HLO, honest memory picture).  For dry-run FLOP accounting the same forward
can be built with ``unroll=True`` (static python loop) so XLA's
``cost_analysis`` sees every layer.

Three entry points, matching the serving/training split of the paper:

* :func:`forward_train`  -- teacher-forced logits over a full sequence.
* :func:`forward_prefill` -- full/chunked prefill that writes caches and
  returns the last-position logits.
* :func:`forward_decode` -- one-token decode step over the caches.

Encoder-decoder (whisper) runs its encoder over stub frame embeddings and
feeds cross-attention KV to every decoder block; prefix-LM (paligemma)
prepends stub patch embeddings with a bidirectional prefix mask.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    attn_defs,
    attention_decode,
    attention_prefill,
    blockwise_attention,
    init_kv_cache,
)
from .config import BlockSpec, ModelConfig, segment_layers
from .layers import apply_mlp, layernorm, mlp_defs, rmsnorm, softcap
from .mla import init_mla_cache, mla_decode, mla_defs, mla_prefill
from .moe import apply_moe, moe_defs
from .params import PDef, init_params
from .rglru import init_rglru_cache, rglru_decode, rglru_defs, rglru_forward
from .ssm import init_ssm_cache, ssm_decode, ssm_defs, ssm_forward

__all__ = [
    "model_defs",
    "init_cache",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "loss_fn",
    "encoder_forward",
    "param_count",
]


# ------------------------------------------------------------------ norms


def _norm_defs(cfg: ModelConfig, d: int) -> dict:
    if cfg.norm == "layernorm":
        return {
            "scale": PDef((d,), ("embed",), "ones"),
            "bias": PDef((d,), ("embed",), "zeros"),
        }
    return {"scale": PDef((d,), ("embed",), "zeros")}  # rmsnorm (1 + scale)


def _apply_norm(cfg: ModelConfig, p: dict, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ------------------------------------------------------------- block defs


def _block_defs(cfg: ModelConfig, spec: BlockSpec) -> dict:
    d = cfg.d_model
    defs: dict = {"ln1": _norm_defs(cfg, d)}
    if spec.mixer in ("attn", "attn_local"):
        defs["attn"] = attn_defs(cfg.attn, d)
    elif spec.mixer == "mla":
        defs["mla"] = mla_defs(cfg.mla, d)
    elif spec.mixer == "ssm":
        defs["ssm"] = ssm_defs(cfg.ssm, d)
    elif spec.mixer == "rec":
        defs["rec"] = rglru_defs(cfg.rglru, d)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        defs["lnx"] = _norm_defs(cfg, d)
        defs["xattn"] = attn_defs(cfg.attn, d)
    if spec.channel == "mlp":
        defs["ln2"] = _norm_defs(cfg, d)
        defs["mlp"] = mlp_defs(d, cfg.d_ff, cfg.mlp_act)
    elif spec.channel == "moe":
        defs["ln2"] = _norm_defs(cfg, d)
        defs["moe"] = moe_defs(cfg.moe, d)
    return defs


def _stack_defs(defs: dict, rep: int) -> dict:
    out = {}
    for k, v in defs.items():
        out[k] = _stack_defs(v, rep) if isinstance(v, dict) else v.stacked(rep)
    return out


def model_defs(cfg: ModelConfig) -> dict:
    """Full parameter-definition tree (PDef leaves)."""
    d, V = cfg.d_model, cfg.vocab_size
    defs: dict = {
        "embed": PDef((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": _norm_defs(cfg, d),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = PDef((d, V), ("embed", "vocab"))
    if cfg.attn is not None and not cfg.attn.rope:
        # learned decoder positions (whisper-style)
        defs["pos_embed"] = PDef((cfg.max_seq_len, d), (None, "embed"),
                                 scale=0.02)
    segs = segment_layers(cfg.block_specs())
    for si, (block, rep) in enumerate(segs):
        seg = {}
        for bi, spec in enumerate(block):
            seg[f"b{bi}"] = _stack_defs(_block_defs(cfg, spec), rep)
        defs[f"seg{si}"] = seg
    if cfg.encoder is not None:
        e = cfg.encoder
        enc_block = {
            "ln1": _norm_defs(cfg, e.d_model),
            "attn": attn_defs(cfg.attn.__class__(
                n_heads=e.n_heads, n_kv_heads=e.n_heads,
                head_dim=e.d_model // e.n_heads, rope=False, causal=False,
            ), e.d_model),
            "ln2": _norm_defs(cfg, e.d_model),
            "mlp": mlp_defs(e.d_model, e.d_ff, "gelu"),
        }
        defs["encoder"] = {
            "pos": PDef((e.n_frames, e.d_model), ("frames", "embed"),
                        scale=0.02),
            "layers": _stack_defs(enc_block, e.n_layers),
            "final_norm": _norm_defs(cfg, e.d_model),
        }
    if cfg.mtp:
        defs["mtp"] = {
            "norm": _norm_defs(cfg, d),
            "proj": PDef((2 * d, d), ("ff", "embed")),
        }
    return defs


# ------------------------------------------------------------------ caches


def _block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int, max_len: int,
                 dtype):
    if spec.mixer in ("attn", "attn_local"):
        ring = cfg.attn.window if spec.mixer == "attn_local" else None
        c = init_kv_cache(batch, max_len, cfg.attn.n_kv_heads,
                          cfg.attn.head_dim, dtype, ring_window=ring,
                          quant=cfg.kv_quant)
    elif spec.mixer == "mla":
        c = init_mla_cache(cfg.mla, batch, max_len, dtype,
                           quant=cfg.kv_quant)
    elif spec.mixer == "ssm":
        c = init_ssm_cache(cfg.ssm, cfg.d_model, batch, dtype)
    elif spec.mixer == "rec":
        c = init_rglru_cache(cfg.rglru, cfg.d_model, batch, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        e = cfg.encoder
        hd = cfg.attn.head_dim
        c = dict(c)
        c["xk"] = jnp.zeros((batch, e.n_frames, cfg.attn.n_kv_heads, hd), dtype)
        c["xv"] = jnp.zeros((batch, e.n_frames, cfg.attn.n_kv_heads, hd), dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-segment stacked cache tree (leading dim = segment repeat)."""
    segs = segment_layers(cfg.block_specs())
    out = []
    for block, rep in segs:
        seg = {}
        for bi, spec in enumerate(block):
            c = _block_cache(cfg, spec, batch, max_len, dtype)
            seg[f"b{bi}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (rep,) + a.shape), c
            )
        out.append(seg)
    return out


# ------------------------------------------------------------- block apply


def _cross_attention(cfg: ModelConfig, p, x, xk, xv):
    """Decoder->encoder cross attention (no mask, no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    out = blockwise_attention(
        q, xk, xv,
        q_positions=jnp.arange(x.shape[1]),
        k_positions=jnp.arange(xk.shape[1]),
        causal=False,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def _apply_block(cfg: ModelConfig, spec: BlockSpec, p, x, *, positions, mode,
                 cache, prefix_len, enc_out, kernel_impl="xla",
                 continuation=False):
    """One layer. mode: "train" | "prefill" | "decode"."""
    h = _apply_norm(cfg, p["ln1"], x)
    new_cache = dict(cache) if cache is not None else None
    if spec.mixer in ("attn", "attn_local"):
        local = spec.mixer == "attn_local"
        kv_keys = ("k", "v", "pos") + (
            ("k_s", "v_s") if cache is not None and "k_s" in cache else ())
        if mode == "decode":
            sub = {k: cache[k] for k in kv_keys}
            out, nc = attention_decode(cfg.attn, p["attn"], h, positions, sub,
                                       local=local)
            new_cache.update(nc)
        else:
            sub = ({k: cache[k] for k in kv_keys}
                   if cache is not None else None)
            out, nc = attention_prefill(
                cfg.attn, p["attn"], h, positions, local=local, cache=sub,
                prefix_len=prefix_len, kernel_impl=kernel_impl,
                continuation=continuation)
            if nc is not None:
                new_cache.update(nc)
    elif spec.mixer == "mla":
        mla_keys = ("c_kv", "k_rope") + (
            ("c_s", "r_s") if cache is not None and "c_s" in cache else ())
        sub = ({k: cache[k] for k in mla_keys}
               if cache is not None else None)
        if mode == "decode":
            out, nc = mla_decode(cfg.mla, p["mla"], h, positions, sub)
            new_cache.update(nc)
        else:
            out, nc = mla_prefill(cfg.mla, p["mla"], h, positions, cache=sub,
                                  continuation=continuation)
            if nc is not None:
                new_cache.update(nc)
    elif spec.mixer == "ssm":
        sub = ({k: cache[k] for k in ("conv", "ssm")}
               if cache is not None else None)
        if mode == "decode":
            out, nc = ssm_decode(cfg.ssm, p["ssm"], h, sub)
            new_cache.update(nc)
        else:
            out, nc = ssm_forward(cfg.ssm, p["ssm"], h, cache=sub)
            if nc is not None:
                new_cache.update(nc)
    elif spec.mixer == "rec":
        sub = ({k: cache[k] for k in ("conv", "h")}
               if cache is not None else None)
        if mode == "decode":
            out, nc = rglru_decode(cfg.rglru, p["rec"], h, sub)
            new_cache.update(nc)
        else:
            out, nc = rglru_forward(cfg.rglru, p["rec"], h, cache=sub)
            if nc is not None:
                new_cache.update(nc)
    else:
        raise ValueError(spec.mixer)
    x = x + out

    if spec.cross_attn:
        hx = _apply_norm(cfg, p["lnx"], x)
        if mode == "decode":
            xk, xv = cache["xk"], cache["xv"]
        else:
            # project encoder output once; persist in the cache if present
            xk = jnp.einsum("bsd,dhk->bshk", enc_out,
                            p["xattn"]["wk"].astype(x.dtype))
            xv = jnp.einsum("bsd,dhk->bshk", enc_out,
                            p["xattn"]["wv"].astype(x.dtype))
            if new_cache is not None:
                new_cache["xk"], new_cache["xv"] = xk, xv
        x = x + _cross_attention(cfg, p["xattn"], hx, xk, xv)

    if spec.channel == "mlp":
        h = _apply_norm(cfg, p["ln2"], x)
        mp = jax.tree.map(lambda a: a.astype(x.dtype), p["mlp"])
        x = x + apply_mlp(mp, h, cfg.mlp_act)
    elif spec.channel == "moe":
        h = _apply_norm(cfg, p["ln2"], x)
        x = x + apply_moe(cfg.moe, p["moe"], h)
    return x, new_cache


# --------------------------------------------------------------- backbone


def _run_segments(cfg: ModelConfig, params, x, *, positions, mode, caches,
                  prefix_len, enc_out, unroll, kernel_impl="xla",
                  remat=False, continuation=False):
    segs = segment_layers(cfg.block_specs())
    new_caches = [] if caches is not None else None
    for si, (block, rep) in enumerate(segs):
        seg_p = params[f"seg{si}"]
        seg_c = caches[si] if caches is not None else None

        def body(x, p_slice, c_slice):
            nc = {} if c_slice is not None else None
            for bi, spec in enumerate(block):
                x, c = _apply_block(
                    cfg, spec, p_slice[f"b{bi}"], x, positions=positions,
                    mode=mode, cache=(c_slice[f"b{bi}"] if c_slice else None),
                    prefix_len=prefix_len, enc_out=enc_out,
                    kernel_impl=kernel_impl, continuation=continuation)
                if nc is not None:
                    nc[f"b{bi}"] = c
            return x, nc

        if remat:
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )

        if unroll or rep == 1:
            ncs = []
            for r in range(rep):
                p_r = jax.tree.map(lambda a: a[r], seg_p)
                c_r = (jax.tree.map(lambda a: a[r], seg_c)
                       if seg_c is not None else None)
                x, nc = body(x, p_r, c_r)
                ncs.append(nc)
            if new_caches is not None:
                new_caches.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *ncs))
        else:
            if seg_c is None:
                def step(carry, p_slice):
                    y, _ = body(carry, p_slice, None)
                    return y, ()
                x, _ = jax.lax.scan(step, x, seg_p)
            else:
                def step(carry, inp):
                    p_slice, c_slice = inp
                    y, nc = body(carry, p_slice, c_slice)
                    return y, nc
                x, nc = jax.lax.scan(step, x, (seg_p, seg_c))
                new_caches.append(nc)
    return x, new_caches


def _logits(cfg: ModelConfig, params, x):
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.logit_softcap is not None:
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits


def _embed(cfg: ModelConfig, params, tokens, positions, prefix_embeds):
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions]
    x = x.astype(jnp.bfloat16 if cfg.param_dtype == "bfloat16" else x.dtype)
    prefix_len = None
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    return x, prefix_len


def encoder_forward(cfg: ModelConfig, params, frames, *, unroll=False):
    """Whisper-style encoder over stub frame embeddings (B, n_frames, d)."""
    e = cfg.encoder
    p = params["encoder"]
    x = frames + p["pos"].astype(frames.dtype)[None]
    acfg = cfg.attn.__class__(
        n_heads=e.n_heads, n_kv_heads=e.n_heads,
        head_dim=e.d_model // e.n_heads, rope=False, causal=False)

    def step(x, lp):
        h = _apply_norm(cfg, lp["ln1"], x)
        out, _ = attention_prefill(
            acfg, lp["attn"], h, jnp.arange(e.n_frames)[None], local=False)
        x = x + out
        h = _apply_norm(cfg, lp["ln2"], x)
        mp = jax.tree.map(lambda a: a.astype(x.dtype), lp["mlp"])
        return x + apply_mlp(mp, h, "gelu"), ()

    if unroll:
        for r in range(e.n_layers):
            x, _ = step(x, jax.tree.map(lambda a: a[r], p["layers"]))
    else:
        x, _ = jax.lax.scan(step, x, p["layers"])
    return _apply_norm(cfg, p["final_norm"], x)


# ------------------------------------------------------------ entry points


def forward_train(cfg: ModelConfig, params, tokens, *, prefix_embeds=None,
                  enc_frames=None, unroll=False, remat=False):
    """Teacher-forced logits (B, S[, +prefix], V)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encoder_forward(cfg, params, enc_frames, unroll=unroll)
    x, prefix_len = _embed(cfg, params, tokens, positions, prefix_embeds)
    if prefix_len:
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], (B, x.shape[1]))
    x, _ = _run_segments(cfg, params, x, positions=positions, mode="train",
                         caches=None, prefix_len=prefix_len, enc_out=enc_out,
                         unroll=unroll, remat=remat)
    if prefix_len:
        x = x[:, prefix_len:]
    return _logits(cfg, params, x), x


def loss_fn(cfg: ModelConfig, params, tokens, labels, *, prefix_embeds=None,
            enc_frames=None, unroll=False, remat=False):
    """Mean next-token cross entropy; labels < 0 are masked out.

    With ``cfg.mtp`` adds DeepSeek-V3-style multi-token prediction: a second
    head predicts token t+2 from [hidden_t ; embed(label_t)].
    """
    logits, hidden = forward_train(
        cfg, params, tokens, prefix_embeds=prefix_embeds,
        enc_frames=enc_frames, unroll=unroll, remat=remat)
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.mtp:
        # predict labels shifted one more step (t+2 target from position t)
        emb_next = params["embed"][lab]
        if cfg.scale_embed:
            emb_next = emb_next * np.sqrt(cfg.d_model).astype(np.float32)
        h2 = jnp.concatenate([hidden, emb_next.astype(hidden.dtype)], axis=-1)
        h2 = h2 @ params["mtp"]["proj"].astype(hidden.dtype)
        h2 = _apply_norm(cfg, params["mtp"]["norm"], h2)
        logits2 = _logits(cfg, params, h2).astype(jnp.float32)
        lab2 = jnp.concatenate(
            [lab[:, 1:], jnp.zeros_like(lab[:, :1])], axis=1)
        mask2 = jnp.concatenate(
            [mask[:, 1:], jnp.zeros_like(mask[:, :1])], axis=1)
        logp2 = jax.nn.log_softmax(logits2, axis=-1)
        nll2 = -jnp.take_along_axis(logp2, lab2[..., None], axis=-1)[..., 0]
        loss = loss + 0.3 * (nll2 * mask2).sum() / jnp.maximum(mask2.sum(), 1.)
    return loss


def forward_prefill(cfg: ModelConfig, params, tokens, positions, caches, *,
                    prefix_embeds=None, enc_frames=None, unroll=False,
                    kernel_impl="xla", continuation=False):
    """Prefill a chunk; returns (last-position logits, new caches).

    positions: (B, S) absolute positions of ``tokens`` (supports chunked /
    continued prefill).
    """
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encoder_forward(cfg, params, enc_frames, unroll=unroll)
    x, prefix_len = _embed(cfg, params, tokens, positions, prefix_embeds)
    if prefix_len:
        B = tokens.shape[0]
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(prefix_len)[None], (B, prefix_len)),
             positions + prefix_len], axis=1)
    x, new_caches = _run_segments(
        cfg, params, x, positions=positions, mode="prefill", caches=caches,
        prefix_len=prefix_len, enc_out=enc_out, unroll=unroll,
        kernel_impl=kernel_impl, continuation=continuation)
    logits = _logits(cfg, params, x[:, -1:])
    return logits, new_caches


def forward_decode(cfg: ModelConfig, params, tokens, positions, caches, *,
                   unroll=False):
    """One-token decode. tokens (B, 1); positions (B,) current index."""
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions][:, None]
    x = x.astype(jnp.bfloat16 if cfg.param_dtype == "bfloat16" else x.dtype)
    x, new_caches = _run_segments(
        cfg, params, x, positions=positions, mode="decode", caches=caches,
        prefix_len=None, enc_out=None, unroll=unroll)
    return _logits(cfg, params, x), new_caches


def init_model(cfg: ModelConfig, key, dtype=jnp.float32):
    return init_params(model_defs(cfg), key, dtype)


def param_count(cfg: ModelConfig) -> int:
    from .params import _walk

    return sum(int(np.prod(d.shape)) for _, d in _walk(model_defs(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k+shared experts only)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    from .params import _walk

    moe_layer = moe_defs(cfg.moe, cfg.d_model)
    routed = sum(
        int(np.prod(d.shape)) for path, d in _walk(moe_layer)
        if path[0] in ("w_gate", "w_up", "w_down"))
    n_moe_layers = sum(
        1 for s in cfg.block_specs() if s.channel == "moe")
    active_frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - n_moe_layers * routed * (1 - active_frac))
