"""Unified model configuration covering the 10 assigned architectures.

A model is a stack of *blocks*; each block has a token-mixing part
("attn" | "mla" | "rec" | "ssm") and a channel-mixing part ("mlp" | "moe").
Per-layer heterogeneity (gemma2 local/global alternation, recurrentgemma's
rec,rec,attn pattern, deepseek-v3's dense-then-MoE prefix) is expressed as a
layer pattern which the runtime compresses into (prefix, periodic-group)
segments so the forward pass can lax.scan over layer-stacked parameters.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

__all__ = [
    "AttentionConfig",
    "MLAConfig",
    "MoEConfig",
    "SSMConfig",
    "RGLRUConfig",
    "EncoderConfig",
    "PrefixVisionStub",
    "AudioFrontendStub",
    "BlockSpec",
    "ModelConfig",
    "segment_layers",
]


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None  # gemma2 attention logit softcap
    window: Optional[int] = None  # sliding window for "local" layers
    rope: bool = True  # whisper uses learned positions instead
    causal: bool = True


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared (always-on) experts
    capacity_factor: float = 1.25
    router_scale: bool = True  # normalise top-k gate weights to sum 1
    # mesh axes for the dispatch buffer (expert_dim, capacity_dim): aligning
    # the capacity dim with the token (data) axis turns GSPMD's giant
    # buffer all-reduces into local scatters + activation-sized all-to-alls
    dispatch_hint: Optional[Tuple] = None


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block."""

    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block."""

    width: int = 0  # lru width (defaults to d_model)
    conv_width: int = 4
    c: float = 8.0  # recurrence exponent scale


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (frontend stubbed to precomputed frames)."""

    n_layers: int
    n_frames: int  # encoder sequence length (e.g. 1500)
    d_model: int
    n_heads: int
    d_ff: int


@dataclass(frozen=True)
class PrefixVisionStub:
    """PaliGemma-style stub: input provides patch embeddings directly."""

    n_patches: int = 256
    d_embed: int = 0  # defaults to d_model


@dataclass(frozen=True)
class AudioFrontendStub:
    """Whisper-style stub: input provides audio frame embeddings directly."""

    n_frames: int = 1500


@dataclass(frozen=True)
class BlockSpec:
    """One layer's structure."""

    mixer: str  # "attn" | "attn_local" | "mla" | "rec" | "ssm"
    channel: str  # "mlp" | "moe" | "none"
    cross_attn: bool = False  # enc-dec decoder blocks


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttentionConfig] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[PrefixVisionStub] = None
    audio: Optional[AudioFrontendStub] = None
    pattern: Tuple[str, ...] = ("attn",)  # mixer pattern, tiled over layers
    moe_start_layer: int = 0  # deepseek-v3: first k layers use dense MLP
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    scale_embed: bool = False  # gemma family: embeddings scaled by sqrt(d)
    mtp: bool = False  # deepseek-v3 multi-token-prediction head
    max_seq_len: int = 32768 + 8
    param_dtype: str = "float32"
    # whether full attention makes 500k-decode infeasible (roofline skip rule)
    subquadratic: bool = False
    # int8 KV cache with per-(token, kv-head) scales (decode memory-term win)
    kv_quant: bool = False
    # explicit per-layer structure override (dry-run segment variants)
    blocks_override: Optional[Tuple["BlockSpec", ...]] = None

    def block_specs(self) -> Tuple[BlockSpec, ...]:
        if self.blocks_override is not None:
            return self.blocks_override
        out = []
        for li in range(self.n_layers):
            mixer = self.pattern[li % len(self.pattern)]
            if self.moe is not None and li >= self.moe_start_layer and mixer != "ssm":
                channel = "moe"
            elif mixer == "ssm":
                channel = "none"  # mamba blocks carry their own projections
            else:
                channel = "mlp"
            out.append(
                BlockSpec(
                    mixer=mixer,
                    channel=channel,
                    cross_attn=(self.family == "encdec"),
                )
            )
        return tuple(out)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def segment_layers(specs: Sequence[BlockSpec]) -> list[tuple[tuple, int]]:
    """Compress the layer list into (superblock, repeat) segments.

    Finds, greedily from the left, maximal segments of the form
    ``superblock * repeat`` where superblock is a short tuple of BlockSpecs
    (period <= 4).  The forward pass scans each segment (stacked params with
    leading dim = repeat), so HLO size is O(#segments * period), not O(L).
    """
    segs: list[tuple[tuple, int]] = []
    i, L = 0, len(specs)
    while i < L:
        best = (tuple(specs[i : i + 1]), 1)
        for p in range(1, 5):
            if i + p > L:
                break
            block = tuple(specs[i : i + p])
            r = 1
            while i + (r + 1) * p <= L and tuple(
                specs[i + r * p : i + (r + 1) * p]
            ) == block:
                r += 1
            if r * p > best[1] * len(best[0]):
                best = (block, r)
        segs.append(best)
        i += len(best[0]) * best[1]
    return segs
