"""Model substrate: configs, params, mixers, and the unified LM."""

from .config import ModelConfig  # noqa: F401
from .model import (  # noqa: F401
    forward_decode,
    forward_prefill,
    forward_train,
    init_cache,
    init_model,
    loss_fn,
    model_defs,
    param_count,
)
