"""Mamba-2 SSD (state-space duality) block.

Chunked SSD forward: within-chunk terms are attention-like einsums (dual
form), across-chunk state is passed through a *static python loop* over
chunks (so compiled FLOP counts stay honest for the roofline; the chunk count
is small: S / chunk).  Decode is the O(1) recurrence h <- a h + dt * B x with
a depthwise-conv state cache.

Layout: d_inner = expand * d_model, heads H = d_inner / head_dim (P),
state N per head; scalar A per head (Mamba-2's SSD restriction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import SSMConfig
from .params import PDef

__all__ = ["ssm_defs", "ssm_forward", "ssm_decode", "init_ssm_cache"]


def _dims(cfg: SSMConfig, d_model: int):
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    return d_in, H


def ssm_defs(cfg: SSMConfig, d_model: int) -> dict:
    d_in, H = _dims(cfg, d_model)
    N = cfg.d_state
    conv_dim = d_in + 2 * N  # conv over (x, B, C) as in mamba2
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": PDef(
            (d_model, 2 * d_in + 2 * N + H), ("embed", "ff")
        ),
        "conv_w": PDef((cfg.conv_width, conv_dim), ("conv", "ff"), scale=0.5),
        "conv_b": PDef((conv_dim,), ("ff",), "zeros"),
        "A_log": PDef((H,), ("heads",), "const:0.0"),
        "dt_bias": PDef((H,), ("heads",), "zeros"),
        "D": PDef((H,), ("heads",), "ones"),
        "norm_scale": PDef((d_in,), ("ff",), "zeros"),
        "w_out": PDef((d_in, d_model), ("ff", "embed")),
    }


def init_ssm_cache(cfg: SSMConfig, d_model: int, batch: int, dtype):
    d_in, H = _dims(cfg, d_model)
    N = cfg.d_state
    conv_dim = d_in + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, cfg.head_dim, N), jnp.float32),
    }


def _split(cfg: SSMConfig, d_model: int, zxbcdt):
    d_in, H = _dims(cfg, d_model)
    N = cfg.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N :]
    return z, xbc, dt


def _gated_norm(x, z, scale, eps=1e-6):
    x = x * jax.nn.silu(z)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def ssm_forward(cfg: SSMConfig, p, x, *, cache=None, initial_state=None):
    """Full-sequence SSD. x (B,S,d_model) -> (B,S,d_model).

    If ``cache`` is given, the final (conv, ssm) states are written to it
    (prefill for subsequent decode).
    """
    B, S, d_model = x.shape
    d_in, H = _dims(cfg, d_model)
    N, P = cfg.d_state, cfg.head_dim
    Q = min(cfg.chunk, S)
    assert S % Q == 0, f"SSD needs seq divisible by chunk ({S} % {Q})"

    zxbcdt = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt = _split(cfg, d_model, zxbcdt)

    # depthwise causal conv over (x, B, C)
    pad = cfg.conv_width - 1
    xbc_pad = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    if cache is not None:
        xbc_pad = xbc_pad.at[:, :pad].set(cache["conv"].astype(xbc.dtype))
    conv_w = p["conv_w"].astype(x.dtype)
    xbc_c = sum(
        xbc_pad[:, i : i + S] * conv_w[i][None, None, :]
        for i in range(cfg.conv_width)
    ) + p["conv_b"].astype(x.dtype)
    xbc_c = jax.nn.silu(xbc_c)

    xs = xbc_c[..., :d_in].reshape(B, S, H, P)
    Bm = xbc_c[..., d_in : d_in + N]  # (B,S,N) single group
    Cm = xbc_c[..., d_in + N :]  # (B,S,N)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    delta = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    # discretise: a_t = exp(delta * A); input scaled by delta
    log_a = delta * A[None, None, :]  # (B,S,H) negative
    xs_dt = xs * delta.astype(xs.dtype)[..., None]

    nC = S // Q
    state = (
        initial_state
        if initial_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )
    ys = []
    for ci in range(nC):  # static loop: honest FLOP counts
        sl = slice(ci * Q, (ci + 1) * Q)
        la = log_a[:, sl]  # (B,Q,H)
        cum = jnp.cumsum(la, axis=1)  # (B,Q,H) inclusive
        xq = xs_dt[:, sl]  # (B,Q,H,P)
        Bq = Bm[:, sl]  # (B,Q,N)
        Cq = Cm[:, sl]
        # intra-chunk (dual/attention-like) term
        # L[b,h,t,s] = exp(cum_t - cum_s) for s<=t
        Lm = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H) t,s
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        Lm = jnp.where(mask[None, :, :, None], jnp.exp(Lm), 0.0)
        cb = jnp.einsum("btn,bsn->bts", Cq, Bq,
                        preferred_element_type=jnp.float32)
        w = cb[..., None] * Lm  # (B,Q,Q,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w.astype(xq.dtype), xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "btn,bhpn,bth->bthp",
            Cq.astype(jnp.float32), state, jnp.exp(cum),
        ).astype(xq.dtype)
        ys.append(y_intra + y_inter)
        # update carried state
        seg = jnp.exp(cum[:, -1:, :] - cum)  # decay from s to chunk end
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bsn,bshp,bsh->bhpn",
            Bq.astype(jnp.float32), xq.astype(jnp.float32), seg,
        )
    y = jnp.concatenate(ys, axis=1)  # (B,S,H,P)
    y = y + xs * p["D"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = _gated_norm(y, z, p["norm_scale"])
    out = y @ p["w_out"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {
            "conv": xbc[:, S - (cfg.conv_width - 1):, :].astype(
                cache["conv"].dtype
            ),
            "ssm": state,
        }
    return out, new_cache


def ssm_decode(cfg: SSMConfig, p, x, cache):
    """Single-token recurrence. x (B,1,d_model)."""
    B, _, d_model = x.shape
    d_in, H = _dims(cfg, d_model)
    N, P = cfg.d_state, cfg.head_dim
    zxbcdt = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt = _split(cfg, d_model, zxbcdt)
    xbc = xbc[:, 0]  # (B, conv_dim)

    # conv cache: window of last conv_width-1 inputs
    conv_w = p["conv_w"].astype(x.dtype)
    hist = cache["conv"].astype(x.dtype)  # (B, w-1, conv_dim)
    full = jnp.concatenate([hist, xbc[:, None, :]], axis=1)  # (B,w,conv)
    xbc_c = jnp.einsum("bwc,wc->bc", full, conv_w) + p["conv_b"].astype(x.dtype)
    xbc_c = jax.nn.silu(xbc_c)
    new_conv = full[:, 1:, :].astype(cache["conv"].dtype)

    xs = xbc_c[..., :d_in].reshape(B, H, P)
    Bm = xbc_c[..., d_in : d_in + N]
    Cm = xbc_c[..., d_in + N :]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    delta = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(delta * A[None, :])  # (B,H)
    state = cache["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bm.astype(jnp.float32), xs.astype(jnp.float32),
        delta,
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state).astype(x.dtype)
    y = y + xs * p["D"].astype(xs.dtype)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = _gated_norm(y, z, p["norm_scale"])
    out = y @ p["w_out"].astype(x.dtype)
    return out, {"conv": new_conv, "ssm": state}
