"""Parameter definitions with logical sharding axes.

Every parameter is declared as a :class:`PDef` (shape + logical axis names +
init).  A single declaration drives both initialisation and the
PartitionSpec tree: logical axes ("embed", "heads", "ff", "vocab", "expert",
"kv_lora", ...) are mapped to mesh axes by a rules dict, so sharding strategy
changes (TP/FSDP/EP experiments in the perf loop) never touch model code.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PDef", "init_params", "partition_specs", "DEFAULT_RULES"]


@dataclass(frozen=True)
class PDef:
    shape: tuple
    axes: tuple  # logical axis per dim (str or None)
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None  # stddev; default fan-in

    def stacked(self, n: int) -> "PDef":
        return PDef((n,) + tuple(self.shape), ("layer",) + tuple(self.axes),
                    self.init, self.scale)


#: Default logical->mesh axis rules (pure tensor-parallel over "model").
DEFAULT_RULES = {
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "q_lora": None,
    "kv_lora": None,
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "expert_ff": None,
    "layer": None,
    "state": None,
    "conv": None,
    "lru": "model",
    "frames": None,
}


def _init_leaf(key, d: PDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        scale = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (scale * jax.random.normal(key, d.shape)).astype(dtype)
    if d.init.startswith("const:"):
        return jnp.full(d.shape, float(d.init.split(":")[1]), dtype)
    raise ValueError(d.init)


def _walk(defs, path=()):
    for k, v in defs.items():
        if isinstance(v, dict):
            yield from _walk(v, path + (k,))
        else:
            yield path + (k,), v


def init_params(defs: dict, key, dtype=jnp.float32) -> dict:
    """Initialise a (nested) dict of PDefs into a matching dict of arrays."""
    flat = list(_walk(defs))
    keys = jax.random.split(key, max(len(flat), 1))
    out: dict = {}
    for (path, d), k in zip(flat, keys):
        node = out
        for pkey in path[:-1]:
            node = node.setdefault(pkey, {})
        leaf_dtype = dtype
        if d.init in ("zeros", "ones") or d.init.startswith("const:"):
            leaf_dtype = jnp.float32 if path[-1].endswith("_f32") else dtype
        node[path[-1]] = _init_leaf(k, d, leaf_dtype)
    return out


def partition_specs(defs: dict, rules: dict = None) -> dict:
    """PartitionSpec tree matching ``defs`` under the logical-axis rules."""
    from jax.sharding import PartitionSpec as P

    rules = dict(DEFAULT_RULES, **(rules or {}))
    out: dict = {}
    for path, d in _walk(defs):
        node = out
        for pkey in path[:-1]:
            node = node.setdefault(pkey, {})
        node[path[-1]] = P(*[rules.get(a) for a in d.axes])
    return out


def abstract_params(defs: dict, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    out: dict = {}
    for path, d in _walk(defs):
        node = out
        for pkey in path[:-1]:
            node = node.setdefault(pkey, {})
        node[path[-1]] = jax.ShapeDtypeStruct(tuple(d.shape), dtype)
    return out
