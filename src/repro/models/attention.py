"""Attention: GQA with RoPE, sliding-window, softcap, prefix-LM; KV caches.

Implementation notes (roofline-driven):

* Prefill/train attention is *blockwise* over query blocks with a **static
  python loop** (unrolled in HLO).  Two reasons: (i) peak memory matches a
  flash-style kernel (no (S,S) score materialisation), and (ii) XLA's
  ``cost_analysis`` counts ``lax.scan`` bodies once, so static unrolling keeps
  the compiled FLOP counts honest (causal blocks also *skip* the strictly
  upper-triangular KV range via static slices -> S^2/2 FLOPs, like a real
  fused kernel).  Only the layer loop is ``lax.scan``-ed (corrected by the
  dry-run's L-extrapolation).
* Decode (Sq == 1) materialises (B, H, S) scores directly (memory-bound,
  matches the decode-attention Pallas kernel's traffic).
* Sliding-window ("local") layers keep a **ring buffer** cache of size
  ``window`` -- this is what makes gemma2/recurrentgemma 500k-decode feasible.

The TPU Pallas kernels in :mod:`repro.kernels` implement the same math; the
XLA path here is the portable oracle and the dry-run lowering target.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import AttentionConfig
from .layers import apply_rope, rope_table, softcap
from .params import PDef

__all__ = [
    "attn_defs",
    "blockwise_attention",
    "decode_attention",
    "attention_prefill",
    "attention_decode",
    "init_kv_cache",
]

_NEG = -2.0e9


def attn_defs(cfg: AttentionConfig, d_model: int) -> dict:
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_in = 1.0 / np.sqrt(d_model)   # fan-in of the (d -> heads) projections
    s_out = 1.0 / np.sqrt(H * D)    # fan-in of the output projection
    defs = {
        "wq": PDef((d_model, H, D), ("embed", "heads", None), scale=s_in),
        "wk": PDef((d_model, KV, D), ("embed", "kv_heads", None), scale=s_in),
        "wv": PDef((d_model, KV, D), ("embed", "kv_heads", None), scale=s_in),
        "wo": PDef((H, D, d_model), ("heads", None, "embed"), scale=s_out),
    }
    if cfg.qkv_bias:
        defs["bq"] = PDef((H, D), ("heads", None), "zeros")
        defs["bk"] = PDef((KV, D), ("kv_heads", None), "zeros")
        defs["bv"] = PDef((KV, D), ("kv_heads", None), "zeros")
    return defs


def _block_mask(q_pos, k_pos, *, causal, window, prefix_len, kv_len,
                slot_idx=None):
    """q_pos (Bq,), k_pos (Bk,) absolute positions -> (B?, Bq, Bk) bool.

    ``slot_idx``: cache slot indices of the keys (differs from k_pos for
    ring caches); ``kv_len`` masks by slot index.  Negative k_pos marks
    empty cache slots.
    """
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    if prefix_len is not None:
        # prefix-LM: bidirectional over the first prefix_len positions
        m = m | (k_pos[None, :] < prefix_len)[None].squeeze(0)
    m &= (k_pos >= 0)[None, :]  # empty ring slots
    if kv_len is not None:
        # kv_len (B,) -> (B, Bq, Bk)
        si = slot_idx if slot_idx is not None else k_pos
        return m[None] & (si[None, None, :] < kv_len[:, None, None])
    return m


def blockwise_attention(
    q, k, v, *,
    q_positions, k_positions,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len=None,
    kv_len=None,
    attn_softcap: Optional[float] = None,
    block_q: int = 512,
):
    """q (B,Sq,H,D); k,v (B,Skv,KV,D) -> (B,Sq,H,D).

    Static python loop over query blocks; causal/local blocks statically slice
    the KV range they can attend to.
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    block_q = min(block_q, Sq)
    n_blocks = (Sq + block_q - 1) // block_q
    outs = []
    kp = k_positions
    for bi in range(n_blocks):
        s0 = bi * block_q
        s1 = min(Sq, s0 + block_q)
        qb = q[:, s0:s1]
        qp = q_positions[..., s0:s1]
        # static KV range restriction
        lo, hi = 0, Skv
        if causal and Sq == Skv and prefix_len is None and kv_len is None:
            hi = s1
            if window is not None:
                lo = max(0, s0 - (window - 1))
        kb, vb = k[:, lo:hi], v[:, lo:hi]
        kpb = kp[lo:hi]
        # scores: (B, KV, G, Bq, Skv'); bf16 inputs, fp32 accumulation
        qg = qb.reshape(B, s1 - s0, KV, G, D)
        sc = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, kb,
            preferred_element_type=jnp.float32,
        ) * scale
        if attn_softcap is not None:
            sc = softcap(sc, attn_softcap)
        m = _block_mask(
            qp if qp.ndim == 1 else qp[0],
            kpb,
            causal=causal, window=window, prefix_len=prefix_len,
            kv_len=kv_len,
            slot_idx=jnp.arange(lo, hi) if kv_len is not None else None,
        )
        if m.ndim == 2:
            m = m[None, None, None]  # (1,1,1,Bq,Bk)
        else:
            m = m[:, None, None]  # (B,1,1,Bq,Bk)
        sc = jnp.where(m, sc, _NEG)
        p = jax.nn.softmax(sc, axis=-1)
        ob = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), vb)
        outs.append(ob.reshape(B, s1 - s0, H, D))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def decode_attention(q, k_cache, v_cache, *, kv_len, k_positions=None,
                     window=None, attn_softcap=None, q_positions=None):
    """Single-token decode: q (B,1,H,D) over cache (B,S,KV,D); kv_len (B,)."""
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    sc = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache,
        preferred_element_type=jnp.float32,
    ) * scale
    if attn_softcap is not None:
        sc = softcap(sc, attn_softcap)
    pos = jnp.arange(S)
    valid = pos[None, :] < kv_len[:, None]  # (B,S)
    if window is not None and k_positions is not None and q_positions is not None:
        # ring cache: entries store absolute positions
        valid &= q_positions[:, None] - k_positions < window
        valid &= k_positions <= q_positions[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, _NEG)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, D)


# ------------------------------------------------------------------ caches


def init_kv_cache(batch, max_len, n_kv, head_dim, dtype, ring_window=None,
                  quant=False):
    """KV cache; ring-buffered when ``ring_window`` is set (local layers).

    ``quant=True`` stores K/V in int8 with per-(token, kv-head) fp16 scales
    (~2x less decode HBM traffic than bf16; the scale overhead is
    2/head_dim).  Quantisation happens in the cache writers; readers
    dequantise on load.
    """
    S = min(max_len, ring_window) if ring_window else max_len
    cache = {
        "k": jnp.zeros((batch, S, n_kv, head_dim),
                       jnp.int8 if quant else dtype),
        "v": jnp.zeros((batch, S, n_kv, head_dim),
                       jnp.int8 if quant else dtype),
        # absolute position of each slot (ring caches need it for masking)
        "pos": jnp.full((batch, S), -1, jnp.int32),
    }
    if quant:
        cache["k_s"] = jnp.zeros((batch, S, n_kv), jnp.float16)
        cache["v_s"] = jnp.zeros((batch, S, n_kv), jnp.float16)
    return cache


def _quantize_kv(x):
    """x (..., D) -> (int8 values, scale over the last axis)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


def cache_write_prefill(cache, k, v, positions):
    """Write a full prefill chunk at positions (B,S) (assumed in range).

    For ring caches only the last `ring` tokens land (modulo write); the
    inputs are sliced first so duplicate ring slots are never scattered.
    """
    S_cache = cache["k"].shape[1]
    if k.shape[1] > S_cache:
        k = k[:, -S_cache:]
        v = v[:, -S_cache:]
        positions = positions[:, -S_cache:]
    idx = positions % S_cache
    b = jnp.arange(k.shape[0])[:, None]
    out = {"pos": cache["pos"].at[b, idx].set(positions)}
    if "k_s" in cache:
        qk, sk = _quantize_kv(k)
        qv, sv = _quantize_kv(v)
        out["k"] = cache["k"].at[b, idx].set(qk)
        out["v"] = cache["v"].at[b, idx].set(qv)
        out["k_s"] = cache["k_s"].at[b, idx].set(sk)
        out["v_s"] = cache["v_s"].at[b, idx].set(sv)
    else:
        out["k"] = cache["k"].at[b, idx].set(k)
        out["v"] = cache["v"].at[b, idx].set(v)
    return out


def cache_write_decode(cache, k, v, positions):
    """Write one token at positions (B,); k,v (B,1,KV,D)."""
    S_cache = cache["k"].shape[1]
    idx = (positions % S_cache)[:, None]
    b = jnp.arange(k.shape[0])[:, None]
    out = {"pos": cache["pos"].at[b, idx].set(positions[:, None])}
    if "k_s" in cache:
        qk, sk = _quantize_kv(k)
        qv, sv = _quantize_kv(v)
        out["k"] = cache["k"].at[b, idx].set(qk)
        out["v"] = cache["v"].at[b, idx].set(qv)
        out["k_s"] = cache["k_s"].at[b, idx].set(sk)
        out["v_s"] = cache["v_s"].at[b, idx].set(sv)
    else:
        out["k"] = cache["k"].at[b, idx].set(k)
        out["v"] = cache["v"].at[b, idx].set(v)
    return out


def cache_kv_arrays(cache, dtype):
    """Read (k, v) from a cache, dequantising if int8-quantised."""
    if "k_s" in cache:
        return (_dequantize_kv(cache["k"], cache["k_s"], dtype),
                _dequantize_kv(cache["v"], cache["v_s"], dtype))
    return cache["k"], cache["v"]


# ------------------------------------------------------------ full blocks


def _project_qkv(cfg: AttentionConfig, p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def attention_prefill(cfg: AttentionConfig, p, x, positions, *, local: bool,
                      cache=None, prefix_len=None, kernel_impl: str = "xla",
                      continuation: bool = False):
    """Full-sequence attention; optionally writes the cache.

    positions: (B, S) absolute positions.  With ``continuation=True`` the
    chunk is first merged into the cache and queries attend over the whole
    cached context (chunked-prefill semantics; assumes batch rows share the
    chunk layout, which holds for the engine's one-request chunks and the
    dry-run's uniform batches).  Returns (out, new_cache).
    """
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.rope:
        sin, cos = rope_table(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    window = cfg.window if local else None
    new_cache = None
    if cache is not None:
        new_cache = cache_write_prefill(cache, k, v, positions)
    if continuation:
        assert new_cache is not None, "continuation needs a cache"
        kk, vv = cache_kv_arrays(new_cache, v.dtype)
        S_cache = kk.shape[1]
        kv_len = jnp.minimum(positions[:, -1] + 1, S_cache)
        out = blockwise_attention(
            q, kk, vv,
            q_positions=positions[0] if positions.ndim > 1 else positions,
            k_positions=new_cache["pos"][0],
            causal=cfg.causal, window=window, prefix_len=prefix_len,
            kv_len=kv_len, attn_softcap=cfg.attn_softcap,
        )
    elif kernel_impl == "pallas":
        from repro.kernels.prefill_attention import ops as pf_ops

        out = pf_ops.prefill_attention(
            q, k, v, causal=cfg.causal, window=window,
            attn_softcap=cfg.attn_softcap, prefix_len=prefix_len,
        )
    else:
        out = blockwise_attention(
            q, k, v,
            q_positions=positions[0] if positions.ndim > 1 else positions,
            k_positions=positions[0] if positions.ndim > 1 else positions,
            causal=cfg.causal, window=window, prefix_len=prefix_len,
            attn_softcap=cfg.attn_softcap,
        )
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return proj, new_cache


def attention_decode(cfg: AttentionConfig, p, x, positions, cache, *,
                     local: bool):
    """One-token decode; positions (B,) = current index; updates cache."""
    q, k, v = _project_qkv(cfg, p, x)  # (B,1,·,D)
    if cfg.rope:
        sin, cos = rope_table(positions[:, None], cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    cache = cache_write_decode(cache, k, v, positions)
    S_cache = cache["k"].shape[1]
    kv_len = jnp.minimum(positions + 1, S_cache)
    kk, vv = cache_kv_arrays(cache, v.dtype)
    out = decode_attention(
        q, kk, vv, kv_len=kv_len,
        k_positions=cache["pos"], q_positions=positions,
        window=cfg.window if local else None,
        attn_softcap=cfg.attn_softcap,
    )
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return proj, cache
