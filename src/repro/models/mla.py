"""Multi-head Latent Attention (DeepSeek-V2/V3).

The KV cache stores only the compressed latent c_kv (rank r) plus the shared
RoPE key -- (r + d_rope) per token per layer instead of 2*KV*D.  Decode uses
the *absorbed* formulation: queries are projected into latent space
(q_nope @ W_uk) so scores are taken directly against the latent cache, and the
attention output stays in latent space until the per-head W_uv/W_o projection.
This is the memory-roofline win that makes deepseek-v3 decode cheap.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import MLAConfig
from .layers import apply_rope, rope_table
from .params import PDef

__all__ = ["mla_defs", "mla_prefill", "mla_decode", "init_mla_cache"]


def mla_defs(cfg: MLAConfig, d_model: int) -> dict:
    H = cfg.n_heads
    s_q = 1.0 / np.sqrt(cfg.q_lora_rank)
    s_kv = 1.0 / np.sqrt(cfg.kv_lora_rank)
    s_o = 1.0 / np.sqrt(H * cfg.v_head_dim)
    return {
        "w_dq": PDef((d_model, cfg.q_lora_rank), ("embed", "q_lora")),
        "w_uq": PDef(
            (cfg.q_lora_rank, H, cfg.qk_nope_dim + cfg.qk_rope_dim),
            ("q_lora", "heads", None), scale=s_q,
        ),
        "w_dkv": PDef((d_model, cfg.kv_lora_rank), ("embed", "kv_lora")),
        "w_kr": PDef((d_model, cfg.qk_rope_dim), ("embed", None)),
        "w_uk": PDef(
            (cfg.kv_lora_rank, H, cfg.qk_nope_dim), ("kv_lora", "heads", None),
            scale=s_kv,
        ),
        "w_uv": PDef(
            (cfg.kv_lora_rank, H, cfg.v_head_dim), ("kv_lora", "heads", None),
            scale=s_kv,
        ),
        "wo": PDef((H, cfg.v_head_dim, d_model), ("heads", None, "embed"),
                   scale=s_o),
    }


def init_mla_cache(cfg: MLAConfig, batch: int, max_len: int, dtype,
                   quant=False):
    """Latent cache; ``quant=True`` stores int8 latents + per-token scales
    (the latent is already compressed -- int8 halves it again)."""
    cache = {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank),
                          jnp.int8 if quant else dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim),
                            jnp.int8 if quant else dtype),
    }
    if quant:
        cache["c_s"] = jnp.zeros((batch, max_len), jnp.float16)
        cache["r_s"] = jnp.zeros((batch, max_len), jnp.float16)
    return cache


def _mla_q(x):
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _mla_write(cache, b, pos2d, c_kv, k_rope):
    if "c_s" in cache:
        qc, sc = _mla_q(c_kv)
        qr, sr = _mla_q(k_rope)
        return {
            "c_kv": cache["c_kv"].at[b, pos2d].set(qc),
            "k_rope": cache["k_rope"].at[b, pos2d].set(qr),
            "c_s": cache["c_s"].at[b, pos2d].set(sc),
            "r_s": cache["r_s"].at[b, pos2d].set(sr),
        }
    return {
        "c_kv": cache["c_kv"].at[b, pos2d].set(c_kv),
        "k_rope": cache["k_rope"].at[b, pos2d].set(k_rope),
    }


def _mla_read(cache, dtype):
    if "c_s" in cache:
        c = (cache["c_kv"].astype(jnp.float32)
             * cache["c_s"].astype(jnp.float32)[..., None]).astype(dtype)
        r = (cache["k_rope"].astype(jnp.float32)
             * cache["r_s"].astype(jnp.float32)[..., None]).astype(dtype)
        return c, r
    return cache["c_kv"], cache["k_rope"]


def _queries(cfg: MLAConfig, p, x, positions):
    q = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype))
    q = jnp.einsum("bsr,rhk->bshk", q, p["w_uq"].astype(x.dtype))
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = q[..., cfg.qk_nope_dim :]
    sin, cos = rope_table(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def mla_prefill(cfg: MLAConfig, p, x, positions, cache=None, block_q=512,
                continuation=False):
    """Full-sequence MLA (causal); writes latent cache.

    ``continuation=True``: chunked-prefill semantics -- the chunk's latents
    are merged into the cache first and queries attend over the cached
    context (absolute positions assumed uniform across batch rows).
    """
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _queries(cfg, p, x, positions)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(x.dtype))
    sin, cos = rope_table(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        b = jnp.arange(B)[:, None]
        pos2d = positions if positions.ndim > 1 else \
            positions[None, :].repeat(B, 0)
        new_cache = _mla_write(cache, b, pos2d, c_kv, k_rope)

    # absorbed scores: q_lat = q_nope @ W_uk  -> (B,S,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x.dtype))
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    if continuation:
        assert new_cache is not None, "continuation needs a cache"
        ckv_all, krope_all = _mla_read(new_cache, x.dtype)
        S_cache = ckv_all.shape[1]
        qpos_abs = positions[0] if positions.ndim > 1 else positions
        sc = (
            jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_all,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bqhk,bsk->bhqs", q_rope, krope_all,
                         preferred_element_type=jnp.float32)
        ) * scale
        kpos = jnp.arange(S_cache)
        sc = jnp.where(kpos[None, None, None, :]
                       <= qpos_abs[None, None, :, None], sc, -2.0e9)
        pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqs,bsr->bqhr", pr, ckv_all)
    else:
        outs = []
        block_q = min(block_q, S)
        n_blocks = (S + block_q - 1) // block_q
        for bi in range(n_blocks):
            s0, s1 = bi * block_q, min(S, (bi + 1) * block_q)
            hi = s1  # causal static restriction
            sc = (
                jnp.einsum("bqhr,bsr->bhqs", q_lat[:, s0:s1], c_kv[:, :hi],
                           preferred_element_type=jnp.float32)
                + jnp.einsum("bqhk,bsk->bhqs", q_rope[:, s0:s1],
                             k_rope[:, :hi],
                             preferred_element_type=jnp.float32)
            ) * scale
            qpos = jnp.arange(s0, s1)
            kpos = jnp.arange(hi)
            sc = jnp.where(
                kpos[None, None, None, :] <= qpos[None, None, :, None],
                sc, -2.0e9)
            pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
            ctx = jnp.einsum("bhqs,bsr->bqhr", pr, c_kv[:, :hi])
            outs.append(ctx)
        ctx = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    o = jnp.einsum("bqhr,rhv->bqhv", ctx, p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bqhv,hvd->bqd", o, p["wo"].astype(x.dtype))
    return out, new_cache


def mla_decode(cfg: MLAConfig, p, x, positions, cache):
    """One-token absorbed decode over the latent cache; positions (B,)."""
    B = x.shape[0]
    q_nope, q_rope = _queries(cfg, p, x, positions[:, None])
    c_new = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    k_new = jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(x.dtype))
    sin, cos = rope_table(positions[:, None], cfg.qk_rope_dim, cfg.rope_theta)
    k_new = apply_rope(k_new[:, :, None, :], sin, cos)[:, :, 0, :]
    b = jnp.arange(B)[:, None]
    cache = _mla_write(cache, b, positions[:, None], c_new, k_new)
    ckv_all, krope_all = _mla_read(cache, x.dtype)
    S = cache["c_kv"].shape[1]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x.dtype))[:, 0]
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    sc = (
        jnp.einsum("bhr,bsr->bhs", q_lat, ckv_all,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], krope_all,
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = jnp.arange(S)[None, :] <= positions[:, None]
    sc = jnp.where(valid[:, None, :], sc, -2.0e9)
    pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", pr, ckv_all)
    o = jnp.einsum("bhr,rhv->bhv", ctx, p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"].astype(x.dtype))[:, None, :]
    return out, cache
