"""Mixture-of-Experts with capacity-based dispatch (GShard/Switch style).

TPU-idiomatic dropless-ish MoE: token copies are sorted by expert id,
scattered into a dense (E, capacity, d) buffer (static shapes -> MXU-friendly
batched matmuls, expert dim shardable over the mesh "model"/"expert" axis =
expert parallelism), then combined back with top-k gate weights.  Tokens
beyond an expert's capacity are dropped (capacity_factor controls slack) --
the standard TPU trade against dynamic shapes.

DeepSeek-V3's sigmoid/grouped router is simplified to softmax top-k with
optional gate renormalisation (noted in DESIGN.md); shared experts are plain
always-on MLPs added to the routed output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import MoEConfig
from .params import PDef

__all__ = ["moe_defs", "apply_moe"]


def moe_defs(cfg: MoEConfig, d_model: int) -> dict:
    E, F = cfg.n_experts, cfg.d_ff_expert
    defs = {
        "router": PDef((d_model, E), ("embed", "expert"), scale=0.02),
        "w_gate": PDef((E, d_model, F), ("expert", "embed", "expert_ff")),
        "w_up": PDef((E, d_model, F), ("expert", "embed", "expert_ff")),
        "w_down": PDef((E, F, d_model), ("expert", "expert_ff", "embed")),
    }
    if cfg.n_shared:
        defs["shared"] = {
            "w_gate": PDef((d_model, F * cfg.n_shared), ("embed", "ff")),
            "w_up": PDef((d_model, F * cfg.n_shared), ("embed", "ff")),
            "w_down": PDef((F * cfg.n_shared, d_model), ("ff", "embed")),
        }
    return defs


def _capacity(cfg: MoEConfig, n_tokens: int) -> int:
    cap = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, ((cap + 7) // 8) * 8)


def apply_moe(cfg: MoEConfig, p: dict, x):
    """x (B,S,d) -> (B,S,d). Static-shape capacity dispatch."""
    B, S, d = x.shape
    T = B * S
    k = cfg.top_k
    E = cfg.n_experts
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_w, idx = jax.lax.top_k(probs, k)  # (T,k)
    if cfg.router_scale:
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # flatten token copies and sort by expert id
    eid = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(eid)  # stable
    eid_s = eid[order]
    tok_s = order // k
    # start offset of each expert in the sorted list (binary search, O(E logT))
    starts = jnp.searchsorted(eid_s, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - starts[eid_s]
    cap = _capacity(cfg, T)
    keep = pos < cap

    buf = jnp.zeros((E, cap, d), x.dtype)
    if cfg.dispatch_hint is not None:
        from jax.sharding import PartitionSpec as P

        e_ax, c_ax = cfg.dispatch_hint
        buf = jax.lax.with_sharding_constraint(buf, P(e_ax, c_ax, None))
    buf = buf.at[
        jnp.where(keep, eid_s, E),  # out-of-range rows dropped
        jnp.where(keep, pos, 0),
    ].set(xf[tok_s], mode="drop")

    # expert FFN (batched over experts; expert dim shardable -> EP)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))

    # combine: weight *inside* the expert shard and scatter-add straight to
    # the (T, d) token grid.  (Gathering the (T*k, d) per-copy tensor across
    # expert shards first -- the obvious formulation -- makes GSPMD
    # all-reduce ~T*k*d floats per layer; this form reduces only (T, d).)
    e_idx = jnp.where(keep, eid_s, E)
    c_idx = jnp.where(keep, pos, 0)
    gw_s = gate_w.reshape(-1)[order]
    tok2 = jnp.zeros((E, cap), jnp.int32).at[e_idx, c_idx].set(
        tok_s, mode="drop")
    gw2 = jnp.zeros((E, cap), jnp.float32).at[e_idx, c_idx].set(
        jnp.where(keep, gw_s, 0.0), mode="drop")
    out_w = out_buf * gw2[..., None].astype(out_buf.dtype)
    yt = jnp.zeros((T, d), x.dtype).at[tok2.reshape(-1)].add(
        out_w.reshape(E * cap, d))
    out = yt.reshape(B, S, d)

    if cfg.n_shared:
        sp = p["shared"]
        g = x @ sp["w_gate"].astype(x.dtype)
        u = x @ sp["w_up"].astype(x.dtype)
        out = out + (jax.nn.silu(g) * u) @ sp["w_down"].astype(x.dtype)
    return out
