"""Pallas TPU kernels for the mixed-iteration hot spots.

Each kernel ships as a triple:

* ``kernel.py`` -- pl.pallas_call with explicit BlockSpec VMEM tiling,
* ``ops.py``    -- jit'd public wrapper (padding, interpret fallback),
* ``ref.py``    -- pure-jnp oracle for the allclose sweeps in tests/.
"""
