"""Decode (single-token) attention kernel for TPU.

The decode iteration is the paper's memory-bound phase: per new token the
whole KV cache streams HBM -> VMEM once.  The kernel tiles the cache
sequence into (block_s, D) VMEM blocks on a (B, KV, n_s_blocks) grid with
the sequence axis innermost-sequential, carrying the online-softmax state
(m, l, acc) for all G group-query heads at once -- one cache stream serves
the whole GQA group, which is the arithmetic-intensity win of GQA decode.

Instead of CUDA-style paged KV (pointer chasing), the cache is a
*contiguous ring* and validity is a per-batch ``kv_len`` scalar plus an
optional absolute-position block (sliding-window archs): dense sequential
DMA, mask in VREGs -- the TPU-native translation of PagedAttention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

_NEG = -2.0e9


def _kernel(kvlen_ref, qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            scale, softcap, window, block_s, n_s_blocks):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[0]
    s0 = isb * block_s

    @pl.when(s0 < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (block_s, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, block_s)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        spos = s0 + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        valid = spos < kv_len
        if window is not None:
            qp = qpos_ref[0]
            kp = kpos_ref[0][None, :]              # absolute ring positions
            valid &= qp - kp < window
            valid &= kp <= qp
        s = jnp.where(valid, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(isb == n_s_blocks - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, kv_len, *, window=None,
                            k_positions=None, q_positions=None,
                            attn_softcap=None, block_s=256,
                            interpret=False):
    """q (B,1,H,D); caches (B,S,KV,D); kv_len (B,) -> (B,1,H,D)."""
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_s = min(block_s, S)
    assert S % block_s == 0
    ns = S // block_s
    scale = 1.0 / (D ** 0.5)

    qg = q.reshape(B, KV, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, KV, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                       (B, S))
    if q_positions is None:
        q_positions = jnp.maximum(kv_len - 1, 0).astype(jnp.int32)

    kernel = functools.partial(
        _kernel, scale=scale, softcap=attn_softcap, window=window,
        block_s=block_s, n_s_blocks=ns)

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),            # kv_len
            pl.BlockSpec((1,), lambda b, h, s: (b,)),            # q_pos
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, block_s), lambda b, h, s: (b, s)),  # k_pos
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q_positions.astype(jnp.int32),
      qg, kt, vt, k_positions.astype(jnp.int32))
    return out.reshape(B, 1, H, D)
