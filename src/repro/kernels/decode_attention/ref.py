"""Pure-jnp oracle for the decode-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["decode_attention_ref"]


def decode_attention_ref(q, k_cache, v_cache, kv_len, *, window=None,
                         k_positions=None, q_positions=None,
                         attn_softcap=None):
    """q (B,1,H,D) against cache (B,S,KV,D); kv_len (B,) -> (B,1,H,D)."""
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    sc = jnp.einsum("bkgd,bskd->bkgs", qg,
                    k_cache.astype(jnp.float32)) / np.sqrt(D)
    if attn_softcap is not None:
        sc = attn_softcap * jnp.tanh(sc / attn_softcap)
    valid = jnp.arange(S)[None, :] < kv_len[:, None]
    if window is not None and k_positions is not None:
        valid &= q_positions[:, None] - k_positions < window
        valid &= k_positions <= q_positions[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, -2.0e9)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)
