"""Jit'd public wrapper for the decode-attention kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas

__all__ = ["decode_attention"]


@partial(jax.jit, static_argnames=("window", "attn_softcap", "block_s",
                                   "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *, window=None,
                     k_positions=None, q_positions=None, attn_softcap=None,
                     block_s=256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S = k_cache.shape[1]
    bs = min(block_s, S)
    while S % bs != 0:
        bs //= 2
    return decode_attention_pallas(
        q, k_cache, v_cache, kv_len, window=window, k_positions=k_positions,
        q_positions=q_positions, attn_softcap=attn_softcap, block_s=bs,
        interpret=interpret)
