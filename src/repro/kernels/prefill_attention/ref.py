"""Pure-jnp oracle for the prefill flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["prefill_attention_ref"]


def prefill_attention_ref(q, k, v, *, causal=True, window=None,
                          attn_softcap=None, prefix_len=None):
    """Naive full-matrix attention.

    q: (B, S, H, D); k, v: (B, S, KV, D) -> (B, S, H, D).
    GQA via head repetition; fp32 softmax.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    kk.astype(jnp.float32)) / np.sqrt(D)
    if attn_softcap is not None:
        sc = attn_softcap * jnp.tanh(sc / attn_softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    if prefix_len is not None:
        mask |= kpos < prefix_len
    sc = jnp.where(mask[None, None], sc, -2.0e9)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(vv.dtype), vv)
