"""Jit'd public wrapper for the prefill flash-attention kernel.

Pads head_dim to the TPU lane width (128) and sequence to the block size,
dispatches to the Pallas kernel on TPU and to interpret mode elsewhere.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import prefill_attention_pallas

__all__ = ["prefill_attention"]


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@partial(jax.jit, static_argnames=("causal", "window", "attn_softcap",
                                   "prefix_len", "block_q", "block_k",
                                   "interpret"))
def prefill_attention(q, k, v, *, causal=True, window=None, attn_softcap=None,
                      prefix_len=None, block_q=128, block_k=128,
                      interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S = q.shape[1]
    block_q = min(block_q, max(8, S))
    block_k = min(block_k, max(8, S))
    q, S0 = _pad_to(q, 1, block_q)
    k, _ = _pad_to(k, 1, block_k)
    v, _ = _pad_to(v, 1, block_k)
    # lane padding for head_dim
    q, D0 = _pad_to(q, 3, 128) if not interpret else (q, q.shape[3])
    if not interpret:
        k, _ = _pad_to(k, 3, 128)
        v, _ = _pad_to(v, 3, 128)
    out = prefill_attention_pallas(
        q, k, v, causal=causal, window=window, attn_softcap=attn_softcap,
        prefix_len=prefix_len, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out[:, :S0, :, :D0]
