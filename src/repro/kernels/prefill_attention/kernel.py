"""Flash-attention prefill kernel for TPU (pl.pallas_call + BlockSpec).

Grid is (batch, heads, n_q_blocks, n_k_blocks) with the K dimension
innermost: on TPU the last grid axis is sequential per core, so the kernel
carries the online-softmax state (running max m, normalizer l, accumulator
acc) in VMEM scratch across K steps -- the standard flash recurrence
re-tiled for the MXU:

* q/k/v tiles are (block_q, D) / (block_k, D) VMEM blocks, D = head_dim
  padded to a lane multiple (128) by the wrapper;
* scores block (block_q, block_k) hits the MXU; masking (causal, sliding
  window, prefix-LM) is applied from statically computed index offsets;
* fully masked K blocks are *skipped* (pl.when) -- causal prefill does
  S^2/2 work like a real fused kernel.

GQA is expressed in the k/v index_map (kv head = q head // group), so no
KV duplication is materialised.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["prefill_attention_pallas"]

_NEG = -2.0e9


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, prefix_len,
            block_q, block_k, n_k_blocks):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = iq * block_q
    k0 = ik * block_k
    qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # block-level skip: fully-masked K blocks do no work
    run = jnp.bool_(True)
    if causal and prefix_len is None:
        run &= k0 <= q0 + block_q - 1
    if window is not None and prefix_len is None:
        run &= q0 - (k0 + block_k - 1) < window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= qpos - kpos < window
        if prefix_len is not None:
            mask |= kpos < prefix_len
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def prefill_attention_pallas(q, k, v, *, causal=True, window=None,
                             attn_softcap=None, prefix_len=None,
                             block_q=128, block_k=128, interpret=False):
    """q (B,S,H,D); k,v (B,S,KV,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (D ** 0.5)

    # layout: (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=attn_softcap, prefix_len=prefix_len,
        block_q=block_q, block_k=block_k, n_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max m
            pltpu.VMEM((block_q,), jnp.float32),   # normalizer l
            pltpu.VMEM((block_q, D), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
