"""Pure-jnp oracle for the SSD chunk-scan kernel: the naive O(S) recurrence.

h_t = a_t * h_{t-1} + B_t x_t^T (outer product, scaled by dt)
y_t = C_t . h_t + D x_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(x_dt, Bm, Cm, log_a, initial_state=None):
    """x_dt (B,S,H,P) already scaled by dt; Bm/Cm (B,S,N); log_a (B,S,H).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).  fp32 throughout.
    """
    Bsz, S, H, P = x_dt.shape
    N = Bm.shape[-1]
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def step(h, inp):
        x_t, b_t, c_t, la_t = inp  # (B,H,P), (B,N), (B,N), (B,H)
        a = jnp.exp(la_t)[:, :, None, None]
        h = h * a + jnp.einsum("bn,bhp->bhpn", b_t.astype(jnp.float32),
                               x_t.astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", c_t.astype(jnp.float32), h)
        return h, y

    xs = (x_dt.transpose(1, 0, 2, 3), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2), log_a.transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x_dt.dtype), hT
