"""Mamba-2 SSD chunk-scan kernel for TPU.

State-space duality re-tiled for the MXU: the sequence is cut into chunks
of Q tokens; within a chunk the output is an attention-like (Q,Q) masked
matmul (dual form, MXU-friendly); across chunks a (P,N) state per head is
carried in VMEM scratch along the innermost-sequential grid axis -- the
recurrent part touches VMEM only, which is the TPU translation of Mamba's
SRAM-resident scan.

Grid: (batch, heads, n_chunks).  Blocks: x (Q,P), B/C (Q,N), log_a (Q,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas"]


def _kernel(x_ref, b_ref, c_ref, la_ref, y_ref, hlast_ref, state_ref, *,
            chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    Bm = b_ref[0].astype(jnp.float32)        # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)        # (Q, N)
    la = la_ref[0, 0].astype(jnp.float32)    # (Q,)

    cum = jnp.cumsum(la)                     # inclusive cumsum
    # intra-chunk dual form: L[t,s] = exp(cum_t - cum_s) for s <= t
    Lm = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    Lm = jnp.where(tri, jnp.exp(Lm), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    w = cb * Lm
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)
    # inter-chunk: y += diag(exp(cum)) C h_prev
    h = state_ref[...]                       # (P, N)
    ch = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,P)
    y = y + ch * jnp.exp(cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h <- exp(cum_Q) h + sum_s exp(cum_Q - cum_s) x_s B_s^T
    seg = jnp.exp(cum[-1] - cum)             # (Q,)
    xw = x * seg[:, None]                    # (Q, P)
    hupd = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (P,N)
    state_ref[...] = h * jnp.exp(cum[-1]) + hupd

    @pl.when(ic == n_chunks - 1)
    def _fin():
        hlast_ref[0, 0] = state_ref[...]


def ssd_scan_pallas(x_dt, Bm, Cm, log_a, *, chunk=256, interpret=False):
    """x_dt (B,S,H,P); Bm/Cm (B,S,N); log_a (B,S,H) ->
    (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x_dt.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    xt = x_dt.transpose(0, 2, 1, 3)   # (B,H,S,P)
    lat = log_a.transpose(0, 2, 1)    # (B,H,S)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, hlast = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, S, P), x_dt.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, Bm, Cm, lat)
    return y.transpose(0, 2, 1, 3), hlast
