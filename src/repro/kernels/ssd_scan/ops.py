"""Jit'd public wrapper for the SSD chunk-scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_scan_pallas

__all__ = ["ssd_scan"]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x_dt, Bm, Cm, log_a, *, chunk=256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S = x_dt.shape[1]
    c = min(chunk, S)
    while S % c != 0:
        c //= 2
    return ssd_scan_pallas(x_dt, Bm, Cm, log_a, chunk=c, interpret=interpret)
