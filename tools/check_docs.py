#!/usr/bin/env python3
"""Docs health check: every internal markdown link must resolve.

Scans the repo's markdown docs for inline links/images and verifies that
relative targets exist on disk (external http(s)/mailto links are
skipped; pure #fragment links are checked against the current file's
headings). Exits nonzero with a listing of broken links. Run from the
repo root; CI runs this next to the tier-1 suite.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = ("README.md", "docs/ARCHITECTURE.md", "benchmarks/README.md",
        "ROADMAP.md", "CHANGES.md")

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def heading_anchors(md: str) -> set:
    anchors = set()
    in_fence = False
    for line in md.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:  # '# comment' inside a code block is not a heading
            continue
        if line.startswith("#"):
            text = line.lstrip("#").strip().lower()
            text = re.sub(r"[^\w\- ]", "", text).replace(" ", "-")
            anchors.add(text)
    return anchors


def check(root: Path) -> list:
    errors = []
    for rel in DOCS:
        doc = root / rel
        if not doc.exists():
            continue
        md = doc.read_text()
        anchors = heading_anchors(md)
        for m in LINK_RE.finditer(md):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, frag = target.partition("#")
            if not path:  # same-file fragment
                if frag and frag.lower() not in anchors:
                    errors.append(f"{rel}: broken anchor #{frag}")
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{rel}: broken link {target}")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parents[1]
    errors = check(root)
    for e in errors:
        print(f"[check_docs] {e}", file=sys.stderr)
    if errors:
        return 1
    print(f"[check_docs] OK ({len(DOCS)} docs scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
