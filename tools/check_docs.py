#!/usr/bin/env python3
"""Docs health check: links, evaluator names and benchmark modules.

Scans the repo's markdown docs for inline links/images and verifies that
relative targets exist on disk (external http(s)/mailto links are
skipped; pure #fragment links are checked against the current file's
headings).  Additionally cross-checks every sweep-evaluator name the
docs mention -- ``--evaluator <name>`` CLI examples, ``"evaluator":
"<name>"`` JSON snippets, and ``\\`name\\` evaluator`` / ``evaluator
\\`name\\``` prose -- against the registry (``EVALUATORS`` in
``repro.sweep.spec``, the names dispatched to
``repro.sweep.evaluators``), and every ``bench_*`` module name any
scanned doc mentions against the ``benchmarks/run.py`` suite registry.
Both cross-checks run in BOTH directions: doc-mentioned names must be
registered, and registered evaluators / benchmark modules must be
documented somewhere -- so documented names and registries cannot
silently drift apart in either direction.  Exits nonzero
with a listing of problems. Run from the repo root; CI runs this next
to the tier-1 suite.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = ("README.md", "docs/ARCHITECTURE.md", "docs/SIMULATORS.md",
        "docs/WORKLOADS.md", "docs/PLANNING.md", "docs/CALIBRATION.md",
        "docs/SHARDING.md", "docs/OBSERVABILITY.md",
        "docs/HETEROGENEITY.md", "benchmarks/README.md", "ROADMAP.md",
        "CHANGES.md")

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")

# how docs name sweep evaluators (CLI flag, JSON schema, backticked prose)
EVALUATOR_RES = (
    re.compile(r"--evaluator[ =]+([a-z_][a-z_,]*)"),
    re.compile(r"\"evaluator\":\s*\"([a-z_]+)\""),
    re.compile(r"`([a-z_]+)` evaluator"),
    re.compile(r"evaluators? `([a-z_]+)`"),
)


def heading_anchors(md: str) -> set:
    anchors = set()
    in_fence = False
    for line in md.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:  # '# comment' inside a code block is not a heading
            continue
        if line.startswith("#"):
            text = line.lstrip("#").strip().lower()
            text = re.sub(r"[^\w\- ]", "", text).replace(" ", "-")
            anchors.add(text)
    return anchors


def known_evaluators(root: Path):
    """The evaluator registry, or an error string if it cannot load."""
    sys.path.insert(0, str(root / "src"))
    try:
        from repro.sweep.spec import EVALUATORS
        return set(EVALUATORS), None
    except Exception as exc:  # missing dep / broken import = check error
        return None, f"cannot import repro.sweep.spec ({exc})"


def mentioned_evaluators(md: str):
    names = set()
    for rx in EVALUATOR_RES:
        for m in rx.finditer(md):
            names.update(p for p in m.group(1).split(",") if p)
    return names


# how docs name batch placements (CLI flag, call kwarg, extra-dict JSON,
# backticked prose) -- same idea as the evaluator patterns
PLACEMENT_RES = (
    re.compile(r"--placement[ =]+([a-z_][a-z_,]*)"),
    re.compile(r"placement=\"([a-z_]+)\""),
    re.compile(r"\"placement\":\s*\"([a-z_]+)\""),
    re.compile(r"`([a-z_]+)` placement"),
    re.compile(r"placements? `([a-z_]+)`"),
)


def known_placements(root: Path):
    """The placement catalog, or an error string if it cannot load."""
    sys.path.insert(0, str(root / "src"))
    try:
        from repro.sweep.sharded import PLACEMENTS
        return set(PLACEMENTS), None
    except Exception as exc:  # missing dep / broken import = check error
        return None, f"cannot import repro.sweep.sharded ({exc})"


def mentioned_placements(md: str):
    names = set()
    for rx in PLACEMENT_RES:
        for m in rx.finditer(md):
            names.update(p for p in m.group(1).split(",") if p)
    return names


def check_placement_catalog(root: Path, registry) -> list:
    """Reverse direction of the placement check: every registered
    placement must be documented in docs/SHARDING.md's catalog."""
    doc = root / "docs" / "SHARDING.md"
    if registry is None:
        return []
    if not doc.exists():
        return ["docs/SHARDING.md: missing (the placement catalog must "
                "be documented there)"]
    ticked = set(re.findall(r"`([a-z0-9_]+)`", doc.read_text()))
    return [
        f"docs/SHARDING.md: registered placement {name!r} is not "
        f"documented in the catalog"
        for name in sorted(registry - ticked)
    ]


# how docs name workload scenarios (CLI flags, MixSpec JSON, backticked
# prose, registry lookups) -- same idea as the evaluator patterns
SCENARIO_RES = (
    re.compile(r"--scenarios?[ =]+([a-z0-9_][a-z0-9_,]*)"),
    re.compile(r"\"scenario\":\s*\"([a-z0-9_]+)\""),
    re.compile(r"`([a-z0-9_]+)` scenario"),
    re.compile(r"scenarios? `([a-z0-9_]+)`"),
    re.compile(r"get_scenario\(\"([a-z0-9_]+)\"\)"),
)


def known_scenarios(root: Path):
    """The workload-scenario registry, or an error string."""
    sys.path.insert(0, str(root / "src"))
    try:
        from repro.workloads import list_scenarios
        return set(list_scenarios()), None
    except Exception as exc:  # missing dep / broken import = check error
        return None, f"cannot import repro.workloads ({exc})"


def mentioned_scenarios(md: str):
    names = set()
    for rx in SCENARIO_RES:
        for m in rx.finditer(md):
            names.update(p for p in m.group(1).split(",") if p)
    return names


def check_scenario_catalog(root: Path, registry) -> list:
    """docs/WORKLOADS.md's catalog must cover every registered scenario
    (the reverse of the mention check: registry entries cannot go
    undocumented)."""
    doc = root / "docs" / "WORKLOADS.md"
    if registry is None or not doc.exists():
        return []
    ticked = set(re.findall(r"`([a-z0-9_]+)`", doc.read_text()))
    return [
        f"docs/WORKLOADS.md: registered scenario {name!r} is not "
        f"documented in the catalog"
        for name in sorted(registry - ticked)
    ]


# how docs name iteration-time models (registry lookups, backticked
# prose) -- same idea as the evaluator/scenario patterns
MODEL_RES = (
    re.compile(r"model_from_artifact\([^,)]+,\s*\"([a-z_]+)\""),
    re.compile(r"`([a-z_]+)` (?:iteration-time )?model\b"),
    re.compile(r"iteration-time models? `([a-z_]+)`"),
)


def known_models(root: Path):
    """The iteration-time-model registry, or an error string."""
    sys.path.insert(0, str(root / "src"))
    try:
        from repro.calibration import list_models
        return set(list_models()), None
    except Exception as exc:  # missing dep / broken import = check error
        return None, f"cannot import repro.calibration ({exc})"


def mentioned_models(md: str):
    names = set()
    for rx in MODEL_RES:
        for m in rx.finditer(md):
            names.update(p for p in m.group(1).split(",") if p)
    return names


def check_model_catalog(root: Path, registry) -> list:
    """docs/CALIBRATION.md's registry table must cover every registered
    iteration-time model (reverse of the mention check)."""
    doc = root / "docs" / "CALIBRATION.md"
    if registry is None:
        return []
    if not doc.exists():
        return ["docs/CALIBRATION.md: missing (the iteration-time-model "
                "registry must be documented there)"]
    ticked = set(re.findall(r"`([a-z0-9_]+)`", doc.read_text()))
    return [
        f"docs/CALIBRATION.md: registered iteration-time model {name!r} "
        f"is not documented in the catalog"
        for name in sorted(registry - ticked)
    ]


# how docs name telemetry probes (backticked prose plus tlm_ carry
# keys) -- same idea as the evaluator/scenario patterns
PROBE_RES = (
    re.compile(r"`([a-z0-9_]+)` probe\b"),
    re.compile(r"probes? `([a-z0-9_]+)`"),
)
PROBE_KEY_RE = re.compile(r"`(tlm_[a-z0-9_]+)`")


def known_probes(root: Path):
    """The telemetry probe registry (name -> carry key), or an error."""
    sys.path.insert(0, str(root / "src"))
    try:
        from repro.telemetry.probes import DERIVED_METRICS, PROBES
        return ({name: d.key for name, d in PROBES.items()},
                set(DERIVED_METRICS), None)
    except Exception as exc:  # missing dep / broken import = check error
        return None, None, f"cannot import repro.telemetry.probes ({exc})"


def mentioned_probes(md: str):
    names = set()
    for rx in PROBE_RES:
        for m in rx.finditer(md):
            names.update(p for p in m.group(1).split(",") if p)
    return names


def check_probe_catalog(root: Path, registry, derived) -> list:
    """Both directions against the telemetry registry: every probe /
    ``tlm_`` carry key a doc mentions must be registered (carry key or
    derived cell metric), and every registered probe (name AND carry
    key) must appear in docs/OBSERVABILITY.md's catalog."""
    if registry is None:
        return []
    errors = []
    keys = set(registry.values()) | set(derived or ())
    for rel in DOCS:
        doc = root / rel
        if not doc.exists():
            continue
        md = doc.read_text()
        for name in sorted(mentioned_probes(md) - set(registry)):
            errors.append(
                f"{rel}: probe {name!r} not in the "
                f"repro.telemetry.probes registry {sorted(registry)}")
        for key in sorted(set(PROBE_KEY_RE.findall(md)) - keys):
            errors.append(
                f"{rel}: probe carry key {key!r} not in the "
                f"repro.telemetry.probes registry {sorted(keys)}")
    obs = root / "docs" / "OBSERVABILITY.md"
    if not obs.exists():
        return ["docs/OBSERVABILITY.md: missing (the probe catalog must "
                "be documented there)"]
    ticked = set(re.findall(r"`([a-z0-9_]+)`", obs.read_text()))
    for name, key in sorted(registry.items()):
        if name not in ticked:
            errors.append(
                f"docs/OBSERVABILITY.md: registered probe {name!r} is "
                f"not documented in the catalog")
        if key not in ticked:
            errors.append(
                f"docs/OBSERVABILITY.md: carry key {key!r} (probe "
                f"{name!r}) is not documented in the catalog")
    return errors


# how docs name GPU server classes (registry lookups, FleetSpec specs,
# backticked prose) -- same idea as the evaluator/scenario patterns
SERVER_CLASS_RES = (
    re.compile(r"`([a-z0-9-]+)` server class"),
    re.compile(r"server class(?:es)? `([a-z0-9-]+)`"),
    re.compile(r"get_server_class\(\"([a-z0-9-]+)\"\)"),
    re.compile(r"FleetSpec\.of\(\[\(\"([a-z0-9-]+)\""),
)


def known_server_classes(root: Path):
    """The GPU server-class registry, or an error string."""
    sys.path.insert(0, str(root / "src"))
    try:
        from repro.core.hetero import list_server_classes
        return set(list_server_classes()), None
    except Exception as exc:  # missing dep / broken import = check error
        return None, f"cannot import repro.core.hetero ({exc})"


def mentioned_server_classes(md: str):
    names = set()
    for rx in SERVER_CLASS_RES:
        for m in rx.finditer(md):
            names.update(p for p in m.group(1).split(",") if p)
    return names


def check_server_class_catalog(root: Path, registry) -> list:
    """Reverse direction of the server-class check: every registered
    class must be documented in docs/HETEROGENEITY.md's catalog."""
    if registry is None:
        return []
    doc = root / "docs" / "HETEROGENEITY.md"
    if not doc.exists():
        return ["docs/HETEROGENEITY.md: missing (the server-class "
                "catalog must be documented there)"]
    ticked = set(re.findall(r"`([a-z0-9-]+)`", doc.read_text()))
    return [
        f"docs/HETEROGENEITY.md: registered server class {name!r} is "
        f"not documented in the catalog"
        for name in sorted(registry - ticked)
    ]


# how docs name capacity-event kinds (CapacityEvent snippets, engine
# event tuples, backticked prose)
EVENT_KIND_RES = (
    re.compile(r"`([a-z_]+)` capacity[ -]events?\b"),
    re.compile(r"capacity[ -]events? `([a-z_]+)`"),
    re.compile(r"`([a-z_]+)` event kind"),
    re.compile(r"event kinds? `([a-z_]+)`"),
    re.compile(r"CapacityEvent\([^,)]+,\s*\"([a-z_]+)\""),
)


def known_event_kinds(root: Path):
    """The capacity-event-kind registry, or an error string."""
    sys.path.insert(0, str(root / "src"))
    try:
        from repro.workloads import EVENT_KINDS
        return set(EVENT_KINDS), None
    except Exception as exc:  # missing dep / broken import = check error
        return None, f"cannot import repro.workloads ({exc})"


def mentioned_event_kinds(md: str):
    names = set()
    for rx in EVENT_KIND_RES:
        for m in rx.finditer(md):
            names.update(p for p in m.group(1).split(",") if p)
    return names


def check_event_kind_catalog(root: Path, registry) -> list:
    """Reverse direction of the event-kind check: every registered
    capacity-event kind must be documented in docs/WORKLOADS.md (where
    the capacity-event scripts live)."""
    if registry is None:
        return []
    doc = root / "docs" / "WORKLOADS.md"
    if not doc.exists():
        return []
    ticked = set(re.findall(r"`([a-z_]+)`", doc.read_text()))
    return [
        f"docs/WORKLOADS.md: registered capacity-event kind {name!r} is "
        f"not documented in the catalog"
        for name in sorted(registry - ticked)
    ]


# how docs name serving-engine modules (module paths only -- a bare
# ``engine_speed`` is a benchmark artifact stem, not an engine)
ENGINE_MODULE_RES = (
    re.compile(r"repro\.serving\.(engine_[a-z0-9_]+)"),
    re.compile(r"serving/(engine_[a-z0-9_]+)\.py"),
)


def engine_mode_kwargs(root: Path):
    """Keyword-only args of the engine constructors, parsed from source
    (no jax import): the mode switches the docs must cover."""
    import ast

    names = {}
    for mod, cls in (("engine_jax", "ClusterEngineJAX"),
                     ("engine_stream", "StreamingEngineJAX")):
        path = root / "src" / "repro" / "serving" / f"{mod}.py"
        if not path.exists():
            continue
        tree = ast.parse(path.read_text())
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == cls:
                for fn in node.body:
                    if (isinstance(fn, ast.FunctionDef)
                            and fn.name == "__init__"):
                        for a in fn.args.kwonlyargs:
                            names.setdefault(a.arg, f"{cls}.__init__")
    return names


def check_engine_catalog(root: Path) -> list:
    """Both directions for the simulator guide: every engine module a
    doc names must exist on disk, every ``engine_*`` module on disk must
    be documented in docs/SIMULATORS.md, and every engine-constructor
    mode switch (keyword-only arg) must be mentioned there too -- so the
    guide's engine/mode tables cannot drift from the code."""
    errors = []
    disk = {p.stem
            for p in (root / "src" / "repro" / "serving").glob("engine_*.py")}
    sim = root / "docs" / "SIMULATORS.md"
    sim_md = sim.read_text() if sim.exists() else ""
    for rel in DOCS:
        doc = root / rel
        if not doc.exists():
            continue
        md = doc.read_text()
        mentioned = {m.group(1) for rx in ENGINE_MODULE_RES
                     for m in rx.finditer(md)}
        for name in sorted(mentioned - disk):
            errors.append(
                f"{rel}: engine module {name!r} has no "
                f"src/repro/serving/{name}.py on disk")
    sim_mentioned = {m.group(1) for rx in ENGINE_MODULE_RES
                     for m in rx.finditer(sim_md)}
    for name in sorted(disk - sim_mentioned):
        errors.append(
            f"docs/SIMULATORS.md: engine module {name!r} "
            f"(src/repro/serving/{name}.py) is not documented")
    for kwarg, owner in sorted(engine_mode_kwargs(root).items()):
        if not re.search(rf"`{kwarg}[`=]", sim_md):
            errors.append(
                f"docs/SIMULATORS.md: engine mode switch {kwarg!r} "
                f"({owner}) is not documented")
    return errors


BENCH_RE = re.compile(r"\b(bench_\w+)\b")


def known_benchmarks(root: Path):
    """Benchmark modules the suite registry knows: parsed from the
    ``benchmarks/run.py`` source (the imports + SUITE table), so the
    check works without importing jax-heavy modules."""
    run_py = root / "benchmarks" / "run.py"
    if not run_py.exists():
        return None, "benchmarks/run.py not found"
    names = set(BENCH_RE.findall(run_py.read_text()))
    return names, None


def check_benchmarks(root: Path) -> list:
    """Both directions, across every scanned doc: any bench_* a doc
    mentions must be in the run.py registry and exist on disk, and every
    registry module must be documented in benchmarks/README.md."""
    errors = []
    registry, err = known_benchmarks(root)
    if err:
        return [f"benchmark registry: {err}"]
    for rel in DOCS:
        doc = root / rel
        if not doc.exists():
            continue
        mentioned = set(BENCH_RE.findall(doc.read_text()))
        for name in sorted(mentioned - registry):
            errors.append(
                f"{rel}: benchmark module {name!r} not in the "
                f"benchmarks/run.py registry")
        for name in sorted(mentioned):
            if not (root / "benchmarks" / f"{name}.py").exists():
                errors.append(
                    f"{rel}: benchmark module {name!r} has no "
                    f"benchmarks/{name}.py on disk")
    readme = root / "benchmarks" / "README.md"
    if readme.exists():
        documented = set(BENCH_RE.findall(readme.read_text()))
        for name in sorted(registry - documented):
            errors.append(
                f"benchmarks/run.py: registered benchmark {name!r} is not "
                f"documented in benchmarks/README.md")
    return errors


def check_evaluator_catalog(root: Path, registry) -> list:
    """Reverse direction of the evaluator check: every registered sweep
    evaluator must be documented (a backticked mention in some scanned
    doc) -- mirrors the scenario-catalog check, so adding an evaluator
    without documenting it fails CI exactly like a stale doc name does."""
    if registry is None:
        return []
    texts = [(root / rel).read_text() for rel in DOCS
             if (root / rel).exists()]
    return [
        f"evaluator registry: {name!r} is in repro.sweep.spec.EVALUATORS "
        f"but documented in none of {', '.join(DOCS)}"
        for name in sorted(registry)
        if not any(f"`{name}`" in t for t in texts)
    ]


def check(root: Path) -> list:
    errors = []
    registry, reg_err = known_evaluators(root)
    if reg_err:
        errors.append(f"evaluator registry: {reg_err}")
    placements, plc_err = known_placements(root)
    if plc_err:
        errors.append(f"placement catalog: {plc_err}")
    scenarios, scn_err = known_scenarios(root)
    if scn_err:
        errors.append(f"scenario registry: {scn_err}")
    models, mdl_err = known_models(root)
    if mdl_err:
        errors.append(f"iteration-time-model registry: {mdl_err}")
    server_classes, svc_err = known_server_classes(root)
    if svc_err:
        errors.append(f"server-class registry: {svc_err}")
    event_kinds, evk_err = known_event_kinds(root)
    if evk_err:
        errors.append(f"capacity-event-kind registry: {evk_err}")
    for rel in DOCS:
        doc = root / rel
        if not doc.exists():
            continue
        md = doc.read_text()
        anchors = heading_anchors(md)
        for m in LINK_RE.finditer(md):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, frag = target.partition("#")
            if not path:  # same-file fragment
                if frag and frag.lower() not in anchors:
                    errors.append(f"{rel}: broken anchor #{frag}")
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{rel}: broken link {target}")
        if registry is not None:
            for name in sorted(mentioned_evaluators(md) - registry):
                errors.append(
                    f"{rel}: evaluator {name!r} not in repro.sweep "
                    f"registry {sorted(registry)}")
        if placements is not None:
            for name in sorted(mentioned_placements(md) - placements):
                errors.append(
                    f"{rel}: placement {name!r} not in the "
                    f"repro.sweep.sharded catalog {sorted(placements)}")
        if scenarios is not None:
            for name in sorted(mentioned_scenarios(md) - scenarios):
                errors.append(
                    f"{rel}: scenario {name!r} not in the repro.workloads "
                    f"registry {sorted(scenarios)}")
        if models is not None:
            for name in sorted(mentioned_models(md) - models):
                errors.append(
                    f"{rel}: iteration-time model {name!r} not in the "
                    f"repro.calibration registry {sorted(models)}")
        if server_classes is not None:
            for name in sorted(mentioned_server_classes(md)
                               - server_classes):
                errors.append(
                    f"{rel}: server class {name!r} not in the "
                    f"repro.core.hetero registry {sorted(server_classes)}")
        if event_kinds is not None:
            for name in sorted(mentioned_event_kinds(md) - event_kinds):
                errors.append(
                    f"{rel}: capacity-event kind {name!r} not in "
                    f"repro.workloads.EVENT_KINDS {sorted(event_kinds)}")
    probes, derived, prb_err = known_probes(root)
    if prb_err:
        errors.append(f"probe registry: {prb_err}")
    errors.extend(check_placement_catalog(root, placements))
    errors.extend(check_scenario_catalog(root, scenarios))
    errors.extend(check_server_class_catalog(root, server_classes))
    errors.extend(check_event_kind_catalog(root, event_kinds))
    errors.extend(check_model_catalog(root, models))
    errors.extend(check_evaluator_catalog(root, registry))
    errors.extend(check_probe_catalog(root, probes, derived))
    errors.extend(check_benchmarks(root))
    errors.extend(check_engine_catalog(root))
    return errors


def main() -> int:
    root = Path(__file__).resolve().parents[1]
    errors = check(root)
    for e in errors:
        print(f"[check_docs] {e}", file=sys.stderr)
    if errors:
        return 1
    print(f"[check_docs] OK ({len(DOCS)} docs scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
