#!/usr/bin/env python3
"""Benchmark-artifact gate: committed ``artifacts/bench/*.json`` must
match the benchmark registry.

Checks, per artifact (committed = tracked by git; falls back to the
on-disk set outside a work tree):

1. the artifact stem is a *registered* artifact name (the ``ARTIFACTS``
   table below), and its producing ``bench_*`` module is in the
   ``benchmarks/run.py`` suite registry and exists on disk -- so an
   artifact cannot outlive or predate its benchmark (drift fails);
2. artifacts marked ``committed`` exist (the repo promises them);
3. the JSON parses to an object carrying every ``required`` key;
4. wherever a ``budget_exhausted`` key appears (any nesting level), its
   value is 0 -- a committed artifact produced by a truncated
   fixed-budget simulation is a lie about the simulated horizon;
5. the artifact embeds a valid ``manifest`` RunRecord
   (:mod:`repro.telemetry.manifest`) whose recorded payload digest
   matches the payload -- provenance, not decoration: a regenerated
   table without a manifest (or with a stale digest) fails.

Run from the repo root; CI runs this in the ``bench-smoke`` job right
after regenerating the smoke-size artifacts.  No third-party imports
(``repro.telemetry.manifest`` is stdlib-only and imported from src/).
"""

from __future__ import annotations

import json
import re
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.telemetry.manifest import (payload_digest,  # noqa: E402
                                      validate_record)

# artifact stem -> producing bench module + keys the suite relies on.
# ``committed`` artifacts are tracked in git and must exist.
ARTIFACTS = {
    "ablations": dict(bench="bench_ablations",
                      required=["rows", "ggsp_best"]),
    "calibration": dict(bench="bench_calibration", committed=True,
                        required=["artifact", "mixed_fit", "solo_fit",
                                  "min_r2",
                                  "fitted_vs_seed_revenue_delta_pct",
                                  "budget_exhausted"]),
    "charging": dict(bench="bench_charging", required=[]),
    "classes": dict(bench="bench_classes", required=[]),
    "convergence": dict(bench="bench_convergence", required=["rows"]),
    "convergence_ctmc_jax": dict(bench="bench_convergence",
                                 required=["rows"]),
    "ctmc_speed": dict(bench="bench_ctmc_speed", required=["speedup"]),
    "engine_speed": dict(bench="bench_engine_speed", committed=True,
                         required=["speedup", "iters_per_sec_jax",
                                   "iters_per_sec_python",
                                   "events_per_sec_legacy",
                                   "events_per_sec_hot",
                                   "events_per_sec_hot_telemetry",
                                   "telemetry_overhead_pct",
                                   "speedup_hot",
                                   "stream", "mode",
                                   "budget_exhausted"]),
    "frontier": dict(bench="bench_frontier", required=[]),
    "heterogeneity": dict(bench="bench_heterogeneity", committed=True,
                          required=["rows", "control",
                                    "aware_beats_blind",
                                    "degenerate_exact",
                                    "noise_floor_pct", "mode",
                                    "budget_exhausted"]),
    "matched": dict(bench="bench_matched", required=[]),
    "matched_jax": dict(bench="bench_matched", required=[]),
    "optimality_gap": dict(bench="bench_optimality_gap", committed=True,
                           required=["rows", "ns", "ci_half_width",
                                     "placement",
                                     "gap_monotone_bundled",
                                     "gap_monotone_separate",
                                     "r_star_agreement_rel",
                                     "budget_exhausted"]),
    "roofline": dict(bench="bench_roofline", committed=True,
                     required=["archs", "dominant_histogram", "hw"]),
    "scale_sweep": dict(bench="bench_scale_sweep", required=[]),
    "scenarios": dict(bench="bench_scenarios", committed=True,
                      required=["scenarios", "rows",
                                "rate_shift_adaptive_lead_pct"]),
    "sensitivity": dict(bench="bench_sensitivity", required=[]),
    "sli_pareto": dict(bench="bench_sli_pareto",
                       required=["prefill_fairness", "decode_fairness",
                                 "tpot"]),
    "trace_replay": dict(bench="bench_trace_replay", required=[]),
    "trace_replay_jax": dict(bench="bench_trace_replay", required=[]),
}

BENCH_RE = re.compile(r"\b(bench_\w+)\b")


def registry_benches(root: Path) -> set:
    """bench_* modules named by benchmarks/run.py (imports + SUITE)."""
    return set(BENCH_RE.findall((root / "benchmarks" / "run.py").read_text()))


def committed_artifacts(root: Path) -> list:
    """Tracked artifacts/bench/*.json (on-disk glob outside a git tree)."""
    try:
        out = subprocess.run(
            ["git", "ls-files", "artifacts/bench/*.json"],
            cwd=root, capture_output=True, text=True, check=True).stdout
        paths = [root / line for line in out.splitlines() if line]
    except (OSError, subprocess.CalledProcessError):
        paths = sorted((root / "artifacts" / "bench").glob("*.json"))
    return [p for p in paths if p.exists()]


def iter_budget_keys(obj, path=""):
    if isinstance(obj, dict):
        for k, v in obj.items():
            sub = f"{path}.{k}" if path else str(k)
            if k == "budget_exhausted":
                yield sub, v
            else:
                yield from iter_budget_keys(v, sub)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from iter_budget_keys(v, f"{path}[{i}]")


def check_engine_speed(payload: dict) -> list:
    """Numeric gates for the hot-path micro-benchmark.

    The committed artifact is produced in ``--full`` mode and promises
    the PR-level bars: >= 5x events/sec over the pre-hot-path engine
    and a streamed replay of >= 1e6 requests on a fixed working set.
    CI's ``bench-smoke`` regenerates the file in quick mode (smaller
    trace, short stream), so the quick bars are a regression canary
    with headroom for runner noise, not the headline.
    """
    errors = []
    full = payload.get("mode") == "full"
    floor = 5.0 if full else 3.0
    hot = payload.get("speedup_hot")
    if isinstance(hot, (int, float)) and hot < floor:
        errors.append(
            f"speedup_hot = {hot:.2f} < {floor} ({payload.get('mode')} "
            f"mode): the multi-event hot path regressed")
    stream = payload.get("stream")
    if isinstance(stream, dict):
        req = stream.get("requests", 0)
        req_floor = 1_000_000 if full else 1
        if not isinstance(req, (int, float)) or req < req_floor:
            errors.append(
                f"stream.requests = {req!r} < {req_floor} "
                f"({payload.get('mode')} mode): the streamed replay no "
                f"longer demonstrates the beyond-memory-ceiling run")
    ovh = payload.get("telemetry_overhead_pct")
    if isinstance(ovh, (int, float)) and ovh >= 10.0:
        errors.append(
            f"telemetry_overhead_pct = {ovh:.1f} >= 10: probes-on hot "
            f"leg regressed past the docs/OBSERVABILITY.md overhead "
            f"contract")
    return errors


def check_manifest(payload: dict) -> list:
    """The embedded provenance record must validate and its recorded
    payload digest must match the payload (minus the record itself)."""
    record = payload.get("manifest")
    if record is None:
        return ["missing 'manifest' RunRecord -- regenerate via "
                "benchmarks.common.save() (repro.telemetry.manifest)"]
    errors = [f"manifest: {e}" for e in validate_record(record)]
    if errors:
        return errors
    want = (record.get("extra") or {}).get("payload_digest")
    if not want:
        errors.append("manifest.extra.payload_digest missing")
    elif want != payload_digest(payload):
        errors.append(
            f"manifest.extra.payload_digest = {want[:12]}... does not "
            f"match the payload (stale or hand-edited artifact)")
    return errors


def check_optimality_gap(payload: dict) -> list:
    """Numeric gates for the many-GPU optimality-gap study.

    The committed artifact is produced in ``--full`` mode and promises
    the production-scale curve: n out to 65536 with every row's
    revenue-gap CI half-width (1.96 x seed-axis standard error) at or
    below 0.5% -- the statistical-resolution gate, separate from the
    structural ``noise_floor_pct`` the monotonicity contract uses.
    CI's ``bench-smoke`` regenerates the file in quick mode (toy sizes,
    few seeds), where only the structural keys are checked.
    """
    errors = []
    if payload.get("quick"):
        return errors
    ns = payload.get("ns") or []
    if not ns or max(ns) < 65536:
        errors.append(
            f"ns = {ns!r}: the full-mode study must extend to n >= 65536")
    ci = payload.get("ci_half_width")
    if not isinstance(ci, (int, float)) or ci > 0.005:
        errors.append(
            f"ci_half_width = {ci!r} > 0.005: a full-mode row's revenue-"
            f"gap CI is wider than the 0.5% resolution gate (raise the "
            f"per-n seed/window schedule)")
    floor = payload.get("noise_floor_pct", 1.0)
    for row in payload.get("rows") or []:
        if row.get("gap_pct", 0.0) < -floor:
            errors.append(
                f"row {row.get('scheme')}/n={row.get('n')}: gap_pct = "
                f"{row.get('gap_pct')!r} < -{floor} (engine 'beating' the "
                f"fluid optimum is a measurement artifact -- e.g. a "
                f"float32 clock stall at production n; rerun with "
                f"extra['ctmc_jax']['x64'])")
    return errors


def check_heterogeneity(payload: dict) -> list:
    """Numeric gates for the mixed-fleet class-aware routing study.

    The committed artifact is produced in ``--full`` mode and promises
    the headline ordering: on every transfer-cost instance
    (``xfer_scale > 0`` -- the free-handoff boundary row legitimately
    favours the pooled class-blind gate) the class-blind gap is at
    least the class-aware gap (minus the structural noise floor), with
    a paired lower confidence bound clear of zero somewhere, and the
    one-class zero-transfer control degenerates to the homogeneous
    planner exactly (R* bitwise) and to the committed optimality_gap
    row within the noise floor.  CI's ``bench-smoke`` regenerates the
    file in quick mode (tiny fleet, few seeds), where only the
    structural keys are checked.
    """
    errors = []
    if payload.get("quick"):
        return errors
    if not payload.get("aware_beats_blind"):
        errors.append(
            "aware_beats_blind is false: no mixed instance shows a "
            "paired class-aware advantage with its CI clear of zero")
    if not payload.get("degenerate_exact"):
        errors.append(
            "degenerate_exact is false: the one-class zero-transfer "
            "hetero LP no longer matches the homogeneous planner bitwise")
    control = payload.get("control") or {}
    if control.get("matches_committed") is False:
        errors.append(
            f"control gap {control.get('gap_pct')!r}% is outside the "
            f"noise floor of the committed optimality_gap row "
            f"({control.get('committed_gap_pct')!r}%)")
    floor = payload.get("noise_floor_pct", 1.0)
    for row in payload.get("rows") or []:
        ga, gb = row.get("gap_aware_pct"), row.get("gap_blind_pct")
        if not (isinstance(ga, (int, float))
                and isinstance(gb, (int, float))):
            errors.append(f"row {row.get('instance')!r}: missing "
                          f"gap_aware_pct/gap_blind_pct")
        elif row.get("xfer_scale", 0.0) == 0.0:
            continue  # boundary row: pooling may beat static splits
        elif gb < ga - floor:
            errors.append(
                f"row {row.get('instance')}/xfer="
                f"{row.get('xfer_scale')}: class-blind gap {gb}% beats "
                f"class-aware {ga}% past the noise floor -- the class-"
                f"aware routing or the per-class LP regressed")
    return errors


def check(root: Path) -> list:
    errors = []
    benches = registry_benches(root)
    for stem, meta in ARTIFACTS.items():
        if meta["bench"] not in benches:
            errors.append(
                f"registry: artifact {stem!r} maps to {meta['bench']!r}, "
                f"which is not in the benchmarks/run.py suite")
        if not (root / "benchmarks" / f"{meta['bench']}.py").exists():
            errors.append(
                f"registry: artifact {stem!r} maps to {meta['bench']!r}, "
                f"which has no benchmarks/{meta['bench']}.py on disk")
        if meta.get("committed") and not (
                root / "artifacts" / "bench" / f"{stem}.json").exists():
            errors.append(
                f"artifacts/bench/{stem}.json: marked committed in the "
                f"registry but missing on disk")

    seen = 0
    for path in committed_artifacts(root):
        rel = path.relative_to(root)
        stem = path.stem
        meta = ARTIFACTS.get(stem)
        if meta is None:
            errors.append(
                f"{rel}: unregistered artifact stem {stem!r} -- add it to "
                f"tools/check_bench.py ARTIFACTS or delete the file")
            continue
        try:
            payload = json.loads(path.read_text())
        except json.JSONDecodeError as exc:
            errors.append(f"{rel}: invalid JSON ({exc})")
            continue
        if not isinstance(payload, dict):
            errors.append(f"{rel}: top level must be a JSON object")
            continue
        for key in meta["required"]:
            if key not in payload:
                errors.append(f"{rel}: missing required key {key!r}")
        errors.extend(f"{rel}: {e}" for e in check_manifest(payload))
        if stem == "engine_speed":
            errors.extend(f"{rel}: {e}" for e in check_engine_speed(payload))
        if stem == "optimality_gap":
            errors.extend(f"{rel}: {e}"
                          for e in check_optimality_gap(payload))
        if stem == "heterogeneity":
            errors.extend(f"{rel}: {e}"
                          for e in check_heterogeneity(payload))
        for where, val in iter_budget_keys(payload):
            if val != 0:
                errors.append(
                    f"{rel}: {where} = {val!r} (fixed simulation budget "
                    f"was exhausted; regenerate at a sufficient size)")
        seen += 1
    if seen == 0:
        errors.append("no committed artifacts/bench/*.json found")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parents[1]
    errors = check(root)
    for e in errors:
        print(f"[check_bench] {e}", file=sys.stderr)
    if errors:
        return 1
    n = len(committed_artifacts(root))
    print(f"[check_bench] OK ({n} artifacts validated against "
          f"{len(ARTIFACTS)} registered stems)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
