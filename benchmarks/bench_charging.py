"""Paper Fig. 2 / Section 5.1: bundled vs separate charging.

Runs the same two-class instance under (i) gate-and-route optimizing the
bundled LP and (ii) prioritize-and-route optimizing the separate LP, in the
exact CTMC.  The paper's qualitative claims:

* the separate scheme recognises more revenue (prefill value is credited
  even without completion),
* it builds persistent *decode* backlogs (inventory to keep decode slots
  busy), while bundled keeps the decode buffer lean and pushes congestion
  upstream into the prefill queue.
"""

from __future__ import annotations

import numpy as np

from repro.core.planning import solve_bundled_lp, solve_separate_lp
from repro.core.policies import gate_and_route, prioritize_and_route
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

from .common import fmt_table, save

PRIM = ServicePrimitives()
PRICING = Pricing(0.1, 0.2)
# heavily overloaded two-class instance: at lambda=4.0 the separate LP
# saturates prefill (x*=1.0) with a persistent decode backlog (q_d*=25.2)
# while the bundled LP balances the pipeline (x*=0.76, q_d*=0)
CLASSES = [
    WorkloadClass("c0-decode-heavy", 300, 1000, 4.0, 0.1),
    WorkloadClass("c1-prefill-heavy", 3000, 400, 4.0, 0.1),
]


def run(quick: bool = True) -> dict:
    n = 100 if quick else 300
    horizon, warmup = (250.0, 60.0) if quick else (500.0, 125.0)
    rows = []
    for name, plan, policy_of, charging in (
        ("bundled/gate-and-route", solve_bundled_lp(CLASSES, PRIM, PRICING),
         gate_and_route, "bundled"),
        ("separate/prioritize-and-route",
         solve_separate_lp(CLASSES, PRIM, PRICING), prioritize_and_route,
         "separate"),
    ):
        pol = policy_of(plan)
        sim = CTMCSimulator(CLASSES, PRIM, PRICING, pol, n=n, seed=0)
        r = sim.run(horizon, warmup=warmup)
        rows.append({
            "scheme": name,
            "revenue_per_server": round(r.revenue_rate_per_server, 2),
            "R_star": round(plan.revenue_rate, 2),
            "decode_queue_per_server": round(float(r.avg_qd.sum()), 3),
            "prefill_queue_per_server": round(float(r.avg_qp.sum()), 3),
        })
    print(fmt_table(rows, ["scheme", "revenue_per_server", "R_star",
                           "decode_queue_per_server",
                           "prefill_queue_per_server"],
                    "\n[charging] bundled vs separate (paper Fig. 2)"))
    out = {"rows": rows,
           "separate_builds_decode_backlog":
               rows[1]["decode_queue_per_server"]
               > 5 * max(rows[0]["decode_queue_per_server"], 1e-6)
               or rows[1]["decode_queue_per_server"]
               > rows[0]["decode_queue_per_server"]}
    save("charging", out)
    return out


if __name__ == "__main__":
    run(quick=True)
