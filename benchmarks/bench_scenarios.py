"""Scenario-registry closed-loop benchmark.

For every registered workload scenario (``repro.workloads``), replays
the same generated trace under the closed-loop variants --
online-adaptive gate-and-route (OnlineController replanning), the
hindsight static plan, the frozen cold-start plan, and the vLLM-style
heuristic -- and tables per-scenario revenue/latency/drop metrics.

Headline check (the Section 6.2 claim): on the ``rate_shift`` scenario
the closed loop must beat the *hindsight* static plan, not just the
cold-start one.  The rate-shift comparison always runs at full scenario
size so the artifact's headline number is quick/full invariant.

Artifact: ``artifacts/bench/scenarios.json``.
"""

from __future__ import annotations

from repro.workloads import (ClosedLoopConfig, compare_policies,
                             get_scenario, list_scenarios,
                             plans_for_scenarios)

from .common import fmt_table, save

COLS = ["scenario", "variant", "revenue_rate", "completion", "drops",
        "ttft_p95", "tpot_p95", "replans"]

# full-size closed loop on the controller's showcase scenario
RATE_SHIFT_CFG = ClosedLoopConfig(n_servers=8, seed=0)


def _rows_of(res: dict) -> list:
    rows = []
    for v, m in res["variants"].items():
        rows.append({
            "scenario": res["scenario"],
            "variant": v,
            "revenue_rate": round(m["revenue_rate"], 2),
            "completion": round(m["completion_rate"], 3),
            "drops": int(m["drops"]),
            "ttft_p95": round(m["ttft_p95"], 2),
            "tpot_p95": round(m["tpot_p95"], 4),
            "replans": int(m["replans"]),
        })
    return rows


def run(quick: bool = True) -> dict:
    variants = (("adaptive", "static", "static_cold", "vllm")
                if quick else
                ("adaptive", "static", "static_cold", "vllm", "sarathi"))
    cells = []
    for name in list_scenarios():
        scn = get_scenario(name)
        if name == "rate_shift":
            cfg = RATE_SHIFT_CFG  # full size: the headline comparison
        elif quick:
            cfg = ClosedLoopConfig(
                n_servers=6, seed=0, rate_scale=0.5,
                horizon=min(scn.horizon, 120.0))
        else:
            cfg = ClosedLoopConfig(n_servers=8, seed=0)
        trace = scn.generate(seed=cfg.seed, horizon=cfg.horizon,
                             compression=cfg.compression,
                             rate_scale=cfg.rate_scale)
        cells.append((name, scn, cfg, trace))
    # all cold-start + hindsight plans of the registry in ONE batched
    # interior-point solve (used to be 2 simplex solves per scenario)
    plans = plans_for_scenarios([c[1] for c in cells], [c[3] for c in cells],
                                [c[2] for c in cells])
    results, rows = {}, []
    for (name, scn, cfg, trace), plan in zip(cells, plans):
        res = compare_policies(scn, cfg, variants=variants,
                               trace=trace, plans=plan)
        results[name] = res
        rows.extend(_rows_of(res))
    print(fmt_table(rows, COLS,
                    f"\n[scenarios] closed loop over "
                    f"{len(results)} registered scenarios"))

    shift = results["rate_shift"]
    lead = shift["adaptive_lead_pct"]
    beats = (shift["variants"]["adaptive"]["revenue_rate"]
             > shift["variants"]["static"]["revenue_rate"])
    print(f"[scenarios] rate_shift: adaptive vs hindsight-static "
          f"{lead:+.1f}% revenue rate "
          f"({'closed loop wins' if beats else 'NO WIN'})")
    out = {
        "scenarios": results,
        "rows": rows,
        "rate_shift_adaptive_lead_pct": lead,
        "rate_shift_adaptive_beats_static": bool(beats),
        "quick": bool(quick),
    }
    save("scenarios", out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(quick=not ap.parse_args().full)
