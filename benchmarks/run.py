"""Run the whole benchmark suite: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only name[,name]]

Artifacts land in artifacts/bench/*.json; each bench prints its table.
"""

from __future__ import annotations

import argparse
import time
import traceback

from . import (bench_ablations, bench_calibration, bench_charging,
               bench_classes, bench_convergence, bench_ctmc_speed,
               bench_engine_speed, bench_frontier, bench_heterogeneity,
               bench_matched, bench_optimality_gap, bench_roofline,
               bench_scale_sweep, bench_scenarios, bench_sensitivity,
               bench_sli_pareto, bench_trace_replay)
from .common import ART


class _SweepCLI:
    """Suite adapter delegating to the ``python -m repro.sweep.run`` CLI."""

    @staticmethod
    def run(quick: bool = True):
        from repro.sweep.run import main as sweep_main

        argv = ["--name", "suite",
                "--out", str(ART.parent / "sweep" / "suite.json")]
        if quick:
            argv.append("--quick")
        rc = sweep_main(argv)
        if rc:
            raise RuntimeError(f"sweep CLI exited with {rc}")


SUITE = [
    ("calibration", bench_calibration),        # Fig 3
    ("charging", bench_charging),              # Fig 2 / Section 5.1
    ("trace_replay", bench_trace_replay),      # Table 2 / Fig 4
    ("frontier", bench_frontier),              # Fig 5
    ("sli_pareto", bench_sli_pareto),          # Fig 6
    ("sensitivity", bench_sensitivity),        # Figs 7-8
    ("matched", bench_matched),                # EC.8.2
    ("scale_sweep", bench_scale_sweep),        # EC.8.3
    ("classes", bench_classes),                # EC.8.4
    ("scenarios", bench_scenarios),            # workload registry closed loop
    ("convergence", bench_convergence),        # EC.8.5
    ("optimality_gap", bench_optimality_gap),  # Theorems 2-3 vanishing gap
    ("heterogeneity", bench_heterogeneity),    # mixed-fleet class-aware study
    ("ctmc_speed", bench_ctmc_speed),          # uniformized engine micro-bench
    ("engine_speed", bench_engine_speed),      # trace-replay engine micro-bench
    ("ablations", bench_ablations),            # EC.8.6
    ("sweep", _SweepCLI),                      # repro.sweep.run default grid
    ("roofline", bench_roofline),              # dry-run roofline table
]


def _artifact_state() -> dict:
    """(size, mtime_ns) per JSON artifact under artifacts/ -- cheap
    before/after snapshot to detect a bench that silently wrote
    nothing."""
    root = ART.parent
    return {str(p): (p.stat().st_size, p.stat().st_mtime_ns)
            for p in root.rglob("*.json") if p.is_file()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow); default is quick mode")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    failures = []
    for name, mod in SUITE:
        if only and name not in only:
            continue
        print(f"\n{'=' * 72}\n== bench: {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        before = _artifact_state()
        try:
            mod.run(quick=not args.full)
            print(f"== {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
            continue
        if args.full and _artifact_state() == before:
            # a full-mode bench that writes no artifact produced nothing
            # a paper table can cite; fail loudly instead of shipping a
            # green run with a silent hole in artifacts/bench/
            failures.append(name)
            print(f"== {name}: FAILED -- wrote no artifact under "
                  f"{ART.parent} in --full mode (every full-mode bench "
                  f"must save() its table)", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks green")


if __name__ == "__main__":
    main()
