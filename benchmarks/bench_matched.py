"""Paper EC.8.2: matched synthetic vs real trace across cluster sizes.

Builds a Markovian synthetic workload sharing the trace's class means,
arrival calibration, horizon, and controller parameters, and compares
online gate-and-route revenue on both across n in {5,10,20} at fixed
per-server offered load.  The paper finds the synthetic slightly
optimistic with a gap shrinking in n (fluid limits coincide).
"""

from __future__ import annotations

import numpy as np

from repro.data.traces import Request, TraceConfig, synth_azure_trace, trace_class_means

from .common import fmt_table, run_trace_policy, save


def matched_synthetic(trace, seed=0):
    """Same class means + rates, Markovian (Poisson/exponentialised)."""
    means = trace_class_means(trace, 2)
    horizon = max(r.t_arrival for r in trace)
    rng = np.random.default_rng(seed)
    reqs = []
    rid = 0
    for i, (P, D, rate) in enumerate(means):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / rate)
            if t > horizon:
                break
            reqs.append(Request(
                rid, t, i,
                max(8, int(rng.exponential(P))),
                max(2, int(rng.exponential(D)))))
            rid += 1
    reqs.sort(key=lambda r: r.t_arrival)
    return reqs


def run(quick: bool = True, engine: str = "python") -> dict:
    """``engine="jax"`` replays both traces in the JAX engine with
    open-loop gate-and-route; the real-vs-synthetic gap is a
    within-table comparison, so the EC.8.2 question is answered either
    way (batch replications over seeds -- where the JAX engine wins --
    are not needed for this deterministic policy)."""
    rows = []
    ns = [5, 10] if quick else [5, 10, 20]
    for n in ns:
        # fixed per-server offered load: compression scales 1/n
        tcfg = TraceConfig(horizon=240.0, compression=0.3 / n, seed=42)
        trace = synth_azure_trace(tcfg)
        synth = matched_synthetic(trace)
        r_real = run_trace_policy("gate_and_route", trace, n,
                                  horizon=tcfg.horizon, engine=engine)
        r_syn = run_trace_policy("gate_and_route", synth, n,
                                 horizon=tcfg.horizon, engine=engine)
        gap = 100 * (r_syn["revenue_rate"] / max(r_real["revenue_rate"],
                                                 1e-9) - 1)
        rows.append({"n": n,
                     "real_rev": round(r_real["revenue_rate"], 1),
                     "synthetic_rev": round(r_syn["revenue_rate"], 1),
                     "gap_pct": round(gap, 2)})
    print(fmt_table(rows, ["n", "real_rev", "synthetic_rev", "gap_pct"],
                    f"\n[matched] synthetic-vs-trace across scale "
                    f"({engine} engine)"))
    out = {"rows": rows, "engine": engine}
    save("matched" if engine == "python" else f"matched_{engine}", out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="python", choices=("python", "jax"))
    a = ap.parse_args()
    run(quick=not a.full, engine=a.engine)
