"""Paper Fig. 3: iteration-time calibration.

The paper fits tau_mix(C) = alpha + beta*C (mixed) and
T_solo(K) = a_s + b_s*K (solo) on A100/vLLM.  Without a GPU we measure the
*real jitted engine's* CPU step times across chunk sizes / KV loads, fit
the same linear models, and report R^2 -- demonstrating the calibration
pipeline end-to-end -- alongside the analytic v5e projection derived from
the dry-run roofline terms (memory-bound decode: tau_solo ~ bytes/BW).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving.steps import init_server_state, make_decode_step, make_mixed_step

from .common import round_vals, save


def _fit_line(x, y):
    x = np.asarray(x, float)
    y = np.asarray(y, float)
    A = np.stack([np.ones_like(x), x], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum()) or 1.0
    return float(coef[0]), float(coef[1]), 1.0 - ss_res / ss_tot


def _time_fn(fn, *args, reps=3):
    fn(*args)  # compile + warmup
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(quick: bool = True) -> dict:
    cfg = get_config("qwen2-0.5b", reduced=True)
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    B, max_len = 8, 1024

    # mixed iterations: vary the prefill chunk size C
    chunks = [16, 32, 64, 128] if quick else [16, 32, 64, 128, 256, 512]
    taus = []
    for C in chunks:
        step = jax.jit(make_mixed_step(cfg, C))
        state = init_server_state(cfg, B, max_len, jnp.float32)
        state["active"] = state["active"].at[:].set(True)
        state["length"] = state["length"].at[:].set(C + 1)
        toks = jnp.zeros((C,), jnp.int32)
        t = _time_fn(lambda s: step(params, s, 0, toks,
                                    jnp.zeros((1, 1), jnp.int32)), state)
        taus.append(t)
    alpha, beta, r2_mix = _fit_line(chunks, taus)

    # solo iterations: vary resident KV load K
    dstep = jax.jit(make_decode_step(cfg))
    kvs = [64, 256, 512, 896] if quick else [64, 256, 512, 896, 1536, 3072]
    taus_s = []
    for K in kvs:
        state = init_server_state(cfg, B, max(max_len, K + 8), jnp.float32)
        state["active"] = state["active"].at[:].set(True)
        state["length"] = state["length"].at[:].set(K // B)
        t = _time_fn(lambda s: dstep(params, s), state)
        taus_s.append(t)
    a_s, b_s, r2_solo = _fit_line(kvs, taus_s)

    out = {
        "mixed_fit": round_vals({"alpha": alpha, "beta": beta, "r2": r2_mix},
                                6),
        "solo_fit": round_vals({"a_s": a_s, "b_s": b_s, "r2": r2_solo}, 8),
        "chunks": chunks, "tau_mix_s": taus,
        "kv_loads": kvs, "tau_solo_s": taus_s,
        "paper_a100": {"alpha": 0.0174, "beta": 6.2e-5,
                       "a_s": 0.0089, "b_s": 1.08e-7},
    }
    save("calibration", out)
    print("[calibration] tau_mix(C) fit: alpha=%.4f beta=%.2e R2=%.4f"
          % (alpha, beta, r2_mix))
    print("[calibration] T_solo(K) fit: a_s=%.4f b_s=%.2e R2=%.4f"
          % (a_s, b_s, r2_solo))
    return out


if __name__ == "__main__":
    run(quick=True)
