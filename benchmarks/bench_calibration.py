"""Paper Fig. 3: iteration-time calibration, end to end.

Runs the :mod:`repro.calibration` pipeline -- (B x C x K) grid ->
timing backend -> robust affine fit -> versioned artifact -- and then
closes the loop: the fitted :class:`IterationTimeModel` re-derives the
planning LP and drives :class:`ClusterEngineJAX` on a fixed trace, and
the headline number is the revenue-rate delta between the fitted model
and the seed ``ServicePrimitives`` constants under identically
re-planned gate-and-route policies.

Backend selection is ``auto``: the Pallas-kernel timer on TPU, the
*deterministic* analytic roofline on CPU (no wall-clock in the
no-accelerator path, so the committed artifact is reproducible
bit-for-bit).  Wall-clock timing, where used, goes through
``timeit_median`` (warmup + median-of-k ``perf_counter``), and the
fitter reports constant-input degeneracy explicitly instead of the old
``ss_tot or 1.0`` fabrication.
"""

from __future__ import annotations

from repro.calibration import (CalibrationGrid, calibrate,
                               model_from_artifact)
from repro.calibration.models import AffineModel
from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.data.traces import TraceConfig, synth_azure_trace, trace_class_means
from repro.serving.engine_jax import ClusterEngineJAX
from repro.serving.engine_sim import EngineConfig

from .common import PRICING, round_vals, save

ARCH = "qwen2-0.5b"
N_SERVERS = 10
HORIZON = 40.0


def _engine_revenue(model, trace, classes) -> dict:
    """Plan + replay under one iteration-time model (closed loop)."""
    prim = model.primitives()
    plan = solve_bundled_lp(classes, prim, PRICING,
                            sli=SLISpec(pin_zero_decode_queue=True))
    cfg = EngineConfig(prim=prim, pricing=PRICING, n_servers=N_SERVERS,
                       iter_model=model)
    eng = ClusterEngineJAX(classes, gate_and_route(plan), cfg, trace,
                           horizon=HORIZON)
    return eng.run(0)


def run(quick: bool = True) -> dict:
    grid = CalibrationGrid.tiny() if quick else CalibrationGrid.default()
    art = calibrate(ARCH, grid=grid, backend="auto", reduced=False)
    fitted = model_from_artifact(art, "fitted")
    seed_model = AffineModel()  # the hand-authored seed constants

    trace = synth_azure_trace(
        TraceConfig(horizon=HORIZON, base_rate=2.0, compression=0.08,
                    seed=42))
    means = trace_class_means(trace, 2)
    from repro.core.types import WorkloadClass
    classes = [WorkloadClass(nm, m[0], m[1], m[2] / N_SERVERS,
                             patience=3e-4)
               for nm, m in zip(("code", "conv"), means)]

    m_seed = _engine_revenue(seed_model, trace, classes)
    m_fit = _engine_revenue(fitted, trace, classes)
    delta_pct = 100.0 * (m_fit["revenue_rate"] - m_seed["revenue_rate"]) \
        / m_seed["revenue_rate"]

    out = {
        "arch": art.arch,
        "backend": art.backend,
        "artifact": art.to_dict(),
        "mixed_fit": round_vals({"alpha": art.alpha, "beta": art.beta,
                                 "r2": art.mix.r2}, 8),
        "solo_fit": round_vals({"a_s": art.a_s, "b_s": art.b_s,
                                "r2": art.solo.r2}, 10),
        "fit_degenerate": bool(art.mix.constant_y or art.solo.constant_y),
        "min_r2": art.min_r2,
        "revenue_rate_seed": m_seed["revenue_rate"],
        "revenue_rate_fitted": m_fit["revenue_rate"],
        "fitted_vs_seed_revenue_delta_pct": delta_pct,
        "budget_exhausted": int(m_seed["budget_exhausted"]
                                + m_fit["budget_exhausted"]),
        "paper_a100": {"alpha": 0.0174, "beta": 6.2e-5,
                       "a_s": 0.0089, "b_s": 1.08e-7},
    }
    save("calibration", out)
    print(f"[calibration] {art.arch} backend={art.backend} "
          f"alpha={art.alpha:.6g} beta={art.beta:.3g} "
          f"a_s={art.a_s:.6g} b_s={art.b_s:.3g} "
          f"R2(mix)={art.mix.r2:.4f} R2(solo)={art.solo.r2:.4f}")
    print(f"[calibration] fitted-vs-seed revenue delta: {delta_pct:+.2f}% "
          f"({m_fit['revenue_rate']:.1f} vs {m_seed['revenue_rate']:.1f})")
    return out


if __name__ == "__main__":
    run(quick=True)
