"""Paper EC.8.5: convergence to the fluid optimum as n grows.

Two-class synthetic instance (decode-heavy / prefill-heavy), exact CTMC
simulation of the paper's stochastic network under gate-and-route and the
SLI-aware randomized router.  Checks:

* per-server revenue -> R* (LP optimum), error shrinking in n;
* prefill occupancies x_i -> x_i* under both policies;
* decode occupancies (y_m+y_s per class) -> LP targets under the
  SLI-aware router (Theorem 4) but not necessarily under plain
  gate-and-route (the paper's Fig. EC.6 observation).
"""

from __future__ import annotations

import numpy as np

from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import gate_and_route, sli_aware_policy
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

from .bench_sli_pareto import CLASSES
from .common import fmt_table, save

PRIM = ServicePrimitives()
PRICING = Pricing(0.1, 0.2)


def run(quick: bool = True) -> dict:
    plan = solve_bundled_lp(CLASSES, PRIM, PRICING)
    plan_sli = solve_bundled_lp(CLASSES, PRIM, PRICING,
                                sli=SLISpec(pin_zero_decode_queue=True))
    ns = [20, 50, 200] if quick else [5, 20, 50, 200, 500]
    seeds = [0, 1] if quick else [0, 1, 2, 3, 4]
    horizon, warmup = (300.0, 75.0) if quick else (600.0, 150.0)
    rows, occ = [], []
    for n in ns:
        for name, pol in (("gate_and_route", gate_and_route(plan)),
                          ("sli_aware", sli_aware_policy(plan_sli))):
            revs, xs, ys = [], [], []
            for seed in seeds:
                sim = CTMCSimulator(CLASSES, PRIM, PRICING, pol, n=n,
                                    seed=seed)
                r = sim.run(horizon, warmup=warmup)
                revs.append(r.revenue_rate_per_server)
                xs.append(r.avg_x)
                ys.append(r.avg_ym + r.avg_ys)
            p = pol.plan
            rev = float(np.mean(revs))
            x_err = float(np.abs(np.mean(xs, 0) - p.x).sum())
            y_err = float(np.abs(np.mean(ys, 0) - (p.ym + p.ys)).sum())
            rows.append({"n": n, "policy": name,
                         "rev_per_server": round(rev, 2),
                         "R_star": round(p.revenue_rate, 2),
                         "gap_pct": round(100 * (1 - rev / p.revenue_rate),
                                          2),
                         "x_err_l1": round(x_err, 4),
                         "y_err_l1": round(y_err, 4)})
    print(fmt_table(rows, ["n", "policy", "rev_per_server", "R_star",
                           "gap_pct", "x_err_l1", "y_err_l1"],
                    "\n[convergence] per-server revenue & occupancy vs n"))
    gr = [r for r in rows if r["policy"] == "gate_and_route"]
    out = {"rows": rows,
           "gap_shrinks": abs(gr[-1]["gap_pct"]) <= abs(gr[0]["gap_pct"])}
    save("convergence", out)
    return out


if __name__ == "__main__":
    run(quick=True)
