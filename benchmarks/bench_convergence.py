"""Paper EC.8.5: convergence to the fluid optimum as n grows.

Two-class synthetic instance (decode-heavy / prefill-heavy), exact CTMC
simulation of the paper's stochastic network under gate-and-route and the
SLI-aware randomized router.  Checks:

* per-server revenue -> R* (LP optimum), error shrinking in n;
* prefill occupancies x_i -> x_i* under both policies;
* decode occupancies (y_m+y_s per class) -> LP targets under the
  SLI-aware router (Theorem 4) but not necessarily under plain
  gate-and-route (the paper's Fig. EC.6 observation).

Grid execution is delegated to :mod:`repro.sweep`; this module only
aggregates the sweep cells into the paper's table.  ``evaluator`` picks
the engine: ``"ctmc"`` (the exact Python loop) or ``"ctmc_jax"`` (the
uniformized JAX engine -- same law, vmapped over the seed axis; use it
for the paper-scale ``--full`` grid, where thousands of replications at
n up to 500 dominate wall-clock).  From the CLI::

    PYTHONPATH=src python -m benchmarks.bench_convergence \
        --evaluator ctmc_jax [--full]
"""

from __future__ import annotations

import numpy as np

from repro.sweep import SweepSpec, run_sweep
from repro.sweep.run import default_mix

from .common import ART, fmt_table, save

POLICIES = ("gate_and_route", "sli_aware")


def run(quick: bool = True, evaluator: str = "ctmc") -> dict:
    ns = (20, 50, 200) if quick else (5, 20, 50, 200, 500)
    n_seeds = 2 if quick else 5
    horizon, warmup = (300.0, 75.0) if quick else (600.0, 150.0)
    bench_name = ("convergence" if evaluator == "ctmc"
                  else f"convergence_{evaluator}")
    spec = SweepSpec(
        name=bench_name, evaluator=evaluator, policies=POLICIES,
        n_servers=ns, n_seeds=n_seeds, seed=0,
        mixes=(default_mix("two_class"),),
        horizon=horizon, warmup=warmup,
        # paired comparison: both policies see the same streams, as the
        # original shared-seed loop did
        extra={"crn_policies": True})
    res = run_sweep(spec)
    I = len(spec.mixes[0].classes)

    rows = []
    for n in ns:
        for name in POLICIES:
            sel = res.select(policy=name, n=n)
            rev = float(np.mean([c.metrics["revenue_rate"] for c in sel]))
            r_star = sel[0].metrics["R_star"]
            # error of the seed-averaged occupancies vs the LP targets
            x_mean = np.array([np.mean([c.metrics[f"avg_x/{i}"] for c in sel])
                               for i in range(I)])
            y_mean = np.array([np.mean([c.metrics[f"avg_y/{i}"] for c in sel])
                               for i in range(I)])
            x_star = np.array([sel[0].metrics[f"x_star/{i}"]
                               for i in range(I)])
            y_star = np.array([sel[0].metrics[f"y_star/{i}"]
                               for i in range(I)])
            rows.append({"n": n, "policy": name,
                         "rev_per_server": round(rev, 2),
                         "R_star": round(r_star, 2),
                         "gap_pct": round(100 * (1 - rev / r_star), 2),
                         "x_err_l1": round(float(np.abs(x_mean - x_star)
                                                 .sum()), 4),
                         "y_err_l1": round(float(np.abs(y_mean - y_star)
                                                 .sum()), 4)})
    print(fmt_table(rows, ["n", "policy", "rev_per_server", "R_star",
                           "gap_pct", "x_err_l1", "y_err_l1"],
                    "\n[convergence] per-server revenue & occupancy vs n"))
    gr = [r for r in rows if r["policy"] == "gate_and_route"]
    artifact = res.save(ART.parent / "sweep" / f"{bench_name}.json")
    out = {"rows": rows,
           "gap_shrinks": abs(gr[-1]["gap_pct"]) <= abs(gr[0]["gap_pct"]),
           "sweep_artifact": str(artifact)}
    save(bench_name, out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--evaluator", default="ctmc",
                    choices=("ctmc", "ctmc_jax"))
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()
    run(quick=not a.full, evaluator=a.evaluator)
