"""Heterogeneous-fleet optimality study: class-aware vs class-blind
gate-and-route against the heterogeneous fluid optimum R*.

The tentpole question: on a mixed GPU fleet (per-class
``ServicePrimitives`` resolved from the calibration surfaces, per-class
KV-handoff transfer costs), how much revenue does an operator lose by
planning as if the fleet were homogeneous?  Three quantities per
instance:

* **R*** -- the heterogeneous fluid optimum from the per-class-blocked
  LP (:func:`repro.core.hetero.plan_fleet`, Eq. 40 extended with one
  capacity row group per server class and fleet-share-weighted flow
  balance);
* **class-aware** -- the paper's gate-and-route instantiated per class
  pool from the heterogeneous plan's projections
  (:meth:`HeteroPlanSolution.pool_plan`), arrivals split across pools
  with the plan's routing probabilities
  (:meth:`HeteroPlanSolution.split_probs`), each pool replayed in the
  JAX trace engine with its own ``EngineConfig.fleet``;
* **class-blind** -- ONE homogeneous gate-and-route planned from the
  fleet-averaged time surfaces (:func:`repro.core.hetero.
  blind_primitives` -- what a single calibration run against the mixed
  fleet would fit), replayed over the whole heterogeneous fleet.

Both policies replay the SAME per-seed trace (common random numbers),
so the headline ``delta_pct = gap_blind - gap_aware`` is a paired
difference and its CI half-width is the paired seed-axis standard
error.  The acceptance bar: class-aware beats class-blind (paired lower
confidence bound > 0) on at least one mixed instance, enforced by
``tools/check_bench.py`` on the committed artifact.

The transfer-cost axis sweeps ``FleetSpec.xfer_scale`` (0 = free KV
handoff, 1 = nominal link pricing, 4 = congested links) on the A/H
two-class fleet; a three-class instance adds the L4-class long tail.
The ``xfer_scale = 0`` row is an informative boundary point, NOT part
of the dominance gate: with free KV handoff the blind average barely
misprices anything, and ONE pooled gate over all n servers out-
multiplexes the class-aware policy's static per-pool splits (each pool
eats its own arrival variance).  Class-aware dominance is asserted --
here and in ``tools/check_bench.py`` -- only on transfer-cost rows
(``xfer_scale > 0``), where the blind plan's mispricing overwhelms the
pooling advantage.
Arrival rates are tuned to OVERLOAD the calibrated fleet (the
roofline-calibrated primitives are ~10x faster than the paper's A100
defaults, so the paper's lambda = 1.0 would leave every instance
capacity-slack and the routing question moot).

**Degeneration control**: a one-class ``paper-a100`` fleet at zero
transfer cost must reproduce the homogeneous PR story exactly -- the
heterogeneous LP's R* equals the homogeneous planner's bitwise, and the
control row re-runs the committed ``optimality_gap`` study's smallest-n
cell (same CTMC evaluator, same schedule, same seeds) through the
hetero pipeline's degenerate plan, so its gap must match the committed
row within the noise floor.

Artifact: ``artifacts/bench/heterogeneity.json`` (committed, validated
by ``tools/check_bench.py``).  ``budget_exhausted`` aggregates the
engine's fixed-scan-budget indicator over every cell and must be 0.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core.hetero import (FleetSpec, blind_primitives,
                               class_aware_policies, plan_fleet)
from repro.core.planning import solve_bundled_lp
from repro.core.planning_batch import solve_plan_jax
from repro.core.policies import gate_and_route
from repro.core.types import WorkloadClass
from repro.data.traces import Request, tensorize_trace, validate_requests
from repro.serving.engine_jax import ClusterEngineJAX
from repro.serving.engine_sim import EngineConfig
from repro.sweep import SweepSpec, run_sweep

from .bench_optimality_gap import NOISE_FLOOR_PCT, OVERLOADED_MIX, SCHEMES
from .common import ART, PRICING, PRIM, fmt_table, save

# the EC.8.5 contrast (decode-heavy vs prefill-heavy), rates scaled to
# overload the calibrated fleet (see module docstring)
LAMBDA_PER_SERVER = 24.0
WORKLOAD = (
    dict(name="decode-heavy", prompt_len=300, decode_len=1000,
         patience=0.1),
    dict(name="prefill-heavy", prompt_len=3000, decode_len=400,
         patience=0.1),
)

# instance -> (fleet spec rows, transfer-cost sweep)
FULL_FLEETS = {
    "mixed_a100_h100": ((("a100-cal", 3), ("h100-cal", 3)),
                        (0.0, 1.0, 4.0)),
    "mixed_three_class": ((("a100-cal", 2), ("h100-cal", 2),
                           ("l4-cal", 2)), (1.0,)),
}
QUICK_FLEETS = {
    "mixed_a100_h100": ((("a100-cal", 2), ("h100-cal", 2)), (1.0,)),
}

# (n_seeds, horizon) for the engine replays; the control row reuses the
# optimality_gap schedule at its smallest n so the numbers are paired
FULL_ENGINE = (4, 10.0)
QUICK_ENGINE = (2, 3.0)
FULL_CONTROL = (16, 32, 300.0, 75.0)  # (n, seeds, horizon, warmup)
QUICK_CONTROL = (8, 4, 40.0, 10.0)


def _workload_classes() -> list:
    return [WorkloadClass(w["name"], w["prompt_len"], w["decode_len"],
                          LAMBDA_PER_SERVER, w["patience"])
            for w in WORKLOAD]


def _arrivals(classes, n: int, horizon: float, seed: int) -> list:
    """Poisson arrivals per class at cluster rate ``lambda_i * n``,
    merged and time-sorted: ``[(t, class_index), ...]``."""
    rng = np.random.default_rng(seed)
    rows = []
    for i, c in enumerate(classes):
        rate = c.arrival_rate * n
        t = float(rng.exponential(1.0 / rate))
        while t <= horizon:
            rows.append((t, i))
            t += float(rng.exponential(1.0 / rate))
    rows.sort()
    return rows


def _tensorize(rows, classes, pad: int):
    reqs = [Request(k, t, i, int(classes[i].prompt_len),
                    int(classes[i].decode_len),
                    patience=classes[i].patience)
            for k, (t, i) in enumerate(rows)]
    validate_requests(reqs)
    tt = tensorize_trace(reqs, pad_to=pad)
    assert tt.n_dropped == 0, "pad underestimated the arrival count"
    return tt


def _engine(classes, policy, fleet, tt, horizon: float):
    cfg = EngineConfig(PRIM, PRICING, n_servers=fleet.n, fleet=fleet)
    return ClusterEngineJAX(classes, policy, cfg, tt, horizon=horizon,
                            drain=True, k_events=4)


def _eval_instance(name, fleet, classes, n_seeds, horizon):
    """One fleet instance: per-seed paired (class-aware, class-blind)
    per-server revenue under common random numbers."""
    hplan = plan_fleet(classes, fleet, PRICING)
    r_star = float(hplan.revenue_rate)
    probs = hplan.split_probs()  # (C, I) routing split
    pool_pols = class_aware_policies(hplan)
    bprim, _, _ = blind_primitives(fleet)
    blind_pol = gate_and_route(solve_bundled_lp(classes, bprim, PRICING),
                               name="gate_and_route_blind")
    pools = [FleetSpec.of([(fleet.classes[c], fleet.counts[c])],
                          xfer_scale=fleet.xfer_scale)
             for c in range(fleet.n_classes)]
    # one fixed pad across seeds/pools => one compiled scan per (n, steps)
    mean_arr = sum(c.arrival_rate for c in classes) * fleet.n * horizon
    pad = 1 << int(np.ceil(np.log2(mean_arr + 6.0 * np.sqrt(mean_arr))))

    aware, blind, budget = [], [], 0.0
    for s in range(n_seeds):
        rows = _arrivals(classes, fleet.n, horizon, seed=7000 + s)
        su = _engine(classes, blind_pol, fleet,
                     _tensorize(rows, classes, pad), horizon).run(s)
        budget = max(budget, float(su["budget_exhausted"]))
        blind.append(float(su["revenue_rate"]) / fleet.n)

        # route each arrival to a class pool with the plan's split
        rng = np.random.default_rng([9000 + s, fleet.n])
        cls_idx = np.array([i for _, i in rows])
        cdf = np.cumsum(probs[:, cls_idx], axis=0)  # (C, R)
        pool_of = (rng.random(len(rows)) > cdf).sum(axis=0)
        rev = 0.0
        for c, (pool, pol) in enumerate(zip(pools, pool_pols)):
            sub = [rows[j] for j in np.nonzero(pool_of == c)[0]]
            if not sub:
                continue
            su = _engine(classes, pol, pool,
                         _tensorize(sub, classes, pad), horizon).run(s)
            budget = max(budget, float(su["budget_exhausted"]))
            rev += float(su["revenue_rate"])
        aware.append(rev / fleet.n)

    aware, blind = np.array(aware), np.array(blind)
    ga = 100.0 * (1.0 - aware / r_star)
    gb = 100.0 * (1.0 - blind / r_star)
    delta = gb - ga  # paired: same trace per seed
    se = lambda v: float(v.std() / np.sqrt(len(v)))  # noqa: E731
    return {
        "instance": name,
        "fleet": "+".join(f"{k}x{cls.name}"
                          for cls, k in zip(fleet.classes, fleet.counts)),
        "n": fleet.n,
        "xfer_scale": fleet.xfer_scale,
        "R_star": round(r_star, 3),
        "rev_aware": round(float(aware.mean()), 3),
        "rev_blind": round(float(blind.mean()), 3),
        "gap_aware_pct": round(float(ga.mean()), 3),
        "gap_blind_pct": round(float(gb.mean()), 3),
        "ci_aware_pct": round(1.96 * se(ga), 3),
        "ci_blind_pct": round(1.96 * se(gb), 3),
        "delta_pct": round(float(delta.mean()), 3),
        "ci_delta_pct": round(1.96 * se(delta), 3),
        "seeds": n_seeds,
        "horizon": horizon,
        "budget_exhausted": budget,
    }


def _control(quick: bool) -> dict:
    """Degeneration control: the hetero pipeline at one class + zero
    transfer cost must reproduce the homogeneous optimality_gap study's
    smallest-n cell (same evaluator, schedule and seeds)."""
    n, n_seeds, horizon, warmup = QUICK_CONTROL if quick else FULL_CONTROL
    classes = OVERLOADED_MIX.workload_classes()
    fleet = FleetSpec.of([("paper-a100", n)], xfer_scale=0.0)
    hplan = plan_fleet(classes, fleet, OVERLOADED_MIX.price())
    hom = solve_plan_jax(classes, OVERLOADED_MIX.primitives(),
                         OVERLOADED_MIX.price())
    degenerate_exact = bool(
        float(hplan.revenue_rate) == float(hom.revenue_rate)
        and np.array_equal(hplan.pool_plan(0).x, hom.x))

    spec = SweepSpec(
        name=f"heterogeneity_control_n{n}", evaluator="ctmc_jax",
        policies=(SCHEMES["bundled"],), n_servers=(n,), n_seeds=n_seeds,
        seed=0, mixes=(OVERLOADED_MIX,), horizon=horizon, warmup=warmup,
        extra={"crn_policies": True, "ctmc_jax": {"x64": True}})
    res = run_sweep(spec, progress=lambda m: print(m, flush=True))
    res.save(ART.parent / "sweep" / f"{spec.name}.json")
    sel = res.select(policy=SCHEMES["bundled"], n=n)
    gaps = np.array([c.metrics["gap_pct"] for c in sel])
    budget = max(float(horizon - c.metrics["t_end"] > 1e-9) for c in sel)

    committed_gap = None
    ref = ART / "optimality_gap.json"
    if ref.exists():
        ref_rows = json.loads(ref.read_text()).get("rows") or []
        for row in ref_rows:
            if row.get("scheme") == "bundled" and row.get("n") == n:
                committed_gap = float(row["gap_pct"])
    gap = float(gaps.mean())
    matches = (None if committed_gap is None
               else bool(abs(gap - committed_gap) <= NOISE_FLOOR_PCT))
    return {
        "n": n,
        "gap_pct": round(gap, 4),
        "ci_half_width_pct": round(
            1.96 * float(gaps.std() / np.sqrt(len(gaps))), 4),
        "R_star_hetero": float(hplan.revenue_rate),
        "R_star_homogeneous": float(hom.revenue_rate),
        "degenerate_exact": degenerate_exact,
        "committed_gap_pct": committed_gap,
        "matches_committed": matches,
        "seeds": n_seeds,
        "horizon": horizon,
        "budget_exhausted": budget,
    }


def run(quick: bool = True) -> dict:
    fleets = QUICK_FLEETS if quick else FULL_FLEETS
    n_seeds, horizon = QUICK_ENGINE if quick else FULL_ENGINE
    classes = _workload_classes()

    rows = []
    for name, (spec, xfers) in fleets.items():
        for xs in xfers:
            fleet = FleetSpec.of(list(spec), xfer_scale=xs)
            rows.append(_eval_instance(name, fleet, classes, n_seeds,
                                       horizon))
            print(f"[heterogeneity] {name} xfer={xs}: gap aware "
                  f"{rows[-1]['gap_aware_pct']}% vs blind "
                  f"{rows[-1]['gap_blind_pct']}%", flush=True)

    control = _control(quick)
    print(f"[heterogeneity] control n={control['n']}: gap "
          f"{control['gap_pct']}% (committed "
          f"{control['committed_gap_pct']}), hetero R* == homogeneous "
          f"R*: {control['degenerate_exact']}", flush=True)

    print(fmt_table(
        rows, ["instance", "xfer_scale", "n", "R_star", "gap_aware_pct",
               "gap_blind_pct", "ci_aware_pct", "ci_blind_pct",
               "delta_pct", "ci_delta_pct", "seeds"],
        "\n[heterogeneity] class-aware vs class-blind revenue gap "
        "(paired seeds; delta = blind - aware)"))

    # class-aware must beat class-blind with a paired lower confidence
    # bound clear of zero on at least one mixed instance
    beats = bool(any(r["delta_pct"] - r["ci_delta_pct"] > 0.0
                     for r in rows))
    budget = max([control["budget_exhausted"]]
                 + [r["budget_exhausted"] for r in rows])
    if not quick:
        assert beats, rows
        assert control["degenerate_exact"], control
        assert control["matches_committed"] in (None, True), control
        # per-row dominance only where transfer costs bite; the
        # xfer_scale == 0 boundary row legitimately favours the pooled
        # blind gate (see module docstring)
        assert all(r["gap_blind_pct"] >= r["gap_aware_pct"]
                   - NOISE_FLOOR_PCT for r in rows
                   if r["xfer_scale"] > 0.0), rows
        assert budget == 0.0, rows

    out = {
        "rows": rows,
        "control": control,
        "aware_beats_blind": beats,
        "degenerate_exact": control["degenerate_exact"],
        "lambda_per_server": LAMBDA_PER_SERVER,
        "noise_floor_pct": NOISE_FLOOR_PCT,
        "budget_exhausted": budget,
        "quick": bool(quick),
        "mode": "quick" if quick else "full",
    }
    save("heterogeneity", out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
