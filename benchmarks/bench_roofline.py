"""Roofline benchmark: summarize the dry-run artifacts (EXPERIMENTS.md
section Roofline reads from this).  Requires ``python -m
repro.launch.dryrun`` artifacts under artifacts/dryrun/."""

from __future__ import annotations

from pathlib import Path

from repro.launch.roofline import load_records, render_table, roofline_terms

from .common import save

DRYRUN = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run(quick: bool = True) -> dict:
    recs = load_records(DRYRUN, "pod16x16", strategy="baseline")
    if not recs:
        print("[roofline] no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun first)")
        return {"cells": 0}
    print(render_table(recs))
    ok = [r for r in recs if r.get("ok")]
    dom = {}
    fracs = {}
    for r in ok:
        t = roofline_terms(r)
        dom[t["dominant"]] = dom.get(t["dominant"], 0) + 1
        fracs[f"{r['arch']}|{r['shape']}"] = t["roofline_fraction"]
    out = {
        "cells_ok": len(ok),
        "cells_skipped": sum(1 for r in recs if "skipped" in r),
        "cells_failed": sum(
            1 for r in recs if not r.get("ok") and "skipped" not in r),
        "dominant_histogram": dom,
        "roofline_fractions": fracs,
    }
    print(f"\n[roofline] ok={out['cells_ok']} skip={out['cells_skipped']} "
          f"fail={out['cells_failed']} dominant terms: {dom}")
    save("roofline", out)
    return out


if __name__ == "__main__":
    run(quick=True)
