"""Analytic roofline projection of the iteration-time surfaces, per arch.

Replaces the old dry-run-artifact summarizer (which silently produced an
empty payload unless ``python -m repro.launch.dryrun`` had been run
first) with a fully deterministic closed-form sweep: for every
architecture in the :mod:`repro.configs` registry, project the paper's
affine surfaces from the per-iteration FLOP/byte costs
(:func:`repro.calibration.iteration_costs`) against the v5e hardware
constants, and report which roofline term dominates each regime.

If dry-run artifacts *are* present they are still summarized (the
``dryrun`` section), so the old EXPERIMENTS.md workflow keeps working.
"""

from __future__ import annotations

from pathlib import Path

from repro.calibration import iteration_costs, roofline_tau
from repro.configs import ARCHS, get_config
from repro.launch.mesh import v5e_constants
from repro.launch.roofline import load_records, roofline_terms

from .common import round_vals, save

DRYRUN = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

QUICK_ARCHS = ("qwen2-0.5b", "gemma2-2b", "mamba2-130m")

# representative operating points (aggregate tokens / resident KV)
_MIX = dict(tokens=256 + 16, kv_tokens=1024)  # full chunk + B decodes
_SOLO = dict(tokens=16, kv_tokens=8192)  # decode-only, heavy KV


def _surface(cfg) -> dict:
    """Two-point affine projection of tau_mix(C) and tau_solo(K)."""
    b = 16
    t0 = roofline_tau(cfg, tokens=b, kv_tokens=1024)
    t1 = roofline_tau(cfg, tokens=512 + b, kv_tokens=1024)
    beta = (t1 - t0) / 512.0
    alpha = t0 - beta * 0.0  # t0 is already the C=0 intercept at K=1024
    s0 = roofline_tau(cfg, tokens=b, kv_tokens=0)
    s1 = roofline_tau(cfg, tokens=b, kv_tokens=8192)
    b_s = (s1 - s0) / 8192.0
    return {"alpha": alpha, "beta": beta, "a_s": s0, "b_s": b_s}


def _dominant(cfg, hw) -> str:
    c = iteration_costs(cfg, **_SOLO)
    t_c = c["flops"] / hw["peak_flops_bf16"]
    t_m = c["bytes"] / hw["hbm_bw"]
    return "compute" if t_c >= t_m else "memory"


def run(quick: bool = True) -> dict:
    hw = v5e_constants()
    archs = QUICK_ARCHS if quick else tuple(sorted(ARCHS))
    per_arch = {}
    dom = {}
    for arch in archs:
        cfg = get_config(arch)
        s = _surface(cfg)
        d = _dominant(cfg, hw)
        dom[d] = dom.get(d, 0) + 1
        per_arch[arch] = dict(round_vals(s, 10), decode_bound=d)
        print(f"[roofline] {arch:18s} alpha={s['alpha']:.4g} "
              f"beta={s['beta']:.3g} a_s={s['a_s']:.4g} "
              f"b_s={s['b_s']:.3g} decode={d}-bound")

    out = {
        "archs": per_arch,
        "dominant_histogram": dom,
        "hw": {k: float(v) for k, v in hw.items()},
    }

    # legacy: summarize compiled dry-run artifacts when they exist
    recs = load_records(DRYRUN, "pod16x16", strategy="baseline")
    ok = [r for r in recs if r.get("ok")]
    if ok:
        out["dryrun"] = {
            "cells_ok": len(ok),
            "roofline_fractions": {
                f"{r['arch']}|{r['shape']}":
                    roofline_terms(r)["roofline_fraction"] for r in ok},
        }

    save("roofline", out)
    print(f"[roofline] {len(per_arch)} archs; decode dominant terms: {dom}")
    return out


if __name__ == "__main__":
    run(quick=True)
