"""Many-GPU optimality-gap study: the paper's vanishing-gap claim.

The headline theory (Theorems 2-3) says the gate-and-route family is
*asymptotically optimal*: the per-server revenue gap between the
engine-achieved rate and the fluid/LP optimum R* vanishes as the cluster
grows, at the O(1/sqrt(n)) CLT scale.  This benchmark measures exactly
that curve on an **overloaded** two-class instance (the EC.8.5 classes
at lambda = 1.0 each, where R* is capacity-limited below the offered
reward, so the gap is a real control gap rather than underload slack),
under BOTH pricing schemes:

* ``bundled``  -- gate-and-route judged against the Eq. (40) optimum;
* ``separate`` -- the same plan-tracking occupancy gate instantiated
  from the Eq. (42) plan and charged separately
  (``gate_and_route_separate``), judged against the Eq. (42) optimum.
  (The Theorem 2/3 guarantee is for plan-*tracking* policies; the
  Section 5.1 priority-ratio gate is not plan-tracking, and in overload
  its CTMC steady state is not bounded by the Eq. 42 LP's x-coupled
  capacity rows -- it measurably out-earns R*, so it cannot demonstrate
  a *vanishing* gap.)

``n`` sweeps 16 -> 65536 servers (quick mode: toy sizes for CI).  The
engine is the uniformized JAX CTMC (``ctmc_jax`` sweep evaluator): the
aggregate state space is per-class counts, so a 65536-server replication
is just a longer scan, and the seed axis is one batched run --
``placement="shard_map"`` partitions it over the device mesh
(:mod:`repro.sweep.sharded`), bitwise identical to the default vmap.
Each n runs its own paired sweep with a per-n (seeds, horizon, warmup)
schedule matched to its variance: per-server revenue noise shrinks like
1/sqrt(n * window * seeds), so small n carries the replications while
the production sizes (16384 / 65536) trade window for tractable
wall-clock and still resolve far below the CI gate.  Every row reports
``ci_half_width_pct`` (1.96 x the seed-axis standard error of the gap)
and the artifact's ``ci_half_width`` (max over rows, as a fraction) is
gated at <= 0.005 by ``tools/check_bench.py`` -- the statistical
*resolution* gate, separate from the structural noise floor below.
The R* targets come from the serial simplex oracle
(through the sweep's plan cache) AND from the batched ``lp_jax``
planner (:func:`repro.core.planning_batch.solve_plan_batch`); their
agreement is reported in the artifact, tying the planner port to the
headline number.

Monotonicity contract: the seed-averaged ``gap_pct`` must strictly
decrease from each n to the next, except once |gap| is inside
``NOISE_FLOOR_PCT`` -- the measurement's resolution limit, set by two
O(1%) effects that R* does not model: CLT noise of the finite window,
and the aggregate CTMC's *documented* static-partition deviation (mixed
decode capacity is tied to the plan partition M = ceil(n x*), not the
instantaneous prefill occupancy X_t, so realized revenue can sit within
~(B-1) c_d (M/n - X_bar)/tau of either side of R* -- about +-1% here;
the same deviation is why very late measurement windows can show small
*negative* gaps).  Within that band the gap has vanished at the model's
resolution; demanding strict decrease of the residual would be a coin
flip.  Asserted at full size; quick-mode toy grids report but do not
assert.

Artifact: ``artifacts/bench/optimality_gap.json`` (committed, validated
by ``tools/check_bench.py``).  ``budget_exhausted`` is the max over
cells of the fixed-scan-budget indicator and must be 0.
"""

from __future__ import annotations

import numpy as np

from repro.core.planning_batch import solve_plan_batch
from repro.sweep import MixSpec, SweepSpec, run_sweep

from .common import ART, fmt_table, save

# scheme -> (policy token judged against that scheme's optimum)
SCHEMES = {"bundled": "gate_and_route",
           "separate": "gate_and_route_separate"}

# the EC.8.5 classes pushed into overload (decode slots bind; R* < offered)
OVERLOADED_MIX = MixSpec(
    name="two_class_overloaded",
    classes=(
        dict(name="decode-heavy", prompt_len=300, decode_len=1000,
             arrival_rate=1.0, patience=0.1),
        dict(name="prefill-heavy", prompt_len=3000, decode_len=400,
             arrival_rate=1.0, patience=0.1),
    ),
)

# per-n (seeds, horizon, warmup) schedule (full mode).  gap variance
# ~ 1/(n * window * seeds): the small-n rows keep the long window and the
# replications; the production sizes shorten the window (their per-lane
# scan is ~n * horizon events) and still land ci_half_width_pct well
# under the 0.5% gate.  All sweeps run the CTMC in double precision
# (extra["ctmc_jax"]["x64"]): beyond n ~ 16384 the float32 clock's ULP
# exceeds the mean inter-event time, so the clock stalls mid-horizon
# while revenue keeps accruing -- which once inflated the large-n rows
# into impossible *negative* gaps (engine "beating" the fluid optimum
# by 30%).  t_end == horizon and the gap floor below guard against it.
FULL_SCHEDULE = {
    16: (32, 300.0, 75.0),
    64: (16, 300.0, 75.0),
    256: (16, 300.0, 75.0),
    1024: (6, 300.0, 75.0),
    4096: (6, 300.0, 75.0),
    16384: (3, 150.0, 75.0),
    65536: (3, 100.0, 50.0),
}
QUICK_SCHEDULE = {8: (4, 40.0, 10.0), 32: (2, 40.0, 10.0)}

NOISE_FLOOR_PCT = 1.0  # |gap| below this is "vanished" (see docstring)
CI_HALF_WIDTH_MAX = 0.005  # resolution gate: max row 1.96*se, fractional


def _monotone(gaps) -> bool:
    ok = True
    for a, b in zip(gaps, gaps[1:]):
        ok &= (b < a) or (abs(a) <= NOISE_FLOOR_PCT
                          and abs(b) <= NOISE_FLOOR_PCT)
    return bool(ok)


def run(quick: bool = True, placement: str = None) -> dict:
    schedule = QUICK_SCHEDULE if quick else FULL_SCHEDULE
    ns = tuple(sorted(schedule))
    mix = OVERLOADED_MIX
    # paired scheme axis (EC.8.6); x64 keeps the event clock exact at
    # production n (see the schedule comment above)
    extra = {"crn_policies": True, "ctmc_jax": {"x64": True}}
    if placement:
        extra["placement"] = placement

    rows_by_cell = {}
    budget_exhausted = 0.0
    sweep_artifacts = []
    shard_devices = None
    for ni, n in enumerate(ns):
        n_seeds, horizon, warmup = schedule[n]
        spec = SweepSpec(
            name=f"optimality_gap_n{n}", evaluator="ctmc_jax",
            policies=tuple(SCHEMES.values()),
            n_servers=(n,), n_seeds=n_seeds, seed=ni, mixes=(mix,),
            horizon=horizon, warmup=warmup, extra=extra)
        res = run_sweep(spec, progress=lambda m: print(m, flush=True))
        shard_devices = res.meta.get("shard_devices", shard_devices)
        sweep_artifacts.append(
            str(res.save(ART.parent / "sweep" / f"{spec.name}.json")))
        for scheme, token in SCHEMES.items():
            sel = res.select(policy=token, n=n)
            gaps = np.array([c.metrics["gap_pct"] for c in sel])
            t_short = max(float(horizon - c.metrics["t_end"]) for c in sel)
            budget_exhausted = max(budget_exhausted, float(t_short > 1e-9))
            se = float(gaps.std() / np.sqrt(len(gaps)))
            rows_by_cell[(scheme, n)] = {
                "scheme": scheme, "policy": token, "n": n,
                "rev_per_server": round(float(np.mean(
                    [c.metrics["revenue_rate"] for c in sel])), 3),
                "R_star": round(float(sel[0].metrics["R_star"]), 3),
                "gap_pct": round(float(gaps.mean()), 4),
                "gap_se": round(se, 4),
                "ci_half_width_pct": round(1.96 * se, 4),
                "seeds": len(sel),
                "horizon": horizon,
            }

    # R* from the batched interior-point planner, next to the simplex
    # R_star the cells carry -- one batch over both objectives.
    classes = mix.workload_classes()
    agreement = 0.0
    for scheme, objective in (("bundled", "bundled"),
                              ("separate", "separate")):
        pb = solve_plan_batch([classes], objective=objective,
                              prims=[mix.primitives()],
                              pricings=[mix.price()])
        assert bool(pb.converged.all()), f"lp_jax planner diverged: {scheme}"
        r_jax = float(pb.revenue_rate[0])
        for n in ns:
            row = rows_by_cell[(scheme, n)]
            row["R_star_lp_jax"] = round(r_jax, 3)
            agreement = max(agreement, abs(row["R_star"] - r_jax)
                            / (1.0 + abs(row["R_star"])))

    rows = [rows_by_cell[(scheme, n)] for scheme in SCHEMES for n in ns]
    print(fmt_table(rows, ["scheme", "n", "rev_per_server", "R_star",
                           "gap_pct", "gap_se", "ci_half_width_pct",
                           "seeds", "horizon"],
                    f"\n[optimality_gap] per-server revenue gap vs n "
                    f"(per-n schedule: {schedule})"))

    monotone = {}
    for scheme in SCHEMES:
        gaps = [rows_by_cell[(scheme, n)]["gap_pct"] for n in ns]
        monotone[scheme] = _monotone(gaps)
        shrink = gaps[0] / max(abs(gaps[-1]), NOISE_FLOOR_PCT)
        print(f"[optimality_gap] {scheme:9s}: gap {gaps[0]:.3f}% @ "
              f"n={ns[0]} -> {gaps[-1]:.3f}% @ n={ns[-1]} "
              f"({'monotone' if monotone[scheme] else 'NOT monotone'}, "
              f">= {shrink:.1f}x shrink)")
    ci_half_width = max(r["ci_half_width_pct"] for r in rows) / 100.0
    if not quick:
        assert monotone["bundled"] and monotone["separate"], rows
        assert ci_half_width <= CI_HALF_WIDTH_MAX, rows
        # a measured gap below -noise_floor means the engine "beat" the
        # fluid optimum -- always a measurement artifact (the float32
        # clock stall produced exactly this), never physics
        assert all(r["gap_pct"] >= -NOISE_FLOOR_PCT for r in rows), rows
        assert budget_exhausted == 0.0, rows
    print(f"[optimality_gap] simplex vs lp_jax R* agreement: "
          f"{agreement:.2e} relative; max CI half-width "
          f"{100 * ci_half_width:.3f}% (gate {100 * CI_HALF_WIDTH_MAX}%)")

    out = {
        "rows": rows,
        "ns": list(ns),
        "schedule": {str(n): list(schedule[n]) for n in ns},
        "seeds_by_n": {str(n): schedule[n][0] for n in ns},
        "noise_floor_pct": NOISE_FLOOR_PCT,
        "ci_half_width": ci_half_width,
        "gap_monotone_bundled": monotone["bundled"],
        "gap_monotone_separate": monotone["separate"],
        "r_star_agreement_rel": agreement,
        "budget_exhausted": budget_exhausted,
        "placement": placement or "vmap",
        "shard_devices": shard_devices,
        "quick": bool(quick),
        "sweep_artifacts": sweep_artifacts,
    }
    save("optimality_gap", out)
    return out


if __name__ == "__main__":
    import argparse

    from repro.sweep.sharded import PLACEMENTS

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--placement", default=None, choices=PLACEMENTS)
    args = ap.parse_args()
    run(quick=not args.full, placement=args.placement)
