"""Paper Fig. 5: TPOT-revenue operating frontier.

Sweeps the TPOT cap eta_3 in the SLI-aware planning LP inside the same
online gate-and-route controller on the trace replay; the no-SLI point is
the benchmark star.  Moving left lowers TPOT at a revenue cost.
"""

from __future__ import annotations

from repro.core.planning import SLISpec
from repro.workloads import get_scenario

from .bench_trace_replay import COMPRESSION
from .common import PRIM, fmt_table, round_vals, run_trace_policy, save


def run(quick: bool = True) -> dict:
    scn = get_scenario("azure_2023")
    trace = scn.generate(compression=COMPRESSION)
    horizon = scn.horizon
    n = 10
    tau, gamma, B = PRIM.tau_mix, PRIM.gamma, PRIM.batch_cap
    lo = 1.0 / gamma            # solo-decode bound (paper: ~0.0089s)
    hi = tau                    # all-mixed pace
    caps = [None] + [round(lo + f * (hi - lo), 4)
                     for f in ((0.15, 0.4, 0.7) if quick
                               else (0.1, 0.2, 0.35, 0.5, 0.7, 0.9))]
    rows = []
    for cap in caps:
        sli = SLISpec(tpot_cap=cap) if cap is not None else None
        s = run_trace_policy("gate_and_route", trace, n, sli=sli,
                             horizon=horizon)
        rows.append(dict(round_vals(s), eta3=cap if cap else "none"))
    print(fmt_table(rows, ["eta3", "revenue_rate", "tpot_mean", "tpot_p95",
                           "completion_rate"],
                    "\n[frontier] TPOT cap sweep (online gate-and-route)"))
    out = {"rows": rows, "tpot_floor": lo}
    save("frontier", out)
    return out


if __name__ == "__main__":
    run(quick=True)
