"""Micro-benchmark: events/sec of the JAX trace-replay engine hot path.

Five legs over the same decode-heavy saturated workload (the regime the
fast-forward kernel is built for -- admission-blocked servers let one
scan step retire a whole batch of events):

* ``python``     -- :class:`repro.serving.engine_sim.ClusterEngine`,
  serial event loop, iterations/sec (the historical baseline metric).
* ``legacy``     -- :class:`ClusterEngineJAX` with ``fastforward=False,
  k_events=1``: the pre-hot-path one-event-per-step scan.
* ``hot``        -- ``fastforward=True``: the multi-event stepping
  kernel (see the engine docstring's *multi-event blocks* section).
* ``hot+tlm``    -- the hot leg again with time-binned telemetry probes
  ON (:mod:`repro.telemetry.probes`); its events/sec regression vs the
  bare hot leg is the probes' measured overhead, gated < 10% by
  ``tools/check_bench.py`` (the docs/OBSERVABILITY.md contract).
* ``stream``     -- :class:`repro.serving.engine_stream.StreamingEngineJAX`
  fed by an on-device :class:`repro.workloads.batch.ScenarioStream`:
  fixed working set, unbounded trace.  In ``--full`` mode this leg
  replays >= 1e6 requests -- the run the host-padded engine cannot
  size (its tables would hold every request at once).

All legs are timed with :func:`repro.telemetry.timing.timeit_median`
(warmup + median-of-reps; the warmup also discards jit compilation), and
jax legs report **events/sec** (arrivals + iteration completions), the
engine's native unit of progress.  ``speedup`` keeps its historical
meaning (jax vs python, iterations/sec); the hot-path gate is
``speedup_hot`` = hot/legacy events/sec, asserted >= 5 (full) / >= 3
(quick, CI-noise headroom) by ``tools/check_bench.py``.

Artifact: ``artifacts/bench/engine_speed.json`` (committed; regenerate
with ``PYTHONPATH=src python -m benchmarks.run --full --only
engine_speed``).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.types import WorkloadClass
from repro.data.traces import TraceConfig, synth_azure_trace
from repro.serving.engine_jax import ClusterEngineJAX, run as engine_run
from repro.serving.engine_sim import ClusterEngine, EngineConfig
from repro.serving.engine_stream import StreamingEngineJAX
from repro.workloads import get_scenario
from repro.workloads.batch import ScenarioStream

from .common import PRICING, PRIM, fmt_table, save, timeit_median

REPS = 32      # jax replication batch (vmapped)
REPS_PY = 8    # python serial replications (rates, not totals, compare)

# decode-heavy mix at compression 0.02: the cluster saturates, admission
# blocks, and fast-forward batches whole arrival bursts per scan step
CLASSES = [WorkloadClass("chat", 512, 768, 0.2),
           WorkloadClass("agent", 1024, 1024, 0.1)]


def _workload(quick: bool):
    horizon = 15.0 if quick else 60.0
    trace = synth_azure_trace(TraceConfig(horizon=horizon, base_rate=2.0,
                                          compression=0.02, seed=11))
    return horizon, trace


def _events(raw) -> float:
    return float(np.asarray(raw["n_events"]).sum())


def run(quick: bool = True) -> dict:
    import jax

    n = 10
    horizon, trace = _workload(quick)
    plan = solve_bundled_lp(CLASSES, PRIM, PRICING)
    policy = gate_and_route(plan)
    seeds = list(range(REPS))
    warmup, reps = (1, 3) if quick else (2, 5)

    # -- Python event loop (serial; iterations/sec baseline) --------------
    def py_leg():
        it = ev = 0
        rev = []
        for r in range(REPS_PY):
            eng = ClusterEngine(CLASSES, policy,
                                EngineConfig(PRIM, PRICING, n, seed=r))
            m = eng.run(trace, horizon=horizon)
            it += m.n_iters
            rev.append(m.revenue_rate())
        py_leg.iters, py_leg.rev = it, float(np.mean(rev))

    wall_py = timeit_median(py_leg, warmup=0, reps=1 if quick else 3)
    ips_py = py_leg.iters / wall_py

    # -- JAX legs: legacy (pre-hot-path) vs hot (fast-forward) ------------
    legs = {}
    for tag, kw in (("legacy", dict(fastforward=False)),
                    ("hot", dict(fastforward=True))):
        eng = ClusterEngineJAX(CLASSES, policy,
                               EngineConfig(PRIM, PRICING, n), trace,
                               horizon=horizon, **kw)

        def leg(eng=eng):
            # through the unified facade, exactly as the sweep evaluator
            leg.raw = engine_run(eng.params, [eng._key(s) for s in seeds],
                                 placement="vmap", **eng.statics)
            jax.block_until_ready(leg.raw)

        wall = timeit_median(leg, warmup=warmup, reps=reps)
        raw = leg.raw
        ev = _events(raw)
        sums = eng.summaries_from_raw(raw)
        legs[tag] = {
            "wall_s": wall, "events": ev,
            "events_per_sec": ev / wall,
            "iters": float(np.asarray(raw["n_iters"]).sum()),
            "ev_per_step": ev / max(float(np.asarray(raw["n_loop"]).sum()),
                                    1.0),
            "rev_rate": float(np.mean([m["revenue_rate"] for m in sums])),
            "budget_exhausted": float(max(m["budget_exhausted"]
                                          for m in sums)),
        }
    ips_jx = legs["hot"]["iters"] / legs["hot"]["wall_s"]

    # -- telemetry overhead: the hot leg again with probes ON -------------
    # the observability contract (docs/OBSERVABILITY.md) bounds the
    # probes-on events/sec regression at < 10%; check_bench gates it
    t_eng = ClusterEngineJAX(CLASSES, policy,
                             EngineConfig(PRIM, PRICING, n), trace,
                             horizon=horizon, fastforward=True,
                             telemetry=True)

    def tlm_leg():
        tlm_leg.raw = engine_run(t_eng.params,
                                 [t_eng._key(s) for s in seeds],
                                 placement="vmap", **t_eng.statics)
        jax.block_until_ready(tlm_leg.raw)

    wall_tlm = timeit_median(tlm_leg, warmup=warmup, reps=reps)
    ev_tlm = _events(tlm_leg.raw)
    eps_tlm = ev_tlm / wall_tlm
    tlm_overhead_pct = 100.0 * (1.0 - eps_tlm
                                / legs["hot"]["events_per_sec"])
    legs["hot_telemetry"] = {
        "wall_s": wall_tlm, "events": ev_tlm, "events_per_sec": eps_tlm,
        "overhead_pct": tlm_overhead_pct,
    }

    # -- streamed leg: on-device trace generation, fixed working set ------
    # quick replays the scenario's nominal horizon; full stretches it
    # until the stream exceeds one million requests
    sc = get_scenario("azure_2023")
    # with infinite patience nothing ever leaves the queue unserved, so
    # the stream only has a bounded working set if offered load sits
    # clearly below the cluster's achieved throughput (~0.6 req/s per
    # server for this plan; at rate_scale >= 14 the backlog grows
    # linearly and overflows ANY window given enough horizon).  rate 10
    # keeps utilization ~0.85 and the occupancy trace flat; the longer
    # full horizon is what carries the run past 1e6 requests.
    s_horizon, s_rate = (300.0, 20.0) if quick else (44000.0, 10.0)
    s_n = 48
    s_window = 8192 if quick else 16384
    # declare per-server class rates matching the stream's offered load
    # (measured ~2.44 req/s at rate_scale 1) so the plan's admission
    # gate is sized for what actually arrives, not a placeholder
    lam = 2.44 * s_rate / s_n
    s_classes = [WorkloadClass(p.name, int(p.mean_prompt),
                               int(p.mean_decode), lam * p.share)
                 for p in sc.profiles]
    s_plan = solve_bundled_lp(s_classes, PRIM, PRICING)
    s_eng = StreamingEngineJAX(s_classes, gate_and_route(s_plan),
                               EngineConfig(PRIM, PRICING, s_n),
                               horizon=s_horizon, window=s_window)
    t0 = time.perf_counter()
    s = s_eng.run_stream(ScenarioStream(sc, seed=3, chunk_size=2048,
                                        horizon=s_horizon,
                                        rate_scale=s_rate), seed=0)
    s_wall = time.perf_counter() - t0
    stream = {
        "requests": int(s["requests"]), "wall_s": s_wall,
        "n_servers": s_n, "horizon": s_horizon, "rate_scale": s_rate,
        "events_per_sec": float(s["n_events"]) / s_wall,
        "completions": int(s["completions"]),
        "n_segments": int(s["n_segments"]),
        "window": s_eng.window, "window_peak": int(s["window_peak"]),
        "budget_exhausted": float(s["budget_exhausted"]),
    }

    rows = [{"leg": "python", "wall_s": round(wall_py, 2),
             "events_per_sec": "-", "ev_per_step": "-",
             "rate": round(ips_py)}]
    for tag in ("legacy", "hot"):
        rows.append({"leg": tag, "wall_s": round(legs[tag]["wall_s"], 2),
                     "events_per_sec": round(legs[tag]["events_per_sec"]),
                     "ev_per_step": round(legs[tag]["ev_per_step"], 1),
                     "rate": round(legs[tag]["iters"]
                                   / legs[tag]["wall_s"])})
    rows.append({"leg": "hot+tlm", "wall_s": round(wall_tlm, 2),
                 "events_per_sec": round(eps_tlm), "ev_per_step": "-",
                 "rate": f"{tlm_overhead_pct:+.1f}%"})
    rows.append({"leg": "stream", "wall_s": round(s_wall, 2),
                 "events_per_sec": round(stream["events_per_sec"]),
                 "ev_per_step": "-", "rate": stream["requests"]})
    print(fmt_table(rows, ["leg", "wall_s", "events_per_sec",
                           "ev_per_step", "rate"],
                    f"\n[engine_speed] {REPS}-rep batch, n={n}, "
                    f"{len(trace)} requests, horizon={horizon} "
                    f"(rate = iters/s; stream rate = requests replayed)"))
    speedup = ips_jx / ips_py
    speedup_hot = (legs["hot"]["events_per_sec"]
                   / legs["legacy"]["events_per_sec"])
    print(f"[engine_speed] hot-path {speedup_hot:.2f}x events/sec over "
          f"legacy engine_jax; jax {speedup:.1f}x iters/sec over python; "
          f"telemetry overhead {tlm_overhead_pct:+.1f}%; "
          f"streamed {stream['requests']} requests in {s_wall:.1f}s "
          f"(window {stream['window_peak']}/{stream['window']})")
    out = {
        "mode": "quick" if quick else "full",
        "n": n, "reps": REPS, "reps_python": REPS_PY,
        "horizon": horizon, "n_requests": len(trace),
        "iters_python": float(py_leg.iters),
        "iters_jax": legs["hot"]["iters"],
        "wall_python": wall_py, "wall_jax_warm": legs["hot"]["wall_s"],
        "iters_per_sec_python": ips_py, "iters_per_sec_jax": ips_jx,
        "speedup": speedup,
        "events_per_sec_legacy": legs["legacy"]["events_per_sec"],
        "events_per_sec_hot": legs["hot"]["events_per_sec"],
        "events_per_sec_hot_telemetry": eps_tlm,
        "telemetry_overhead_pct": tlm_overhead_pct,
        "speedup_hot": speedup_hot,
        "legs": legs, "stream": stream,
        "rev_rate_python": py_leg.rev,
        "rev_rate_jax": legs["hot"]["rev_rate"],
        "rev_rate_rel_gap": (abs(py_leg.rev - legs["hot"]["rev_rate"])
                             / max(py_leg.rev, 1e-12)),
        "budget_exhausted": float(max(legs["legacy"]["budget_exhausted"],
                                      legs["hot"]["budget_exhausted"],
                                      stream["budget_exhausted"])),
    }
    save("engine_speed", out)
    return out


if __name__ == "__main__":
    run(quick=True)
