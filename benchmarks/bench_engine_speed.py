"""Micro-benchmark: iterations/sec of the JAX trace-replay engine vs the
Python event loop.

Replays the same Azure-like trace 32 times (one replication per PRNG
seed) through :class:`repro.serving.engine_sim.ClusterEngine` (serial
Python loop) and :class:`repro.serving.engine_jax.ClusterEngineJAX` (one
``jax.vmap`` batch) under online-free gate-and-route, and reports
simulated server *iterations* per wall-second for each.  The JAX engine
is timed twice -- once cold (including jit compilation) and once warm --
and the headline ``speedup`` uses the warm number, the steady-state
throughput a sweep sees after its first cell.  Revenue rates are
cross-checked (same trace, same policy, near-identical trajectories), so
the speedup is apples to apples.

Artifact: ``artifacts/bench/engine_speed.json`` with per-engine
iterations/sec, the warm/cold walls, the scan budget, and the agreement
gap.  Acceptance bar for the repo: ``speedup >= 10`` at the
32-replication batch.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.serving.engine_jax import ClusterEngineJAX
from repro.serving.engine_sim import ClusterEngine, EngineConfig
from repro.sweep.evaluators import planner_classes_from_trace
from repro.workloads import get_scenario

from .common import PRICING, PRIM, fmt_table, save

REPS = 32


def run(quick: bool = True) -> dict:
    import jax

    n = 10
    # the registry's Azure 2023 marginals at bench sizing
    horizon, compression = (30.0, 0.06) if quick else (90.0, 0.05)
    trace = get_scenario("azure_2023").generate(
        seed=42, horizon=horizon, compression=compression)
    classes = planner_classes_from_trace(trace, n)
    plan = solve_bundled_lp(classes, PRIM, PRICING)
    policy = gate_and_route(plan)

    # -- Python event loop (one fresh engine per replication, serial) -----
    t0 = time.perf_counter()
    it_py = 0
    res_py = []
    for r in range(REPS):
        eng = ClusterEngine(classes, policy,
                            EngineConfig(PRIM, PRICING, n, seed=r))
        m = eng.run(trace, horizon=horizon)
        it_py += m.n_iters
        res_py.append(m.revenue_rate())
    wall_py = time.perf_counter() - t0

    # -- JAX engine (one vmapped scan over the replication batch) ---------
    jeng = ClusterEngineJAX(classes, policy,
                            EngineConfig(PRIM, PRICING, n), trace,
                            horizon=horizon)
    seeds = list(range(REPS))
    t0 = time.perf_counter()
    jax.block_until_ready(jeng.run_batch_raw(seeds))
    wall_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    raw = jeng.run_batch_raw([s + REPS for s in seeds])
    jax.block_until_ready(raw)
    wall_jx = time.perf_counter() - t0
    res_jx = jeng.summaries_from_raw(raw)
    it_jx = float(np.asarray(raw["n_iters"]).sum())

    rev_py = float(np.mean(res_py))
    rev_jx = float(np.mean([m["revenue_rate"] for m in res_jx]))
    ips_py = it_py / wall_py
    ips_jx = it_jx / wall_jx
    rows = [
        {"engine": "python", "iters": int(it_py),
         "wall_s": round(wall_py, 3), "iters_per_sec": round(ips_py),
         "rev_rate": round(rev_py, 2)},
        {"engine": "engine_jax", "iters": int(it_jx),
         "wall_s": round(wall_jx, 3), "iters_per_sec": round(ips_jx),
         "rev_rate": round(rev_jx, 2)},
    ]
    print(fmt_table(rows, ["engine", "iters", "wall_s", "iters_per_sec",
                           "rev_rate"],
                    f"\n[engine_speed] {REPS}-replication batch, n={n}, "
                    f"{len(trace)} requests, horizon={horizon}"))
    speedup = ips_jx / ips_py
    print(f"[engine_speed] speedup {speedup:.1f}x "
          f"(compile {wall_cold - wall_jx:.1f}s amortised)")
    out = {
        "n": n, "reps": REPS, "horizon": horizon,
        "n_requests": len(trace),
        "iters_python": float(it_py), "iters_jax": it_jx,
        "wall_python": wall_py, "wall_jax_warm": wall_jx,
        "wall_jax_cold": wall_cold,
        "iters_per_sec_python": ips_py, "iters_per_sec_jax": ips_jx,
        "speedup": speedup,
        "n_steps_jax": jeng.n_steps,
        "rev_rate_python": rev_py, "rev_rate_jax": rev_jx,
        "rev_rate_rel_gap": abs(rev_py - rev_jx) / max(rev_py, 1e-12),
        "budget_exhausted": float(max(m["budget_exhausted"]
                                      for m in res_jx)),
    }
    save("engine_speed", out)
    return out


if __name__ == "__main__":
    run(quick=True)
