"""Paper EC.8.4: effect of finer workload classification.

The native trace labels are imperfect class definitions; k-means on
(log P, log D) refines the 'conversation' class into k subclasses, the
scheduler is given the refined labels, and the planning LP gets more
accurate class-level summaries.  The paper finds revenue increases with k.
"""

from __future__ import annotations

import numpy as np

from repro.data.traces import Request
from repro.workloads import get_scenario

from .common import fmt_table, run_trace_policy, save

# The paper's premise (Fig EC.4): the native 'conversation' label mixes
# requests with materially different prefill/decode profiles.  The
# three-latent-profile generator is the registry's `conv_latent`
# scenario; the scheduler only sees the coarse native label (code vs
# conversation) and k-means refinement should recover the latent split.
# With this mixture the *fluid optimum itself* improves ~15% when the
# planner sees the latent split (the blurred conv mean hides that analysis
# is decode-cheap), so refinement has genuine planning value -- the paper's
# EC.8.4 regime.
LATENT_SCENARIO = "conv_latent"
COMPRESSION = 0.03


def _kmeans(X, k, iters=30, seed=0):
    rng = np.random.default_rng(seed)
    centers = X[rng.choice(len(X), k, replace=False)]
    for _ in range(iters):
        d = ((X[:, None] - centers[None]) ** 2).sum(-1)
        a = d.argmin(1)
        for j in range(k):
            if (a == j).any():
                centers[j] = X[a == j].mean(0)
    return a


def refine_conversation(trace, k, seed=0):
    """Split class 1 ('conversation') into k subclasses via k-means."""
    conv = [r for r in trace if r.cls == 1]
    X = np.log(np.array([[r.prompt_len, r.decode_len] for r in conv],
                        dtype=float))
    assign = _kmeans(X, k, seed=seed)
    out = []
    it = iter(assign)
    for r in trace:
        cls = 0 if r.cls == 0 else 1 + int(next(it))
        out.append(Request(r.rid, r.t_arrival, cls, r.prompt_len,
                           r.decode_len, r.patience))
    return out


def run(quick: bool = True) -> dict:
    scn = get_scenario(LATENT_SCENARIO)
    latent = scn.generate(compression=COMPRESSION)
    # native coarse labels: both conv profiles -> class 1
    trace = [Request(r.rid, r.t_arrival, min(r.cls, 1), r.prompt_len,
                     r.decode_len, r.patience) for r in latent]
    n = 10
    rows = []
    ks = [1, 2, 3] if quick else [1, 2, 3, 4]
    for k in ks:
        tr = trace if k == 1 else refine_conversation(trace, k)
        n_classes = 1 + k
        # safety rho=1.5: the paper's rho=3 rate inflation distorts the
        # admission mix under saturation once classes are fine-grained
        # (a ~25% revenue hit at k=2 when first measured, on the
        # pre-registry trace realization) -- a finite-n finding about
        # the online controller.
        s = run_trace_policy("gate_and_route", tr, n,
                             horizon=scn.horizon, safety=1.5)
        rows.append({"conv_subclasses": k,
                     "n_classes": n_classes,
                     "revenue_rate": round(s["revenue_rate"], 1),
                     "completion": round(s["completion_rate"], 4)})
    print(fmt_table(rows, ["conv_subclasses", "n_classes", "revenue_rate",
                           "completion"],
                    "\n[classes] EC.8.4 finer workload classification"))
    out = {"rows": rows,
           "refinement_helps":
               max(r["revenue_rate"] for r in rows[1:])
               > rows[0]["revenue_rate"]}
    save("classes", out)
    return out


if __name__ == "__main__":
    run(quick=True)
