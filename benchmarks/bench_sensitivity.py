"""Paper Figs. 7-8: sensitivity to (B, alpha, beta, gamma) and the
pricing-ratio invariance heatmap.

All curves are planning-LP sweeps (the paper's own methodology for Fig. 7):
revenue = optimal LP value, TPOT = Eq. (47) at the optimum.  Fig. 8b checks
that argmax_{c_p+c_d=k} revenue keeps a constant c_p/c_d ratio across k.
"""

from __future__ import annotations

import numpy as np

from repro.core.planning import solve_bundled_lp, tpot_of_plan
from repro.core.types import Pricing, ServicePrimitives

from .bench_sli_pareto import CLASSES
from .common import save


def _solve(prim, pricing=Pricing(0.1, 0.2)):
    plan = solve_bundled_lp(CLASSES, prim, pricing)
    return float(plan.revenue_rate), float(tpot_of_plan(plan))


def run(quick: bool = True) -> dict:
    base = dict(alpha=0.0174, beta=6.2e-5, gamma=1 / 0.0089, batch_cap=16,
                chunk=256)
    out: dict = {}

    sweeps = {
        "B": [4, 8, 16, 24, 32] if not quick else [4, 8, 16, 32],
        "alpha": list(np.linspace(0.02, 0.15, 4 if quick else 8)),
        "beta": list(np.geomspace(1e-5, 1e-3, 4 if quick else 8)),
        "gamma": list(np.linspace(10, 50, 4 if quick else 8)),
    }
    for key, vals in sweeps.items():
        rows = []
        for v in vals:
            kw = dict(base)
            if key == "B":
                kw["batch_cap"] = int(v)
            else:
                kw[key] = float(v)
            rev, tpot = _solve(ServicePrimitives(**kw))
            rows.append({"value": float(v), "revenue": rev, "tpot": tpot})
        out[key] = rows
        trend = "+" if rows[-1]["revenue"] >= rows[0]["revenue"] else "-"
        print(f"[sensitivity] {key}: revenue {rows[0]['revenue']:.1f} -> "
              f"{rows[-1]['revenue']:.1f} ({trend})")

    # revenue landscape over (B, beta) -- Fig 8a
    grid = []
    Bs = [4, 8, 16, 32]
    betas = list(np.geomspace(1e-5, 5e-4, 4))
    for Bv in Bs:
        for bv in betas:
            kw = dict(base, batch_cap=Bv, beta=bv)
            rev, _ = _solve(ServicePrimitives(**kw))
            grid.append({"B": Bv, "beta": bv, "revenue": rev})
    out["landscape"] = grid

    # pricing-ratio invariance -- Fig 8b
    ratios = []
    for k in ([0.3, 0.6, 1.2] if quick else [0.15, 0.3, 0.6, 1.2, 2.4]):
        best = None
        for f in np.linspace(0.05, 0.95, 19):
            rev, _ = _solve(ServicePrimitives(**base),
                            Pricing(c_p=f * k, c_d=(1 - f) * k))
            if best is None or rev > best[1]:
                best = (f, rev)
        ratios.append({"k": k, "cp_share": best[0],
                       "cp_over_cd": best[0] / (1 - best[0])})
    out["pricing_ratio"] = ratios
    spread = max(r["cp_over_cd"] for r in ratios) - min(
        r["cp_over_cd"] for r in ratios)
    out["pricing_ratio_spread"] = spread
    print(f"[sensitivity] optimal c_p/c_d across budgets: "
          f"{[round(r['cp_over_cd'], 3) for r in ratios]} "
          f"(spread {spread:.4f} -> scale-invariant)")
    save("sensitivity", out)
    return out


if __name__ == "__main__":
    run(quick=True)
