"""Paper Figs. 7-8: sensitivity to (B, alpha, beta, gamma) and the
pricing-ratio invariance heatmap.

All curves are planning-LP sweeps (the paper's own methodology for Fig. 7):
revenue = optimal LP value, TPOT = Eq. (47) at the optimum.  Fig. 8b checks
that argmax_{c_p+c_d=k} revenue keeps a constant c_p/c_d ratio across k.

Every parameter point is one workload mix of an "lp"-evaluator sweep
(:mod:`repro.sweep`); the LP is deterministic, so the sweep cells equal
the former serial loop's solves exactly.
"""

from __future__ import annotations

import numpy as np

from repro.sweep import MixSpec, SweepSpec, run_sweep
from repro.sweep.run import default_mix

from .common import ART, save

BASE_PRIM = dict(alpha=0.0174, beta=6.2e-5, gamma=1 / 0.0089, batch_cap=16,
                 chunk=256)
CLASS_DICTS = default_mix("two_class").classes


def _lp_sweep(name: str, mixes) -> dict:
    """Run an LP sweep; returns {mix name: metrics} preserving mix order."""
    spec = SweepSpec(name=name, evaluator="lp", policies=("lp",),
                     n_servers=(1,), n_seeds=1, seed=0, mixes=tuple(mixes))
    res = run_sweep(spec)
    res.save(ART.parent / "sweep" / f"{name}.json")
    return {m.name: res.select(mix=m.name)[0].metrics for m in spec.mixes}


def _mix(name: str, prim: dict, pricing: dict = None) -> MixSpec:
    return MixSpec(name=name, classes=CLASS_DICTS, prim=prim,
                   pricing=pricing or {})


def run(quick: bool = True) -> dict:
    out: dict = {}

    sweeps = {
        "B": [4, 8, 16, 24, 32] if not quick else [4, 8, 16, 32],
        "alpha": list(np.linspace(0.02, 0.15, 4 if quick else 8)),
        "beta": list(np.geomspace(1e-5, 1e-3, 4 if quick else 8)),
        "gamma": list(np.linspace(10, 50, 4 if quick else 8)),
    }
    for key, vals in sweeps.items():
        mixes = []
        for v in vals:
            kw = dict(BASE_PRIM)
            if key == "B":
                kw["batch_cap"] = int(v)
            else:
                kw[key] = float(v)
            mixes.append(_mix(f"{key}={float(v):.6g}", kw))
        cells = _lp_sweep(f"sensitivity_{key}", mixes)
        rows = [{"value": float(v), "revenue": m["revenue"],
                 "tpot": m["tpot"]}
                for v, m in zip(vals, cells.values())]
        out[key] = rows
        trend = "+" if rows[-1]["revenue"] >= rows[0]["revenue"] else "-"
        print(f"[sensitivity] {key}: revenue {rows[0]['revenue']:.1f} -> "
              f"{rows[-1]['revenue']:.1f} ({trend})")

    # revenue landscape over (B, beta) -- Fig 8a
    Bs = [4, 8, 16, 32]
    betas = list(np.geomspace(1e-5, 5e-4, 4))
    mixes = [_mix(f"B={Bv}_beta={bv:.6g}",
                  dict(BASE_PRIM, batch_cap=Bv, beta=bv))
             for Bv in Bs for bv in betas]
    cells = _lp_sweep("sensitivity_landscape", mixes)
    grid = [{"B": Bv, "beta": bv, "revenue": m["revenue"]}
            for (Bv, bv), m in zip(((B, b) for B in Bs for b in betas),
                                   cells.values())]
    out["landscape"] = grid

    # pricing-ratio invariance -- Fig 8b
    ks = [0.3, 0.6, 1.2] if quick else [0.15, 0.3, 0.6, 1.2, 2.4]
    fs = list(np.linspace(0.05, 0.95, 19))
    mixes = [_mix(f"k={k:g}_f={f:.4f}", dict(BASE_PRIM),
                  pricing=dict(c_p=f * k, c_d=(1 - f) * k))
             for k in ks for f in fs]
    cells = _lp_sweep("sensitivity_pricing", mixes)
    ratios = []
    for k in ks:
        best = max(((f, cells[f"k={k:g}_f={f:.4f}"]["revenue"]) for f in fs),
                   key=lambda t: t[1])
        ratios.append({"k": k, "cp_share": best[0],
                       "cp_over_cd": best[0] / (1 - best[0])})
    out["pricing_ratio"] = ratios
    spread = max(r["cp_over_cd"] for r in ratios) - min(
        r["cp_over_cd"] for r in ratios)
    out["pricing_ratio_spread"] = spread
    print(f"[sensitivity] optimal c_p/c_d across budgets: "
          f"{[round(r['cp_over_cd'], 3) for r in ratios]} "
          f"(spread {spread:.4f} -> scale-invariant)")
    save("sensitivity", out)
    return out


if __name__ == "__main__":
    run(quick=True)
