"""Micro-benchmark: events/sec of the uniformized JAX CTMC vs the Python loop.

Runs the same 64-replication batch (two-class EC.8.5 instance, n = 50,
gate-and-route) through :class:`repro.core.simulator.CTMCSimulator` and
:class:`repro.core.ctmc_jax.UniformizedCTMC` and reports simulated CTMC
transitions per wall-second for each.  The JAX engine is timed twice --
once cold (including jit compilation) and once warm -- and the headline
``speedup`` uses the warm number, which is the steady-state throughput a
sweep sees after its first cell.  Also cross-checks that the two engines
agree on mean revenue rate (same law), so the speedup is apples to
apples.

Artifact: ``artifacts/bench/ctmc_speed.json`` with per-engine events/sec,
the warm/cold walls, the self-loop-free step budget, and the agreement
gap.  Acceptance bar for the repo: ``speedup >= 10`` at the
64-replication batch.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.ctmc_jax import UniformizedCTMC
from repro.core.planning import solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.simulator import CTMCSimulator
from repro.sweep.run import default_mix

from .common import fmt_table, save

REPS = 64


def run(quick: bool = True) -> dict:
    import jax

    n = 50
    horizon, warmup = (30.0, 8.0) if quick else (90.0, 30.0)
    mix = default_mix("two_class")
    classes, prim, pricing = (mix.workload_classes(), mix.primitives(),
                              mix.price())
    policy = gate_and_route(solve_bundled_lp(classes, prim, pricing))

    # -- Python event loop (one simulator, one stream per replication) ----
    sim = CTMCSimulator(classes, prim, pricing, policy, n=n)
    streams = np.random.SeedSequence(0).spawn(REPS)
    t0 = time.perf_counter()
    res_py = sim.run_batch(horizon, warmup=warmup, rngs=streams)
    wall_py = time.perf_counter() - t0
    ev_py = float(sum(r.n_events for r in res_py))

    # -- uniformized JAX engine (one vmapped scan over the batch) ---------
    jsim = UniformizedCTMC(classes, prim, pricing, policy, n=n,
                           horizon=horizon, warmup=warmup)
    seeds = list(range(REPS))
    t0 = time.perf_counter()
    jax.block_until_ready(jsim.run_batch_raw(seeds))
    wall_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    raw = jsim.run_batch_raw([s + REPS for s in seeds])
    jax.block_until_ready(raw)
    wall_jx = time.perf_counter() - t0
    res_jx = jsim.results_from_raw(raw)
    ev_jx = float(np.asarray(raw["n_events"]).sum())

    rev_py = float(np.mean([r.revenue_rate_per_server for r in res_py]))
    rev_jx = float(np.mean([r.revenue_rate_per_server for r in res_jx]))
    eps_py = ev_py / wall_py
    eps_jx = ev_jx / wall_jx
    rows = [
        {"engine": "python", "events": int(ev_py),
         "wall_s": round(wall_py, 3), "events_per_sec": round(eps_py),
         "rev_rate": round(rev_py, 2)},
        {"engine": "ctmc_jax", "events": int(ev_jx),
         "wall_s": round(wall_jx, 3), "events_per_sec": round(eps_jx),
         "rev_rate": round(rev_jx, 2)},
    ]
    print(fmt_table(rows, ["engine", "events", "wall_s", "events_per_sec",
                           "rev_rate"],
                    f"\n[ctmc_speed] {REPS}-replication batch, n={n}, "
                    f"horizon={horizon}"))
    speedup = eps_jx / eps_py
    print(f"[ctmc_speed] speedup {speedup:.1f}x "
          f"(compile {wall_cold - wall_jx:.1f}s amortised)")
    out = {
        "n": n, "reps": REPS, "horizon": horizon, "warmup": warmup,
        "events_python": ev_py, "events_jax": ev_jx,
        "wall_python": wall_py, "wall_jax_warm": wall_jx,
        "wall_jax_cold": wall_cold,
        "events_per_sec_python": eps_py, "events_per_sec_jax": eps_jx,
        "speedup": speedup,
        "n_steps_jax": jsim.n_steps, "stepping": jsim.stepping,
        "Lambda": jsim.Lambda,
        "rev_rate_python": rev_py, "rev_rate_jax": rev_jx,
        "rev_rate_rel_gap": abs(rev_py - rev_jx) / max(rev_py, 1e-12),
        "t_end_ok": bool(np.all(np.asarray(raw["t"]) == horizon)),
    }
    save("ctmc_speed", out)
    return out


if __name__ == "__main__":
    run(quick=True)
