"""Paper EC.8.3: benchmark ranking across cluster scale.

Holds per-server offered load fixed (cluster size x compression constant)
and grows the cluster: (10, c), (20, c/2), (40, c/4).  Checks that the
revenue ranking -- online gate-and-route first -- is stable across scale.

The whole (policy x n) grid runs as one "engine" sweep
(:mod:`repro.sweep`): the mix's ``compression_per_server`` keeps per-server
load constant, and the DistServe comparator's fixed splits are the
``frac=0.2`` / ``frac=0.5`` policy tokens (k = n/5 and n/2, the same two
splits the serial loop used to scan).
"""

from __future__ import annotations

from repro.sweep import MixSpec, SweepSpec, run_sweep

from .common import ART, fmt_table, save

DIRECT = ("gate_and_route", "sarathi", "vllm")
DISTSERVE = ("distserve_mix_solo:frac=0.2", "distserve_mix_solo:frac=0.5")


def run(quick: bool = True) -> dict:
    base_comp = 0.3
    horizon = 240.0
    ns = (10, 20) if quick else (10, 20, 40)
    mix = MixSpec(name="azure",
                  trace=dict(horizon=horizon,
                             compression_per_server=base_comp, seed=42))
    spec = SweepSpec(
        name="scale_sweep", evaluator="engine",
        policies=DIRECT + DISTSERVE, n_servers=ns, n_seeds=1, seed=42,
        mixes=(mix,), horizon=horizon,
        # paired ranking: all policies replay the trace under the same
        # engine streams, as the original shared-seed loop did
        extra={"crn_policies": True})
    res = run_sweep(spec)

    out = {}
    for n in ns:
        rows = []
        for pol in DIRECT:
            (c,) = res.select(policy=pol, n=n)
            rows.append({"policy": pol,
                         "revenue_rate": round(c.metrics["revenue_rate"], 1),
                         "completion": round(c.metrics["completion_rate"], 3),
                         "ttft_mean": round(c.metrics["ttft_mean"], 2)})
        # DistServe comparator: best of the scanned fixed splits
        best = max((c for t in DISTSERVE for c in res.select(policy=t, n=n)),
                   key=lambda c: c.metrics["revenue_rate"])
        rows.append({"policy": "distserve_mix_solo",
                     "revenue_rate": round(best.metrics["revenue_rate"], 1),
                     "completion": round(best.metrics["completion_rate"], 3),
                     "ttft_mean": round(best.metrics["ttft_mean"], 2)})
        rows.sort(key=lambda r: -r["revenue_rate"])
        out[f"n{n}"] = rows
        print(fmt_table(rows, ["policy", "revenue_rate", "completion",
                               "ttft_mean"],
                        f"\n[scale_sweep] n={n} (fixed per-server load)"))
    out["ours_first_everywhere"] = all(
        v[0]["policy"] == "gate_and_route" for v in out.values()
        if isinstance(v, list))
    artifact = res.save(ART.parent / "sweep" / "scale_sweep.json")
    out["sweep_artifact"] = str(artifact)
    save("scale_sweep", out)
    return out


if __name__ == "__main__":
    run(quick=True)
