"""Paper EC.8.3: benchmark ranking across cluster scale.

Holds per-server offered load fixed (cluster size x compression constant)
and grows the cluster: (10, c), (20, c/2), (40, c/4).  Checks that the
revenue ranking -- online gate-and-route first -- is stable across scale.
"""

from __future__ import annotations

from repro.data.traces import TraceConfig, synth_azure_trace

from .common import best_fixed_split, fmt_table, run_trace_policy, save


def run(quick: bool = True) -> dict:
    base_comp = 0.3
    ns = [10, 20] if quick else [10, 20, 40]
    out = {}
    for n in ns:
        tcfg = TraceConfig(horizon=240.0, compression=base_comp / n, seed=42)
        trace = synth_azure_trace(tcfg)
        rows = []
        for pol in ("gate_and_route", "sarathi", "vllm"):
            s = run_trace_policy(pol, trace, n, horizon=tcfg.horizon)
            rows.append({"policy": pol,
                         "revenue_rate": round(s["revenue_rate"], 1),
                         "completion": round(s["completion_rate"], 3),
                         "ttft_mean": round(s["ttft_mean"], 2)})
        s = best_fixed_split("mix_solo", trace, n,
                             ks=[max(1, n // 5), n // 2], horizon=tcfg.horizon)
        rows.append({"policy": "distserve_mix_solo",
                     "revenue_rate": round(s["revenue_rate"], 1),
                     "completion": round(s["completion_rate"], 3),
                     "ttft_mean": round(s["ttft_mean"], 2)})
        rows.sort(key=lambda r: -r["revenue_rate"])
        out[f"n{n}"] = rows
        print(fmt_table(rows, ["policy", "revenue_rate", "completion",
                               "ttft_mean"],
                        f"\n[scale_sweep] n={n} (fixed per-server load)"))
    out["ours_first_everywhere"] = all(
        v[0]["policy"] == "gate_and_route" for v in out.values()
        if isinstance(v, list))
    save("scale_sweep", out)
    return out


if __name__ == "__main__":
    run(quick=True)
