"""Shared helpers for the benchmark suite (one module per paper table)."""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core.types import Pricing, ServicePrimitives
from repro.sweep.evaluators import (evaluate_trace_policy,
                                    planner_classes_from_trace)
from repro.sweep.run import fmt_table  # noqa: F401 - shared table formatter
from repro.telemetry.manifest import (append_record, payload_digest,
                                      run_record)
from repro.telemetry.timing import timeit_median  # noqa: F401 - canonical

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
MANIFESTS = ART.parents[0] / "manifests" / "runs.jsonl"

PRIM = ServicePrimitives()       # paper's A100/Qwen3-8B calibration
PRICING = Pricing(c_p=0.1, c_d=0.2)


def save(name: str, payload: dict):
    """Write one benchmark artifact with an embedded RunRecord.

    Every ``artifacts/bench/<name>.json`` carries provenance under its
    ``"manifest"`` key (git SHA, versions, a digest of the payload
    *minus* that key -- see :func:`repro.telemetry.manifest.
    payload_digest`); the same record also appends to
    ``artifacts/manifests/runs.jsonl``.  ``tools/check_bench.py`` gates
    both the presence and the digest of the embedded record.
    """
    payload = dict(payload)
    record = run_record(
        kind="bench", name=name,
        extra={"payload_digest": payload_digest(payload),
               "mode": payload.get("mode")})
    payload["manifest"] = record
    ART.mkdir(parents=True, exist_ok=True)
    out = ART / f"{name}.json"
    out.write_text(json.dumps(payload, indent=1, default=float))
    append_record(dict(record, artifacts={
        str(out.relative_to(ART.parents[1])): record["extra"][
            "payload_digest"]}), MANIFESTS)


def planner_classes(trace, n, n_classes=2, theta=3e-4):
    return planner_classes_from_trace(trace, n, n_classes=n_classes,
                                      theta=theta)


def run_trace_policy(policy_name: str, trace, n: int, *, prim=PRIM,
                     pricing=PRICING, horizon=600.0, online=True,
                     seed=42, sli=None, distserve_k=None,
                     safety=3.0, engine="python") -> dict:
    """One (policy, trace) evaluation in the calibrated engine.

    Thin wrapper over :func:`repro.sweep.evaluators.evaluate_trace_policy`,
    which is also the sweep subsystem's "engine" cell evaluator.

    ``engine="jax"`` replays the trace in the vmapped
    :class:`repro.serving.engine_jax.ClusterEngineJAX` instead -- the
    fast path for policy tables; it runs open-loop (no online
    controller), so pair it only with like-for-like comparisons."""
    token = policy_name
    if distserve_k is not None:
        token = f"{policy_name}:k={int(distserve_k)}"
    if engine == "jax":
        return run_trace_policy_jax(token, trace, n, prim=prim,
                                    pricing=pricing, horizon=horizon,
                                    seed=seed, sli=sli)
    return evaluate_trace_policy(token, trace, n, prim=prim, pricing=pricing,
                                 horizon=horizon, online=online, seed=seed,
                                 sli=sli, safety=safety)


def run_trace_policy_jax(token: str, trace, n: int, *, prim=PRIM,
                         pricing=PRICING, horizon=600.0, seed=42,
                         sli=None) -> dict:
    """One (policy, trace) evaluation in the JAX trace-replay engine.

    Same policy tokens and summary keys as the Python path (plus the
    engine diagnostics); successive calls that only vary the DistServe
    split k reuse one compiled scan, which is what makes
    :func:`best_fixed_split` cheap under ``engine="jax"``."""
    from repro.core.planning import solve_bundled_lp
    from repro.serving.engine_jax import ClusterEngineJAX
    from repro.sweep.evaluators import (_distserve_k, engine_policy_and_cfg,
                                        parse_policy_token)

    classes = planner_classes_from_trace(trace, n)
    plan = solve_bundled_lp(classes, prim, pricing, sli=sli)
    policy, cfg = engine_policy_and_cfg(token, plan, prim, pricing, n,
                                        seed=seed)
    out = ClusterEngineJAX(classes, policy, cfg, trace,
                           horizon=horizon).run(seed)
    name, args = parse_policy_token(token)
    if name.startswith("distserve_"):
        out["distserve_k"] = float(_distserve_k(args, n))
    return {k: float(v) for k, v in out.items()}


def best_fixed_split(variant: str, trace, n: int, ks=None,
                     engine="python", **kw) -> dict:
    """DistServe-style comparator: scan fixed splits, report the best.

    Under ``engine="jax"`` the whole k-scan runs as ONE
    ``jax.vmap``-batched replay (the split only changes the traced
    ``Mi`` parameter, so every k shares a single compiled step) --
    this is where the trace-replay fast path pays off."""
    ks = list(ks) if ks is not None else list(range(1, n))
    if engine == "jax":
        return _best_fixed_split_jax(variant, trace, n, ks, **kw)
    best = None
    for k in ks:
        s = run_trace_policy(f"distserve_{variant}", trace, n,
                             online=False, distserve_k=k, **kw)
        if best is None or s["revenue_rate"] > best["revenue_rate"]:
            best = dict(s, k=k)
    return best


def _best_fixed_split_jax(variant: str, trace, n: int, ks, *, prim=PRIM,
                          pricing=PRICING, horizon=600.0, seed=42,
                          sli=None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.planning import solve_bundled_lp
    from repro.data.traces import tensorize_trace
    from repro.serving.engine_jax import ClusterEngineJAX, run_engine_multi
    from repro.sweep.evaluators import engine_policy_and_cfg

    classes = planner_classes_from_trace(trace, n)
    plan = solve_bundled_lp(classes, prim, pricing, sli=sli)
    tt = tensorize_trace(trace)  # shared across the k axis
    engines = []
    for k in ks:
        policy, cfg = engine_policy_and_cfg(
            f"distserve_{variant}:k={int(k)}", plan, prim, pricing, n,
            seed=seed)
        engines.append(ClusterEngineJAX(classes, policy, cfg, tt,
                                        horizon=horizon))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                     *[e.params for e in engines])
    keys = jnp.stack([e._key(seed) for e in engines])
    raw = run_engine_multi(stacked, keys, **engines[0]._static)
    host = {kk: np.asarray(v) for kk, v in raw.items()}
    best = None
    for i, k in enumerate(ks):
        s = engines[i]._summary({kk: v[i] for kk, v in host.items()})
        if best is None or s["revenue_rate"] > best["revenue_rate"]:
            best = dict(s, k=int(k), distserve_k=float(k))
    return best


def round_vals(d: dict, nd=4) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, float):
            out[k] = round(v, nd)
        else:
            out[k] = v
    return out
