"""Shared helpers for the benchmark suite (one module per paper table)."""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.types import Pricing, ServicePrimitives
from repro.sweep.evaluators import (evaluate_trace_policy,
                                    planner_classes_from_trace)
from repro.sweep.run import fmt_table  # noqa: F401 - shared table formatter

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"

PRIM = ServicePrimitives()       # paper's A100/Qwen3-8B calibration
PRICING = Pricing(c_p=0.1, c_d=0.2)


def save(name: str, payload: dict):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                 default=float))


def planner_classes(trace, n, n_classes=2, theta=3e-4):
    return planner_classes_from_trace(trace, n, n_classes=n_classes,
                                      theta=theta)


def run_trace_policy(policy_name: str, trace, n: int, *, prim=PRIM,
                     pricing=PRICING, horizon=600.0, online=True,
                     seed=42, sli=None, distserve_k=None,
                     safety=3.0) -> dict:
    """One (policy, trace) evaluation in the calibrated engine.

    Thin wrapper over :func:`repro.sweep.evaluators.evaluate_trace_policy`,
    which is also the sweep subsystem's "engine" cell evaluator."""
    token = policy_name
    if distserve_k is not None:
        token = f"{policy_name}:k={int(distserve_k)}"
    return evaluate_trace_policy(token, trace, n, prim=prim, pricing=pricing,
                                 horizon=horizon, online=online, seed=seed,
                                 sli=sli, safety=safety)


def best_fixed_split(variant: str, trace, n: int, ks=None, **kw) -> dict:
    """DistServe-style comparator: scan fixed splits, report the best."""
    ks = ks if ks is not None else range(1, n)
    best = None
    for k in ks:
        s = run_trace_policy(f"distserve_{variant}", trace, n,
                             online=False, distserve_k=k, **kw)
        if best is None or s["revenue_rate"] > best["revenue_rate"]:
            best = dict(s, k=k)
    return best


def round_vals(d: dict, nd=4) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, float):
            out[k] = round(v, nd)
        else:
            out[k] = v
    return out
