"""Shared helpers for the benchmark suite (one module per paper table)."""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.online import OnlineController, OnlineControllerConfig
from repro.core.planning import solve_bundled_lp
from repro.core.policies import (PolicySpec, baseline_distserve,
                                 baseline_sarathi, baseline_vllm,
                                 gate_and_route)
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.data.traces import (Request, TraceConfig, synth_azure_trace,
                               trace_class_means)
from repro.serving.engine_sim import ClusterEngine, EngineConfig

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"

PRIM = ServicePrimitives()       # paper's A100/Qwen3-8B calibration
PRICING = Pricing(c_p=0.1, c_d=0.2)


def save(name: str, payload: dict):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                 default=float))


def planner_classes(trace, n, n_classes=2, theta=3e-4):
    means = trace_class_means(trace, n_classes)
    return [
        WorkloadClass(f"class{i}", prompt_len=means[i][0],
                      decode_len=means[i][1],
                      arrival_rate=max(means[i][2] / n, 1e-6),
                      patience=theta)
        for i in range(n_classes)
    ]


def run_trace_policy(policy_name: str, trace, n: int, *, prim=PRIM,
                     pricing=PRICING, horizon=600.0, online=True,
                     seed=42, sli=None, distserve_k=None,
                     safety=3.0) -> dict:
    """One (policy, trace) evaluation in the calibrated engine."""
    n_classes = max(r.cls for r in trace) + 1
    classes = planner_classes(trace, n, n_classes=n_classes)
    plan = solve_bundled_lp(classes, prim, pricing, sli=sli)
    controller = None
    cfg = EngineConfig(prim, pricing, n, seed=seed)
    if policy_name == "gate_and_route":
        policy = gate_and_route(plan)
        if online:
            controller = OnlineController(
                classes, prim, pricing, n=n,
                config=OnlineControllerConfig(sli=sli, safety=safety))
    elif policy_name == "sarathi":
        policy = baseline_sarathi(plan)
        cfg = EngineConfig(prim, pricing, n, seed=seed, sarathi_budget=True)
    elif policy_name == "vllm":
        # prefill-first scheduling; chunking stays a system property (C),
        # exactly as in the paper's Section 2 model.
        policy = baseline_vllm(plan)
    elif policy_name == "distserve_mix_solo":
        policy = baseline_distserve(plan, distserve_k, variant="mix_solo")
    elif policy_name == "distserve_prefill_solo":
        policy = baseline_distserve(plan, distserve_k, variant="prefill_solo")
    else:
        raise ValueError(policy_name)
    eng = ClusterEngine(classes, policy, cfg, controller=controller)
    m = eng.run(trace, horizon=horizon)
    return m.summary()


def best_fixed_split(variant: str, trace, n: int, ks=None, **kw) -> dict:
    """DistServe-style comparator: scan fixed splits, report the best."""
    ks = ks if ks is not None else range(1, n)
    best = None
    for k in ks:
        s = run_trace_policy(f"distserve_{variant}", trace, n,
                             online=False, distserve_k=k, **kw)
        if best is None or s["revenue_rate"] > best["revenue_rate"]:
            best = dict(s, k=k)
    return best


def fmt_table(rows: list[dict], cols: list[str], title: str) -> str:
    w = {c: max(len(c), *(len(f"{r.get(c, '')}") for r in rows)) for c in cols}
    out = [title, " | ".join(c.ljust(w[c]) for c in cols)]
    out.append("-|-".join("-" * w[c] for c in cols))
    for r in rows:
        out.append(" | ".join(f"{r.get(c, '')}".ljust(w[c]) for c in cols))
    return "\n".join(out)


def round_vals(d: dict, nd=4) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, float):
            out[k] = round(v, nd)
        else:
            out[k] = v
    return out
