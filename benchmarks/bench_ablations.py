"""Paper EC.8.6: policy-component ablations on synthetic workloads.

GG-SP (full) vs FI-WSP (~Sarathi), GI-WSP, GF-WSP, FG-SP across varied
infrastructure hyperparameters and class mixes; reports normalized mean
revenue (+/- std) per policy, expecting GG-SP best.
"""

from __future__ import annotations

import numpy as np

from repro.core.planning import solve_bundled_lp
from repro.core.policies import ablation_policy
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

from .common import fmt_table, save

VARIANTS = ("GG-SP", "FI-WSP", "GI-WSP", "GF-WSP", "FG-SP")


def _instances(quick: bool):
    grids = [
        dict(alpha=0.02, beta=6e-5, gamma=40.0, P=(300, 3000), D=(1000, 400),
             lam=0.5),
        dict(alpha=0.06, beta=2e-4, gamma=25.0, P=(200, 2000), D=(800, 300),
             lam=0.4),
        dict(alpha=0.15, beta=1e-3, gamma=10.0, P=(500, 2500), D=(600, 200),
             lam=0.25),
    ]
    return grids[:2] if quick else grids


def run(quick: bool = True) -> dict:
    n = 100 if quick else 500
    horizon, warmup = (200.0, 50.0) if quick else (400.0, 100.0)
    per_variant = {v: [] for v in VARIANTS}
    for inst in _instances(quick):
        prim = ServicePrimitives(alpha=inst["alpha"], beta=inst["beta"],
                                 gamma=inst["gamma"])
        pricing = Pricing(0.1, 0.2)
        classes = [
            WorkloadClass("c0", inst["P"][0], inst["D"][0], inst["lam"], 0.1),
            WorkloadClass("c1", inst["P"][1], inst["D"][1], inst["lam"], 0.1),
        ]
        plan = solve_bundled_lp(classes, prim, pricing)
        for v in VARIANTS:
            sim = CTMCSimulator(classes, prim, pricing,
                                ablation_policy(plan, v), n=n, seed=0)
            r = sim.run(horizon, warmup=warmup)
            per_variant[v].append(r.revenue_rate_per_server)
    # normalise within each instance by the best policy
    arr = np.array([per_variant[v] for v in VARIANTS])  # (V, inst)
    norm = arr / arr.max(axis=0, keepdims=True)
    rows = [{"variant": v,
             "norm_revenue_mean": round(float(norm[i].mean()), 4),
             "norm_revenue_std": round(float(norm[i].std()), 4)}
            for i, v in enumerate(VARIANTS)]
    rows.sort(key=lambda r: -r["norm_revenue_mean"])
    print(fmt_table(rows, ["variant", "norm_revenue_mean",
                           "norm_revenue_std"],
                    "\n[ablations] EC.8.6 component ablations"))
    out = {"rows": rows, "ggsp_best": rows[0]["variant"] == "GG-SP"}
    save("ablations", out)
    return out


if __name__ == "__main__":
    run(quick=True)
