"""Paper EC.8.6: policy-component ablations on synthetic workloads.

GG-SP (full) vs FI-WSP (~Sarathi), GI-WSP, GF-WSP, FG-SP across varied
infrastructure hyperparameters and class mixes; reports normalized mean
revenue (+/- std) per policy, expecting GG-SP best.

Each hyperparameter instance is one workload mix of a single CTMC sweep
(:mod:`repro.sweep`); this module only normalises and ranks the cells.
"""

from __future__ import annotations

import numpy as np

from repro.sweep import MixSpec, SweepSpec, run_sweep

from .common import ART, fmt_table, save

VARIANTS = ("GG-SP", "FI-WSP", "GI-WSP", "GF-WSP", "FG-SP")


def _instances(quick: bool):
    grids = [
        dict(alpha=0.02, beta=6e-5, gamma=40.0, P=(300, 3000), D=(1000, 400),
             lam=0.5),
        dict(alpha=0.06, beta=2e-4, gamma=25.0, P=(200, 2000), D=(800, 300),
             lam=0.4),
        dict(alpha=0.15, beta=1e-3, gamma=10.0, P=(500, 2500), D=(600, 200),
             lam=0.25),
    ]
    return grids[:2] if quick else grids


def _mix(idx: int, inst: dict) -> MixSpec:
    return MixSpec(
        name=f"inst{idx}",
        classes=(
            dict(name="c0", prompt_len=inst["P"][0], decode_len=inst["D"][0],
                 arrival_rate=inst["lam"], patience=0.1),
            dict(name="c1", prompt_len=inst["P"][1], decode_len=inst["D"][1],
                 arrival_rate=inst["lam"], patience=0.1),
        ),
        prim=dict(alpha=inst["alpha"], beta=inst["beta"],
                  gamma=inst["gamma"]),
        pricing=dict(c_p=0.1, c_d=0.2),
    )


def run(quick: bool = True) -> dict:
    n = 100 if quick else 500
    horizon, warmup = (200.0, 50.0) if quick else (400.0, 100.0)
    mixes = tuple(_mix(i, inst)
                  for i, inst in enumerate(_instances(quick)))
    spec = SweepSpec(
        name="ablations", evaluator="ctmc", policies=VARIANTS,
        n_servers=(n,), n_seeds=1, seed=0, mixes=mixes,
        horizon=horizon, warmup=warmup,
        # paired comparison: every variant sees the same RNG streams, as
        # the original single-seed loop did (variance-reduced ranking);
        # batch_plans solves all per-instance planning LPs in one
        # vmapped interior-point run before the CTMC cells start
        extra={"crn_policies": True, "batch_plans": True})
    res = run_sweep(spec)
    per_variant = {
        v: [res.mean_over_seeds("revenue_rate", mix=m.name, policy=v, n=n)
            for m in mixes]
        for v in VARIANTS
    }
    # normalise within each instance by the best policy
    arr = np.array([per_variant[v] for v in VARIANTS])  # (V, inst)
    norm = arr / arr.max(axis=0, keepdims=True)
    rows = [{"variant": v,
             "norm_revenue_mean": round(float(norm[i].mean()), 4),
             "norm_revenue_std": round(float(norm[i].std()), 4)}
            for i, v in enumerate(VARIANTS)]
    rows.sort(key=lambda r: -r["norm_revenue_mean"])
    print(fmt_table(rows, ["variant", "norm_revenue_mean",
                           "norm_revenue_std"],
                    "\n[ablations] EC.8.6 component ablations"))
    artifact = res.save(ART.parent / "sweep" / "ablations.json")
    out = {"rows": rows, "ggsp_best": rows[0]["variant"] == "GG-SP",
           "sweep_artifact": str(artifact)}
    save("ablations", out)
    return out


if __name__ == "__main__":
    run(quick=True)
