"""Paper Table 2 / Fig. 4: trace-driven policy comparison.

Replays Azure-like 2023/2024 traces (compressed interarrivals, paper
Section 6.2) through the calibrated engine for the five policy families:
online gate-and-route (ours), Sarathi-style, vLLM-style, and the two
DistServe best-fixed-split comparators.
"""

from __future__ import annotations

from repro.workloads import get_scenario

from .common import (best_fixed_split, fmt_table, round_vals,
                     run_trace_policy, save)

# The two Azure-like slices are registry scenarios now (the marginals
# previously lived here as hand-rolled TraceConfig blocks); replay keeps
# the classic compression and seeds.
COMPRESSION = 0.03

COLS = ["policy", "revenue_rate", "completion_rate", "ttft_mean", "ttft_p95",
        "ttft_p99", "tpot_mean", "tpot_p95", "tpot_p99"]


def _one_replay(tag: str, scenario: str, n: int, quick: bool,
                engine: str = "python") -> list:
    scn = get_scenario(scenario)
    trace = scn.generate(compression=COMPRESSION)
    horizon = scn.horizon
    rows = []
    for pol in ("gate_and_route", "sarathi", "vllm"):
        s = run_trace_policy(pol, trace, n, horizon=horizon,
                             engine=engine)
        rows.append(dict(round_vals(s), policy=pol))
    ks = ([2, 4, 6] if quick else range(1, n))
    for variant in ("mix_solo", "prefill_solo"):
        s = best_fixed_split(variant, trace, n, ks=ks, horizon=horizon,
                             engine=engine)
        rows.append(dict(round_vals(s), policy=f"distserve_{variant}"))
    print(fmt_table(rows, COLS,
                    f"\n[trace_replay] {tag} ({n} servers, {engine} engine)"))
    return rows


def run(quick: bool = True, engine: str = "python") -> dict:
    """``engine="jax"`` replays the same tables in
    :class:`repro.serving.engine_jax.ClusterEngineJAX`.  The win is the
    DistServe comparator: the whole k-scan runs as ONE vmapped batch
    (the split is just the traced ``Mi`` parameter), so ``--full`` mode
    -- where the two k in 1..n-1 scans dominate -- gets the batched
    engine's throughput; the three single-policy replays don't batch
    and are faster on the Python engine.  The jax path runs open-loop
    (gate-and-route without the online controller), so its numbers are
    comparable within the table, not with the python-engine artifact."""
    n = 10
    out = {
        "azure2023": _one_replay("2023 Azure-like replay", "azure_2023", n,
                                 quick, engine),
        "azure2024": _one_replay("2024 Azure-like replay", "azure_2024", n,
                                 quick, engine),
    }
    # headline check: ours leads on revenue in both slices
    leads = {}
    for tag, rows in out.items():
        ours = rows[0]["revenue_rate"]
        best_other = max(r["revenue_rate"] for r in rows[1:])
        leads[f"{tag}_lead_pct"] = 100 * (ours - best_other) / best_other
    out.update(leads)
    out["engine"] = engine
    save("trace_replay" if engine == "python" else f"trace_replay_{engine}",
         out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="python", choices=("python", "jax"))
    a = ap.parse_args()
    run(quick=not a.full, engine=a.engine)
