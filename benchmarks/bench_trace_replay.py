"""Paper Table 2 / Fig. 4: trace-driven policy comparison.

Replays Azure-like 2023/2024 traces (compressed interarrivals, paper
Section 6.2) through the calibrated engine for the five policy families:
online gate-and-route (ours), Sarathi-style, vLLM-style, and the two
DistServe best-fixed-split comparators.
"""

from __future__ import annotations

from repro.data.traces import ClassProfile, TraceConfig, synth_azure_trace

from .common import (best_fixed_split, fmt_table, round_vals,
                     run_trace_policy, save)

TRACE_2023 = TraceConfig(horizon=300.0, compression=0.03, seed=42)
# the 2024 slice: heavier conversation share, longer outputs
TRACE_2024 = TraceConfig(
    horizon=300.0, compression=0.03, seed=24,
    profiles=(
        ClassProfile("code", mean_prompt=3200, mean_decode=25,
                     cv_prompt=1.1, cv_decode=1.3, share=0.35),
        ClassProfile("conversation", mean_prompt=810, mean_decode=320,
                     cv_prompt=1.5, cv_decode=1.2, share=0.65),
    ))

COLS = ["policy", "revenue_rate", "completion_rate", "ttft_mean", "ttft_p95",
        "ttft_p99", "tpot_mean", "tpot_p95", "tpot_p99"]


def _one_replay(tag: str, tcfg: TraceConfig, n: int, quick: bool) -> list:
    trace = synth_azure_trace(tcfg)
    rows = []
    for pol in ("gate_and_route", "sarathi", "vllm"):
        s = run_trace_policy(pol, trace, n, horizon=tcfg.horizon)
        rows.append(dict(round_vals(s), policy=pol))
    ks = ([2, 4, 6] if quick else range(1, n))
    for variant in ("mix_solo", "prefill_solo"):
        s = best_fixed_split(variant, trace, n, ks=ks, horizon=tcfg.horizon)
        rows.append(dict(round_vals(s), policy=f"distserve_{variant}"))
    print(fmt_table(rows, COLS, f"\n[trace_replay] {tag} ({n} servers)"))
    return rows


def run(quick: bool = True) -> dict:
    n = 10
    out = {
        "azure2023": _one_replay("2023 Azure-like replay", TRACE_2023, n,
                                 quick),
        "azure2024": _one_replay("2024 Azure-like replay", TRACE_2024, n,
                                 quick),
    }
    # headline check: ours leads on revenue in both slices
    leads = {}
    for tag, rows in out.items():
        ours = rows[0]["revenue_rate"]
        best_other = max(r["revenue_rate"] for r in rows[1:])
        leads[f"{tag}_lead_pct"] = 100 * (ours - best_other) / best_other
    out.update(leads)
    save("trace_replay", out)
    return out


if __name__ == "__main__":
    run(quick=True)
