"""Paper Fig. 6: shadow prices of SLIs (Pareto frontiers from the LP).

Uses the same two-class synthetic instance as the convergence analysis
(EC.8.5): class 0 decode-heavy (P=300,D=1000), class 1 prefill-heavy
(P=3000,D=400), lambda=[.5,.5], theta=[.1,.1], separate charging prices
c_p=.1, c_d=.2 -- and sweeps one SLI cap at a time on the *planning LP*,
reporting optimal revenue vs the cap (the slope is the shadow price).
"""

from __future__ import annotations

import numpy as np

from repro.core.planning import SLISpec, solve_bundled_lp, tpot_of_plan
from repro.core.planning_batch import solve_plan_batch
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

from .common import save

CLASSES = [
    WorkloadClass("decode-heavy", 300, 1000, 0.5, 0.1),
    WorkloadClass("prefill-heavy", 3000, 400, 0.5, 0.1),
]
PRIM = ServicePrimitives()
PRICING = Pricing(0.1, 0.2)

_CAP_FIELD = {"prefill_fairness": "prefill_fairness_cap",
              "decode_fairness": "decode_fairness_cap",
              "tpot": "tpot_cap"}


def _sweep(kind: str, caps) -> list[dict]:
    """One whole cap frontier as a single batched planning solve (the cap
    values ride the batch axis of ``solve_plan_batch``; this used to be a
    Python loop of simplex solves)."""
    caps = np.asarray(caps, dtype=float)
    pb = solve_plan_batch(
        [CLASSES] * len(caps), PRIM, PRICING,
        sli=SLISpec(**{_CAP_FIELD[kind]: caps}))
    assert bool(pb.converged.all()), "planner did not converge on a cap"
    return [{"cap": float(cap),
             "revenue": float(pb.revenue_rate[k]),
             "tpot": float(tpot_of_plan(pb.solution(k)))}
            for k, cap in enumerate(caps)]


def run(quick: bool = True) -> dict:
    base = solve_bundled_lp(CLASSES, PRIM, PRICING)
    npts = 6 if quick else 15
    gap_x = abs(base.x[0] - base.x[1])
    gap_y = abs(base.ys[0] - base.ys[1])
    out = {
        "unconstrained_revenue": float(base.revenue_rate),
        "prefill_fairness": _sweep(
            "prefill_fairness", np.linspace(1e-4, max(gap_x, .2), npts)),
        "decode_fairness": _sweep(
            "decode_fairness", np.linspace(1e-4, max(gap_y, 2.0), npts)),
        "tpot": _sweep(
            "tpot", np.linspace(1.05 / PRIM.gamma, PRIM.tau_mix, npts)),
    }

    def shadow(rows):
        if len(rows) < 2:
            return 0.0
        return (rows[-1]["revenue"] - rows[0]["revenue"]) / (
            rows[-1]["cap"] - rows[0]["cap"])

    for k in ("prefill_fairness", "decode_fairness", "tpot"):
        out[f"{k}_shadow_price"] = shadow(out[k])
        print(f"[sli_pareto] {k:18s}: revenue "
              f"{out[k][0]['revenue']:8.2f} (tight) -> "
              f"{out[k][-1]['revenue']:8.2f} (loose); "
              f"mean shadow price {out[f'{k}_shadow_price']:.2f}")
    save("sli_pareto", out)
    return out


if __name__ == "__main__":
    run(quick=True)
