"""Fluid-limit and CTMC consistency tests (Theorems 1, 2, 4 behaviour)."""

import numpy as np
import pytest

from repro.core.fluid import fluid_steady_state
from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import gate_and_route, sli_aware_policy
from repro.core.simulator import CTMCSimulator
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass

pytestmark = pytest.mark.sim

C0 = WorkloadClass("decode_heavy", 300, 1000, arrival_rate=0.5, patience=0.1)
C1 = WorkloadClass("prefill_heavy", 3000, 400, arrival_rate=0.5, patience=0.1)
PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)
CLASSES = [C0, C1]


@pytest.fixture(scope="module")
def plan():
    return solve_bundled_lp(CLASSES, PRIM, PRICE,
                            sli=SLISpec(pin_zero_decode_queue=True))


def test_fluid_converges_to_lp(plan):
    ss = fluid_steady_state(CLASSES, PRIM, PRICE, plan, horizon=300.0, dt=2e-3)
    # Theorem 2 (fluid version): prefill occupancy -> x*, revenue -> R*.
    np.testing.assert_allclose(ss["x"], plan.x, atol=5e-3)
    assert ss["revenue_rate"] == pytest.approx(plan.revenue_rate, rel=0.02)
    # Decode buffer drains (Prop. EC.1).
    assert np.all(ss["qd"] < 5e-3)
    # Prefill queues converge to q_p* (Lemma EC.3).
    np.testing.assert_allclose(ss["qp"], plan.qp, atol=2e-2)


def test_fluid_randomized_router_hits_pool_targets(plan):
    ss = fluid_steady_state(
        CLASSES, PRIM, PRICE, plan, horizon=300.0, dt=2e-3,
        randomized_router=True,
    )
    # Theorem 4: class-level decode occupancies converge to (y_m*, y_s*).
    np.testing.assert_allclose(ss["ym"], plan.ym, atol=1.5e-2)
    np.testing.assert_allclose(ss["ys"], plan.ys, atol=1.5e-2)


def test_ctmc_revenue_approaches_fluid_optimum(plan):
    pol = gate_and_route(plan)
    res = CTMCSimulator(CLASSES, PRIM, PRICE, pol, n=200, seed=1).run(
        horizon=150.0, warmup=50.0
    )
    assert res.revenue_rate_per_server == pytest.approx(
        plan.revenue_rate, rel=0.08
    )
    # occupancy convergence (Theorem 2 / EC.8.5 figure behaviour)
    np.testing.assert_allclose(res.avg_x, plan.x, atol=0.02)


def test_ctmc_sli_router_occupancy_convergence(plan):
    pol = sli_aware_policy(plan)
    res = CTMCSimulator(CLASSES, PRIM, PRICE, pol, n=200, seed=2).run(
        horizon=150.0, warmup=50.0
    )
    np.testing.assert_allclose(res.avg_ym, plan.ym, atol=0.12)
    np.testing.assert_allclose(res.avg_ys, plan.ys, atol=0.12)


def test_ctmc_scaling_improves_accuracy(plan):
    pol = gate_and_route(plan)
    errs = []
    for n in (20, 200):
        res = CTMCSimulator(CLASSES, PRIM, PRICE, pol, n=n, seed=3).run(
            horizon=120.0, warmup=40.0
        )
        errs.append(abs(res.revenue_rate_per_server - plan.revenue_rate))
    assert errs[1] <= errs[0] + 1e-9


def test_ctmc_conservation_laws(plan):
    """Pathwise flow conservation: arrivals = completions + abandons + in-system."""
    pol = gate_and_route(plan)
    sim = CTMCSimulator(CLASSES, PRIM, PRICE, pol, n=50, seed=4)
    res = sim.run(horizon=60.0, warmup=0.0)
    in_system = sim.Qp + sim.X + sim.Qdm + sim.Qds + sim.Ym + sim.Ys
    lhs = res.arrivals
    rhs = res.completions + res.abandons_p + res.abandons_d + in_system
    np.testing.assert_allclose(lhs, rhs, atol=1e-9)
    # capacity constraints held at the end state
    assert sim.X.sum() <= sim.M + 1e-9
    assert sim.Ym.sum() <= (sim.B - 1) * sim.M + 1e-9
    assert sim.Ys.sum() <= sim.B * (sim.n - sim.M) + 1e-9
