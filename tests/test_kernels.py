"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode executes the kernel body in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.prefill_attention.kernel import prefill_attention_pallas
from repro.kernels.prefill_attention.ref import prefill_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 32),    # MHA
    (2, 256, 8, 2, 64),    # GQA
    (1, 512, 4, 1, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=True, window=96),
    dict(causal=True, attn_softcap=50.0),
    dict(causal=True, prefix_len=64),
    dict(causal=False),
])
def test_prefill_attention_sweep(B, S, H, KV, D, dtype, kw):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = prefill_attention_pallas(q, k, v, block_q=64, block_k=64,
                                   interpret=True, **kw)
    ref = prefill_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,KV,D", [
    (2, 256, 4, 4, 32),
    (3, 512, 8, 2, 64),
    (1, 1024, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, H, KV, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    kv_len = jnp.array([S // 3 + 1, S, max(1, S // 7)][:B], jnp.int32)
    out = decode_attention_pallas(q, k, v, kv_len, block_s=128,
                                  interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_ring_window():
    B, S, H, KV, D = 2, 256, 4, 2, 32
    W = 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    kv_len = jnp.array([200, 256], jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    qpos = kv_len - 1
    out = decode_attention_pallas(q, k, v, kv_len, window=W, k_positions=kpos,
                                  q_positions=qpos, block_s=64,
                                  interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len, window=W, k_positions=kpos,
                               q_positions=qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 256, 3, 16, 32, 64),
    (1, 512, 4, 32, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    Bm = (jax.random.normal(ks[1], (B, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[2], (B, S, N)) * 0.5).astype(dtype)
    log_a = -jnp.abs(jax.random.normal(ks[3], (B, S, H))) * 0.1
    y, h = ssd_scan_pallas(x, Bm, Cm, log_a, chunk=chunk, interpret=True)
    yr, hr = ssd_scan_ref(x, Bm, Cm, log_a)
    tol = dict(atol=2e-1, rtol=2e-1) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-2,
                               rtol=1e-2)


# --------------------------------------------------------------------------
# Calibration-grid parity: the calibration subsystem times the kernels
# through their public ops wrappers at (C, K) shapes the grid produces --
# including odd / non-multiple-of-block edges that exercise the wrappers'
# padding and block-halving logic.  These sweeps guarantee calibration
# never times a kernel whose numerics are unverified at that shape.

# odd prefill chunks C (prime / non-multiple-of-block) + one block edge
CALIB_CHUNKS = (17, 48, 100, 128)
# per-stream cache lengths ceil(K / B) from odd aggregate-KV grid points
CALIB_KV_LENS = (33, 108, 300)


@pytest.mark.parametrize("C", CALIB_CHUNKS)
def test_prefill_ops_parity_at_calibration_chunks(C):
    from repro.kernels.prefill_attention.ops import prefill_attention

    H, KV, D = 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (1, C, H, D))
    k = jax.random.normal(ks[1], (1, C, KV, D))
    v = jax.random.normal(ks[2], (1, C, KV, D))
    out = prefill_attention(q, k, v, interpret=True)
    ref = prefill_attention_ref(q, k, v)
    assert out.shape == ref.shape == (1, C, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("S", CALIB_KV_LENS)
def test_decode_ops_parity_at_calibration_kv(S):
    from repro.kernels.decode_attention.ops import decode_attention

    B, H, KV, D = 4, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    # ragged fills: full cache plus partial residency per stream
    kv_len = jnp.array([S, max(1, S - 1), max(1, S // 2), max(1, S // 3)],
                       jnp.int32)
    out = decode_attention(q, k, v, kv_len, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    assert out.shape == ref.shape == (B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("S", (48, 100))
def test_ssd_ops_parity_at_calibration_chunks(S):
    from repro.kernels.ssd_scan.ops import ssd_scan

    B, H, P, N = 1, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(12), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    log_a = -jnp.abs(jax.random.normal(ks[3], (B, S, H))) * 0.1
    y, h = ssd_scan(x, Bm, Cm, log_a, interpret=True)
    yr, hr = ssd_scan_ref(x, Bm, Cm, log_a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-2, rtol=1e-2)


def test_model_attention_pallas_path_matches_xla():
    """attention_prefill(kernel_impl='pallas') == xla path."""
    from repro.models.attention import attention_prefill, attn_defs
    from repro.models.config import AttentionConfig
    from repro.models.params import init_params

    cfg = AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=32)
    p = init_params(attn_defs(cfg, 64), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    out_x, _ = attention_prefill(cfg, p, x, pos, local=False)
    out_p, _ = attention_prefill(cfg, p, x, pos, local=False,
                                 kernel_impl="pallas")
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_p),
                               atol=2e-4, rtol=2e-4)
