"""Workload-scenario subsystem: registry, generation, arrival processes.

Deterministic coverage of the registry contract (>= 8 scenarios,
validated generation, tensor round-trip), mix schedules, per-class
patience, and capacity scripts; the hypothesis property tests for the
new arrival processes live in ``test_workloads_properties.py`` so this
module runs even where hypothesis is absent.
"""

import numpy as np
import pytest

from repro.data.traces import (ClassProfile, TraceConfig, synth_azure_trace,
                               trace_class_means_windowed, untensorize_trace,
                               validate_requests)
from repro.workloads import (CapacityEvent, MMPPArrivals,
                             PiecewiseConstantArrivals, PoissonArrivals,
                             Scenario, ScenarioError, diurnal, flash_crowd,
                             get_scenario, list_scenarios, rate_shift,
                             register_scenario)


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------


def test_registry_has_catalog():
    names = list_scenarios()
    assert len(names) >= 8
    assert names == sorted(names)
    for required in ("azure_2023", "azure_2024", "rate_shift", "flash_crowd",
                     "diurnal", "capacity_churn", "dolly_mix", "conv_latent"):
        assert required in names


def test_get_scenario_unknown_name():
    with pytest.raises(ScenarioError, match="unknown scenario"):
        get_scenario("no_such_scenario")


def test_register_scenario_no_silent_shadowing():
    s = get_scenario("azure_2023")
    with pytest.raises(ScenarioError, match="already registered"):
        register_scenario(s.replace(description="shadow"))


@pytest.mark.parametrize("name", list_scenarios())
def test_every_scenario_generates_and_roundtrips(name):
    """Acceptance bar: every registered scenario emits a validated trace
    that round-trips through tensorize_trace (quick-sized)."""
    scn = get_scenario(name)
    trace = scn.generate(seed=2, horizon=min(40.0, scn.horizon),
                         rate_scale=0.5)
    assert trace, f"{name} generated an empty quick trace"
    validate_requests(trace)  # idempotent: generate already validates
    tt = scn.tensorize(seed=2, horizon=min(40.0, scn.horizon),
                       rate_scale=0.5, pad_to=len(trace) + 7)
    back = untensorize_trace(tt)
    assert len(back) == len(trace)
    assert [r.cls for r in back] == [r.cls for r in trace]
    assert tt.n_classes <= scn.n_classes
    assert all(r.cls < scn.n_classes for r in trace)


def test_generation_is_deterministic_and_seed_sensitive():
    scn = get_scenario("rate_shift")
    a = scn.generate(seed=5, horizon=60.0)
    b = scn.generate(seed=5, horizon=60.0)
    c = scn.generate(seed=6, horizon=60.0)
    assert [(r.t_arrival, r.cls, r.prompt_len) for r in a] == \
           [(r.t_arrival, r.cls, r.prompt_len) for r in b]
    assert [r.t_arrival for r in a] != [r.t_arrival for r in c]


def test_mix_schedule_shifts_composition():
    scn = get_scenario("rate_shift")  # shares flip 0.8/0.2 -> 0.25/0.75
    np.testing.assert_allclose(scn.shares_at(0.0), [0.8, 0.2])
    np.testing.assert_allclose(scn.shares_at(200.0), [0.25, 0.75])
    trace = scn.generate(seed=0)
    pre = [r.cls for r in trace if r.t_arrival < 120.0]
    post = [r.cls for r in trace if r.t_arrival >= 120.0]
    assert np.mean(pre) < 0.35 and np.mean(post) > 0.6


def test_capacity_events_script():
    scn = get_scenario("capacity_churn")
    evs = scn.failure_events(n=2)  # sids clamped into the tiny cluster
    assert all(ev[2] < 2 for ev in evs)
    kinds = {ev[1] for ev in evs}
    assert kinds == {"fail", "recover", "straggle"}
    assert all(len(ev) == 4 for ev in evs if ev[1] == "straggle")
    with pytest.raises(ValueError, match="kind"):
        CapacityEvent(1.0, "explode", 0)


def test_expected_rates_average_mix_schedule():
    scn = get_scenario("rate_shift")
    rates = scn.expected_rates()
    # total = time-averaged intensity; split reflects both phases
    assert rates.sum() == pytest.approx(
        scn.arrivals.mean_rate(scn.horizon), rel=1e-6)
    assert rates[1] > rates[0] * 0.5  # conversation gains mass post-shift


# ---------------------------------------------------------------------------
# Per-class patience (synthetic traces can exercise expiry now)
# ---------------------------------------------------------------------------


def test_synth_azure_trace_per_class_patience():
    cfg = TraceConfig(
        horizon=5.0, compression=0.2,
        profiles=(
            ClassProfile("deadline", 100, 20, share=0.5, patience=7.5),
            ClassProfile("lenient", 100, 20, share=0.5),
        ))
    trace = synth_azure_trace(cfg)
    assert trace
    for r in trace:
        if r.cls == 0:
            assert r.patience == 7.5
        else:
            assert np.isinf(r.patience)


def test_scenario_patience_flows_to_requests():
    trace = get_scenario("dolly_mix").generate(seed=0, horizon=20.0)
    assert trace and all(np.isfinite(r.patience) for r in trace)


def test_cli_csv_export_roundtrips(tmp_path):
    """CLI --out CSV preserves class ids AND patience through
    load_trace_csv (numeric ids; optional patience column)."""
    from repro.data.traces import load_trace_csv
    from repro.workloads.run import main

    out = tmp_path / "t.csv"
    assert main(["--scenario", "dolly_mix", "--stats", "--quick",
                 "--seed", "3", "--out", str(out)]) == 0
    scn = get_scenario("dolly_mix")
    direct = scn.generate(seed=3, horizon=60.0, rate_scale=0.5)
    back = load_trace_csv(str(out))
    assert [(r.t_arrival, r.cls, r.prompt_len, r.decode_len, r.patience)
            for r in back] == \
           [(r.t_arrival, r.cls, r.prompt_len, r.decode_len, r.patience)
            for r in direct]


# ---------------------------------------------------------------------------
# Windowed class means (online-controller ground truth)
# ---------------------------------------------------------------------------


def test_trace_class_means_windowed_matches_global():
    trace = get_scenario("azure_2023").generate(seed=1, horizon=60.0,
                                                rate_scale=2.0)
    wins = trace_class_means_windowed(trace, 2, window=15.0)
    assert len(wins) == 4
    # windowed arrival counts must add up to the per-class totals; the
    # last window normalizes by its covered duration (up to the final
    # arrival), not the nominal window length
    horizon = max(r.t_arrival for r in trace)
    for i in range(2):
        total = sum(m[i][2] * (min(t1, horizon) - t0)
                    for t0, t1, m in wins)
        assert total == pytest.approx(
            sum(1 for r in trace if r.cls == i), rel=1e-6)
    with pytest.raises(ValueError, match="window"):
        trace_class_means_windowed(trace, 2, window=0.0)


def test_trace_class_means_windowed_sees_rate_shift():
    trace = get_scenario("rate_shift").generate(seed=3)
    wins = trace_class_means_windowed(trace, 2, window=30.0)
    pre = wins[1][2]  # [30, 60): phase 0
    post = wins[-1][2]  # last window: phase 1
    assert sum(m[2] for m in post) > 1.8 * sum(m[2] for m in pre)


# ---------------------------------------------------------------------------
# Arrival-process behaviour (deterministic; hypothesis properties live in
# test_workloads_properties.py)
# ---------------------------------------------------------------------------


def test_mmpp_scaling_matches_compression_law():
    """scaled(f) multiplies arrival AND switching rates -- same law as
    TraceConfig interarrival compression (statistical check)."""
    proc = MMPPArrivals(base_rate=2.0)
    fast = proc.scaled(10.0)
    assert fast.base_rate == 20.0
    assert fast.switch == tuple(s * 10.0 for s in proc.switch)
    counts = [len(fast.sample(np.random.default_rng(s), 100.0))
              for s in range(8)]
    expect = fast.mean_rate(100.0) * 100.0
    assert abs(np.mean(counts) - expect) < 0.25 * expect


def test_poisson_sample_statistics():
    proc = PoissonArrivals(rate=12.0)
    ts = proc.sample(np.random.default_rng(0), 200.0)
    assert (np.diff(ts) > 0).all() and ts[-1] < 200.0
    assert abs(len(ts) - 2400) < 4 * np.sqrt(2400)


def test_builder_shapes():
    rs = rate_shift(2.0, 6.0, t_shift=50.0)
    assert rs.rate_at(0.0) == 2.0 and rs.rate_at(50.0) == 6.0
    fc = flash_crowd(3.0, spike_mult=4.0, t_on=10.0, t_off=20.0)
    assert fc.rate_at(15.0) == 12.0 and fc.rate_at(25.0) == 3.0
    dn = diurnal(base_rate=10.0, amplitude=0.5, period=100.0, horizon=200.0,
                 n_bins=10)
    assert dn.rate_bound() <= 15.0 + 1e-9
    assert min(dn.rates) >= 5.0 - 1e-9
    assert dn.mean_rate(200.0) == pytest.approx(10.0, rel=0.05)


def test_invalid_process_specs_rejected():
    with pytest.raises(ValueError):
        PoissonArrivals(rate=0.0)
    with pytest.raises(ValueError):
        MMPPArrivals(base_rate=1.0, levels=(1.0,), switch=(0.1,))
    with pytest.raises(ValueError):
        PiecewiseConstantArrivals(times=(0.0, 5.0), rates=(0.0, 0.0))
    with pytest.raises(ValueError):
        PiecewiseConstantArrivals(times=(1.0, 5.0), rates=(1.0, 2.0))
    with pytest.raises(ValueError, match="mix_schedule"):
        Scenario(name="bad", description="", arrivals=PoissonArrivals(1.0),
                 profiles=(ClassProfile("a", 10, 10),),
                 mix_schedule=((0.0, (0.5, 0.5)),))
