"""Calibration subsystem: deterministic fitter/model edge cases, the
bitwise-default oracle guarantee, the CPU (roofline-fallback) round-trip
acceptance test, and statistical equivalence of the two serving engines
under a *fitted* non-default iteration-time model."""

import numpy as np
import pytest

from repro.calibration import (AffineModel, CalibrationArtifact,
                               CalibrationGrid, Sample, TableModel,
                               calibrate, fit_affine, fit_surfaces,
                               model_from_artifact, roofline_tau)
from repro.calibration.fit import FitDegenerateError
from repro.configs import get_config
from repro.core.planning import SLISpec, solve_bundled_lp
from repro.core.policies import gate_and_route
from repro.core.types import (DEFAULT_PRIMITIVES, Pricing,
                              ServicePrimitives, WorkloadClass, rates_for,
                              resolve_primitives)
from repro.data.traces import TraceConfig, synth_azure_trace, trace_class_means

PRIM = ServicePrimitives()
PRICE = Pricing(0.1, 0.2)
N = 10
HORIZON = 40.0


# ------------------------------------------------------------- fitter unit
def test_fit_degenerate_constant_x_raises():
    with pytest.raises(FitDegenerateError):
        fit_affine([5.0, 5.0, 5.0], [1.0, 2.0, 3.0])


def test_fit_constant_y_flagged_not_fabricated():
    f = fit_affine([1.0, 2.0, 3.0], [7.0, 7.0, 7.0])
    assert f.constant_y and f.slope == 0.0 and f.intercept == 7.0
    assert f.r2 == 1.0 and f.rmse == 0.0


def test_fit_exact_affine_recovery():
    xs = [32.0, 64.0, 128.0, 256.0, 512.0]
    f = fit_affine(xs, [0.01 + 5e-5 * x for x in xs])
    assert f.intercept == pytest.approx(0.01, rel=1e-9)
    assert f.slope == pytest.approx(5e-5, rel=1e-9)
    assert f.r2 == pytest.approx(1.0, abs=1e-12)
    assert not f.clamped and not f.constant_y


def test_fit_negative_slope_clamped():
    f = fit_affine([1.0, 2.0, 3.0], [3.0, 2.0, 1.0])
    assert f.clamped and f.slope == 0.0


def test_fit_surfaces_uses_reference_batch():
    """Smaller-batch cells are diagnostics, not regression inputs."""
    good = [Sample("mixed", 16, c, 1024, 0.01 + 1e-5 * c, "roofline")
            for c in (32, 64, 128)]
    good += [Sample("solo", 16, 0, k, 0.005 + 1e-8 * k, "roofline")
             for k in (256, 1024, 4096)]
    # batch-8 cells with wildly different times must not move the fit
    noise = [Sample("mixed", 8, c, 1024, 99.0, "roofline")
             for c in (32, 64, 128)]
    noise += [Sample("solo", 8, 0, k, 99.0, "roofline")
              for k in (256, 1024, 4096)]
    fits = fit_surfaces(good + noise)
    assert fits["mix"].intercept == pytest.approx(0.01, rel=1e-9)
    assert fits["solo"].slope == pytest.approx(1e-8, rel=1e-9)


# ------------------------------------------------------------- models unit
def test_default_affine_model_is_seed_constants():
    m = AffineModel()
    assert m.tau_mix(256.0) == (DEFAULT_PRIMITIVES.alpha
                                + DEFAULT_PRIMITIVES.beta * 256.0)
    assert m.tau_solo(0.0) == DEFAULT_PRIMITIVES.tau_solo
    assert m.primitives() == DEFAULT_PRIMITIVES


def test_table_model_interp_matches_knots():
    t = TableModel(mix_x=(32.0, 256.0), mix_y=(0.01, 0.02),
                   solo_x=(0.0, 1000.0), solo_y=(0.005, 0.006))
    assert t.tau_mix(32.0) == 0.01 and t.tau_mix(256.0) == 0.02
    assert t.tau_mix(1.0) == 0.01  # constant extrapolation below
    assert t.tau_mix(512.0) == 0.02  # and above
    assert t.tau_mix(144.0) == pytest.approx(0.015)


def test_grid_validation():
    with pytest.raises(ValueError):
        CalibrationGrid(chunk=(64, 32, 128))  # not increasing
    with pytest.raises(ValueError):
        CalibrationGrid(kv=(1024,))  # cannot identify a slope
    g = CalibrationGrid.tiny()
    assert g.n_cells == len(list(g.cells()))


def test_artifact_schema_version_rejected():
    art = calibrate("qwen2-0.5b", grid=CalibrationGrid.tiny(),
                    backend="roofline")
    d = art.to_dict()
    d["schema_version"] = 999
    with pytest.raises(ValueError, match="schema_version"):
        CalibrationArtifact.from_dict(d)


def test_resolve_primitives_accepts_models():
    m = AffineModel()
    assert resolve_primitives(m) == DEFAULT_PRIMITIVES
    assert resolve_primitives(DEFAULT_PRIMITIVES) is DEFAULT_PRIMITIVES
    with pytest.raises(TypeError):
        resolve_primitives(object())
    cls = WorkloadClass("c", 512, 128, 0.1)
    assert rates_for(cls, m) == rates_for(cls, DEFAULT_PRIMITIVES)
    # and the planning LP consumes a model directly
    classes = [WorkloadClass("a", 512, 128, 0.4, patience=3e-4),
               WorkloadClass("b", 2048, 256, 0.2, patience=3e-4)]
    p1 = solve_bundled_lp(classes, m, PRICE)
    p2 = solve_bundled_lp(classes, DEFAULT_PRIMITIVES, PRICE)
    assert p1.revenue_rate == pytest.approx(p2.revenue_rate, rel=1e-12)


def test_roofline_backend_deterministic():
    """No wall-clock anywhere in the fallback: bit-identical artifacts."""
    g = CalibrationGrid.tiny()
    a1 = calibrate("qwen2-0.5b", grid=g, backend="roofline")
    a2 = calibrate("qwen2-0.5b", grid=g, backend="roofline")
    assert a1.to_json() == a2.to_json()
    cfg = get_config("qwen2-0.5b")
    assert roofline_tau(cfg, tokens=100, kv_tokens=1000) == \
        roofline_tau(cfg, tokens=100, kv_tokens=1000)


# --------------------------------------------------- engine integrations
pytest_sim = pytest.mark.sim


def _mk(seed=42):
    trace = synth_azure_trace(
        TraceConfig(horizon=HORIZON, base_rate=2.0, compression=0.08,
                    seed=seed))
    means = trace_class_means(trace, 2)
    classes = [
        WorkloadClass(nm, m[0], m[1], m[2] / N, patience=3e-4)
        for nm, m in zip(("code", "conv"), means)
    ]
    return trace, classes


def _policy(classes, prim):
    plan = solve_bundled_lp(classes, prim, PRICE,
                            sli=SLISpec(pin_zero_decode_queue=True))
    return gate_and_route(plan)


def _py(trace, classes, pol, **cfg_kw):
    from repro.serving.engine_sim import ClusterEngine, EngineConfig

    cfg = EngineConfig(cfg_kw.pop("prim", PRIM), PRICE, n_servers=N,
                       seed=1, **cfg_kw)
    return ClusterEngine(classes, pol, cfg).run(
        trace, horizon=HORIZON).summary()


def _jx(trace, classes, pol, **cfg_kw):
    from repro.serving.engine_jax import ClusterEngineJAX
    from repro.serving.engine_sim import EngineConfig

    cfg = EngineConfig(cfg_kw.pop("prim", PRIM), PRICE, n_servers=N,
                       **cfg_kw)
    return ClusterEngineJAX(classes, pol, cfg, trace, horizon=HORIZON).run(0)


def _half_width(vals):
    return 1.96 * np.std(vals, ddof=1) / np.sqrt(len(vals))


@pytest_sim
def test_engine_sim_default_model_bitwise_identical():
    """iter_model=AffineModel() (seed constants) must not move a single
    bit of either engine's output vs the historical inline arithmetic."""
    trace, classes = _mk()
    pol = _policy(classes, PRIM)
    assert _py(trace, classes, pol) == \
        _py(trace, classes, pol, iter_model=AffineModel())
    assert _jx(trace, classes, pol) == \
        _jx(trace, classes, pol, iter_model=AffineModel())


@pytest_sim
def test_cpu_roundtrip_fitted_model_all_engines():
    """Acceptance: CPU roofline calibration -> artifact -> fitted model
    plugs into engine_sim, engine_jax AND ctmc_jax; R^2 >= 0.95."""
    from repro.core.ctmc_jax import UniformizedCTMC

    art = calibrate("qwen2-0.5b", grid=CalibrationGrid.tiny(),
                    backend="roofline")
    assert art.min_r2 >= 0.95
    assert np.isfinite([art.alpha, art.beta, art.a_s, art.b_s,
                        art.mix.rmse, art.solo.rmse]).all()
    fitted = model_from_artifact(art, "fitted")
    assert fitted.name == "fitted" and fitted.primitives().alpha == art.alpha

    trace, classes = _mk()
    pol = _policy(classes, fitted)
    m_py = _py(trace, classes, pol, prim=fitted.primitives(),
               iter_model=fitted)
    m_jx = _jx(trace, classes, pol, prim=fitted.primitives(),
               iter_model=fitted)
    assert m_jx["budget_exhausted"] == 0.0
    assert m_py["arrivals"] == m_jx["arrivals"]
    assert m_jx["revenue_rate"] == pytest.approx(
        m_py["revenue_rate"], rel=0.05)

    # ctmc_jax consumes the model via resolve_primitives
    ctmc_classes = [
        WorkloadClass("d", 300, 1000, arrival_rate=0.5, patience=0.1),
        WorkloadClass("p", 3000, 400, arrival_rate=0.5, patience=0.1)]
    plan = solve_bundled_lp(ctmc_classes, fitted, PRICE,
                            sli=SLISpec(pin_zero_decode_queue=True))
    jsim = UniformizedCTMC(ctmc_classes, fitted, PRICE,
                           gate_and_route(plan), n=20, horizon=10.0)
    res = jsim.results_from_raw(jsim.run_batch_raw([0, 1]))
    assert all(np.isfinite(r.revenue_rate_per_server) for r in res)


@pytest_sim
def test_engines_equivalent_under_fitted_model():
    """engine_sim vs engine_jax stay statistically equivalent under a
    *fitted* non-default model (the test_engine_jax CI half-width
    harness), not just under the seed constants."""
    art = calibrate("qwen2-0.5b", grid=CalibrationGrid.tiny(),
                    backend="roofline")
    fitted = model_from_artifact(art, "fitted")
    assert fitted.jax_params() != AffineModel().jax_params()  # non-default

    n_traces = 5
    rev = []
    for s in range(n_traces):
        trace, classes = _mk(seed=200 + s)
        pol = _policy(classes, fitted)
        kw = dict(prim=fitted.primitives(), iter_model=fitted)
        m_py = _py(trace, classes, pol, **kw)
        m_jx = _jx(trace, classes, pol, **kw)
        assert m_jx["budget_exhausted"] == 0.0
        assert m_py["arrivals"] == m_jx["arrivals"]
        assert m_jx["revenue_rate"] == pytest.approx(
            m_py["revenue_rate"], rel=0.05)
        rev.append((m_py["revenue_rate"], m_jx["revenue_rate"]))
    py_v, jx_v = np.array(rev).T
    tol = 2.0 * (_half_width(py_v) + _half_width(jx_v)) + 1e-9
    assert abs(py_v.mean() - jx_v.mean()) <= tol


@pytest_sim
def test_table_model_agrees_across_engines():
    """The jnp.interp step-kernel path matches the Python TableModel."""
    art = calibrate("qwen2-0.5b", grid=CalibrationGrid.tiny(),
                    backend="roofline")
    table = model_from_artifact(art, "table")
    trace, classes = _mk()
    pol = _policy(classes, table)
    kw = dict(prim=table.primitives(), iter_model=table)
    m_py = _py(trace, classes, pol, **kw)
    m_jx = _jx(trace, classes, pol, **kw)
    assert m_jx["budget_exhausted"] == 0.0
    assert m_jx["revenue_rate"] == pytest.approx(
        m_py["revenue_rate"], rel=0.05)
    assert m_jx["completions"] == pytest.approx(
        m_py["completions"], rel=0.05, abs=3)
