"""Integration tests for the real-compute serving path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.planning import solve_bundled_lp
from repro.core.types import Pricing, ServicePrimitives, WorkloadClass
from repro.models import model as M
from repro.serving.cluster import RealCluster
from repro.serving.engine import ServerEngine, SlotRequest
from repro.serving.steps import (init_server_state, make_decode_step,
                                 make_mixed_step)


def _mk(arch="qwen2-0.5b"):
    cfg = get_config(arch, reduced=True)
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_mixed_step_prefill_isolation():
    """A mixed iteration must not corrupt co-resident decode slots."""
    cfg, params = _mk()
    B, max_len, C = 4, 128, 16
    mixed = jax.jit(make_mixed_step(cfg, C))
    dec = jax.jit(make_decode_step(cfg))

    # two engines with the same two active decode slots; one also prefills
    def setup():
        st = init_server_state(cfg, B, max_len, jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 2,
                                  cfg.vocab_size)
        pos = jnp.broadcast_to(jnp.arange(8)[None], (B, 8))
        from repro.serving.steps import make_prefill_step
        pf = make_prefill_step(cfg)
        caches, nxt = pf(params, st["caches"], toks, pos)
        st = dict(st, caches=caches,
                  length=jnp.full((B,), 8, jnp.int32),
                  last_token=nxt,
                  active=jnp.array([True, True, False, False]))
        return st

    s_solo = dec(params, setup())[0]
    chunk = jax.random.randint(jax.random.PRNGKey(2), (C,), 2,
                               cfg.vocab_size)
    s_mixed, dec_tokens, _ = mixed(params, setup(), 3, chunk,
                                   jnp.zeros((1, 1), jnp.int32))
    # decode slots 0 and 1 advanced identically in both modes
    np.testing.assert_array_equal(np.asarray(s_solo["last_token"][:2]),
                                  np.asarray(s_mixed["last_token"][:2]))
    np.testing.assert_array_equal(np.asarray(s_solo["length"][:2]),
                                  np.asarray(s_mixed["length"][:2]))


def test_kv_migration_preserves_tokens():
    """extract_slot/inject_slot must not change the decoded stream."""
    cfg, params = _mk()
    prim = ServicePrimitives(batch_cap=4, chunk=16)
    eng_a = ServerEngine(cfg, params, prim=prim, max_len=128)
    eng_b = ServerEngine(cfg, params, prim=prim, max_len=128)

    rng = np.random.default_rng(0)
    toks = rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
    req = SlotRequest(rid=0, cls=0, prompt_len=24, decode_len=6)
    eng_a.start_prefill(req, toks)
    while eng_a.has_prefill:
        eng_a.step()
    # migrate to engine B and decode there
    slot = next(i for i, s in enumerate(eng_a.slots) if s is req)
    _, sub, meta = eng_a.extract_slot(slot)
    eng_b.inject_slot(0, req, sub, meta)
    outs_b = []
    while req.tokens_out < req.decode_len:
        eng_b.step()
    outs_b = list(req.out_tokens)

    # reference: same request decoded without migration
    req2 = SlotRequest(rid=1, cls=0, prompt_len=24, decode_len=6)
    eng_c = ServerEngine(cfg, params, prim=prim, max_len=128)
    eng_c.start_prefill(req2, toks)
    while eng_c.has_prefill:
        eng_c.step()
    slot2 = next(i for i, s in enumerate(eng_c.slots) if s is req2)
    eng_c.activate_slot(slot2)
    while req2.tokens_out < req2.decode_len:
        eng_c.step()
    assert outs_b == req2.out_tokens


def test_real_cluster_end_to_end():
    cfg, params = _mk()
    prim = ServicePrimitives(batch_cap=4, chunk=16)
    pricing = Pricing()
    classes = [WorkloadClass("a", 24, 6, 0.5, 0.1),
               WorkloadClass("b", 8, 12, 0.5, 0.1)]
    plan = solve_bundled_lp(classes, prim, pricing)
    cl = RealCluster(cfg, params, classes, plan, prim, pricing,
                     n_servers=2, max_len=128)
    rng = np.random.default_rng(1)
    reqs, t = [], 0.0
    for k in range(6):
        t += rng.exponential(0.5)
        c = k % 2
        P = classes[c].prompt_len
        reqs.append((t, c, rng.integers(2, cfg.vocab_size, size=P)
                     .astype(np.int32), classes[c].decode_len))
    m = cl.run(reqs, horizon=500.0)
    assert m.completions == 6
    assert m.revenue > 0
